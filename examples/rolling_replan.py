"""Rolling weekly re-planning: Algorithm 1 as the paper actually runs it.

    PYTHONPATH=src python examples/rolling_replan.py

The one-shot planner (`examples/capacity_planning.py`) fits a forecaster
once and buys every commitment band up front.  Operationally the paper
re-runs the decision every period: new demand history arrives, the
forecaster is re-fit, and only *incremental* tranches are purchased on top
of what is already committed — expiring tranches roll off, shortfalls price
at on-demand.  This walkthrough replays that loop over a two-year drifting
synthetic fleet and compares three operating points on the same window:

    rolling    re-plan every `cadence_weeks`, buy increments
    one-shot   buy the week-26 plan once, let tranches expire
    hindsight  the optimal constant stack given the realized demand

The replay is one `lax.scan` program (see `repro.core.replan`), so the
whole multi-year loop runs in seconds on CPU.

With `--migration` the fleet undergoes hardware-generation turnover
(`capacity.generations`) and the planner re-plans with the share-based
migration-aware forecaster plus cloud-level convertible commitments
(`migration=True, convertible=True`) — the subsystem that keeps dying-
family tranches from stranding.
"""

import argparse

import numpy as np

from repro.core import api
from repro.data import traces


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--migration", action="store_true",
                    help="turnover fleet + migration-aware re-planning "
                         "with convertible commitments")
    args = ap.parse_args()

    pools = traces.synthetic_pool_set(
        num_pools=4, num_hours=24 * 7 * 104, migration=args.migration,
    )
    print("== fleet ==")
    for key, row in zip(pools.keys, pools.demand):
        cloud, region, family = key
        print(f"  {cloud:5s} {region:9s} {family:12s} "
              f"mean {row.mean():7.1f} peak {row.max():7.1f} chips")

    rep = api.plan(api.PlanRequest(
        pools=pools, mode="rolling",
        rolling=api.RollingConfig(
            cadence_weeks=2, start_weeks=26,
        ),
        horizon_weeks=6, term_weighting=1.0,
        migration=args.migration, convertible=args.migration or None,
    ))

    print(f"\n== rolling replay (weeks {rep.weeks[0]}..{rep.weeks[-1]}, "
          f"cadence {rep.cadence_weeks}w) ==")
    sample = rep.weeks[:: max(len(rep.weeks) // 8, 1)]
    print("  week   committed   on-demand   utilization   stack")
    for w in sample:
        i = int(w - rep.weeks[0])
        print(f"  {int(w):4d} {rep.committed_cost[i].sum():11.0f} "
              f"{rep.on_demand_cost[i].sum():11.0f} "
              f"{rep.utilization[i].mean() * 100:12.1f}% "
              f"{rep.active[i].sum():7.1f}")

    total_tranches = sum(
        len(lad.amount) for lad in rep.ladders.ladders
    )
    print(f"\n  tranches purchased: {total_tranches} across "
          f"{len(rep.keys)} pool ladders")
    skus = {
        rep.options[k].name
        for k in np.flatnonzero((rep.increments > 0).any((0, 1)))
    }
    print(f"  SKUs on the stack:  {', '.join(sorted(skus))}")
    if rep.conv_options is not None:
        print(f"  convertible stack:  {rep.conv_active[-1].sum():.1f} chips "
              f"across {len(rep.conv_clouds)} clouds "
              f"(re-pinned weekly; spend {rep.conv_committed_cost.sum():.0f})")

    print("\n== rolling vs one-shot vs hindsight ==")
    print(f"  rolling total:    {rep.total_cost:14.0f}")
    print(f"  one-shot total:   {rep.one_shot_cost:14.0f}  "
          f"(rolling saves {rep.savings_vs_one_shot * 100:.1f}%)")
    print(f"  hindsight total:  {rep.hindsight_cost:14.0f}  "
          f"(rolling regret {rep.regret_vs_hindsight * 100:+.1f}%)")
    print(f"  all-on-demand:    {rep.all_on_demand_cost:14.0f}  "
          f"(rolling saves {rep.savings_vs_on_demand * 100:.1f}%)")

    # Where the one-shot plan bleeds: its tranches expire and demand grows
    # past the frozen stack, so its weekly cost curve bends up while the
    # rolling curve keeps tracking demand.
    last = slice(-8, None)
    print(f"\n  last-8-week spend: rolling {rep.weekly_cost[last].sum():.0f} "
          f"vs one-shot {rep.one_shot_weekly_cost[last].sum():.0f}")

    # The same loop replayed over a batch of perturbed demand futures —
    # one scan program carries (scenarios x pools); scenario 0 is the
    # realized path, so the distribution brackets the replay above.
    scen = api.plan(api.PlanRequest(
        pools=pools, mode="rolling",
        rolling=api.RollingConfig(cadence_weeks=2, start_weeks=26),
        horizon_weeks=6, term_weighting=1.0,
        migration=args.migration, convertible=args.migration or None,
        scenarios=api.ScenarioConfig(n_scenarios=8, family="regime"),
    ))
    s = scen.summary()
    print(f"\n== {scen.n_scenarios} regime-switch scenarios ==")
    print(f"  cost   mean {s['scenario_cost_mean']:14.0f}  "
          f"p95 {s['scenario_cost_p95']:14.0f}")
    print(f"  CR     mean {s['scenario_cr_mean']:8.3f}  "
          f"p95 {s['scenario_cr_p95']:8.3f}")
    print(f"  regret mean {s['scenario_regret_mean']:14.0f}  "
          f"p95 {s['scenario_regret_p95']:14.0f}")


if __name__ == "__main__":
    main()
