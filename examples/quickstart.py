"""Quickstart: the Shaved Ice pipeline on one resource pool in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Generates a 2-year hourly demand trace calibrated to the paper's dataset,
fits the forecaster, runs Algorithm 1, and prints the commitment decision
with its cost breakdown.
"""

import jax
import numpy as np

from repro.core import commitment as cm
from repro.core import demand as dm
from repro.core import planner as pl
from repro.core.demand import HOURS_PER_WEEK


def main():
    # 1. Two years of hourly demand for one pool (paper §2 characteristics).
    trace = dm.synth_demand(24 * 365 * 2, key=jax.random.PRNGKey(0))
    stats = dm.characterize(np.asarray(trace))
    print("== demand characterization (paper §2.2) ==")
    for k, v in stats.items():
        print(f"  {k:24s} {v:.3f}")

    # 2. The two-sided commitment objective (paper §3.2, Fig 4).
    last_2w = trace[-HOURS_PER_WEEK * 2:]
    levels, costs, best = cm.scenario_costs(last_2w, 9)
    c_exact = float(cm.optimal_commitment_quantile(last_2w))
    print("\n== commitment scenarios (paper Fig 4) ==")
    for i, (l, c) in enumerate(zip(levels, costs)):
        marker = " <- best" if i == int(best) else ""
        print(f"  scenario {i + 1}: level {float(l):8.1f} "
              f"cost {float(c):12.0f}{marker}")
    print(f"  exact optimum (A/(A+B) quantile): {c_exact:.1f}")

    # 3. Algorithm 1: forecast-driven commitment for next week.
    res = pl.plan_commitment(trace, num_horizons=12)
    print("\n== Algorithm 1 (paper §3.3.3) ==")
    print("  per-horizon optimal levels: "
          f"{np.array2string(np.asarray(res.per_horizon_levels), precision=1)}")
    print(f"  c* = min over horizons  = {res.commitment:.1f} "
          f"(binding horizon: {res.argmin_horizon + 1} weeks out)")

    # 4. What the decision costs over the binding horizon.
    w = (res.argmin_horizon + 1) * HOURS_PER_WEEK
    seg = res.forecast[:w]
    print("  expected C(c*) over horizon: "
          f"{float(cm.commitment_cost(seg, res.commitment)):.0f}")
    print("  unused-commitment fraction:  "
          f"{float(cm.unused_commitment_fraction(seg, res.commitment)) * 100:.1f}%"
          " (paper §4: ~4.3%)")


if __name__ == "__main__":
    main()
