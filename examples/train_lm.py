"""End-to-end training driver: train an LM with the production loop —
checkpointing, restart, straggler watchdog, deterministic data.

    PYTHONPATH=src python examples/train_lm.py                 # ~20M params
    PYTHONPATH=src python examples/train_lm.py --full          # ~110M params
    PYTHONPATH=src python examples/train_lm.py --arch rwkv6-3b # any family

The default config is sized for this CPU container; --full trains a ~110M
stablelm-family model for 300 steps (the assignment's "100M for a few
hundred steps" driver — expect ~1-2h on one CPU core; on real accelerators
the same driver runs unchanged with a mesh + shardings).
"""

import argparse
import dataclasses

from repro import configs
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models.model import build
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def small_cfg(arch: str):
    return configs.reduced(arch)


def full_cfg(arch: str):
    """~110M-parameter member of the chosen family."""
    base = configs.get(arch)
    return dataclasses.replace(
        base,
        num_layers=8,
        d_model=768,
        num_heads=12,
        num_kv_heads=min(base.num_kv_heads, 12),
        head_dim=64,
        d_ff=2048,
        vocab_size=32_000,
        max_seq=512,
        **({"mrope_sections": (8, 12, 12)} if base.mrope_sections else {}),
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b",
                    choices=sorted(configs.ARCHS))
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    args = ap.parse_args()

    cfg = full_cfg(args.arch) if args.full else small_cfg(args.arch)
    model = build(cfg)
    print(f"arch={args.arch} params={model.num_params() / 1e6:.1f}M")

    steps = args.steps or (300 if args.full else 60)
    seq = 256 if args.full else 64
    batch = 8
    data = TokenPipeline(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=seq, global_batch=batch,
    ))
    trainer = Trainer(
        model, data,
        TrainerConfig(
            total_steps=steps, ckpt_every=max(steps // 5, 10),
            opt=AdamWConfig(lr=3e-3 if not args.full else 6e-4,
                            warmup_steps=max(steps // 10, 5)),
        ),
        args.ckpt_dir,
    )
    start = trainer.init_or_restore()
    if start:
        print(f"resumed from checkpoint at step {start}")
    losses = trainer.fit()
    print(f"step {trainer.step}: loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    if trainer.watchdog.flagged_steps:
        print("straggler watchdog flagged steps: "
              f"{trainer.watchdog.flagged_steps}")
    print(f"checkpoints: {trainer.ckpt.all_steps()} in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
