"""End-to-end capacity planning for a multi-architecture fleet.

    PYTHONPATH=src python examples/capacity_planning.py

Builds the 10-architecture serving fleet + training jobs, rolls them into a
chip-demand trace, and runs the full paper pipeline: Algorithm 1 commitment,
laddered purchases, §4 time shifting of the framework's own deferrable
workloads, and the total cost vs all-on-demand.
"""

import numpy as np

from repro.capacity.pricing import on_demand_premium
from repro.capacity.scheduler import default_workloads, schedule
from repro.capacity.simulator import (
    default_fleet,
    fleet_chip_demand,
    fleet_pool_demand,
    plan_fleet,
    plan_fleet_portfolio,
)
from repro.core import planner as pl
from repro.core import commitment as cm
from repro.core import ladder as ld
from repro.core.demand import HOURS_PER_WEEK


def main():
    fleets, jobs = default_fleet()
    print("== fleet ==")
    for f in fleets:
        print(f"  {f.arch:24s} {f.chips_per_replica:4d} chips/replica")
    for j in jobs:
        print(f"  train {j.arch:18s} {j.chips:4d} chips x "
              f"{j.duration_hours // 24}d starting day {j.start_hour // 24}")

    demand = fleet_chip_demand(fleets, jobs, 24 * 7 * 40)
    print(f"\n  mean fleet demand {demand.mean():.0f} chips, "
          f"peak {demand.max():.0f}, on-demand premium "
          f"{on_demand_premium():.2f}x")

    # Commitment planning (Algorithm 1) with and without time shifting.
    base = plan_fleet(demand, horizon_weeks=8)
    shifted = plan_fleet(demand, horizon_weeks=8, shiftable_frac=0.05)
    print("\n== commitment plan (paper §3) ==")
    print(f"  c* = {base.commitment:.0f} committed chips")
    print(f"  total cost:           {base.total_cost:14.0f}")
    print(f"  all-on-demand cost:   {base.all_on_demand_cost:14.0f}")
    print(f"  savings:              {base.savings_vs_on_demand * 100:13.1f}%")
    print("  with 5% time shifting: on-demand spill "
          f"{base.on_demand_cost:.0f} -> {shifted.on_demand_cost:.0f}")

    # Portfolio of Table-2 purchasing options instead of one averaged level.
    port = plan_fleet_portfolio(demand, horizon_weeks=8)
    hedged = plan_fleet_portfolio(demand, horizon_weeks=8, term_weighting=1.0)
    print("\n== commitment portfolio (Table 2 SKUs) ==")
    for opt, w in zip(port.options, port.widths):
        if w > 0:
            print(f"  {opt.name:24s} rate {opt.rate:.2f} "
                  f"term {opt.term_weeks:3d}w  width {w:7.1f} chips")
    print(f"  on-demand above {port.total_commitment:.0f} chips")
    print(f"  total cost:           {port.total_cost:14.0f}")
    print(f"  vs single-level plan: {port.savings_vs_single_level * 100:12.2f}% cheaper")
    print(f"  vs all-on-demand:     {port.savings_vs_on_demand * 100:12.1f}%")
    hedge_names = [o.name for o, w in zip(hedged.options, hedged.widths)
                   if w > 0]
    print(f"  term-weighted hedge stack: {', '.join(hedge_names)} "
          f"({hedged.savings_vs_single_level * 100:.2f}% vs single-level)")

    # Per-pool planning (paper §6: demand is keyed per cloud/region/family,
    # commitments are purchased per cloud/SKU — the aggregate trace above
    # cannot answer "how much 3y GCP in region_2?").
    pools = fleet_pool_demand(fleets, jobs, 24 * 7 * 40)
    pool_plan = pl.plan_fleet_pools(pools, horizon_weeks=8)
    print("\n== per-pool plans (paper §6 pool granularity) ==")
    for entry in pool_plan.per_pool:
        if entry.total_commitment < 0.05:    # skip numerical-dust stacks
            continue
        cloud, region, family = entry.key
        print(f"  {cloud:5s} {region:9s} {family:12s} "
              f"commit {entry.total_commitment:7.1f} chips  "
              f"cost {entry.spend.total:10.0f}  "
              f"savings {entry.spend.savings_vs_on_demand * 100:5.1f}%")
    gcp_3y = pool_plan.commitment(cloud="gcp", term_weeks=156)
    print(f"  3y GCP commitment across regions: {gcp_3y:.1f} chips")
    print(f"  fleet total cost:     {pool_plan.total_cost:14.0f}")
    print("  vs all-on-demand:     "
          f"{pool_plan.savings_vs_on_demand * 100:13.1f}%")
    print("  pooling premium:      "
          f"{pool_plan.pooling_premium * 100:+13.2f}%  "
          "(per-pool plans vs one aggregate plan — capacity cannot "
          "actually pool across clouds)")

    # Laddered purchases over the planning window (paper §3.3.4).
    weeks = 8
    weekly_targets = [
        float(cm.optimal_commitment_quantile(
            demand[-(weeks - w) * HOURS_PER_WEEK:][:HOURS_PER_WEEK]
            .astype(np.float32)))
        for w in range(weeks)
    ]
    lad = ld.plan_purchases(np.asarray(weekly_targets),
                            term_hours=52 * HOURS_PER_WEEK)
    print("\n== ladder (paper §3.3.4) ==")
    print(f"  tranches purchased: {len(lad.amount)}; "
          f"amounts: {np.array2string(lad.amount, precision=0)}")

    # Schedule the framework's deferrable workloads into the troughs (§4).
    week = demand[-HOURS_PER_WEEK:]
    c_week = float(cm.optimal_commitment_quantile(week.astype(np.float32)))
    report = schedule(week, c_week, default_workloads())
    print("\n== deferrable workload schedule (paper §4) ==")
    for name, slices in report.placements.items():
        hours = len(slices)
        print(f"  {name:24s} -> {hours} trough slots")
    print(f"  on-demand avoided: {report.savings:.0f} "
          f"({report.savings_frac * 100:.0f}%)")


if __name__ == "__main__":
    main()
