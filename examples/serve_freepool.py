"""End-to-end serving driver: continuous-batching engine + §5 free-pool
autoscaling.

    PYTHONPATH=src python examples/serve_freepool.py

Serves batched requests through a small model on the slotted engine, then
simulates a day of fleet-level demand against the free-pool autoscaler,
comparing static vs predicted pool sizing (paper Fig 12).
"""

import jax
import numpy as np

from repro import configs
from repro.core import demand as dm
from repro.models.model import build
from repro.serve.autoscaler import AutoscalerConfig, FreePoolAutoscaler
from repro.serve.engine import Request, ServeEngine


def main():
    # --- engine demo: batched requests through one replica ---
    model = build(configs.reduced("stablelm-1.6b"))
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, num_slots=4, cache_len=96)
    rng = np.random.default_rng(0)

    requests = [
        Request(rid=i,
                prompt=rng.integers(0, 256, size=rng.integers(4, 12)).astype(np.int32),
                max_new_tokens=8)
        for i in range(10)
    ]
    pending = list(requests)
    ticks = 0
    while pending or engine.active_slots:
        while pending and engine.try_admit(params, pending[0]):
            pending.pop(0)
        engine.tick(params)
        ticks += 1
    print(f"served {len(requests)} requests in {ticks} engine ticks "
          f"(continuous batching over {engine.num_slots} slots)")
    print(f"  sample generation: {requests[0].generated}")

    # --- free-pool autoscaling (paper §5) ---
    hist = np.asarray(dm.synth_demand(
        24 * 21, dm.DemandConfig(base_level=20.0),
        key=jax.random.PRNGKey(1))).astype(np.float32)
    fut = np.asarray(dm.synth_demand(
        24 * 23, dm.DemandConfig(base_level=20.0),
        key=jax.random.PRNGKey(1))).astype(np.float32)[-48:]

    print("\nfree-pool sizing over a 2-day horizon (paper Fig 12):")
    for label, static in [("predicted", None),
                          ("static p50", float(np.percentile(hist, 50))),
                          ("static max", float(hist.max()))]:
        auto = FreePoolAutoscaler(AutoscalerConfig(provision_latency=2))
        stats = auto.run(hist, fut, static_size=static)
        print(f"  {label:12s} slo_misses={stats.slo_misses:4d} "
              f"replica_ticks={stats.replica_ticks:6d} "
              f"cost={stats.cost:8.0f}")


if __name__ == "__main__":
    main()
