"""Hardware-generation turnover: the fourth pillar next to commitments,
pools, and spot.

    PYTHONPATH=src python examples/generation_turnover.py

Fleet demand is the product of three drivers (paper §2.3): user workload
growth x hardware generational turnover x software efficiency.  A family
launch moves demand volume from old-family pools to successor pools along
a logistic S-curve — which a per-pool forecaster reads as organic decay,
so a migration-blind planner keeps buying tranches on a dying family and
strands them.  This walkthrough runs the whole subsystem:

  1. synthesize a 3-year fleet with two planted family turnovers
     (`pricing.GENERATIONS` successor pairs, `capacity.generations`
     logistic transfer + software-efficiency deflator);
  2. fit the drivers back out of the realized fleet
     (`migration.decompose_drivers`): logistic midpoints/spans per edge,
     hardware index, efficiency drift vs the planted user volume;
  3. re-plan the fleet weekly, migration-blind vs migration-aware with
     cloud-level *convertible* commitments that re-pin to the successor
     family each week — the unstranding lever.
"""


from repro.capacity import generations as gn
from repro.capacity import pricing
from repro.core import migration as mg
from repro.core import planner as pl
from repro.data import traces


def main():
    # Two family turnovers inside a 2-year window (quick enough for CI;
    # the acceptance-scale 3-year run lives in tests/test_generations.py).
    plant = gn.MigrationConfig(generations=(
        pricing.Generation("aws", "C6i", "C7i", 20, 28.0, 0.25),
        pricing.Generation("gcp", "N2-Standard", "N4-Standard", 55, 24.0,
                           0.50),
    ))
    num_hours = 24 * 7 * 104
    base = traces.synthetic_base_pool_set(
        num_pools=4, num_hours=num_hours, migration=plant
    )
    pools = gn.migrate_pool_set(base, plant)

    print("== turnover fleet (volume moves old family -> successor) ==")
    for key, row in zip(pools.keys, pools.demand):
        name = "/".join(key)
        print(f"  {name:28s} first-month {row[:720].mean():7.1f}  "
              f"last-month {row[-720:].mean():7.1f} chips")

    print("\n== driver decomposition (fitted back from realized demand) ==")
    dec = mg.decompose_drivers(
        pools, migration=plant, user_volume=base.demand.sum(0)
    )
    for ef in dec.edge_fits:
        print(f"  {ef.cloud}: {ef.old_family} -> {ef.new_family}  "
              f"midpoint wk {ef.midpoint_weeks:5.1f}  "
              f"span wk {ef.span_weeks:5.1f}  "
              f"adopted {ef.final_share * 100:5.1f}%")
    print("  software efficiency drift: "
          f"{dec.efficiency_per_year * 100:.1f}%/yr "
          f"(planted {plant.software_efficiency_per_year * 100:.0f}%/yr)")
    print(f"  hardware index at end: {dec.hardware_index[-1]:.3f} "
          "(VMs per old-equivalent VM after turnover)")

    print("\n== rolling re-plan: migration-blind vs aware + convertible ==")
    kw = dict(
        mode="rolling", cadence_weeks=2, start_weeks=20, horizon_weeks=26,
        compare=False,
    )
    blind = pl.plan_fleet_pools(pools, **kw)
    aware = pl.plan_fleet_pools(
        pools, migration=plant, convertible=True, **kw
    )
    print(f"  migration-blind rolling:      {blind.total_cost:14.0f}")
    print(f"  aware + convertible rolling:  {aware.total_cost:14.0f}  "
          f"({(1 - aware.total_cost / blind.total_cost) * 100:.1f}% "
          "cheaper)")
    s = aware.summary()
    print(f"  convertible spend {s['convertible_cost']:.0f}, final "
          f"cloud-level width {s['convertible_final_width']:.1f} chips")
    conv_tranches = sum(
        len(lad.amount) for lad in aware.conv_ladders.ladders
    )
    print(f"  convertible tranches: {conv_tranches} across clouds "
          f"{', '.join(aware.conv_clouds)} (re-pinned to the successor "
          "family each week)")


if __name__ == "__main__":
    main()
