"""Telemetry walkthrough: where every dollar of a rolling plan went —
and whether the forecast bands that priced its risk were calibrated.

    PYTHONPATH=src python examples/plan_telemetry.py \
        [--ledger-out LEDGER.jsonl] [--spans-out SPANS.json] \
        [--calib-out CALIB.jsonl] [--calib-fail-above DRIFT]

`telemetry=TelemetryConfig(calibration=True, provenance=True)` on a
rolling :class:`~repro.core.api.PlanRequest` makes the replay scan emit
its own billing decomposition (per-week x per-pool x per-source),
the weekly forecast fractile levels scored against realized demand
(:class:`repro.obs.CalibrationCube`), and per-week decision records
(:class:`repro.obs.DecisionLog`).  The ledger's weekly row-sums must
reconcile with the report's weekly costs to f32 machine precision, and
the calibration coverage must stay inside the drift gate; this example
**exits nonzero on reconciliation drift or calibration-gate breach**,
which is exactly the gate the CI bench-smoke job runs.

Wall time is recorded caller-side with the span profiler
(`repro.obs.spans`) — the planner core itself never reads a clock
(analysis rules R2/R7).

The exported JSONL round-trips through the CLI:

    python -m repro.obs report LEDGER.jsonl
    python -m repro.obs diff  A.jsonl B.jsonl --fail-above 1.0
    python -m repro.obs calib CALIB.jsonl --fail-above 0.5
"""

import argparse
import sys

from repro.core import api
from repro.data import traces
from repro.obs import SpanRecorder, TelemetryConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ledger-out", default=None, metavar="PATH",
                    help="export the cost ledger as JSONL")
    ap.add_argument("--spans-out", default=None, metavar="PATH",
                    help="export the wall-clock span report as JSON")
    ap.add_argument("--calib-out", default=None, metavar="PATH",
                    help="export the calibration cube as JSONL")
    # The demo fleet trends hard (migration ramps), so the trailing-window
    # bands under-cover by design — exactly the miscalibration the cube is
    # built to surface.  The default gate is therefore generous; steady
    # fleets sit well under 0.05 (see tests/test_obs.py::TestCalibration).
    ap.add_argument("--calib-fail-above", type=float, default=0.5,
                    metavar="DRIFT",
                    help="exit 1 when max |coverage - nominal| exceeds "
                         "this (default %(default)s)")
    args = ap.parse_args()

    rec = SpanRecorder()
    with rec.span("example/pools", phase="host"):
        pools = traces.synthetic_pool_set(
            num_pools=4, num_hours=24 * 7 * 20, migration=True,
        )

    # All bands on: spot floor, migration-aware forecaster, cloud-level
    # convertible commitments — the richest bill the planner can produce.
    with rec.span("example/plan", phase="execute"):
        rep = api.plan(api.PlanRequest(
            pools=pools, mode="rolling",
            rolling=api.RollingConfig(cadence_weeks=2, start_weeks=6,
                                      compare=False),
            horizon_weeks=4,
            spot=True, migration=True, convertible=True,
            telemetry=TelemetryConfig(calibration=True, provenance=True),
        ))
    led = rep.ledger

    print("== cost attribution (weeks "
          f"{int(led.weeks[0])}..{int(led.weeks[-1])}) ==")
    print("spend by source:")
    for s, v in sorted(led.by_source().items(), key=lambda kv: -kv[1]):
        print(f"  {s:24s} {v:14,.2f}")
    print("spend by entity:")
    for e, v in sorted(led.by_entity().items(), key=lambda kv: -kv[1]):
        print(f"  {e:28s} {v:14,.2f}")

    econ = led.unit_economics()
    print("\n== unit economics ==")
    print(f"  total cost              {econ['total_cost']:14,.2f}")
    print(f"  idle committed hours    {econ['idle_committed_hours']:14,.0f}"
          f"  ({econ['idle_fraction']:.1%} of committed)")
    print(f"  mean pool utilization   {econ['utilization_mean']:14.1%}")
    print(f"  cost per used chip-hour "
          f"{econ['cost_per_used_chip_hour']:14.4f}")

    one_cell = led.attribute(week=int(led.weeks[-1]),
                             pool=led.entities[0])
    print(f"\none cell of the bill — week {int(led.weeks[-1])}, "
          f"{led.entities[0]}: {one_cell:,.2f}")

    cube = rep.calibration
    print("\n== forecast calibration ==")
    print(cube.report())

    dlog = rep.decision_log
    print("\n== decision provenance ==")
    for k, v in dlog.summary().items():
        print(f"  {k:24s} {v}")
    last_dec = int(dlog.decision_weeks[-1])
    exp = dlog.explain(last_dec)
    print(f"  binding constraints at week {last_dec}: "
          + ", ".join(f"{p}={d['binding']}"
                      for p, d in sorted(exp["pools"].items())))

    with rec.span("example/export", phase="host"):
        if args.ledger_out:
            led.to_jsonl(args.ledger_out)
            print(f"wrote {args.ledger_out}")
        if args.spans_out:
            rec.to_json(args.spans_out)
            print(f"wrote {args.spans_out}")
        if args.calib_out:
            cube.to_jsonl(args.calib_out)
            print(f"wrote {args.calib_out}")

    print("\n== wall-clock spans ==")
    print(rec.report())

    # The CI gates: ledger row-sums must reconcile with the report, and
    # forecast coverage must stay inside the drift budget.
    res = led.reconcile(rep)
    print(f"\nreconciliation: max_rel {res['max_rel']:.2e} "
          f"(gate {res['rtol']:.0e}) -> "
          f"{'OK' if res['ok'] else 'DRIFT'}")
    drift = cube.max_coverage_drift
    print(f"calibration: max coverage drift {drift:.3f} "
          f"(gate {args.calib_fail_above:.3f}) -> "
          f"{'OK' if drift <= args.calib_fail_above else 'BREACH'}")
    if not res["ok"]:
        print(f"reconciliation drift: {res}", file=sys.stderr)
        sys.exit(1)
    if drift > args.calib_fail_above:
        print(f"calibration gate breach: drift {drift:.4f} > "
              f"{args.calib_fail_above:.4f}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
