"""Policy tournament: competitive ratio and regret across workload families.

    PYTHONPATH=src python examples/policy_tournament.py

The rolling replay (`examples/rolling_replan.py`) is a harness; the weekly
purchasing decision behind it is a *policy* (`repro.core.policy`).  Besides
the paper's forecast-and-solve loop, the registry carries the forecast-free
online hedging algorithms of Ambati, Urgaonkar & Sitaraman ("Hedge Your
Bets", arXiv 2004.04302) — per-capacity-band ski rental with classical
competitive-ratio guarantees (2 deterministic, e/(e-1) randomized).

This walkthrough runs the tournament rig (`repro.core.tournament`): every
policy replays every seeded demand path of the scenario taxonomy
(`repro.data.scenarios` — steady / burst / cyclic / declining /
unpredictable), one compiled vmapped program per policy, scored against the
per-path hindsight-optimal constant stack.  Swapping a policy into the full
planner is one kwarg:

    pl.plan_fleet_pools(pools, mode="rolling", policy="deterministic_hedge")
"""

import time

from repro.core import policy as pol
from repro.core import tournament as tn

# Small shapes so the walkthrough stays fast; drop the overrides for the
# paper-scale defaults (5 families x 32 seeds x 48 weeks).
REPORT_KW = dict(
    policies=("rolling_portfolio", "one_shot", "deterministic_hedge",
              "randomized_hedge"),
    families=("steady", "burst", "declining"),
    num_pools=2, num_weeks=24, num_seeds=4,
    start_weeks=12, cadence_weeks=2, horizon_weeks=4,
)


def main():
    t0 = time.perf_counter()
    rep = tn.run_tournament(**REPORT_KW)
    rep.elapsed_s = time.perf_counter() - t0

    print("== mean competitive ratio (cost / per-path hindsight) ==")
    print(rep.to_markdown())

    print("\n== tails ==")
    for p in rep.policies:
        worst = max(
            (rep.family_stats(p, f)["cr_max"], f) for f in rep.families
        )
        print(f"  {p:20s} worst CR {worst[0]:6.3f}  on {worst[1]}")

    det = rep.family_stats("deterministic_hedge", "steady")
    rnd = rep.family_stats("randomized_hedge", "steady")
    print(f"\nclassical bounds on the steady family: "
          f"deterministic {det['cr_max']:.3f} <= "
          f"{pol.DETERMINISTIC_CR_BOUND:.3f}, "
          f"randomized mean {rnd['cr_mean']:.3f} <= "
          f"{pol.RANDOMIZED_CR_BOUND:.3f}")
    roll = rep.family_stats("rolling_portfolio", "declining")["cr_mean"]
    hedge = rep.family_stats("deterministic_hedge", "declining")["cr_mean"]
    print(f"declining fleet: forecasting planner CR {roll:.3f} vs "
          f"forecast-free hedge {hedge:.3f} — forecasts pay for themselves "
          f"when demand has structure")
    print(f"\n({rep.num_seeds} seeds/family, {rep.elapsed_s:.1f}s)")


if __name__ == "__main__":
    main()
