"""Spot capacity as the third purchasing option: risk-priced, chance-bound.

    PYTHONPATH=src python examples/spot_portfolio.py

Commitments are cheap but rigid; on-demand is flexible but 2.1x the price.
Spot/preemptible capacity is the hedge between them: deeply discounted,
pay-only-while-used — and revocable at any hour.  This walkthrough prices
the revocation risk into the portfolio:

  1. per-cloud spot terms (`pricing.SPOT_MARKETS`): discount, revocation
     hazard/recovery rates, price band;
  2. the *effective* spot rate (`core.spot`): market rate + expected
     requeue/recompute + on-demand fallback while revoked;
  3. a chance constraint capping the demand fraction per pool on spot so
     expected demand-weighted availability stays >= the target;
  4. the rolling re-planning loop with the spot band enabled — committed
     tranches are the slow capacity the scan carries, the spot floor is
     re-decided every week;
  5. a Monte-Carlo replay of the finished plan against sampled revocation
     paths: realized cost and availability vs the planner's expectation.
"""

import numpy as np

from repro.capacity import pricing
from repro.capacity import simulator as sim
from repro.core import planner as pl
from repro.core import spot as sp
from repro.data import traces


def main():
    pools = traces.synthetic_pool_set(num_pools=4, num_hours=24 * 7 * 104)
    od = pricing.on_demand_premium()

    print("== spot markets (Table-2-style rows) ==")
    cfg = sp.SpotConfig(availability_target=0.95)
    lines = sp.pool_spot_lines(pools.clouds, od_rate=od, cfg=cfg)
    a = np.asarray(lines.availability)
    print("  pool                        avail   market  effective  cap")
    for i, key in enumerate(pools.keys):
        name = "/".join(key)
        print(f"  {name:27s} {a[i]:6.3f} "
              f"{float(lines.market_rate[i]):8.2f} "
              f"{float(lines.rate[i]):8.2f}  {float(lines.cap[i]):5.2f}")
    print(f"  (on-demand rate {od:.2f}; effective = availability-weighted "
          "market + requeue + fallback)")

    common = dict(
        mode="rolling", cadence_weeks=2, start_weeks=26, horizon_weeks=6,
        term_weighting=1.0, compare=False,
    )
    base = pl.plan_fleet_pools(pools, **common)
    rep = pl.plan_fleet_pools(pools, spot=cfg, **common)

    print("\n== rolling replay: commitments-only vs spot-enabled ==")
    print(f"  commitments-only total: {base.total_cost:14.0f}")
    print(f"  spot-enabled total:     {rep.total_cost:14.0f}  "
          f"({(1 - rep.total_cost / base.total_cost) * 100:.1f}% cheaper)")
    s = rep.summary()
    print(f"  spot spend {s['spot_cost']:.0f} over "
          f"{s['spot_chip_hours']:.0f} chip-hours "
          f"({s['spot_cost'] / max(s['spot_chip_hours'], 1e-9):.2f}/h vs "
          f"od {od:.2f}/h)")
    tranches = sum(len(l.amount) for l in rep.spot_ladders.ladders)
    print(f"  fast/slow split: {tranches} one-week spot tranches vs "
          f"{sum(len(l.amount) for l in rep.ladders.ladders)} committed")

    print("\n== Monte-Carlo replay vs sampled revocation paths ==")
    rr = sim.replay_spot_plan(pools, rep, num_draws=32, seed=1)
    print(f"  realized cost (mean of {rr.num_draws} draws): "
          f"{rr.realized_cost:.0f}  (planned {rr.planned_cost:.0f}, "
          f"{(rr.realized_cost / rr.planned_cost - 1) * 100:+.1f}%)")
    print(f"    spot bill {rr.realized_spot_cost:.0f} + od fallback "
          f"{rr.fallback_on_demand_cost:.0f} + requeue "
          f"{rr.requeue_cost:.0f}")
    print("  availability per pool (mean over draws): "
          + " ".join(f"{v:.4f}" for v in rr.mean_availability))
    print(f"  target {rr.availability_target:.2f} -> "
          f"{'MET' if rr.meets_target else 'MISSED'} "
          f"(shortfall {rr.shortfall_chip_hours:.0f} chip-hours/draw)")


if __name__ == "__main__":
    main()
