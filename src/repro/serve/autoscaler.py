"""Free-pool replica autoscaler (paper §5 wired into the serving runtime).

Maintains a pool of *warm* engine replicas sized by the newsvendor-optimal
forecast (core.freepool): demand above warm capacity waits out the simulated
CSP provisioning latency (paper Fig 10 — minutes-scale p99), demand below
wastes replica-hours.  The simulator and the SLO accounting mirror the
paper's cost function c(t) = p_o*(over) + p_u*(under).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import freepool as fp


@dataclasses.dataclass
class AutoscalerConfig:
    pool: fp.FreePoolConfig = dataclasses.field(default_factory=fp.FreePoolConfig)
    provision_latency: int = 3      # ticks to bring up a cold replica
    window: int = 24


@dataclasses.dataclass
class AutoscalerStats:
    slo_misses: int = 0
    served_warm: int = 0
    replica_ticks: int = 0          # warm replica-time paid for
    cost: float = 0.0


class FreePoolAutoscaler:
    """Discrete-tick simulation driver around engine replicas."""

    def __init__(self, cfg: AutoscalerConfig):
        self.cfg = cfg
        self.warm = 0
        self.pending: list[int] = []   # ticks remaining per cold start
        self.stats = AutoscalerStats()

    def plan(self, history: np.ndarray, horizon: int) -> np.ndarray:
        """Forecast-driven pool size for the next ``horizon`` ticks."""
        return np.asarray(
            fp.predicted_pool(
                jnp.asarray(history.astype(np.float32)), horizon, self.cfg.pool
            )
        )

    def step(self, target: float, demand: float):
        """One tick: scale toward ``target`` warm replicas, then serve
        ``demand`` concurrent requests."""
        # finish cold starts
        self.pending = [t - 1 for t in self.pending]
        arrived = sum(1 for t in self.pending if t <= 0)
        self.warm += arrived
        self.pending = [t for t in self.pending if t > 0]

        want = int(np.ceil(target))
        in_flight = self.warm + len(self.pending)
        if want > in_flight:
            self.pending.extend(
                [self.cfg.provision_latency] * (want - in_flight)
            )
        elif want < self.warm:
            self.warm = want  # deprovision is fast (paper §5.1)

        served = min(self.warm, int(np.ceil(demand)))
        missed = max(0, int(np.ceil(demand)) - served)
        over = max(0, self.warm - int(np.ceil(demand)))
        self.stats.slo_misses += missed
        self.stats.served_warm += served
        self.stats.replica_ticks += self.warm
        self.stats.cost += (
            self.cfg.pool.p_over * over + self.cfg.pool.p_under * missed
        )

    def run(self, history: np.ndarray, demand_future: np.ndarray,
            *, static_size: float | None = None) -> AutoscalerStats:
        """Simulate the full horizon with forecast-driven (default) or
        static pool sizing; returns accumulated stats (paper Fig 12)."""
        horizon = len(demand_future)
        if static_size is None:
            targets = self.plan(history, horizon)
        else:
            targets = np.full(horizon, static_size)
        for t in range(horizon):
            self.step(float(targets[t]), float(demand_future[t]))
        return self.stats
