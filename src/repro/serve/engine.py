"""Serving engine: continuous batching over a fixed slot pool.

One engine replica = one jit'd (prefill, decode) pair over a slotted KV
cache.  Requests are admitted into free slots (prefilled individually into
their slot), every engine tick decodes ALL active slots in one batched step,
finished sequences free their slots.  Replica counts are managed by the
free-pool autoscaler (serve/autoscaler.py, paper §5).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model: Model, *, num_slots: int, cache_len: int):
        self.model = model
        self.num_slots = num_slots
        self.cache_len = cache_len
        self.cache = model.init_cache(num_slots, cache_len)
        self.slot_req: list[Request | None] = [None] * num_slots
        self.slot_pos = np.zeros(num_slots, np.int32)
        self.slot_limit = np.zeros(num_slots, np.int32)

        def decode_step(params, cache, tokens, pos_vec):
            # batched decode: every slot advances by one token.  Each slot
            # has its own fill level; we decode with per-slot positions by
            # using the max position mask trick (positions padded safely).
            logits, new_cache = model.apply(
                params, tokens=tokens, mode="decode", cache=cache,
                pos=pos_vec,
            )
            return logits, new_cache

        self._decode = jax.jit(decode_step)

        def _batch_axis(c_shape, nc_shape):
            # cache leaves are (L, B, ...) or (B, ...): the batch axis is the
            # first axis where pool cache (B=num_slots) and single-slot
            # result (B=1) disagree.
            for ax, (a, b) in enumerate(zip(c_shape, nc_shape)):
                if a != b:
                    return ax
            raise ValueError(f"no batch axis: {c_shape} vs {nc_shape}")

        def prefill_one(params, cache, tokens, slot):
            # prefill into a fresh single-slot cache, then merge that slot
            # into the pool cache (other slots untouched).
            single = jax.tree.map(
                lambda s: jnp.zeros(s.shape, s.dtype),
                model.abstract_cache(1, cache_len),
            )
            logits, new_single = model.apply(
                params, tokens=tokens, mode="prefill", cache=single, pos=0,
            )

            def merge(c, nc):
                if c.shape == nc.shape:  # num_slots == 1: whole leaf
                    return nc.astype(c.dtype)
                ax = _batch_axis(c.shape, nc.shape)
                return jax.lax.dynamic_update_slice_in_dim(
                    c, nc.astype(c.dtype), slot, axis=ax
                )

            return logits, jax.tree.map(merge, cache, new_single)

        self._prefill = jax.jit(prefill_one, static_argnums=())

    # ------------------------------------------------------------ admission
    def try_admit(self, params, req: Request) -> bool:
        for slot, occupant in enumerate(self.slot_req):
            if occupant is None:
                tokens = jnp.asarray(req.prompt, jnp.int32)[None, :]
                logits, self.cache = self._prefill(
                    params, self.cache, tokens, slot
                )
                first = int(jnp.argmax(logits[0, -1]))
                req.generated.append(first)
                self.slot_req[slot] = req
                self.slot_pos[slot] = len(req.prompt)
                self.slot_limit[slot] = len(req.prompt) + req.max_new_tokens
                return True
        return False

    @property
    def active_slots(self) -> int:
        return sum(r is not None for r in self.slot_req)

    # ----------------------------------------------------------------- tick
    def tick(self, params):
        """One decode step for every active slot."""
        if self.active_slots == 0:
            return
        tokens = np.zeros((self.num_slots, 1), np.int32)
        for slot, req in enumerate(self.slot_req):
            if req is not None:
                tokens[slot, 0] = req.generated[-1]
        # per-slot fill levels: the decode step supports vector pos
        # (continuous batching with heterogeneous positions).
        pos = jnp.asarray(self.slot_pos)
        logits, self.cache = self._decode(
            params, self.cache, jnp.asarray(tokens), pos
        )
        nxt = np.asarray(jnp.argmax(logits[:, 0], -1), np.int32)
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            req.generated.append(int(nxt[slot]))
            self.slot_pos[slot] += 1
            if self.slot_pos[slot] >= self.slot_limit[slot]:
                req.done = True
                self.slot_req[slot] = None
