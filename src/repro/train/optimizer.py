"""AdamW with fp32 master weights and sharded optimizer state.

Optimizer state mirrors the parameter tree (same logical axes), so the
FSDP x TP sharding of params applies verbatim to m/v/master — the memory
math that lets jamba-52B train on 256 chips (see DESIGN §5).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def init_opt_state(params: Any) -> dict[str, Any]:
    # copy=True: fp32 param leaves (norm scales) must not alias the master
    # copy, or buffer donation sees the same buffer twice.
    f32 = lambda p: jnp.array(p, jnp.float32, copy=True)  # noqa: E731
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return {
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    return cfg.lr * warm


def global_norm(tree: Any) -> jnp.ndarray:
    return jnp.sqrt(
        sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(tree)
        )
    )


def adamw_update(
    grads: Any, opt_state: dict[str, Any], cfg: AdamWConfig
) -> tuple[Any, dict[str, Any]]:
    """Returns (new bf16 params, new opt state)."""
    step = opt_state["step"]
    lr = _schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(g, m, v, w):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m_new / bc1
        vh = v_new / bc2
        w_new = w - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * w)
        return m_new, v_new, w_new

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    flat_w = jax.tree.leaves(opt_state["master"])
    out = [upd(g, m, v, w) for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w)]
    new_m = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_w = jax.tree.unflatten(treedef, [o[2] for o in out])

    # bf16 working copy for the forward pass
    orig_dtypes = jax.tree.map(lambda g: g.dtype, grads)
    new_params = jax.tree.map(lambda w, d: w.astype(d), new_w, orig_dtypes)
    new_state = {
        "master": new_w, "m": new_m, "v": new_v, "step": step + 1,
    }
    return new_params, new_state
