"""Gradient compression for cross-pod sync: int8 error-feedback all-reduce.

At multi-pod scale the "pod" axis rides the slowest links (DCN/inter-pod
ICI), so the pure-DP gradient all-reduce over "pod" is the collective to
compress.  Classic EF-SGD: quantize (g + e) to int8 with a per-tensor scale,
sum the int8 payload across pods (4x fewer bytes on the wire than bf16...
16x vs fp32), dequantize, and carry the quantization residual e into the
next step — unbiased in the long run, bounded staleness.

Implemented with shard_map + lax.psum over the "pod" axis only; within-pod
FSDP/TP collectives stay full-precision.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat


def ef_int8_psum(
    g: jnp.ndarray, err: jnp.ndarray, axis_name: str
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One-tensor error-feedback compressed psum over ``axis_name``.
    Returns (averaged gradient, new error state).  Call inside shard_map.

    The quantization scale is *shared* across the axis (pmax of |x|): the
    summed int8 payload then dequantizes exactly as scale * sum(q) — per-pod
    scales would make the sum undecodable.  The scalar pmax adds negligible
    wire bytes next to the int8 tensor payload (4x smaller than bf16).
    """
    x = g.astype(jnp.float32) + err
    scale = jax.lax.pmax(jnp.abs(x).max(), axis_name) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    # int8 payload on the wire; accumulate in int32 to avoid overflow.
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(1, axis_name)
    deq_local = q.astype(jnp.float32) * scale
    new_err = x - deq_local                      # local quantization residual
    g_avg = total.astype(jnp.float32) * scale / n
    return g_avg.astype(g.dtype), new_err


def compressed_pod_sync(
    grads: Any, err_state: Any, mesh: Mesh, grad_pspecs: Any
) -> tuple[Any, Any]:
    """Apply EF-int8 all-reduce over the "pod" mesh axis to a gradient tree.

    grads are assumed *not* sharded over "pod" (pure DP on that axis); each
    pod holds its local gradient and the compressed psum produces the
    synchronized mean.  Within-pod axes pass through untouched.
    """
    if "pod" not in mesh.axis_names:
        return grads, err_state

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    flat_s = jax.tree.leaves(
        grad_pspecs, is_leaf=lambda x: isinstance(x, P) or x is None
    )

    outs = []
    for g, e, spec in zip(flat_g, flat_e, flat_s):
        spec = spec if spec is not None else P()

        fn = compat.shard_map(
            functools.partial(ef_int8_psum, axis_name="pod"),
            mesh=mesh,
            in_specs=(spec, spec),
            out_specs=(spec, spec),
            check_vma=False,
        )
        outs.append(fn(g, e))
    new_g = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_e = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return new_g, new_e


def init_error_state(grads_like: Any) -> Any:
    return jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like
    )
