"""Train / serve step builders: loss, grad, update — pjit-ready.

``build_train_step`` returns a pure function suitable for
``jax.jit(..., in_shardings=..., out_shardings=...)`` — the launcher and the
dry-run both consume it.  Gradient-compression (EF-int8 over the "pod" axis)
is wired via shard_map with auto inner axes when enabled.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro import compat
from repro.models.model import Model
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state


def cross_entropy(
    logits: jnp.ndarray,  # (B, S, V) fp32
    labels: jnp.ndarray,  # (B, S) int32
    *,
    z_loss: float = 1e-4,
) -> jnp.ndarray:
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    ce = (lse - gold).mean()
    if z_loss:
        ce = ce + z_loss * jnp.square(lse).mean()
    return ce


def build_loss_fn(model: Model, batch_part=None) -> Callable:
    def loss_fn(params, batch: dict[str, jnp.ndarray]):
        inputs = {k: v for k, v in batch.items() if k != "labels"}
        logits, _ = model.apply(
            params, **inputs, mode="train", batch_part=batch_part
        )
        return cross_entropy(logits, batch["labels"])

    return loss_fn


def build_train_step(
    model: Model,
    opt_cfg: AdamWConfig = AdamWConfig(),
    batch_part=None,
) -> Callable:
    """(params, opt_state, batch) -> (loss, params, opt_state)."""
    loss_fn = build_loss_fn(model, batch_part)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        new_params, new_opt = adamw_update(grads, opt_state, opt_cfg)
        return loss, new_params, new_opt

    return train_step


def build_grad_accum_train_step(
    model: Model,
    opt_cfg: AdamWConfig = AdamWConfig(),
    num_microbatches: int = 4,
    batch_part=None,
) -> Callable:
    """Gradient accumulation over the leading batch dim, python-unrolled
    (keeps HLO FLOP accounting exact; microbatch counts are small)."""
    loss_fn = build_loss_fn(model, batch_part)

    def train_step(params, opt_state, batch):
        def micro(i):
            mb = jax.tree.map(
                lambda x: x.reshape(num_microbatches,
                                    x.shape[0] // num_microbatches,
                                    *x.shape[1:])[i],
                batch,
            )
            return jax.value_and_grad(loss_fn)(params, mb)

        loss, grads = micro(0)
        for i in range(1, num_microbatches):
            li, gi = micro(i)
            loss = loss + li
            grads = jax.tree.map(jnp.add, grads, gi)
        inv = 1.0 / num_microbatches
        loss = loss * inv
        grads = jax.tree.map(lambda g: g * inv, grads)
        new_params, new_opt = adamw_update(grads, opt_state, opt_cfg)
        return loss, new_params, new_opt

    return train_step


def build_serve_step(model: Model, batch_part=None) -> Callable:
    """(params, cache, tokens/embeds, pos) -> (logits, new_cache): one decode
    step against a KV cache/state at fill level ``pos``."""

    def serve_step(params, cache, batch, pos):
        logits, new_cache = model.apply(
            params, **batch, mode="decode", cache=cache, pos=pos,
            batch_part=batch_part,
        )
        return logits, new_cache

    return serve_step


def build_prefill_step(model: Model, cache_len: int, batch_part=None) -> Callable:
    def prefill_step(params, batch):
        first = next(iter(batch.values()))
        b = first.shape[0]
        cache = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            model.abstract_cache(b, cache_len),
        )
        logits, new_cache = model.apply(
            params, **batch, mode="prefill", cache=cache, pos=0,
            batch_part=batch_part,
        )
        return logits, new_cache  # (B, 1, V): model slices pre-head

    return prefill_step


def init_train_state(model: Model, key: jax.Array):
    params = model.init(key)
    return params, init_opt_state(params)


# ---------------------------------------------------------------------------
# Compressed-DP variant (EF-int8 across "pod")
# ---------------------------------------------------------------------------

def build_compressed_train_step(
    model: Model,
    mesh,
    param_pspecs,
    batch_pspecs,
    opt_cfg: AdamWConfig = AdamWConfig(),
) -> Callable:
    """Pod-local gradients + EF-int8 compressed all-reduce over "pod".

    The grad computation runs under shard_map manual on "pod" (auto on
    data/model), so each pod computes gradients on its local batch and only
    the int8 payload crosses pods.  state carries the error-feedback tree.
    """
    from jax.sharding import PartitionSpec as P

    from repro.train.compression import ef_int8_psum

    loss_fn = build_loss_fn(model)

    def pod_local(params, batch, err):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(err)
        synced = [
            ef_int8_psum(g, e, "pod") for g, e in zip(flat_g, flat_e)
        ]
        grads = jax.tree.unflatten(tdef, [s[0] for s in synced])
        new_err = jax.tree.unflatten(tdef, [s[1] for s in synced])
        loss = jax.lax.pmean(loss, "pod")
        return loss, grads, new_err

    # Partial-manual shard_map: specs mention ONLY the manual "pod" axis;
    # the data/model shardings of params/batch ride through as auto axes
    # governed by the outer jit's in_shardings.
    def pod_only(spec):
        def fix(part):
            parts = part if isinstance(part, (tuple, list)) else (part,)
            return "pod" if "pod" in parts else None

        return P(*(fix(p) for p in spec))

    is_p = lambda x: isinstance(x, P)  # noqa: E731
    rep = jax.tree.map(lambda _: P(), param_pspecs, is_leaf=is_p)

    def train_step(params, opt_state, err_state, batch):
        wrapped = compat.shard_map(
            pod_local,
            mesh=mesh,
            in_specs=(
                rep,
                jax.tree.map(pod_only, batch_pspecs, is_leaf=is_p),
                rep,
            ),
            out_specs=(P(), rep, rep),
            check_vma=False,
            axis_names=frozenset({"pod"}),
        )
        loss, grads, new_err = wrapped(params, batch, err_state)
        new_params, new_opt = adamw_update(grads, opt_state, opt_cfg)
        return loss, new_params, new_opt, new_err

    return train_step
