"""Trainer: the production loop — jit'd step, checkpoint/restart, straggler
watchdog, elastic re-mesh restore, deterministic data resume.

Fault-tolerance model (single-host container, cluster-shaped logic):
  * `fit` periodically checkpoints (async) params+opt+data-state; a crash at
    any point resumes from the newest complete checkpoint (atomic renames
    guarantee completeness) and the data pipeline skips ahead
    deterministically — verified bit-exact in tests/test_fault_tolerance.py;
  * the straggler watchdog compares each step's wall time against a running
    EMA; slow steps past `straggler_factor` raise a counter and trigger the
    (pluggable) mitigation hook — on a real cluster that hook re-assigns the
    data shard / evicts the slow host; here it is observable state tests
    assert on;
  * elastic re-mesh: `CheckpointManager.restore(..., shardings=...)` places
    saved full arrays onto any new mesh; the Trainer just rebuilds its jit
    with the new shardings.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax

from repro.ckpt.manager import CheckpointManager
from repro.data.pipeline import TokenPipeline
from repro.models.model import Model
from repro.obs.spans import SpanRecorder
from repro.train.optimizer import AdamWConfig
from repro.train.step import build_train_step, init_train_state


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    log_every: int = 10
    straggler_factor: float = 3.0
    straggler_ema: float = 0.9
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)


class StragglerWatchdog:
    def __init__(self, factor: float, ema: float):
        self.factor = factor
        self.ema_coef = ema
        self.ema: float | None = None
        self.flagged_steps: list[int] = []
        self.mitigations = 0

    def observe(self, step: int, dt: float,
                mitigate: Callable[[], None] | None = None):
        if self.ema is None:
            self.ema = dt
            return False
        slow = dt > self.factor * self.ema
        if slow:
            self.flagged_steps.append(step)
            self.mitigations += 1
            if mitigate is not None:
                mitigate()
        # slow steps don't poison the EMA
        self.ema = self.ema_coef * self.ema + (1 - self.ema_coef) * (
            min(dt, self.factor * self.ema)
        )
        return slow


class Trainer:
    def __init__(
        self,
        model: Model,
        pipeline: TokenPipeline,
        cfg: TrainerConfig,
        ckpt_dir: str,
        *,
        shardings: Any | None = None,
        donate: bool = True,
    ):
        self.model = model
        self.pipeline = pipeline
        self.cfg = cfg
        self.ckpt = CheckpointManager(ckpt_dir)
        self.watchdog = StragglerWatchdog(
            cfg.straggler_factor, cfg.straggler_ema
        )
        # Per-step wall times come from the obs span profiler — the one
        # sanctioned clock entry point (analysis rule R7) — so the trainer
        # itself never reads a clock and the full step timeline is
        # inspectable after fit() via `self.spans.report()`.
        self.spans = SpanRecorder()
        step_fn = build_train_step(model, cfg.opt)
        jit_kw = {}
        if donate:
            jit_kw["donate_argnums"] = (0, 1)
        self.step_fn = jax.jit(step_fn, **jit_kw)
        self.losses: list[float] = []
        self.step = 0
        self.params = None
        self.opt_state = None

    # ------------------------------------------------------------ lifecycle
    def init_or_restore(self, key=None):
        key = key if key is not None else jax.random.PRNGKey(0)
        params, opt_state = init_train_state(self.model, key)
        restored = self.ckpt.restore_latest(
            {"params": params, "opt": opt_state}
        )
        if restored is None:
            self.params, self.opt_state, self.step = params, opt_state, 0
        else:
            step, tree, meta = restored
            self.params, self.opt_state = tree["params"], tree["opt"]
            self.step = step
            self.pipeline.skip_to(meta.get("data_step", step))
        return self.step

    def _checkpoint(self):
        self.ckpt.save_async(
            self.step,
            {"params": self.params, "opt": self.opt_state},
            metadata={"data_step": self.pipeline.step,
                      "losses_tail": self.losses[-5:]},
        )

    # ------------------------------------------------------------------ fit
    def fit(self, max_steps: int | None = None,
            fail_at_step: int | None = None):
        """Run to cfg.total_steps.  ``fail_at_step`` injects a crash for the
        fault-tolerance tests."""
        total = max_steps or self.cfg.total_steps
        try:
            while self.step < total:
                if fail_at_step is not None and self.step == fail_at_step:
                    raise RuntimeError(
                        f"injected failure at step {self.step}"
                    )
                batch_np = self.pipeline.next_batch()
                batch = jax.tree.map(jax.numpy.asarray, batch_np)
                with self.spans.span("train/step", phase="execute") as sp:
                    loss, self.params, self.opt_state = self.step_fn(
                        self.params, self.opt_state, batch
                    )
                    loss = float(loss)  # blocks on the device result
                self.watchdog.observe(self.step, sp.duration_s)
                self.losses.append(loss)
                self.step += 1
                if self.step % self.cfg.ckpt_every == 0:
                    self._checkpoint()
        finally:
            # Graceful-shutdown flush: drain any pending async save before a
            # failure escapes the loop (the SIGTERM-grace-period behavior on
            # a real cluster).  Without it a crash races the checkpoint
            # writer thread and restart may resume from the previous step.
            self.ckpt.wait()
        return self.losses
