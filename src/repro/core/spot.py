"""Spot capacity as a portfolio line: effective cost + chance constraint.

Spot capacity bills like on-demand (pay only while used) at a deep discount,
but the slice can be revoked at any hour (``capacity.preemption``).  Pricing
it into the §3 cost-line model means folding the revocation risk into the
*used* rate: per chip-hour of demand routed to the spot band,

    eff = a * (spot_rate * price + hazard * requeue_hours * od_rate)
          + (1 - a) * od_rate

      a            stationary availability  recovery / (hazard + recovery)
      spot_rate    (1 - discount) * od_rate      (pricing.SPOT_MARKETS)
      price        mean hourly spot-price multiplier (1.0 analytically;
                   empirical mean of the in-band price walk when estimated
                   from simulated draws)
      hazard * requeue_hours * od_rate
                   expected recompute: each revocation of a serving slice
                   loses ``requeue_hours`` of work, redone at on-demand
      (1 - a) * od_rate
                   fallback: while revoked, the demand the band was serving
                   runs on-demand instead

so the spot option is one more cost line l(u) = eff * (1 - u) — alpha = eff,
beta = 0, exactly like on-demand but cheaper — an extra K-line next to
``portfolio.pool_option_lines``'s committed lines.  Because beta = 0 and
eff < od_rate, the uncapped envelope would hand spot the *entire*
above-commitment band; what keeps the portfolio honest is the

**chance constraint** (Cohen et al.'s overcommitment shape): demand served
from spot is unavailable a (1 - a) fraction of hours, so if a fraction x of
the pool's demand volume rides spot, expected demand-weighted availability
is 1 - x * (1 - a).  Requiring it >= ``availability_target`` caps

    x <= (1 - availability_target) / (1 - a)      (* (1 - risk_buffer))

per pool (``spot_cap_fraction``).  The capped optimum keeps the envelope
shape: the marginal saving of routing one more unit of volume to spot,
l_best(u) / (1 - u) - eff, is nondecreasing in utilization fractile u, so
the best capped spot band is the TOP of the demand distribution truncated
at the volume cap — the solvers (``portfolio.optimal_portfolio_stack``,
``optimal_portfolio_grid``, and the rolling prefix solver) implement
exactly that truncation.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.capacity import preemption as pe


@dataclasses.dataclass(frozen=True)
class SpotConfig:
    """Knobs of the spot subsystem.

    ``availability_target`` is the chance-constraint floor on demand-
    weighted availability; ``risk_buffer`` backs the resulting volume cap
    off (the cap binds exactly at the target in expectation, so planning
    *at* it leaves no room for sampling noise in realized paths).
    ``num_draws`` > 0 estimates the effective rate from simulated
    revocation paths instead of the analytic stationary distribution
    (``sim_hours`` hours, seeded by ``seed``)."""

    availability_target: float = 0.95
    requeue_hours: float = 2.0
    risk_buffer: float = 0.2
    num_draws: int = 0            # 0 = analytic stationary distribution
    sim_hours: int = 24 * 7 * 8
    seed: int = 0


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SpotLines:
    """The spot line per pool: the extra K-line the solvers price.

    ``rate`` is alpha of the cost line (beta = 0); ``cap`` the chance-
    constrained demand-volume fraction; ``market_rate`` the raw (1 -
    discount) * od rate actually billed per served spot chip-hour (the
    difference between ``rate`` and ``market_rate`` is the priced-in
    preemption risk).  All arrays (P,), aligned with the pool axis."""

    rate: jnp.ndarray          # (P,) effective cost-line alpha
    cap: jnp.ndarray           # (P,) max demand-volume fraction on spot
    market_rate: jnp.ndarray   # (P,) raw spot $/used chip-hour
    availability: jnp.ndarray  # (P,) availability the cap was derived from
    params: pe.PreemptionParams


def spot_cap_fraction(
    availability: jnp.ndarray,
    target: float,
    *,
    risk_buffer: float = 0.0,
) -> jnp.ndarray:
    """Chance-constrained cap on the demand fraction a pool may serve from
    spot: routing fraction x to capacity that is up ``availability`` of the
    time leaves demand-weighted availability 1 - x(1 - availability), so
    x <= (1 - target) / (1 - availability), backed off by ``risk_buffer``
    and clipped to [0, 1] (fully reliable capacity is uncapped)."""
    if not 0.0 < target <= 1.0:
        raise ValueError(f"availability_target must be in (0, 1], {target}")
    short = jnp.maximum(1.0 - availability, 1e-9)
    return jnp.clip((1.0 - target) / short * (1.0 - risk_buffer), 0.0, 1.0)


def effective_spot_rate(
    params: pe.PreemptionParams,
    *,
    od_rate: float,
    requeue_hours: float,
    availability: jnp.ndarray | None = None,
    hazard: jnp.ndarray | None = None,
    price: jnp.ndarray | float = 1.0,
) -> jnp.ndarray:
    """(P,) effective $/demanded-chip-hour of the spot band (module
    docstring formula).  ``availability``/``hazard``/``price`` default to
    the analytic process constants and can be overridden with empirical
    estimates from simulated draws."""
    a = (
        availability if availability is not None
        else pe.stationary_availability(params)
    )
    lam = hazard if hazard is not None else params.hazard
    spot_rate = (1.0 - params.discount) * od_rate
    serving = spot_rate * price + lam * requeue_hours * od_rate
    return a * serving + (1.0 - a) * od_rate


def pool_spot_lines(
    clouds,
    *,
    od_rate: float,
    cfg: SpotConfig = SpotConfig(),
    markets=None,
) -> SpotLines:
    """Build the per-pool spot line for a fleet on ``clouds``.

    Analytic by default; with ``cfg.num_draws`` > 0 the availability,
    interruption rate, and mean price multiplier are estimated from
    ``num_draws`` simulated revocation paths instead (the two agree as
    draws x hours grow — tested).  Pools whose effective rate is not below
    on-demand get cap 0: spot that prices worse than on-demand after risk
    is simply not purchased."""
    params = pe.params_for_clouds(clouds, markets)
    if cfg.num_draws > 0:
        paths = pe.simulate_revocations(
            params, cfg.sim_hours, num_draws=cfg.num_draws,
            key=jax.random.PRNGKey(cfg.seed),
        )
        avail = jnp.asarray(paths.availability())
        up_hours = jnp.maximum(paths.available.sum((0, 2)), 1.0)
        hazard = paths.interrupted.sum((0, 2)) / up_hours
        price = (paths.price * paths.available).sum((0, 2)) / up_hours
    else:
        avail = pe.stationary_availability(params)
        hazard = params.hazard
        price = 1.0
    rate = effective_spot_rate(
        params, od_rate=od_rate, requeue_hours=cfg.requeue_hours,
        availability=avail, hazard=hazard, price=price,
    )
    cap = spot_cap_fraction(
        avail, cfg.availability_target, risk_buffer=cfg.risk_buffer
    )
    cap = jnp.where(rate < od_rate, cap, 0.0)
    return SpotLines(
        rate=rate,
        cap=cap,
        market_rate=(1.0 - params.discount) * od_rate,
        availability=avail,
        params=params,
    )


def spot_entry_fractile(
    alphas: jnp.ndarray,
    betas: jnp.ndarray,
    spot_rate: jnp.ndarray,
    *,
    od_rate: float,
    resolution: int = 4096,
) -> jnp.ndarray:
    """Utilization fractile where the spot line enters the lower envelope of
    [on-demand, committed options, spot] — below it some committed line is
    cheaper, above it spot wins.  The envelope bound on the spot band: even
    a loose chance-constraint cap must not push spot below this fractile
    into territory a commitment prices better.  Scalar per (K,) line set;
    vmap for a (P, K) fleet."""
    u = jnp.linspace(0.0, 1.0, resolution)
    lines = jnp.concatenate(
        [
            (od_rate * (1.0 - u))[:, None],
            alphas[None, :] * (1.0 - u)[:, None]
            + betas[None, :] * u[:, None],
            (spot_rate * (1.0 - u))[:, None],
        ],
        axis=1,
    )
    spot_idx = lines.shape[1] - 1
    wins = jnp.argmin(lines, axis=1) == spot_idx
    return jnp.where(wins.any(), jnp.where(wins, u, 2.0).min(), 1.0)


def resolve_spot(
    spot,
    clouds,
    *,
    od_rate: float,
) -> tuple[SpotConfig, SpotLines] | None:
    """Normalize the planner-facing ``spot=`` argument: None/False disables
    (the legacy bit-identical path), True takes the default
    :class:`SpotConfig`, a SpotConfig is used as-is, and a prebuilt
    (SpotConfig, SpotLines) pair passes through (so a replay can reuse the
    exact lines a plan was made with)."""
    if spot is None or spot is False:
        return None
    if spot is True:
        spot = SpotConfig()
    if isinstance(spot, SpotConfig):
        return spot, pool_spot_lines(clouds, od_rate=od_rate, cfg=spot)
    if (
        not isinstance(spot, tuple)
        or len(spot) != 2
        or not isinstance(spot[0], SpotConfig)
        or not isinstance(spot[1], SpotLines)
    ):
        raise TypeError(
            "spot must be None/bool/SpotConfig/(SpotConfig, SpotLines), "
            f"got {spot!r}"
        )
    return spot


def expected_availability(
    spot_frac: jnp.ndarray, availability: jnp.ndarray
) -> jnp.ndarray:
    """Demand-weighted availability when ``spot_frac`` of a pool's demand
    volume rides capacity that is up ``availability`` of the time — the
    quantity the chance constraint bounds from below."""
    return 1.0 - spot_frac * (1.0 - availability)
