"""Demand-driver decomposition + share-based forecasting (paper §2.3).

The **inference** side of the generation-turnover subsystem (the generative
side is ``repro.capacity.generations``): given a realized fleet, fit the
three drivers the paper says compose demand —

    per-pool VM demand = fleet user growth x family adoption share
                         x software efficiency

— and forecast *family share x pair total* instead of raw per-pool traces.
A per-pool structural fit sees a mid-migration family as organic decay (or
explosive growth on the successor side) and extrapolates it linearly in
log-space; the S-curve then accelerates past the fit on one side and
flattens under it on the other.  The share-based forecaster removes the
turnover driver before fitting: the *pair total in old-equivalent units*
(old + (1 + uplift) x successor) is turnover-invariant, so the structural
forecaster fits a stable series, and the turnover itself is carried by a
2-parameter logistic share fit — weighted least squares on the logit, which
is exactly linear in time for a logistic adoption curve.

Everything is prefix-sum friendly so the rolling replanner
(``repro.core.replan``) re-fits both pieces every week inside its
``lax.scan``: the pair-total rows ride the existing
``forecast.prefix_fit_state`` normal equations, and the share fit keeps
five cumulative weekly sums per edge (a 2x2 solve per week —
:class:`SharePrefixState` / :func:`solve_share_prefix`).

:func:`decompose_drivers` is the offline report: fitted logistic
midpoints/spans per edge, the hardware-corrected fleet trend, and — when an
independent user-volume series is supplied (the paper measured query volume
and the Snowflake Performance Index separately; volume and efficiency are
multiplicatively confounded in VM counts alone) — the software-efficiency
drift."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.capacity import generations as gn
from repro.core import demand as dm
from repro.core import forecast as fc
from repro.core.demand import HOURS_PER_WEEK

# Observed shares are clipped into [SHARE_EPS, 1 - SHARE_EPS] before the
# logit: a successor pool with literally zero demand is "not launched yet",
# not infinitely unlaunched.
SHARE_EPS = 1e-5
_RIDGE = 1e-6


def share_observations(
    demand: jnp.ndarray, edges: gn.MigrationEdges
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(z, w) each (G, T): per-edge logit of the successor's share of the
    pair total in old-equivalent units, and its logistic-regression weight
    s(1 - s) — near-zero weight where the share pins to a clipped extreme,
    so pre-launch hours barely move the fit."""
    d = jnp.asarray(demand, jnp.float32)
    old = d[edges.src]                                  # (G, T)
    new_adj = d[edges.dst] * (1.0 + edges.uplift[:, None])
    total = old + new_adj
    s = jnp.where(total > 0, new_adj / jnp.maximum(total, 1e-12), 0.0)
    s = jnp.clip(s, SHARE_EPS, 1.0 - SHARE_EPS)
    z = jnp.log(s) - jnp.log1p(-s)
    return z, s * (1.0 - s)


def _wls_line(sw, swt, swt2, swz, swtz):
    """Weighted least-squares line z ~ a + b t from the five moment sums
    (broadcasts over any leading axes)."""
    denom = sw * swt2 - swt * swt + _RIDGE
    b = (sw * swtz - swt * swz) / denom
    a = (swz - b * swt) / jnp.maximum(sw, 1e-9)
    return a, b


def _prior_moments(
    edges: gn.MigrationEdges, t_max: float, weight: float
) -> jnp.ndarray:
    """(G, 5) pseudo-observation moments encoding the successor table's
    announced S-curve as a prior on the logit-share line: two points of
    total weight ``weight`` at normalized times 0 and 1 on the table's
    line z(t) = rate (t - midpoint).  Pre-launch — when every real share
    observation sits at a clipped extreme with weight ~ 0 — the prior IS
    the fit; once adoption is underway the data weights (thousands of
    hours) swamp it."""
    b0 = edges.rate_per_hour * t_max
    a0 = -edges.rate_per_hour * edges.midpoint_hours
    half = weight / 2.0
    return jnp.stack(
        [
            jnp.full_like(a0, weight),           # sum w
            jnp.full_like(a0, half),             # sum w t   (t in {0, 1})
            jnp.full_like(a0, half),             # sum w t^2
            half * (2.0 * a0 + b0),              # sum w z
            half * (a0 + b0),                    # sum w t z
        ],
        axis=-1,
    )


def fit_share(
    demand: jnp.ndarray,
    edges: gn.MigrationEdges,
    *,
    t_max: float,
    prior_weight: float = 0.0,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(a, b) each (G,): full-window logit-share line fits, time normalized
    by ``t_max`` (same convention as the forecaster's trend columns so the
    two extrapolate on one clock).  predicted share = sigmoid(a + b t/t_max).

    ``prior_weight`` blends in the table's announced adoption curve (see
    :func:`_prior_moments`); 0 fits the data alone."""
    z, w = share_observations(demand, edges)
    t = jnp.arange(z.shape[-1], dtype=jnp.float32) / t_max
    sums = [
        w.sum(-1),
        (w * t).sum(-1),
        (w * t * t).sum(-1),
        (w * z).sum(-1),
        (w * t * z).sum(-1),
    ]
    if prior_weight > 0:
        prior = _prior_moments(edges, t_max, prior_weight)
        sums = [s + prior[:, i] for i, s in enumerate(sums)]
    return _wls_line(*sums)


def predict_share(
    a: jnp.ndarray, b: jnp.ndarray, t_hours: jnp.ndarray, t_max: float
) -> jnp.ndarray:
    """(G, H) logistic share forecast at absolute hours ``t_hours``."""
    ts = jnp.asarray(t_hours, jnp.float32) / t_max
    return jax.nn.sigmoid(a[:, None] + b[:, None] * ts[None, :])


def transform_for_fit(
    demand: jnp.ndarray, edges: gn.MigrationEdges
) -> jnp.ndarray:
    """Replace each edge's old-family row by the pair total in
    old-equivalent units — the turnover-invariant series the structural
    forecaster should fit.  Successor rows are left as-is (their fits are
    overwritten by the share composition and never read)."""
    d = jnp.asarray(demand, jnp.float32)
    total = d[edges.src] + d[edges.dst] * (1.0 + edges.uplift[:, None])
    return d.at[edges.src].set(total)


def compose_forecast(
    yhat_total: jnp.ndarray,
    shares: jnp.ndarray,
    edges: gn.MigrationEdges,
) -> jnp.ndarray:
    """Recombine pair-total forecasts (P, H) with share forecasts (G, H)
    into per-pool forecasts: the old family keeps (1 - s) of the pair
    total, the successor serves s of it at 1/(1 + uplift) VMs per
    old-equivalent unit."""
    tot = yhat_total[edges.src]                          # (G, H)
    y = yhat_total.at[edges.src].set((1.0 - shares) * tot)
    return y.at[edges.dst].set(
        shares * tot * edges.inv_gain[:, None]
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SharePrefixState:
    """Cumulative weekly moment sums for rolling logit-share re-fits.

    ``cum[g, w]`` holds [sum w, sum w t, sum w t^2, sum w z, sum w t z]
    over the first w+1 whole weeks of edge g's share observations (time
    normalized by ``t_max``), so the week-w share fit inside the replay
    scan is one gather + a closed-form 2x2 solve — the share-fit analogue
    of ``forecast.PrefixFitState``."""

    cum: jnp.ndarray       # (G, W, 5)
    t_max: jnp.ndarray     # scalar, forecast-state time normalization


def share_prefix_state(
    demand: jnp.ndarray,
    edges: gn.MigrationEdges,
    *,
    t_max: float,
    period_hours: int = HOURS_PER_WEEK,
    prior_weight: float = 0.0,
) -> SharePrefixState:
    """Build the rolling share-fit state for a (P, T) fleet (T truncated to
    whole periods, matching ``forecast.prefix_fit_state``).  The prior
    moments, if any, ride inside every prefix (the announced-launch prior
    binds hardest exactly when the prefix holds no adoption signal)."""
    z, w = share_observations(demand, edges)
    g = z.shape[0]
    num_weeks = z.shape[-1] // period_hours
    t_hist = num_weeks * period_hours
    t = jnp.arange(t_hist, dtype=jnp.float32) / t_max
    z, w = z[:, :t_hist], w[:, :t_hist]
    moments = jnp.stack(
        [w, w * t, w * t * t, w * z, w * t * z], axis=-1
    )                                                    # (G, T, 5)
    weekly = moments.reshape(g, num_weeks, period_hours, 5).sum(2)
    cum = jnp.cumsum(weekly, axis=1)
    if prior_weight > 0:
        cum = cum + _prior_moments(edges, t_max, prior_weight)[:, None, :]
    return SharePrefixState(
        cum=cum,
        t_max=jnp.float32(t_max),
    )


def solve_share_prefix(
    state: SharePrefixState, week
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(a, b) each (G,) fit on the prefix of ``week`` whole periods —
    scan-safe (``week`` may be traced, >= 1)."""
    c = jax.lax.dynamic_index_in_dim(
        state.cum, week - 1, axis=1, keepdims=False
    )                                                    # (G, 5)
    return _wls_line(c[:, 0], c[:, 1], c[:, 2], c[:, 3], c[:, 4])


@dataclasses.dataclass
class EdgeFit:
    """One fitted turnover edge, reported in table units."""

    cloud: str
    region: str
    old_family: str
    new_family: str
    uplift: float
    midpoint_weeks: float    # fitted 50%-adoption epoch
    span_weeks: float        # fitted 10%->90% width
    final_share: float       # fitted share at the end of the window


@dataclasses.dataclass
class DriverDecomposition:
    """The three fitted demand drivers of a realized fleet.

    ``edge_fits`` carry the per-family logistic turnover; ``fleet_model``
    is the structural fit of the hardware-corrected fleet total (user
    growth x software efficiency — the turnover driver removed);
    ``efficiency_per_year`` separates the software driver out of that
    product when an independent user-volume series was supplied, else
    None.  ``hardware_index`` is the realized VM-count multiplier of
    turnover: raw fleet total over old-equivalent total (< 1 once
    adoption of a faster family is underway)."""

    keys: tuple[dm.PoolKey, ...]
    edges: gn.MigrationEdges
    share_a: np.ndarray            # (G,) logit intercepts (t / t_max clock)
    share_b: np.ndarray            # (G,) logit slopes
    t_max: float
    edge_fits: list[EdgeFit]
    fleet_model: fc.ForecastModel
    hardware_index: np.ndarray     # (T,)
    efficiency_per_year: float | None
    growth_per_year: float | None  # user-volume trend when supplied

    def predicted_shares(self, t_hours: jnp.ndarray) -> np.ndarray:
        return np.asarray(predict_share(
            jnp.asarray(self.share_a), jnp.asarray(self.share_b),
            t_hours, self.t_max,
        ))


def _log_slope_per_year(series: np.ndarray) -> float:
    """OLS slope of log(series) per year of hours."""
    y = np.log(np.maximum(np.asarray(series, np.float64), 1e-12))
    t = np.arange(y.shape[-1], dtype=np.float64) / gn.HOURS_PER_YEAR
    t = t - t.mean()
    return float((t * (y - y.mean())).sum() / np.maximum((t * t).sum(), 1e-12))


def decompose_drivers(
    pools: dm.PoolSet,
    *,
    migration: "gn.MigrationConfig | bool | None" = True,
    user_volume: np.ndarray | None = None,
    cfg: fc.ForecastConfig = fc.ForecastConfig(),
) -> DriverDecomposition:
    """Fit the three-driver decomposition to a realized fleet.

    ``migration`` supplies the successor *structure* (which family pairs
    can turn over, and their published perf uplifts); the adoption epochs
    themselves are fitted from the data, never read from the table.
    ``user_volume`` (T,) is the independent demand-driver measurement
    (query volume in old-equivalent VM units); with it the software-
    efficiency drift is identified as the log-slope of corrected-VM-total
    over user volume, without it user growth and efficiency stay folded
    into ``fleet_model``'s trend (they are multiplicatively confounded in
    VM counts alone — the paper separates them with the SPI)."""
    mig = gn.resolve_migration(migration)
    if mig is None:
        # False/None mean "migration off" everywhere else in this
        # subsystem; silently substituting the default successor table
        # here would invert that contract.
        raise ValueError(
            "decompose_drivers needs a successor structure; pass "
            "migration=True (pricing.GENERATIONS) or a MigrationConfig"
        )
    edges = gn.migration_edges(pools.keys, mig)
    demand = jnp.asarray(pools.demand, jnp.float32)
    t_hist = pools.num_hours
    t_max = float(max(t_hist - 1, 1))

    a, b = fit_share(demand, edges, t_max=t_max)
    a_np, b_np = np.asarray(a, np.float64), np.asarray(b, np.float64)
    src_np = np.asarray(edges.src)
    dst_np = np.asarray(edges.dst)
    up_np = np.asarray(edges.uplift, np.float64)
    edge_fits = []
    for g in range(edges.num_edges):
        rate_hr = b_np[g] / t_max                  # logit slope per hour
        wk = HOURS_PER_WEEK
        mid = -a_np[g] / rate_hr / wk if abs(rate_hr) > 1e-12 else np.inf
        span = (
            gn._LOGISTIC_1090 / rate_hr / wk
            if abs(rate_hr) > 1e-12 else np.inf
        )
        key_old, key_new = pools.keys[src_np[g]], pools.keys[dst_np[g]]
        edge_fits.append(EdgeFit(
            cloud=key_old[0], region=key_old[1],
            old_family=key_old[2], new_family=key_new[2],
            uplift=float(up_np[g]),
            midpoint_weeks=float(mid),
            span_weeks=float(span),
            final_share=float(
                jax.nn.sigmoid(a_np[g] + b_np[g] * (t_hist - 1) / t_max)
            ),
        ))

    # Hardware-corrected fleet total: successors counted at (1 + uplift)
    # VMs of old-equivalent work — the turnover driver divided out.
    perf = np.ones(pools.num_pools, np.float64)
    perf[dst_np] = 1.0 + up_np
    corrected = (np.asarray(pools.demand, np.float64) * perf[:, None]).sum(0)
    raw_total = pools.demand.sum(0)
    fleet_model = fc.fit(jnp.asarray(corrected, jnp.float32), cfg)
    hardware_index = raw_total / np.maximum(corrected, 1e-12)

    efficiency = growth = None
    if user_volume is not None:
        user_volume = np.asarray(user_volume, np.float64)
        if user_volume.shape[-1] != t_hist:
            raise ValueError(
                f"user_volume length {user_volume.shape[-1]} != "
                f"{t_hist} fleet hours"
            )
        # corrected / user = (1 + r)^(-t/yr): slope recovers the drift.
        slope = _log_slope_per_year(
            corrected / np.maximum(user_volume, 1e-12)
        )
        efficiency = float(np.expm1(-slope))
        growth = float(np.expm1(_log_slope_per_year(user_volume)))

    return DriverDecomposition(
        keys=pools.keys,
        edges=edges,
        share_a=np.asarray(a),
        share_b=np.asarray(b),
        t_max=t_max,
        edge_fits=edge_fits,
        fleet_model=fleet_model,
        hardware_index=np.asarray(hardware_index, np.float32),
        efficiency_per_year=efficiency,
        growth_per_year=growth,
    )
