"""Commitment policies behind the rolling replay (paper §3.3.3 + baselines).

The weekly replay in ``repro.core.replan`` is a harness: roll expired
tranches off, let a *policy* pick this week's per-pool target stack, buy
only the increments, bill the week.  This module owns the policy side of
that contract so alternative purchasing strategies can ride the same
``lax.scan`` — and the same tournament rig (``repro.core.tournament``) —
without touching the harness:

    RollingPortfolioPolicy   the paper's Algorithm 1 loop: weekly prefix
                             refit -> per-horizon thresholds -> monotone
                             stack (the pre-PR replan body, op for op).
    OneShotPolicy            degenerate rolling policy with a single
                             decision week (what ``plan_fleet_pools``
                             prices at t0).
    HindsightPolicy          non-causal: the optimal constant stack on
                             the realized demand, rebought weekly so
                             expiring tranches run back-to-back.
    DeterministicHedgePolicy the break-even online algorithm of Ambati,
    RandomizedHedgePolicy    Urgaonkar & Sitaraman, *Hedge Your Bets:
                             Optimizing Long-Term Cloud Costs* (arXiv
                             2004.04302): forecast-free ski-rental per
                             capacity band, with the classical 2 and
                             e/(e-1) competitive-ratio guarantees.

A policy is two phases.  ``setup(ctx)`` runs once per replay at trace
time against a :class:`PolicyContext` (demand, cost lines, forecaster
prefix state, solver hooks) and returns ``(pstate0, decide)``; ``decide``
is the pure per-week function the scan body calls:

    pstate, Decision(targets, floor, yhat, is_decision)
        = decide(pstate, Observation(week, active, d_prev))

``pstate`` is an arbitrary pytree carried through the scan (the rolling
policy carries ``()`` so the default replay's carry — and therefore its
compiled program — is unchanged).  ``targets`` are absolute per-option
stack widths; the harness buys ``max(targets - active, 0)`` on weeks
where ``is_decision`` holds and never sells, so any policy inherits the
paper's commitments-only-expire semantics for free.

The hedging policies run classical ski-rental *per capacity band*: the
candidate range [0, top) per pool is cut into ``grid_size`` bands; each
band accrues the on-demand spend it would have absorbed while uncovered
by a commitment, and is committed (into the pool's cheapest available
SKU) once that spend reaches ``z x`` its buy price.  ``z = 1`` is the
deterministic break-even rule (competitive ratio <= 2); the randomized
variant draws ``z`` per band from the density ``e^z / (e - 1)`` on
(0, 1], the classical distribution with expected ratio e/(e-1) ~ 1.582.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import forecast as fc
from repro.core import portfolio as pf
from repro.core.demand import HOURS_PER_WEEK
from repro.core.planner import _monotone_stack, _prefix_weighted_quantiles

# Competitive-ratio guarantees from Ambati et al. (arXiv 2004.04302):
# break-even deterministic ski rental is 2-competitive; the randomized
# threshold density e^z/(e-1) on (0, 1] achieves e/(e-1) in expectation.
DETERMINISTIC_CR_BOUND = 2.0
RANDOMIZED_CR_BOUND = math.e / (math.e - 1.0)


@dataclasses.dataclass
class PolicyContext:
    """Everything a policy may consult, assembled once per replay.

    Built by ``replan.replan_fleet_pools`` (full harness: spot floors,
    migration recomposition and the grid solver ride in ``targets_for``
    and ``compose_forecast``) or by :func:`make_context` (the lean
    tournament variant: quantile solver only).  All array members are
    JAX arrays so the whole context can be closed over inside a traced
    program; ``solve_fn``/``targets_for``/``compose_forecast`` are
    trace-time callables, not runtime data."""

    demand: jnp.ndarray          # (P, T) whole-week demand, history + eval
    options: list
    clouds: tuple[str, ...]
    od: float
    rates: jnp.ndarray           # (K,) committed rates
    term_weeks: jnp.ndarray      # (K,) int32 terms
    avail: jnp.ndarray           # (P, K) option available on pool's cloud
    qs: jnp.ndarray              # (P, K) handover fractiles
    w_hours: jnp.ndarray         # (H,) horizon prefix lengths in hours
    start_weeks: int
    cadence_weeks: int
    horizon_weeks: int
    total_weeks: int
    state: fc.PrefixFitState
    solve_fn: Callable           # (state, week) -> beta  (scan or loop)
    irls_iters: int = 0
    #: carry the IRLS weight-adjustment moments in the scan state
    #: (frozen-weights incremental IRLS) instead of re-running full
    #: masked passes per week — see ``fc.irls_carry_init``.
    irls_carry: bool = False
    # yhat (P, Wh*168) -> (targets (P, K), spot floor (P,) | None)
    targets_for: Callable | None = None
    # migration hook: (yhat, week) -> recomposed yhat
    compose_forecast: Callable | None = None
    #: "weekly" (the harness cadence rule) or "breach": re-solve only in
    #: weeks where last week's realized demand exited the forecast band
    #: held since the previous decision (plus the mandatory start week).
    cadence_mode: str = "weekly"
    #: (q_lo, q_hi) forecast fractile pair that frames the breach band.
    breach_band: tuple = (0.05, 0.95)
    #: hour-budget multiplier: a week breaches when more than
    #: ``tolerance x nominal miss mass`` of its 168 hours exit the band.
    breach_tolerance: float = 4.0
    #: scenario-batched replays flatten (N, P) -> R demand rows; breach
    #: decisions are fleet-wide *per scenario*, so the mask is reduced
    #: over each block of ``num_pools / scenario_blocks`` rows.
    scenario_blocks: int = 1

    @property
    def num_pools(self) -> int:
        return self.demand.shape[0]

    @property
    def num_options(self) -> int:
        return self.qs.shape[-1]

    @property
    def horizon_hours(self) -> int:
        return self.horizon_weeks * HOURS_PER_WEEK


class Observation(NamedTuple):
    """Per-week inputs the harness hands to ``decide``."""

    week: jnp.ndarray            # scalar int32, absolute week index
    active: jnp.ndarray          # (P, K) committed stack after roll-offs
    d_prev: jnp.ndarray | None   # (P, 168) last week's realized demand
    #  (None unless the policy sets ``needs_prev_demand`` — the default
    #  harness program must not gain even a dead gather)
    #: (P, TRAIL_WEEKS*168) trailing realized demand window, the spread
    #: anchor for ``fc.anchored_fractile_levels``; gathered only under
    #: ``cadence_mode="breach"`` or calibration telemetry, None otherwise.
    d_trail: Any = None


class Decision(NamedTuple):
    """Per-week outputs of ``decide``."""

    targets: jnp.ndarray         # (P, K) absolute stack widths to hold
    floor: jnp.ndarray | None    # (P,) spot floor (forecasting + spot only)
    yhat: jnp.ndarray | None     # (P, H) forecast (None = non-forecasting)
    is_decision: jnp.ndarray     # bool: may this week buy?  scalar, or a
    #  per-row (P,) vector under ``cadence_mode="breach"`` (uniform
    #  within each scenario block)
    #: optional dict of extra per-week arrays the harness forwards into
    #: the scan outputs verbatim (breach mode emits the active band as
    #: ``band_lo``/``band_hi``); None on the default paths so the weekly
    #: compiled program is unchanged.
    extras: Any = None


class Policy:
    """Base policy: subclass and implement :meth:`setup`."""

    name: str = "policy"
    #: produces a forecast (yhat) — required by the spot / migration /
    #: convertible bands, which all key on this week's forecast.
    forecasting: bool = False
    #: wants last week's realized demand in the Observation.
    needs_prev_demand: bool = False

    def setup(self, ctx: PolicyContext) -> tuple[Any, Callable]:
        raise NotImplementedError

    def _is_decision(self, ctx: PolicyContext, w) -> jnp.ndarray:
        """The harness cadence rule: every ``cadence_weeks`` from the
        start week; ``cadence_weeks == 0`` means the single start week
        (the one-shot baseline replay)."""
        if ctx.cadence_weeks > 0:
            return (w - ctx.start_weeks) % ctx.cadence_weeks == 0
        return w == ctx.start_weeks

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"{type(self).__name__}()"


class RollingPortfolioPolicy(Policy):
    """The paper's rolling loop as a policy: re-fit the forecaster on the
    week-``w`` prefix (one gather + ridge solve against the cumulative
    normal equations), forecast the horizon, and run Algorithm 1 steps
    2-4 for the target stack.  This is the pre-refactor ``replan`` scan
    body verbatim — the default-policy goldens pin that equivalence."""

    name = "rolling_portfolio"
    forecasting = True

    def setup(self, ctx: PolicyContext):
        carry_irls = ctx.irls_carry and ctx.irls_iters > 0
        breach = ctx.cadence_mode == "breach"
        # Incremental IRLS: seed the scan state with the exact adjustment
        # moments on the start prefix; each week then solves against
        # prefix + carried moments and appends only the newest week's
        # block.  Off (the default) the pstate stays () and the compiled
        # program is unchanged.
        inner0 = (
            fc.irls_carry_init(ctx.state, ctx.start_weeks, ctx.irls_iters)
            if carry_irls else ()
        )
        if breach:
            q_lo, q_hi = ctx.breach_band
            # Integer hour budgets: a week breaches when strictly MORE
            # than tolerance x the nominal miss mass of its 168 hours
            # exit the band.  Counts and thresholds are exact ints so a
            # host-side python-loop oracle over the emitted bands
            # reproduces the decision mask bit-for-bit.
            allow_above = int(
                ctx.breach_tolerance * (1.0 - q_hi) * HOURS_PER_WEEK
            )
            allow_below = int(ctx.breach_tolerance * q_lo * HOURS_PER_WEEK)
            blocks = ctx.scenario_blocks
            rows_per = ctx.num_pools // blocks
            band0 = (
                jnp.zeros((ctx.num_pools,), jnp.float32),
                jnp.zeros((ctx.num_pools,), jnp.float32),
            )
            pstate0 = (inner0, band0)
        else:
            pstate0 = inner0

        def decide(pstate, obs: Observation):
            w = obs.week
            if breach:
                inner, (lo, hi) = pstate
            else:
                inner = pstate
            if carry_irls:
                g_adj, r_adj = inner
                beta = fc.solve_prefix_adjusted(ctx.state, w, g_adj, r_adj)
                inner = fc.irls_carry_extend(
                    ctx.state, beta, g_adj, r_adj, w
                )
            else:
                beta = ctx.solve_fn(ctx.state, w)
                beta = fc.irls_refine(ctx.state, beta, w, ctx.irls_iters)
            yhat = fc.predict_from_beta(
                ctx.state, beta, w * HOURS_PER_WEEK, ctx.horizon_hours
            )
            if ctx.compose_forecast is not None:
                yhat = ctx.compose_forecast(yhat, w)
            targets, floor = ctx.targets_for(yhat)
            if not breach:
                return inner, Decision(
                    targets, floor, yhat, self._is_decision(ctx, w)
                )
            # Band breach on the most recent completed week: the band
            # held in the carry is the fractile pair of the forecast made
            # at the last decision week.
            above = (obs.d_prev > hi[:, None]).sum(-1)       # (R,) int
            below = (obs.d_prev < lo[:, None]).sum(-1)
            breach_row = (above > allow_above) | (below > allow_below)
            is_dec = (w == ctx.start_weeks) | breach_row
            # Fleet-wide per scenario: any pool breaching re-solves its
            # whole scenario block (blocks == 1 -> the whole fleet).
            scen = is_dec.reshape(blocks, rows_per).any(axis=1)
            is_dec = jnp.repeat(scen, rows_per)              # (R,) bool
            band = fc.anchored_fractile_levels(obs.d_trail, (q_lo, q_hi))
            lo = jnp.where(is_dec, band[:, 0], lo)
            hi = jnp.where(is_dec, band[:, 1], hi)
            return (inner, (lo, hi)), Decision(
                targets, floor, yhat, is_dec,
                {"band_lo": lo, "band_hi": hi},
            )

        return pstate0, decide


class OneShotPolicy(RollingPortfolioPolicy):
    """Degenerate rolling policy: one decision at the start week, then
    tranches only expire — what ``plan_fleet_pools`` prices at t0."""

    name = "one_shot"

    def _is_decision(self, ctx: PolicyContext, w):
        return w == ctx.start_weeks


class HindsightPolicy(Policy):
    """Non-causal reference: the optimal *constant* stack on the realized
    evaluation demand (billing lines, ``term_weighting=0``), held every
    week.  Deciding weekly makes expiring tranches rebuy back-to-back, so
    the replayed cost matches the analytic hindsight baseline."""

    name = "hindsight"

    def setup(self, ctx: PolicyContext):
        al0, be0, _ = pf.pool_option_lines(
            ctx.options, ctx.clouds, term_weighting=0.0, od_rate=ctx.od
        )
        eval_demand = ctx.demand[:, ctx.start_weeks * HOURS_PER_WEEK:]
        plan = jax.vmap(
            lambda f_, a_, b_: pf.optimal_portfolio_stack(
                f_, a_, b_, od_rate=ctx.od
            )
        )(eval_demand, al0, be0)
        widths = plan.widths                                   # (P, K)

        def decide(pstate, obs: Observation):
            is_dec = jnp.asarray(True)
            return pstate, Decision(widths, None, None, is_dec)

        return (), decide


def _hedge_threshold(u: jnp.ndarray) -> jnp.ndarray:
    """Inverse CDF of the density e^z/(e-1) on (0, 1]: the classical
    randomized ski-rental threshold distribution."""
    return jnp.log1p(u * (math.e - 1.0))


class DeterministicHedgePolicy(Policy):
    """Ambati et al.'s break-even hedging rule per capacity band.

    The candidate range [0, ``top_multiplier`` x history peak) of each
    pool is split into ``grid_size`` equal bands.  A band accrues the
    on-demand spend it absorbed last week whenever it sits above the
    committed stack top; once the accrued spend reaches ``z x`` the
    band's buy price (rate x term of the pool's cheapest available SKU)
    the band is committed and its meter resets — after the tranche
    expires the band starts saving for the next one.  No forecast, no
    solver: the guarantees are adversarial (total cost <= 2 x the
    per-band hindsight optimum for ``z = 1``).  Decisions fire every
    week regardless of the harness cadence — reacting on the week the
    meter crosses is the algorithm."""

    name = "deterministic_hedge"
    needs_prev_demand = True

    def __init__(self, grid_size: int = 32, top_multiplier: float = 1.5):
        if grid_size < 1:
            raise ValueError(f"grid_size must be >= 1, got {grid_size}")
        if top_multiplier <= 0:
            raise ValueError(
                f"top_multiplier must be > 0, got {top_multiplier}"
            )
        self.grid_size = int(grid_size)
        self.top_multiplier = float(top_multiplier)

    def _thresholds(self, num_pools: int) -> jnp.ndarray:
        return jnp.ones((num_pools, self.grid_size), jnp.float32)

    def _band_spend(self, d, levels, dg, od):
        """(P, G) on-demand spend each band would have absorbed over the
        demand block ``d`` (P, T): od x clipped occupancy of the band."""
        occ = jnp.clip(
            d[:, None, :] - levels[:, :, None], 0.0, dg[:, None, None]
        )
        return od * occ.sum(-1)

    def setup(self, ctx: PolicyContext):
        num_p, num_k, g = ctx.num_pools, ctx.num_options, self.grid_size
        hist = ctx.demand[:, : ctx.start_weeks * HOURS_PER_WEEK]
        top = jnp.maximum(hist.max(-1), 1e-6) * self.top_multiplier  # (P,)
        dg = top / g
        levels = dg[:, None] * jnp.arange(g, dtype=jnp.float32)[None, :]
        # One designated SKU per pool: cheapest rate available on its
        # cloud (ski rental hedges od vs ONE buy price; portfolio mixing
        # is the forecasting planner's game).
        rate_eff = jnp.where(ctx.avail, ctx.rates[None, :], jnp.inf)
        kstar = jnp.argmin(rate_eff, axis=-1)                    # (P,)
        onehot = jax.nn.one_hot(kstar, num_k, dtype=jnp.float32)
        # Finite-horizon Bahncard adaptation: tranches bill weekly while
        # active, so the most a stranded commitment can cost inside the
        # replay window is rate x min(term, window) — price the ski
        # rental at that, or a term longer than the window would push
        # break-even past the horizon and the rule degenerates to
        # never-commit.
        eff_term = jnp.minimum(
            ctx.term_weeks[kstar], ctx.total_weeks - ctx.start_weeks
        ).astype(jnp.float32)
        buy_unit = ctx.rates[kstar] * eff_term * HOURS_PER_WEEK  # (P,)
        band_price = buy_unit * dg                               # (P,)
        z = self._thresholds(num_p)                              # (P, G)
        # Pre-accrue the uncommitted history [0, start-1): the first
        # decision's Observation carries week start-1, so stopping one
        # week short here counts every hour exactly once.
        a0 = jnp.zeros((num_p, g), jnp.float32)
        pre = hist[:, : max(ctx.start_weeks - 1, 0) * HOURS_PER_WEEK]
        if pre.shape[-1]:
            a0 = a0 + self._band_spend(pre, levels, dg, ctx.od)

        def decide(pstate, obs: Observation):
            accrued = pstate
            stack_top = obs.active.sum(-1)                       # (P,)
            covered = (
                levels + dg[:, None] <= stack_top[:, None] + 1e-6
            )
            spend = self._band_spend(obs.d_prev, levels, dg, ctx.od)
            accrued = jnp.where(covered, accrued, accrued + spend)
            commit = ~covered & (accrued >= z * band_price[:, None])
            accrued = jnp.where(commit, 0.0, accrued)
            width = dg * commit.sum(-1)                          # (P,)
            targets = (stack_top + width)[:, None] * onehot      # (P, K)
            return accrued, Decision(
                targets, None, None, jnp.asarray(True)
            )

        return a0, decide


class RandomizedHedgePolicy(DeterministicHedgePolicy):
    """The randomized variant: each band draws its own threshold ``z``
    from the density e^z/(e-1) on (0, 1] at setup, lowering the expected
    competitive ratio from 2 to e/(e-1) against an oblivious adversary."""

    name = "randomized_hedge"

    def __init__(
        self,
        grid_size: int = 32,
        top_multiplier: float = 1.5,
        seed: int = 0,
    ):
        super().__init__(grid_size=grid_size, top_multiplier=top_multiplier)
        self.seed = int(seed)

    def _thresholds(self, num_pools: int) -> jnp.ndarray:
        u = jax.random.uniform(
            jax.random.PRNGKey(self.seed), (num_pools, self.grid_size)
        )
        return _hedge_threshold(u)


POLICIES: dict[str, Callable[[], Policy]] = {
    "rolling_portfolio": RollingPortfolioPolicy,
    "one_shot": OneShotPolicy,
    "hindsight": HindsightPolicy,
    "deterministic_hedge": DeterministicHedgePolicy,
    "randomized_hedge": RandomizedHedgePolicy,
}


def get_policy(policy: "Policy | str | None") -> Policy:
    """Resolve the ``policy=`` planner kwarg: None -> the paper's rolling
    loop, a registry name -> a fresh instance, an instance -> itself."""
    if policy is None:
        return RollingPortfolioPolicy()
    if isinstance(policy, Policy):
        return policy
    if isinstance(policy, str):
        try:
            return POLICIES[policy]()
        except KeyError:
            raise ValueError(
                f"unknown policy {policy!r}; known: {sorted(POLICIES)}"
            ) from None
    raise TypeError(f"policy must be a Policy, name or None, got {policy!r}")


def make_context(
    demand: jnp.ndarray,
    options: list | None = None,
    *,
    clouds: tuple[str, ...],
    od_rate: float,
    term_weighting: float = 0.0,
    cfg: fc.ForecastConfig = fc.ForecastConfig(),
    start_weeks: int,
    cadence_weeks: int = 1,
    horizon_weeks: int = 8,
    solve_fn: Callable | None = None,
) -> PolicyContext:
    """The lean context the tournament rig runs policies against: the
    shared-sort quantile solver only (no spot band, no migration, no
    grid sweep), fully traceable so one context per demand path can be
    built *inside* a vmapped program.  ``replan_fleet_pools`` builds the
    full-harness equivalent from its own closures."""
    options = options if options is not None else pf.options_from_pricing()
    demand = jnp.asarray(demand, jnp.float32)
    total_weeks = demand.shape[-1] // HOURS_PER_WEEK
    demand = demand[:, : total_weeks * HOURS_PER_WEEK]
    horizon_hours = horizon_weeks * HOURS_PER_WEEK
    al, be, avail = pf.pool_option_lines(
        options, clouds, term_weighting=term_weighting, od_rate=od_rate
    )
    qs = jax.vmap(
        lambda a_, b_: pf.handover_fractiles(a_, b_, od_rate=od_rate)
    )(al, be)
    rates = jnp.asarray([o.rate for o in options], jnp.float32)
    term_weeks = jnp.asarray([o.term_weeks for o in options], jnp.int32)
    w_hours = jnp.arange(1, horizon_weeks + 1) * HOURS_PER_WEEK
    state = fc.prefix_fit_state(
        demand, cfg, horizon_hours=horizon_hours,
        min_prefix_hours=start_weeks * HOURS_PER_WEEK,
    )

    def targets_for(yhat):
        per_h = jax.vmap(
            lambda y, q: _prefix_weighted_quantiles(y, w_hours, q)
        )(yhat, qs)
        widths, _ = jax.vmap(
            lambda ph, q: _monotone_stack(
                ph, q, term_weeks, horizon_weeks
            )
        )(per_h, qs)
        return widths, None

    return PolicyContext(
        demand=demand, options=options, clouds=tuple(clouds), od=od_rate,
        rates=rates, term_weeks=term_weeks, avail=avail, qs=qs,
        w_hours=w_hours, start_weeks=start_weeks,
        cadence_weeks=cadence_weeks, horizon_weeks=horizon_weeks,
        total_weeks=total_weeks, state=state,
        solve_fn=solve_fn if solve_fn is not None else fc.solve_prefix,
        targets_for=targets_for,
    )
