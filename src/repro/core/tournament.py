"""Adversarial policy tournament: competitive ratio + regret, vmapped.

The paper scores one strategy on one realized trace; this rig scores
every :mod:`repro.core.policy` policy across the §2 workload taxonomy
(``repro.data.scenarios``): for each policy, ONE compiled program runs
the weekly replay over every (family x seed) demand path at once —
``jit(vmap(path_cost))`` over the stacked (F*N, P, T) batch — and the
per-path hindsight-optimal constant stack (the same reference
``replan_fleet_pools`` reports) is computed once in its own vmapped
program and shared by all policies.

Reported per (policy, family, seed):

    competitive ratio   realized cost / hindsight-optimal cost  (>= 1)
    regret              realized cost - hindsight-optimal cost

so the hedging policies' classical guarantees (<= 2 deterministic,
<= e/(e-1) randomized, Ambati et al. arXiv 2004.04302) become *testable
distributions* instead of citations, and every future policy change has
a scoreboard: tests pin the deterministic bound on steady fleets and the
rolling planner's margin over both hedges on the declining fleet.

The replay here is the lean commitments-only harness (no spot /
migration / convertible bands — those key on the forecasting planner's
weekly yhat): roll off expired tranches, let the policy decide, buy
increments on decision weeks, bill committed rates plus on-demand
overflow.  ``backend="loop"`` replays the same weeks as a Python loop
(the scan-parity oracle, mirroring ``replan``'s loop backend).
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.capacity import pricing
from repro.core import forecast as fc
from repro.core import ladder as ld
from repro.core import policy as pol
from repro.core import portfolio as pf
from repro.core.demand import HOURS_PER_WEEK
from repro.data import scenarios as sc
from repro.launch import mesh as mesh_mod
from repro.obs import spans as obs_spans

pricing.validate_tables()

DEFAULT_POLICIES = (
    "rolling_portfolio", "one_shot", "deterministic_hedge",
    "randomized_hedge",
)


@dataclasses.dataclass
class TournamentReport:
    """Per-(policy, family, seed) outcome grid plus summaries."""

    policies: tuple[str, ...]
    families: tuple[str, ...]
    num_seeds: int
    start_weeks: int
    cadence_weeks: int
    horizon_weeks: int
    cost: np.ndarray               # (Pol, F, N) realized replay cost
    hindsight_cost: np.ndarray     # (F, N) per-path hindsight optimum
    competitive_ratio: np.ndarray  # (Pol, F, N) cost / hindsight
    regret: np.ndarray             # (Pol, F, N) cost - hindsight
    #: wall time, stamped by callers (benchmarks/examples) — core modules
    #: are wall-clock-free by contract (analysis rule R2)
    elapsed_s: float = 0.0

    def family_stats(self, policy: str, family: str) -> dict:
        i = self.policies.index(policy)
        j = self.families.index(family)
        cr, rg = self.competitive_ratio[i, j], self.regret[i, j]
        return {
            "cr_mean": float(cr.mean()),
            "cr_p95": float(np.quantile(cr, 0.95)),
            "cr_max": float(cr.max()),
            "regret_mean": float(rg.mean()),
            "regret_max": float(rg.max()),
        }

    def summary(self) -> dict:
        return {
            p: {f: self.family_stats(p, f) for f in self.families}
            for p in self.policies
        }

    def to_markdown(self) -> str:
        """Mean competitive ratio per policy x family, one screen."""
        head = "| policy | " + " | ".join(self.families) + " |"
        sep = "|---" * (len(self.families) + 1) + "|"
        rows = [head, sep]
        for i, p in enumerate(self.policies):
            cells = " | ".join(
                f"{self.competitive_ratio[i, j].mean():.3f}"
                for j in range(len(self.families))
            )
            rows.append(f"| {p} | {cells} |")
        return "\n".join(rows)


def _lean_replay(policy: pol.Policy, ctx: pol.PolicyContext, backend: str):
    """Total replay cost of ``policy`` on ``ctx``'s demand path: the
    commitments-only weekly harness (roll off, decide, buy increments,
    bill) — the scan body of ``replan`` minus the optional bands."""
    pstate0, decide = policy.setup(ctx)
    num_p, num_k = ctx.num_pools, ctx.num_options
    sched_len = ctx.total_weeks + int(max(
        o.term_weeks for o in ctx.options
    )) + 1
    demand_wk = ctx.demand.reshape(
        num_p, ctx.total_weeks, HOURS_PER_WEEK
    )

    def step(carry, w):
        active, rolloff, pstate = carry
        expired = jax.lax.dynamic_index_in_dim(
            rolloff, w, axis=2, keepdims=False
        )
        active = active - expired
        d_prev = (
            jax.lax.dynamic_index_in_dim(
                demand_wk, w - 1, axis=1, keepdims=False
            )
            if policy.needs_prev_demand else None
        )
        pstate, dec = decide(
            pstate, pol.Observation(week=w, active=active, d_prev=d_prev)
        )
        inc = jnp.maximum(dec.targets - active, 0.0)
        inc = jnp.where(
            dec.is_decision & (inc > ld.PURCHASE_EPS), inc, 0.0
        )
        active = active + inc
        expiry = jax.nn.one_hot(
            w + ctx.term_weeks, sched_len, dtype=rolloff.dtype
        )
        rolloff = rolloff + inc[:, :, None] * expiry[None, :, :]
        d = jax.lax.dynamic_index_in_dim(
            demand_wk, w, axis=1, keepdims=False
        )
        level = active.sum(-1)
        committed = (ctx.rates * active).sum(-1) * HOURS_PER_WEEK
        over = jnp.maximum(d - level[:, None], 0.0).sum(-1)
        return (active, rolloff, pstate), committed.sum() + ctx.od * over.sum()

    carry0 = (
        jnp.zeros((num_p, num_k), jnp.float32),
        jnp.zeros((num_p, num_k, sched_len), jnp.float32),
        pstate0,
    )
    if backend == "scan":
        _, weekly = jax.lax.scan(
            step, carry0, jnp.arange(ctx.start_weeks, ctx.total_weeks)
        )
        return weekly.sum()
    carry, total = carry0, jnp.float32(0.0)
    for w in range(ctx.start_weeks, ctx.total_weeks):
        carry, cost = step(carry, jnp.int32(w))
        total = total + cost
    return total


def _hindsight_cost(demand, *, options, clouds, od, start_weeks):
    """Per-path hindsight optimum: the optimal constant stack on the
    realized evaluation demand, billing lines (``term_weighting=0``) —
    the exact reference ``replan_fleet_pools`` reports regret against."""
    al0, be0, _ = pf.pool_option_lines(
        options, clouds, term_weighting=0.0, od_rate=od
    )
    total_weeks = demand.shape[-1] // HOURS_PER_WEEK
    ev = demand[:, start_weeks * HOURS_PER_WEEK: total_weeks * HOURS_PER_WEEK]
    plan = jax.vmap(
        lambda f_, a_, b_: pf.optimal_portfolio_stack(
            f_, a_, b_, od_rate=od
        )
    )(ev, al0, be0)
    rates = jnp.asarray([o.rate for o in options], jnp.float32)
    level = plan.widths.sum(-1)
    over = jnp.maximum(ev - level[:, None], 0.0).sum(-1)
    committed = (
        (rates * plan.widths).sum(-1)
        * (total_weeks - start_weeks) * HOURS_PER_WEEK
    )
    return committed.sum() + od * over.sum()


def run_tournament(
    policies: Sequence["pol.Policy | str"] = DEFAULT_POLICIES,
    families: Sequence[str] = sc.FAMILIES,
    *,
    num_pools: int = 3,
    num_weeks: int = 48,
    num_seeds: int = 32,
    base_seed: int = 0,
    start_weeks: int = 20,
    cadence_weeks: int = 2,
    horizon_weeks: int = 8,
    options: list | None = None,
    od_rate: float | None = None,
    cfg: fc.ForecastConfig = fc.ForecastConfig(),
    backend: Literal["scan", "loop"] = "scan",
    spans: "obs_spans.SpanRecorder | None" = None,
) -> TournamentReport:
    """Run the policy tournament: ONE compiled replay program per policy
    over every (family x seed) path, scored against per-path hindsight.

    Paths come from :func:`repro.data.scenarios.scenario_paths` (N >= 32
    seeds per family by default); clouds cycle aws/azure/gcp exactly as
    the synthetic artifact's pools do, so the Table-2 purchase options
    apply unchanged.

    ``spans`` (a :class:`repro.obs.spans.SpanRecorder`) brackets the
    hindsight pass and each policy's compiled replay with caller-side
    wall-clock spans; the clock read stays in ``repro.obs.spans``, so the
    tournament core itself remains clock-free (rules R2/R7) and
    ``spans=None`` does no timing work at all."""
    resolved = [pol.get_policy(p) for p in policies]
    families = tuple(families)
    options = options if options is not None else pf.options_from_pricing()
    od = od_rate if od_rate is not None else pricing.on_demand_premium()
    clouds = tuple(c for c, _, _ in sc.scenario_keys(num_pools))

    paths = np.stack([
        sc.scenario_paths(
            f, num_pools=num_pools, num_weeks=num_weeks,
            num_seeds=num_seeds, base_seed=base_seed,
        )
        for f in families
    ])                                      # (F, N, P, T)
    num_f = len(families)
    # Shard the (F*N) path axis across local devices when available (no-op
    # on one device): the vmapped replays are embarrassingly parallel per
    # path, so placing the batch once shards every policy's program.
    flat = mesh_mod.shard_rows(jnp.asarray(
        paths.reshape(num_f * num_seeds, num_pools, -1), jnp.float32
    ))

    solve_fn = (
        fc.solve_prefix if backend == "scan" else fc.solve_prefix_direct
    )

    def make_path_cost(policy):
        def path_cost(demand):
            ctx = pol.make_context(
                demand, options, clouds=clouds, od_rate=od, cfg=cfg,
                start_weeks=start_weeks, cadence_weeks=cadence_weeks,
                horizon_weeks=horizon_weeks, solve_fn=solve_fn,
            )
            return _lean_replay(policy, ctx, backend)
        return path_cost

    with obs_spans.span(spans, "tournament/hindsight", phase="execute"):
        hs = jax.jit(jax.vmap(
            lambda d: _hindsight_cost(
                d, options=options, clouds=clouds, od=od,
                start_weeks=start_weeks,
            )
        ))(flat)
        hindsight = np.asarray(hs, np.float64).reshape(num_f, num_seeds)

    cost = np.empty((len(resolved), num_f, num_seeds), np.float64)
    for i, policy in enumerate(resolved):
        # One compiled program per policy: the vmap batches every
        # family's every seed through the same replay.
        with obs_spans.span(
            spans, f"tournament/{policy.name}", phase="execute"
        ):
            totals = jax.jit(jax.vmap(make_path_cost(policy)))(flat)
            cost[i] = np.asarray(totals, np.float64).reshape(
                num_f, num_seeds
            )

    return TournamentReport(
        policies=tuple(p.name for p in resolved),
        families=families,
        num_seeds=num_seeds,
        start_weeks=start_weeks,
        cadence_weeks=cadence_weeks,
        horizon_weeks=horizon_weeks,
        cost=cost,
        hindsight_cost=hindsight,
        competitive_ratio=cost / hindsight[None],
        regret=cost - hindsight[None],
    )
