"""Time shifting of deferrable workloads into commitment troughs (paper §4).

Given a demand series and a commitment level, the *trough capacity*
u(t) = max(c - f(t), 0) is already paid for.  Deferrable+interruptible
internal workloads (regression tests, load tests, security scans, CI builds —
in this framework: eval jobs, checkpoint-replay regression suites, compile
farms) can be moved into those troughs, displacing demand that would
otherwise ride the peak at on-demand rates.

Model (following Sukprasert et al.'s two axes, as the paper does):
  * a job j has arrival a_j, total work w_j (chip-hours), deadline d_j,
    and is interruptible (may run in disjoint hourly slices).
  * shiftable jobs are packed into trough capacity earliest-deadline-first;
    non-shiftable demand is untouched.

``schedule_jobs`` is the host-side scheduler used by the capacity layer;
``shift_demand`` is a vectorized "fluid" approximation (fraction-of-demand
shiftable) used inside jit for planner what-if sweeps.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import commitment as cm


@dataclasses.dataclass(frozen=True)
class Job:
    arrival: int        # hour index
    work: float         # chip-hours of work
    deadline: int       # must finish by this hour (exclusive)
    interruptible: bool = True
    deferrable: bool = True


def trough_capacity(f: np.ndarray, c: float) -> np.ndarray:
    return np.maximum(c - f, 0.0)


def schedule_jobs(
    base_demand: np.ndarray, c: float, jobs: list[Job]
) -> dict:
    """EDF-pack deferrable jobs into trough capacity.

    Returns the new total demand series, the per-job placements, and the
    on-demand chip-hours avoided vs. running every job at its arrival time.
    """
    t_len = len(base_demand)
    free = trough_capacity(base_demand, c).copy()
    placed = np.zeros(t_len)

    # Cost if jobs ran at arrival (work stacked on top of base at arrival).
    naive = base_demand.copy()
    for j in jobs:
        # spread at arrival hour(s), possibly exceeding commitment
        h = min(j.arrival, t_len - 1)
        naive[h] += j.work

    placements: list[tuple[Job, list[tuple[int, float]]]] = []
    for j in sorted(jobs, key=lambda j: j.deadline):
        slices: list[tuple[int, float]] = []
        remaining = j.work
        if j.deferrable:
            lo, hi = j.arrival, min(j.deadline, t_len)
            order = np.argsort(-free[lo:hi]) + lo  # fill deepest troughs first
            for h in order:
                if remaining <= 1e-12:
                    break
                take = min(free[h], remaining)
                if take <= 0:
                    continue
                free[h] -= take
                placed[h] += take
                slices.append((int(h), float(take)))
                remaining -= take
                if not j.interruptible and slices and len(slices) > 1:
                    # non-interruptible jobs must be one contiguous slice;
                    # fall back to arrival placement
                    for hh, tk in slices:
                        free[hh] += tk
                        placed[hh] -= tk
                    slices = []
                    remaining = j.work
                    break
        if remaining > 1e-12:
            h = min(j.arrival, t_len - 1)
            placed[h] += remaining
            slices.append((h, float(remaining)))
        placements.append((j, slices))

    shifted = base_demand + placed
    od_rate = cm.DEFAULT_A
    naive_over = np.maximum(naive - c, 0.0).sum() * od_rate
    shifted_over = np.maximum(shifted - c, 0.0).sum() * od_rate
    return {
        "demand": shifted,
        "placements": placements,
        "on_demand_cost_naive": float(naive_over),
        "on_demand_cost_shifted": float(shifted_over),
        "on_demand_savings": float(naive_over - shifted_over),
    }


def shift_demand(
    f: jnp.ndarray, c: float, shiftable_frac: float
) -> jnp.ndarray:
    """Fluid approximation (jit-friendly): remove ``shiftable_frac`` of the
    demand *above* the commitment line and pour it into the troughs,
    deepest-first, conserving total work.  Used in planner sweeps to estimate
    how much time shifting flattens the optimal commitment."""
    over = jnp.maximum(f - c, 0.0)
    movable = shiftable_frac * over
    f_cut = f - movable
    budget = movable.sum()
    # Trough room per hour; hours already above the line contribute none
    # (without the clip, negative "room" poisons the fill sums and the
    # conservation rescale divides by ~0 — blowing demand up by ~1e12 when
    # the commitment sits low and the troughs cannot absorb the budget).
    room = jnp.maximum(c - f_cut, 0.0)
    placeable = jnp.minimum(budget, room.sum())

    # Water-fill the troughs: find level L <= c such that
    # sum(max(L - f_cut, 0) clipped to trough room) == placeable.
    def fill_amount(level):
        return jnp.minimum(jnp.maximum(level - f_cut, 0.0), room).sum()

    lo = f_cut.min()
    hi = c

    def body(_, st):
        lo, hi = st
        mid = 0.5 * (lo + hi)
        too_much = fill_amount(mid) > placeable
        return jnp.where(too_much, lo, mid), jnp.where(too_much, mid, hi)

    import jax

    lo, hi = jax.lax.fori_loop(0, 40, body, (lo, hi))
    level = 0.5 * (lo + hi)
    add = jnp.minimum(jnp.maximum(level - f_cut, 0.0), room)
    # Exact conservation: scale the fill to the placeable budget; work the
    # troughs cannot absorb stays on the timeline, spread uniformly.
    add = add * (placeable / jnp.maximum(add.sum(), 1e-12))
    excess = (budget - placeable) / f.shape[-1]
    return f_cut + add + excess


def shiftable_supply_stats(f: np.ndarray, c: float) -> dict:
    """Paper §4: the optimal commitment leaves ~4.3% of committed capacity
    unused, concentrated on weekends/nights; report that supply."""
    unused = trough_capacity(f, c)
    total_commit = c * len(f)
    hours = np.arange(len(f))
    dow = (hours // 24) % 7
    weekend = unused[(dow >= 5)].sum()
    return {
        "unused_frac": float(unused.sum() / total_commit),
        "weekend_share": float(weekend / max(unused.sum(), 1e-12)),
        "unused_chip_hours": float(unused.sum()),
    }
