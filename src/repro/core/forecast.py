"""JAX structural time-series forecaster (paper §3.3.3, Prophet replacement).

The paper fits Prophet [Taylor & Letham 2018] with a *weighted* error metric
whose asymmetry matches the cost asymmetry (under-forecast pays 2.1x
on-demand; over-forecast pays 1x unused commitment).  We replace Prophet with
a JAX-native decomposable model over hourly data

    y_t = trend(t) * seasonality(t) * holiday(t) * (1 + eps)

fit in log-space as a linear model:

    log y = beta . [1, t, relu(t - cp_1..K),            # piecewise trend
                    fourier_daily, fourier_weekly, fourier_yearly,
                    holiday_dummy]

solved by ridge-regularized weighted least squares (normal equations), with
IRLS reweighting to realize the asymmetric error metric: residuals where the
model under-forecasts get weight ``asym`` (=A/B=2.1), over-forecasts weight 1.
The whole fit is jit-able and vmappable over thousands of pools.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.demand import DAYS_PER_YEAR, HOURS_PER_DAY, HOURS_PER_WEEK

HOURS_PER_YEAR = HOURS_PER_DAY * DAYS_PER_YEAR


@dataclasses.dataclass(frozen=True)
class ForecastConfig:
    daily_order: int = 4        # Fourier harmonics per period
    weekly_order: int = 6
    yearly_order: int = 8
    num_changepoints: int = 8   # evenly spaced piecewise-linear trend knots
    ridge: float = 1e-3
    asym_weight: float = 2.1    # paper footnote 2: under-forecast costs 2.1x
    irls_iters: int = 4
    holiday_start_day: int = 357  # Dec 24 (day-of-year, 0-based)
    holiday_len_days: int = 9


def _fourier(t: jnp.ndarray, period: float, order: int) -> jnp.ndarray:
    """(T, 2*order) Fourier design block."""
    k = jnp.arange(1, order + 1, dtype=jnp.float32)
    ang = 2.0 * jnp.pi * t[:, None] * k[None, :] / period
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def design_matrix(
    t_hours: jnp.ndarray, cfg: ForecastConfig, t_max: float
) -> jnp.ndarray:
    """Feature matrix X (T, D).  ``t_max`` fixes changepoint locations so the
    same basis extends consistently into the future."""
    t = t_hours.astype(jnp.float32)
    ts = t / t_max  # normalized time for trend columns
    cols = [jnp.ones_like(ts)[:, None], ts[:, None]]
    if cfg.num_changepoints:
        cps = jnp.linspace(0.1, 0.9, cfg.num_changepoints)
        cols.append(jnp.maximum(ts[:, None] - cps[None, :], 0.0))
    cols.append(_fourier(t, HOURS_PER_DAY, cfg.daily_order))
    cols.append(_fourier(t, HOURS_PER_WEEK, cfg.weekly_order))
    cols.append(_fourier(t, HOURS_PER_YEAR, cfg.yearly_order))
    day_of_year = jnp.mod(t // HOURS_PER_DAY, DAYS_PER_YEAR)
    holiday = (
        (day_of_year >= cfg.holiday_start_day)
        & (day_of_year < cfg.holiday_start_day + cfg.holiday_len_days)
    ).astype(jnp.float32)
    cols.append(holiday[:, None])
    return jnp.concatenate(cols, axis=-1)


@dataclasses.dataclass
class ForecastModel:
    beta: jnp.ndarray  # (D,)
    t_max: float
    cfg: ForecastConfig


def _solve_wls(x, y, w, ridge):
    xw = x * w[:, None]
    gram = xw.T @ x + ridge * jnp.eye(x.shape[1], dtype=x.dtype)
    rhs = xw.T @ y
    return jnp.linalg.solve(gram, rhs)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _fit(y: jnp.ndarray, cfg: ForecastConfig, t_max: float):
    t = jnp.arange(y.shape[-1], dtype=jnp.float32)
    x = design_matrix(t, cfg, t_max)
    logy = jnp.log(jnp.maximum(y, 1e-6))

    beta = _solve_wls(x, logy, jnp.ones_like(logy), cfg.ridge)

    def irls_step(beta, _):
        resid = logy - x @ beta
        # Under-forecast (actual above prediction) weighted ``asym`` heavier.
        w = jnp.where(resid > 0, cfg.asym_weight, 1.0)
        return _solve_wls(x, logy, w, cfg.ridge), None

    beta, _ = jax.lax.scan(irls_step, beta, None, length=cfg.irls_iters)
    return beta


def fit(y: jnp.ndarray, cfg: ForecastConfig = ForecastConfig()) -> ForecastModel:
    """Fit on an hourly history ``y`` (T,). Returns a ForecastModel.

    Yearly Fourier terms are disabled automatically when the history is
    shorter than ~1.2 years: with less than one full cycle observed they are
    unidentifiable and extrapolate wildly (the same guard Prophet applies).
    """
    if y.shape[-1] < 1.2 * HOURS_PER_YEAR and cfg.yearly_order:
        cfg = dataclasses.replace(cfg, yearly_order=0)
    t_max = float(max(y.shape[-1] - 1, 1))
    beta = _fit(y, cfg, t_max)
    return ForecastModel(beta=beta, t_max=t_max, cfg=cfg)


def predict(model: ForecastModel, t_hours: jnp.ndarray) -> jnp.ndarray:
    """Predict demand at absolute hour indices ``t_hours`` (may be future)."""
    x = design_matrix(t_hours.astype(jnp.float32), model.cfg, model.t_max)
    return jnp.exp(x @ model.beta)


def forecast_horizon(
    model: ForecastModel, t_start: int, num_hours: int
) -> jnp.ndarray:
    """Forecast ``num_hours`` starting at absolute hour ``t_start`` (Step 1 of
    Algorithm 1 uses num_hours = 52*7*24)."""
    t = t_start + jnp.arange(num_hours)
    return predict(model, t)


def weighted_mape(
    y_true: jnp.ndarray, y_pred: jnp.ndarray, asym: float = 2.1
) -> jnp.ndarray:
    """The paper's asymmetric error metric (footnote 2): under-forecast errors
    (y_true > y_pred, i.e. we'd pay on-demand) cost ``asym`` x more."""
    err = (y_true - y_pred) / jnp.maximum(y_true, 1e-9)
    w = jnp.where(err > 0, asym, 1.0)
    return (w * jnp.abs(err)).mean(-1)


# Batched fits across pools: vmap over the leading axis of ``ys``.
def fit_batched(ys: jnp.ndarray, cfg: ForecastConfig = ForecastConfig()):
    """``fit`` vmapped over a (P, T) pool batch — same short-history guard
    on the yearly Fourier terms as the single-series path."""
    if ys.shape[-1] < 1.2 * HOURS_PER_YEAR and cfg.yearly_order:
        cfg = dataclasses.replace(cfg, yearly_order=0)
    t_max = float(max(ys.shape[-1] - 1, 1))
    betas = jax.vmap(lambda y: _fit(y, cfg, t_max))(ys)
    return ForecastModel(beta=betas, t_max=t_max, cfg=cfg)


def predict_batched(model: ForecastModel, t_hours: jnp.ndarray) -> jnp.ndarray:
    x = design_matrix(t_hours.astype(jnp.float32), model.cfg, model.t_max)
    return jnp.exp(model.beta @ x.T)
