"""JAX structural time-series forecaster (paper §3.3.3, Prophet replacement).

The paper fits Prophet [Taylor & Letham 2018] with a *weighted* error metric
whose asymmetry matches the cost asymmetry (under-forecast pays 2.1x
on-demand; over-forecast pays 1x unused commitment).  We replace Prophet with
a JAX-native decomposable model over hourly data

    y_t = trend(t) * seasonality(t) * holiday(t) * (1 + eps)

fit in log-space as a linear model:

    log y = beta . [1, t, relu(t - cp_1..K),            # piecewise trend
                    fourier_daily, fourier_weekly, fourier_yearly,
                    holiday_dummy]

solved by ridge-regularized weighted least squares (normal equations), with
IRLS reweighting to realize the asymmetric error metric: residuals where the
model under-forecasts get weight ``asym`` (=A/B=2.1), over-forecasts weight 1.
The whole fit is jit-able and vmappable over thousands of pools.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.demand import DAYS_PER_YEAR, HOURS_PER_DAY, HOURS_PER_WEEK

HOURS_PER_YEAR = HOURS_PER_DAY * DAYS_PER_YEAR


@dataclasses.dataclass(frozen=True)
class ForecastConfig:
    daily_order: int = 4        # Fourier harmonics per period
    weekly_order: int = 6
    yearly_order: int = 8
    num_changepoints: int = 8   # evenly spaced piecewise-linear trend knots
    ridge: float = 1e-3
    asym_weight: float = 2.1    # paper footnote 2: under-forecast costs 2.1x
    irls_iters: int = 4
    holiday_start_day: int = 357  # Dec 24 (day-of-year, 0-based)
    holiday_len_days: int = 9


def _fourier(t: jnp.ndarray, period: float, order: int) -> jnp.ndarray:
    """(T, 2*order) Fourier design block."""
    k = jnp.arange(1, order + 1, dtype=jnp.float32)
    ang = 2.0 * jnp.pi * t[:, None] * k[None, :] / period
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def design_matrix(
    t_hours: jnp.ndarray, cfg: ForecastConfig, t_max: float
) -> jnp.ndarray:
    """Feature matrix X (T, D).  ``t_max`` fixes changepoint locations so the
    same basis extends consistently into the future."""
    t = t_hours.astype(jnp.float32)
    ts = t / t_max  # normalized time for trend columns
    cols = [jnp.ones_like(ts)[:, None], ts[:, None]]
    if cfg.num_changepoints:
        cps = jnp.linspace(0.1, 0.9, cfg.num_changepoints)
        cols.append(jnp.maximum(ts[:, None] - cps[None, :], 0.0))
    cols.append(_fourier(t, HOURS_PER_DAY, cfg.daily_order))
    cols.append(_fourier(t, HOURS_PER_WEEK, cfg.weekly_order))
    cols.append(_fourier(t, HOURS_PER_YEAR, cfg.yearly_order))
    day_of_year = jnp.mod(t // HOURS_PER_DAY, DAYS_PER_YEAR)
    holiday = (
        (day_of_year >= cfg.holiday_start_day)
        & (day_of_year < cfg.holiday_start_day + cfg.holiday_len_days)
    ).astype(jnp.float32)
    cols.append(holiday[:, None])
    return jnp.concatenate(cols, axis=-1)


@dataclasses.dataclass
class ForecastModel:
    beta: jnp.ndarray  # (D,)
    t_max: float
    cfg: ForecastConfig


def _solve_wls(x, y, w, ridge):
    xw = x * w[:, None]
    gram = xw.T @ x + ridge * jnp.eye(x.shape[1], dtype=x.dtype)
    rhs = xw.T @ y
    return jnp.linalg.solve(gram, rhs)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _fit(y: jnp.ndarray, cfg: ForecastConfig, t_max: float):
    t = jnp.arange(y.shape[-1], dtype=jnp.float32)
    x = design_matrix(t, cfg, t_max)
    logy = jnp.log(jnp.maximum(y, 1e-6))

    beta = _solve_wls(x, logy, jnp.ones_like(logy), cfg.ridge)

    def irls_step(beta, _):
        resid = logy - x @ beta
        # Under-forecast (actual above prediction) weighted ``asym`` heavier.
        w = jnp.where(resid > 0, cfg.asym_weight, 1.0)
        return _solve_wls(x, logy, w, cfg.ridge), None

    beta, _ = jax.lax.scan(irls_step, beta, None, length=cfg.irls_iters)
    return beta


def fit(y: jnp.ndarray, cfg: ForecastConfig = ForecastConfig()) -> ForecastModel:
    """Fit on an hourly history ``y`` (T,). Returns a ForecastModel.

    Yearly Fourier terms are disabled automatically when the history is
    shorter than ~1.2 years: with less than one full cycle observed they are
    unidentifiable and extrapolate wildly (the same guard Prophet applies).
    """
    if y.shape[-1] < 1.2 * HOURS_PER_YEAR and cfg.yearly_order:
        cfg = dataclasses.replace(cfg, yearly_order=0)
    t_max = float(max(y.shape[-1] - 1, 1))
    beta = _fit(y, cfg, t_max)
    return ForecastModel(beta=beta, t_max=t_max, cfg=cfg)


def predict(model: ForecastModel, t_hours: jnp.ndarray) -> jnp.ndarray:
    """Predict demand at absolute hour indices ``t_hours`` (may be future)."""
    x = design_matrix(t_hours.astype(jnp.float32), model.cfg, model.t_max)
    return jnp.exp(x @ model.beta)


def forecast_horizon(
    model: ForecastModel, t_start: int, num_hours: int
) -> jnp.ndarray:
    """Forecast ``num_hours`` starting at absolute hour ``t_start`` (Step 1 of
    Algorithm 1 uses num_hours = 52*7*24)."""
    t = t_start + jnp.arange(num_hours)
    return predict(model, t)


def weighted_mape(
    y_true: jnp.ndarray, y_pred: jnp.ndarray, asym: float = 2.1
) -> jnp.ndarray:
    """The paper's asymmetric error metric (footnote 2): under-forecast errors
    (y_true > y_pred, i.e. we'd pay on-demand) cost ``asym`` x more."""
    err = (y_true - y_pred) / jnp.maximum(y_true, 1e-9)
    w = jnp.where(err > 0, asym, 1.0)
    return (w * jnp.abs(err)).mean(-1)


def _ridge_solve(gram: jnp.ndarray, rhs: jnp.ndarray, ridge: float):
    """Solve (gram + ridge I) beta = rhs for one shared gram and a batch of
    right-hand sides rhs (P, D) -> (P, D)."""
    g = gram + ridge * jnp.eye(gram.shape[-1], dtype=gram.dtype)
    return jnp.linalg.solve(g, rhs.T).T


@dataclasses.dataclass(frozen=True)
class PrefixFitState:
    """Precomputed normal-equation state for *rolling* prefix re-fits.

    The rolling planner re-fits the forecaster every week on the extended
    demand prefix.  Re-running :func:`fit_batched` per week costs a full
    O(T D^2) design pass per refit; but with one FIXED design matrix (time
    normalization ``t_max`` and changepoint locations pinned to the full
    trace so every week solves in the same basis), the week-w normal
    equations are *prefix sums* of per-week blocks:

        gram_prefix[w] = sum_{t < (w+1) 168} x_t x_t^T     (pool-shared)
        rhs_prefix[p, w] = sum_{t < (w+1) 168} x_t log y_{p,t}

    so a refit inside ``lax.scan`` is one (D, D) gather + ridge solve —
    O(D^3) per week instead of O(T D^2) — which is what makes a multi-year
    replay one compiled program (see ``repro.core.replan``).

    Unweighted (the IRLS asymmetry reweights per-residual and therefore
    needs a full masked pass; :func:`irls_refine` provides it as an optional
    exact refinement on top of the prefix solve).
    """

    x: jnp.ndarray            # (T + H, D) design over history + horizon
    gram_prefix: jnp.ndarray  # (W, D, D) cumulative X^T X per week prefix
    rhs_prefix: jnp.ndarray   # (P, W, D) cumulative X^T log y per prefix
    logy: jnp.ndarray         # (P, T) log-space targets
    cfg: ForecastConfig
    t_max: float
    num_hist_hours: int
    period_hours: int

    @property
    def num_weeks(self) -> int:
        return self.gram_prefix.shape[0]


def prefix_fit_state(
    ys: jnp.ndarray,
    cfg: ForecastConfig = ForecastConfig(),
    *,
    horizon_hours: int,
    period_hours: int = HOURS_PER_WEEK,
    min_prefix_hours: int | None = None,
) -> PrefixFitState:
    """Build the rolling-refit state for a (P, T) pool batch.

    ``min_prefix_hours`` is the shortest prefix any refit will see: the
    short-history guard on the yearly Fourier terms keys on it (the one-shot
    ``fit`` keys the same guard on its single history length).  T is
    truncated to whole periods."""
    ys = jnp.asarray(ys, jnp.float32)
    num_weeks = ys.shape[-1] // period_hours
    t_hist = num_weeks * period_hours
    ys = ys[..., :t_hist]
    guard_hours = t_hist if min_prefix_hours is None else min_prefix_hours
    if guard_hours < 1.2 * HOURS_PER_YEAR and cfg.yearly_order:
        cfg = dataclasses.replace(cfg, yearly_order=0)
    t_max = float(max(t_hist - 1, 1))
    t_all = jnp.arange(t_hist + horizon_hours, dtype=jnp.float32)
    x = design_matrix(t_all, cfg, t_max)
    xh = x[:t_hist]
    d = xh.shape[-1]
    xw = xh.reshape(num_weeks, period_hours, d)
    gram_prefix = jnp.cumsum(
        jnp.einsum("wtd,wte->wde", xw, xw), axis=0
    )
    logy = jnp.log(jnp.maximum(ys, 1e-6))
    lw = logy.reshape(ys.shape[0], num_weeks, period_hours)
    rhs_prefix = jnp.cumsum(jnp.einsum("wtd,pwt->pwd", xw, lw), axis=1)
    return PrefixFitState(
        x=x, gram_prefix=gram_prefix, rhs_prefix=rhs_prefix, logy=logy,
        cfg=cfg, t_max=t_max, num_hist_hours=t_hist,
        period_hours=period_hours,
    )


def solve_prefix(state: PrefixFitState, week) -> jnp.ndarray:
    """beta (P, D) fit on the prefix of ``week`` whole periods — one gather
    into the cumulative normal equations + a ridge solve.  ``week`` may be a
    traced integer (scan-safe); must be >= 1."""
    g = jax.lax.dynamic_index_in_dim(
        state.gram_prefix, week - 1, axis=0, keepdims=False
    )
    r = jax.lax.dynamic_index_in_dim(
        state.rhs_prefix, week - 1, axis=1, keepdims=False
    )
    return _ridge_solve(g, r, state.cfg.ridge)


def solve_prefix_direct(state: PrefixFitState, week) -> jnp.ndarray:
    """The same prefix fit computed the naive way: mask the full design and
    re-accumulate the normal equations from scratch, O(T D^2) per call.
    This is the python-loop replay baseline the scan path is benched
    against; it differs from :func:`solve_prefix` only in float summation
    order."""
    xh = state.x[: state.num_hist_hours]
    t = jnp.arange(state.num_hist_hours)
    mask = (t < week * state.period_hours).astype(xh.dtype)
    xm = xh * mask[:, None]
    g = xm.T @ xh
    r = jnp.einsum("td,pt->pd", xm, state.logy)
    return _ridge_solve(g, r, state.cfg.ridge)


def irls_refine(
    state: PrefixFitState, beta: jnp.ndarray, week, iters: int
) -> jnp.ndarray:
    """Optional asymmetric-error refinement of a prefix fit: ``iters`` IRLS
    passes over the masked prefix (under-forecast residuals weighted
    ``cfg.asym_weight``).  Each pass is a full O(P T D^2) masked
    accumulation — exact but W-times more expensive inside a replay, hence
    opt-in (``iters=0`` keeps the pure prefix-sum path)."""
    if iters == 0:
        return beta
    xh = state.x[: state.num_hist_hours]
    t = jnp.arange(state.num_hist_hours)
    mask = (t < week * state.period_hours).astype(xh.dtype)
    eye = state.cfg.ridge * jnp.eye(xh.shape[-1], dtype=xh.dtype)
    for _ in range(iters):
        resid = state.logy - beta @ xh.T                     # (P, T)
        w = jnp.where(resid > 0, state.cfg.asym_weight, 1.0) * mask
        g = jnp.einsum("pt,td,te->pde", w, xh, xh)           # (P, D, D)
        r = jnp.einsum("pt,td->pd", w * state.logy, xh)
        beta = jax.vmap(lambda gi, ri: jnp.linalg.solve(gi + eye, ri))(g, r)
    return beta


def solve_prefix_adjusted(
    state: PrefixFitState, week, gram_adj: jnp.ndarray, rhs_adj: jnp.ndarray
) -> jnp.ndarray:
    """Prefix fit with carried IRLS weight-adjustment moments.

    The asymmetric weights ``w = 1 + (asym-1)[resid > 0]`` split the
    weighted normal equations into the unweighted prefix sums (already in
    ``state``) plus an adjustment accumulated only over under-forecast
    hours: ``gram_adj (P, D, D)``, ``rhs_adj (P, D)``.  Solving

        (gram_prefix[w] + gram_adj + ridge I) beta = rhs_prefix[w] + rhs_adj

    reproduces a weighted fit without any O(T D^2) pass — the carried-
    moments half of the incremental IRLS scheme (see
    :func:`irls_carry_init` / :func:`irls_carry_extend`)."""
    g = jax.lax.dynamic_index_in_dim(
        state.gram_prefix, week - 1, axis=0, keepdims=False
    )
    r = jax.lax.dynamic_index_in_dim(
        state.rhs_prefix, week - 1, axis=1, keepdims=False
    )
    eye = state.cfg.ridge * jnp.eye(g.shape[-1], dtype=g.dtype)
    return jax.vmap(
        lambda ga, ri: jnp.linalg.solve(g + ga + eye, ri)
    )(gram_adj, r + rhs_adj)


def irls_carry_init(
    state: PrefixFitState, week: int, iters: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Exact IRLS adjustment moments on the ``week``-period start prefix.

    Runs the full masked IRLS (matching :func:`irls_refine`) once at trace
    time and returns the final iteration's weight-adjustment moments
    ``(gram_adj (P, D, D), rhs_adj (P, D))``.  A replay seeds its scan
    carry with these, then keeps them current with
    :func:`irls_carry_extend` — O(period D^2) per replayed week instead of
    ``iters`` full O(T D^2) passes."""
    beta = solve_prefix(state, week)
    xh = state.x[: state.num_hist_hours]
    t = jnp.arange(state.num_hist_hours)
    mask = (t < week * state.period_hours).astype(xh.dtype)
    num_p, d = state.logy.shape[0], xh.shape[-1]
    g_adj = jnp.zeros((num_p, d, d), xh.dtype)
    r_adj = jnp.zeros((num_p, d), xh.dtype)
    for _ in range(max(iters, 0)):
        resid = state.logy - beta @ xh.T                     # (P, T)
        wadj = (state.cfg.asym_weight - 1.0) * (resid > 0) * mask
        g_adj = jnp.einsum("pt,td,te->pde", wadj, xh, xh)
        r_adj = jnp.einsum("pt,td->pd", wadj * state.logy, xh)
        beta = solve_prefix_adjusted(state, week, g_adj, r_adj)
    return g_adj, r_adj


def irls_carry_extend(
    state: PrefixFitState,
    beta: jnp.ndarray,
    gram_adj: jnp.ndarray,
    rhs_adj: jnp.ndarray,
    week,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Extend carried IRLS moments with period ``week``'s demand block.

    Classifies only the newest period's residuals under the *current*
    ``beta`` and adds their asymmetric-weight contribution, so the moments
    cover the ``week+1``-period prefix for the next refit.  Older periods
    keep the classification they had when appended (frozen-weights IRLS) —
    the approximation that buys O(period D^2)/week; the closeness test
    pins it against the exact :func:`irls_refine` path.  Scan-safe
    (``week`` may be traced)."""
    ph = state.period_hours
    xb = jax.lax.dynamic_slice_in_dim(
        state.x, week * ph, ph, axis=0
    )                                                        # (ph, D)
    lb = jax.lax.dynamic_slice_in_dim(
        state.logy, week * ph, ph, axis=1
    )                                                        # (P, ph)
    resid = lb - beta @ xb.T
    wadj = (state.cfg.asym_weight - 1.0) * (resid > 0)
    dg = jnp.einsum("pt,td,te->pde", wadj, xb, xb)
    dr = jnp.einsum("pt,td->pd", wadj * lb, xb)
    return gram_adj + dg, rhs_adj + dr


def predict_from_beta(
    state: PrefixFitState, beta: jnp.ndarray, t_start, num_hours: int
) -> jnp.ndarray:
    """(P, num_hours) forecast from prefix-fit betas starting at absolute
    hour ``t_start`` (traced-safe dynamic slice into the shared design)."""
    xf = jax.lax.dynamic_slice_in_dim(state.x, t_start, num_hours, axis=0)
    return jnp.exp(beta @ xf.T)


def weekly_fractile_levels(
    yhat: jnp.ndarray,
    fractiles,
    hours: int = HOURS_PER_WEEK,
) -> jnp.ndarray:
    """(..., Q) fractile levels of the first ``hours`` of a forecast.

    The pure-model band: quantiles of the smooth structural fit's own
    hourly distribution.  The calibration telemetry and the breach
    cadence both use :func:`anchored_fractile_levels` instead (same
    shape, realized-spread anchored) because the smooth fit alone
    under-disperses; this variant remains for model-only diagnostics."""
    q = jnp.asarray(fractiles, yhat.dtype)
    levels = jnp.quantile(yhat[..., :hours], q, axis=-1)
    return jnp.moveaxis(levels, 0, -1)


#: Trailing realized weeks pooled into the anchored band's empirical
#: spread.  Four weeks keeps steady-family coverage within ~1pp of
#: nominal while still tracking level moves within a month.
TRAIL_WEEKS = 4


def anchored_fractile_levels(d_trail: jnp.ndarray, fractiles) -> jnp.ndarray:
    """(..., Q) forecast fractile levels for the coming week, anchored on
    the realized hourly distribution of the trailing window.

    Empirical quantiles of ``d_trail`` ((..., TRAIL_WEEKS*168) hours) —
    the persistence-quantile forecast of next week's hourly distribution.
    The smooth structural fit is deliberately NOT blended in: ridge + the
    finite Fourier order shrink its seasonal amplitude and it carries no
    residual noise, so :func:`weekly_fractile_levels` of the fit alone
    under-covers the tails by ~20pp, and shifting this band by the fit's
    predicted mean move only injects fit noise (measured: coverage drift
    1pp -> 8pp on the steady family).  Anchoring keeps coverage within
    ~1pp of nominal on predictable families while regime shifts — which
    a trailing window cannot see coming — still degrade it, exactly the
    signal the calibration telemetry and the breach cadence key on."""
    q = jnp.asarray(fractiles, d_trail.dtype)
    base = jnp.quantile(d_trail, q, axis=-1)
    return jnp.moveaxis(base, 0, -1)


# Batched fits across pools: vmap over the leading axis of ``ys``.
def fit_batched(ys: jnp.ndarray, cfg: ForecastConfig = ForecastConfig()):
    """``fit`` vmapped over a (P, T) pool batch — same short-history guard
    on the yearly Fourier terms as the single-series path."""
    if ys.shape[-1] < 1.2 * HOURS_PER_YEAR and cfg.yearly_order:
        cfg = dataclasses.replace(cfg, yearly_order=0)
    t_max = float(max(ys.shape[-1] - 1, 1))
    betas = jax.vmap(lambda y: _fit(y, cfg, t_max))(ys)
    return ForecastModel(beta=betas, t_max=t_max, cfg=cfg)


def predict_batched(model: ForecastModel, t_hours: jnp.ndarray) -> jnp.ndarray:
    x = design_matrix(t_hours.astype(jnp.float32), model.cfg, model.t_max)
    return jnp.exp(model.beta @ x.T)
