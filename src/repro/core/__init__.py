"""Shaved Ice core: the paper's contribution as composable JAX modules.

  api         — unified PlanRequest front door (one request in, plan out)
  demand      — §2 demand characterization + calibrated synthetic traces
  commitment  — §3.1-3.2 two-sided commitment cost + solvers
  forecast    — §3.3.3 structural forecaster (Prophet replacement)
  planner     — Algorithm 1 (forecast -> per-horizon optima -> min)
  ladder      — §3.3.4 staggered commitments / expirations
  timeshift   — §4 deferrable-workload scheduling into troughs
  freepool    — §5 predictive pre-provisioning (newsvendor pools)
  portfolio   — §3 generalized to Table-2 purchase-option stacks
  replan      — §3.3.3-3.3.4 rolling weekly re-planning (one lax.scan)
  spot        — preemptible capacity: effective spot line + chance
                constraint over capacity.preemption's revocation process
"""

from repro.core import (  # noqa: F401
    api,
    commitment,
    demand,
    forecast,
    freepool,
    ladder,
    planner,
    portfolio,
    replan,
    spot,
    timeshift,
)
