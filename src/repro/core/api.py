"""Unified planner request API: one frozen :class:`PlanRequest` in, one
plan out.

The planner surface grew band by band — spot, migration, convertibles,
policies, scenario batching — and with it ``plan_fleet_pools`` grew a
kwarg soup whose rolling-mode knobs were invisible ``**rolling_kw``
pass-throughs.  This module is the redesigned front door:

    request = PlanRequest(
        pools=pools,
        mode="rolling",
        rolling=RollingConfig(cadence_weeks=2, start_weeks=26),
        spot=True,
        scenarios=ScenarioConfig(n_scenarios=32, family="regime"),
    )
    report = plan(request)

Everything is validated eagerly in ``__post_init__`` — an unknown policy
name, a bool where a config belongs, or a rolling-only knob on a one-shot
request fails at *construction*, not three bands deep into a jitted replay.
The legacy ``plan_fleet_pools(pools, mode=..., cadence_weeks=...)``
spelling still works: it is now a thin shim that builds the equivalent
``PlanRequest`` (emitting a ``DeprecationWarning`` for loose rolling
kwargs) and calls :func:`plan`, so both spellings are bit-identical by
construction — and golden-tested to stay that way.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Literal

from repro.core import forecast as fc
from repro.core import policy as pol
from repro.data.scenarios import ScenarioConfig, resolve_scenarios
from repro.obs.config import TelemetryConfig, resolve_telemetry

__all__ = [
    "PlanRequest",
    "RollingConfig",
    "ScenarioConfig",
    "TelemetryConfig",
    "plan",
]

_SOLVERS = ("quantile", "grid")
_BACKENDS = ("scan", "loop")
_MODES = ("one_shot", "rolling")


@dataclasses.dataclass(frozen=True)
class RollingConfig:
    """Rolling-replay knobs of a :class:`PlanRequest` (``mode="rolling"``).

    The defaults reproduce ``replan_fleet_pools``'s defaults exactly; see
    :func:`repro.core.replan.replan_fleet_pools` for the semantics of each
    field.  ``irls_carry`` makes ``irls_iters > 0`` cheap per replayed
    week by carrying reweighted normal-equation moments in the scan state
    instead of re-running the masked design pass."""

    cadence_weeks: int = 1
    start_weeks: int | None = None
    solver: Literal["quantile", "grid"] = "quantile"
    num_grid: int = 128
    use_kernel: bool = False
    irls_iters: int = 0
    irls_carry: bool = False
    backend: Literal["scan", "loop"] = "scan"
    compare: bool = True
    #: "weekly" re-solves on the ``cadence_weeks`` grid (the default,
    #: bit-identical to pre-cadence builds); "breach" re-solves only in
    #: weeks where last week's realized demand exited the forecast band
    #: held since the previous decision (forecasting policies only).
    cadence: Literal["weekly", "breach"] = "weekly"
    #: (q_lo, q_hi) forecast fractiles framing the breach band.
    breach_band: tuple = (0.05, 0.95)
    #: a week breaches when more than ``tolerance x nominal miss mass``
    #: of its 168 hours exit the band (exact integer hour budget).
    breach_tolerance: float = 4.0

    def __post_init__(self):
        if self.cadence_weeks < 1:
            raise ValueError(
                f"cadence_weeks must be >= 1, got {self.cadence_weeks}"
            )
        if self.cadence not in ("weekly", "breach"):
            raise ValueError(
                f"unknown cadence {self.cadence!r}; "
                "known: ('weekly', 'breach')"
            )
        if self.cadence == "breach" and self.cadence_weeks != 1:
            raise ValueError(
                "cadence='breach' evaluates every week and masks "
                "decisions itself; combine it with cadence_weeks=1, "
                f"got cadence_weeks={self.cadence_weeks}"
            )
        if len(self.breach_band) != 2:
            raise ValueError(
                f"breach_band must be a (lo, hi) pair, got {self.breach_band}"
            )
        lo, hi = self.breach_band
        if not 0.0 < lo < hi < 1.0:
            raise ValueError(
                "breach_band must be an increasing fractile pair inside "
                f"(0, 1), got {self.breach_band}"
            )
        if self.breach_tolerance <= 0.0:
            raise ValueError(
                f"breach_tolerance must be > 0, got {self.breach_tolerance}"
            )
        if self.start_weeks is not None and self.start_weeks < 1:
            raise ValueError(
                f"start_weeks must be >= 1 or None, got {self.start_weeks}"
            )
        if self.solver not in _SOLVERS:
            raise ValueError(
                f"unknown solver {self.solver!r}; known: {_SOLVERS}"
            )
        if self.num_grid < 2:
            raise ValueError(f"num_grid must be >= 2, got {self.num_grid}")
        if self.irls_iters < 0:
            raise ValueError(
                f"irls_iters must be >= 0, got {self.irls_iters}"
            )
        if self.backend not in _BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; known: {_BACKENDS}"
            )


@dataclasses.dataclass(frozen=True)
class PlanRequest:
    """One planner invocation, fully specified and eagerly validated.

    ``pools`` carries the (P, T) demand; the optional band configs nest
    their own dataclasses (:class:`repro.core.spot.SpotConfig`,
    :class:`repro.capacity.generations.MigrationConfig`, convertible
    purchase options, a :class:`repro.core.policy.Policy` or registry
    name, a :class:`repro.data.scenarios.ScenarioConfig`), each keeping
    the ``True``/int conveniences of the kwarg spelling.  Rolling-only
    knobs live in ``rolling``; setting them on a one-shot request is a
    construction-time error rather than a silently ignored kwarg."""

    pools: Any
    options: list | None = None
    mode: Literal["one_shot", "rolling"] = "one_shot"
    horizon_weeks: int = 8
    od_rate: float | None = None
    term_weighting: float = 0.0
    forecast: fc.ForecastConfig = dataclasses.field(
        default_factory=fc.ForecastConfig
    )
    spot: Any = None            # SpotConfig | bool | None
    migration: Any = None       # MigrationConfig | bool | None
    convertible: Any = None     # list[PurchaseOption] | bool | None
    policy: Any = None          # Policy | str | None
    scenarios: "ScenarioConfig | int | None" = None
    telemetry: "TelemetryConfig | bool | None" = None
    rolling: RollingConfig = dataclasses.field(default_factory=RollingConfig)

    def __post_init__(self):
        from repro.capacity import generations as gn
        from repro.core import spot as spot_mod

        if self.mode not in _MODES:
            raise ValueError(
                f"unknown mode {self.mode!r}; known: {_MODES}"
            )
        if self.horizon_weeks < 1:
            raise ValueError(
                f"horizon_weeks must be >= 1, got {self.horizon_weeks}"
            )
        if not isinstance(self.rolling, RollingConfig):
            raise TypeError(
                "rolling= takes a RollingConfig, got "
                f"{type(self.rolling).__name__}"
            )
        if not isinstance(self.forecast, fc.ForecastConfig):
            raise TypeError(
                "forecast= takes a ForecastConfig, got "
                f"{type(self.forecast).__name__}"
            )
        # Band configs: run each resolver once so malformed specs fail
        # here (the planner re-resolves identically — both are pure).
        if self.spot is not None and not isinstance(self.spot, bool):
            if not isinstance(self.spot, spot_mod.SpotConfig):
                raise TypeError(
                    "spot= takes a SpotConfig, bool, or None, got "
                    f"{type(self.spot).__name__}"
                )
        gn.resolve_migration(self.migration)
        if isinstance(self.policy, str) and self.policy not in pol.POLICIES:
            raise ValueError(
                f"unknown policy {self.policy!r}; "
                f"known: {tuple(pol.POLICIES)}"
            )
        resolve_scenarios(self.scenarios)
        resolve_telemetry(self.telemetry)
        if self.mode == "one_shot":
            if self.policy is not None:
                raise ValueError("policy= applies to mode='rolling' only")
            if self.scenarios is not None:
                raise ValueError(
                    "scenarios= applies to mode='rolling' only"
                )
            if resolve_telemetry(self.telemetry) is not None:
                raise ValueError(
                    "telemetry= applies to mode='rolling' only (the "
                    "ledger decomposes the weekly replay)"
                )
            if self.rolling != RollingConfig():
                raise ValueError(
                    "rolling= knobs were set on a mode='one_shot' request"
                )

    def rolling_kwargs(self) -> dict:
        """The ``replan_fleet_pools`` keyword spelling of ``rolling`` —
        the single source of truth the legacy shim and :func:`plan` share."""
        return dataclasses.asdict(self.rolling)


def plan(request: PlanRequest):
    """Canonical planner entry: execute one :class:`PlanRequest`.

    Returns :class:`repro.core.planner.FleetPoolsPlan` for one-shot
    requests and :class:`repro.core.replan.RollingPlanReport` for rolling
    ones — exactly what the legacy ``plan_fleet_pools`` spelling returns
    for the same configuration (golden-tested bit-identical)."""
    if not isinstance(request, PlanRequest):
        raise TypeError(
            f"plan() takes a PlanRequest, got {type(request).__name__}"
        )
    # Late import: planner -> replan -> policy all import at module scope;
    # api sits in front of them without joining the cycle.
    from repro.core import planner

    common = dict(
        horizon_weeks=request.horizon_weeks,
        od_rate=request.od_rate,
        term_weighting=request.term_weighting,
        cfg=request.forecast,
        spot=request.spot,
        migration=request.migration,
        convertible=request.convertible,
    )
    if request.mode == "one_shot":
        return planner._plan_fleet_pools_one_shot(
            request.pools, request.options, **common
        )
    from repro.core import replan

    return replan.replan_fleet_pools(
        request.pools, request.options, **common,
        policy=request.policy, scenarios=request.scenarios,
        telemetry=request.telemetry,
        **request.rolling_kwargs(),
    )
