"""Commitment-level optimization (paper §3.1-§3.2).

The paper minimizes, over the commitment level ``c``, the two-sided cost

    C(c) = A * sum_t max(f_t - c, 0)     (on-demand premium above the line)
        + B * sum_t max(c - f_t, 0)     (unused committed capacity below)

for an empirical hourly demand curve ``f``.  The paper uses Brent's method on
the 1-D objective.  ``C`` is a nonneg-weighted sum of convex hinge functions,
hence **convex piecewise-linear** in ``c`` — so we additionally ship an exact
solver: the minimizer is the A/(A+B) weighted quantile of ``f`` (the
newsvendor critical fractile; dC/dc = -A·#{f>c} + B·#{f<c} crosses zero
there).  Three solvers, all tested against each other:

  * ``optimal_commitment_quantile`` — exact, O(T log T), the beyond-paper fast
    path (also used by §5 free pools, which share the same objective).
  * ``optimal_commitment_golden``  — vectorized fixed-iteration golden-section
    (jit/vmap-friendly TPU adaptation of the paper's derivative-free search).
  * ``optimal_commitment_brent``   — scipy Brent, the paper-faithful baseline
    (host-side; used as the oracle in tests/benchmarks).

``commitment_cost`` is the common objective; ``cost_curve`` evaluates a whole
candidate grid (the hot loop the Pallas ``commitment_sweep`` kernel fuses).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

# Paper §3.2: On-Demand averages 2.1x the 3-year savings-plan rate (Table 2).
DEFAULT_A = 2.1  # cost factor for demand above the commitment (on-demand)
DEFAULT_B = 1.0  # cost factor for unused commitment below the line

_INVPHI = (np.sqrt(5.0) - 1.0) / 2.0  # 1/phi
_INVPHI2 = (3.0 - np.sqrt(5.0)) / 2.0  # 1/phi^2


def commitment_cost(
    f: jnp.ndarray, c: jnp.ndarray, a: float = DEFAULT_A, b: float = DEFAULT_B
) -> jnp.ndarray:
    """C(c): paper Eq. (1) discretized on the sample grid of ``f``.

    Shapes: ``f`` (..., T), ``c`` broadcastable to (...,). Returns (...,).
    Note the committed capacity itself costs ``1.0 * c * T`` regardless of
    use; the paper's objective counts only the *mismatch* areas, which is
    equivalent up to the constant-in-f term — we follow the paper exactly.
    """
    c = jnp.asarray(c)[..., None]
    over = jnp.maximum(f - c, 0.0)
    under = jnp.maximum(c - f, 0.0)
    return a * over.sum(-1) + b * under.sum(-1)


def total_spend(
    f: jnp.ndarray,
    c: jnp.ndarray,
    a: float = DEFAULT_A,
    committed_rate: float = 1.0,
) -> jnp.ndarray:
    """Actual dollars: committed capacity (used or not) + on-demand overflow.

    Commitment is paid at the committed rate whether used or not; demand
    above the line pays the on-demand rate ``a``.  NB this real-dollar
    objective has a *different* minimizer than Eq (1): d/dc = T - a*#{f>c}
    vanishes at the (1 - 1/a) quantile, vs A/(A+B) for C(c).  The paper
    optimizes and reports Eq (1) (Fig 8 caption compares C(.) values), so the
    planner/benchmarks use ``commitment_cost``; this helper exists for
    real-dollar accounting in the capacity simulator, where the committed
    base rate must be paid out.
    """
    c = jnp.asarray(c)[..., None]
    t = f.shape[-1]
    over = jnp.maximum(f - c, 0.0).sum(-1)
    return committed_rate * jnp.squeeze(c, -1) * t + a * over


def cost_curve(
    f: jnp.ndarray,
    cs: jnp.ndarray,
    a: float = DEFAULT_A,
    b: float = DEFAULT_B,
) -> jnp.ndarray:
    """Evaluate C(c) on a grid: f (..., T), cs (G,) -> (..., G).

    Pure-jnp reference implementation; the Pallas kernel
    ``repro.kernels.commitment_sweep`` computes the same thing in one HBM
    pass for large (pools x grid x time) problems.
    """
    over = jnp.maximum(f[..., None, :] - cs[:, None], 0.0).sum(-1)
    under = jnp.maximum(cs[:, None] - f[..., None, :], 0.0).sum(-1)
    return a * over + b * under


def optimal_commitment_quantile(
    f: jnp.ndarray, a: float = DEFAULT_A, b: float = DEFAULT_B
) -> jnp.ndarray:
    """Exact minimizer of C(c): the A/(A+B) quantile of ``f`` (newsvendor).

    Beyond-paper optimization: closed form replaces the iterative search.
    For the discrete-sum objective, C is piecewise linear with breakpoints at
    the data points; with k samples below c the slope is B*k - A*(T-k), which
    first becomes >= 0 at k* = ceil(T * A/(A+B)) — so the minimizer is the
    k*-th order statistic (NOT the interpolated quantile, which can sit off
    the vertex for small T).  Works under vmap/jit; f (..., T) -> (...,).
    """
    q = a / (a + b)
    t = f.shape[-1]
    idx = jnp.clip(jnp.ceil(t * q).astype(jnp.int32) - 1, 0, t - 1)
    return jnp.sort(f, axis=-1)[..., idx]


def optimal_commitment_golden(
    f: jnp.ndarray,
    a: float = DEFAULT_A,
    b: float = DEFAULT_B,
    *,
    iters: int = 60,
) -> jnp.ndarray:
    """Vectorized golden-section minimization of C(c) (TPU-friendly).

    Fixed iteration count (60 halves the bracket by 1/phi each step: bracket
    shrinks ~1e-13x) instead of data-dependent while loops, so it jits, vmaps
    and batches over pools. f (..., T) -> (...,).
    """
    lo = f.min(-1)
    hi = f.max(-1)

    def body(_, state):
        lo, hi = state
        x1 = lo + _INVPHI2 * (hi - lo)
        x2 = lo + _INVPHI * (hi - lo)
        f1 = commitment_cost(f, x1, a, b)
        f2 = commitment_cost(f, x2, a, b)
        smaller1 = f1 < f2
        new_lo = jnp.where(smaller1, lo, x1)
        new_hi = jnp.where(smaller1, x2, hi)
        return new_lo, new_hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return 0.5 * (lo + hi)


def optimal_commitment_brent(
    f: np.ndarray, a: float = DEFAULT_A, b: float = DEFAULT_B
) -> float:
    """Paper-faithful baseline: Brent's method [Brent 1973] via scipy.

    Host-side (numpy) — this is the reference the paper describes in §3.2 for
    minimizing the non-analytic empirical objective.
    """
    from scipy.optimize import minimize_scalar

    f = np.asarray(f)

    def obj(c):
        return float(
            a * np.maximum(f - c, 0.0).sum() + b * np.maximum(c - f, 0.0).sum()
        )

    res = minimize_scalar(
        obj, bounds=(float(f.min()), float(f.max())), method="bounded"
    )
    return float(res.x)


@functools.partial(jax.jit, static_argnames=("num_levels",))
def scenario_costs(
    f: jnp.ndarray,
    num_levels: int = 9,
    a: float = DEFAULT_A,
    b: float = DEFAULT_B,
):
    """Paper Fig 4: evaluate ``num_levels`` evenly spaced commitment levels
    between min and max demand; returns (levels, costs, argmin index)."""
    levels = jnp.linspace(f.min(), f.max(), num_levels)
    costs = cost_curve(f, levels, a, b)
    return levels, costs, jnp.argmin(costs, axis=-1)


def unused_commitment_fraction(f: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Fraction of committed capacity left unused (paper §4 reports ~4.3%)."""
    c = jnp.asarray(c)[..., None]
    unused = jnp.maximum(c - f, 0.0).sum(-1)
    total = jnp.squeeze(c, -1) * f.shape[-1]
    return unused / jnp.maximum(total, 1e-12)
