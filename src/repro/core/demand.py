"""Demand-trace modelling for cloud compute pools (paper §2).

The paper characterizes Snowflake VM demand by four drivers: user demand
(periodic + trend), software efficiency, hardware generation step-functions,
and utilization.  This module provides

  * a calibrated synthetic generator reproducing every statistic the paper
    publishes about its released dataset (§2.2, §3.3, §6), used everywhere a
    trace is needed (the real Zenodo artifact is loadable via
    ``repro.data.traces`` when present);
  * the statistics used in the paper's characterization (lag-k autocorrelation,
    weekly max/min ratio, diurnal ratio, week-over-week growth);
  * demand-driver composition: applying hardware/software efficiency
    step-functions to a base user-demand series (§2.3-§2.4).

All array code is jax.numpy so traces can be generated/transformed inside jit
(e.g. in the vmapped Monte-Carlo risk analysis of the planner).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

HOURS_PER_DAY = 24
HOURS_PER_WEEK = 24 * 7
DAYS_PER_YEAR = 365


@dataclasses.dataclass(frozen=True)
class DemandConfig:
    """Parameters of the synthetic demand model, calibrated to paper §2.2/§3.3.

    Defaults reproduce the published dataset statistics:
      * annual growth  ~58%  (paper: 3.9x over 3 years = 57.5%/yr)
      * diurnal peak/trough ~1.34x   (paper §2.2: daily max 34% above min)
      * weekly  peak/trough ~1.35x   (paper §2.2: weekly max 35% above min)
      * holiday (Dec 24 - Jan 1) drop ~8%  (paper §3.3.2)
      * lag-7 daily autocorrelation ~0.975 (paper §2.2)
    """

    base_level: float = 100.0
    annual_growth: float = 0.58
    diurnal_amplitude: float = 0.145  # -> ~1.34x daily max/min
    weekly_amplitude: float = 0.15    # weekend dip -> ~1.35x weekly max/min
    holiday_drop: float = 0.08
    noise_sigma: float = 0.01
    # Hour-of-year (0-based) at which the holiday window starts (Dec 24).
    holiday_start_day: int = 357
    holiday_len_days: int = 9


def _periodic_profile(t_hours: jnp.ndarray, cfg: DemandConfig) -> jnp.ndarray:
    """Multiplicative diurnal x weekly profile, mean ~1.0.

    Business-hours bump on weekdays, weekend dip — the paper's Fig 2(B) shape.
    """
    hour_of_day = jnp.mod(t_hours, HOURS_PER_DAY)
    day_of_week = jnp.mod(t_hours // HOURS_PER_DAY, 7)

    # Diurnal: cosine dipping at night (min ~3am, max ~3pm local).
    diurnal = 1.0 + cfg.diurnal_amplitude * jnp.cos(
        2.0 * jnp.pi * (hour_of_day - 15.0) / HOURS_PER_DAY
    )
    # Weekly: weekdays ~1.0, weekend dip.
    is_weekend = (day_of_week >= 5).astype(jnp.float32)
    weekly = 1.0 + cfg.weekly_amplitude * (0.4 - is_weekend)
    return diurnal * weekly


def _holiday_mask(t_hours: jnp.ndarray, cfg: DemandConfig) -> jnp.ndarray:
    day_of_year = jnp.mod(t_hours // HOURS_PER_DAY, DAYS_PER_YEAR)
    in_window = (day_of_year >= cfg.holiday_start_day) & (
        day_of_year < cfg.holiday_start_day + cfg.holiday_len_days
    )
    return in_window.astype(jnp.float32)


def synth_demand(
    num_hours: int,
    cfg: DemandConfig = DemandConfig(),
    *,
    key: jax.Array | None = None,
) -> jnp.ndarray:
    """Hourly VM-demand trace of length ``num_hours`` (float32, >= 0)."""
    t = jnp.arange(num_hours, dtype=jnp.float32)
    years = t / (DAYS_PER_YEAR * HOURS_PER_DAY)
    trend = cfg.base_level * jnp.power(1.0 + cfg.annual_growth, years)
    profile = _periodic_profile(t, cfg)
    holiday = 1.0 - cfg.holiday_drop * _holiday_mask(t, cfg)
    demand = trend * profile * holiday
    if key is not None:
        # AR(1) multiplicative noise: smooth, like aggregate workload jitter.
        eps = jax.random.normal(key, (num_hours,), dtype=jnp.float32)

        def ar_step(carry, e):
            nxt = 0.95 * carry + cfg.noise_sigma * e
            return nxt, nxt

        _, ar = jax.lax.scan(ar_step, jnp.float32(0.0), eps)
        demand = demand * (1.0 + ar)
    return jnp.maximum(demand, 0.0)


def apply_efficiency_events(
    demand: jnp.ndarray,
    event_hours: Sequence[int],
    event_gains: Sequence[float],
) -> jnp.ndarray:
    """Apply hardware/software efficiency step-functions (paper §2.3-§2.4).

    A gain g at hour h multiplies demand at t >= h by 1/(1+g): e.g. the
    Graviton2->3 transition (25% latency reduction) reduces the VM count
    needed for the same user demand.
    """
    t = jnp.arange(demand.shape[-1], dtype=jnp.float32)
    scale = jnp.ones_like(demand)
    for h, g in zip(event_hours, event_gains):
        step = (t >= h).astype(demand.dtype)
        scale = scale * (1.0 + step * (1.0 / (1.0 + g) - 1.0))
    return demand * scale


# ---------------------------------------------------------------------------
# Characterization statistics (paper §2.2, §3.3.1)
# ---------------------------------------------------------------------------

def autocorrelation(x: jnp.ndarray, lag: int) -> jnp.ndarray:
    """Pearson autocorrelation at ``lag`` (paper reports lag-7 daily = 0.975)."""
    a = x[..., :-lag] if lag else x
    b = x[..., lag:]
    a = a - a.mean(-1, keepdims=True)
    b = b - b.mean(-1, keepdims=True)
    denom = jnp.sqrt((a * a).sum(-1) * (b * b).sum(-1))
    return (a * b).sum(-1) / jnp.maximum(denom, 1e-12)


def hourly_to_daily(x: jnp.ndarray) -> jnp.ndarray:
    n = (x.shape[-1] // HOURS_PER_DAY) * HOURS_PER_DAY
    return x[..., :n].reshape(*x.shape[:-1], -1, HOURS_PER_DAY).mean(-1)


def weekly_peak_trough_ratio(x_hourly: jnp.ndarray) -> jnp.ndarray:
    """Mean over weeks of (weekly max / weekly min) of daily demand."""
    daily = hourly_to_daily(x_hourly)
    n = (daily.shape[-1] // 7) * 7
    weeks = daily[..., :n].reshape(*daily.shape[:-1], -1, 7)
    return (weeks.max(-1) / jnp.maximum(weeks.min(-1), 1e-12)).mean(-1)


def diurnal_peak_trough_ratio(x_hourly: jnp.ndarray) -> jnp.ndarray:
    """Mean over days of (daily max hour / daily min hour)."""
    n = (x_hourly.shape[-1] // HOURS_PER_DAY) * HOURS_PER_DAY
    days = x_hourly[..., :n].reshape(*x_hourly.shape[:-1], -1, HOURS_PER_DAY)
    return (days.max(-1) / jnp.maximum(days.min(-1), 1e-12)).mean(-1)


def week_over_week_growth(x_hourly: jnp.ndarray) -> jnp.ndarray:
    """Weekly mean demand growth rates (paper Fig 5: 37% of weeks negative)."""
    n = (x_hourly.shape[-1] // HOURS_PER_WEEK) * HOURS_PER_WEEK
    weekly = x_hourly[..., :n].reshape(*x_hourly.shape[:-1], -1, HOURS_PER_WEEK)
    weekly = weekly.mean(-1)
    return weekly[..., 1:] / jnp.maximum(weekly[..., :-1], 1e-12) - 1.0


def characterize(x_hourly: np.ndarray) -> dict:
    """Full §2.2 characterization of a trace — returns plain floats."""
    x = jnp.asarray(x_hourly)
    daily = hourly_to_daily(x)
    wow = week_over_week_growth(x)
    n_hours = x.shape[-1]
    years = n_hours / (HOURS_PER_DAY * DAYS_PER_YEAR)
    total_growth = float(daily[-7:].mean() / daily[:7].mean())
    return {
        "lag7_daily_autocorr": float(autocorrelation(daily, 7)),
        "weekly_ratio": float(weekly_peak_trough_ratio(x)),
        "diurnal_ratio": float(diurnal_peak_trough_ratio(x)),
        "neg_week_fraction": float((wow < 0).mean()),
        "total_growth": total_growth,
        "annual_growth": float(total_growth ** (1.0 / max(years, 1e-9)) - 1.0),
    }


# ---------------------------------------------------------------------------
# Multi-pool demand (paper §2, §6)
# ---------------------------------------------------------------------------

# (cloud, region, machine_family) — the key the released dataset uses.
PoolKey = tuple[str, str, str]


@dataclasses.dataclass(frozen=True)
class PoolSet:
    """An aligned multi-pool fleet: demand matrix (P, T) with labelled rows.

    The released dataset (§6) keys demand by (cloud, region, machine_type),
    and commitments are purchased per cloud/SKU — so the native planning
    shape is *per pool*, not one aggregate series.  Row p of ``demand`` is
    the hourly trace of pool ``keys[p]``; every row shares one hourly time
    axis (loaders in ``repro.data.traces`` align ragged sources before
    construction, so a PoolSet always stacks cleanly into the (P, T) batch
    the vmapped solvers and the Pallas 2-D sweep consume).
    """

    keys: tuple[PoolKey, ...]
    demand: np.ndarray                          # (P, T) float32, hourly
    configs: tuple[DemandConfig, ...] | None = None   # per-pool synth params

    def __post_init__(self):
        demand = np.asarray(self.demand, np.float32)
        if demand.ndim != 2:
            raise ValueError(f"demand must be (P, T), got {demand.shape}")
        if len(self.keys) != demand.shape[0]:
            raise ValueError(
                f"{len(self.keys)} keys for {demand.shape[0]} demand rows"
            )
        if self.configs is not None and len(self.configs) != len(self.keys):
            raise ValueError(
                f"{len(self.configs)} configs for {len(self.keys)} pools"
            )
        object.__setattr__(self, "keys", tuple(self.keys))
        object.__setattr__(self, "demand", demand)

    @property
    def num_pools(self) -> int:
        return self.demand.shape[0]

    @property
    def num_hours(self) -> int:
        return self.demand.shape[1]

    @property
    def clouds(self) -> tuple[str, ...]:
        """Per-pool cloud labels, aligned with ``demand`` rows."""
        return tuple(k[0] for k in self.keys)

    def aggregate(self) -> np.ndarray:
        """The fleet-total series — what single-pool planning collapses to."""
        return self.demand.sum(0)

    def pool(self, key: PoolKey) -> np.ndarray:
        return self.demand[self.keys.index(tuple(key))]

    def select(
        self,
        cloud: str | None = None,
        region: str | None = None,
        machine_type: str | None = None,
    ) -> "PoolSet":
        """Sub-fleet matching the given key components (None = wildcard)."""
        want = (cloud, region, machine_type)
        idx = [
            i for i, k in enumerate(self.keys)
            if all(w is None or w == part for w, part in zip(want, k))
        ]
        return PoolSet(
            keys=tuple(self.keys[i] for i in idx),
            demand=self.demand[idx],
            configs=(
                tuple(self.configs[i] for i in idx)
                if self.configs is not None else None
            ),
        )

    @classmethod
    def from_dict(
        cls,
        pools: dict[PoolKey, np.ndarray],
        configs: dict[PoolKey, DemandConfig] | None = None,
    ) -> "PoolSet":
        """Stack a {key: trace} mapping into a PoolSet (keys sorted).

        All traces must already share one length — ragged sources go through
        ``repro.data.traces.load_dataset_csv``, whose union-timestamp
        alignment produces equal-length series.
        """
        if not pools:
            raise ValueError(
                "cannot build a PoolSet from zero pools (empty dataset?)"
            )
        keys = tuple(sorted(pools))
        lengths = {k: len(pools[k]) for k in keys}
        if len(set(lengths.values())) > 1:
            raise ValueError(
                f"ragged pools cannot stack: lengths {lengths}; align them "
                "first (data.traces.load_dataset_csv aligns on the union "
                "timestamp grid)"
            )
        return cls(
            keys=keys,
            demand=np.stack([np.asarray(pools[k], np.float32) for k in keys]),
            configs=(
                tuple(configs[k] for k in keys) if configs is not None
                else None
            ),
        )
