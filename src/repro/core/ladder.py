"""Commitment laddering (paper §3.3.4).

Commitments are bought with staggered start dates and fixed terms (like bond
ladders): the *cumulative* committed level at time t is the sum of all active
tranches.  Increments can be purchased any period; reductions happen only by
letting tranches expire.  This module provides:

  * ``Ladder`` — an immutable schedule of tranches (start, term, amount);
  * ``active_level`` — committed level over time;
  * ``plan_purchases`` — translate a target level series into per-period
    incremental purchases honoring the "can only add" constraint (the
    modification of Algorithm 1 the paper describes for Fig 9);
  * ``ladder_vs_flat`` — the Fig 9 Scenario A (flat) vs Scenario B (perfect
    laddering) comparison.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import commitment as cm
from repro.core.demand import HOURS_PER_WEEK

# Increments below this are numerical dust, not purchases: both the host
# ladder planners and the scan-compiled rolling replay apply the same
# threshold so their tranche books agree.
PURCHASE_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class Ladder:
    """Tranches: arrays of (start_hour, term_hours, amount[, option]).

    ``option`` tags each tranche with the index of the purchasing option it
    was bought under (§3 portfolio; -1 = untagged/single-option ladders) so
    terms are per-tranche properties of the SKU, not a global constant."""

    start: np.ndarray   # (K,) int
    term: np.ndarray    # (K,) int
    amount: np.ndarray  # (K,) float
    option: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros((0,), int) - 1
    )                   # (K,) int, -1 = untagged

    def __post_init__(self):
        if self.option.shape != self.start.shape:
            if self.option.size:  # caller passed tags but mis-sized them
                raise ValueError(
                    f"option tags shape {self.option.shape} != tranche "
                    f"shape {self.start.shape}"
                )
            object.__setattr__(
                self, "option",
                np.full(self.start.shape, -1, int),
            )

    def active_level(self, num_hours: int, option: int | None = None):
        """Cumulative committed level for hours [0, num_hours); restricted
        to one option's tranches when ``option`` is given."""
        t = np.arange(num_hours)[:, None]
        active = (t >= self.start[None, :]) & (
            t < (self.start + self.term)[None, :]
        )
        if option is not None:
            active = active & (self.option[None, :] == option)
        return (active * self.amount[None, :]).sum(-1)

    def active_width(self, hour: int, option: int | None = None) -> float:
        """Committed width active at one hour — O(tranches), no activity
        matrix.  A tranche (start, term) is live for hours [start,
        start+term): bought in week w with term k weeks it contributes
        through week w+k-1 and has rolled off by week w+k."""
        live = (hour >= self.start) & (hour < self.start + self.term)
        if option is not None:
            live = live & (self.option == option)
        return float((live * self.amount).sum())

    def option_widths(self, hour: int, num_options: int) -> np.ndarray:
        """(K,) active width per purchasing option at ``hour`` (untagged
        option=-1 tranches are excluded)."""
        live = (
            (hour >= self.start) & (hour < self.start + self.term)
            & (self.option >= 0)
        )
        out = np.zeros(num_options)
        np.add.at(out, self.option[live], self.amount[live])
        return out

    def extended(
        self, start: int, term: int, amount: float, option: int = -1
    ) -> "Ladder":
        return Ladder(
            start=np.append(self.start, start),
            term=np.append(self.term, term),
            amount=np.append(self.amount, amount),
            option=np.append(self.option, option),
        )


def empty_ladder() -> Ladder:
    z = np.zeros((0,))
    return Ladder(start=z.astype(int), term=z.astype(int), amount=z)


def plan_purchases(
    target_levels: np.ndarray,
    *,
    period_hours: int = HOURS_PER_WEEK,
    term_hours: int = 52 * HOURS_PER_WEEK,
    existing: Ladder | None = None,
) -> Ladder:
    """Buy, at the start of each period, the increment needed to lift the
    active ladder level up to that period's target (never selling).  Where
    the target is *below* the currently active level no purchase is made and
    the surplus persists until tranches expire — exactly the §3.3.4
    mechanism ("simply stop purchasing new commitments").
    """
    ladder = existing or empty_ladder()
    num_periods = len(target_levels)
    for p in range(num_periods):
        t0 = p * period_hours
        gap = float(target_levels[p]) - ladder.active_width(t0)
        if gap > PURCHASE_EPS:
            ladder = ladder.extended(t0, term_hours, gap)
    return ladder


def plan_portfolio_purchases(
    target_levels: np.ndarray,
    term_hours: np.ndarray,
    *,
    period_hours: int = HOURS_PER_WEEK,
    existing: Ladder | None = None,
) -> Ladder:
    """Portfolio laddering: per period, per option, buy the increment that
    lifts that option's active tranches up to its target band width.

    target_levels (W, K): per-period target *width* of each option's band
    (e.g. the widths from ``planner.plan_portfolio`` re-run each week).
    term_hours (K,): each option's own commitment term — a 1y tranche rolls
    off 3x sooner than a 3y tranche, which is exactly the flexibility the
    portfolio pays for."""
    ladder = existing or empty_ladder()
    target_levels = np.asarray(target_levels)
    num_periods, num_options = target_levels.shape

    for p in range(num_periods):
        t0 = p * period_hours
        for k in range(num_options):
            # Single-hour active sample, O(tranches) — an increment tops up
            # exactly the live width, so an active tranche is never
            # double-counted into a new purchase.
            gap = float(target_levels[p, k]) - ladder.active_width(t0, k)
            if gap > PURCHASE_EPS:
                ladder = ladder.extended(t0, int(term_hours[k]), gap, k)
    return ladder


@dataclasses.dataclass(frozen=True)
class PoolLadderBook:
    """Per-pool tranche stacks: one :class:`Ladder` per (cloud, region,
    machine-family) pool, aligned with ``keys``.

    Commitments attach to the pool they were purchased for — a tranche in
    one cloud/region cannot serve another pool's demand — so the fleet's
    committed state is a *book* of independent ladders, not one schedule."""

    keys: tuple
    ladders: tuple[Ladder, ...]

    def __post_init__(self):
        if len(self.keys) != len(self.ladders):
            raise ValueError(
                f"{len(self.keys)} keys for {len(self.ladders)} ladders"
            )
        object.__setattr__(self, "keys", tuple(self.keys))
        object.__setattr__(self, "ladders", tuple(self.ladders))

    def ladder(self, key) -> Ladder:
        return self.ladders[self.keys.index(tuple(key))]

    def active_level(
        self, num_hours: int, option: int | None = None
    ) -> np.ndarray:
        """(P, T) committed level per pool (optionally one option's band)."""
        return np.stack([
            lad.active_level(num_hours, option=option)
            for lad in self.ladders
        ])

    def fleet_level(self, num_hours: int) -> np.ndarray:
        """(T,) fleet-total committed level — the only view the aggregate
        planner ever saw; kept for comparing against per-pool plans."""
        return self.active_level(num_hours).sum(0)

    def option_widths(self, hour: int, num_options: int) -> np.ndarray:
        """(P, K) active width per pool per option at ``hour`` — the
        committed-stack snapshot the rolling replay carries through its
        scan; the two views must agree at every decision hour."""
        return np.stack([
            lad.option_widths(hour, num_options) for lad in self.ladders
        ])


def plan_pool_portfolio_purchases(
    pool_targets: np.ndarray,
    term_hours: np.ndarray,
    keys,
    *,
    period_hours: int = HOURS_PER_WEEK,
    existing: PoolLadderBook | None = None,
) -> PoolLadderBook:
    """Portfolio laddering across a fleet of pools.

    pool_targets (P, W, K): per pool, per period, the target band width of
    each purchasing option (e.g. the (P, K) widths from
    ``planner.plan_fleet_pools``, re-planned each week).  Each pool's
    purchases thread through ``plan_portfolio_purchases`` independently —
    per-pool increments, per-option terms."""
    pool_targets = np.asarray(pool_targets)
    keys = tuple(tuple(k) for k in keys)
    if pool_targets.shape[0] != len(keys):
        raise ValueError(
            f"{len(keys)} keys for {pool_targets.shape[0]} target rows"
        )
    if existing is not None and existing.keys != keys:
        # Positional reuse of another fleet's book would silently attach
        # tranches to the wrong pool (e.g. a new pool appearing mid-replan).
        raise ValueError(
            f"existing book keys {existing.keys} != planned keys {keys}"
        )
    return PoolLadderBook(
        keys=keys,
        ladders=tuple(
            plan_portfolio_purchases(
                pool_targets[p], term_hours, period_hours=period_hours,
                existing=existing.ladders[p] if existing else None,
            )
            for p in range(len(keys))
        ),
    )


def convertible_ladder_book(
    cloud_targets: np.ndarray,
    term_hours: np.ndarray,
    clouds,
    *,
    period_hours: int = HOURS_PER_WEEK,
    existing: PoolLadderBook | None = None,
) -> PoolLadderBook:
    """Convertible tranches as a *cloud-level* ladder book.

    cloud_targets (C, W, Kc): per cloud, per period, the target width of
    each convertible SKU's band.  Convertible commitments attach to a
    cloud, not a pool — they re-pin across that cloud's machine families
    at every re-plan boundary — so the book's keys are the pseudo-pools
    ``(cloud, "*", "convertible")``: the region/family slots are
    wildcards by construction.  Tranche mechanics (increment-only buys,
    per-SKU terms, roll-off) are identical to the pool-level book, which
    is what lets the reconciliation test compare the book's live widths
    against the replay scan's carried cloud-level stack week by week."""
    keys = tuple((c, "*", "convertible") for c in clouds)
    return plan_pool_portfolio_purchases(
        cloud_targets, term_hours, keys,
        period_hours=period_hours, existing=existing,
    )


def weekly_spot_ladder(
    peaks: np.ndarray,
    *,
    start_week: int = 0,
    period_hours: int = HOURS_PER_WEEK,
) -> Ladder:
    """Spot capacity as a tranche schedule: one 1-period tranche per week.

    Spot holds no term — it is re-decided every period and never carried —
    so in ladder vocabulary it is the degenerate ladder whose every tranche
    expires the period it was bought (the *fast* half of the rolling
    replanner's fast/slow capacity split; committed tranches are the slow
    half).  ``peaks`` (W,) is the peak spot chip usage per period; zero
    weeks produce no tranche.  Kept as an audit view: the book's active
    width at any hour of week w is exactly that week's spot exposure."""
    peaks = np.asarray(peaks, np.float64)
    weeks = np.flatnonzero(peaks > PURCHASE_EPS)
    return Ladder(
        start=(start_week + weeks) * period_hours,
        term=np.full(weeks.shape, period_hours, int),
        amount=peaks[weeks],
    )


def spot_ladder_book(
    weekly_peaks: np.ndarray,
    keys,
    *,
    start_week: int = 0,
    period_hours: int = HOURS_PER_WEEK,
) -> PoolLadderBook:
    """Per-pool spot audit book from (S weeks, P pools) peak spot usage —
    the spot counterpart of the committed :class:`PoolLadderBook` the
    rolling replay returns."""
    weekly_peaks = np.asarray(weekly_peaks)
    keys = tuple(tuple(k) for k in keys)
    if weekly_peaks.shape[1] != len(keys):
        raise ValueError(
            f"{len(keys)} keys for {weekly_peaks.shape[1]} peak columns"
        )
    return PoolLadderBook(
        keys=keys,
        ladders=tuple(
            weekly_spot_ladder(
                weekly_peaks[:, p], start_week=start_week,
                period_hours=period_hours,
            )
            for p in range(len(keys))
        ),
    )


def ladder_vs_flat(
    demand: np.ndarray,
    weekly_targets: np.ndarray,
    *,
    a: float = cm.DEFAULT_A,
) -> dict:
    """Paper Fig 9: Scenario A applies one flat optimal level over the whole
    window; Scenario B assumes perfect laddering (weekly level can step down
    to each week's target thanks to expiring tranches).  Costs are evaluated
    with the paper's Eq (1) metric C(c) — the same objective the optimizer
    minimizes (Fig 8's caption compares C(c_w1, X) vs C(c_w2, X)), under
    which per-week optima dominate any flat level by pointwise optimality.
    Paper reports ~1.1% savings for its year-end window."""
    num_weeks = len(weekly_targets)
    window = demand[: num_weeks * HOURS_PER_WEEK]
    flat_level = float(cm.optimal_commitment_quantile(jnp.asarray(window), a))
    flat_spend = float(cm.commitment_cost(jnp.asarray(window), flat_level, a))

    laddered_spend = 0.0
    for w in range(num_weeks):
        seg = jnp.asarray(window[w * HOURS_PER_WEEK : (w + 1) * HOURS_PER_WEEK])
        laddered_spend += float(
            cm.commitment_cost(seg, float(weekly_targets[w]), a)
        )

    return {
        "flat_level": flat_level,
        "flat_spend": flat_spend,
        "laddered_spend": laddered_spend,
        "savings_frac": 1.0 - laddered_spend / flat_spend,
    }


def expiration_profile(ladder: Ladder, num_hours: int) -> np.ndarray:
    """Capacity expiring per hour — the 'rolling downward expiration' the
    paper describes; used by the planner to know how much level decays on its
    own before new purchases are needed."""
    out = np.zeros(num_hours)
    ends = ladder.start + ladder.term
    for e, amt in zip(ends, ladder.amount):
        if 0 <= e < num_hours:
            out[e] += amt
    return out
