"""Free-pool sizing: predictive pre-provisioning (paper §5).

CSP VM provisioning latency (minutes at p90/p99, paper Fig 10) is far above
the sub-second SLO for warehouse creation, so a pool of pre-provisioned VMs
absorbs demand spikes.  The paper minimizes

    c(t) = p_o * max(0, y_hat_t - d_t) + p_u * max(0, d_t - y_hat_t)

over the pool size y_hat_t per time window.  This is the same asymmetric
newsvendor objective as §3's commitment problem, so the optimal *static*
pool is the p_u/(p_o+p_u) quantile of demand, and the optimal *predicted*
pool is that quantile of the forecast-residual distribution stacked on the
point forecast.  We implement both plus the provisioning-latency-aware
variant: the pool must cover demand over the replenishment lead time.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import forecast as fc


@dataclasses.dataclass(frozen=True)
class FreePoolConfig:
    p_over: float = 1.0    # cost / over-provisioned server-minute
    p_under: float = 10.0  # cost / under-provisioned server (SLO miss)
    lead_time: int = 3     # provisioning latency in windows (paper Fig 10)


def pool_cost(
    pool: jnp.ndarray, demand: jnp.ndarray, cfg: FreePoolConfig = FreePoolConfig()
) -> jnp.ndarray:
    """The paper's c(t), summed over time. pool/demand: (..., T)."""
    over = jnp.maximum(pool - demand, 0.0)
    under = jnp.maximum(demand - pool, 0.0)
    return (cfg.p_over * over + cfg.p_under * under).sum(-1)


def critical_fractile(cfg: FreePoolConfig) -> float:
    return cfg.p_under / (cfg.p_under + cfg.p_over)


def optimal_static_pool(
    demand: jnp.ndarray, cfg: FreePoolConfig = FreePoolConfig()
) -> jnp.ndarray:
    """Best single pool size: the critical-fractile quantile of demand."""
    return jnp.quantile(demand, critical_fractile(cfg), axis=-1)


@functools.partial(
    jax.jit, static_argnames=("cfg", "window", "demand_future_len")
)
def predicted_pool(
    demand_history: jnp.ndarray,
    demand_future_len: int,
    cfg: FreePoolConfig = FreePoolConfig(),
    window: int = 24,
) -> jnp.ndarray:
    """Forecast-driven pool sizing (paper §5.1).

    Fits the structural forecaster on history, takes the point forecast for
    the future, and adds a safety margin equal to the critical-fractile
    quantile of in-sample residuals over a trailing ``window`` — i.e. the
    newsvendor answer under the empirical residual distribution.  The lead
    time shifts the target: the pool set now must cover demand ``lead_time``
    windows ahead (provisioning latency), so we take the max of the forecast
    over the lead window.

    The pool model deliberately has NO trend changepoints: the final
    changepoint segment's slope is fit on a sliver of recent history (often
    pure weekend/noise), and ``fc.predict`` extrapolates that slope — over
    even a 2-day sizing horizon this injected double-digit-% phantom demand
    drops, sinking the pool below actual demand (far more SLO misses than a
    static p50 pool).  A single global trend plus daily/weekly seasonality
    is the right capacity model for short free-pool horizons; the in-sample
    residual quantile then absorbs what the simpler trend misses.
    """
    model_cfg = fc.ForecastConfig(yearly_order=0, num_changepoints=0)
    t_hist = demand_history.shape[-1]
    beta = fc._fit(demand_history, model_cfg, float(t_hist - 1))
    model = fc.ForecastModel(beta=beta, t_max=float(t_hist - 1), cfg=model_cfg)

    fitted = fc.predict(model, jnp.arange(t_hist))
    resid = demand_history - fitted
    q = jnp.quantile(resid, critical_fractile(cfg))

    future_t = t_hist + jnp.arange(demand_future_len + cfg.lead_time)
    yhat = fc.predict(model, future_t)
    # Cover the worst point forecast over the lead window ending at each t.
    if cfg.lead_time > 0:
        stacked = jnp.stack(
            [yhat[i : i + demand_future_len] for i in range(cfg.lead_time + 1)]
        )
        yhat_eff = stacked.max(0)
    else:
        yhat_eff = yhat[:demand_future_len]
    return jnp.maximum(yhat_eff + q, 0.0)


def compare_static_vs_predicted(
    history: jnp.ndarray,
    future: jnp.ndarray,
    cfg: FreePoolConfig = FreePoolConfig(),
) -> dict:
    """Paper Fig 12: cost of the best static pool vs the predicted pool on a
    held-out window."""
    static = optimal_static_pool(history, cfg)
    static_series = jnp.full_like(future, static)
    pred = predicted_pool(history, future.shape[-1], cfg)
    return {
        "static_size": float(static),
        "static_cost": float(pool_cost(static_series, future, cfg)),
        "predicted_cost": float(pool_cost(pred, future, cfg)),
        "predicted_mean_size": float(pred.mean()),
        "under_minutes_static": float(
            jnp.maximum(future - static_series, 0.0).sum()
        ),
        "under_minutes_predicted": float(jnp.maximum(future - pred, 0.0).sum()),
    }


def provisioning_latency_profile(hour_of_day: jnp.ndarray) -> jnp.ndarray:
    """Synthetic p99 provisioning-latency curve (minutes) by hour-of-day,
    shaped like paper Fig 10: elevated at top-of-hour/business peaks."""
    base = 2.0 + 1.5 * jnp.sin(2 * jnp.pi * (hour_of_day - 14) / 24.0) ** 2
    return base
