"""Algorithm 1: Optimal Commitment For Demand Forecast (paper §3.3.3).

Step 1  Fit the forecaster on the hourly training history; forecast 1 year.
Step 2  For each weekly horizon w = 1..52, take the forecast prefix X̂_w.
Step 3  Compute the minimal-cost commitment level c_w over each prefix.
Step 4  c* = min_w c_w  — commitments can be *increased* later but never
        reduced, so the safe level to buy now is the minimum over horizons
        (buying more than some future optimum strands capacity).

All 52 horizon optimizations run as one vectorized pass: with the exact
quantile solver each c_w is a weighted quantile of a prefix, and with the
golden-section solver the 52 prefixes are masked views of one array, batched
under vmap.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Literal

import jax
import jax.numpy as jnp

from repro.core import commitment as cm
from repro.core import forecast as fc
from repro.core.demand import HOURS_PER_WEEK


@dataclasses.dataclass
class PlanResult:
    commitment: float                 # c* to purchase now
    per_horizon_levels: jnp.ndarray   # (W,) c_w for each horizon
    argmin_horizon: int               # which horizon set the binding level
    forecast: jnp.ndarray             # (W*168,) hourly forecast used


def _masked_prefix_optimum(
    yhat: jnp.ndarray, w_hours: jnp.ndarray, a: float, b: float
) -> jnp.ndarray:
    """Optimal commitment over the prefix yhat[:w_hours] without dynamic
    shapes: elements past the prefix are masked to +inf for the 'over' hinge
    and... simpler: replace them with the prefix's own values via clamped
    gather is costly — instead use the weighted-quantile closed form with a
    validity mask (exact for the two-sided objective)."""
    t = jnp.arange(yhat.shape[0])
    valid = (t < w_hours).astype(yhat.dtype)
    # Weighted quantile at q = a/(a+b) over valid entries:
    q = a / (a + b)
    # Sort demand ascending; accumulate validity mass; pick first index where
    # cumulative fraction >= q.
    order = jnp.argsort(yhat)
    sorted_y = yhat[order]
    sorted_valid = valid[order]
    cum = jnp.cumsum(sorted_valid)
    total = jnp.maximum(cum[-1], 1.0)
    frac = cum / total
    idx = jnp.argmax(frac >= q)  # first crossing
    return sorted_y[idx]


def plan_commitment(
    history: jnp.ndarray,
    *,
    num_horizons: int = 52,
    a: float = cm.DEFAULT_A,
    b: float = cm.DEFAULT_B,
    cfg: fc.ForecastConfig = fc.ForecastConfig(),
    solver: Literal["quantile", "golden"] = "quantile",
) -> PlanResult:
    """Run Algorithm 1 on an hourly demand history."""
    model = fc.fit(history, cfg)
    t0 = history.shape[-1]
    horizon_hours = num_horizons * HOURS_PER_WEEK
    yhat = fc.forecast_horizon(model, t0, horizon_hours)  # Step 1

    w_hours = (jnp.arange(1, num_horizons + 1)) * HOURS_PER_WEEK  # Step 2

    if solver == "quantile":
        levels = jax.vmap(
            lambda w: _masked_prefix_optimum(yhat, w, a, b)
        )(w_hours)  # Step 3
    else:
        def golden_prefix(w):
            t = jnp.arange(yhat.shape[0])
            # Mask out-of-horizon hours by pinning them to the prefix median:
            # they then contribute a c-independent-gradient-free... not exact.
            # For the golden path we instead clamp to the valid min so masked
            # entries never bind the 'over' hinge and contribute a constant
            # slope to 'under'; exactness is restored by subtracting that
            # slope — in practice we simply evaluate cost only on valid hours
            # via where().
            fvals = jnp.where(t < w, yhat, jnp.nan)
            # golden on nan-masked cost:
            lo, hi = jnp.nanmin(fvals), jnp.nanmax(fvals)

            def cost(c):
                over = jnp.where(t < w, jnp.maximum(yhat - c, 0.0), 0.0)
                under = jnp.where(t < w, jnp.maximum(c - yhat, 0.0), 0.0)
                return a * over.sum() + b * under.sum()

            def body(_, st):
                lo, hi = st
                x1 = lo + (hi - lo) * 0.381966
                x2 = lo + (hi - lo) * 0.618034
                sm = cost(x1) < cost(x2)
                return jnp.where(sm, lo, x1), jnp.where(sm, x2, hi)

            lo, hi = jax.lax.fori_loop(0, 60, body, (lo, hi))
            return 0.5 * (lo + hi)

        levels = jax.vmap(golden_prefix)(w_hours)

    c_star = levels.min()  # Step 4
    return PlanResult(
        commitment=float(c_star),
        per_horizon_levels=levels,
        argmin_horizon=int(jnp.argmin(levels)),
        forecast=yhat,
    )


def compare_horizons(
    yhat: jnp.ndarray,
    horizons_weeks: tuple[int, ...] = (1, 2),
    a: float = cm.DEFAULT_A,
    b: float = cm.DEFAULT_B,
    eval_weeks: int | None = None,
) -> dict:
    """Paper Fig 8: commitment from a w1-week horizon vs w2-week horizon,
    both *applied over* the longer evaluation window.  Costs use the paper's
    Eq (1) metric: the figure's caption compares C(c_w1, X-hat_w2) vs
    C(c_w2, X-hat_w2).  Demonstrates why upcoming demand drops must be
    considered: the longer-horizon level is lower and cheaper.
    """
    eval_weeks = eval_weeks or max(horizons_weeks)
    eval_slice = yhat[: eval_weeks * HOURS_PER_WEEK]
    out = {}
    for w in horizons_weeks:
        prefix = yhat[: w * HOURS_PER_WEEK]
        c_w = float(cm.optimal_commitment_quantile(prefix, a, b))
        spend = float(cm.commitment_cost(eval_slice, c_w, a, b))
        out[w] = {"level": c_w, "total_spend": spend}
    return out
