"""Algorithm 1: Optimal Commitment For Demand Forecast (paper §3.3.3).

Step 1  Fit the forecaster on the hourly training history; forecast 1 year.
Step 2  For each weekly horizon w = 1..52, take the forecast prefix X̂_w.
Step 3  Compute the minimal-cost commitment level c_w over each prefix.
Step 4  c* = min_w c_w  — commitments can be *increased* later but never
        reduced, so the safe level to buy now is the minimum over horizons
        (buying more than some future optimum strands capacity).

All 52 horizon optimizations run as one vectorized pass: with the exact
quantile solver each c_w is a weighted quantile of a prefix, and with the
golden-section solver the 52 prefixes are masked views of one array, batched
under vmap.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Literal

import jax
import jax.numpy as jnp

from repro.core import commitment as cm
from repro.core import forecast as fc
from repro.core import portfolio as pf
from repro.core.demand import HOURS_PER_WEEK


@dataclasses.dataclass
class PlanResult:
    commitment: float                 # c* to purchase now
    per_horizon_levels: jnp.ndarray   # (W,) c_w for each horizon
    argmin_horizon: int               # which horizon set the binding level
    forecast: jnp.ndarray             # (W*168,) hourly forecast used


def plan_commitment(
    history: jnp.ndarray,
    *,
    num_horizons: int = 52,
    a: float = cm.DEFAULT_A,
    b: float = cm.DEFAULT_B,
    cfg: fc.ForecastConfig = fc.ForecastConfig(),
    solver: Literal["quantile", "golden"] = "quantile",
) -> PlanResult:
    """Run Algorithm 1 on an hourly demand history."""
    model = fc.fit(history, cfg)
    t0 = history.shape[-1]
    horizon_hours = num_horizons * HOURS_PER_WEEK
    yhat = fc.forecast_horizon(model, t0, horizon_hours)  # Step 1

    w_hours = (jnp.arange(1, num_horizons + 1)) * HOURS_PER_WEEK  # Step 2

    if solver == "quantile":
        # Exact weighted quantile at q = a/(a+b) over each masked prefix —
        # the K=1 instance of the portfolio prefix solver (one shared sort).
        q = jnp.asarray([a / (a + b)], yhat.dtype)
        levels = _prefix_weighted_quantiles(yhat, w_hours, q)[:, 0]  # Step 3
    else:
        def golden_prefix(w):
            t = jnp.arange(yhat.shape[0])
            # Mask out-of-horizon hours by pinning them to the prefix median:
            # they then contribute a c-independent-gradient-free... not exact.
            # For the golden path we instead clamp to the valid min so masked
            # entries never bind the 'over' hinge and contribute a constant
            # slope to 'under'; exactness is restored by subtracting that
            # slope — in practice we simply evaluate cost only on valid hours
            # via where().
            fvals = jnp.where(t < w, yhat, jnp.nan)
            # golden on nan-masked cost:
            lo, hi = jnp.nanmin(fvals), jnp.nanmax(fvals)

            def cost(c):
                over = jnp.where(t < w, jnp.maximum(yhat - c, 0.0), 0.0)
                under = jnp.where(t < w, jnp.maximum(c - yhat, 0.0), 0.0)
                return a * over.sum() + b * under.sum()

            def body(_, st):
                lo, hi = st
                x1 = lo + (hi - lo) * 0.381966
                x2 = lo + (hi - lo) * 0.618034
                sm = cost(x1) < cost(x2)
                return jnp.where(sm, lo, x1), jnp.where(sm, x2, hi)

            lo, hi = jax.lax.fori_loop(0, 60, body, (lo, hi))
            return 0.5 * (lo + hi)

        levels = jax.vmap(golden_prefix)(w_hours)

    c_star = levels.min()  # Step 4
    return PlanResult(
        commitment=float(c_star),
        per_horizon_levels=levels,
        argmin_horizon=int(jnp.argmin(levels)),
        forecast=yhat,
    )


@dataclasses.dataclass
class PortfolioPlanResult:
    """Algorithm 1 generalized to a commitment portfolio (one run per
    option term).  Arrays are aligned with ``options``."""

    options: list[pf.PurchaseOption]
    widths: jnp.ndarray               # (K,) band width to purchase now
    levels: jnp.ndarray               # (K,) stack tops (envelope-monotone)
    per_horizon_levels: jnp.ndarray   # (W, K) per-horizon prefix thresholds
    fractiles: jnp.ndarray            # (K,) per-option critical fractiles
    forecast: jnp.ndarray             # (W*168,) hourly forecast used


def _prefix_weighted_quantiles(
    yhat: jnp.ndarray, w_hours: jnp.ndarray, qs: jnp.ndarray
) -> jnp.ndarray:
    """Thresholds (W, K): for each horizon prefix yhat[:w] the weighted
    quantile at each fractile q — the vectorized heart of Step 3, one sort
    for all horizons x options (same masked-prefix trick as the single-level
    path, broadcast over the portfolio's critical fractiles)."""
    order = jnp.argsort(yhat)
    sorted_y = yhat[order]
    t = jnp.arange(yhat.shape[0])
    sorted_t = t[order]

    def one_horizon(w):
        valid = (sorted_t < w).astype(yhat.dtype)
        cum = jnp.cumsum(valid)
        frac = cum / jnp.maximum(cum[-1], 1.0)
        idx = jnp.argmax(frac[None, :] >= qs[:, None], axis=-1)  # (K,)
        return sorted_y[idx]

    return jax.vmap(one_horizon)(w_hours)


def plan_portfolio(
    history: jnp.ndarray,
    options: list[pf.PurchaseOption] | None = None,
    *,
    num_horizons: int = 52,
    od_rate: float = 2.1,
    term_weighting: float = 0.0,
    cfg: fc.ForecastConfig = fc.ForecastConfig(),
) -> PortfolioPlanResult:
    """Algorithm 1 with one horizon sweep per purchasing option.

    Steps 1-2 are shared (one forecast, 52 weekly prefixes).  Step 3
    computes each option's optimal stack threshold on every prefix — a
    weighted quantile at the option's critical fractile (portfolio lower
    envelope).  Step 4 takes the min per option over the horizons *within
    that option's term*: a commitment can never be reduced while its term
    runs, so upcoming demand drops inside the term cap today's safe
    purchase; drops after expiry are irrelevant (the tranche simply is not
    renewed) — short-term options therefore clear fewer horizons and may
    commit more aggressively than long-term ones.  Finally the stack is
    re-monotonized (running max in envelope-depth order) since per-option
    minima over different horizon sets can cross."""
    options = options if options is not None else pf.options_from_pricing()
    alphas, betas = pf.option_lines(options, term_weighting=term_weighting)
    qs = pf.handover_fractiles(alphas, betas, od_rate=od_rate)

    model = fc.fit(history, cfg)
    t0 = history.shape[-1]
    horizon_hours = num_horizons * HOURS_PER_WEEK
    yhat = fc.forecast_horizon(model, t0, horizon_hours)          # Step 1
    w_hours = jnp.arange(1, num_horizons + 1) * HOURS_PER_WEEK    # Step 2

    per_horizon = _prefix_weighted_quantiles(yhat, w_hours, qs)   # Step 3

    term_weeks = jnp.asarray([o.term_weeks for o in options])
    weeks = jnp.arange(1, num_horizons + 1)[:, None]              # (W, 1)
    in_term = weeks <= jnp.maximum(term_weeks[None, :], 1)        # Step 4
    big = jnp.float32(jnp.inf)
    mins = jnp.where(in_term, per_horizon, big).min(0)            # (K,)
    on_env = qs > 0

    # Monotone stack in envelope-depth order (ascending fractile).
    depth = jnp.argsort(jnp.where(on_env, qs, jnp.inf))
    inv = jnp.argsort(depth)
    mins_d = jnp.where(on_env, mins, 0.0)[depth]
    tops_d = jax.lax.associative_scan(jnp.maximum, mins_d)
    prev_d = jnp.concatenate([jnp.zeros((1,), tops_d.dtype), tops_d[:-1]])
    widths_d = jnp.where(on_env[depth], tops_d - prev_d, 0.0)
    return PortfolioPlanResult(
        options=options,
        widths=widths_d[inv],
        levels=tops_d[inv],
        per_horizon_levels=per_horizon,
        fractiles=qs,
        forecast=yhat,
    )


def compare_horizons(
    yhat: jnp.ndarray,
    horizons_weeks: tuple[int, ...] = (1, 2),
    a: float = cm.DEFAULT_A,
    b: float = cm.DEFAULT_B,
    eval_weeks: int | None = None,
) -> dict:
    """Paper Fig 8: commitment from a w1-week horizon vs w2-week horizon,
    both *applied over* the longer evaluation window.  Costs use the paper's
    Eq (1) metric: the figure's caption compares C(c_w1, X-hat_w2) vs
    C(c_w2, X-hat_w2).  Demonstrates why upcoming demand drops must be
    considered: the longer-horizon level is lower and cheaper.
    """
    eval_weeks = eval_weeks or max(horizons_weeks)
    eval_slice = yhat[: eval_weeks * HOURS_PER_WEEK]
    out = {}
    for w in horizons_weeks:
        prefix = yhat[: w * HOURS_PER_WEEK]
        c_w = float(cm.optimal_commitment_quantile(prefix, a, b))
        spend = float(cm.commitment_cost(eval_slice, c_w, a, b))
        out[w] = {"level": c_w, "total_spend": spend}
    return out
