"""Algorithm 1: Optimal Commitment For Demand Forecast (paper §3.3.3).

Step 1  Fit the forecaster on the hourly training history; forecast 1 year.
Step 2  For each weekly horizon w = 1..52, take the forecast prefix X̂_w.
Step 3  Compute the minimal-cost commitment level c_w over each prefix.
Step 4  c* = min_w c_w  — commitments can be *increased* later but never
        reduced, so the safe level to buy now is the minimum over horizons
        (buying more than some future optimum strands capacity).

All 52 horizon optimizations run as one vectorized pass: with the exact
quantile solver each c_w is a weighted quantile of a prefix, and with the
golden-section solver the 52 prefixes are masked views of one array, batched
under vmap.
"""

from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.capacity import generations as gn
from repro.capacity import pricing
from repro.core import commitment as cm
from repro.core import demand as dm
from repro.core import forecast as fc
from repro.core import ladder as ld
from repro.core import migration as mg
from repro.core import portfolio as pf
from repro.core import spot as spot_mod
from repro.core.demand import HOURS_PER_WEEK

pricing.validate_tables()


@dataclasses.dataclass
class PlanResult:
    commitment: float                 # c* to purchase now
    per_horizon_levels: jnp.ndarray   # (W,) c_w for each horizon
    argmin_horizon: int               # which horizon set the binding level
    forecast: jnp.ndarray             # (W*168,) hourly forecast used


def plan_commitment(
    history: jnp.ndarray,
    *,
    num_horizons: int = 52,
    a: float = cm.DEFAULT_A,
    b: float = cm.DEFAULT_B,
    cfg: fc.ForecastConfig = fc.ForecastConfig(),
    solver: Literal["quantile", "golden"] = "quantile",
) -> PlanResult:
    """Run Algorithm 1 on an hourly demand history."""
    model = fc.fit(history, cfg)
    t0 = history.shape[-1]
    horizon_hours = num_horizons * HOURS_PER_WEEK
    yhat = fc.forecast_horizon(model, t0, horizon_hours)  # Step 1

    w_hours = (jnp.arange(1, num_horizons + 1)) * HOURS_PER_WEEK  # Step 2

    if solver == "quantile":
        # Exact weighted quantile at q = a/(a+b) over each masked prefix —
        # the K=1 instance of the portfolio prefix solver (one shared sort).
        q = jnp.asarray([a / (a + b)], yhat.dtype)
        levels = _prefix_weighted_quantiles(yhat, w_hours, q)[:, 0]  # Step 3
    else:
        def golden_prefix(w):
            t = jnp.arange(yhat.shape[0])
            # Mask out-of-horizon hours by pinning them to the prefix median:
            # they then contribute a c-independent-gradient-free... not exact.
            # For the golden path we instead clamp to the valid min so masked
            # entries never bind the 'over' hinge and contribute a constant
            # slope to 'under'; exactness is restored by subtracting that
            # slope — in practice we simply evaluate cost only on valid hours
            # via where().
            fvals = jnp.where(t < w, yhat, jnp.nan)
            # golden on nan-masked cost:
            lo, hi = jnp.nanmin(fvals), jnp.nanmax(fvals)

            def cost(c):
                over = jnp.where(t < w, jnp.maximum(yhat - c, 0.0), 0.0)
                under = jnp.where(t < w, jnp.maximum(c - yhat, 0.0), 0.0)
                return a * over.sum() + b * under.sum()

            def body(_, st):
                lo, hi = st
                x1 = lo + (hi - lo) * 0.381966
                x2 = lo + (hi - lo) * 0.618034
                sm = cost(x1) < cost(x2)
                return jnp.where(sm, lo, x1), jnp.where(sm, x2, hi)

            lo, hi = jax.lax.fori_loop(0, 60, body, (lo, hi))
            return 0.5 * (lo + hi)

        levels = jax.vmap(golden_prefix)(w_hours)

    c_star = levels.min()  # Step 4
    return PlanResult(
        commitment=float(c_star),
        per_horizon_levels=levels,
        argmin_horizon=int(jnp.argmin(levels)),
        forecast=yhat,
    )


@dataclasses.dataclass
class PortfolioPlanResult:
    """Algorithm 1 generalized to a commitment portfolio (one run per
    option term).  Arrays are aligned with ``options``."""

    options: list[pf.PurchaseOption]
    widths: jnp.ndarray               # (K,) band width to purchase now
    levels: jnp.ndarray               # (K,) stack tops (envelope-monotone)
    per_horizon_levels: jnp.ndarray   # (W, K) per-horizon prefix thresholds
    fractiles: jnp.ndarray            # (K,) per-option critical fractiles
    forecast: jnp.ndarray             # (W*168,) hourly forecast used


def _prefix_weighted_quantiles(
    yhat: jnp.ndarray, w_hours: jnp.ndarray, qs: jnp.ndarray
) -> jnp.ndarray:
    """Thresholds (W, K): for each horizon prefix yhat[:w] the weighted
    quantile at each fractile q — the vectorized heart of Step 3, one sort
    for all horizons x options (same masked-prefix trick as the single-level
    path, broadcast over the portfolio's critical fractiles)."""
    order = jnp.argsort(yhat)
    sorted_y = yhat[order]
    t = jnp.arange(yhat.shape[0])
    sorted_t = t[order]

    def one_horizon(w):
        valid = (sorted_t < w).astype(yhat.dtype)
        cum = jnp.cumsum(valid)
        frac = cum / jnp.maximum(cum[-1], 1.0)
        idx = jnp.argmax(frac[None, :] >= qs[:, None], axis=-1)  # (K,)
        return sorted_y[idx]

    return jax.vmap(one_horizon)(w_hours)


def _prefix_spot_floors(
    yhat: jnp.ndarray, w_hours: jnp.ndarray, cap: jnp.ndarray
) -> jnp.ndarray:
    """(W,) per-horizon spot floor levels: on each prefix yhat[:w], the
    smallest demand level whose above-floor volume fits the chance-
    constraint cap — sum_t max(yhat_t - floor, 0) <= cap * sum_t yhat_t.
    The volume analogue of the weighted-quantile thresholds (same shared
    sort + masked-prefix trick; the floor snaps up to an observed level so
    the cap is never exceeded).  Vmap over pools for per-pool caps."""
    order = jnp.argsort(yhat)
    sorted_y = yhat[order]
    t = jnp.arange(yhat.shape[0])
    sorted_t = t[order]

    def one_horizon(w):
        valid = (sorted_t < w).astype(yhat.dtype)
        v = sorted_y * valid
        suf = jnp.flip(jnp.cumsum(jnp.flip(v)))          # sum_{j >= i} v_j
        cnt = jnp.flip(jnp.cumsum(jnp.flip(valid)))
        # volume above level sorted_y[i], prefix hours only — nonincreasing
        # in i, so the first index inside the cap is the lowest floor.
        va = (suf - v) - sorted_y * (cnt - valid)
        return sorted_y[jnp.argmax(va <= cap * suf[0])]

    return jax.vmap(one_horizon)(w_hours)


def _monotone_stack(
    per_horizon: jnp.ndarray,
    qs: jnp.ndarray,
    term_weeks: jnp.ndarray,
    num_horizons: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Step 4 of Algorithm 1 for one pool's option stack.

    per_horizon (W, K) prefix thresholds, qs (K,) critical fractiles ->
    (widths (K,), levels (K,)).  Takes each option's min over the horizons
    within its own term, then re-monotonizes the stack (running max in
    envelope-depth order) since per-option minima over different horizon
    sets can cross.  Pure array code — vmapped over the P pool axis by
    ``plan_fleet_pools``."""
    weeks = jnp.arange(1, num_horizons + 1)[:, None]              # (W, 1)
    in_term = weeks <= jnp.maximum(term_weeks[None, :], 1)
    big = jnp.float32(jnp.inf)
    mins = jnp.where(in_term, per_horizon, big).min(0)            # (K,)
    on_env = qs > 0

    depth = jnp.argsort(jnp.where(on_env, qs, jnp.inf))
    inv = jnp.argsort(depth)
    mins_d = jnp.where(on_env, mins, 0.0)[depth]
    tops_d = jax.lax.associative_scan(jnp.maximum, mins_d)
    prev_d = jnp.concatenate([jnp.zeros((1,), tops_d.dtype), tops_d[:-1]])
    widths_d = jnp.where(on_env[depth], tops_d - prev_d, 0.0)
    return widths_d[inv], tops_d[inv]


def plan_portfolio(
    history: jnp.ndarray,
    options: list[pf.PurchaseOption] | None = None,
    *,
    num_horizons: int = 52,
    od_rate: float = 2.1,
    term_weighting: float = 0.0,
    cfg: fc.ForecastConfig = fc.ForecastConfig(),
    lines: tuple[jnp.ndarray, jnp.ndarray] | None = None,
) -> PortfolioPlanResult:
    """Algorithm 1 with one horizon sweep per purchasing option.

    Steps 1-2 are shared (one forecast, 52 weekly prefixes).  Step 3
    computes each option's optimal stack threshold on every prefix — a
    weighted quantile at the option's critical fractile (portfolio lower
    envelope).  Step 4 takes the min per option over the horizons *within
    that option's term*: a commitment can never be reduced while its term
    runs, so upcoming demand drops inside the term cap today's safe
    purchase; drops after expiry are irrelevant (the tranche simply is not
    renewed) — short-term options therefore clear fewer horizons and may
    commit more aggressively than long-term ones.  Finally the stack is
    re-monotonized (running max in envelope-depth order) since per-option
    minima over different horizon sets can cross.

    ``lines`` overrides the (alphas, betas) cost lines derived from
    ``options`` — the hook ``plan_fleet_pools`` uses to price one pool's
    unavailable (wrong-cloud) options at the on-demand rate."""
    options = options if options is not None else pf.options_from_pricing()
    alphas, betas = (
        lines if lines is not None
        else pf.option_lines(options, term_weighting=term_weighting)
    )
    qs = pf.handover_fractiles(alphas, betas, od_rate=od_rate)

    model = fc.fit(history, cfg)
    t0 = history.shape[-1]
    horizon_hours = num_horizons * HOURS_PER_WEEK
    yhat = fc.forecast_horizon(model, t0, horizon_hours)          # Step 1
    w_hours = jnp.arange(1, num_horizons + 1) * HOURS_PER_WEEK    # Step 2

    per_horizon = _prefix_weighted_quantiles(yhat, w_hours, qs)   # Step 3

    term_weeks = jnp.asarray([o.term_weeks for o in options])
    widths, levels = _monotone_stack(                             # Step 4
        per_horizon, qs, term_weeks, num_horizons
    )
    return PortfolioPlanResult(
        options=options,
        widths=widths,
        levels=levels,
        per_horizon_levels=per_horizon,
        fractiles=qs,
        forecast=yhat,
    )


@dataclasses.dataclass
class PoolPlanEntry:
    """One pool's slice of a fleet plan: Algorithm-1 stack + evaluation."""

    key: dm.PoolKey
    widths: np.ndarray            # (K,) band widths, options-aligned
    levels: np.ndarray            # (K,) stack tops
    total_commitment: float       # stack top = on-demand threshold
    spend: pf.PortfolioSpend      # real-dollar eval on the held-out window


@dataclasses.dataclass
class FleetPoolsPlan:
    """Per-pool fleet plan: Algorithm 1 batched over the P pool axis.

    ``pooling_premium`` is the diagnostic the paper's per-pool framing
    implies: sum-of-pool-plan cost over the cost of one plan on the pooled
    (aggregate) trace, minus 1.  The aggregate plan pretends capacity in any
    cloud can serve any pool's demand — commitments cannot actually move
    across clouds/SKUs, so the premium is the pooling benefit an aggregate
    planner overstates."""

    keys: tuple[dm.PoolKey, ...]
    options: list[pf.PurchaseOption]
    available: np.ndarray             # (P, K) purchasable mask (cloud match)
    widths: np.ndarray                # (P, K) band widths to purchase now
    levels: np.ndarray                # (P, K) stack tops
    fractiles: np.ndarray             # (P, K) per-pool critical fractiles
    per_horizon_levels: np.ndarray    # (P, W, K) prefix thresholds
    forecasts: np.ndarray             # (P, W*168) hourly forecasts
    ladders: ld.PoolLadderBook        # per-pool tranche stacks
    per_pool: list[PoolPlanEntry]
    committed_cost: float
    on_demand_cost: float
    total_cost: float
    all_on_demand_cost: float
    savings_vs_on_demand: float
    aggregate_cost: float             # one plan on the summed fleet trace
    pooling_premium: float
    # Spot band (None / 0.0 on spot-free plans): the per-pool demand level
    # above which the plan routes demand to preemptible capacity, priced at
    # the risk-adjusted effective rate in ``spot_lines``.
    spot_lines: "spot_mod.SpotLines | None" = None
    spot_floor: np.ndarray | None = None    # (P,) spot band bottoms
    spot_cost: float = 0.0
    # Migration awareness (None on migration-blind plans): the successor
    # edges the share-based forecaster composed per-pool forecasts over.
    migration_edges: "gn.MigrationEdges | None" = None
    # Convertible band (None on convertible-free plans): cloud-level
    # exchangeable tranches sized on the residual demand above the pool
    # stacks and re-pinned onto pools for the evaluation window.
    conv_options: "list[pf.PurchaseOption] | None" = None
    conv_clouds: tuple[str, ...] | None = None
    conv_widths: np.ndarray | None = None   # (C, Kc) widths purchased now
    conv_alloc: np.ndarray | None = None    # (P,) re-pinned allocation
    conv_ladders: ld.PoolLadderBook | None = None
    conv_cost: float = 0.0

    def commitment(
        self,
        cloud: str | None = None,
        region: str | None = None,
        term_weeks: int | None = None,
    ) -> float:
        """Answer "how much 3y GCP commitment in us-central1": total width
        purchased, filtered by pool cloud/region and option term."""
        total = 0.0
        for p, key in enumerate(self.keys):
            if cloud is not None and key[0] != cloud:
                continue
            if region is not None and key[1] != region:
                continue
            for k, opt in enumerate(self.options):
                if term_weeks is not None and opt.term_weeks != term_weeks:
                    continue
                total += float(self.widths[p, k])
        return total


def plan_fleet_pools(
    pools: dm.PoolSet,
    options: list[pf.PurchaseOption] | None = None,
    *,
    horizon_weeks: int = 8,
    od_rate: float | None = None,
    term_weighting: float = 0.0,
    cfg: fc.ForecastConfig = fc.ForecastConfig(),
    mode: Literal["one_shot", "rolling"] = "one_shot",
    spot: "spot_mod.SpotConfig | bool | None" = None,
    migration: "gn.MigrationConfig | bool | None" = None,
    convertible: "list[pf.PurchaseOption] | bool | None" = None,
    policy=None,
    telemetry=None,
    **rolling_kw,
):
    """Algorithm 1 + the portfolio solver over every pool in ONE batched
    pass: the (P, T) demand matrix rides the vmapped forecaster fit, one
    shared sort per pool for all horizons x options, and per-pool purchase
    options masked to each pool's cloud (Table-2 SKUs are per cloud).

    ``mode="one_shot"`` (default, returns :class:`FleetPoolsPlan`): the
    last ``horizon_weeks`` of the trace are held out; plans are fit on the
    prefix and evaluated in real dollars on the holdout, per pool and
    fleet-total, alongside the aggregate-trace plan for the pooling-premium
    diagnostic.  Mirrors ``capacity.simulator.plan_fleet`` semantics at the
    pool level.

    ``mode="rolling"`` (returns :class:`repro.core.replan.RollingPlanReport`)
    replays the paper's actual operating loop instead: week by week, re-fit
    the forecaster on the extended prefix, re-run the solver, and buy only
    incremental tranches while expiring ones roll off — with one-shot and
    hindsight baselines on the same window.  Extra keyword arguments
    (``cadence_weeks``, ``start_weeks``, ``backend``, ``solver``, ...) are
    forwarded to :func:`repro.core.replan.replan_fleet_pools`.

    ``spot`` enables the preemptible third purchasing option (``core.spot``;
    True = default :class:`repro.core.spot.SpotConfig`): each pool gains a
    risk-priced spot band above its commitment stack, chance-constrained so
    expected demand-weighted availability stays >= the configured target.
    ``spot=None`` (default) leaves every code path bit-identical to the
    spot-free planner.

    ``migration`` makes forecasting turnover-aware (``core.migration``):
    pools matched by the ``pricing.GENERATIONS`` successor table are
    forecast as *pair total x logistic family share* instead of raw
    per-pool traces, so a generational migration is not extrapolated as
    permanent organic decay/growth.  ``convertible`` adds the cloud-level
    exchangeable SKUs (``pricing.CONVERTIBLE_PLANS``): a convertible
    stack is sized on the cloud residual demand above the pool-pinned
    stacks and its width re-pinned onto pools over the evaluation window
    (the aggregate pooling-premium baseline stays commitments+spot only —
    pooled capacity is already fungible, which is exactly what a
    convertible buys back).  Both default to None and leave every code
    path bit-identical to the pre-migration planner.

    ``policy`` (rolling mode only) selects the weekly decision rule — a
    :class:`repro.core.policy.Policy`, a registry name such as
    ``"deterministic_hedge"``, or None for the paper's rolling portfolio
    loop.  ``policy=None`` (default) keeps the replay bit-identical to
    the pre-policy planner (golden-tested).

    ``telemetry`` (rolling mode only; True or a
    :class:`repro.obs.config.TelemetryConfig`) attaches the observability
    layer — the per-week x per-pool x per-source cost ledger and kernel
    stats (``repro.obs``).  ``telemetry=None`` (default) keeps the replay
    bit-identical to the telemetry-free planner (golden-tested).

    This is the *legacy* spelling, kept as a thin shim over the unified
    request API: it builds the equivalent :class:`repro.core.api.PlanRequest`
    and calls :func:`repro.core.api.plan`, so both spellings are
    bit-identical by construction.  Loose rolling knobs in ``rolling_kw``
    (``cadence_weeks=``, ``backend=``, ...) emit a ``DeprecationWarning``
    pointing at ``RollingConfig``; new call sites should construct a
    ``PlanRequest`` directly."""
    from repro.core import api

    if mode != "rolling":
        if rolling_kw:
            raise TypeError(
                "unexpected arguments for mode='one_shot': "
                f"{sorted(rolling_kw)}"
            )
        if policy is not None:
            raise TypeError("policy= applies to mode='rolling' only")
        if telemetry is not None:
            raise TypeError("telemetry= applies to mode='rolling' only")
        request = api.PlanRequest(
            pools=pools, options=options, mode="one_shot",
            horizon_weeks=horizon_weeks, od_rate=od_rate,
            term_weighting=term_weighting, forecast=cfg, spot=spot,
            migration=migration, convertible=convertible,
        )
        return api.plan(request)

    scenarios = rolling_kw.pop("scenarios", None)
    rolling_fields = {f.name for f in dataclasses.fields(api.RollingConfig)}
    unknown = set(rolling_kw) - rolling_fields
    if unknown:
        raise TypeError(
            f"unexpected arguments for mode='rolling': {sorted(unknown)}"
        )
    if rolling_kw:
        warnings.warn(
            "passing rolling-replay knobs as loose keyword arguments "
            f"({sorted(rolling_kw)}) is deprecated; build a "
            "repro.core.api.PlanRequest with rolling=RollingConfig(...) "
            "and call repro.core.api.plan()",
            DeprecationWarning,
            stacklevel=2,
        )
    request = api.PlanRequest(
        pools=pools, options=options, mode="rolling",
        horizon_weeks=horizon_weeks, od_rate=od_rate,
        term_weighting=term_weighting, forecast=cfg, spot=spot,
        migration=migration, convertible=convertible, policy=policy,
        scenarios=scenarios, telemetry=telemetry,
        rolling=api.RollingConfig(**rolling_kw),
    )
    return api.plan(request)


def _plan_fleet_pools_one_shot(
    pools: dm.PoolSet,
    options: list[pf.PurchaseOption] | None = None,
    *,
    horizon_weeks: int = 8,
    od_rate: float | None = None,
    term_weighting: float = 0.0,
    cfg: fc.ForecastConfig = fc.ForecastConfig(),
    spot: "spot_mod.SpotConfig | bool | None" = None,
    migration: "gn.MigrationConfig | bool | None" = None,
    convertible: "list[pf.PurchaseOption] | bool | None" = None,
) -> FleetPoolsPlan:
    """The one-shot planning pipeline behind :func:`repro.core.api.plan`
    (see :func:`plan_fleet_pools` for the full narrative docstring)."""
    options = options if options is not None else pf.options_from_pricing()
    od = od_rate if od_rate is not None else pricing.on_demand_premium()
    eval_hours = horizon_weeks * HOURS_PER_WEEK
    if pools.num_hours <= eval_hours:
        raise ValueError(
            f"need > {eval_hours} hours of demand for a {horizon_weeks}-week"
            f" holdout, got {pools.num_hours}"
        )
    hist = jnp.asarray(pools.demand[:, :-eval_hours], jnp.float32)
    actual = pools.demand[:, -eval_hours:]

    # Per-pool cost lines: options off the pool's cloud priced at od_rate
    # (provably zero width) so one dense (P, K) batch feeds vmap.
    al_p, be_p, avail = pf.pool_option_lines(
        options, pools.clouds, term_weighting=term_weighting, od_rate=od
    )
    qs = jax.vmap(
        functools.partial(pf.handover_fractiles, od_rate=od)
    )(al_p, be_p)                                                 # (P, K)

    # Steps 1-2, batched: one vmapped fit + forecast over the P axis
    # (fit_batched applies fit's own short-history yearly-term guard).
    # With migration awareness, the structural fit runs on turnover-
    # invariant pair totals and per-pool forecasts are recomposed from
    # total x logistic share.
    mig_cfg = gn.resolve_migration(migration)
    edges = (
        gn.migration_edges(pools.keys, mig_cfg)
        if mig_cfg is not None else None
    )
    use_mig = edges is not None and edges.num_edges > 0
    t_fut = hist.shape[-1] + jnp.arange(eval_hours)
    if use_mig:
        model = fc.fit_batched(mg.transform_for_fit(hist, edges), cfg)
        yhat_tot = fc.predict_batched(model, t_fut)
        sh_a, sh_b = mg.fit_share(
            hist, edges, t_max=model.t_max,
            prior_weight=mig_cfg.share_prior_weight,
        )
        shares = mg.predict_share(sh_a, sh_b, t_fut, model.t_max)
        yhat = mg.compose_forecast(yhat_tot, shares, edges)
    else:
        model = fc.fit_batched(hist, cfg)
        yhat = fc.predict_batched(model, t_fut)                   # (P, H)
    w_hours = jnp.arange(1, horizon_weeks + 1) * HOURS_PER_WEEK

    # Steps 3-4, vmapped over pools (per-pool fractiles ride along).
    per_horizon = jax.vmap(
        lambda y, q: _prefix_weighted_quantiles(y, w_hours, q)
    )(yhat, qs)                                                   # (P, W, K)

    # Spot band: per-horizon floors (envelope entry <-> chance-constraint
    # volume cap) truncate the committed stack — capacity above the floor
    # is cheaper to serve from risk-priced preemptible supply than to
    # commit to or buy on demand.
    sp_res = spot_mod.resolve_spot(spot, pools.clouds, od_rate=od)
    spot_floor = None
    if sp_res is not None:
        _, s_lines = sp_res
        u_env = jax.vmap(
            lambda a_, b_, r_: spot_mod.spot_entry_fractile(
                a_, b_, r_, od_rate=od
            )
        )(al_p, be_p, s_lines.rate)                               # (P,)
        env_fl = jax.vmap(
            lambda y, q: _prefix_weighted_quantiles(y, w_hours, q[None])[:, 0]
        )(yhat, u_env)                                            # (P, W)
        vol_fl = jax.vmap(_prefix_spot_floors, in_axes=(0, None, 0))(
            yhat, w_hours, s_lines.cap
        )                                                         # (P, W)
        floors = jnp.maximum(env_fl, vol_fl)
        floors = jnp.where(s_lines.cap[:, None] > 0, floors, jnp.inf)
        per_horizon = jnp.minimum(per_horizon, floors[..., None])
        spot_floor = np.asarray(floors[:, -1])    # full-window floor

    term_weeks = jnp.asarray([o.term_weeks for o in options])
    widths, levels = jax.vmap(
        lambda ph, q: _monotone_stack(ph, q, term_weeks, horizon_weeks)
    )(per_horizon, qs)                                            # (P, K)
    widths_np = np.asarray(widths)

    # Convertible stack: cloud-level exchangeable SKUs sized on the
    # residual forecast above the pool-pinned stacks, re-pinned onto the
    # pools for the evaluation window (same machinery as the weekly
    # re-pin in the rolling replay, applied once).
    conv_opts = pf.resolve_convertible(convertible, pools.clouds)
    conv_alloc_np = None
    conv_cost = 0.0
    if conv_opts is not None:
        conv_clouds, member, al_c, be_c, qs_c, conv_terms = (
            pf.convertible_cloud_setup(
                conv_opts, pools.clouds, term_weighting=term_weighting,
                od_rate=od,
            )
        )
        pool_top = jnp.asarray(widths_np.sum(-1))
        # Cloud totals are turnover-invariant; convertible buys the band
        # that is safe at cloud level but above what pools pin themselves
        # (same sizing as the rolling replay's weekly conv pass).
        total_c = member @ yhat
        per_h_c = jax.vmap(
            lambda y, q: _prefix_weighted_quantiles(y, w_hours, q)
        )(total_c, qs_c)
        cw, ct = jax.vmap(
            lambda ph, q: _monotone_stack(ph, q, conv_terms, horizon_weeks)
        )(per_h_c, qs_c)                                          # (C, Kc)
        conv_widths = pf.truncate_convertible_stack(
            ct, cw, member @ pool_top
        )
        # Need keys on the window's forecast PEAK, mirroring the rolling
        # replay: allocating sunk capacity is free, and a mean-based need
        # would leave the diurnal peaks billing at on-demand.
        excess = jnp.maximum(yhat.max(-1) - pool_top, 0.0)
        conv_alloc = pf.allocate_convertible(
            conv_widths.sum(-1), excess, member
        )
        conv_widths_np = np.asarray(conv_widths)
        conv_alloc_np = np.asarray(conv_alloc)
        conv_rates = np.asarray([o.rate for o in conv_opts])
        conv_cost = float(
            (conv_rates * conv_widths_np).sum() * eval_hours
        )
        conv_ladders = ld.convertible_ladder_book(
            conv_widths_np[:, None, :],
            np.asarray(
                [o.term_weeks * HOURS_PER_WEEK for o in conv_opts]
            ),
            conv_clouds,
        )

    # Per-pool tranche stacks: buy every band now; terms are per-SKU.
    term_hours = np.asarray([o.term_weeks * HOURS_PER_WEEK for o in options])
    ladders = ld.plan_pool_portfolio_purchases(
        widths_np[:, None, :], term_hours, pools.keys
    )

    per_pool = []
    for p, key in enumerate(pools.keys):
        spend = pf.portfolio_spend(
            jnp.asarray(actual[p], jnp.float32), widths_np[p], options,
            od_rate=od,
            spot_rate=(
                float(sp_res[1].rate[p]) if sp_res is not None else None
            ),
            spot_floor=(
                float(spot_floor[p]) if spot_floor is not None else None
            ),
            level_offset=(
                float(conv_alloc_np[p]) if conv_alloc_np is not None
                else 0.0
            ),
        )
        per_pool.append(PoolPlanEntry(
            key=key,
            widths=widths_np[p],
            levels=np.asarray(levels[p]),
            total_commitment=float(widths_np[p].sum()),
            spend=spend,
        ))

    committed = sum(float(e.spend.committed.sum()) for e in per_pool)
    on_demand = sum(e.spend.on_demand for e in per_pool)
    spot_cost = sum(e.spend.spot for e in per_pool)
    total = committed + on_demand + spot_cost + conv_cost
    all_od = sum(e.spend.all_on_demand for e in per_pool)
    savings = 1.0 - total / all_od if all_od > 0 else 0.0

    # The aggregate (single-pool) plan the fleet trace used to collapse to:
    # same pipeline, pooled demand, every option purchasable.
    agg_hist = jnp.asarray(hist.sum(0))
    agg_res = plan_portfolio(
        agg_hist, options, num_horizons=horizon_weeks, od_rate=od,
        term_weighting=term_weighting, cfg=cfg,
    )
    agg_widths = np.asarray(agg_res.widths)
    agg_spot_rate = agg_spot_floor = None
    if sp_res is not None:
        # The premium must isolate the pooling effect, so the aggregate
        # baseline gets the same spot option: the demand-weighted mean of
        # the per-pool lines (pooled capacity has no single cloud), floors
        # from its own forecast, committed stack truncated identically.
        share = np.asarray(hist.sum(-1))
        share = share / max(share.sum(), 1e-9)
        rate_a = jnp.float32((np.asarray(s_lines.rate) * share).sum())
        cap_a = jnp.float32((np.asarray(s_lines.cap) * share).sum())
        al_a, be_a = pf.option_lines(options, term_weighting=term_weighting)
        u_env_a = spot_mod.spot_entry_fractile(
            al_a, be_a, rate_a, od_rate=od
        )
        ayhat = jnp.asarray(agg_res.forecast)
        env_a = _prefix_weighted_quantiles(ayhat, w_hours, u_env_a[None])
        vol_a = _prefix_spot_floors(ayhat, w_hours, cap_a)
        floors_a = jnp.maximum(env_a[:, 0], vol_a)
        if float(cap_a) > 0:
            per_h_a = jnp.minimum(
                jnp.asarray(agg_res.per_horizon_levels), floors_a[:, None]
            )
            agg_w, _ = _monotone_stack(
                per_h_a, agg_res.fractiles, term_weeks, horizon_weeks
            )
            agg_widths = np.asarray(agg_w)
            agg_spot_floor = float(floors_a[-1])
        else:
            agg_spot_floor = np.inf
        agg_spot_rate = float(rate_a)
    agg_spend = pf.portfolio_spend(
        jnp.asarray(actual.sum(0), jnp.float32), agg_widths,
        options, od_rate=od,
        spot_rate=agg_spot_rate, spot_floor=agg_spot_floor,
    )

    return FleetPoolsPlan(
        keys=pools.keys,
        options=options,
        available=avail,
        widths=widths_np,
        levels=np.asarray(levels),
        fractiles=np.asarray(qs),
        per_horizon_levels=np.asarray(per_horizon),
        forecasts=np.asarray(yhat),
        ladders=ladders,
        per_pool=per_pool,
        committed_cost=committed,
        on_demand_cost=on_demand,
        total_cost=total,
        all_on_demand_cost=all_od,
        savings_vs_on_demand=savings,
        aggregate_cost=agg_spend.total,
        # An empty holdout window (every pool retired) has no plan to
        # compare against: report a neutral premium instead of dividing by 0.
        pooling_premium=(
            total / agg_spend.total - 1.0 if agg_spend.total > 0 else 0.0
        ),
        spot_lines=sp_res[1] if sp_res is not None else None,
        spot_floor=spot_floor,
        spot_cost=spot_cost,
        migration_edges=edges if use_mig else None,
        conv_options=conv_opts,
        conv_clouds=(
            tuple(conv_clouds) if conv_opts is not None else None
        ),
        conv_widths=(
            conv_widths_np if conv_opts is not None else None
        ),
        conv_alloc=conv_alloc_np,
        conv_ladders=(
            conv_ladders if conv_opts is not None else None
        ),
        conv_cost=conv_cost,
    )


def compare_horizons(
    yhat: jnp.ndarray,
    horizons_weeks: tuple[int, ...] = (1, 2),
    a: float = cm.DEFAULT_A,
    b: float = cm.DEFAULT_B,
    eval_weeks: int | None = None,
) -> dict:
    """Paper Fig 8: commitment from a w1-week horizon vs w2-week horizon,
    both *applied over* the longer evaluation window.  Costs use the paper's
    Eq (1) metric: the figure's caption compares C(c_w1, X-hat_w2) vs
    C(c_w2, X-hat_w2).  Demonstrates why upcoming demand drops must be
    considered: the longer-horizon level is lower and cheaper.
    """
    eval_weeks = eval_weeks or max(horizons_weeks)
    eval_slice = yhat[: eval_weeks * HOURS_PER_WEEK]
    out = {}
    for w in horizons_weeks:
        prefix = yhat[: w * HOURS_PER_WEEK]
        c_w = float(cm.optimal_commitment_quantile(prefix, a, b))
        spend = float(cm.commitment_cost(eval_slice, c_w, a, b))
        out[w] = {"level": c_w, "total_spend": spend}
    return out
