"""Rolling weekly re-planning over pool portfolios (paper §3.3.3-§3.3.4).

Algorithm 1 is a *rolling* procedure: the paper's planner re-runs the
purchase decision every period as new demand history arrives, buying only
incremental tranches on top of what is already committed (commitments can be
added any week but only ever expire off).  ``planner.plan_fleet_pools`` is
the one-shot instance — fit at t0, buy every (P, K) width up front.  This
module replays the full operating mode over a multi-year (P, T) demand
matrix:

    for each week w (from ``start_weeks``):
        roll off tranches whose term ends at w
        re-fit the batched forecaster on the demand prefix [0, w·168)
        forecast ``horizon_weeks`` ahead; run the stacked-quantile
            portfolio solver (Algorithm 1 steps 2-4) vmapped over pools
        on decision weeks (every ``cadence_weeks``): buy, per pool per
            option, only the increment that lifts the active committed
            width up to the solver's target
        bill the week: every active tranche at its committed rate,
            demand above the stack top at the on-demand rate

The hot path is one ``lax.scan`` over weeks carrying ``(active committed
stack (P, K), tranche roll-off schedule (P, K, W))``: prefix re-fits gather
precomputed cumulative normal equations (``forecast.prefix_fit_state``) so a
3-year x 12-pool replay is a single compiled program instead of ~156
Python-level solves.  ``backend="loop"`` is the naive replay — one
re-accumulated prefix fit and one Python dispatch per week — kept as the
benchmark baseline (``bench_rolling_replan``) and as an independent
implementation the scan path is tested against.

The report compares three operating points on the same evaluation window:

    rolling    — the replay above;
    one-shot   — the same replay with a single decision week (buy the
                 t0 plan, then let tranches expire; what
                 ``plan_fleet_pools`` prices today);
    hindsight  — the optimal *constant* stack computed on the realized
                 demand (``portfolio.optimal_portfolio_stack`` per pool,
                 full knowledge; short-term tranches assumed repurchased
                 back-to-back).

``solver="grid"`` routes each week's per-horizon prefix solves through the
``commitment_sweep`` over/under sweep on 0/1 prefix-mask weights (the
Pallas kernel on TPU via ``use_kernel=True``) instead of the shared-sort
quantile path — the K-option generalization of Algorithm 1's 52 weight
patterns.

``scenarios=`` batches the whole replay over N sampled demand futures
(``data.scenarios.ScenarioConfig``): the (N, P) block is *flattened* into
the scan's pool-row axis — every per-pool op in the harness is already
row-elementwise or vmapped, so N x P rows ride the same compiled program
(cost lines, spot lines and policy pstates tile per scenario; migration
edges re-index into each scenario's row block; the convertible membership
goes block-diagonal so capacity never pools across futures).  Scenario 0
is always the realized trace, ladders are built from it, and
``n_scenarios=1`` is bit-identical to the unbatched replay (golden-
tested).  Rows are sharded over local devices (``launch.mesh``) when more
than one exists, and ``ScenarioConfig.chunk`` splits very large N into
sequential compiled chunks on one host.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.capacity import generations as gn
from repro.capacity import pricing
from repro.core import demand as dm
from repro.core import forecast as fc
from repro.core import ladder as ld
from repro.core import migration as mg
from repro.core import policy as pol
from repro.core import portfolio as pf
from repro.core import spot as spot_mod
from repro.core.demand import HOURS_PER_WEEK
from repro.core.planner import (
    _monotone_stack,
    _prefix_spot_floors,
    _prefix_weighted_quantiles,
)
from repro.core.portfolio import allocate_convertible  # noqa: F401  (API)
from repro.data import scenarios as sc
from repro.launch import mesh as mesh_mod
from repro.obs import calibration as obs_calib
from repro.obs import config as obs_config
from repro.obs import kernelstats as obs_kstats
from repro.obs import ledger as obs_ledger
from repro.obs import provenance as obs_prov

pricing.validate_tables()


@dataclasses.dataclass
class RollingPlanReport:
    """Replay of the rolling re-planning loop plus its two baselines.

    Per-week arrays are aligned with ``weeks`` (absolute week indices into
    the trace, starting at ``start_weeks``); per-pool axes align with
    ``keys``; option axes with ``options``."""

    keys: tuple[dm.PoolKey, ...]
    options: list[pf.PurchaseOption]
    cadence_weeks: int
    start_weeks: int
    horizon_weeks: int
    weeks: np.ndarray                 # (S,) absolute week index
    targets: np.ndarray               # (S, P, K) per-week solver targets
    increments: np.ndarray            # (S, P, K) tranches actually bought
    active: np.ndarray                # (S, P, K) committed stack after buys
    committed_cost: np.ndarray        # (S, P) weekly committed spend
    on_demand_cost: np.ndarray        # (S, P) weekly shortfall spend
    utilization: np.ndarray           # (S, P) used / committed chip-hours
    ladders: ld.PoolLadderBook        # the purchases as a tranche book
    total_cost: float
    all_on_demand_cost: float
    savings_vs_on_demand: float
    # one-shot baseline: buy the week-``start_weeks`` plan, never re-plan
    one_shot_weekly_cost: np.ndarray | None = None    # (S,)
    one_shot_cost: float | None = None
    savings_vs_one_shot: float | None = None
    # hindsight baseline: optimal constant stack on the realized demand
    hindsight_widths: np.ndarray | None = None        # (P, K)
    hindsight_weekly_cost: np.ndarray | None = None   # (S,)
    hindsight_cost: float | None = None
    regret_vs_hindsight: float | None = None
    # Spot band (None on spot-free replays): the fast half of the capacity
    # split — re-decided every week from that week's forecast, no tranche,
    # no term.  ``spot_floor`` is clamped to the committed stack top;
    # demand above it bills at the effective spot rate, between stack top
    # and floor at on-demand.
    spot_config: "spot_mod.SpotConfig | None" = None
    spot_lines: "spot_mod.SpotLines | None" = None
    spot_floor: np.ndarray | None = None              # (S, P) weekly floors
    spot_cost: np.ndarray | None = None               # (S, P) weekly spend
    spot_volume: np.ndarray | None = None             # (S, P) chip-hours
    spot_ladders: ld.PoolLadderBook | None = None     # 1-week audit tranches
    # Migration awareness (None on migration-blind replays): the successor
    # edges the share-based forecaster composed per-pool forecasts over.
    migration_config: "gn.MigrationConfig | None" = None
    migration_edges: "gn.MigrationEdges | None" = None
    # Convertible band (None on convertible-free replays): cloud-level
    # exchangeable tranches, carried per cloud in the scan and re-pinned
    # onto that cloud's pools every week (``conv_alloc``).  Cloud axes
    # align with ``conv_clouds``; option axes with ``conv_options``.
    conv_options: "list[pf.PurchaseOption] | None" = None
    conv_clouds: tuple[str, ...] | None = None
    conv_targets: np.ndarray | None = None            # (S, C, Kc) targets
    conv_increments: np.ndarray | None = None         # (S, C, Kc) buys
    conv_active: np.ndarray | None = None             # (S, C, Kc) stack
    conv_alloc: np.ndarray | None = None              # (S, P) re-pinned
    conv_committed_cost: np.ndarray | None = None     # (S, C) weekly spend
    conv_ladders: ld.PoolLadderBook | None = None     # cloud-level book
    # Which policy drove the weekly decisions (``core.policy``).
    policy_name: str = "rolling_portfolio"
    # Scenario batch (fields None / axis absent on single-path replays):
    # with a ScenarioConfig of n_scenarios > 1 every per-week array above
    # gains an N axis at position 1 — (S, N, P, K) etc., clouds axes
    # (S, N, C, Kc) — ``hindsight_widths`` becomes (N, P, K), the baseline
    # weekly costs (S, N), and the scalar aggregates (``total_cost``,
    # ``*_cost``) are MEANS over scenarios.  Ladders are always built from
    # scenario 0, the realized trace.
    n_scenarios: int = 1
    scenario_family: str | None = None
    scenario_cost: np.ndarray | None = None            # (N,) replay cost
    scenario_one_shot_cost: np.ndarray | None = None   # (N,)
    scenario_hindsight_cost: np.ndarray | None = None  # (N,)
    scenario_cr: np.ndarray | None = None              # (N,) cost/hindsight
    scenario_regret: np.ndarray | None = None          # (N,) cost-hindsight
    # Request provenance (always set by the replay): the resolved on-demand
    # rate and scenario config, so downstream consumers (spot replay,
    # ledger) need no side-channel.
    od_rate: float | None = None
    scenario_config: "sc.ScenarioConfig | None" = None
    # Telemetry (``repro.obs``; all None on telemetry=None replays —
    # the scan emits no extra outputs at all, so those paths stay
    # bit-identical, golden-tested).  The usage arrays are scan outputs;
    # ``ledger`` / ``kernel_stats`` are the materialized obs objects.
    telemetry: "obs_config.TelemetryConfig | None" = None
    committed_by_sku: np.ndarray | None = None         # (S, P, K) spend
    conv_committed_by_sku: np.ndarray | None = None    # (S, C, Kc) spend
    used_hours: np.ndarray | None = None               # (S, P) chip-hours
    od_volume: np.ndarray | None = None                # (S, P) chip-hours
    ledger: "obs_ledger.CostLedger | None" = None
    kernel_stats: "obs_kstats.KernelStats | None" = None
    # Decision cadence.  "weekly" is the harness grid (the default, the
    # pre-cadence program bit for bit); "breach" re-solves only in weeks
    # where realized demand exited the forecast band held since the last
    # decision.  ``decision_mask`` records which evaluated weeks decided —
    # (S,) bool, (S, N) on scenario batches (uniform within a scenario);
    # the breach bands ride along so a host-side oracle can replay the
    # mask exactly.
    cadence: str = "weekly"
    decision_mask: np.ndarray | None = None            # (S,) / (S, N)
    breach_band_lo: np.ndarray | None = None           # (S, P) / (S, N, P)
    breach_band_hi: np.ndarray | None = None
    # Calibration telemetry (``TelemetryConfig(calibration=True)``): the
    # per-week forecast fractile levels the scan emitted and the scored
    # CalibrationCube (hits / coverage / pinball vs realized demand).
    fractile_levels: np.ndarray | None = None      # (S, P, Q) / (S, N, P, Q)
    calibration: "obs_calib.CalibrationCube | None" = None
    # Decision provenance (``provenance=True``): queryable per-week record
    # of buys, roll-offs and binding constraints on scenario 0.
    decision_log: "obs_prov.DecisionLog | None" = None

    @property
    def weekly_cost(self) -> np.ndarray:
        """(S,) fleet-total spend per week ((S, N) when scenario-batched)."""
        total = self.committed_cost + self.on_demand_cost
        if self.spot_cost is not None:
            total = total + self.spot_cost
        total = total.sum(-1)
        if self.conv_committed_cost is not None:
            total = total + self.conv_committed_cost.sum(-1)
        return total

    def summary(self) -> dict:
        out = {
            "weeks_evaluated": int(len(self.weeks)),
            "cadence_weeks": self.cadence_weeks,
            "total_cost": self.total_cost,
            "savings_vs_on_demand": self.savings_vs_on_demand,
        }
        if self.cadence != "weekly":
            out["cadence"] = self.cadence
        if self.decision_mask is not None:
            dm0 = (
                self.decision_mask if self.decision_mask.ndim == 1
                else self.decision_mask[:, 0]
            )
            out["decision_weeks"] = int(dm0.sum())
        if self.spot_cost is not None:
            out["spot_cost"] = float(self.spot_cost.sum())
            out["spot_chip_hours"] = float(self.spot_volume.sum())
        if self.conv_committed_cost is not None:
            out["convertible_cost"] = float(self.conv_committed_cost.sum())
            out["convertible_final_width"] = float(
                self.conv_active[-1].sum()
            )
        if self.one_shot_cost is not None:
            out["one_shot_cost"] = self.one_shot_cost
            out["savings_vs_one_shot"] = self.savings_vs_one_shot
        if self.hindsight_cost is not None:
            out["hindsight_cost"] = self.hindsight_cost
            out["regret_vs_hindsight"] = self.regret_vs_hindsight
        if self.n_scenarios > 1:
            out["n_scenarios"] = self.n_scenarios
            out["scenario_cost_mean"] = float(self.scenario_cost.mean())
            out["scenario_cost_p95"] = float(
                np.quantile(self.scenario_cost, 0.95)
            )
            if self.scenario_cr is not None:
                out["scenario_cr_mean"] = float(self.scenario_cr.mean())
                out["scenario_cr_p95"] = float(
                    np.quantile(self.scenario_cr, 0.95)
                )
                out["scenario_regret_mean"] = float(
                    self.scenario_regret.mean()
                )
                out["scenario_regret_p95"] = float(
                    np.quantile(self.scenario_regret, 0.95)
                )
        return out


def _tile_edges(edges: gn.MigrationEdges, n: int, p: int) -> gn.MigrationEdges:
    """Replicate one fleet's migration edges onto the flattened
    (N scenarios x P pools) row axis: scenario s's copy of edge g joins
    rows ``src[g] + s*p -> dst[g] + s*p`` — scenarios never exchange
    demand."""
    off = (jnp.arange(n, dtype=jnp.int32) * p)[:, None]
    return dataclasses.replace(
        edges,
        src=(edges.src[None, :] + off).reshape(-1),
        dst=(edges.dst[None, :] + off).reshape(-1),
        uplift=jnp.tile(edges.uplift, n),
        inv_gain=jnp.tile(edges.inv_gain, n),
        midpoint_hours=jnp.tile(edges.midpoint_hours, n),
        rate_per_hour=jnp.tile(edges.rate_per_hour, n),
    )


def _merge_scenario_reports(
    parts: list[RollingPlanReport],
) -> RollingPlanReport:
    """Stitch chunked scenario replays (``ScenarioConfig.chunk``) back into
    one report: per-week arrays concatenate along the scenario axis,
    per-scenario distributions along N, and the scalar aggregates are
    recomputed as means over the full scenario set.  Ladders (always built
    from scenario 0) come from the first chunk."""
    first = parts[0]

    def cat(name: str, axis: int):
        vals = [getattr(p, name) for p in parts]
        return None if vals[0] is None else np.concatenate(vals, axis=axis)

    ns = np.asarray([p.n_scenarios for p in parts], np.float64)
    rep = dataclasses.replace(
        first,
        targets=cat("targets", 1),
        increments=cat("increments", 1),
        active=cat("active", 1),
        committed_cost=cat("committed_cost", 1),
        on_demand_cost=cat("on_demand_cost", 1),
        utilization=cat("utilization", 1),
        spot_floor=cat("spot_floor", 1),
        spot_cost=cat("spot_cost", 1),
        spot_volume=cat("spot_volume", 1),
        conv_targets=cat("conv_targets", 1),
        conv_increments=cat("conv_increments", 1),
        conv_active=cat("conv_active", 1),
        conv_alloc=cat("conv_alloc", 1),
        conv_committed_cost=cat("conv_committed_cost", 1),
        committed_by_sku=cat("committed_by_sku", 1),
        conv_committed_by_sku=cat("conv_committed_by_sku", 1),
        used_hours=cat("used_hours", 1),
        od_volume=cat("od_volume", 1),
        breach_band_lo=cat("breach_band_lo", 1),
        breach_band_hi=cat("breach_band_hi", 1),
        fractile_levels=cat("fractile_levels", 1),
        one_shot_weekly_cost=cat("one_shot_weekly_cost", 1),
        hindsight_weekly_cost=cat("hindsight_weekly_cost", 1),
        hindsight_widths=cat("hindsight_widths", 0),
        scenario_cost=cat("scenario_cost", 0),
        scenario_one_shot_cost=cat("scenario_one_shot_cost", 0),
        scenario_hindsight_cost=cat("scenario_hindsight_cost", 0),
        scenario_cr=cat("scenario_cr", 0),
        scenario_regret=cat("scenario_regret", 0),
        n_scenarios=int(ns.sum()),
    )
    if first.decision_mask is not None:
        # Weekly-mode masks are (S,) and identical across chunks; breach
        # masks carry the scenario axis and concatenate along it.
        rep.decision_mask = (
            first.decision_mask if first.decision_mask.ndim == 1
            else np.concatenate([p.decision_mask for p in parts], axis=1)
        )
    if first.calibration is not None:
        cubes = [p.calibration for p in parts]
        rep.calibration = dataclasses.replace(
            cubes[0],
            levels=np.concatenate([c.levels for c in cubes], axis=1),
            hits=np.concatenate([c.hits for c in cubes], axis=1),
            pinball=np.concatenate([c.pinball for c in cubes], axis=1),
            realized_mean=np.concatenate(
                [c.realized_mean for c in cubes], axis=1
            ),
            realized_peak=np.concatenate(
                [c.realized_peak for c in cubes], axis=1
            ),
        )
    rep.total_cost = float(rep.scenario_cost.mean())
    rep.all_on_demand_cost = float(np.average(
        [p.all_on_demand_cost for p in parts], weights=ns
    ))
    rep.savings_vs_on_demand = (
        1.0 - rep.total_cost / rep.all_on_demand_cost
        if rep.all_on_demand_cost > 0 else 0.0
    )
    if rep.scenario_one_shot_cost is not None:
        rep.one_shot_cost = float(rep.scenario_one_shot_cost.mean())
        rep.savings_vs_one_shot = (
            1.0 - rep.total_cost / rep.one_shot_cost
            if rep.one_shot_cost > 0 else 0.0
        )
    if rep.scenario_hindsight_cost is not None:
        rep.hindsight_cost = float(rep.scenario_hindsight_cost.mean())
        rep.regret_vs_hindsight = (
            rep.total_cost / rep.hindsight_cost - 1.0
            if rep.hindsight_cost > 0 else 0.0
        )
    return rep


def _validate(total_weeks: int, start_weeks: int, cadence_weeks: int):
    if cadence_weeks < 1:
        raise ValueError(f"cadence_weeks must be >= 1, got {cadence_weeks}")
    if not 1 <= start_weeks < total_weeks:
        raise ValueError(
            f"start_weeks={start_weeks} must leave history and an "
            f"evaluation window inside {total_weeks} whole trace weeks"
        )


def replan_fleet_pools(
    pools: dm.PoolSet,
    options: list[pf.PurchaseOption] | None = None,
    *,
    cadence_weeks: int = 1,
    start_weeks: int | None = None,
    horizon_weeks: int = 8,
    od_rate: float | None = None,
    term_weighting: float = 0.0,
    cfg: fc.ForecastConfig = fc.ForecastConfig(),
    solver: Literal["quantile", "grid"] = "quantile",
    num_grid: int = 128,
    use_kernel: bool = False,
    irls_iters: int = 0,
    backend: Literal["scan", "loop"] = "scan",
    compare: bool = True,
    spot: "spot_mod.SpotConfig | bool | None" = None,
    migration: "gn.MigrationConfig | bool | None" = None,
    convertible: "list[pf.PurchaseOption] | bool | None" = None,
    policy: "pol.Policy | str | None" = None,
    scenarios: "sc.ScenarioConfig | int | None" = None,
    irls_carry: bool = False,
    telemetry: "obs_config.TelemetryConfig | bool | None" = None,
    cadence: Literal["weekly", "breach"] = "weekly",
    breach_band: tuple = (0.05, 0.95),
    breach_tolerance: float = 4.0,
    _scen_slice: tuple[int, int] | None = None,
) -> RollingPlanReport:
    """Replay the rolling re-planning loop over ``pools``.

    The first ``start_weeks`` weeks are pure history (default: a quarter of
    the trace, at least ``horizon_weeks``); every week after that is
    forecast, (on cadence weeks) re-planned, and billed.  ``irls_iters``
    adds asymmetric-error IRLS passes to each weekly refit — exact but a
    full masked design pass per week, so the default keeps the pure
    prefix-sum fit (the one-shot planner's IRLS matters most when a fit
    must survive unrevised for months; a weekly refit corrects drift
    faster than the reweighting does).  With ``compare`` the one-shot and
    hindsight baselines are replayed on the same window.

    ``spot`` adds the preemptible band (``core.spot``): committed tranches
    are the *slow* capacity the scan carries (bought incrementally, rolled
    off at term), while the spot floor is *fast* — re-derived every week
    from that week's forecast with no carry at all, since spot holds no
    term.  Weekly billing then splits three ways: committed rates below
    the stack top, on-demand between stack top and floor, the risk-priced
    effective spot rate above the floor.  The one-shot baseline replays
    with the same spot band; hindsight stays commitments-only.  With
    ``spot=None`` (default) the scan program is unchanged bit for bit.

    ``migration`` makes the weekly forecasts *turnover-aware*
    (``core.migration``): wherever the successor table matches an
    (old family, successor) pool pair, the structural forecaster fits the
    pair total in old-equivalent units (turnover-invariant) and a rolling
    logit-share fit carries the S-curve, so a migrating family's decay is
    forecast as share transfer instead of permanent organic decline — the
    failure mode that keeps migration-blind replans buying tranches on a
    dying family.  One extra prefix-sum state (five moments per edge per
    week) rides the same scan.

    ``convertible`` adds the cloud-level exchangeable SKUs
    (``pricing.CONVERTIBLE_PLANS``): each week, after the pool-pinned
    targets are decided, the *residual* cloud-level demand — forecast
    above the pool stacks, summed per cloud — is solved against the
    convertible cost lines, increments are bought into a cloud-level
    tranche stack the scan carries next to the pool-level one, and the
    live convertible width is re-pinned onto the cloud's pools
    proportionally to each pool's forecast excess
    (:func:`allocate_convertible`).  A migrating family's demand can
    therefore ride one convertible tranche across the family boundary
    instead of stranding a pinned tranche and re-buying on the successor.
    With ``migration=None`` and ``convertible=None`` (defaults) every
    code path is bit-identical to the pre-migration planner.

    ``policy`` selects the weekly decision rule (``core.policy``): a
    :class:`repro.core.policy.Policy` instance, a registry name, or None
    for the paper's :class:`~repro.core.policy.RollingPortfolioPolicy` —
    the pre-refactor scan body op for op, so ``policy=None`` replays are
    bit-identical to the pre-policy planner (golden-tested).  The spot,
    migration and convertible bands all key on the weekly forecast, so
    they require a forecasting policy; the hedging policies are
    forecast-free and run commitments-only.  The ``compare`` baselines
    always replay the standard one-shot and hindsight references,
    whichever policy drives the main replay.

    ``scenarios`` batches the replay over N demand futures derived from
    the realized trace (``data.scenarios.ScenarioConfig``; an int means
    that many "realized" copies).  The (N, P) block is flattened into the
    scan's row axis, so one compiled program replays every scenario;
    reports grow per-scenario cost/CR/regret distributions and an N axis
    on the per-week arrays (see :class:`RollingPlanReport`).
    ``irls_carry`` makes ``irls_iters > 0`` cheap inside the replay by
    carrying the asymmetric-weight moments in the scan state (frozen-
    weights incremental IRLS) instead of full masked passes per week.

    ``telemetry`` (``repro.obs``; None/False default, True, or a
    :class:`~repro.obs.config.TelemetryConfig`) turns on the cost-
    attribution layer: the scan additionally emits per-SKU committed
    spend and usage hours — still trace-pure, still deterministic — and
    the report gains a :class:`~repro.obs.ledger.CostLedger` whose weekly
    row-sums reconcile with ``weekly_cost`` plus, for the grid solver,
    the :class:`~repro.obs.kernelstats.KernelStats` of the sweep shape.
    With ``telemetry=None`` no extra scan outputs exist, so every replay
    compiles the exact pre-telemetry program (golden-tested).

    ``TelemetryConfig(calibration=True)`` additionally emits each week's
    forecast fractile levels (``tele.fractiles``) from the scan and
    scores them against realized demand as a
    :class:`~repro.obs.calibration.CalibrationCube` — per (week x pool x
    fractile) hit indicators, empirical coverage vs nominal, interval
    widths and pinball loss, with per-scenario-family distributions when
    scenario-batched.  ``provenance=True`` emits per-week decision
    records (buys per SKU, roll-offs, binding constraint: envelope vs
    spot cap vs convertible suppression) materialized as a
    :class:`~repro.obs.provenance.DecisionLog`.  Both require a
    forecasting policy (calibration scores the forecast) and, like the
    ledger, add ZERO scan outputs when off.

    ``cadence="breach"`` (with ``cadence_weeks=1``) replaces the weekly
    decision grid with band-breach triggering: the policy re-solves only
    in weeks where last week's realized demand spent more than
    ``breach_tolerance x`` the nominal miss mass of its hours outside
    the ``breach_band`` fractile pair of the forecast made at the last
    decision (plus the mandatory start week).  The mask is computed
    in-scan through the policy ``Decision.is_decision`` carry; the
    default ``cadence="weekly"`` path stays bit-identical.
    """
    options = options if options is not None else pf.options_from_pricing()
    od = od_rate if od_rate is not None else pricing.on_demand_premium()
    total_weeks = pools.num_hours // HOURS_PER_WEEK
    if start_weeks is None:
        start_weeks = min(max(horizon_weeks, total_weeks // 4),
                          max(total_weeks - 1, 1))
    _validate(total_weeks, start_weeks, cadence_weeks)
    if cadence not in ("weekly", "breach"):
        raise ValueError(
            f"unknown cadence {cadence!r}; known: ('weekly', 'breach')"
        )
    if cadence == "breach" and cadence_weeks != 1:
        raise ValueError(
            "cadence='breach' evaluates every week and masks decisions "
            f"itself; use cadence_weeks=1, got {cadence_weeks}"
        )
    tele = obs_config.resolve_telemetry(telemetry)

    scen = sc.resolve_scenarios(scenarios)
    if (
        scen is not None and _scen_slice is None
        and scen.chunk is not None and scen.chunk < scen.n_scenarios
    ):
        # Memory relief on one host: sequential compiled chunks over
        # scenario sub-batches, merged back into one report.
        parts = [
            replan_fleet_pools(
                pools, options, cadence_weeks=cadence_weeks,
                start_weeks=start_weeks, horizon_weeks=horizon_weeks,
                od_rate=od, term_weighting=term_weighting, cfg=cfg,
                solver=solver, num_grid=num_grid, use_kernel=use_kernel,
                irls_iters=irls_iters, backend=backend, compare=compare,
                spot=spot, migration=migration, convertible=convertible,
                policy=policy, scenarios=scen, irls_carry=irls_carry,
                telemetry=tele, cadence=cadence, breach_band=breach_band,
                breach_tolerance=breach_tolerance,
                _scen_slice=(lo, min(lo + scen.chunk, scen.n_scenarios)),
            )
            for lo in range(0, scen.n_scenarios, scen.chunk)
        ]
        return _merge_scenario_reports(parts)

    num_pools, num_opts = pools.num_pools, len(options)
    horizon_hours = horizon_weeks * HOURS_PER_WEEK
    t_hist = total_weeks * HOURS_PER_WEEK
    demand = jnp.asarray(pools.demand[:, :t_hist], jnp.float32)
    if scen is None:
        num_scen = 1
        row_clouds = pools.clouds
    else:
        lo, hi = (
            _scen_slice if _scen_slice is not None
            else (0, scen.n_scenarios)
        )
        batch = sc.scenario_batch(pools.demand[:, :t_hist], scen)[lo:hi]
        num_scen = batch.shape[0]
        # Flatten (N, P) -> N*P rows: every per-pool op in the harness is
        # row-elementwise or vmapped, so the scenario axis rides the pool
        # axis through one compiled scan.  Scenario 0 (the realized trace)
        # occupies the first P rows; rows shard over local devices when
        # more than one exists (no-op, bit-identical, on one device).
        demand = mesh_mod.shard_rows(jnp.asarray(
            batch.reshape(num_scen * num_pools, t_hist), jnp.float32
        ))
        row_clouds = pools.clouds * num_scen
    num_rows = demand.shape[0]
    # The scenario axis materializes on report arrays only for a true
    # batch (chunked sub-replays always carry it so chunks concatenate).
    scen_axis = scen is not None and (
        num_scen > 1 or _scen_slice is not None
    )

    al_p, be_p, avail_p = pf.pool_option_lines(
        options, row_clouds, term_weighting=term_weighting, od_rate=od
    )
    qs = jax.vmap(
        functools.partial(pf.handover_fractiles, od_rate=od)
    )(al_p, be_p)                                              # (P, K)
    sp_res = spot_mod.resolve_spot(spot, row_clouds, od_rate=od)
    if sp_res is not None:
        s_cfg, s_lines = sp_res
        u_env = jax.vmap(
            lambda a_, b_, r_: spot_mod.spot_entry_fractile(
                a_, b_, r_, od_rate=od
            )
        )(al_p, be_p, s_lines.rate)                            # (P,)
    rates = jnp.asarray([o.rate for o in options], jnp.float32)
    term_weeks = jnp.asarray([o.term_weeks for o in options], jnp.int32)

    # Migration awareness: the structural forecaster fits pair totals (the
    # old-family rows replaced by old + (1+uplift) x successor), a share
    # prefix state rides along, and each week's per-pool forecasts are
    # recomposed from total x share inside the step.
    mig_cfg = gn.resolve_migration(migration)
    edges = (
        gn.migration_edges(pools.keys, mig_cfg)
        if mig_cfg is not None else None
    )
    if edges is not None and num_scen > 1:
        edges = _tile_edges(edges, num_scen, num_pools)
    use_mig = edges is not None and edges.num_edges > 0
    fit_demand = mg.transform_for_fit(demand, edges) if use_mig else demand

    # Convertible band: cloud-level SKUs next to the pool-pinned options.
    conv_opts = pf.resolve_convertible(convertible, pools.clouds)
    if conv_opts is not None:
        conv_clouds, member, al_c, be_c, qs_c, conv_terms = (
            pf.convertible_cloud_setup(
                conv_opts, pools.clouds, term_weighting=term_weighting,
                od_rate=od,
            )
        )
        num_clouds, num_conv = len(conv_clouds), len(conv_opts)
        if num_scen > 1:
            # Each scenario owns a private copy of the cloud axis —
            # convertible capacity must not pool across futures that
            # never co-occur.  The per-cloud lines tile; the membership
            # matrix stays (C, P) and is applied per scenario block (see
            # ``pool_to_cloud``) so the cloud-total contraction runs over
            # exactly P terms — the same float reduction order as the
            # unbatched replay, keeping scenario 0 bit-identical.
            al_c = jnp.tile(al_c, (num_scen, 1))
            be_c = jnp.tile(be_c, (num_scen, 1))
            qs_c = jnp.tile(qs_c, (num_scen, 1))
        num_cloud_rows = num_clouds * num_scen

        def pool_to_cloud(v):
            """Aggregate per-pool rows (R, ...) onto the per-scenario
            cloud rows (N*C, ...) — block-diagonal membership without a
            widened contraction."""
            if num_scen == 1:
                return member @ v
            vs = v.reshape(num_scen, num_pools, *v.shape[1:])
            out = jnp.einsum("cp,sp...->sc...", member, vs)
            return out.reshape(num_cloud_rows, *v.shape[1:])

        conv_rates = jnp.asarray(
            [o.rate for o in conv_opts], jnp.float32
        )
        max_term = max(int(term_weeks.max()), int(conv_terms.max()))
    else:
        max_term = int(term_weeks.max())
    sched_len = total_weeks + max_term + 1
    w_hours = jnp.arange(1, horizon_weeks + 1) * HOURS_PER_WEEK

    pcy = pol.get_policy(policy)
    if not pcy.forecasting:
        bands = [
            name for name, on in [
                ("spot", sp_res is not None), ("migration", use_mig),
                ("convertible", conv_opts is not None),
            ] if on
        ]
        if bands:
            raise ValueError(
                f"policy {pcy.name!r} does not forecast, but "
                f"{'/'.join(bands)} bands key on the weekly forecast; "
                "use a forecasting policy or disable the bands"
            )
        if tele is not None and tele.calibration:
            raise ValueError(
                f"policy {pcy.name!r} does not forecast, but "
                "TelemetryConfig(calibration=True) scores the weekly "
                "forecast fractiles; use a forecasting policy"
            )
        if cadence == "breach":
            raise ValueError(
                f"policy {pcy.name!r} does not forecast, but "
                "cadence='breach' triggers on the forecast band; use a "
                "forecasting policy"
            )

    state = fc.prefix_fit_state(
        fit_demand, cfg, horizon_hours=horizon_hours,
        min_prefix_hours=start_weeks * HOURS_PER_WEEK,
    )
    share_state = (
        mg.share_prefix_state(
            demand, edges, t_max=state.t_max,
            prior_weight=mig_cfg.share_prior_weight,
        )
        if use_mig else None
    )
    demand_wk = demand.reshape(num_rows, total_weeks, HOURS_PER_WEEK)

    def grid_prefix_levels(yhat, al, be, num_rows, num_k):
        """Per-horizon stack tops via the over/under sweep on prefix-mask
        weights: horizon prefixes fold into the row axis so the whole
        (R x Wh, H, G) problem is one batched sweep (rows = pools for the
        standard options, clouds for the convertible residual)."""
        f_rep = jnp.repeat(yhat, horizon_weeks, axis=0)    # (R*Wh, H)
        t = jnp.arange(horizon_hours)
        masks = (t[None, :] < w_hours[:, None]).astype(yhat.dtype)
        w_rep = jnp.tile(masks, (num_rows, 1))
        plan = pf.optimal_portfolio_grid(
            f_rep,
            jnp.repeat(al, horizon_weeks, axis=0),
            jnp.repeat(be, horizon_weeks, axis=0),
            od_rate=od, num_grid=num_grid, use_kernel=use_kernel,
            weights=w_rep,
        )
        return plan.levels.reshape(num_rows, horizon_weeks, num_k)

    def spot_floors_for(yhat):
        """(P, W) per-horizon spot floors on one week's forecast: the
        envelope entry (below it a commitment prices better than spot) vs
        the chance-constraint volume cap, whichever is higher; +inf where
        the cap is 0 so an uneconomic spot market is never routed to."""
        env_fl = jax.vmap(
            lambda y, q: _prefix_weighted_quantiles(y, w_hours, q[None])[:, 0]
        )(yhat, u_env)
        vol_fl = jax.vmap(_prefix_spot_floors, in_axes=(0, None, 0))(
            yhat, w_hours, s_lines.cap
        )
        floors = jnp.maximum(env_fl, vol_fl)
        return jnp.where(s_lines.cap[:, None] > 0, floors, jnp.inf)

    def targets_for(yhat):
        """Algorithm 1 steps 2-4 on one week's forecast: per-horizon
        prefix thresholds -> min within each option's term -> monotone
        stack widths (P, K).  With spot, the per-horizon committed levels
        truncate at the spot floors first and the coming week's floor
        (horizon 1 — spot is re-decided weekly, so only the nearest
        horizon binds it) rides along as the fast-capacity decision."""
        if solver == "grid":
            per_h = grid_prefix_levels(
                yhat, al_p, be_p, num_rows, num_opts
            )
        else:
            per_h = jax.vmap(
                lambda y, q: _prefix_weighted_quantiles(y, w_hours, q)
            )(yhat, qs)
        floor = None
        if sp_res is not None:
            floors = spot_floors_for(yhat)                 # (P, W)
            per_h = jnp.minimum(per_h, floors[..., None])
            floor = floors[:, 0]
        widths, _ = jax.vmap(
            lambda ph, q: _monotone_stack(ph, q, term_weeks, horizon_weeks)
        )(per_h, qs)
        return widths, floor

    def conv_targets_for(yhat, pool_top):
        """Cloud-level convertible targets on one week's forecast.

        The cloud *total* is the turnover-invariant series (demand moves
        between a cloud's families, it does not leave the cloud), so the
        safe cloud-level stack comes from the same per-horizon prefix
        thresholds -> term minima -> monotone stack machinery run on the
        summed forecast with the convertible cost lines.  Pools pin the
        bottom ``pool_top`` of that demand themselves (standard SKUs are
        cheaper), so the convertible bands are truncated below the summed
        pool targets: convertible buys exactly the band that is safe at
        cloud level but pinnable to no single family — the volume that
        migrates."""
        total_c = pool_to_cloud(yhat)                        # (C, H)
        if solver == "grid":
            per_h = grid_prefix_levels(
                total_c, al_c, be_c, num_cloud_rows, num_conv
            )
        else:
            per_h = jax.vmap(
                lambda y, q: _prefix_weighted_quantiles(y, w_hours, q)
            )(total_c, qs_c)
        widths_c, tops_c = jax.vmap(
            lambda ph, q: _monotone_stack(ph, q, conv_terms, horizon_weeks)
        )(per_h, qs_c)                                       # (C, Kc) x2
        return pf.truncate_convertible_stack(
            tops_c, widths_c, pool_to_cloud(pool_top)
        )                                                    # (C, Kc)

    # Migration recomposition as the policy hook: pair totals x rolling
    # logit-share fits become per-pool forecasts (the share state solves
    # on the same week prefix the structural fit did).
    if use_mig:
        def compose_forecast(yhat, w):
            sa, sb = mg.solve_share_prefix(share_state, w)
            t_fut = w * HOURS_PER_WEEK + jnp.arange(horizon_hours)
            sh = mg.predict_share(sa, sb, t_fut, share_state.t_max)
            return mg.compose_forecast(yhat, sh, edges)
    else:
        compose_forecast = None

    def make_ctx(
        cadence_wk: int, solve_fn, mode: str = "weekly"
    ) -> pol.PolicyContext:
        """The full-harness policy context: ``targets_for`` carries the
        configured solver (quantile or grid sweep) and the spot floors;
        ``compose_forecast`` the migration recomposition.  ``mode`` is
        "weekly" for every baseline replay — only the main replay runs
        the requested cadence."""
        return pol.PolicyContext(
            demand=demand, options=options, clouds=row_clouds, od=od,
            rates=rates, term_weeks=term_weeks, avail=avail_p, qs=qs,
            w_hours=w_hours, start_weeks=start_weeks,
            cadence_weeks=cadence_wk, horizon_weeks=horizon_weeks,
            total_weeks=total_weeks, state=state, solve_fn=solve_fn,
            irls_iters=irls_iters, irls_carry=irls_carry,
            targets_for=targets_for,
            compose_forecast=compose_forecast,
            cadence_mode=mode, breach_band=breach_band,
            breach_tolerance=breach_tolerance, scenario_blocks=num_scen,
        )

    def make_step(
        cadence_wk: int, solve_fn, step_policy: pol.Policy,
        mode: str = "weekly",
    ):
        pstate0, decide = step_policy.setup(
            make_ctx(cadence_wk, solve_fn, mode)
        )
        needs_prev = step_policy.needs_prev_demand or mode == "breach"
        # The trailing realized window anchoring the fractile bands
        # (spread from realized hours, level from the forecast).  Only
        # breach cadence and calibration telemetry pay for the gather.
        needs_trail = mode == "breach" or (
            tele is not None and tele.calibration
        )

        def step(carry, w):
            if conv_opts is None:
                active, rolloff, pstate = carry
            else:
                active, rolloff, pstate, active_c, rolloff_c = carry
            # 1. tranches whose term ends at week w roll off the stack
            expired = jax.lax.dynamic_index_in_dim(
                rolloff, w, axis=2, keepdims=False
            )
            active = active - expired
            # 2-4. the policy decides this week's target stack (for the
            # default rolling policy: prefix refit -> horizon forecast ->
            # solver targets, op for op the pre-policy scan body).  Buys
            # happen only on decision weeks and only as increments —
            # surpluses persist until their tranches expire.  The spot
            # floor is NOT carried: it is this week's fast-capacity
            # decision, re-derived from scratch on every step.
            d_prev = (
                jax.lax.dynamic_index_in_dim(
                    demand_wk, w - 1, axis=1, keepdims=False
                )
                if needs_prev else None
            )
            d_trail = None
            if needs_trail:
                # (R, TRAIL_WEEKS, 168) -> (R, TRAIL_WEEKS*168); the
                # dynamic-slice start clamps, so the first replayed weeks
                # of a short start simply see a shifted-but-valid window.
                d_trail = jax.lax.dynamic_slice_in_dim(
                    demand_wk, w - fc.TRAIL_WEEKS, fc.TRAIL_WEEKS, axis=1
                ).reshape(demand_wk.shape[0], -1)
            pstate, dec = decide(
                pstate,
                pol.Observation(
                    week=w, active=active, d_prev=d_prev, d_trail=d_trail
                ),
            )
            widths, floor, yhat, is_dec = (
                dec.targets, dec.floor, dec.yhat, dec.is_decision
            )
            # Weekly cadences emit a scalar is_dec and the masks below
            # broadcast it exactly as before; breach mode emits a per-row
            # (R,) vector, lifted to a column at trace time so the weekly
            # compiled program is untouched.
            vec_dec = getattr(is_dec, "ndim", 0) >= 1
            dec_p = is_dec[:, None] if vec_dec else is_dec
            if conv_opts is not None:
                # Cloud-row view of the mask: breach decisions are
                # uniform within a scenario block, so each scenario's
                # pool-row flag replicates onto its cloud rows.
                dec_c = (
                    jnp.repeat(
                        is_dec.reshape(num_scen, num_pools)[:, 0],
                        num_clouds,
                    )[:, None]
                    if vec_dec else is_dec
                )
            if conv_opts is None:
                inc = jnp.maximum(widths - active, 0.0)
                inc = jnp.where(
                    dec_p & (inc > ld.PURCHASE_EPS), inc, 0.0
                )
                active = active + inc
            else:
                # Convertible pass, decided BEFORE the standard buys: roll
                # off, size the cloud-level band (cloud-total stack
                # truncated below the pool targets), buy increments into
                # the cloud-level carry, then re-pin the live width onto
                # the pools with the largest gaps between forecast and
                # their pinned stacks.  Live convertible capacity then
                # *suppresses* new standard purchases pro rata — a tranche
                # that migrated from a dying family serves the successor
                # instead of the successor re-buying pinned capacity under
                # it (the unstranding this SKU class exists for).
                expired_c = jax.lax.dynamic_index_in_dim(
                    rolloff_c, w, axis=2, keepdims=False
                )
                active_c = active_c - expired_c
                # Truncate below the HIGHER of this week's targets and the
                # carried stack: surplus standard tranches (targets fell,
                # tranches persist to term) already cover their band — a
                # convertible bought there would bill the same demand
                # twice.
                pool_top = jnp.maximum(widths.sum(-1), active.sum(-1))
                widths_c = conv_targets_for(yhat, pool_top)
                inc_c = jnp.maximum(widths_c - active_c, 0.0)
                inc_c = jnp.where(
                    dec_c & (inc_c > ld.PURCHASE_EPS), inc_c, 0.0
                )
                active_c = active_c + inc_c
                expiry_c = jax.nn.one_hot(
                    w + conv_terms, sched_len, dtype=rolloff_c.dtype
                )
                rolloff_c = rolloff_c + (
                    inc_c[:, :, None] * expiry_c[None, :, :]
                )
                # Allocation need keys on the coming week's forecast PEAK:
                # allocating sunk capacity is free, and a mean-based need
                # would leave the diurnal peaks billing at on-demand.
                week1 = yhat[:, :HOURS_PER_WEEK].max(-1)
                need = jnp.maximum(week1 - active.sum(-1), 0.0)
                if num_scen == 1:
                    alloc = allocate_convertible(
                        active_c.sum(-1), need, member
                    )
                else:
                    # Per-scenario-block allocation with the base (C, P)
                    # membership — same program per block as unbatched.
                    alloc = jax.vmap(
                        lambda wv, nv: allocate_convertible(wv, nv, member)
                    )(
                        active_c.sum(-1).reshape(num_scen, num_clouds),
                        need.reshape(num_scen, num_pools),
                    ).reshape(num_rows)
                desired = jnp.maximum(widths - active, 0.0)
                lift = desired.sum(-1)                     # (P,)
                scale = jnp.where(
                    lift > ld.PURCHASE_EPS,
                    jnp.maximum(lift - alloc, 0.0)
                    / jnp.maximum(lift, 1e-9),
                    0.0,
                )
                inc = desired * scale[:, None]
                inc = jnp.where(
                    dec_p & (inc > ld.PURCHASE_EPS), inc, 0.0
                )
                active = active + inc
            expiry = jax.nn.one_hot(
                w + term_weeks, sched_len, dtype=rolloff.dtype
            )                                              # (K, sched)
            rolloff = rolloff + inc[:, :, None] * expiry[None, :, :]
            # 5. bill the week: committed rates regardless of use,
            # shortfall above the stack top at the on-demand rate — or,
            # with a spot band, on-demand only up to the floor and the
            # effective spot rate above it.  A convertible allocation
            # lifts each pool's effective level for the week (the tranche
            # itself bills at cloud level whether or not it is pinned).
            d = jax.lax.dynamic_index_in_dim(
                demand_wk, w, axis=1, keepdims=False
            )                                              # (P, 168)
            level = active.sum(-1)
            committed = (rates * active).sum(-1) * HOURS_PER_WEEK
            if conv_opts is not None:
                level = level + alloc
            used = jnp.minimum(d, level[:, None]).sum(-1)
            util = jnp.where(
                level > 0, used / (level * HOURS_PER_WEEK), 0.0
            )
            if sp_res is None:
                over = jnp.maximum(d - level[:, None], 0.0).sum(-1)
                out = {
                    "target": widths, "inc": inc, "active": active,
                    "committed": committed, "od": od * over, "util": util,
                    "is_dec": is_dec,
                }
            else:
                fl = jnp.maximum(floor, level)
                over = jnp.maximum(
                    jnp.minimum(d, fl[:, None]) - level[:, None], 0.0
                ).sum(-1)
                spot_over = jnp.maximum(d - fl[:, None], 0.0)
                out = {
                    "target": widths, "inc": inc, "active": active,
                    "committed": committed, "od": od * over, "util": util,
                    "is_dec": is_dec,
                    "floor": fl,
                    "spot_vol": spot_over.sum(-1),
                    "spot": s_lines.rate * spot_over.sum(-1),
                    "spot_peak": spot_over.max(-1),
                }
            if tele is not None and tele.ledger:
                # Ledger-only outputs, emitted ONLY when telemetry is on:
                # per-SKU committed spend plus the usage split the ledger
                # turns into idle hours and on-demand volume.  With
                # telemetry=None these keys do not exist and the compiled
                # program is the exact pre-telemetry one (golden-tested).
                out["committed_k"] = rates * active * HOURS_PER_WEEK
                out["used"] = used
                out["od_vol"] = over
            if tele is not None and tele.calibration:
                # Calibration-only output: the anchored fractile levels
                # of this week's forecast over the week being billed,
                # scored host-side against that week's realized demand.
                out["calib_levels"] = fc.anchored_fractile_levels(
                    d_trail, tele.fractiles
                )
            if tele is not None and tele.provenance:
                # Provenance-only outputs: the roll-offs this week and
                # the spot-cap binding flag (the stack top hit the spot
                # floor, so the floor — not the envelope — sized it).
                out["prov_expired"] = expired
                if sp_res is not None:
                    out["prov_spot_bound"] = (
                        widths.sum(-1) >= floor - 1e-3
                    )
            if dec.extras is not None:
                # Policy-authored per-week extras (breach mode emits the
                # active band as band_lo/band_hi); None on the default
                # paths, so weekly programs gain nothing.
                out.update(dec.extras)
            if conv_opts is None:
                return (active, rolloff, pstate), out
            out.update({
                "conv_target": widths_c, "conv_inc": inc_c,
                "conv_active": active_c, "conv_alloc": alloc,
                "conv_committed": (
                    (conv_rates * active_c).sum(-1) * HOURS_PER_WEEK
                ),
            })
            if tele is not None and tele.ledger:
                out["conv_committed_k"] = (
                    conv_rates * active_c * HOURS_PER_WEEK
                )
            if tele is not None and tele.provenance:
                out["prov_conv_expired"] = expired_c
                # Convertible suppression: this pool wanted a standard
                # buy (lift) and live convertible capacity was allocated
                # over it, scaling the purchase down.
                out["prov_conv_sup"] = (
                    (alloc > ld.PURCHASE_EPS) & (lift > ld.PURCHASE_EPS)
                )
            return (active, rolloff, pstate, active_c, rolloff_c), out
        return step, pstate0

    def replay(
        cadence_wk: int, which: str, step_policy: pol.Policy,
        mode: str = "weekly",
    ):
        active0 = jnp.zeros((num_rows, num_opts), jnp.float32)
        rolloff0 = jnp.zeros((num_rows, num_opts, sched_len), jnp.float32)
        if which == "scan":
            step, pstate0 = make_step(
                cadence_wk, fc.solve_prefix, step_policy, mode
            )
            carry0 = (active0, rolloff0, pstate0)
            if conv_opts is not None:
                carry0 = carry0 + (
                    jnp.zeros((num_cloud_rows, num_conv), jnp.float32),
                    jnp.zeros(
                        (num_cloud_rows, num_conv, sched_len), jnp.float32
                    ),
                )
            ws = jnp.arange(start_weeks, total_weeks)
            _, ys = jax.lax.scan(step, carry0, ws)
            return ys
        # Naive python-level replay: one full prefix re-accumulation and
        # one host dispatch per week (what the scan path replaces).
        step, pstate0 = make_step(
            cadence_wk, fc.solve_prefix_direct, step_policy, mode
        )
        carry0 = (active0, rolloff0, pstate0)
        if conv_opts is not None:
            carry0 = carry0 + (
                jnp.zeros((num_cloud_rows, num_conv), jnp.float32),
                jnp.zeros(
                    (num_cloud_rows, num_conv, sched_len), jnp.float32
                ),
            )
        carry, outs = carry0, []
        for w in range(start_weeks, total_weeks):
            carry, out = step(carry, jnp.int32(w))
            outs.append(out)
        return {
            key: jnp.stack([o[key] for o in outs]) for key in outs[0]
        }

    ys = replay(
        cadence_weeks, "scan" if backend == "scan" else "loop", pcy,
        cadence,
    )
    ys = {k_: np.asarray(v) for k_, v in ys.items()}
    weeks = np.arange(start_weeks, total_weeks)

    # The purchases as a tranche book: per-week targets (0 outside decision
    # weeks, so the ladder planner's "never below active" rule buys exactly
    # the scan's increments) threaded through the portfolio ladder.  With a
    # convertible band the solver targets are NOT what was bought (live
    # convertible capacity suppresses standard purchases), so the book
    # replays the scan's realized post-purchase stack instead.
    targets_full = np.zeros((num_pools, total_weeks, num_opts), np.float32)
    dec_raw = ys.pop("is_dec").astype(bool)  # the policy's decision weeks
    # Weekly cadences emit one scalar flag per week; breach mode emits a
    # per-row (R,) vector, uniform within each scenario block.  Books and
    # baselines key on scenario 0 — the realized trace, i.e. the first P
    # rows of the flattened batch (the whole batch on single-path runs).
    dec = dec_raw[:, 0] if dec_raw.ndim == 2 else dec_raw
    book_targets = (
        ys["target"] if conv_opts is None else ys["active"]
    )[:, :num_pools]
    targets_full[:, weeks[dec]] = np.swapaxes(book_targets[dec], 0, 1)
    term_hours = np.asarray(
        [o.term_weeks * HOURS_PER_WEEK for o in options]
    )
    ladders = ld.plan_pool_portfolio_purchases(
        targets_full, term_hours, pools.keys
    )

    total = float(ys["committed"].sum() + ys["od"].sum())
    if sp_res is not None:
        total += float(ys["spot"].sum())
    if conv_opts is not None:
        total += float(ys["conv_committed"].sum())
    eval_demand = demand[:, start_weeks * HOURS_PER_WEEK:]
    all_od = od * float(eval_demand.sum())
    scen_cost = None
    if scen is not None:
        # Per-scenario replay cost, sliced row-block by row-block in the
        # same summation order as the single-path totals — so the N=1
        # realized batch reproduces them bit for bit — and the scalar
        # aggregates become means over scenarios.
        def _srows(a, s, rows=num_pools):
            return a[:, s * rows:(s + 1) * rows]

        def _scen_total(s):
            cs = float(
                _srows(ys["committed"], s).sum() + _srows(ys["od"], s).sum()
            )
            if sp_res is not None:
                cs += float(_srows(ys["spot"], s).sum())
            if conv_opts is not None:
                cs += float(
                    _srows(ys["conv_committed"], s, num_clouds).sum()
                )
            return cs

        scen_cost = np.asarray([_scen_total(s) for s in range(num_scen)])
        scen_all_od = np.asarray([
            od * float(
                eval_demand[s * num_pools:(s + 1) * num_pools].sum()
            )
            for s in range(num_scen)
        ])
        total = float(scen_cost.mean())
        all_od = float(scen_all_od.mean())

    def _rep(a, rows=num_pools):
        """Report view of a per-week (S, R, ...) array: insert the N axis
        on true scenario batches, pass through otherwise."""
        if not scen_axis:
            return a
        return a.reshape(a.shape[0], num_scen, rows, *a.shape[2:])

    report = RollingPlanReport(
        keys=pools.keys,
        options=options,
        cadence_weeks=cadence_weeks,
        start_weeks=start_weeks,
        horizon_weeks=horizon_weeks,
        weeks=weeks,
        targets=_rep(ys["target"]),
        increments=_rep(ys["inc"]),
        active=_rep(ys["active"]),
        committed_cost=_rep(ys["committed"]),
        on_demand_cost=_rep(ys["od"]),
        utilization=_rep(ys["util"]),
        ladders=ladders,
        total_cost=total,
        all_on_demand_cost=all_od,
        savings_vs_on_demand=1.0 - total / all_od if all_od > 0 else 0.0,
        policy_name=pcy.name,
        n_scenarios=num_scen,
        scenario_family=scen.family if scen is not None else None,
        scenario_cost=scen_cost,
        od_rate=float(od),
        scenario_config=scen,
    )
    report.cadence = cadence
    if dec_raw.ndim == 1:
        report.decision_mask = dec_raw                   # (S,)
    elif scen_axis:
        # Breach masks are uniform within a scenario block, so one flag
        # per (week, scenario) is the whole story.
        report.decision_mask = dec_raw.reshape(
            len(weeks), num_scen, num_pools
        )[:, :, 0]                                       # (S, N)
    else:
        report.decision_mask = dec                       # (S,)
    if "band_lo" in ys:
        report.breach_band_lo = _rep(ys["band_lo"])
        report.breach_band_hi = _rep(ys["band_hi"])
    if sp_res is not None:
        report.spot_config = s_cfg
        report.spot_lines = s_lines
        report.spot_floor = _rep(ys["floor"])
        report.spot_cost = _rep(ys["spot"])
        report.spot_volume = _rep(ys["spot_vol"])
        # The fast half of the split as a tranche book: spot is a ladder
        # whose every tranche lasts exactly one period (re-decided, never
        # carried), sized at the week's peak spot usage (scenario 0).
        report.spot_ladders = ld.spot_ladder_book(
            ys["spot_peak"][:, :num_pools], pools.keys,
            start_week=start_weeks,
        )
    if use_mig:
        report.migration_config = mig_cfg
        report.migration_edges = edges
    if conv_opts is not None:
        report.conv_options = conv_opts
        report.conv_clouds = tuple(conv_clouds)
        report.conv_targets = _rep(ys["conv_target"], num_clouds)
        report.conv_increments = _rep(ys["conv_inc"], num_clouds)
        report.conv_active = _rep(ys["conv_active"], num_clouds)
        report.conv_alloc = _rep(ys["conv_alloc"])
        report.conv_committed_cost = _rep(ys["conv_committed"], num_clouds)
        # The cloud-level tranche book: same increment-only semantics as
        # the pool book, so its live widths must reconcile with the scan's
        # carried cloud-level stack every week (tested).  Scenario 0 rows.
        conv_full = np.zeros(
            (len(conv_clouds), total_weeks, len(conv_opts)), np.float32
        )
        conv_full[:, weeks[dec]] = np.swapaxes(
            ys["conv_target"][:, :num_clouds][dec], 0, 1
        )
        report.conv_ladders = ld.convertible_ladder_book(
            conv_full,
            np.asarray(
                [o.term_weeks * HOURS_PER_WEEK for o in conv_opts]
            ),
            conv_clouds,
        )
    if tele is not None:
        report.telemetry = tele
        if tele.kernel_stats and solver == "grid":
            # The batched sweep shape the grid solver launches each
            # decision week: horizon prefixes fold into the row axis
            # (see ``grid_prefix_levels``).
            report.kernel_stats = obs_kstats.sweep_kernel_stats(
                num_rows * horizon_weeks, num_grid, horizon_hours,
            )
        if tele.ledger:
            report.committed_by_sku = _rep(ys["committed_k"])
            report.used_hours = _rep(ys["used"])
            report.od_volume = _rep(ys["od_vol"])
            if conv_opts is not None:
                report.conv_committed_by_sku = _rep(
                    ys["conv_committed_k"], num_clouds
                )
            report.ledger = obs_ledger.ledger_from_report(report)
        if tele.calibration:
            # Score the scan-emitted fractile levels against the demand
            # the scan actually billed — every scenario out of one scan.
            report.fractile_levels = _rep(ys["calib_levels"])
            realized = np.swapaxes(
                np.asarray(demand_wk)[:, start_weeks:, :], 0, 1
            )                                            # (S, R, 168)
            report.calibration = obs_calib.calibration_from_arrays(
                weeks, ["/".join(k) for k in pools.keys], tele.fractiles,
                ys["calib_levels"], realized,
                n_scenarios=num_scen,
                meta={
                    "policy": pcy.name,
                    "cadence": cadence,
                    "scenario_family": (
                        scen.family if scen is not None else None
                    ),
                },
            )
        if tele.provenance:
            # Queryable decision records on scenario 0, matching the
            # tranche books and the ledger.
            prov_kw = {}
            if sp_res is not None:
                prov_kw["spot_bound"] = (
                    ys["prov_spot_bound"][:, :num_pools]
                )
            if conv_opts is not None:
                prov_kw.update(
                    conv_suppressed=ys["prov_conv_sup"][:, :num_pools],
                    conv_clouds=conv_clouds,
                    conv_skus=[o.name for o in conv_opts],
                    conv_term_weeks=[o.term_weeks for o in conv_opts],
                    conv_increments=ys["conv_inc"][:, :num_clouds],
                    conv_rolloffs=(
                        ys["prov_conv_expired"][:, :num_clouds]
                    ),
                    conv_active=ys["conv_active"][:, :num_clouds],
                )
            report.decision_log = obs_prov.decision_log_from_arrays(
                weeks, ["/".join(k) for k in pools.keys],
                [o.name for o in options],
                [o.term_weeks for o in options],
                is_decision=dec,
                targets=ys["target"][:, :num_pools],
                increments=ys["inc"][:, :num_pools],
                rolloffs=ys["prov_expired"][:, :num_pools],
                active=ys["active"][:, :num_pools],
                purchase_eps=float(ld.PURCHASE_EPS),
                meta={"policy": pcy.name, "cadence": cadence},
                **prov_kw,
            )
    if not compare:
        return report

    # One-shot baseline: identical replay, single decision week (with the
    # same spot/convertible bands when enabled — the baselines differ in
    # commitment cadence, not in which purchasing options exist).  Always
    # driven by the standard rolling policy so a custom ``policy=`` is
    # still scored against the paper's reference points.
    one = replay(0, "scan", pol.RollingPortfolioPolicy())
    one_weekly = _rep(np.asarray(one["committed"] + one["od"])).sum(-1)
    if sp_res is not None:
        one_weekly = one_weekly + _rep(np.asarray(one["spot"])).sum(-1)
    if conv_opts is not None:
        one_weekly = one_weekly + _rep(
            np.asarray(one["conv_committed"]), num_clouds
        ).sum(-1)
    report.one_shot_weekly_cost = one_weekly
    if scen is not None:
        scen_one = (
            one_weekly.sum(0) if scen_axis
            else np.asarray([one_weekly.sum()])
        )
        report.scenario_one_shot_cost = scen_one
        report.one_shot_cost = float(scen_one.mean())
    else:
        report.one_shot_cost = float(one_weekly.sum())
    report.savings_vs_one_shot = (
        1.0 - total / report.one_shot_cost
        if report.one_shot_cost > 0 else 0.0
    )

    # Hindsight baseline: the optimal constant stack on realized demand
    # (billing lines, i.e. term_weighting=0: every active tranche bills its
    # rate; expiring short tranches are repurchased back-to-back).
    al0, be0, _ = pf.pool_option_lines(
        options, row_clouds, term_weighting=0.0, od_rate=od
    )
    hs = jax.vmap(
        lambda f_, a_, b_: pf.optimal_portfolio_stack(f_, a_, b_, od_rate=od)
    )(eval_demand, al0, be0)
    hs_widths = np.asarray(hs.widths)
    hs_level = hs_widths.sum(-1)
    ed_wk = np.asarray(eval_demand).reshape(num_rows, len(weeks),
                                            HOURS_PER_WEEK)
    hs_over = np.maximum(ed_wk - hs_level[:, None, None], 0.0).sum(-1)
    hs_committed = (np.asarray(rates) * hs_widths).sum(-1) * HOURS_PER_WEEK
    hs_weekly = hs_committed[:, None] + od * hs_over      # (R, S)
    report.hindsight_widths = hs_widths
    report.hindsight_weekly_cost = hs_weekly.sum(0)
    report.hindsight_cost = float(hs_weekly.sum())
    if scen is not None:
        scen_hind = np.asarray([
            float(hs_weekly[s * num_pools:(s + 1) * num_pools].sum())
            for s in range(num_scen)
        ])
        report.scenario_hindsight_cost = scen_hind
        report.hindsight_cost = float(scen_hind.mean())
        report.scenario_cr = scen_cost / scen_hind
        report.scenario_regret = scen_cost - scen_hind
        if scen_axis:
            report.hindsight_widths = hs_widths.reshape(
                num_scen, num_pools, num_opts
            )
            report.hindsight_weekly_cost = hs_weekly.reshape(
                num_scen, num_pools, len(weeks)
            ).sum(1).T                                    # (S, N)
    report.regret_vs_hindsight = (
        total / report.hindsight_cost - 1.0
        if report.hindsight_cost > 0 else 0.0
    )
    return report
