"""Multi-option commitment portfolios (paper §3 generalized; Table 2 SKUs).

The paper optimizes ONE commitment level against one on-demand premium, yet
its Table 2 lists eight savings-plan SKUs across three clouds with distinct
1y/3y discounts.  Mixing purchasing options strictly dominates any
single-option plan ("Hedge Your Bets", Ambati et al.; "No Reservations",
Ambati/Irwin/Shenoy): cheap long commitments cover the always-on demand
base, lighter short commitments the mid band, on-demand the peaks.

Model.  Capacity is built as a *stack* of tranches: option k covers the band
(s_{k-1}, s_k], on-demand everything above the stack top.  Each option is a
**cost line** over slice utilization: a capacity slice at height y is used in
the hours where demand f_t > y and idle otherwise, so with
u(y) = #{t: f_t < y} / T the per-hour cost of covering the slice with
option k is

    l_k(u) = alpha_k * (1 - u) + beta_k * u
       alpha_k : $/hour while the slice is USED
       beta_k  : $/hour while the slice sits IDLE
    committed option:  alpha = beta = committed rate r_k (paid regardless);
                       beta optionally discounted by term length — a
                       stranded 1y tranche stops billing 3x sooner than a
                       stranded 3y tranche (``term_weighting``).
    on-demand:         alpha = od_rate, beta = 0.

The paper's Eq (1) is the K=1 instance (alpha_1=0, beta_1=B, od_rate=A).

Because every l_k is linear in u and u(y) is monotone in y, the optimal
stack is the *lower envelope* of the K+1 lines: each option wins a
contiguous utilization interval, so each optimal threshold s_k is a weighted
quantile of f at the fractile where option k hands over to the next — the
exact stacked generalization of the A/(A+B) newsvendor quantile in
``commitment.optimal_commitment_quantile``.  The objective stays convex
piecewise-linear, so a grid solver over the Pallas over/under sweep serves
as the jit/vmap oracle (``optimal_portfolio_grid``).

Band-assignment solver (exact, O(T log T) per pool): the argmin of the K+1
lines over the T+1 discrete utilization levels i/T is *demand independent* —
one (T+1, K+1) argmin shared by every pool — and per-pool thresholds are
gathers into the pool's sorted demand.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.capacity import pricing

# Fail at import, not as a silently absurd plan, if the pricing data rows
# this module turns into cost lines ever stop satisfying their invariants.
pricing.validate_tables()


@dataclasses.dataclass(frozen=True)
class PurchaseOption:
    """One purchasable commitment SKU.

    ``rate`` is the committed $/unit-hour in the repo's normalized units
    (mean Table-2 3y committed rate = 1.0, so on-demand ~= 2.1).

    ``convertible`` marks the cloud-level exchangeable SKU class
    (``pricing.CONVERTIBLE_PLANS``): a convertible tranche is purchasable
    against a *cloud*, not a (cloud, region, machine-family) pool, and may
    be re-pinned to a different family of that cloud at every re-plan
    boundary — the lever that keeps long commitments useful through a
    hardware-generation migration.  The flexibility costs a discount
    haircut, so on a static fleet a convertible line never beats the
    matching standard line; its value is dynamic and the planners size it
    on cloud-level residual demand (see ``core.replan``)."""

    name: str
    cloud: str
    rate: float
    term_weeks: int
    convertible: bool = False


ON_DEMAND = "on-demand"


def options_from_pricing(
    plans: Sequence[pricing.SavingsPlan] | None = None,
    *,
    terms: Sequence[str] = ("1y", "3y"),
    clouds: Sequence[str] | None = None,
) -> list[PurchaseOption]:
    """Turn Table 2 rows into PurchaseOptions (1y and 3y per SKU), rates
    normalized so the mean 3y committed rate is 1.0 — the same unit the
    single-level planner prices commitments in."""
    plans = list(plans if plans is not None else pricing.SAVINGS_PLANS)
    if clouds is not None:
        plans = [p for p in plans if p.cloud in clouds]
    base = 1.0 - pricing.mean_discount_3y()
    out = []
    for p in plans:
        if "1y" in terms:
            out.append(PurchaseOption(
                f"{p.cloud}/{p.family}/1y", p.cloud,
                (1.0 - p.discount_1y) / base, 52,
            ))
        if "3y" in terms:
            out.append(PurchaseOption(
                f"{p.cloud}/{p.family}/3y", p.cloud,
                (1.0 - p.discount_3y) / base, 156,
            ))
    return out


def convertible_options_from_pricing(
    clouds: Sequence[str] | None = None,
    *,
    terms: Sequence[str] = ("1y", "3y"),
) -> list[PurchaseOption]:
    """The per-cloud convertible SKUs (``pricing.CONVERTIBLE_PLANS``):
    rate = (1 - (mean standard discount - haircut)) in the same normalized
    units as :func:`options_from_pricing`, one SKU per cloud per term —
    family-agnostic by construction."""
    if clouds is None:
        clouds = sorted(pricing.known_clouds())
    base = 1.0 - pricing.mean_discount_3y()
    out = []
    for c in clouds:
        d1, d3 = pricing.convertible_discounts(c)
        if "1y" in terms:
            out.append(PurchaseOption(
                f"{c}/convertible/1y", c, (1.0 - d1) / base, 52,
                convertible=True,
            ))
        if "3y" in terms:
            out.append(PurchaseOption(
                f"{c}/convertible/3y", c, (1.0 - d3) / base, 156,
                convertible=True,
            ))
    return out


def resolve_convertible(
    convertible, clouds: Sequence[str]
) -> list[PurchaseOption] | None:
    """Normalize the planner-facing ``convertible=`` argument: None/False
    disables (the legacy bit-identical path), True takes the default
    per-cloud SKUs for the clouds present in the fleet, and an explicit
    option list passes through (every option must be convertible)."""
    if convertible is None or convertible is False:
        return None
    if convertible is True:
        convertible = convertible_options_from_pricing(
            sorted(set(clouds))
        )
    if not isinstance(convertible, (list, tuple)) or not all(
        isinstance(o, PurchaseOption) and o.convertible for o in convertible
    ):
        raise TypeError(
            "convertible must be None/bool or a list of convertible "
            f"PurchaseOptions, got {convertible!r}"
        )
    # An empty list (e.g. a caller's cloud filter matched nothing) means
    # "no convertible SKUs exist" — the disabled path, not a zero-option
    # solve that would crash on conv_terms.max().
    return list(convertible) or None


def convertible_cloud_setup(
    conv_options: Sequence[PurchaseOption],
    pool_clouds: Sequence[str],
    *,
    term_weighting: float = 0.0,
    od_rate: float = 2.1,
):
    """Shared cloud-level machinery for the convertible band, used
    identically by the one-shot planner and the rolling replay so the two
    cannot drift apart: the sorted cloud axis, the (C, P) membership
    matrix, per-cloud convertible cost lines (wrong-cloud SKUs priced at
    on-demand, same trick as ``pool_option_lines``), handover fractiles,
    and the per-SKU terms.  Returns
    ``(clouds, member, alphas, betas, fractiles, term_weeks)``."""
    clouds = sorted(set(pool_clouds))
    member = jnp.asarray(
        [[1.0 if c == pc else 0.0 for pc in pool_clouds] for c in clouds],
        jnp.float32,
    )
    al, be, _ = pool_option_lines(
        conv_options, clouds, term_weighting=term_weighting,
        od_rate=od_rate,
    )
    qs = jax.vmap(
        functools.partial(handover_fractiles, od_rate=od_rate)
    )(al, be)
    terms = jnp.asarray(
        [o.term_weeks for o in conv_options], jnp.int32
    )
    return clouds, member, al, be, qs, terms


def truncate_convertible_stack(
    tops: jnp.ndarray, widths: jnp.ndarray, pinned: jnp.ndarray
) -> jnp.ndarray:
    """(C, Kc) convertible band widths: the cloud-total stack truncated
    below the pool-pinned level — option bands cover (top - width, top];
    everything under ``pinned`` (C,) belongs to the cheaper family-pinned
    standard SKUs, so convertible keeps only the part of each band above
    it."""
    return jnp.maximum(
        tops - jnp.maximum(tops - widths, pinned[:, None]), 0.0
    )


def allocate_convertible(
    conv_width: jnp.ndarray,
    excess: jnp.ndarray,
    membership: jnp.ndarray,
    *,
    rounds: int = 3,
) -> jnp.ndarray:
    """Re-pin each cloud's convertible capacity onto its pools for one
    period.

    ``conv_width`` (C,) is the live convertible width per cloud,
    ``excess`` (P,) each pool's forecast demand above its own pinned
    stack, ``membership`` (C, P) the 0/1 cloud-of-pool matrix.  Allocation
    is proportional-to-excess with ``rounds`` redistribution passes (a
    pool never receives more than its excess while another of its cloud
    still starves); capacity left over when a cloud's total excess is
    smaller than its convertible width stays unallocated — it bills its
    committed rate either way and covers nothing.  Pure array math so it
    runs inside the rolling replay's scan."""
    alloc = jnp.zeros_like(excess)
    need = excess
    rem = conv_width
    for _ in range(rounds):
        cloud_need = membership @ need                       # (C,)
        give = membership.T @ (
            rem / jnp.maximum(cloud_need, 1e-9)
        ) * need                                             # (P,)
        give = jnp.minimum(give, need)
        alloc = alloc + give
        need = need - give
        rem = rem - membership @ give
    return alloc


def option_lines(
    options: Sequence[PurchaseOption],
    *,
    term_weighting: float = 0.0,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(alphas, betas) cost-line coefficients for ``options``.

    ``term_weighting`` in [0, 1] interpolates the idle-cost coefficient
    between exact in-window dollars (0.0: beta = rate — every active tranche
    bills all window hours) and term-proportional stranding (1.0:
    beta = rate * term/term_max — an idle tranche bills only until it
    expires, so short terms are cheaper to strand; this is what lets weaker
    1y discounts onto the envelope as a hedging mid-band)."""
    if not options:
        raise ValueError("portfolio requires at least one purchase option")
    rates = jnp.asarray([o.rate for o in options], jnp.float32)
    terms = jnp.asarray([o.term_weeks for o in options], jnp.float32)
    load = (1.0 - term_weighting) + term_weighting * terms / terms.max()
    return rates, rates * load


def pool_option_lines(
    options: Sequence[PurchaseOption],
    clouds: Sequence[str],
    *,
    term_weighting: float = 0.0,
    od_rate: float = 2.1,
) -> tuple[jnp.ndarray, jnp.ndarray, np.ndarray]:
    """Per-pool cost lines (P, K) for a fleet of pools on ``clouds``.

    Commitments are purchased per cloud/SKU (Table 2), so an option is
    purchasable in a pool only when their clouds match.  Rather than ragged
    per-pool option lists (which would break vmap over the P axis),
    unavailable options are priced *at* the on-demand rate (alpha = beta =
    od_rate): such a line never undercuts the on-demand line at any
    utilization u > 0, and the tie at u = 0 resolves to on-demand (listed
    first in every solver's argmin), so the envelope provably assigns them
    zero width.  Returns (alphas (P, K), betas (P, K), available (P, K))."""
    al, be = option_lines(options, term_weighting=term_weighting)
    avail = np.asarray(
        [[o.cloud == c for o in options] for c in clouds], bool
    )
    mask = jnp.asarray(avail)
    return (
        jnp.where(mask, al[None, :], od_rate),
        jnp.where(mask, be[None, :], od_rate),
        avail,
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PortfolioPlan:
    """A stacked-commitment plan for one pool.

    Arrays are aligned with the input option list; options off the envelope
    get zero width.  ``levels[k]`` is the stack top of option k's band (==
    the bottom of the band when the width is zero).

    With a spot line (``spot_rate``/``spot_cap`` on the solvers) the plan
    additionally carries ``spot_floor`` — the demand level above which spot
    serves (on-demand covers (total, spot_floor], spot everything higher) —
    and ``spot_frac``, the demand-volume fraction routed to spot (<= the
    chance-constraint cap).  Both are None on spot-free plans, keeping the
    legacy pytree shape."""

    levels: jnp.ndarray       # (..., K) band tops
    widths: jnp.ndarray       # (..., K) band widths, >= 0
    total: jnp.ndarray        # (...,)   stack top = on-demand threshold
    cost: jnp.ndarray         # (...,)   objective value (cost-line dollars)
    spot_floor: jnp.ndarray | None = None   # (...,) spot band bottom
    spot_frac: jnp.ndarray | None = None    # (...,) demand volume on spot


def _stack_heights(
    has: jnp.ndarray, lo: jnp.ndarray, widths: jnp.ndarray, sentinel
) -> jnp.ndarray:
    """Geometric stack tops from per-option band widths: cumulative widths
    in envelope depth order (ascending first-band index ``lo``; options off
    the envelope sort last via ``sentinel``), scattered back to input-option
    order.  Shared by the exact and grid solvers."""
    order = jnp.argsort(jnp.where(has, lo, sentinel), axis=-1)
    inv = jnp.argsort(order, axis=-1)
    w_ord = jnp.take_along_axis(
        jnp.broadcast_to(widths, jnp.broadcast_shapes(widths.shape, order.shape)),
        jnp.broadcast_to(order, jnp.broadcast_shapes(widths.shape, order.shape)),
        axis=-1,
    )
    heights = jnp.cumsum(w_ord, axis=-1)
    return jnp.take_along_axis(
        heights, jnp.broadcast_to(inv, heights.shape), axis=-1
    )


def _band_assignment(
    t: int, alphas: jnp.ndarray, betas: jnp.ndarray, od_rate: float
) -> jnp.ndarray:
    """(T,) argmin option per capacity band; K = on-demand.

    Band j sits between sorted demand values j-1 and j, where exactly j of
    the T hours fall below it: per-height cost of covering it with option k
    is alpha_k*(T-j) + beta_k*j, vs od_rate*(T-j) uncovered.  On-demand is
    placed FIRST so cost ties (e.g. a zero-discount option) resolve to no
    commitment."""
    j = jnp.arange(t, dtype=jnp.float32)[:, None]
    lines = jnp.concatenate(
        [
            jnp.asarray([[od_rate]], jnp.float32) * (t - j),
            alphas[None, :] * (t - j) + betas[None, :] * j,
        ],
        axis=1,
    )  # (T, K+1); column 0 = on-demand
    return jnp.argmin(lines, axis=1)


@functools.partial(jax.jit, static_argnames=("od_rate",))
def optimal_portfolio_stack(
    f: jnp.ndarray,
    alphas: jnp.ndarray,
    betas: jnp.ndarray,
    *,
    od_rate: float = 2.1,
    spot_rate: jnp.ndarray | float | None = None,
    spot_cap: jnp.ndarray | float | None = None,
) -> PortfolioPlan:
    """Exact minimizer of the stacked cost-line objective. f (..., T).

    The lower-envelope intervals are computed once (demand independent);
    per-pool thresholds are gathers into sorted demand — vmap/jit friendly,
    O(T log T) per pool like the single-level quantile solver.

    ``spot_rate``/``spot_cap`` (scalars; vmap for per-pool values) add the
    spot line alpha = spot_rate, beta = 0 under the chance-constraint cap on
    the demand-volume fraction routed to spot (``core.spot``).  The capped
    optimum keeps the envelope shape: marginal spot saving per unit volume,
    l_best(u)/(1-u) - spot_rate, is nondecreasing in u, so spot takes the
    TOP of the demand distribution down to a floor — the larger of the
    envelope entry (where spot stops beating committed lines) and the
    volume cap (smallest floor whose above-volume fits the cap, snapped up
    to a band edge so the cap is never exceeded).  Committed bands above
    the floor are truncated; on-demand covers (stack top, floor].  With
    ``spot_rate=None`` (default) the computation is the legacy spot-free
    program, bit for bit."""
    t = f.shape[-1]
    k = alphas.shape[0]
    best = _band_assignment(t, alphas, betas, od_rate)  # (T,)
    opt = best - 1  # -1 = on-demand, 0..K-1 = options

    sorted_f = jnp.sort(f, axis=-1)  # (..., T); band j's top is sorted_f[j]
    bands = jnp.arange(t)
    mask = opt[None, :] == jnp.arange(k)[:, None]      # (K, T)
    has = mask.any(-1)
    hi = jnp.where(mask, bands[None, :], -1).max(-1)            # (K,)
    lo = jnp.where(mask, bands[None, :], t + 1).min(-1)         # (K,)

    def gather(idx):  # sorted_f[..., idx] with idx (K,) >= 0
        return jnp.take(sorted_f, idx, axis=-1)

    # Exact objective: integrate the winning line over every band.
    jf = bands.astype(jnp.float32)
    alph_all = jnp.concatenate([jnp.asarray([od_rate], jnp.float32), alphas])
    beta_all = jnp.concatenate([jnp.asarray([0.0], jnp.float32), betas])
    line_best = alph_all[best] * (t - jf) + beta_all[best] * jf     # (T,)
    h = jnp.diff(sorted_f, axis=-1, prepend=jnp.zeros_like(sorted_f[..., :1]))
    covered = (opt >= 0)

    if spot_rate is None:
        tops = gather(jnp.maximum(hi, 0))
        bottoms = jnp.where(lo > 0, gather(jnp.maximum(lo - 1, 0)), 0.0)
        widths = jnp.where(has, tops - bottoms, 0.0)
        # The committed bands tile a prefix of the capacity axis, so
        # cumulative widths in envelope depth order ARE the geometric tops.
        # The (has, lo) assignment is demand independent — one permutation
        # for every pool.
        heights = _stack_heights(has, lo, widths, t + 1)
        cost_committed = (h * line_best * covered).sum(-1)
        total = widths.sum(-1) + jnp.zeros_like(f[..., 0])
        over = jnp.maximum(f - total[..., None], 0.0).sum(-1)
        cost = cost_committed + od_rate * over

        shape = f.shape[:-1] + (k,)
        return PortfolioPlan(
            levels=jnp.broadcast_to(heights, shape),
            widths=jnp.broadcast_to(widths, shape),
            total=total,
            cost=cost,
        )

    sr = jnp.asarray(spot_rate, jnp.float32)
    sc = jnp.asarray(
        1.0 if spot_cap is None else spot_cap, jnp.float32
    )
    # Envelope bound: spot wins the top-contiguous region where its line
    # undercuts the base winner (strictly, so rate ties keep zero spot).
    spot_line = sr * (t - jf)                                       # (T,)
    spot_better = (spot_line < line_best).astype(jnp.int32)
    all_above = jnp.flip(jnp.cumprod(jnp.flip(spot_better)))
    j_env = jnp.where(all_above.any(), jnp.argmax(all_above), t)

    # Volume bound, per pool: vb[j] = spot volume if the floor sits at band
    # j's bottom (level sorted_f[j-1]); nonincreasing in j, so the first
    # band index inside the cap is the lowest admissible floor.
    total_vol = sorted_f.sum(-1)                                    # (...,)
    suffix = jnp.flip(jnp.cumsum(jnp.flip(sorted_f, -1), -1), -1)
    above_cnt = (t - 1 - bands).astype(f.dtype)
    va = (suffix - sorted_f) - above_cnt * sorted_f                 # (..., T)
    vb = jnp.concatenate([total_vol[..., None], va[..., :-1]], -1)
    feasible = vb <= sc * total_vol[..., None]
    j_vol = jnp.where(feasible.any(-1), jnp.argmax(feasible, -1), t)
    j_floor = jnp.maximum(j_env, j_vol)                             # (...,)

    floor_idx = jnp.clip(j_floor - 1, 0, t - 1)[..., None]
    floor = jnp.where(
        j_floor[..., None] > 0,
        jnp.take_along_axis(sorted_f, floor_idx, -1),
        0.0,
    )[..., 0]
    spot_vol = jnp.where(
        j_floor >= t,
        0.0,
        jnp.take_along_axis(
            vb, jnp.clip(j_floor, 0, t - 1)[..., None], -1
        )[..., 0],
    )

    # Committed bands truncate at the floor (their tops gather per pool
    # now — the floor is demand dependent even though the assignment isn't).
    hi2 = jnp.minimum(hi, j_floor[..., None] - 1)                 # (..., K)
    has2 = has & (lo <= hi2)
    tops = jnp.take_along_axis(
        jnp.broadcast_to(sorted_f, f.shape[:-1] + (t,)),
        jnp.clip(hi2, 0, t - 1), -1,
    )
    bottoms = jnp.where(lo > 0, gather(jnp.maximum(lo - 1, 0)), 0.0)
    widths = jnp.where(has2, tops - bottoms, 0.0)
    heights = _stack_heights(has2, lo, widths, t + 1)

    below = bands < j_floor[..., None]                            # (..., T)
    cost_committed = (h * line_best * covered * below).sum(-1)
    total = widths.sum(-1)
    over = jnp.maximum(f - total[..., None], 0.0).sum(-1)
    od_vol = jnp.maximum(over - spot_vol, 0.0)
    cost = cost_committed + od_rate * od_vol + sr * spot_vol

    shape = f.shape[:-1] + (k,)
    return PortfolioPlan(
        levels=jnp.broadcast_to(heights, shape),
        widths=jnp.broadcast_to(widths, shape),
        total=total,
        cost=cost,
        spot_floor=jnp.maximum(floor, total),
        spot_frac=spot_vol / jnp.maximum(total_vol, 1e-9),
    )


def portfolio_cost(
    f: jnp.ndarray,
    levels: jnp.ndarray,
    alphas: jnp.ndarray,
    betas: jnp.ndarray,
    *,
    od_rate: float = 2.1,
) -> jnp.ndarray:
    """Cost-line objective of an arbitrary monotone stack. f (..., T),
    levels (..., K) nondecreasing band tops *in stack order* (option k
    covers the band (levels[k-1], levels[k]]).  The brute-force/test
    oracle — reduces to ``commitment.commitment_cost`` at K=1, alpha=0."""
    prev = jnp.concatenate(
        [jnp.zeros_like(levels[..., :1]), levels[..., :-1]], axis=-1
    )
    fexp = f[..., None, :]                               # (..., 1, T)
    top = levels[..., :, None]
    bot = prev[..., :, None]
    used = jnp.clip(jnp.minimum(fexp, top) - bot, 0.0, None).sum(-1)
    width = levels - prev
    unused = width * f.shape[-1] - used
    over = jnp.maximum(f - levels[..., -1:], 0.0).sum(-1)
    return (alphas * used + betas * unused).sum(-1) + od_rate * over


def optimal_portfolio_grid(
    f: jnp.ndarray,
    alphas: jnp.ndarray,
    betas: jnp.ndarray,
    *,
    od_rate: float = 2.1,
    num_grid: int = 256,
    use_kernel: bool = False,
    weights: jnp.ndarray | None = None,
    spot_rate: jnp.ndarray | float | None = None,
    spot_cap: jnp.ndarray | float | None = None,
) -> PortfolioPlan:
    """Grid solver on the over/under sweep — the batched jit oracle.

    One sweep over candidate levels per pool yields exact per-cell
    used/idle integrals (d/dc of the over/under hinge sums), the envelope
    picks the best option per cell, thresholds land on cell edges
    (resolution span/num_grid).  With ``use_kernel`` the sweep runs through
    the Pallas 2-D kernel: P pools x G candidates in one HBM pass.

    ``alphas``/``betas`` may be (K,) shared lines or (P, K) per-pool lines
    (the ``pool_option_lines`` fleet shape).  ``weights`` (P, T) masks or
    reweights hours — a 0/1 prefix mask turns the sweep into Algorithm 1's
    per-horizon prefix solve (the rolling replanner batches its horizon
    prefixes through here; the idle integral of a masked-out hour is 0, so
    masked hours price nothing).

    ``spot_rate``/``spot_cap`` (scalars or (P,)) add the chance-constrained
    spot line (see ``optimal_portfolio_stack``): cells where spot undercuts
    the base winner flip to spot from the top down while their cumulative
    used-volume stays inside cap * total volume; the floor lands on a cell
    edge (same resolution as every other threshold)."""
    squeeze = f.ndim == 1
    if squeeze:
        f = f[None, :]
        if weights is not None and weights.ndim == 1:
            weights = weights[None, :]
    p, t = f.shape
    k = alphas.shape[-1]
    al = jnp.broadcast_to(jnp.atleast_2d(alphas), (p, k))
    be = jnp.broadcast_to(jnp.atleast_2d(betas), (p, k))
    w = jnp.ones_like(f) if weights is None else weights.astype(f.dtype)

    grid = jnp.linspace(0.0, 1.0, num_grid, dtype=jnp.float32)
    cs = f.max(-1, keepdims=True) * grid[None, :]        # (P, G) per-pool
    if use_kernel:
        from repro.kernels.commitment_sweep.ops import (
            commitment_sweep_over_under,
        )
        over, under = commitment_sweep_over_under(f, cs, w)
    else:
        from repro.kernels.commitment_sweep.ref import (
            commitment_sweep_over_under_ref,
        )
        over, under = commitment_sweep_over_under_ref(f, w, cs)

    used = over[:, :-1] - over[:, 1:]                    # (P, G-1) cell ints
    idle = under[:, 1:] - under[:, :-1]
    cell_cost = jnp.concatenate(
        [
            (od_rate * used)[:, None, :],
            al[:, :, None] * used[:, None, :]
            + be[:, :, None] * idle[:, None, :],
        ],
        axis=1,
    )  # (P, K+1, G-1); index 0 = on-demand (first wins ties)
    best = jnp.argmin(cell_cost, axis=1) - 1             # (P, G-1)

    spot_win = None
    if spot_rate is not None:
        sr = jnp.broadcast_to(jnp.asarray(spot_rate, jnp.float32), (p,))
        sc = jnp.broadcast_to(jnp.asarray(
            1.0 if spot_cap is None else spot_cap, jnp.float32
        ), (p,))
        base_cost = jnp.min(cell_cost, axis=1)           # (P, G-1)
        spot_cell = sr[:, None] * used
        elig = spot_cell < base_cost
        # Cumulative eligible volume at-or-above each cell; spot takes the
        # top cells whose running volume fits the chance-constraint cap.
        rev_cum = jnp.flip(jnp.cumsum(jnp.flip(elig * used, -1), -1), -1)
        total_vol = over[:, :1]                          # level 0 = all f
        spot_win = elig & (rev_cum <= sc[:, None] * total_vol)

    cells = jnp.arange(num_grid - 1)
    mask = best[:, None, :] == jnp.arange(k)[None, :, None]   # (P, K, G-1)
    if spot_win is not None:
        mask = mask & ~spot_win[:, None, :]
    has = mask.any(-1)
    hi = jnp.where(mask, cells[None, None, :], -1).max(-1)    # (P, K)
    lo = jnp.where(mask, cells[None, None, :], num_grid).min(-1)
    tops = jnp.take_along_axis(cs, jnp.maximum(hi + 1, 0), axis=-1)
    bottoms = jnp.take_along_axis(cs, jnp.clip(lo, 0, num_grid - 1), axis=-1)
    widths = jnp.where(has, tops - bottoms, 0.0)
    heights = _stack_heights(has, lo, widths, num_grid)

    spot_floor = spot_frac = None
    if spot_win is not None:
        cost = jnp.where(spot_win, spot_cell, base_cost).sum(-1)
        spot_vol = (spot_win * used).sum(-1)
        lo_spot = jnp.where(
            spot_win, cells[None, :], num_grid - 1
        ).min(-1, keepdims=True)
        spot_floor = jnp.take_along_axis(cs, lo_spot, axis=-1)[:, 0]
        spot_floor = jnp.maximum(spot_floor, widths.sum(-1))
        spot_frac = spot_vol / jnp.maximum(total_vol[:, 0], 1e-9)
    else:
        cost = jnp.min(cell_cost, axis=1).sum(-1)

    plan = PortfolioPlan(
        levels=heights, widths=widths, total=widths.sum(-1), cost=cost,
        spot_floor=spot_floor, spot_frac=spot_frac,
    )
    if squeeze:
        plan = PortfolioPlan(
            levels=plan.levels[0], widths=plan.widths[0],
            total=plan.total[0], cost=plan.cost[0],
            spot_floor=None if spot_floor is None else plan.spot_floor[0],
            spot_frac=None if spot_frac is None else plan.spot_frac[0],
        )
    return plan


def handover_fractiles(
    alphas: jnp.ndarray,
    betas: jnp.ndarray,
    *,
    od_rate: float = 2.1,
    resolution: int = 4096,
) -> jnp.ndarray:
    """(K,) utilization fractile u*_k where option k hands over to the next
    envelope occupant; 0.0 marks options off the envelope (zero width).
    These are the per-option critical fractiles: the optimal threshold of
    option k on ANY demand curve is its weighted u*_k-quantile — what the
    horizon planner evaluates on forecast prefixes."""
    u = jnp.linspace(0.0, 1.0, resolution)
    lines = jnp.concatenate(
        [
            (od_rate * (1.0 - u))[:, None],
            alphas[None, :] * (1.0 - u)[:, None]
            + betas[None, :] * u[:, None],
        ],
        axis=1,
    )
    best = jnp.argmin(lines, axis=1) - 1                 # (R,) -1 = od
    k = alphas.shape[0]
    mask = best[None, :] == jnp.arange(k)[:, None]
    hi = jnp.where(mask, u[None, :], -1.0).max(-1)       # (K,)
    return jnp.where(hi >= 0, hi, 0.0)


@dataclasses.dataclass
class PortfolioSpend:
    """Real-dollar accounting of a stack over an evaluation window.

    ``spot`` is the expected-rate bill of the demand above the spot floor
    (0.0 on spot-free plans); ``spot_chip_hours`` the volume that rode
    spot."""

    committed: np.ndarray         # (K,) committed spend per option
    on_demand: float
    total: float
    all_on_demand: float
    savings_vs_on_demand: float
    spot: float = 0.0
    spot_chip_hours: float = 0.0


def portfolio_spend(
    f: jnp.ndarray,
    widths: jnp.ndarray,
    options: Sequence[PurchaseOption],
    *,
    od_rate: float = 2.1,
    spot_rate: float | None = None,
    spot_floor: float | None = None,
    level_offset: float = 0.0,
) -> PortfolioSpend:
    """In-window dollars: every active tranche bills its committed rate for
    all hours; demand above the stack pays on-demand — except, with a spot
    band (``spot_rate``/``spot_floor``), demand above the floor bills at
    the effective spot rate instead.

    ``level_offset`` lifts the effective serving level above the pool's
    own stack without billing here — the convertible allocation a
    cloud-level tranche re-pins onto this pool (its committed rate bills
    at cloud level, in the caller's accounting)."""
    t = f.shape[-1]
    rates = np.asarray([o.rate for o in options])
    w = np.asarray(widths)
    committed = rates * w * t
    total_level = float(w.sum()) + float(level_offset)
    over = float(jnp.maximum(f - total_level, 0.0).sum())
    spot_vol = 0.0
    spot_cost = 0.0
    if spot_rate is not None:
        floor = max(float(spot_floor), total_level)
        spot_vol = float(jnp.maximum(f - floor, 0.0).sum())
        spot_cost = float(spot_rate) * spot_vol
        over = max(over - spot_vol, 0.0)
    od = od_rate * over
    all_od = od_rate * float(f.sum())
    total = float(committed.sum()) + od + spot_cost
    return PortfolioSpend(
        committed=committed,
        on_demand=od,
        total=total,
        all_on_demand=all_od,
        # A pool can sit empty over the window (e.g. its training job ended):
        # no demand means nothing to save on.
        savings_vs_on_demand=1.0 - total / all_od if all_od > 0 else 0.0,
        spot=spot_cost,
        spot_chip_hours=spot_vol,
    )
