"""CLI over exported cost ledgers and calibration cubes.

    python -m repro.obs report LEDGER.jsonl            totals + economics
    python -m repro.obs diff A.jsonl B.jsonl           regression compare
    python -m repro.obs top A.jsonl [B.jsonl]          top spend (movers)
    python -m repro.obs calib C.jsonl [B.jsonl]        coverage vs nominal

``diff``/``top`` exit 1 when ``--fail-above`` is set and the largest
per-cell spend delta exceeds it — the CI reconciliation/drift gate.
``calib`` gates on coverage drift instead: with one cube,
``--fail-above`` bounds max |empirical - nominal| coverage; with two,
the max per-fractile |coverage delta| between them.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.obs.calibration import CalibrationCube
from repro.obs.ledger import CostLedger


def _load(path: str) -> CostLedger:
    return CostLedger.from_jsonl(path)


def cmd_report(args) -> int:
    led = _load(args.ledger)
    print(f"ledger {args.ledger}: weeks {int(led.weeks[0])}.."
          f"{int(led.weeks[-1])}, {len(led.entities)} entities, "
          f"{len(led.sources)} sources")
    if led.meta:
        keys = ("policy", "cadence_weeks", "start_weeks", "horizon_weeks")
        line = ", ".join(
            f"{k}={led.meta[k]}" for k in keys if k in led.meta
        )
        if line:
            print(f"  {line}")
    print("\nspend by source:")
    for s, v in sorted(led.by_source().items(), key=lambda kv: -kv[1]):
        print(f"  {s:24s} {v:16,.2f}")
    print("\nspend by entity:")
    for e, v in sorted(led.by_entity().items(), key=lambda kv: -kv[1]):
        print(f"  {e:28s} {v:16,.2f}")
    print("\nunit economics:")
    for k, v in led.unit_economics().items():
        print(f"  {k:26s} {v:16,.4f}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({
                "by_source": led.by_source(),
                "by_entity": led.by_entity(),
                "unit_economics": led.unit_economics(),
                "meta": led.meta,
            }, f, indent=2)
    return 0


def cmd_diff(args) -> int:
    diff = _load(args.a).diff(_load(args.b))
    print(diff.report())
    if args.json:
        with open(args.json, "w") as f:
            json.dump(diff.to_dict(), f, indent=2)
    if args.fail_above is not None and diff.max_abs_delta > args.fail_above:
        print(f"FAIL: max |spend delta| {diff.max_abs_delta:,.2f} > "
              f"{args.fail_above:,.2f}", file=sys.stderr)
        return 1
    return 0


def cmd_top(args) -> int:
    led = _load(args.a)
    if args.b is None:
        tot = led.cost.sum(axis=0)
        cells = [
            (led.entities[ei], led.sources[mi], float(tot[ei, mi]))
            for ei in range(len(led.entities))
            for mi in range(len(led.sources))
            if tot[ei, mi] != 0.0
        ]
        cells.sort(key=lambda c: -abs(c[2]))
        print(f"top {args.n} spend cells:")
        for e, s, v in cells[:args.n]:
            print(f"  {e:28s} {s:24s} {v:16,.2f}")
        return 0
    diff = led.diff(_load(args.b))
    print(f"top {args.n} spend movers (A - B):")
    for e, s, d in diff.top_movers(args.n):
        print(f"  {e:28s} {s:24s} {d:+16,.2f}")
    if args.fail_above is not None and diff.max_abs_delta > args.fail_above:
        print(f"FAIL: max |spend delta| {diff.max_abs_delta:,.2f} > "
              f"{args.fail_above:,.2f}", file=sys.stderr)
        return 1
    return 0


def cmd_calib(args) -> int:
    cube = CalibrationCube.from_jsonl(args.a)
    if args.b is None:
        print(cube.report())
        if args.json:
            with open(args.json, "w") as f:
                json.dump(cube.summary(), f, indent=2)
        drift = cube.max_coverage_drift
        if args.fail_above is not None and drift > args.fail_above:
            print(
                f"FAIL: max |coverage drift| {drift:.4f} > "
                f"{args.fail_above:.4f}", file=sys.stderr,
            )
            return 1
        return 0
    diff = cube.diff(CalibrationCube.from_jsonl(args.b))
    print(diff.report())
    if args.json:
        with open(args.json, "w") as f:
            json.dump(diff.to_dict(), f, indent=2)
    if (
        args.fail_above is not None
        and diff.max_abs_coverage_delta > args.fail_above
    ):
        print(
            f"FAIL: max |coverage delta| "
            f"{diff.max_abs_coverage_delta:.4f} > {args.fail_above:.4f}",
            file=sys.stderr,
        )
        return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("report", help="summarize one ledger")
    p.add_argument("ledger")
    p.add_argument("--json", help="also write the summary as JSON")
    p.set_defaults(fn=cmd_report)

    p = sub.add_parser("diff", help="compare two ledgers (A - B)")
    p.add_argument("a")
    p.add_argument("b")
    p.add_argument("--json", help="also write the diff as JSON")
    p.add_argument("--fail-above", type=float, default=None,
                   help="exit 1 if any |cell delta| exceeds this")
    p.set_defaults(fn=cmd_diff)

    p = sub.add_parser("top", help="top spend cells (one ledger) or "
                                   "movers (two)")
    p.add_argument("a")
    p.add_argument("b", nargs="?", default=None)
    p.add_argument("-n", type=int, default=10)
    p.add_argument("--fail-above", type=float, default=None,
                   help="with two ledgers: exit 1 on a larger mover")
    p.set_defaults(fn=cmd_top)

    p = sub.add_parser(
        "calib",
        help="calibration coverage report (one cube) or delta (two)",
    )
    p.add_argument("a")
    p.add_argument("b", nargs="?", default=None)
    p.add_argument("--json", help="also write the summary/diff as JSON")
    p.add_argument(
        "--fail-above", type=float, default=None,
        help="exit 1 when coverage drift (one cube) or the coverage "
             "delta (two cubes) exceeds this",
    )
    p.set_defaults(fn=cmd_calib)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
