"""Caller-side wall-clock span profiler (the observability layer's host
timer).

The planner core is wall-clock-free by contract — analysis rule R2 bans
clock reads from ``core/``/``capacity/``/``kernels/``/``data/``/``serve/``,
and rule R7 extends the ban to the whole of ``src/repro`` — so *this
module* is the single sanctioned place a wall-clock is read.  Everything
that wants timing (benchmarks, examples, the tournament scoreboard, CI
artifacts) records **spans** through a :class:`SpanRecorder` owned by the
caller:

    rec = SpanRecorder()
    with rec.span("tournament/rolling_portfolio", phase="execute"):
        report = tn.run_tournament(...)
    print(rec.report())

Spans nest (the recorder keeps a stack, so ``report()`` renders a tree)
and carry a coarse *phase* tag — ``"compile"`` (tracing + XLA compile),
``"execute"`` (device compute), ``"host"`` (numpy/report assembly, I/O) —
the three buckets a JAX program's wall time actually splits into.  The
recorder never touches traced values: it brackets *host* calls, so R2's
determinism guarantee (goldens are pure functions of their inputs) is
untouched — a span changes when the machine does, a golden never.

Core modules that optionally accept a recorder (``run_tournament(...,
spans=...)``, ``TelemetryConfig.spans``) take it as an opaque object and
call only :func:`span` / :meth:`SpanRecorder.span`; the clock read stays
here.  ``span(None, ...)`` is a zero-cost no-op, so ``spans=None`` paths
do no timing work at all.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import time

PHASES = ("compile", "execute", "host")


@dataclasses.dataclass
class Span:
    """One recorded interval.  ``parent`` indexes into the recorder's span
    list (-1 for roots); ``depth`` is the nesting level at entry."""

    name: str
    phase: str
    start_s: float
    duration_s: float = 0.0
    depth: int = 0
    parent: int = -1

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "phase": self.phase,
            "start_s": self.start_s,
            "duration_s": self.duration_s,
            "depth": self.depth,
            "parent": self.parent,
        }


class SpanRecorder:
    """Append-only wall-clock span log with a nesting stack.

    The clock defaults to ``time.perf_counter`` (monotonic, high
    resolution); tests inject a fake clock to keep themselves
    deterministic."""

    def __init__(self, clock=time.perf_counter):
        self._clock = clock
        self.spans: list[Span] = []
        self._stack: list[int] = []

    @contextlib.contextmanager
    def span(self, name: str, phase: str = "host"):
        """Record ``name`` for the duration of the ``with`` body."""
        if phase not in PHASES:
            raise ValueError(f"unknown phase {phase!r}; known: {PHASES}")
        idx = len(self.spans)
        self.spans.append(Span(
            name=name, phase=phase, start_s=self._clock(),
            depth=len(self._stack),
            parent=self._stack[-1] if self._stack else -1,
        ))
        self._stack.append(idx)
        try:
            yield self.spans[idx]
        finally:
            self._stack.pop()
            self.spans[idx].duration_s = (
                self._clock() - self.spans[idx].start_s
            )

    # -- summaries ---------------------------------------------------------

    @property
    def total_s(self) -> float:
        """Wall time covered by root spans (nested spans not double-counted)."""
        return sum(s.duration_s for s in self.spans if s.parent == -1)

    def summary(self) -> dict[str, dict]:
        """name -> {count, total_s, mean_s, phase} over all spans."""
        out: dict[str, dict] = {}
        for s in self.spans:
            agg = out.setdefault(
                s.name, {"count": 0, "total_s": 0.0, "phase": s.phase}
            )
            agg["count"] += 1
            agg["total_s"] += s.duration_s
        for agg in out.values():
            agg["mean_s"] = agg["total_s"] / agg["count"]
        return out

    def by_phase(self) -> dict[str, float]:
        """phase -> total seconds (nested spans attributed to their own
        phase; a parent's *self* time is its duration minus its children)."""
        child_time: dict[int, float] = {}
        for s in self.spans:
            if s.parent >= 0:
                child_time[s.parent] = (
                    child_time.get(s.parent, 0.0) + s.duration_s
                )
        out = {p: 0.0 for p in PHASES}
        for i, s in enumerate(self.spans):
            self_s = s.duration_s - child_time.get(i, 0.0)
            out[s.phase] += max(self_s, 0.0)
        return out

    def report(self) -> str:
        """The span tree, one line per span, indented by nesting depth."""
        lines = ["span                                   phase     seconds"]
        for s in self.spans:
            label = "  " * s.depth + s.name
            lines.append(f"{label:38s} {s.phase:9s} {s.duration_s:9.4f}")
        for p, t in self.by_phase().items():
            lines.append(f"{'total ' + p:38s} {'':9s} {t:9.4f}")
        return "\n".join(lines)

    def to_dicts(self) -> list[dict]:
        return [s.to_dict() for s in self.spans]

    def to_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(
                {"spans": self.to_dicts(), "by_phase": self.by_phase()},
                f, indent=2,
            )


@contextlib.contextmanager
def span(recorder: SpanRecorder | None, name: str, phase: str = "host"):
    """``recorder.span(...)`` when a recorder is present, a no-op
    otherwise — the one-liner call sites use so ``spans=None`` costs
    nothing (and reads no clock at all)."""
    if recorder is None:
        yield None
        return
    with recorder.span(name, phase=phase) as s:
        yield s
