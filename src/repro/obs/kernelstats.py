"""Structured kernel accounting for the Pallas commitment sweep.

``sweep_block_plan`` already *chooses* block sizes against a VMEM budget
and an HBM-pass budget; this module surfaces the resulting accounting —
the chosen tile, the padded problem, how many times the demand trace
streams from HBM, how big the broadcast temporary is, and a FLOP
estimate on the bench convention (4·P·T·G: over/under compare +
accumulate per cell) — as a frozen :class:`KernelStats` record that
benches attach to their JSON rows and the telemetry layer attaches to
the plan ledger.

The arithmetic here mirrors ``kernels.commitment_sweep.ops`` exactly
(same ``_round_up``, same temp-size formula) but never imports JAX and
never runs the kernel: stats for a shape are a pure host-side function
of (p, g, t) and the budgets, so they are free to compute anywhere —
including inside CI on machines with no accelerator.
"""

from __future__ import annotations

import dataclasses

from repro.kernels.commitment_sweep.ops import (
    SWEEP_HBM_PASS_BUDGET,
    SWEEP_VMEM_BUDGET,
    sweep_block_plan,
)


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class KernelStats:
    """Accounting for one commitment-sweep launch shape."""

    kernel: str          # kernel name, e.g. "commitment_sweep"
    p: int               # problem rows (pools, or pools x horizon weeks)
    g: int               # candidate-grid levels
    t: int               # trace hours
    block: tuple[int, int, int]        # (bp, bg, bt) chosen tile
    padded: tuple[int, int, int]       # (P_pad, G_pad, T_pad)
    hbm_passes: int      # trace re-reads per sweep: ceil(G_pad / bg)
    vmem_temp_bytes: int  # fp32 (bp, bg, bt) broadcast temporary
    vmem_budget: int
    pass_budget: int
    flops: int           # estimate, bench convention: 4 * P * T * G

    @property
    def vmem_utilization(self) -> float:
        return self.vmem_temp_bytes / self.vmem_budget

    @property
    def padding_waste(self) -> float:
        """Fraction of the padded launch volume that is padding."""
        pad = self.padded[0] * self.padded[1] * self.padded[2]
        return 1.0 - (self.p * self.g * self.t) / pad

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["block"] = list(d["block"])
        d["padded"] = list(d["padded"])
        d["vmem_utilization"] = self.vmem_utilization
        d["padding_waste"] = self.padding_waste
        return d


def sweep_kernel_stats(
    p: int,
    g: int,
    t: int,
    *,
    vmem_budget: int = SWEEP_VMEM_BUDGET,
    pass_budget: int = SWEEP_HBM_PASS_BUDGET,
) -> KernelStats:
    """Stats for one (P, G, T) commitment-sweep shape.

    Uses the real ``sweep_block_plan`` so the reported tile is exactly the
    tile a launch would use; padding mirrors ``ops.commitment_sweep``
    (rows to bp, grid/time to their lane tiles)."""
    bp, bg, bt = sweep_block_plan(
        p, g, t, vmem_budget=vmem_budget, pass_budget=pass_budget
    )
    p_pad, g_pad, t_pad = _round_up(p, bp), _round_up(g, bg), _round_up(t, bt)
    return KernelStats(
        kernel="commitment_sweep",
        p=p, g=g, t=t,
        block=(bp, bg, bt),
        padded=(p_pad, g_pad, t_pad),
        hbm_passes=-(-g_pad // bg),
        vmem_temp_bytes=bp * bg * bt * 4,
        vmem_budget=vmem_budget,
        pass_budget=pass_budget,
        flops=4 * p * t * g,
    )
