"""Telemetry configuration for plan requests.

``PlanRequest.telemetry`` (and the ``telemetry=`` kwarg on the legacy
``plan_fleet_pools`` shim) takes one of:

    None / False        no telemetry — the default; every plan path stays
                        bit-identical to a build without this subsystem
                        (the rolling scan emits no extra outputs at all)
    True                TelemetryConfig() — ledger + kernel stats on
    TelemetryConfig(...)  pick layers individually, attach a SpanRecorder

Kept separate from ``core.api`` so the obs package has no import cycle
with the planner: core imports ``obs.config``/``obs.ledger``, while obs
duck-types the report objects it receives and never imports core.
"""

from __future__ import annotations

import dataclasses
import typing

if typing.TYPE_CHECKING:  # pragma: no cover
    from repro.obs.spans import SpanRecorder


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """Which telemetry layers a plan request materializes.

    ``ledger``       emit per-week x per-pool x per-source billing rows
                     from the rolling scan and attach a ``CostLedger``
    ``kernel_stats`` attach ``KernelStats`` for the grid-solver sweep
                     shape (no-op for the quantile solver)
    ``spans``        optional ``SpanRecorder`` for caller-side wall-clock
                     phases; never read inside traced code
    """

    ledger: bool = True
    kernel_stats: bool = True
    spans: "SpanRecorder | None" = None

    @property
    def enabled(self) -> bool:
        return self.ledger or self.kernel_stats or self.spans is not None


def resolve_telemetry(spec) -> TelemetryConfig | None:
    """Normalize a user telemetry spec to ``TelemetryConfig | None``."""
    if spec is None or spec is False:
        return None
    if spec is True:
        return TelemetryConfig()
    if isinstance(spec, TelemetryConfig):
        return spec if spec.enabled else None
    raise TypeError(
        "telemetry must be None, a bool, or a TelemetryConfig, "
        f"got {type(spec).__name__}"
    )
