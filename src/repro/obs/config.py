"""Telemetry configuration for plan requests.

``PlanRequest.telemetry`` (and the ``telemetry=`` kwarg on the legacy
``plan_fleet_pools`` shim) takes one of:

    None / False        no telemetry — the default; every plan path stays
                        bit-identical to a build without this subsystem
                        (the rolling scan emits no extra outputs at all)
    True                TelemetryConfig() — ledger + kernel stats on
    TelemetryConfig(...)  pick layers individually, attach a SpanRecorder

Kept separate from ``core.api`` so the obs package has no import cycle
with the planner: core imports ``obs.config``/``obs.ledger``, while obs
duck-types the report objects it receives and never imports core.
"""

from __future__ import annotations

import dataclasses
import typing

if typing.TYPE_CHECKING:  # pragma: no cover
    from repro.obs.spans import SpanRecorder


#: Forecast fractiles the calibration layer scores each week; the outer
#: pair doubles as the default breach band (``RollingConfig.breach_band``).
DEFAULT_FRACTILES = (0.05, 0.25, 0.5, 0.75, 0.95)


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """Which telemetry layers a plan request materializes.

    ``ledger``       emit per-week x per-pool x per-source billing rows
                     from the rolling scan and attach a ``CostLedger``
    ``kernel_stats`` attach ``KernelStats`` for the grid-solver sweep
                     shape (no-op for the quantile solver)
    ``calibration``  emit each week's forecast fractile levels from the
                     scan and score them against realized demand as a
                     ``CalibrationCube`` (forecasting policies only)
    ``provenance``   emit per-week decision records (buys, roll-offs,
                     binding constraints) and attach a ``DecisionLog``
    ``fractiles``    the forecast fractiles the calibration layer scores
    ``spans``        optional ``SpanRecorder`` for caller-side wall-clock
                     phases; never read inside traced code
    """

    ledger: bool = True
    kernel_stats: bool = True
    calibration: bool = False
    provenance: bool = False
    fractiles: tuple[float, ...] = DEFAULT_FRACTILES
    spans: "SpanRecorder | None" = None

    def __post_init__(self):
        fr = tuple(float(q) for q in self.fractiles)
        if not fr:
            raise ValueError("fractiles must be non-empty")
        if any(not 0.0 < q < 1.0 for q in fr):
            raise ValueError(
                f"fractiles must lie strictly inside (0, 1), got {fr}"
            )
        if list(fr) != sorted(set(fr)):
            raise ValueError(
                f"fractiles must be strictly increasing, got {fr}"
            )
        object.__setattr__(self, "fractiles", fr)

    @property
    def enabled(self) -> bool:
        return (
            self.ledger or self.kernel_stats or self.calibration
            or self.provenance or self.spans is not None
        )


def resolve_telemetry(spec) -> TelemetryConfig | None:
    """Normalize a user telemetry spec to ``TelemetryConfig | None``."""
    if spec is None or spec is False:
        return None
    if spec is True:
        return TelemetryConfig()
    if isinstance(spec, TelemetryConfig):
        return spec if spec.enabled else None
    raise TypeError(
        "telemetry must be None, a bool, or a TelemetryConfig, "
        f"got {type(spec).__name__}"
    )
