"""Decision provenance: why does week w hold this stack?

With ``TelemetryConfig(provenance=True)`` the rolling scan additionally
emits, per evaluated week, the tranche roll-offs and the flags needed to
label each pool's *binding constraint* — which rule actually sized the
buy:

    envelope       the per-horizon demand envelope (Algorithm 1's
                   quantile thresholds) set the target
    spot_cap       the spot floor truncated the committed stack (capacity
                   above it was routed to the preemptible band instead)
    convertible    live cloud-level convertible capacity suppressed the
                   standard purchase (the unstranding rule)
    carry          not a decision week (or nothing to buy): the stack is
                   whatever previous weeks' tranches still hold

materialized as a :class:`DecisionLog`: a queryable per-week record of
bands bought per SKU, roll-offs, the ``is_decision`` flag, and a
tranche-level :meth:`~DecisionLog.holdings` reconstruction that answers
"why does week w hold this stack" — every live width traced back to the
week that bought it and the week it expires.

On scenario-batched replays the log covers scenario 0 — the realized
trace — matching the tranche books and the cost ledger.  This module
imports only numpy (core imports obs, never the reverse).
"""

from __future__ import annotations

import dataclasses

import numpy as np

#: binding-constraint labels, in suppression-priority order.
BINDINGS = ("convertible", "spot_cap", "envelope", "carry")


@dataclasses.dataclass
class DecisionLog:
    """Per-week decision records of one rolling replay (scenario 0)."""

    weeks: np.ndarray             # (S,) absolute week indices
    entities: tuple[str, ...]     # (P,) pool names
    skus: tuple[str, ...]         # (K,) standard option names
    term_weeks: np.ndarray        # (K,) option terms in weeks
    is_decision: np.ndarray       # (S,) decision-week flags
    targets: np.ndarray           # (S, P, K) solver targets
    increments: np.ndarray        # (S, P, K) tranches bought
    rolloffs: np.ndarray          # (S, P, K) widths expired at week start
    active: np.ndarray            # (S, P, K) stack after buys
    binding: np.ndarray           # (S, P) labels from :data:`BINDINGS`
    # Convertible band (None on convertible-free replays): cloud-level
    # records, axes (S, C, Kc) aligned with ``conv_clouds``/``conv_skus``.
    conv_clouds: "tuple[str, ...] | None" = None
    conv_skus: "tuple[str, ...] | None" = None
    conv_term_weeks: "np.ndarray | None" = None
    conv_increments: "np.ndarray | None" = None
    conv_rolloffs: "np.ndarray | None" = None
    conv_active: "np.ndarray | None" = None
    meta: dict = dataclasses.field(default_factory=dict)

    # -- indexing ----------------------------------------------------------

    def _week_index(self, week: int) -> int:
        idx = np.flatnonzero(self.weeks == week)
        if idx.size == 0:
            raise KeyError(
                f"week {week} not in log "
                f"({self.weeks[0]}..{self.weeks[-1]})"
            )
        return int(idx[0])

    @property
    def decision_weeks(self) -> np.ndarray:
        """(D,) absolute week indices where the policy decided."""
        return self.weeks[self.is_decision.astype(bool)]

    # -- queries -----------------------------------------------------------

    def holdings(self, week: int) -> dict:
        """The stack at ``week``, tranche by tranche: for every pool, the
        live (sku, width, bought_week, expires_week) entries — a purchase
        at week b with term t serves weeks [b, b + t).  This is the "why
        does week w hold this stack" answer: each width is traced to the
        decision week that bought it."""
        si = self._week_index(week)
        out: dict[str, list[dict]] = {}
        for pi, pool in enumerate(self.entities):
            tranches = []
            for sj in range(si + 1):
                for ki, sku in enumerate(self.skus):
                    wdt = float(self.increments[sj, pi, ki])
                    expires = int(
                        self.weeks[sj] + self.term_weeks[ki]
                    )
                    if wdt > 0.0 and expires > week:
                        tranches.append({
                            "sku": sku,
                            "width": wdt,
                            "bought_week": int(self.weeks[sj]),
                            "expires_week": expires,
                            "binding": str(self.binding[sj, pi]),
                        })
            out[pool] = tranches
        return out

    def explain(self, week: int) -> dict:
        """One week's decision record as a readable dict: what rolled
        off, what was bought under which binding constraint, and the
        resulting stack."""
        si = self._week_index(week)
        pools = {}
        for pi, pool in enumerate(self.entities):
            pools[pool] = {
                "binding": str(self.binding[si, pi]),
                "bought": {
                    sku: float(self.increments[si, pi, ki])
                    for ki, sku in enumerate(self.skus)
                    if self.increments[si, pi, ki] > 0.0
                },
                "rolled_off": {
                    sku: float(self.rolloffs[si, pi, ki])
                    for ki, sku in enumerate(self.skus)
                    if self.rolloffs[si, pi, ki] > 0.0
                },
                "target_top": float(self.targets[si, pi].sum()),
                "stack_top": float(self.active[si, pi].sum()),
            }
        out = {
            "week": int(week),
            "is_decision": bool(self.is_decision[si]),
            "pools": pools,
        }
        if self.conv_clouds is not None:
            out["clouds"] = {
                cloud: {
                    "bought": {
                        sku: float(self.conv_increments[si, ci, ki])
                        for ki, sku in enumerate(self.conv_skus)
                        if self.conv_increments[si, ci, ki] > 0.0
                    },
                    "rolled_off": {
                        sku: float(self.conv_rolloffs[si, ci, ki])
                        for ki, sku in enumerate(self.conv_skus)
                        if self.conv_rolloffs[si, ci, ki] > 0.0
                    },
                    "stack_top": float(self.conv_active[si, ci].sum()),
                }
                for ci, cloud in enumerate(self.conv_clouds)
            }
        return out

    def binding_counts(self) -> dict[str, int]:
        """How many (week, pool) decisions each constraint bound."""
        return {
            b: int((self.binding == b).sum()) for b in BINDINGS
        }

    def summary(self) -> dict:
        bought = self.increments > 0.0
        out = {
            "weeks": int(len(self.weeks)),
            "decision_weeks": int(self.is_decision.astype(bool).sum()),
            "tranches_bought": int(bought.sum()),
            "width_bought": float(self.increments.sum()),
            "width_rolled_off": float(self.rolloffs.sum()),
            "binding_counts": self.binding_counts(),
        }
        if self.conv_increments is not None:
            out["conv_tranches_bought"] = int(
                (self.conv_increments > 0.0).sum()
            )
            out["conv_width_bought"] = float(self.conv_increments.sum())
        out.update({k: v for k, v in self.meta.items()
                    if k in ("policy", "cadence")})
        return out


def decision_log_from_arrays(
    weeks,
    entities,
    skus,
    term_weeks,
    *,
    is_decision,
    targets,
    increments,
    rolloffs,
    active,
    spot_bound=None,
    conv_suppressed=None,
    conv_clouds=None,
    conv_skus=None,
    conv_term_weeks=None,
    conv_increments=None,
    conv_rolloffs=None,
    conv_active=None,
    purchase_eps: float = 1e-4,
    meta: "dict | None" = None,
) -> DecisionLog:
    """Assemble a :class:`DecisionLog` from scan-emitted arrays.

    The binding label per (week, pool) follows suppression priority: a
    week that bought nothing (or was not a decision week) is ``carry``;
    a convertible-suppressed buy is ``convertible``; a spot-floor-
    truncated target is ``spot_cap``; otherwise the demand ``envelope``
    sized the buy.  Called by ``core.replan`` with plain scenario-0
    arrays (obs never imports core)."""
    weeks = np.asarray(weeks)
    is_decision = np.asarray(is_decision).astype(bool)
    targets = np.asarray(targets, np.float64)
    increments = np.asarray(increments, np.float64)
    rolloffs = np.asarray(rolloffs, np.float64)
    active = np.asarray(active, np.float64)
    s_n, p_n, _ = increments.shape

    bought = increments.sum(-1) > purchase_eps          # (S, P)
    decided = bought & is_decision[:, None]
    binding = np.full((s_n, p_n), "carry", object)
    binding[decided] = "envelope"
    if spot_bound is not None:
        binding[decided & np.asarray(spot_bound).astype(bool)] = "spot_cap"
    if conv_suppressed is not None:
        sup = np.asarray(conv_suppressed).astype(bool)
        binding[decided & sup] = "convertible"

    return DecisionLog(
        weeks=weeks,
        entities=tuple(entities),
        skus=tuple(skus),
        term_weeks=np.asarray(term_weeks),
        is_decision=is_decision,
        targets=targets,
        increments=increments,
        rolloffs=rolloffs,
        active=active,
        binding=binding.astype(str),
        conv_clouds=tuple(conv_clouds) if conv_clouds is not None else None,
        conv_skus=tuple(conv_skus) if conv_skus is not None else None,
        conv_term_weeks=(
            np.asarray(conv_term_weeks)
            if conv_term_weeks is not None else None
        ),
        conv_increments=(
            np.asarray(conv_increments, np.float64)
            if conv_increments is not None else None
        ),
        conv_rolloffs=(
            np.asarray(conv_rolloffs, np.float64)
            if conv_rolloffs is not None else None
        ),
        conv_active=(
            np.asarray(conv_active, np.float64)
            if conv_active is not None else None
        ),
        meta=dict(meta or {}),
    )
