"""Observability layer: cost-attribution ledger, span profiler, kernel
stats.

Three layers, all strictly outside the traced planning core (rules
R2/R7):

- ``obs.ledger`` — :class:`~repro.obs.ledger.CostLedger`, the per-week x
  per-pool x per-source billing decomposition materialized from a
  telemetry-enabled rolling replay; JSONL export, ``diff`` comparator,
  unit-economics summaries.
- ``obs.spans`` — :class:`~repro.obs.spans.SpanRecorder`, the sanctioned
  caller-side wall clock (compile / execute / host phases).
- ``obs.kernelstats`` — :class:`~repro.obs.kernelstats.KernelStats` for
  the Pallas commitment-sweep launch shapes.

Enable per request: ``api.PlanRequest(..., telemetry=True)`` or
``telemetry=obs.TelemetryConfig(spans=rec)``; ``telemetry=None`` (the
default) keeps every plan path bit-identical.  ``python -m repro.obs``
reports/diffs exported ledgers.
"""

from repro.obs.config import TelemetryConfig, resolve_telemetry
from repro.obs.kernelstats import KernelStats, sweep_kernel_stats
from repro.obs.ledger import CostLedger, LedgerDiff, ledger_from_report
from repro.obs.spans import Span, SpanRecorder, span

__all__ = [
    "TelemetryConfig",
    "resolve_telemetry",
    "KernelStats",
    "sweep_kernel_stats",
    "CostLedger",
    "LedgerDiff",
    "ledger_from_report",
    "Span",
    "SpanRecorder",
    "span",
]
