"""Observability layer: cost-attribution ledger, forecast calibration,
decision provenance, span profiler, kernel stats.

Five layers, all strictly outside the traced planning core (rules
R2/R7):

- ``obs.ledger`` — :class:`~repro.obs.ledger.CostLedger`, the per-week x
  per-pool x per-source billing decomposition materialized from a
  telemetry-enabled rolling replay; JSONL export, ``diff`` comparator,
  unit-economics summaries.
- ``obs.calibration`` — :class:`~repro.obs.calibration.CalibrationCube`,
  the per (week x pool x fractile) forecast-calibration scores (hit
  coverage vs nominal, pinball loss, band widths) scored against the
  demand the scan billed; same JSONL round-trip + ``diff`` guarantees.
- ``obs.provenance`` — :class:`~repro.obs.provenance.DecisionLog`, the
  queryable per-week decision record (buys per SKU, roll-offs, binding
  constraints) answering "why does week w hold this stack".
- ``obs.spans`` — :class:`~repro.obs.spans.SpanRecorder`, the sanctioned
  caller-side wall clock (compile / execute / host phases).
- ``obs.kernelstats`` — :class:`~repro.obs.kernelstats.KernelStats` for
  the Pallas commitment-sweep launch shapes.

Enable per request: ``api.PlanRequest(..., telemetry=True)`` or
``telemetry=obs.TelemetryConfig(calibration=True, provenance=True)``;
``telemetry=None`` (the default) keeps every plan path bit-identical.
``python -m repro.obs`` reports/diffs exported ledgers and calibration
cubes.
"""

from repro.obs.calibration import (
    CalibrationCube,
    CalibrationDiff,
    calibration_from_arrays,
)
from repro.obs.config import TelemetryConfig, resolve_telemetry
from repro.obs.kernelstats import KernelStats, sweep_kernel_stats
from repro.obs.ledger import CostLedger, LedgerDiff, ledger_from_report
from repro.obs.provenance import DecisionLog, decision_log_from_arrays
from repro.obs.spans import Span, SpanRecorder, span

__all__ = [
    "TelemetryConfig",
    "resolve_telemetry",
    "KernelStats",
    "sweep_kernel_stats",
    "CostLedger",
    "LedgerDiff",
    "ledger_from_report",
    "CalibrationCube",
    "CalibrationDiff",
    "calibration_from_arrays",
    "DecisionLog",
    "decision_log_from_arrays",
    "Span",
    "SpanRecorder",
    "span",
]
