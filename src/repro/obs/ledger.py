"""Cost-attribution ledger: the rolling replay's bill, decomposed.

A :class:`CostLedger` is a dense (week x entity x source) spend cube
materialized from a telemetry-enabled ``RollingPlanReport``:

    entities   the P planned pools ("cloud/region/family") plus, when the
               convertible band is on, one "cloud:<name>" pseudo-entity
               per cloud — convertible tranches bill at cloud level and
               are re-pinned weekly, so attributing them to a single pool
               would be fiction; the ledger bills them where the invoice
               does and reconciliation stays exact.
    sources    "commit:<sku>" per standard SKU band, "on_demand"
               overflow, the spot band split into "spot_market" /
               "spot_requeue" (the priced requeue penalty) /
               "spot_fallback" (the unavailable-capacity on-demand
               share), and "convertible:<sku>" per convertible SKU.

All arithmetic is float64 over arrays the scan itself emitted (per-SKU
committed spend, usage hours, on-demand volume — see
``core.replan``'s telemetry outputs), so ledger row-sums reconcile with
``RollingPlanReport.weekly_cost()`` to float32 machine precision: the
only divergence is f32-in-scan vs f64-here summation order, ~1e-7
relative (:meth:`CostLedger.reconcile` enforces 1e-5).

On scenario-batched reports the ledger covers **scenario 0** — the
realized trace — by default, matching the tranche books;
``ledger_from_report(report, scenario=k)`` bills any sampled future
instead, and :meth:`CostLedger.reconcile` then compares against
``weekly_cost[:, k]`` automatically.

This module imports only numpy: it duck-types the report (core imports
obs, never the reverse), so it can also round-trip ledgers from JSONL in
environments where the planner never loads.
"""

from __future__ import annotations

import dataclasses
import json

import numpy as np

SCHEMA_VERSION = 1
HOURS_PER_WEEK = 168


def _sview(a, nd: int, scenario: int = 0):
    """Scenario-``scenario`` view of a per-week report array: batched
    reports carry an N axis at position 1 (nd is the unbatched rank)."""
    if a is None:
        return None
    a = np.asarray(a)
    return a if a.ndim == nd else a[:, scenario]


@dataclasses.dataclass
class CostLedger:
    """Per-week x per-entity x per-source billing decomposition."""

    weeks: np.ndarray            # (S,) absolute week indices
    entities: tuple[str, ...]    # (E,) pools then cloud pseudo-entities
    sources: tuple[str, ...]     # (M,) billing sources
    cost: np.ndarray             # (S, E, M) spend, float64
    volume: np.ndarray           # (S, E, M) attributed chip-hours
    used_hours: np.ndarray       # (S, E) demand served under the level
    idle_hours: np.ndarray       # (S, E) committed-but-unused chip-hours
    utilization: np.ndarray      # (S, E) used / committed chip-hours
    meta: dict = dataclasses.field(default_factory=dict)

    # -- selection ---------------------------------------------------------

    def _sel(self, week, pool, sku, source):
        wsel = np.ones(len(self.weeks), bool)
        if week is not None:
            wsel = self.weeks == week
            if not wsel.any():
                raise KeyError(f"week {week} not in ledger "
                               f"({self.weeks[0]}..{self.weeks[-1]})")
        esel = np.ones(len(self.entities), bool)
        if pool is not None:
            esel = np.asarray([e == pool for e in self.entities])
            if not esel.any():
                raise KeyError(f"unknown entity {pool!r}")
        msel = np.ones(len(self.sources), bool)
        if sku is not None:
            wanted = {sku, f"commit:{sku}", f"convertible:{sku}"}
            msel = np.asarray([s in wanted for s in self.sources])
            if not msel.any():
                raise KeyError(f"unknown sku {sku!r}")
        if source is not None:
            msel = msel & np.asarray([s == source for s in self.sources])
            if not msel.any():
                raise KeyError(f"unknown source {source!r}")
        return wsel, esel, msel

    def attribute(self, *, week=None, pool=None, sku=None,
                  source=None) -> float:
        """Spend for any (week, pool, sku/source) slice; None = marginal.

        ``attribute()`` with no selector is the grand total;
        ``attribute(week=30, pool="aws/us-east-1/c7", sku="3yr_all")``
        is one cell of the bill."""
        wsel, esel, msel = self._sel(week, pool, sku, source)
        return float(self.cost[np.ix_(wsel, esel, msel)].sum())

    def volume_of(self, *, week=None, pool=None, sku=None,
                  source=None) -> float:
        """Chip-hours for the same selectors as :meth:`attribute`."""
        wsel, esel, msel = self._sel(week, pool, sku, source)
        return float(self.volume[np.ix_(wsel, esel, msel)].sum())

    # -- summaries ---------------------------------------------------------

    @property
    def total(self) -> float:
        return float(self.cost.sum())

    def weekly_totals(self) -> np.ndarray:
        """(S,) all-source all-entity spend per week — the reconciliation
        row-sums."""
        return self.cost.sum(axis=(1, 2))

    def by_source(self) -> dict[str, float]:
        tot = self.cost.sum(axis=(0, 1))
        return {s: float(t) for s, t in zip(self.sources, tot)}

    def by_entity(self) -> dict[str, float]:
        tot = self.cost.sum(axis=(0, 2))
        return {e: float(t) for e, t in zip(self.entities, tot)}

    def unit_economics(self) -> dict:
        """The waste/efficiency summary the serving-loop roadmap item
        reports in: where the money went, how much bought capacity sat
        idle, and what a served chip-hour actually cost."""
        by = self.by_source()
        committed = sum(v for s, v in by.items() if s.startswith("commit:"))
        conv = sum(v for s, v in by.items()
                   if s.startswith("convertible:"))
        spot = sum(v for s, v in by.items() if s.startswith("spot_"))
        commit_srcs = [
            i for i, s in enumerate(self.sources)
            if s.startswith(("commit:", "convertible:"))
        ]
        committed_hours = float(self.volume[:, :, commit_srcs].sum())
        used = float(self.used_hours.sum())
        idle = float(self.idle_hours.sum())
        # Utilization is a pool-level quantity; cloud pseudo-entities
        # carry none (their capacity bills where it is re-pinned).
        p_n = self.meta.get("num_pools", len(self.entities))
        return {
            "total_cost": self.total,
            "committed_cost": committed,
            "convertible_cost": conv,
            "on_demand_cost": by.get("on_demand", 0.0),
            "spot_cost": spot,
            "committed_chip_hours": committed_hours,
            "used_chip_hours": used,
            "idle_committed_hours": idle,
            "idle_fraction": (
                idle / committed_hours if committed_hours > 0 else 0.0
            ),
            "utilization_mean": float(self.utilization[:, :p_n].mean()),
            # Zero served hours (a fleet that only ever idled) must not
            # poison downstream aggregation with inf/NaN: report 0.0 and
            # flag the degenerate case instead.
            "cost_per_used_chip_hour": (
                self.total / used if used > 0 else 0.0
            ),
            "idle_only": bool(used <= 0.0),
        }

    # -- reconciliation ----------------------------------------------------

    def reconcile(
        self, report, *, rtol: float = 1e-5,
        scenario: "int | None" = None,
    ) -> dict:
        """Check ledger row-sums against ``report.weekly_cost`` week by
        week.  The ledger re-sums the scan's own f32 billing terms in
        f64, so the residual is pure summation-order noise — ``max_rel``
        lands around 1e-7 and the default 1e-5 gate (f32 machine
        precision across a K-term sum) is generous.

        ``scenario`` picks which column of a batched report's (S, N)
        weekly cost to reconcile against; the default is the scenario
        this ledger was materialized from (``meta["scenario"]``, 0 for
        pre-scenario ledgers), so a ``ledger_from_report(rep, scenario=k)``
        ledger reconciles against its own scenario automatically."""
        k = (
            int(self.meta.get("scenario", 0))
            if scenario is None else int(scenario)
        )
        wc = np.asarray(report.weekly_cost, np.float64)
        if wc.ndim == 2:           # scenario-batched: slice the N axis
            if not 0 <= k < wc.shape[1]:
                raise ValueError(
                    f"scenario index {k} out of range for a report of "
                    f"{wc.shape[1]} scenario(s)"
                )
            wc = wc[:, k]
        elif k != 0:
            raise ValueError(
                f"scenario index {k} out of range for an unbatched report"
            )
        mine = self.weekly_totals()
        if mine.shape != wc.shape:
            raise ValueError(
                f"week axes disagree: ledger {mine.shape}, "
                f"report {wc.shape}"
            )
        err = np.abs(mine - wc)
        rel = err / np.maximum(np.abs(wc), 1.0)
        return {
            "ok": bool(rel.max() <= rtol),
            "rtol": rtol,
            "scenario": k,
            "max_abs": float(err.max()),
            "max_rel": float(rel.max()),
            "worst_week": int(self.weeks[int(rel.argmax())]),
            "total_ledger": float(mine.sum()),
            "total_report": float(wc.sum()),
        }

    # -- serialization -----------------------------------------------------

    def to_jsonl(self, path: str) -> None:
        """Header line, then one row per nonzero (week, entity, source)
        cell, then one usage line per (week, entity)."""
        with open(path, "w") as f:
            f.write(json.dumps({
                "kind": "header",
                "schema_version": SCHEMA_VERSION,
                "weeks": [int(w) for w in self.weeks],
                "entities": list(self.entities),
                "sources": list(self.sources),
                "meta": self.meta,
            }) + "\n")
            nz = np.argwhere((self.cost != 0) | (self.volume != 0))
            for si, ei, mi in nz:
                f.write(json.dumps({
                    "kind": "row",
                    "week": int(self.weeks[si]),
                    "entity": self.entities[ei],
                    "source": self.sources[mi],
                    "cost": float(self.cost[si, ei, mi]),
                    "volume": float(self.volume[si, ei, mi]),
                }) + "\n")
            for si in range(len(self.weeks)):
                for ei in range(len(self.entities)):
                    f.write(json.dumps({
                        "kind": "usage",
                        "week": int(self.weeks[si]),
                        "entity": self.entities[ei],
                        "used_hours": float(self.used_hours[si, ei]),
                        "idle_hours": float(self.idle_hours[si, ei]),
                        "utilization": float(self.utilization[si, ei]),
                    }) + "\n")

    @classmethod
    def from_jsonl(cls, path: str) -> "CostLedger":
        with open(path) as f:
            header = json.loads(f.readline())
            if header.get("kind") != "header":
                raise ValueError(f"{path}: first line is not a ledger "
                                 "header")
            if header["schema_version"] != SCHEMA_VERSION:
                raise ValueError(
                    f"{path}: schema v{header['schema_version']} != "
                    f"v{SCHEMA_VERSION}"
                )
            weeks = np.asarray(header["weeks"])
            entities = tuple(header["entities"])
            sources = tuple(header["sources"])
            widx = {int(w): i for i, w in enumerate(weeks)}
            eidx = {e: i for i, e in enumerate(entities)}
            midx = {s: i for i, s in enumerate(sources)}
            shape = (len(weeks), len(entities), len(sources))
            led = cls(
                weeks=weeks, entities=entities, sources=sources,
                cost=np.zeros(shape), volume=np.zeros(shape),
                used_hours=np.zeros(shape[:2]),
                idle_hours=np.zeros(shape[:2]),
                utilization=np.zeros(shape[:2]),
                meta=header.get("meta", {}),
            )
            for line in f:
                rec = json.loads(line)
                si, ei = widx[rec["week"]], eidx[rec["entity"]]
                if rec["kind"] == "row":
                    mi = midx[rec["source"]]
                    led.cost[si, ei, mi] = rec["cost"]
                    led.volume[si, ei, mi] = rec["volume"]
                elif rec["kind"] == "usage":
                    led.used_hours[si, ei] = rec["used_hours"]
                    led.idle_hours[si, ei] = rec["idle_hours"]
                    led.utilization[si, ei] = rec["utilization"]
        return led

    # -- regression comparison ---------------------------------------------

    def diff(self, other: "CostLedger") -> "LedgerDiff":
        """``self - other`` as a regression comparator: per-source totals
        and per-(entity, source) spend movers, aligned on the union of
        axes (a week/entity/source absent on one side contributes 0)."""
        def cells(led):
            out: dict[tuple[str, str], float] = {}
            tot = led.cost.sum(axis=0)
            for ei, e in enumerate(led.entities):
                for mi, s in enumerate(led.sources):
                    if tot[ei, mi] != 0.0:
                        out[(e, s)] = float(tot[ei, mi])
            return out

        a, b = cells(self), cells(other)
        keys = sorted(set(a) | set(b))
        deltas = {k: a.get(k, 0.0) - b.get(k, 0.0) for k in keys}
        by_source: dict[str, float] = {}
        for (_, s), d in deltas.items():
            by_source[s] = by_source.get(s, 0.0) + d
        return LedgerDiff(
            total_a=self.total, total_b=other.total,
            total_delta=self.total - other.total,
            max_abs_delta=max(
                (abs(d) for d in deltas.values()), default=0.0
            ),
            by_source=by_source,
            cell_deltas=deltas,
        )


@dataclasses.dataclass
class LedgerDiff:
    """Spend deltas between two ledgers (A - B)."""

    total_a: float
    total_b: float
    total_delta: float
    max_abs_delta: float
    by_source: dict[str, float]
    cell_deltas: dict[tuple[str, str], float]

    def top_movers(self, n: int = 10) -> list[tuple[str, str, float]]:
        """The n largest |spend delta| (entity, source) cells.  Zero
        deltas are dropped BEFORE ranking so an empty or all-equal diff
        returns [] instead of zero-padded rows."""
        movers = [
            (e, s, d) for (e, s), d in self.cell_deltas.items()
            if d != 0.0
        ]
        movers.sort(key=lambda t: -abs(t[2]))
        return movers[:n]

    def to_dict(self) -> dict:
        return {
            "total_a": self.total_a,
            "total_b": self.total_b,
            "total_delta": self.total_delta,
            "max_abs_delta": self.max_abs_delta,
            "by_source": self.by_source,
            "top_movers": [
                {"entity": e, "source": s, "delta": d}
                for e, s, d in self.top_movers()
            ],
        }

    def report(self) -> str:
        lines = [
            f"total: {self.total_a:,.2f} vs {self.total_b:,.2f} "
            f"(delta {self.total_delta:+,.2f})",
            "by source:",
        ]
        for s, d in sorted(self.by_source.items(), key=lambda kv: kv[0]):
            lines.append(f"  {s:24s} {d:+14.2f}")
        movers = self.top_movers()
        if movers:
            lines.append("top movers:")
            for e, s, d in movers:
                lines.append(f"  {e:28s} {s:24s} {d:+14.2f}")
        return "\n".join(lines)


def ledger_from_report(report, *, scenario: int = 0) -> CostLedger:
    """Materialize the ledger off a telemetry-enabled rolling report.

    Needs the scan's telemetry outputs (``committed_by_sku``,
    ``used_hours``, ``od_volume``); a report replayed with
    ``telemetry=None`` has none and raises.  ``scenario`` slices the N
    axis of a scenario-batched report the way ``replay_spot_plan``'s
    ``scenario=`` does — the default 0 is the realized trace; nonzero
    indices bill one sampled future."""
    if getattr(report, "committed_by_sku", None) is None:
        raise ValueError(
            "report carries no telemetry outputs — re-run the plan with "
            "telemetry=True (or a TelemetryConfig) to build a CostLedger"
        )
    n = int(getattr(report, "n_scenarios", 1) or 1)
    if not 0 <= scenario < n:
        raise ValueError(
            f"scenario index {scenario} out of range for a report of "
            f"{n} scenario(s)"
        )

    def _sv(a, nd):
        return _sview(a, nd, scenario)

    weeks = np.asarray(report.weeks)
    s_n = len(weeks)
    pool_names = ["/".join(k) for k in report.keys]
    p_n, k_n = len(pool_names), len(report.options)
    entities = list(pool_names)
    sources = [f"commit:{o.name}" for o in report.options] + ["on_demand"]
    has_spot = report.spot_cost is not None
    if has_spot:
        sources += ["spot_market", "spot_requeue", "spot_fallback"]
    has_conv = report.conv_committed_cost is not None
    if has_conv:
        entities += [f"cloud:{c}" for c in report.conv_clouds]
        sources += [f"convertible:{o.name}" for o in report.conv_options]

    e_n, m_n = len(entities), len(sources)
    cost = np.zeros((s_n, e_n, m_n))
    volume = np.zeros((s_n, e_n, m_n))
    src_i = {s: i for i, s in enumerate(sources)}

    # Standard commitment bands: the scan's own per-SKU weekly spend.
    committed_k = _sv(report.committed_by_sku, 3).astype(np.float64)
    active = _sv(report.active, 3).astype(np.float64)
    cost[:, :p_n, :k_n] = committed_k
    volume[:, :p_n, :k_n] = active * HOURS_PER_WEEK

    # On-demand overflow: the report arrays verbatim.
    od_cost = _sv(report.on_demand_cost, 2).astype(np.float64)
    cost[:, :p_n, src_i["on_demand"]] = od_cost
    od_vol = _sv(report.od_volume, 2)
    if od_vol is not None:
        volume[:, :p_n, src_i["on_demand"]] = od_vol

    level = active.sum(-1)
    if has_spot:
        # Decompose the effective spot rate back into its pricing terms:
        #   rate = a * (market + hazard * requeue_hours * od) + (1-a) * od
        # (see ``core.spot.effective_spot_rate``) — fallback is the
        # unavailability share billed at on-demand, requeue the priced
        # preemption penalty, market the residual so the three sum to the
        # reported spot spend exactly.
        lines = report.spot_lines
        a = np.asarray(lines.availability, np.float64)
        hazard = np.asarray(lines.params.hazard, np.float64)
        if a.shape[0] == n * p_n:
            # Batched replays keep spot lines per flattened (N x P) row;
            # take this scenario's block to match the (S, P) views above.
            a = a[scenario * p_n:(scenario + 1) * p_n]
            hazard = hazard[scenario * p_n:(scenario + 1) * p_n]
        od = float(report.od_rate)
        rq = float(report.spot_config.requeue_hours)
        vol = _sv(report.spot_volume, 2).astype(np.float64)
        spot_cost = _sv(report.spot_cost, 2).astype(np.float64)
        fallback = (1.0 - a)[None, :] * od * vol
        requeue = (a * hazard)[None, :] * rq * od * vol
        market = spot_cost - fallback - requeue
        cost[:, :p_n, src_i["spot_market"]] = market
        cost[:, :p_n, src_i["spot_requeue"]] = requeue
        cost[:, :p_n, src_i["spot_fallback"]] = fallback
        volume[:, :p_n, src_i["spot_market"]] = vol

    if has_conv:
        conv_k = _sv(report.conv_committed_by_sku, 3).astype(np.float64)
        conv_active = _sv(report.conv_active, 3).astype(np.float64)
        for ci in range(len(report.conv_clouds)):
            for ki, o in enumerate(report.conv_options):
                mi = src_i[f"convertible:{o.name}"]
                cost[:, p_n + ci, mi] = conv_k[:, ci, ki]
                volume[:, p_n + ci, mi] = (
                    conv_active[:, ci, ki] * HOURS_PER_WEEK
                )
        # A pool's effective level includes its re-pinned allocation.
        level = level + _sv(report.conv_alloc, 2).astype(np.float64)

    used = np.zeros((s_n, e_n))
    idle = np.zeros((s_n, e_n))
    util = np.zeros((s_n, e_n))
    used[:, :p_n] = _sv(report.used_hours, 2)
    idle[:, :p_n] = np.maximum(level * HOURS_PER_WEEK - used[:, :p_n], 0.0)
    util[:, :p_n] = _sv(report.utilization, 2)

    meta = {
        "policy": report.policy_name,
        "cadence_weeks": int(report.cadence_weeks),
        "start_weeks": int(report.start_weeks),
        "horizon_weeks": int(report.horizon_weeks),
        "od_rate": float(report.od_rate),
        "n_scenarios": int(report.n_scenarios),
        "scenario": int(scenario),
        "num_pools": p_n,
    }
    if getattr(report, "kernel_stats", None) is not None:
        meta["kernel_stats"] = report.kernel_stats.to_dict()
    return CostLedger(
        weeks=weeks, entities=tuple(entities), sources=tuple(sources),
        cost=cost, volume=volume,
        used_hours=used, idle_hours=idle, utilization=util,
        meta=meta,
    )
