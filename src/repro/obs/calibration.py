"""Forecast-calibration telemetry: did the weekly fractile bands cover
realized demand?

The chance-constrained machinery (spot caps today, the planned
overcommitment layer) prices risk off the weekly forecast fractiles; an
uncalibrated band makes those constraints fiction.  With
``TelemetryConfig(calibration=True)`` the rolling scan emits each week's
forecast fractile levels (``core.forecast.anchored_fractile_levels`` —
trailing-window empirical quantiles, the deployed band) and this module
scores them host-side against the demand the scan actually billed:

    hits[s, n, p, q]     share of week s's 168 realized hours at or below
                         the q-fractile level — the per-cell coverage
                         indicator (a calibrated band has E[hit] == q)
    pinball[s, n, p, q]  pinball (quantile) loss of the level against the
                         realized hours — the proper score for fractiles

materialized as a :class:`CalibrationCube` with empirical-vs-nominal
coverage, interval widths, a ``diff()`` regression comparator, an exact
JSONL round-trip (same guarantee as the cost ledger's), and the
``python -m repro.obs calib`` CLI gate.

Scenario-batched replays score every scenario out of the ONE scan: the
cube carries an N axis, so per-scenario-family calibration distributions
(``scenario_coverage()``) come for free next to the pooled summary.

All arithmetic is float64 numpy over arrays the scan emitted; this module
imports only numpy (core imports obs, never the reverse).
"""

from __future__ import annotations

import dataclasses
import json

import numpy as np

SCHEMA_VERSION = 1
HOURS_PER_WEEK = 168


@dataclasses.dataclass
class CalibrationCube:
    """Per (week x scenario x pool x fractile) forecast-calibration scores.

    Axes: ``weeks`` (S,) absolute week indices, scenario axis N (1 on
    unbatched replays), ``entities`` (P,) pool names, ``fractiles`` (Q,)
    nominal coverage levels."""

    weeks: np.ndarray             # (S,)
    entities: tuple[str, ...]     # (P,)
    fractiles: tuple[float, ...]  # (Q,)
    levels: np.ndarray            # (S, N, P, Q) forecast fractile levels
    hits: np.ndarray              # (S, N, P, Q) in-week coverage share
    pinball: np.ndarray           # (S, N, P, Q) pinball loss, float64
    realized_mean: np.ndarray     # (S, N, P) realized weekly mean demand
    realized_peak: np.ndarray     # (S, N, P) realized weekly peak demand
    meta: dict = dataclasses.field(default_factory=dict)

    # -- shape -------------------------------------------------------------

    @property
    def n_scenarios(self) -> int:
        return int(self.levels.shape[1])

    def _scen(self, scenario: "int | None") -> np.ndarray:
        """Hit cube restricted to one scenario, or all pooled."""
        if scenario is None:
            return self.hits
        n = self.n_scenarios
        if not 0 <= scenario < n:
            raise ValueError(
                f"scenario index {scenario} out of range for a cube of "
                f"{n} scenario(s)"
            )
        return self.hits[:, scenario:scenario + 1]

    # -- coverage ----------------------------------------------------------

    def coverage(self, scenario: "int | None" = None) -> np.ndarray:
        """(Q,) empirical coverage per fractile: mean hit share over weeks
        x pools (x scenarios when ``scenario`` is None) — a calibrated
        band lands on the nominal fractile."""
        return self._scen(scenario).mean(axis=(0, 1, 2))

    def coverage_error(self, scenario: "int | None" = None) -> np.ndarray:
        """(Q,) signed empirical - nominal coverage."""
        return self.coverage(scenario) - np.asarray(self.fractiles)

    @property
    def max_coverage_drift(self) -> float:
        """max_q |empirical - nominal| pooled over every scenario — the
        scalar the ``--fail-above`` CLI gate compares."""
        return float(np.abs(self.coverage_error()).max())

    def scenario_coverage(self) -> np.ndarray:
        """(N, Q) per-scenario empirical coverage — the per-family
        calibration distribution a batched replay yields from one scan."""
        return self.hits.mean(axis=(0, 2))

    def interval_width(
        self, lo: "float | None" = None, hi: "float | None" = None
    ) -> float:
        """Mean forecast-band width between two carried fractiles
        (default: the outermost pair) in demand units."""
        lo = self.fractiles[0] if lo is None else lo
        hi = self.fractiles[-1] if hi is None else hi
        qi = {q: i for i, q in enumerate(self.fractiles)}
        if lo not in qi or hi not in qi:
            raise KeyError(
                f"fractile pair ({lo}, {hi}) not carried; cube has "
                f"{self.fractiles}"
            )
        return float(
            (self.levels[..., qi[hi]] - self.levels[..., qi[lo]]).mean()
        )

    def pinball_mean(self) -> np.ndarray:
        """(Q,) mean pinball loss per fractile over all cells."""
        return self.pinball.mean(axis=(0, 1, 2))

    def summary(self) -> dict:
        cov = self.coverage()
        err = self.coverage_error()
        worst = int(np.abs(err).argmax())
        out = {
            "weeks": int(len(self.weeks)),
            "entities": int(len(self.entities)),
            "n_scenarios": self.n_scenarios,
            "fractiles": list(self.fractiles),
            "coverage": [float(c) for c in cov],
            "coverage_error": [float(e) for e in err],
            "max_coverage_drift": self.max_coverage_drift,
            "worst_fractile": float(self.fractiles[worst]),
            "pinball_mean": [float(p) for p in self.pinball_mean()],
            "interval_width": self.interval_width(),
        }
        out.update({k: v for k, v in self.meta.items()
                    if k in ("policy", "scenario_family")})
        return out

    def report(self) -> str:
        lines = [
            f"calibration: {len(self.weeks)} weeks x "
            f"{len(self.entities)} pools x {self.n_scenarios} scenario(s)",
            f"{'fractile':>10s} {'coverage':>10s} {'error':>9s} "
            f"{'pinball':>12s}",
        ]
        cov, err, pb = (
            self.coverage(), self.coverage_error(), self.pinball_mean()
        )
        for i, q in enumerate(self.fractiles):
            lines.append(
                f"{q:10.3f} {cov[i]:10.3f} {err[i]:+9.3f} {pb[i]:12.4f}"
            )
        lines.append(
            f"max |coverage drift| {self.max_coverage_drift:.4f}; "
            f"mean band width {self.interval_width():.3f}"
        )
        return "\n".join(lines)

    # -- regression comparison ---------------------------------------------

    def diff(self, other: "CalibrationCube") -> "CalibrationDiff":
        """``self - other`` as a regression comparator on the pooled
        per-fractile coverage and pinball scores (cubes must carry the
        same fractile set; week/pool axes may differ)."""
        if tuple(self.fractiles) != tuple(other.fractiles):
            raise ValueError(
                f"fractile axes disagree: {self.fractiles} vs "
                f"{other.fractiles}"
            )
        cov_d = self.coverage() - other.coverage()
        pb_d = self.pinball_mean() - other.pinball_mean()
        return CalibrationDiff(
            fractiles=tuple(self.fractiles),
            coverage_delta={
                float(q): float(d) for q, d in zip(self.fractiles, cov_d)
            },
            pinball_delta={
                float(q): float(d) for q, d in zip(self.fractiles, pb_d)
            },
            max_abs_coverage_delta=float(np.abs(cov_d).max()),
            drift_a=self.max_coverage_drift,
            drift_b=other.max_coverage_drift,
        )

    # -- serialization -----------------------------------------------------

    def to_jsonl(self, path: str) -> None:
        """Header line, then one row per (week, scenario, entity) cell
        carrying the full fractile vectors.  Floats serialize via json's
        repr round-trip, so ``from_jsonl`` is exact — the ledger's
        guarantee."""
        with open(path, "w") as f:
            f.write(json.dumps({
                "kind": "header",
                "schema_version": SCHEMA_VERSION,
                "weeks": [int(w) for w in self.weeks],
                "entities": list(self.entities),
                "fractiles": list(self.fractiles),
                "n_scenarios": self.n_scenarios,
                "meta": self.meta,
            }) + "\n")
            for si in range(len(self.weeks)):
                for ni in range(self.n_scenarios):
                    for ei in range(len(self.entities)):
                        f.write(json.dumps({
                            "kind": "row",
                            "week": int(self.weeks[si]),
                            "scenario": ni,
                            "entity": self.entities[ei],
                            "levels": [
                                float(v) for v in self.levels[si, ni, ei]
                            ],
                            "hits": [
                                float(v) for v in self.hits[si, ni, ei]
                            ],
                            "pinball": [
                                float(v) for v in self.pinball[si, ni, ei]
                            ],
                            "realized_mean": float(
                                self.realized_mean[si, ni, ei]
                            ),
                            "realized_peak": float(
                                self.realized_peak[si, ni, ei]
                            ),
                        }) + "\n")

    @classmethod
    def from_jsonl(cls, path: str) -> "CalibrationCube":
        with open(path) as f:
            header = json.loads(f.readline())
            if header.get("kind") != "header":
                raise ValueError(
                    f"{path}: first line is not a calibration header"
                )
            if header["schema_version"] != SCHEMA_VERSION:
                raise ValueError(
                    f"{path}: schema v{header['schema_version']} != "
                    f"v{SCHEMA_VERSION}"
                )
            weeks = np.asarray(header["weeks"])
            entities = tuple(header["entities"])
            fractiles = tuple(header["fractiles"])
            n = int(header["n_scenarios"])
            widx = {int(w): i for i, w in enumerate(weeks)}
            eidx = {e: i for i, e in enumerate(entities)}
            shape = (len(weeks), n, len(entities), len(fractiles))
            cube = cls(
                weeks=weeks, entities=entities, fractiles=fractiles,
                levels=np.zeros(shape), hits=np.zeros(shape),
                pinball=np.zeros(shape),
                realized_mean=np.zeros(shape[:3]),
                realized_peak=np.zeros(shape[:3]),
                meta=header.get("meta", {}),
            )
            for line in f:
                rec = json.loads(line)
                si = widx[rec["week"]]
                ni = rec["scenario"]
                ei = eidx[rec["entity"]]
                cube.levels[si, ni, ei] = rec["levels"]
                cube.hits[si, ni, ei] = rec["hits"]
                cube.pinball[si, ni, ei] = rec["pinball"]
                cube.realized_mean[si, ni, ei] = rec["realized_mean"]
                cube.realized_peak[si, ni, ei] = rec["realized_peak"]
        return cube


@dataclasses.dataclass
class CalibrationDiff:
    """Calibration deltas between two cubes (A - B)."""

    fractiles: tuple[float, ...]
    coverage_delta: dict[float, float]
    pinball_delta: dict[float, float]
    max_abs_coverage_delta: float
    drift_a: float
    drift_b: float

    def to_dict(self) -> dict:
        return {
            "fractiles": list(self.fractiles),
            "coverage_delta": {
                str(q): d for q, d in self.coverage_delta.items()
            },
            "pinball_delta": {
                str(q): d for q, d in self.pinball_delta.items()
            },
            "max_abs_coverage_delta": self.max_abs_coverage_delta,
            "drift_a": self.drift_a,
            "drift_b": self.drift_b,
        }

    def report(self) -> str:
        lines = [
            f"coverage drift: A {self.drift_a:.4f} vs B {self.drift_b:.4f}",
            f"{'fractile':>10s} {'d-coverage':>11s} {'d-pinball':>12s}",
        ]
        for q in self.fractiles:
            lines.append(
                f"{q:10.3f} {self.coverage_delta[q]:+11.4f} "
                f"{self.pinball_delta[q]:+12.4f}"
            )
        lines.append(
            f"max |coverage delta| {self.max_abs_coverage_delta:.4f}"
        )
        return "\n".join(lines)


def calibration_from_arrays(
    weeks,
    entities,
    fractiles,
    levels,
    realized,
    *,
    n_scenarios: int = 1,
    meta: "dict | None" = None,
) -> CalibrationCube:
    """Score scan-emitted fractile ``levels`` (S, N*P, Q) against the
    ``realized`` weekly demand hours (S, N*P, H) and build the cube.

    Called by ``core.replan`` with plain arrays (obs never imports core);
    all scoring runs in float64 so the cube is exactly reproducible from
    its JSONL export."""
    levels = np.asarray(levels, np.float64)
    realized = np.asarray(realized, np.float64)
    s_n, r_n, q_n = levels.shape
    if realized.shape[:2] != (s_n, r_n):
        raise ValueError(
            f"levels {levels.shape} and realized {realized.shape} "
            "disagree on (weeks, rows)"
        )
    p_n = r_n // n_scenarios
    if p_n * n_scenarios != r_n or p_n != len(entities):
        raise ValueError(
            f"{r_n} rows do not factor into {n_scenarios} scenario(s) x "
            f"{len(entities)} entities"
        )
    q = np.asarray(fractiles, np.float64)
    d = realized[:, :, :, None]                      # (S, R, H, 1)
    lv = levels[:, :, None, :]                       # (S, R, 1, Q)
    hits = (d <= lv).mean(axis=2)                    # (S, R, Q)
    over = np.maximum(d - lv, 0.0)
    under = np.maximum(lv - d, 0.0)
    pinball = (q * over + (1.0 - q) * under).mean(axis=2)

    def cube_axes(a):                                # (S, R, ...) -> (S, N, P, ...)
        return a.reshape(s_n, n_scenarios, p_n, *a.shape[2:])

    return CalibrationCube(
        weeks=np.asarray(weeks),
        entities=tuple(entities),
        fractiles=tuple(float(v) for v in fractiles),
        levels=cube_axes(levels),
        hits=cube_axes(hits),
        pinball=cube_axes(pinball),
        realized_mean=cube_axes(realized.mean(axis=-1)),
        realized_peak=cube_axes(realized.max(axis=-1)),
        meta=dict(meta or {}),
    )
