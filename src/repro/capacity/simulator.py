"""Fleet simulator: turns (training jobs + serving fleets) into chip-demand
traces, then runs the paper's full §3 pipeline against them.

This is where the Shaved Ice technique becomes a first-class framework
feature: the training runtime reports chips-per-job, the serving runtime
reports chips-per-replica x autoscaled replica counts, the simulator rolls
them into an hourly chip-demand series, and the planner (core.planner)
prices commitments for the fleet.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import demand as dm
from repro.core import planner as pl
from repro.core import portfolio as pf
from repro.core import timeshift as ts
from repro.capacity import generations as gn
from repro.capacity import preemption as pe
from repro.capacity import pricing
from repro.capacity.pricing import on_demand_premium
from repro.data import scenarios as sc
from repro.models.model import build

pricing.validate_tables()


@dataclasses.dataclass(frozen=True)
class ServingFleet:
    """A served architecture: replicas autoscale with request demand.

    ``pool`` pins the fleet's chips to one (cloud, region, machine-family)
    pool — the granularity commitments are actually purchased at (§6).
    None falls back to a deterministic slot in the default pool catalog."""

    arch: str
    chips_per_replica: int
    tokens_per_sec_per_replica: float
    base_requests_per_hour: float
    demand_cfg: dm.DemandConfig = dataclasses.field(
        default_factory=lambda: dm.DemandConfig(base_level=1.0)
    )
    pool: dm.PoolKey | None = None


@dataclasses.dataclass(frozen=True)
class TrainingJob:
    """A scheduled training run: a block of chips for a window of hours."""

    arch: str
    chips: int
    start_hour: int
    duration_hours: int
    deferrable: bool = False
    deadline_slack_hours: int = 0
    pool: dm.PoolKey | None = None


def default_pool_catalog() -> list[dm.PoolKey]:
    """12 (cloud, region, machine-family) pools drawn from the Table-2 SKUs
    — the pool granularity the released dataset keys demand by, so fleet
    plans can answer per-cloud/per-region commitment questions."""
    regions = ["region_0", "region_1", "region_2", "region_3"]
    plans = list(pricing.SAVINGS_PLANS)
    catalog = [
        (p.cloud, regions[i % len(regions)], p.family)
        for i, p in enumerate(plans)
    ]
    catalog += [
        (p.cloud, regions[(i + 1) % len(regions)], p.family)
        for i, p in enumerate(plans[:4])
    ]
    return catalog


def default_fleet() -> tuple[list[ServingFleet], list[TrainingJob]]:
    """A fleet spanning the assigned architectures: chips-per-replica scales
    with parameter count (bf16 weights + KV/state under ~12 GB/chip).
    Every fleet/job is pinned to a pool from the default catalog."""
    catalog = default_pool_catalog()
    fleets = []
    for i, arch in enumerate(sorted(configs.ARCHS)):
        n = build(configs.get(arch)).num_params()
        chips = max(1, int(np.ceil(n * 2 / (12 * 1024**3))))
        fleets.append(ServingFleet(
            arch=arch,
            chips_per_replica=chips,
            tokens_per_sec_per_replica=5e4 / chips,
            base_requests_per_hour=50.0 * chips,
            pool=catalog[i % len(catalog)],
        ))
    jobs = [
        TrainingJob("stablelm-1.6b", chips=64, start_hour=24 * 7,
                    duration_hours=24 * 5, pool=catalog[10]),
        TrainingJob("internlm2-20b", chips=256, start_hour=24 * 30,
                    duration_hours=24 * 14, pool=catalog[11]),
        TrainingJob("jamba-v0.1-52b", chips=512, start_hour=24 * 60,
                    duration_hours=24 * 21, pool=catalog[6]),
    ]
    return fleets, jobs


def fleet_pool_demand(
    fleets: list[ServingFleet],
    jobs: list[TrainingJob],
    num_hours: int,
    *,
    seed: int = 0,
    migration: "gn.MigrationConfig | bool | None" = None,
) -> dm.PoolSet:
    """Hourly chip demand of the fleet, attributed per pool.

    Each serving fleet / training job lands in its own (cloud, region,
    machine-family) pool instead of being summed into one series — the
    native shape for the batched planner.  Unpinned members fall back to a
    deterministic catalog slot so attribution is reproducible.

    ``migration`` runs the attributed demand through the hardware-
    generation turnover model (``capacity.generations``): wherever the
    catalog holds both an old family and its successor in one (cloud,
    region), demand volume transfers along the logistic adoption curve and
    the software-efficiency deflator acts on every pool.  ``None``
    (default) keeps attribution bit-identical to the pre-migration path."""
    import jax

    catalog = default_pool_catalog()
    per_pool: dict[dm.PoolKey, np.ndarray] = defaultdict(
        lambda: np.zeros(num_hours, np.float64)
    )
    for i, fl in enumerate(fleets):
        req = np.asarray(dm.synth_demand(
            num_hours, fl.demand_cfg, key=jax.random.PRNGKey(seed + i)
        ))
        req = req / req.mean() * fl.base_requests_per_hour
        # replicas needed to serve the request rate (ceil'd, autoscaled)
        replicas = np.ceil(req / 50.0)
        key = fl.pool if fl.pool is not None else catalog[i % len(catalog)]
        per_pool[tuple(key)] += replicas * fl.chips_per_replica
    for j, job in enumerate(jobs):
        lo = min(job.start_hour, num_hours)
        hi = min(job.start_hour + job.duration_hours, num_hours)
        key = job.pool if job.pool is not None else catalog[j % len(catalog)]
        per_pool[tuple(key)][lo:hi] += job.chips
    pools = dm.PoolSet.from_dict(dict(per_pool))
    mig = gn.resolve_migration(migration)
    if mig is not None:
        pools = gn.migrate_pool_set(pools, mig)
    return pools


def fleet_chip_demand(
    fleets: list[ServingFleet],
    jobs: list[TrainingJob],
    num_hours: int,
    *,
    seed: int = 0,
) -> np.ndarray:
    """Hourly total chip demand of the fleet — the aggregate view, i.e. the
    per-pool demand summed over pools (kept for single-level planning)."""
    return fleet_pool_demand(
        fleets, jobs, num_hours, seed=seed
    ).aggregate().astype(np.float64)


@dataclasses.dataclass
class FleetPlan:
    commitment: float
    on_demand_chip_hours: float
    unused_chip_hours: float
    committed_cost: float
    on_demand_cost: float
    total_cost: float
    all_on_demand_cost: float
    savings_vs_on_demand: float


def plan_fleet(
    demand: np.ndarray,
    *,
    horizon_weeks: int = 8,
    shiftable_frac: float = 0.0,
    portfolio: bool = False,
    options: "list[pf.PurchaseOption] | None" = None,
    term_weighting: float = 0.0,
):
    """Run Algorithm 1 on fleet demand; optionally time-shift the deferrable
    fraction into troughs first (§4) — the full paper pipeline.

    With ``portfolio=True`` the single averaged commitment is replaced by a
    stack of Table-2 purchasing options (returns a ``PortfolioFleetPlan``
    with per-option spend breakdown; see ``plan_fleet_portfolio``;
    ``term_weighting`` > 0 prices term-stranding risk and admits the 1y
    hedge bands onto the stack)."""
    if portfolio:
        return plan_fleet_portfolio(
            demand, horizon_weeks=horizon_weeks,
            shiftable_frac=shiftable_frac, options=options,
            term_weighting=term_weighting,
        )
    hist = jnp.asarray(demand[: -horizon_weeks * 168].astype(np.float32))
    res = pl.plan_commitment(hist, num_horizons=horizon_weeks)
    c = res.commitment

    actual = jnp.asarray(demand[-horizon_weeks * 168:].astype(np.float32))
    if shiftable_frac > 0:
        actual = ts.shift_demand(actual, c, shiftable_frac)

    premium = on_demand_premium()
    over = float(jnp.maximum(actual - c, 0.0).sum())
    under = float(jnp.maximum(c - actual, 0.0).sum())
    hours = actual.shape[0]
    committed_cost = c * hours            # committed rate = 1.0/chip-hour
    od_cost = premium * over
    all_od = premium * float(actual.sum())
    total = committed_cost + od_cost
    return FleetPlan(
        commitment=float(c),
        on_demand_chip_hours=over,
        unused_chip_hours=under,
        committed_cost=committed_cost,
        on_demand_cost=od_cost,
        total_cost=total,
        all_on_demand_cost=all_od,
        savings_vs_on_demand=1.0 - total / all_od,
    )


@dataclasses.dataclass
class PortfolioFleetPlan:
    """Fleet plan built from a stack of Table-2 purchasing options."""

    options: list[pf.PurchaseOption]
    widths: np.ndarray                  # (K,) committed band widths
    total_commitment: float             # stack top
    breakdown: dict[str, float]         # per-option committed spend (nonzero)
    committed_cost: float
    on_demand_cost: float
    total_cost: float
    all_on_demand_cost: float
    savings_vs_on_demand: float
    single_level_cost: float            # the single-level plan, same trace
    savings_vs_single_level: float


def plan_fleet_portfolio(
    demand: np.ndarray,
    *,
    horizon_weeks: int = 8,
    shiftable_frac: float = 0.0,
    options: list[pf.PurchaseOption] | None = None,
    term_weighting: float = 0.0,
) -> PortfolioFleetPlan:
    """§3 pipeline with the Table-2 purchase portfolio instead of one
    averaged commitment level: Algorithm 1 runs once per option term, the
    resulting stack is billed per option at its own committed rate, and the
    result is compared against both all-on-demand and the single-level
    ``plan_fleet`` on the same trace.

    Accounting note: rates are normalized so the mean 3y committed rate is
    1.0 — identical units to ``plan_fleet`` — so ``savings_vs_single_level``
    is an apples-to-apples statement about mixing SKUs (cheaper base-load
    rate + per-term thresholds) rather than a unit artifact."""
    options = options if options is not None else pf.options_from_pricing()
    premium = on_demand_premium()

    hist = jnp.asarray(demand[: -horizon_weeks * 168].astype(np.float32))
    res = pl.plan_portfolio(
        hist, options, num_horizons=horizon_weeks,
        od_rate=premium, term_weighting=term_weighting,
    )
    widths = np.asarray(res.widths)

    actual = jnp.asarray(demand[-horizon_weeks * 168:].astype(np.float32))
    single = plan_fleet(
        demand, horizon_weeks=horizon_weeks, shiftable_frac=shiftable_frac
    )
    if shiftable_frac > 0:
        actual = ts.shift_demand(actual, float(widths.sum()), shiftable_frac)

    spend = pf.portfolio_spend(actual, widths, options, od_rate=premium)
    breakdown = {
        o.name: float(c)
        for o, c in zip(options, spend.committed) if c > 0
    }
    return PortfolioFleetPlan(
        options=options,
        widths=widths,
        total_commitment=float(widths.sum()),
        breakdown=breakdown,
        committed_cost=float(spend.committed.sum()),
        on_demand_cost=spend.on_demand,
        total_cost=spend.total,
        all_on_demand_cost=spend.all_on_demand,
        savings_vs_on_demand=spend.savings_vs_on_demand,
        single_level_cost=single.total_cost,
        savings_vs_single_level=1.0 - spend.total / single.total_cost,
    )


def simulate_and_plan_pools(
    fleets: list[ServingFleet] | None = None,
    jobs: list[TrainingJob] | None = None,
    *,
    num_hours: int = 24 * 7 * 40,
    horizon_weeks: int = 8,
    seed: int = 0,
    demand_migration: "gn.MigrationConfig | bool | None" = None,
    **plan_kw,
) -> tuple[dm.PoolSet, pl.FleetPoolsPlan]:
    """One-call per-pool pipeline: attribute the (default) fleet's demand to
    its (cloud, region, machine-family) pools, then run the batched
    Algorithm-1 portfolio planner over the pool axis.  Returns the PoolSet
    alongside the plan so callers can inspect the traces that produced it.

    ``demand_migration`` is the *generative* turnover switch (demand
    volume actually moves between families); pass ``migration=`` in
    ``plan_kw`` to additionally make the planner migration-aware."""
    if fleets is None or jobs is None:
        d_fleets, d_jobs = default_fleet()
        fleets = d_fleets if fleets is None else fleets
        jobs = d_jobs if jobs is None else jobs
    pools = fleet_pool_demand(
        fleets, jobs, num_hours, seed=seed, migration=demand_migration
    )
    return pools, pl.plan_fleet_pools(
        pools, horizon_weeks=horizon_weeks, **plan_kw
    )


@dataclasses.dataclass
class SpotReplayReport:
    """A spot-enabled plan replayed against sampled revocation paths.

    The planner prices the spot band at an *expected* effective rate; this
    report is the realized counterpart: for ``num_draws`` Monte-Carlo
    revocation paths, demand routed above the spot floor is billed at the
    market spot price while the slice is up, falls back to on-demand while
    it is revoked, and pays the requeue/recompute penalty on every
    revocation of a serving slice.  ``availability`` is demand-weighted —
    1 - (spot demand-hours caught by a revoked slice) / (all demand-hours)
    — the quantity the chance constraint promises stays >= the target."""

    num_draws: int
    availability_target: float
    availability: np.ndarray        # (N, P) realized per draw per pool
    mean_availability: np.ndarray   # (P,) mean over draws
    fleet_availability: float       # demand-weighted, mean over draws
    meets_target: bool              # min over pools of mean availability
    shortfall_chip_hours: float     # mean over draws, fleet total
    planned_cost: float             # the plan's expected-rate bill
    realized_cost: float            # mean over draws
    realized_spot_cost: float       # market-price spot bill, mean
    fallback_on_demand_cost: float  # revoked-hours od fallback, mean
    requeue_cost: float             # recompute penalty, mean


def replay_spot_plan(
    pools: dm.PoolSet,
    report,
    *,
    num_draws: int = 32,
    seed: int = 0,
    scenario: int = 0,
) -> SpotReplayReport:
    """Replay a spot-enabled rolling plan against sampled revocation paths.

    ``report`` is a :class:`repro.core.replan.RollingPlanReport` produced
    with ``spot=...`` on the same ``pools``.  Weekly committed levels and
    spot floors are broadcast back to hours, ``num_draws`` revocation paths
    are sampled from the per-cloud two-state process, and the realized
    three-way bill (committed / on-demand / spot + fallback + requeue) is
    accounted per draw.

    On a scenario-batched report (``scenarios=`` on the plan request)
    ``scenario`` selects which demand future to replay: its floors and
    base costs are sliced off the report's N axis, and for ``scenario >
    0`` the demand path itself is regenerated from the report's
    ``scenario_config`` (scenario batches are pure functions of the
    realized trace + config, so the replayed path is exactly the one the
    scan billed).  Scenario 0 — the realized trace — is the default and
    the only valid index on unbatched reports."""
    if report.spot_floor is None:
        raise ValueError("report has no spot band; re-plan with spot=...")
    cfg, lines = report.spot_config, report.spot_lines
    wk = dm.HOURS_PER_WEEK
    batched = np.asarray(report.spot_floor).ndim == 3    # (S, N, P)
    n_scen = report.n_scenarios if batched else 1
    if not 0 <= scenario < n_scen:
        raise ValueError(
            f"scenario index {scenario} out of range for a report with "
            f"{n_scen} scenario(s)"
        )

    def _pick(a):
        """Scenario view of a per-week report array."""
        a = np.asarray(a)
        return a[:, scenario] if batched else a

    spot_floor = _pick(report.spot_floor)
    s, p = spot_floor.shape
    if batched:
        # The report's spot lines were built for the flattened (N x P)
        # row axis; every leaf is per-row, so one tree-slice recovers
        # this scenario's (P,) block.
        blk = slice(scenario * p, (scenario + 1) * p)
        lines = jax.tree_util.tree_map(lambda a: a[blk], lines)
    t0 = report.start_weeks * wk
    if scenario == 0:
        demand = np.asarray(pools.demand[:, t0: t0 + s * wk], np.float32)
    else:
        # Re-derive the scenario's demand path: scenario_batch is a pure
        # function of (realized trace, config) and scenario 0 is the
        # trace verbatim, so this reproduces the exact rows the scan saw.
        t_hist = (pools.num_hours // wk) * wk
        batch = sc.scenario_batch(
            pools.demand[:, :t_hist], report.scenario_config
        )
        demand = np.asarray(
            batch[scenario][:, t0: t0 + s * wk], np.float32
        )
    floor = np.repeat(spot_floor.T, wk, axis=1)
    spot_dem = np.maximum(demand - floor, 0.0)            # (P, T)

    paths = pe.simulate_revocations(
        lines.params, s * wk, num_draws=num_draws,
        key=jax.random.PRNGKey(seed),
    )
    up = np.asarray(paths.available)                      # (N, P, T)
    price = np.asarray(paths.price)

    served = spot_dem[None] * up
    fallback = spot_dem[None] * (1.0 - up)
    od = on_demand_premium()
    market = np.asarray(lines.market_rate)[None, :, None]
    spot_bill = (market * price * served).sum(-1)         # (N, P)
    fallback_bill = od * fallback.sum(-1)
    requeue_bill = od * np.asarray(
        pe.requeue_cost_hours(paths, spot_dem, cfg.requeue_hours)
    )

    total_dem = np.maximum(demand.sum(-1), 1e-9)          # (P,)
    avail = 1.0 - fallback.sum(-1) / total_dem            # (N, P)
    fleet_avail = float(
        1.0 - fallback.sum((-1, -2)).mean() / total_dem.sum()
    )
    # The committed + convertible + mid-band on-demand bill is path
    # independent — read it off the report rather than re-deriving the
    # replanner's billing here.
    base = float(
        _pick(report.committed_cost).sum()
        + _pick(report.on_demand_cost).sum()
    )
    if report.conv_committed_cost is not None:
        base += float(_pick(report.conv_committed_cost).sum())
    realized = base + float(
        (spot_bill + fallback_bill + requeue_bill).sum(-1).mean()
    )
    planned = (
        float(report.scenario_cost[scenario]) if batched
        else report.total_cost
    )
    mean_avail = avail.mean(0)
    return SpotReplayReport(
        num_draws=num_draws,
        availability_target=cfg.availability_target,
        availability=avail,
        mean_availability=mean_avail,
        fleet_availability=fleet_avail,
        meets_target=bool(mean_avail.min() >= cfg.availability_target),
        shortfall_chip_hours=float(fallback.sum((-1, -2)).mean()),
        planned_cost=planned,
        realized_cost=realized,
        realized_spot_cost=float(spot_bill.sum(-1).mean()),
        fallback_on_demand_cost=float(fallback_bill.sum(-1).mean()),
        requeue_cost=float(requeue_bill.sum(-1).mean()),
    )


def simulate_and_replan_pools(
    fleets: list[ServingFleet] | None = None,
    jobs: list[TrainingJob] | None = None,
    *,
    num_hours: int = 24 * 7 * 60,
    cadence_weeks: int = 1,
    horizon_weeks: int = 8,
    seed: int = 0,
    **replan_kw,
):
    """The rolling counterpart of :func:`simulate_and_plan_pools`: attribute
    the fleet's demand to its pools, then *replay* the weekly re-planning
    loop over the whole simulated window (re-fit, re-solve, buy increments,
    roll tranches off) instead of fitting once against a holdout.  Returns
    ``(PoolSet, repro.core.replan.RollingPlanReport)`` — the report carries
    the one-shot and hindsight baselines for the same window.  Pass
    ``spot=...`` to add the preemptible band, then hand the report to
    :func:`replay_spot_plan` to price it against sampled revocation
    paths."""
    return simulate_and_plan_pools(
        fleets, jobs, num_hours=num_hours, horizon_weeks=horizon_weeks,
        seed=seed, mode="rolling", cadence_weeks=cadence_weeks, **replan_kw,
    )
