"""Hardware-generation turnover: the demand driver the paper says breaks
per-pool planning (§2.3).

Fleet demand is not one curve: it is user workload growth x hardware
generational turnover x software efficiency.  A generation launch moves
demand *volume* between pools — the old family's trace decays and the
successor's grows along a logistic S-curve, scaled by the generational
perf-per-dollar uplift (the same user work needs fewer successor VMs) — so
to a per-pool forecaster a migration is indistinguishable from organic
decay, and commitments pinned to the dying family strand.

This module is the **generative** side of the subsystem (the inference side
— fitting the drivers back out of a realized fleet — is
``repro.core.migration``):

  * per-cloud successor edges from ``pricing.GENERATIONS`` matched onto a
    fleet's (cloud, region, machine-family) pool keys;
  * cumulative adoption as a logistic S-curve, walked as the exact
    discrete-time hazard recurrence m_{t+1} = m_t + (1 - m_t) h_t inside
    ONE ``lax.scan`` over the hour axis carrying the per-edge migrated
    shares (``migrate_demand``); a python-loop replay of the identical
    step is kept as the benchmark floor and bit-for-bit test oracle
    (``migrate_demand_loop``, ``bench_migration_scan``);
  * a multiplicative software-efficiency deflator
    (1 + rate)^(-t/year) applied to every pool (§2.4, SPI);
  * :func:`migrate_pool_set` — the PoolSet-level transform
    ``data.traces.synthetic_pool_set(migration=...)`` and
    ``capacity.simulator.fleet_pool_demand(migration=...)`` route through.

The scan formulation mirrors ``capacity.preemption``: tiny per-hour state
updates are exactly what python-level replay cannot afford at fleet scale
(P=12+ pools x 26k hours), and the hazard-recurrence form generalizes to
state-dependent adoption (gated rollouts, paused migrations) where the
closed form does not.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.capacity import pricing
from repro.core import demand as dm
from repro.core.demand import HOURS_PER_DAY, HOURS_PER_WEEK, DAYS_PER_YEAR

pricing.validate_tables()

HOURS_PER_YEAR = HOURS_PER_DAY * DAYS_PER_YEAR

# Logistic 10%->90% span in units of 1/rate: s(mid +/- ln(9)/k) = 0.9/0.1.
_LOGISTIC_1090 = 2.0 * float(np.log(9.0))


@dataclasses.dataclass(frozen=True)
class MigrationConfig:
    """Knobs of the generation-turnover model.

    ``generations`` is the successor table (default: the
    ``pricing.GENERATIONS`` data rows); ``software_efficiency_per_year``
    the multiplicative deflator rate (§2.4).  Pass custom ``Generation``
    rows to plant specific midpoints/uplifts on synthetic fleets — the
    recovery tests in ``core.migration`` do exactly that."""

    generations: tuple[pricing.Generation, ...] = tuple(pricing.GENERATIONS)
    software_efficiency_per_year: float = pricing.SOFTWARE_EFFICIENCY_PER_YEAR
    # Weight of the successor table's announced launch epochs as a prior on
    # the rolling logit-share fits (``core.migration``): generation
    # launches are public roadmap events, so a planner may lean on the
    # announced S-curve before adoption shows up in its own demand data —
    # the realized data overrides the prior as observations accumulate
    # (share weights sum over thousands of hours; the prior is worth
    # ``share_prior_weight`` observations).  0 disables (pure data fits,
    # what ``decompose_drivers`` uses for recovery).
    share_prior_weight: float = 100.0

    def __post_init__(self):
        # Custom planted rows must satisfy the same structural invariants
        # validate_tables() enforces on the static table: a duplicate
        # source would scatter more than 100% of a pool's volume away
        # (negative demand), a chained edge is unmodelled, and
        # non-positive spans/uplifts make the logistic degenerate.
        seen_src: set[tuple[str, str]] = set()
        for g in self.generations:
            if g.span_weeks <= 0 or g.perf_uplift <= 0 or g.launch_week < 0:
                raise ValueError(
                    f"generation epochs/uplift must be positive: {g}"
                )
            if g.old_family == g.new_family:
                raise ValueError(f"generation must change family: {g}")
            src = (g.cloud, g.old_family)
            if src in seen_src:
                raise ValueError(
                    f"duplicate generation source {src}: two edges would "
                    "migrate more than 100% of the pool's volume"
                )
            seen_src.add(src)
        seen_dst: set[tuple[str, str]] = set()
        for g in self.generations:
            dst = (g.cloud, g.new_family)
            if dst in seen_dst:
                raise ValueError(
                    f"duplicate generation successor {dst}: the share "
                    "decomposition attributes a successor pool to exactly "
                    "one pair"
                )
            seen_dst.add(dst)
        new_fams = {(g.cloud, g.new_family) for g in self.generations}
        for g in self.generations:
            if (g.cloud, g.old_family) in new_fams:
                raise ValueError(
                    "chained generations are not modelled (a source is "
                    f"another edge's successor): {g}"
                )
        if self.share_prior_weight < 0:
            raise ValueError(
                f"share_prior_weight must be >= 0: {self.share_prior_weight}"
            )
        if not 0.0 <= self.software_efficiency_per_year < 1.0:
            raise ValueError(
                "software_efficiency_per_year must be in [0, 1): "
                f"{self.software_efficiency_per_year}"
            )


def resolve_migration(migration) -> MigrationConfig | None:
    """Normalize the planner-facing ``migration=`` argument: None/False
    disables (the legacy bit-identical path), True takes the default
    :class:`MigrationConfig`, a MigrationConfig passes through."""
    if migration is None or migration is False:
        return None
    if migration is True:
        return MigrationConfig()
    if isinstance(migration, MigrationConfig):
        return migration
    raise TypeError(
        f"migration must be None/bool/MigrationConfig, got {migration!r}"
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class MigrationEdges:
    """Generation edges matched onto one fleet's pool axis.

    Arrays are (G,): edge g transfers demand from pool ``src[g]`` to pool
    ``dst[g]`` (same cloud and region, old family -> successor family)
    along a logistic with midpoint ``midpoint_hours[g]`` and per-hour rate
    ``rate_per_hour[g]``; one unit of old-family demand becomes
    1 / (1 + ``uplift[g]``) units on the successor."""

    src: jnp.ndarray             # (G,) int32 pool index of the old family
    dst: jnp.ndarray             # (G,) int32 pool index of the successor
    uplift: jnp.ndarray          # (G,) perf-per-dollar uplift
    inv_gain: jnp.ndarray        # (G,) 1 / (1 + uplift), precomputed: a
    #   multiply is bitwise deterministic across compilations where a
    #   divide-by-constant may lower to reciprocal-multiply in one fusion
    #   and real division in another (breaks the scan==loop guarantee)
    midpoint_hours: jnp.ndarray  # (G,) logistic midpoint, hours
    rate_per_hour: jnp.ndarray   # (G,) logistic rate, 1/hours

    @property
    def num_edges(self) -> int:
        return self.src.shape[0]


def _empty_edges() -> MigrationEdges:
    z = jnp.zeros((0,), jnp.float32)
    return MigrationEdges(
        src=jnp.zeros((0,), jnp.int32), dst=jnp.zeros((0,), jnp.int32),
        uplift=z, inv_gain=z, midpoint_hours=z, rate_per_hour=z,
    )


def migration_edges(
    keys: Sequence[dm.PoolKey],
    cfg: MigrationConfig = MigrationConfig(),
) -> MigrationEdges:
    """Match the successor table onto a fleet: an edge exists wherever both
    the old-family and new-family pool of one (cloud, region) are present.
    Pools without a matched edge simply do not migrate."""
    index = {tuple(k): i for i, k in enumerate(keys)}
    src, dst, up, mid, rate = [], [], [], [], []
    for g in cfg.generations:
        regions = {k[1] for k in index if k[0] == g.cloud}
        for r in sorted(regions):
            old = index.get((g.cloud, r, g.old_family))
            new = index.get((g.cloud, r, g.new_family))
            if old is None or new is None:
                continue
            src.append(old)
            dst.append(new)
            up.append(g.perf_uplift)
            mid.append(g.midpoint_week * HOURS_PER_WEEK)
            rate.append(
                _LOGISTIC_1090 / (g.span_weeks * HOURS_PER_WEEK)
            )
    if not src:
        return _empty_edges()
    up_arr = jnp.asarray(up, jnp.float32)
    return MigrationEdges(
        src=jnp.asarray(src, jnp.int32),
        dst=jnp.asarray(dst, jnp.int32),
        uplift=up_arr,
        inv_gain=1.0 / (1.0 + up_arr),
        midpoint_hours=jnp.asarray(mid, jnp.float32),
        rate_per_hour=jnp.asarray(rate, jnp.float32),
    )


def _sigmoid(x: jnp.ndarray) -> jnp.ndarray:
    """Numerically safe logistic built from exp/add/divide primitives.

    ``lax.logistic`` may lower through different expansions depending on
    fusion context (observed: last-ulp differences for deeply negative
    arguments between a scan body and a standalone jitted step), which
    would break the scan==loop bit-for-bit guarantee; the explicit
    composition rounds identically in both compilations."""
    pos = 1.0 / (1.0 + jnp.exp(-jnp.abs(x)))
    neg_e = jnp.exp(-jnp.abs(x))
    neg = neg_e / (1.0 + neg_e)
    return jnp.where(x >= 0, pos, neg)


def adoption_shares(edges: MigrationEdges, t_hours: jnp.ndarray) -> jnp.ndarray:
    """(G, T) closed-form cumulative adoption s_g(t) — the share of edge
    g's base demand volume that has migrated to the successor by hour t.
    The scan recurrence in :func:`migrate_demand` reproduces exactly this
    curve (induction on the discrete hazard); kept closed-form here for the
    inference side and the tests."""
    t = jnp.asarray(t_hours, jnp.float32)
    return _sigmoid(
        edges.rate_per_hour[:, None]
        * (t[None, :] - edges.midpoint_hours[:, None])
    )


def software_deflator(
    t_hours: jnp.ndarray, rate_per_year: float
) -> jnp.ndarray:
    """(T,) multiplicative software-efficiency deflator: the same user work
    needs (1 + rate)^(-t/year) VMs as engine improvements land (§2.4)."""
    t = jnp.asarray(t_hours, jnp.float32)
    return jnp.exp(-jnp.log1p(rate_per_year) / HOURS_PER_YEAR * t)


def _mig_step(edges: MigrationEdges, sw_log_hourly: float, carry, inp):
    """One hour of turnover: place demand per the carried migrated shares,
    then advance the carry to the next hour's share.

    The hazard recurrence m_{t+1} = m_t + (1 - m_t) h_t with
    h_t = (s(t+1) - s(t)) / (1 - s(t)) has the closed-form solution
    m_t = s(t); the step advances the carry by evaluating that solution
    directly rather than accumulating the increment — the incremental form
    picks up 1-ulp fma drift that contracts differently in the fused scan
    body vs the eagerly dispatched step, which would break the scan==loop
    bit-for-bit guarantee the tests and bench rely on."""
    m = carry                                    # (G,) migrated share at t
    b, t = inp                                   # (P,) base column, hour
    tf = t.astype(jnp.float32)
    moved = b[edges.src] * m                     # (G,) volume leaving src
    col = b.at[edges.src].add(-moved)
    col = col.at[edges.dst].add(moved * edges.inv_gain)
    eff = jnp.exp(-sw_log_hourly * tf)
    m_next = _sigmoid(
        edges.rate_per_hour * (tf + 1.0 - edges.midpoint_hours)
    )
    return m_next, col * eff


@functools.partial(jax.jit, static_argnames=("sw_rate",))
def migrate_demand(
    base: jnp.ndarray,
    edges: MigrationEdges,
    *,
    sw_rate: float = pricing.SOFTWARE_EFFICIENCY_PER_YEAR,
) -> jnp.ndarray:
    """Apply generation turnover + the software deflator to a (P, T) base
    demand matrix — ONE ``lax.scan`` over the hour axis carrying the (G,)
    migrated shares, so a multi-year fleet transforms as a single compiled
    program (``unroll=8`` amortizes the tiny per-step math over blocks of
    hours, same as the preemption walk)."""
    base = jnp.asarray(base, jnp.float32)
    t = jnp.arange(base.shape[1], dtype=jnp.int32)
    m0 = adoption_shares(edges, jnp.zeros((1,)))[:, 0]
    sw_log = float(np.log1p(sw_rate) / HOURS_PER_YEAR)
    step = functools.partial(_mig_step, edges, sw_log)
    _, cols = jax.lax.scan(step, m0, (base.T, t), unroll=8)
    return cols.T                                # (T, P) -> (P, T)


def migrate_demand_loop(
    base: jnp.ndarray,
    edges: MigrationEdges,
    *,
    sw_rate: float = pricing.SOFTWARE_EFFICIENCY_PER_YEAR,
) -> jnp.ndarray:
    """The same turnover replayed as a naive python loop over hours: the
    identical (jitted) step dispatched host-side once per hour — the
    benchmark floor (``bench_migration_scan``) and an independent execution
    the scan path is tested against bit for bit."""
    base = jnp.asarray(base, jnp.float32)
    m = adoption_shares(edges, jnp.zeros((1,)))[:, 0]
    sw_log = float(np.log1p(sw_rate) / HOURS_PER_YEAR)
    step = jax.jit(functools.partial(_mig_step, edges, sw_log))
    cols = []
    for t in range(base.shape[1]):
        m, col = step(m, (base[:, t], jnp.int32(t)))
        cols.append(np.asarray(col))
    return jnp.asarray(np.stack(cols, axis=1))


def migrate_pool_set(
    pools: dm.PoolSet,
    cfg: MigrationConfig = MigrationConfig(),
) -> dm.PoolSet:
    """PoolSet-level turnover transform: same keys/configs, demand run
    through :func:`migrate_demand` on the edges the successor table matches
    onto this fleet."""
    edges = migration_edges(pools.keys, cfg)
    demand = migrate_demand(
        jnp.asarray(pools.demand), edges,
        sw_rate=cfg.software_efficiency_per_year,
    )
    return dm.PoolSet(
        keys=pools.keys, demand=np.asarray(demand), configs=pools.configs
    )
