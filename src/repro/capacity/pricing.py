"""CSP pricing and hardware-generation data (paper Tables 1-2), encoded as
the data the capacity planner consumes."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class SavingsPlan:
    cloud: str
    family: str
    discount_1y: float
    discount_3y: float


# Paper Table 2: savings-plan discounts vs on-demand.
SAVINGS_PLANS = [
    SavingsPlan("aws", "C6i", 0.28, 0.52),
    SavingsPlan("aws", "C7i", 0.28, 0.52),
    SavingsPlan("aws", "C7GD", 0.28, 0.52),
    SavingsPlan("aws", "M7GD", 0.27, 0.50),
    SavingsPlan("azure", "Std_Dd_v4", 0.31, 0.54),
    SavingsPlan("azure", "Std_Dpd_v5", 0.31, 0.54),
    SavingsPlan("gcp", "N2-Standard", 0.37, 0.55),
    SavingsPlan("gcp", "N4-Standard", 0.37, 0.55),
]


def mean_discount_3y() -> float:
    return sum(p.discount_3y for p in SAVINGS_PLANS) / len(SAVINGS_PLANS)


def on_demand_premium() -> float:
    """On-demand price relative to committed price.  Paper §3.1: committed
    = (1 - mean 3y discount) x on-demand => premium = 1/(1-d) ~= 2.1x."""
    return 1.0 / (1.0 - mean_discount_3y())


@dataclasses.dataclass(frozen=True)
class SpotMarket:
    """Per-cloud spot/preemptible capacity terms (Table-2-style data row).

    ``discount`` is the mean spot price discount vs on-demand for the
    compute families in Table 2; ``hazard_per_hour`` / ``recovery_per_hour``
    are the two-state revocation-process rates (probability per hour of an
    available slice being revoked, and of a revoked slice coming back).
    ``price_band`` is the +/- fractional band hourly spot prices wander in
    around the mean (spot prices float with market pressure; committed and
    on-demand rates do not).  Stationary availability of the process is
    recovery / (hazard + recovery)."""

    cloud: str
    discount: float           # spot rate = (1 - discount) * on-demand rate
    hazard_per_hour: float    # P(available -> revoked) per hour
    recovery_per_hour: float  # P(revoked -> available) per hour
    price_band: float         # hourly spot price in mean * (1 +/- band)


# Spot market terms per cloud: deeper discounts ride with higher revocation
# hazard (AWS spot reclaims most aggressively; GCP spot VMs discount hardest
# with moderate churn; Azure sits between).  Rates are per hour on the same
# normalized price axis as SAVINGS_PLANS.
SPOT_MARKETS = [
    SpotMarket("aws", 0.68, 0.050, 0.50, 0.15),
    SpotMarket("azure", 0.62, 0.035, 0.45, 0.12),
    SpotMarket("gcp", 0.70, 0.060, 0.60, 0.10),
]


def spot_market(cloud: str) -> SpotMarket:
    """The spot terms for one cloud (KeyError on unknown clouds, so a typo'd
    pool key fails loudly instead of silently pricing at a default)."""
    for m in SPOT_MARKETS:
        if m.cloud == cloud:
            return m
    raise KeyError(f"no spot market data for cloud {cloud!r}")


@dataclasses.dataclass(frozen=True)
class HardwareTransition:
    date: str
    cloud: str
    old: str
    new: str
    latency_reduction: float  # median query-latency reduction


# Paper Table 1: step-function performance gains.
HARDWARE_TRANSITIONS = [
    HardwareTransition("2022-05", "aws", "Graviton2", "Graviton3", 0.25),
    HardwareTransition("2024-08", "aws", "Graviton3", "Graviton4", 0.30),
    HardwareTransition("2022-09", "azure", "DPv5", "DPv6", 0.20),
    HardwareTransition("2024-04", "gcp", "X86", "Axion", 0.50),
]

# Paper §2.4: software performance improvement (Snowflake Performance Index).
SOFTWARE_EFFICIENCY_PER_YEAR = 0.12
