"""CSP pricing and hardware-generation data (paper Tables 1-2), encoded as
the data the capacity planner consumes."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class SavingsPlan:
    cloud: str
    family: str
    discount_1y: float
    discount_3y: float


# Paper Table 2: savings-plan discounts vs on-demand.
SAVINGS_PLANS = [
    SavingsPlan("aws", "C6i", 0.28, 0.52),
    SavingsPlan("aws", "C7i", 0.28, 0.52),
    SavingsPlan("aws", "C7GD", 0.28, 0.52),
    SavingsPlan("aws", "M7GD", 0.27, 0.50),
    SavingsPlan("azure", "Std_Dd_v4", 0.31, 0.54),
    SavingsPlan("azure", "Std_Dpd_v5", 0.31, 0.54),
    SavingsPlan("gcp", "N2-Standard", 0.37, 0.55),
    SavingsPlan("gcp", "N4-Standard", 0.37, 0.55),
]


def mean_discount_3y() -> float:
    return sum(p.discount_3y for p in SAVINGS_PLANS) / len(SAVINGS_PLANS)


def on_demand_premium() -> float:
    """On-demand price relative to committed price.  Paper §3.1: committed
    = (1 - mean 3y discount) x on-demand => premium = 1/(1-d) ~= 2.1x."""
    return 1.0 / (1.0 - mean_discount_3y())


@dataclasses.dataclass(frozen=True)
class SpotMarket:
    """Per-cloud spot/preemptible capacity terms (Table-2-style data row).

    ``discount`` is the mean spot price discount vs on-demand for the
    compute families in Table 2; ``hazard_per_hour`` / ``recovery_per_hour``
    are the two-state revocation-process rates (probability per hour of an
    available slice being revoked, and of a revoked slice coming back).
    ``price_band`` is the +/- fractional band hourly spot prices wander in
    around the mean (spot prices float with market pressure; committed and
    on-demand rates do not).  Stationary availability of the process is
    recovery / (hazard + recovery)."""

    cloud: str
    discount: float           # spot rate = (1 - discount) * on-demand rate
    hazard_per_hour: float    # P(available -> revoked) per hour
    recovery_per_hour: float  # P(revoked -> available) per hour
    price_band: float         # hourly spot price in mean * (1 +/- band)


# Spot market terms per cloud: deeper discounts ride with higher revocation
# hazard (AWS spot reclaims most aggressively; GCP spot VMs discount hardest
# with moderate churn; Azure sits between).  Rates are per hour on the same
# normalized price axis as SAVINGS_PLANS.
SPOT_MARKETS = [
    SpotMarket("aws", 0.68, 0.050, 0.50, 0.15),
    SpotMarket("azure", 0.62, 0.035, 0.45, 0.12),
    SpotMarket("gcp", 0.70, 0.060, 0.60, 0.10),
]


def spot_market(cloud: str) -> SpotMarket:
    """The spot terms for one cloud (KeyError on unknown clouds, so a typo'd
    pool key fails loudly instead of silently pricing at a default)."""
    for m in SPOT_MARKETS:
        if m.cloud == cloud:
            return m
    raise KeyError(f"no spot market data for cloud {cloud!r}")


@dataclasses.dataclass(frozen=True)
class HardwareTransition:
    date: str
    cloud: str
    old: str
    new: str
    latency_reduction: float  # median query-latency reduction


# Paper Table 1: step-function performance gains (date-sorted — an
# invariant ``validate_tables`` enforces so replay code can bisect).
HARDWARE_TRANSITIONS = [
    HardwareTransition("2022-05", "aws", "Graviton2", "Graviton3", 0.25),
    HardwareTransition("2022-09", "azure", "DPv5", "DPv6", 0.20),
    HardwareTransition("2024-04", "gcp", "X86", "Axion", 0.50),
    HardwareTransition("2024-08", "aws", "Graviton3", "Graviton4", 0.30),
]

# Paper §2.4: software performance improvement (Snowflake Performance Index).
SOFTWARE_EFFICIENCY_PER_YEAR = 0.12


@dataclasses.dataclass(frozen=True)
class Generation:
    """One hardware-generation turnover edge: demand on ``old_family`` pools
    migrates to ``new_family`` pools of the same cloud (paper §2.3 / Table 1,
    keyed by the Table-2 machine families commitments are sold against).

    ``launch_week`` is the adoption epoch relative to the trace start (the
    week cumulative adoption crosses ~10%); ``span_weeks`` is the 10%->90%
    width of the logistic S-curve; ``perf_uplift`` is the generational
    perf-per-dollar gain — one old-family VM of work needs
    1/(1 + perf_uplift) successor VMs, which is what makes a migration look
    like organic demand decay to a per-pool forecaster."""

    cloud: str
    old_family: str
    new_family: str
    launch_week: int
    span_weeks: float
    perf_uplift: float

    @property
    def midpoint_week(self) -> float:
        """Week of 50% adoption (logistic midpoint)."""
        return self.launch_week + 0.5 * self.span_weeks


# Successor table: which Table-2 family each generation hands demand to,
# with launch epochs staggered so multi-year traces see turnover mid-trace.
# Uplifts follow the paper's Table-1 latency reductions per cloud.
GENERATIONS = [
    Generation("aws", "C6i", "C7i", 26, 40.0, 0.25),
    Generation("aws", "C7GD", "M7GD", 78, 40.0, 0.30),
    Generation("azure", "Std_Dd_v4", "Std_Dpd_v5", 52, 48.0, 0.20),
    Generation("gcp", "N2-Standard", "N4-Standard", 104, 36.0, 0.50),
]


def generations_for_cloud(cloud: str) -> list[Generation]:
    return [g for g in GENERATIONS if g.cloud == cloud]


@dataclasses.dataclass(frozen=True)
class ConvertiblePlan:
    """Per-cloud convertible-commitment terms (the first-party analogue of
    reservation resale/conversion in "Hedge Your Bets" / "No Reservations").

    A convertible tranche may be exchanged across machine families within
    its cloud at re-plan boundaries; the flexibility costs a discount
    *haircut* vs the cloud's standard family-pinned savings plans:
    convertible discount = mean standard discount - haircut per term."""

    cloud: str
    haircut_1y: float
    haircut_3y: float


CONVERTIBLE_PLANS = [
    ConvertiblePlan("aws", 0.04, 0.07),
    ConvertiblePlan("azure", 0.04, 0.07),
    ConvertiblePlan("gcp", 0.05, 0.08),
]


def convertible_plan(cloud: str) -> ConvertiblePlan:
    for p in CONVERTIBLE_PLANS:
        if p.cloud == cloud:
            return p
    raise KeyError(f"no convertible plan data for cloud {cloud!r}")


def convertible_discounts(cloud: str) -> tuple[float, float]:
    """(discount_1y, discount_3y) of the cloud's convertible SKU: the mean
    standard discount across the cloud's Table-2 families minus the
    haircut."""
    rows = [p for p in SAVINGS_PLANS if p.cloud == cloud]
    if not rows:
        raise KeyError(f"no savings plans for cloud {cloud!r}")
    d1 = sum(p.discount_1y for p in rows) / len(rows)
    d3 = sum(p.discount_3y for p in rows) / len(rows)
    hc = convertible_plan(cloud)
    return d1 - hc.haircut_1y, d3 - hc.haircut_3y


def known_clouds() -> frozenset[str]:
    """The clouds commitments are sold on — every other table must key
    inside this set (a typo'd cloud would otherwise silently price at
    defaults)."""
    return frozenset(p.cloud for p in SAVINGS_PLANS)


#: set after the first successful validate_tables() run; the tables are
#: module-level constants, so one clean pass proves them for the process.
_VALIDATED = False


def validate_tables(force: bool = False) -> None:
    """Invariant checker for the pricing tables, run at import time by the
    tables' consumers (portfolio/preemption/generations): discounts in
    (0, 1) and monotone in term (a 3y lock can't discount less than 1y),
    convertible haircuts smaller than the discounts they cut, transition
    dates sorted, and SPOT_MARKETS / GENERATIONS / CONVERTIBLE_PLANS keyed
    strictly inside the Table-2 clouds.  Raises ValueError on the first
    violated invariant so a corrupted table fails loudly at import, not as
    a silently absurd plan.

    Memoized after the first clean pass — every consumer calls this at
    import, and the tables never change at runtime.  Pass ``force=True``
    to re-check anyway (tests that monkeypatch a table corrupted rely on
    this escape hatch)."""
    global _VALIDATED
    if _VALIDATED and not force:
        return
    clouds = known_clouds()
    for p in SAVINGS_PLANS:
        if not (0.0 < p.discount_1y < 1.0 and 0.0 < p.discount_3y < 1.0):
            raise ValueError(
                f"savings-plan discounts must be in (0, 1): {p}"
            )
        if p.discount_3y <= p.discount_1y:
            raise ValueError(
                f"discounts must be monotone in term (3y > 1y): {p}"
            )
    for m in SPOT_MARKETS:
        if m.cloud not in clouds:
            raise ValueError(f"spot market for unknown cloud: {m}")
        if not 0.0 < m.discount < 1.0:
            raise ValueError(f"spot discount must be in (0, 1): {m}")
        if not (0.0 < m.hazard_per_hour < 1.0
                and 0.0 < m.recovery_per_hour < 1.0):
            raise ValueError(f"spot rates must be in (0, 1): {m}")
        if not 0.0 <= m.price_band < 1.0:
            raise ValueError(f"spot price band must be in [0, 1): {m}")
    dates = [t.date for t in HARDWARE_TRANSITIONS]
    if dates != sorted(dates):
        raise ValueError(
            f"HARDWARE_TRANSITIONS must be date-sorted, got {dates}"
        )
    families = {(p.cloud, p.family) for p in SAVINGS_PLANS}
    for g in GENERATIONS:
        if g.cloud not in clouds:
            raise ValueError(f"generation for unknown cloud: {g}")
        if (g.cloud, g.old_family) not in families or (
                g.cloud, g.new_family) not in families:
            raise ValueError(
                f"generation families must be Table-2 SKUs: {g}"
            )
        if g.old_family == g.new_family:
            raise ValueError(f"generation must change family: {g}")
        if g.launch_week < 0 or g.span_weeks <= 0:
            raise ValueError(f"generation epochs must be positive: {g}")
        if g.perf_uplift <= 0:
            raise ValueError(f"perf uplift must be positive: {g}")
    sources = {(g.cloud, g.old_family) for g in GENERATIONS}
    for g in GENERATIONS:
        if (g.cloud, g.new_family) in sources:
            raise ValueError(
                "chained generations are not modelled (successor is itself "
                f"a source): {g}"
            )
    for c in CONVERTIBLE_PLANS:
        if c.cloud not in clouds:
            raise ValueError(f"convertible plan for unknown cloud: {c}")
        d1, d3 = convertible_discounts(c.cloud)
        if not (0.0 < d1 < 1.0 and 0.0 < d3 < 1.0):
            raise ValueError(
                f"convertible haircut must leave a discount in (0, 1): {c}"
            )
        if d3 <= d1:
            raise ValueError(
                f"convertible discounts must stay monotone in term: {c}"
            )
    if not 0.0 < SOFTWARE_EFFICIENCY_PER_YEAR < 1.0:
        raise ValueError(
            "SOFTWARE_EFFICIENCY_PER_YEAR must be in (0, 1): "
            f"{SOFTWARE_EFFICIENCY_PER_YEAR}"
        )
    _VALIDATED = True
