"""CSP pricing and hardware-generation data (paper Tables 1-2), encoded as
the data the capacity planner consumes."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class SavingsPlan:
    cloud: str
    family: str
    discount_1y: float
    discount_3y: float


# Paper Table 2: savings-plan discounts vs on-demand.
SAVINGS_PLANS = [
    SavingsPlan("aws", "C6i", 0.28, 0.52),
    SavingsPlan("aws", "C7i", 0.28, 0.52),
    SavingsPlan("aws", "C7GD", 0.28, 0.52),
    SavingsPlan("aws", "M7GD", 0.27, 0.50),
    SavingsPlan("azure", "Std_Dd_v4", 0.31, 0.54),
    SavingsPlan("azure", "Std_Dpd_v5", 0.31, 0.54),
    SavingsPlan("gcp", "N2-Standard", 0.37, 0.55),
    SavingsPlan("gcp", "N4-Standard", 0.37, 0.55),
]


def mean_discount_3y() -> float:
    return sum(p.discount_3y for p in SAVINGS_PLANS) / len(SAVINGS_PLANS)


def on_demand_premium() -> float:
    """On-demand price relative to committed price.  Paper §3.1: committed
    = (1 - mean 3y discount) x on-demand => premium = 1/(1-d) ~= 2.1x."""
    return 1.0 / (1.0 - mean_discount_3y())


@dataclasses.dataclass(frozen=True)
class HardwareTransition:
    date: str
    cloud: str
    old: str
    new: str
    latency_reduction: float  # median query-latency reduction


# Paper Table 1: step-function performance gains.
HARDWARE_TRANSITIONS = [
    HardwareTransition("2022-05", "aws", "Graviton2", "Graviton3", 0.25),
    HardwareTransition("2024-08", "aws", "Graviton3", "Graviton4", 0.30),
    HardwareTransition("2022-09", "azure", "DPv5", "DPv6", 0.20),
    HardwareTransition("2024-04", "gcp", "X86", "Axion", 0.50),
]

# Paper §2.4: software performance improvement (Snowflake Performance Index).
SOFTWARE_EFFICIENCY_PER_YEAR = 0.12
