"""Capacity-commitment-aware scheduler for deferrable jobs (paper §4 and
Future Work #1, applied to this framework's own workloads).

Deferrable framework workloads — eval sweeps, checkpoint-replay regression
suites, compile farms, dataset preprocessing — are the Snowtrail/CI analogue
of the paper's §4 categories.  The scheduler packs them into the troughs
below the commitment line (already-paid capacity) instead of riding the
peak at on-demand rates.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import timeshift as ts
from repro.capacity import pricing

pricing.validate_tables()


@dataclasses.dataclass(frozen=True)
class DeferrableWorkload:
    name: str
    kind: str                  # eval | regression | loadtest | build
    chip_hours: float
    arrival_hour: int
    deadline_hour: int
    interruptible: bool = True


FRAMEWORK_WORKLOADS = (
    # the framework's own §4-style internal workloads
    ("nightly-eval-sweep", "eval", 96.0, 18, 42, True),
    ("ckpt-replay-regression", "regression", 64.0, 10, 58, True),
    ("serving-loadtest", "loadtest", 48.0, 30, 78, True),
    ("artifact-builds", "build", 24.0, 40, 64, False),
)


def default_workloads(week_offset_hours: int = 0) -> list[DeferrableWorkload]:
    return [
        DeferrableWorkload(n, k, ch, a + week_offset_hours,
                           d + week_offset_hours, i)
        for (n, k, ch, a, d, i) in FRAMEWORK_WORKLOADS
    ]


@dataclasses.dataclass
class ScheduleReport:
    placements: dict[str, list[tuple[int, float]]]
    on_demand_cost_naive: float
    on_demand_cost_shifted: float
    savings: float
    savings_frac: float


def schedule(
    base_demand: np.ndarray,
    commitment: float,
    workloads: list[DeferrableWorkload],
) -> ScheduleReport:
    jobs = [
        ts.Job(arrival=w.arrival_hour, work=w.chip_hours,
               deadline=w.deadline_hour, interruptible=w.interruptible,
               deferrable=True)
        for w in workloads
    ]
    out = ts.schedule_jobs(base_demand, commitment, jobs)
    placements = {
        w.name: slices
        for w, (job, slices) in zip(workloads, out["placements"])
    }
    naive = out["on_demand_cost_naive"]
    shifted = out["on_demand_cost_shifted"]
    return ScheduleReport(
        placements=placements,
        on_demand_cost_naive=naive,
        on_demand_cost_shifted=shifted,
        savings=naive - shifted,
        savings_frac=(naive - shifted) / max(naive, 1e-9),
    )
