"""Stochastic spot-revocation model: a per-pool two-state Markov process.

Spot/preemptible capacity is the third purchasing option next to commitments
and on-demand ("Hedge Your Bets", Ambati et al.): its used rate is deeply
discounted, but the provider may revoke a slice at any hour.  This module
models revocation as a two-state (available / revoked) Markov chain per
(cloud, region, machine-family) pool with per-cloud rates from
``pricing.SPOT_MARKETS``:

    P(available -> revoked  | one hour) = hazard
    P(revoked   -> available| one hour) = recovery

so the stationary availability is a = recovery / (hazard + recovery), the
mean run between interruptions is 1/hazard hours, and the mean outage is
1/recovery hours.  Hourly spot prices additionally wander inside a per-cloud
band around the mean spot rate (an AR(1) walk clipped to the band) — the
"spot price band" planners hedge against.

The Monte-Carlo simulator is ONE ``lax.scan`` over the T hour axis carrying
the (N draws, P pools) state, with all randomness pre-keyed so the compiled
scan and the naive python-loop replay (`simulate_revocations_loop`, the
benchmark baseline) walk identical paths.  ``bench_preemption_scan`` shows
the scan >= 5x the loop at fleet scale (P=12, T=26280).

What downstream consumes:

  * ``core.spot`` turns the stationary distribution (or simulated draws)
    into an *effective spot cost line* for the portfolio solvers;
  * ``capacity.simulator.replay_spot_plan`` replays a finished plan against
    sampled paths and reports realized availability / shortfall vs the
    chance-constraint target.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.capacity import pricing

# The SPOT_MARKETS rows must satisfy their invariants before any revocation
# process is built from them (see pricing.validate_tables).
pricing.validate_tables()


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PreemptionParams:
    """Per-pool revocation-process parameters, arrays aligned with the pool
    axis (P,).  Built from the per-cloud ``pricing.SPOT_MARKETS`` rows via
    :func:`params_for_clouds`; kept as arrays so the whole fleet rides one
    vmapped/scanned program."""

    hazard: jnp.ndarray      # (P,) P(available -> revoked) per hour
    recovery: jnp.ndarray    # (P,) P(revoked -> available) per hour
    discount: jnp.ndarray    # (P,) spot discount vs on-demand
    price_band: jnp.ndarray  # (P,) +/- fractional hourly price band

    @property
    def num_pools(self) -> int:
        return self.hazard.shape[0]


def params_for_clouds(
    clouds: Sequence[str],
    markets: Sequence[pricing.SpotMarket] | None = None,
) -> PreemptionParams:
    """(P,) revocation parameters for a fleet of pools on ``clouds`` —
    per-cloud Table rows broadcast to the pool axis, so spot pricing is
    data (``pricing.SPOT_MARKETS``), not constants buried in solver code."""
    by_cloud = {m.cloud: m for m in (markets or pricing.SPOT_MARKETS)}
    missing = sorted(set(clouds) - set(by_cloud))
    if missing:
        raise KeyError(f"no spot market data for clouds {missing}")
    rows = [by_cloud[c] for c in clouds]
    return PreemptionParams(
        hazard=jnp.asarray([m.hazard_per_hour for m in rows], jnp.float32),
        recovery=jnp.asarray(
            [m.recovery_per_hour for m in rows], jnp.float32
        ),
        discount=jnp.asarray([m.discount for m in rows], jnp.float32),
        price_band=jnp.asarray([m.price_band for m in rows], jnp.float32),
    )


def stationary_availability(params: PreemptionParams) -> jnp.ndarray:
    """(P,) long-run fraction of hours a spot slice is available:
    a = recovery / (hazard + recovery)."""
    return params.recovery / jnp.maximum(
        params.hazard + params.recovery, 1e-12
    )


def interruption_rate(params: PreemptionParams) -> jnp.ndarray:
    """(P,) expected revocations per *wall-clock* hour in steady state —
    hazard while available, weighted by the availability fraction.  This is
    the rate the requeue/recompute penalty accrues at per unit of spot
    capacity held."""
    return params.hazard * stationary_availability(params)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RevocationPaths:
    """Sampled revocation paths: N Monte-Carlo draws x P pools x T hours.

    ``available`` is the state path (1.0 while the pool's spot capacity is
    up); ``interrupted`` marks the hours where an available slice was
    revoked (the requeue/recompute-penalty events); ``price`` is the hourly
    spot price multiplier (mean 1.0, wandering in the per-cloud band)."""

    available: jnp.ndarray    # (N, P, T) float32 in {0, 1}
    interrupted: jnp.ndarray  # (N, P, T) float32 in {0, 1}
    price: jnp.ndarray        # (N, P, T) float32 multiplier around 1.0

    @property
    def num_draws(self) -> int:
        return self.available.shape[0]

    def availability(self) -> np.ndarray:
        """(P,) mean availability over draws and hours — the empirical
        counterpart of :func:`stationary_availability`."""
        return np.asarray(self.available.mean((0, 2)))

    def interruptions_per_hour(self) -> np.ndarray:
        """(P,) empirical revocations per wall-clock hour — the counterpart
        of :func:`interruption_rate`."""
        return np.asarray(self.interrupted.mean((0, 2)))


def _step(params: PreemptionParams, carry, inp):
    """One hour of the fleet: flip each (draw, pool) state by its cloud's
    hazard/recovery coin, walk the price AR(1) inside the band."""
    avail, price = carry
    u, z = inp
    stay_up = u >= params.hazard[None, :]
    come_up = u < params.recovery[None, :]
    nxt = jnp.where(avail > 0.5, stay_up, come_up).astype(jnp.float32)
    interrupted = avail * (1.0 - nxt)
    # AR(1) with stationary sd ~ band/2, clipped into the band so a long
    # quiet stretch cannot drift the price out of the published range.
    band = params.price_band[None, :]
    price = jnp.clip(0.9 * price + 0.3 * band * z, -band, band)
    return (nxt, price), (nxt, interrupted, 1.0 + price)


def draw_noise(
    params: PreemptionParams,
    num_hours: int,
    num_draws: int,
    key: jax.Array,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Pre-draw everything random: initial states from the stationary
    distribution (so short windows are not biased by an all-available hour
    0) plus the per-hour transition uniforms and price-walk normals.  The
    compiled scan and the python-loop replay consume the SAME draws, so
    they walk identical paths — the bench compares the walks, not the
    (shared) RNG cost."""
    k0, ku, kz = jax.random.split(key, 3)
    p = params.num_pools
    a = stationary_availability(params)
    avail0 = (
        jax.random.uniform(k0, (num_draws, p)) < a[None, :]
    ).astype(jnp.float32)
    us = jax.random.uniform(ku, (num_hours, num_draws, p))
    zs = jax.random.normal(kz, (num_hours, num_draws, p))
    return avail0, us, zs


@jax.jit
def revocation_walk(
    params: PreemptionParams,
    avail0: jnp.ndarray,
    us: jnp.ndarray,
    zs: jnp.ndarray,
) -> RevocationPaths:
    """The fleet walk as ONE compiled ``lax.scan`` over the hour axis
    carrying the (N, P) state — all Monte-Carlo draws advance in lockstep
    as the leading axis of the carry, so there is no python-level loop over
    draws either.  ``unroll=8`` amortizes the while-loop step overhead over
    blocks of hours (the per-step math is a few hundred lanes, far below
    dispatch cost)."""
    price0 = jnp.zeros_like(avail0)
    step = functools.partial(_step, params)
    _, (avail, interrupted, price) = jax.lax.scan(
        step, (avail0, price0), (us, zs), unroll=8
    )
    to_npt = lambda x: jnp.moveaxis(x, 0, -1)  # (T, N, P) -> (N, P, T)
    return RevocationPaths(
        available=to_npt(avail),
        interrupted=to_npt(interrupted),
        price=to_npt(price),
    )


def simulate_revocations(
    params: PreemptionParams,
    num_hours: int,
    *,
    num_draws: int = 32,
    key: jax.Array | None = None,
) -> RevocationPaths:
    """Sample revocation paths for the whole fleet: pre-draw the noise,
    run the compiled scan."""
    key = key if key is not None else jax.random.PRNGKey(0)
    avail0, us, zs = draw_noise(params, num_hours, num_draws, key)
    return revocation_walk(params, avail0, us, zs)


def revocation_walk_loop(
    params: PreemptionParams,
    avail0: jnp.ndarray,
    us: jnp.ndarray,
    zs: jnp.ndarray,
) -> RevocationPaths:
    """The same walk as a naive python loop over hours: the identical
    :func:`_step`, dispatched host-side once per hour on re-sliced noise —
    the same shape of baseline as the rolling replanner's
    ``backend="loop"``.  Kept as the benchmark floor
    (``bench_preemption_scan``) and as an independent execution the scan
    path is tested against (state and interruption paths match bit for
    bit; prices agree to float tolerance — the compiled scan contracts the
    AR(1) multiply-add into an fma)."""
    num_hours = us.shape[0]
    carry = (jnp.asarray(avail0), jnp.zeros_like(avail0))
    avails, interrupts, prices = [], [], []
    for t in range(num_hours):
        carry, (av, itr, pr) = _step(params, carry, (us[t], zs[t]))
        avails.append(np.asarray(av))
        interrupts.append(np.asarray(itr))
        prices.append(np.asarray(pr))
    stack = lambda x: jnp.asarray(np.moveaxis(np.stack(x), 0, -1))
    return RevocationPaths(
        available=stack(avails),
        interrupted=stack(interrupts),
        price=stack(prices),
    )


def simulate_revocations_loop(
    params: PreemptionParams,
    num_hours: int,
    *,
    num_draws: int = 32,
    key: jax.Array | None = None,
) -> RevocationPaths:
    """:func:`simulate_revocations` through the python-loop walk."""
    key = key if key is not None else jax.random.PRNGKey(0)
    avail0, us, zs = draw_noise(params, num_hours, num_draws, key)
    return revocation_walk_loop(params, avail0, us, zs)


def requeue_cost_hours(
    paths: RevocationPaths,
    spot_usage: jnp.ndarray,
    requeue_hours: float,
) -> jnp.ndarray:
    """(N, P) recompute/requeue chip-hours: every interruption of a slice
    that was actually *serving demand* loses ``requeue_hours`` of work per
    interrupted chip (checkpoint-to-revocation progress redone elsewhere).
    ``spot_usage`` (P, T) or (N, P, T) is the spot chip demand per hour."""
    usage = jnp.asarray(spot_usage, jnp.float32)
    if usage.ndim == 2:
        usage = usage[None, :, :]
    return (paths.interrupted * usage * requeue_hours).sum(-1)
