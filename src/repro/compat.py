"""JAX version-compatibility shims.

The repo targets the modern JAX surface (``jax.shard_map`` with
``check_vma``/``axis_names``, ``jax.make_mesh(..., axis_types=...)`` with
``jax.sharding.AxisType``), but must also run on older installs (0.4.x) where
shard_map lives in ``jax.experimental.shard_map`` with the ``check_rep`` /
``auto`` spelling and meshes have no axis types.  All call sites in the repo
import from here instead of feature-testing jax themselves.

Translation table (new API -> 0.4.x):

    check_vma=False                  -> check_rep=False
    axis_names={manual axes}         -> auto = mesh axes - manual axes
    axis_types=(AxisType.Auto, ...)  -> dropped (0.4.x meshes are untyped)
"""

from __future__ import annotations

from typing import Sequence

import jax

HAS_NATIVE_SHARD_MAP = hasattr(jax, "shard_map")
HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")


def shard_map(
    f,
    *,
    mesh,
    in_specs,
    out_specs,
    check_vma: bool = True,
    axis_names: frozenset | None = None,
):
    """``jax.shard_map`` with the modern keyword surface on any JAX version.

    ``axis_names`` is the set of *manual* mesh axes (None = all axes manual,
    matching the native default).
    """
    if HAS_NATIVE_SHARD_MAP:
        kwargs = dict(
            mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
        if axis_names is not None:
            kwargs["axis_names"] = frozenset(axis_names)
        return jax.shard_map(f, **kwargs)

    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    auto = frozenset()
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _legacy_shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma, auto=auto,
    )


def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    *,
    axis_types: Sequence | None = None,
    devices=None,
):
    """``jax.make_mesh`` accepting ``axis_types`` on any JAX version.

    ``axis_types`` entries may be given as the strings "auto" / "explicit"
    so callers need not touch ``jax.sharding.AxisType`` directly; on JAX
    versions without typed mesh axes the argument is ignored.
    """
    if HAS_AXIS_TYPE and axis_types is not None:
        resolved = tuple(
            getattr(jax.sharding.AxisType, t.capitalize())
            if isinstance(t, str) else t
            for t in axis_types
        )
        return jax.make_mesh(
            axis_shapes, axis_names, devices=devices, axis_types=resolved
        )
    return jax.make_mesh(axis_shapes, axis_names, devices=devices)


def auto_axis_types(n: int):
    """``n`` Auto-typed axes where supported, else None (untyped mesh)."""
    if HAS_AXIS_TYPE:
        return (jax.sharding.AxisType.Auto,) * n
    return None
