"""Pure-jnp oracle for flash attention: GQA + causal + padded-KV masking."""

from __future__ import annotations

import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray,  # (B, Hq, Sq, D)
    k: jnp.ndarray,  # (B, Hkv, Skv, D)
    v: jnp.ndarray,  # (B, Hkv, Skv, D)
    *,
    causal: bool = True,
    kv_len: int | None = None,
    scale: float | None = None,
) -> jnp.ndarray:
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5

    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32)
    ) * scale

    mask = jnp.zeros((sq, skv), bool)
    if causal:
        # query i sits at absolute position (skv_eff - sq + i): decode-style
        # alignment where queries are the final sq positions of the context.
        eff = kv_len if kv_len is not None else skv
        row = jnp.arange(sq)[:, None] + (eff - sq)
        col = jnp.arange(skv)[None, :]
        mask = mask | (col > row)
    if kv_len is not None:
        mask = mask | (jnp.arange(skv)[None, :] >= kv_len)
    s = jnp.where(mask[None, None], -jnp.inf, s)

    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)
