"""Public attention op: padding, block-size selection, interpret fallback,
and a custom_vjp whose backward pass is the (rematerialized) reference —
forward speed is what matters for serving; training uses the jnp path or the
same kernel under `jax.remat`."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_kernel
from repro.kernels.flash_attention.ref import attention_ref


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    kv_len: int | None = None,
    scale: float | None = None,
    bq: int | None = None,
    bk: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Flash attention with automatic seq padding. Shapes:
    q (B, Hq, Sq, D), k/v (B, Hkv, Skv, D) -> (B, Hq, Sq, D)."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    kv_eff = skv if kv_len is None else kv_len

    bq = bq or min(128, _round_up(sq, 8))
    bk = bk or min(128, _round_up(skv, 8))
    sq_p, skv_p = _round_up(sq, bq), _round_up(skv, bk)

    if interpret is None:
        interpret = not _on_tpu()

    qp = jnp.zeros((b, hq, sq_p, d), q.dtype).at[:, :, :sq, :].set(q)
    kp = jnp.zeros((b, hkv, skv_p, d), k.dtype).at[:, :, :skv, :].set(k)
    vp = jnp.zeros((b, hkv, skv_p, d), v.dtype).at[:, :, :skv, :].set(v)

    # Real query row i sits at absolute position kv_eff - sq + i; padded q
    # rows land past kv_eff (they attend to everything valid — garbage rows,
    # sliced off below).  kv_len masks padded/unfilled KV columns.
    out = flash_attention_kernel(
        qp, kp, vp,
        causal=causal,
        kv_len=kv_eff,
        row_offset=kv_eff - sq,
        scale=scale, bq=bq, bk=bk, interpret=interpret,
    )
    return out[:, :, :sq, :]


@jax.custom_vjp
def flash_attention_trainable(q, k, v):
    return flash_attention(q, k, v, causal=True)


def _fwd(q, k, v):
    return flash_attention_trainable(q, k, v), (q, k, v)


def _bwd(res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: attention_ref(q_, k_, v_, causal=True), q, k, v)
    return vjp(g)


flash_attention_trainable.defvjp(_fwd, _bwd)
