"""Pallas TPU flash attention (forward) with GQA, causal masking, padded KV.

Online-softmax blocked attention [Dao et al.], adapted to TPU:
  * grid (B, Hq, Sq/bq, Skv/bk), KV innermost so (m, l, acc) scratch carries
    across KV blocks in VMEM;
  * GQA without materializing repeated KV: the K/V BlockSpec index map sends
    q-head h to kv-head h // group — HBM traffic is O(Hkv), not O(Hq);
  * block shapes aligned to MXU tiles: bq, bk multiples of 128 lanes
    (sublane-padded by ops.py), D assumed <= 256 and lane-aligned;
  * causal block skip: KV blocks entirely above the diagonal are skipped
    (pl.when), giving the ~2x wall-time saving on TPU; fully-unmasked blocks
    skip the mask computation entirely.

Decode alignment: queries are the *last* ``sq`` positions of an effective
context of ``kv_len`` tokens (kv_len <= Skv covers padded caches), which
makes the same kernel serve train (sq == skv), prefill, and batched decode
(sq == 1..few, kv_len = cache fill level).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, scale: float, causal: bool, kv_len: int, row_offset: int,
    bq: int, bk: int,
):
    ik = pl.program_id(3)
    iq = pl.program_id(2)
    num_k = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Absolute positions: query row i (global) sits at context position
    # row_offset + i; KV column j is valid iff j < kv_len.
    row0 = row_offset + iq * bq  # absolute position of first q row in block
    col0 = ik * bk

    # Causal block skip: this KV block starts past the last query's position.
    block_needed = True
    if causal:
        block_needed = col0 <= row0 + bq - 1
    kv_valid = col0 < kv_len  # KV block fully in padding -> skip

    @pl.when(jnp.logical_and(block_needed, kv_valid))
    def _update():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # (bq, bk)

        rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = col0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = cols >= kv_len
        if causal:
            mask = jnp.logical_or(mask, cols > rows)
        s = jnp.where(mask, NEG_INF, s)

        m_prev = m_ref[...]            # (bq, 1)
        m_cur = s.max(-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = alpha * l_ref[...] + p.sum(-1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(ik == num_k - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "kv_len", "row_offset", "scale", "bq", "bk", "interpret",
    ),
)
def flash_attention_kernel(
    q: jnp.ndarray,  # (B, Hq, Sq, D)   Sq % bq == 0
    k: jnp.ndarray,  # (B, Hkv, Skv, D) Skv % bk == 0
    v: jnp.ndarray,
    *,
    causal: bool = True,
    kv_len: int | None = None,
    row_offset: int | None = None,
    scale: float | None = None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    kv_len = skv if kv_len is None else kv_len
    # Default: queries are the last sq real positions of the kv_len context.
    row_offset = (kv_len - sq) if row_offset is None else row_offset
    scale = d ** -0.5 if scale is None else scale

    grid = (b, hq, sq // bq, skv // bk)
    kernel = functools.partial(
        _flash_kernel,
        scale=scale, causal=causal, kv_len=kv_len, row_offset=row_offset,
        bq=bq, bk=bk,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec(
                (1, 1, bk, d), lambda b_, h, i, j, g=group: (b_, h // g, j, 0)
            ),
            pl.BlockSpec(
                (1, 1, bk, d), lambda b_, h, i, j, g=group: (b_, h // g, j, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
