"""Pure-jnp oracle for the commitment sweep kernel.

Weighted two-sided commitment mismatch areas over a candidate grid:

    over [p, g] = sum_t w[p,t] * max(f[p,t] - c[p,g], 0)
    under[p, g] = sum_t w[p,t] * max(c[p,g] - f[p,t], 0)

and the classic cost combination a*over + b*under.  Candidate grids are
per-pool (``cs (P, G)``); a shared 1-D grid is just a broadcast of the same
row.  The weight vector generalizes the paper's objective to masked prefixes
(Algorithm 1's 52 horizons are 52 weight patterns) and non-uniform hour
weighting.
"""

from __future__ import annotations

import jax.numpy as jnp


def commitment_sweep_over_under_ref(
    f: jnp.ndarray,
    w: jnp.ndarray,
    cs: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """f, w: (P, T); cs: (P, G) -> (over, under), each (P, G) in float32."""
    f = f.astype(jnp.float32)
    w = w.astype(jnp.float32)
    cs = cs.astype(jnp.float32)
    diff = f[:, None, :] - cs[:, :, None]  # (P, G, T)
    wexp = w[:, None, :]
    over = (jnp.maximum(diff, 0.0) * wexp).sum(-1)
    under = (jnp.maximum(-diff, 0.0) * wexp).sum(-1)
    return over, under


def commitment_sweep_ref(
    f: jnp.ndarray,
    w: jnp.ndarray,
    cs: jnp.ndarray,
    a: float = 2.1,
    b: float = 1.0,
) -> jnp.ndarray:
    """f, w: (P, T); cs: (P, G) or (G,) -> (P, G) in float32."""
    if cs.ndim == 1:
        cs = jnp.broadcast_to(cs[None, :], (f.shape[0], cs.shape[0]))
    over, under = commitment_sweep_over_under_ref(f, w, cs)
    return a * over + b * under
