"""Pure-jnp oracle for the commitment sweep kernel.

Weighted two-sided commitment cost over a candidate grid:

    out[p, g] = A * sum_t w[p,t] * max(f[p,t] - c[g], 0)
             + B * sum_t w[p,t] * max(c[g] - f[p,t], 0)

The weight vector generalizes the paper's objective to masked prefixes
(Algorithm 1's 52 horizons are 52 weight patterns) and non-uniform hour
weighting.
"""

from __future__ import annotations

import jax.numpy as jnp


def commitment_sweep_ref(
    f: jnp.ndarray,
    w: jnp.ndarray,
    cs: jnp.ndarray,
    a: float = 2.1,
    b: float = 1.0,
) -> jnp.ndarray:
    """f, w: (P, T); cs: (G,) -> (P, G) in float32."""
    f = f.astype(jnp.float32)
    w = w.astype(jnp.float32)
    cs = cs.astype(jnp.float32)
    diff = f[:, None, :] - cs[None, :, None]  # (P, G, T)
    over = jnp.maximum(diff, 0.0)
    under = jnp.maximum(-diff, 0.0)
    return ((a * over + b * under) * w[:, None, :]).sum(-1)
