"""Public jit'd wrapper for the commitment-sweep kernel: padding, block-size
selection, CPU-interpret fallback, and the grid+refine optimizer built on it."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.commitment_sweep.commitment_sweep import (
    commitment_sweep_kernel,
)
from repro.kernels.commitment_sweep.ref import (
    commitment_sweep_over_under_ref,
    commitment_sweep_ref,
)


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


#: VMEM budget for the kernel's (bp, bg, bt) broadcast temporary, bytes.
#: Well under the ~16 MB/core so the f/w/c blocks and double-buffering fit.
SWEEP_VMEM_BUDGET = 4 * 1024 * 1024

#: HBM-pass budget per sweep: each candidate tile beyond the first
#: re-streams the (P, T) demand trace from HBM (the t grid axis re-runs per
#: g tile), so a replanned week costs ``ceil(G / bg)`` trace passes.  The
#: fleet-scale replay (P ~ 1000 rows x 52 candidate levels per refine
#: stage, every cadence week) caps that re-read factor here and grows the
#: candidate tile ``bg`` instead.
SWEEP_HBM_PASS_BUDGET = 8


def sweep_block_plan(
    p: int,
    g: int,
    t: int,
    *,
    vmem_budget: int = SWEEP_VMEM_BUDGET,
    pass_budget: int = SWEEP_HBM_PASS_BUDGET,
) -> tuple[int, int, int]:
    """Choose kernel block sizes (bp, bg, bt) for a (P, G, T) sweep.

    Invariants (the R3 kernel contract plus the budgets):

    - every block is a lane/sublane multiple (bp of 8, bg/bt of 128) and
      divides its padded dim by construction (ops pads up to the block);
    - HBM passes over the trace, ``ceil(G / bg)``, stay <= ``pass_budget``:
      bg grows in lane multiples until the whole candidate grid fits in
      ``pass_budget`` tiles;
    - the (bp, bg, bt) fp32 broadcast temporary stays <= ``vmem_budget``:
      bt shrinks (to the 128 lane minimum) to pay for a wider bg.

    For every shape the planner issued before the fleet-scale work
    (G <= 128 * pass_budget) this returns exactly the historical
    ``(8, min(128, G_pad), min(512, T_pad))`` choice, so accumulation
    order — and the kernel's bit-exact outputs — are unchanged there.
    """
    bp = 8
    # Candidate tile: at least 128 (one lane row), grown so the padded
    # grid fits the pass budget.  VMEM is the hard constraint: bg never
    # exceeds what fits next to a minimum (128) time tile, even if that
    # costs extra HBM passes on a pathologically wide candidate grid.
    bg = max(128, 128 * -(-g // (128 * pass_budget)))
    bg = min(bg, _round_up(g, 128))
    bg_max = vmem_budget // (bp * 128 * 4) // 128 * 128
    bg = min(bg, max(bg_max, 128))
    # Time tile: historical 512 cap, shrunk while the broadcast tmp
    # overflows the VMEM budget (floor 128 — one lane row).
    bt = min(512, _round_up(t, 128))
    while bt > 128 and bp * bg * bt * 4 > vmem_budget:
        bt -= 128
    return bp, bg, bt


def commitment_sweep_over_under(
    f: jnp.ndarray,
    cs: jnp.ndarray,
    w: jnp.ndarray | None = None,
    *,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Raw over/under integrals for pools f (P, T) [or (T,)] over candidate
    levels cs (P, G), (G,) or (T,)-style 1-D grids.

    The 2-D sweep primitive: every pool gets its own candidate grid in one
    HBM pass.  Pads every dim to TPU-friendly multiples (weights zero on
    padding so padded hours contribute nothing; padded pools/candidates are
    sliced off; candidate padding reuses each pool's last level so no
    spurious extreme levels enter the padded lanes) and dispatches to the
    Pallas kernel (interpret mode off-TPU).
    """
    squeeze = f.ndim == 1
    if squeeze:
        f = f[None, :]
    p, t = f.shape
    if cs.ndim == 1:
        cs = jnp.broadcast_to(cs[None, :], (p, cs.shape[0]))
    g = cs.shape[-1]
    if w is None:
        w = jnp.ones_like(f)

    # Block sizes: VMEM + HBM-pass budgeted (historical choices for every
    # pre-fleet-scale shape; see sweep_block_plan).
    bp, bg, bt = sweep_block_plan(p, g, t)

    pp, gg, tt = _round_up(p, bp), _round_up(g, bg), _round_up(t, bt)
    f_pad = jnp.zeros((pp, tt), f.dtype).at[:p, :t].set(f)
    w_pad = jnp.zeros((pp, tt), w.dtype).at[:p, :t].set(w)
    c_pad = jnp.zeros((pp, gg), cs.dtype)
    c_pad = c_pad.at[:p, :].set(
        jnp.concatenate(
            [cs, jnp.broadcast_to(cs[:, -1:], (p, gg - g))], axis=-1
        )
        if gg > g else cs
    )

    if interpret is None:
        interpret = not _on_tpu()

    over, under = commitment_sweep_kernel(
        f_pad, w_pad, c_pad, bp=bp, bg=bg, bt=bt, interpret=interpret
    )
    over, under = over[:p, :g], under[:p, :g]
    if squeeze:
        over, under = over[0], under[0]
    return over, under


def commitment_sweep(
    f: jnp.ndarray,
    cs: jnp.ndarray,
    w: jnp.ndarray | None = None,
    *,
    a: float = 2.1,
    b: float = 1.0,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Cost curve C(c) for pools f (P, T) [or (T,)] over candidates cs,
    shared (G,) or per-pool (P, G).  Thin epilogue over the over/under
    sweep: costs = a*over + b*under."""
    over, under = commitment_sweep_over_under(f, cs, w, interpret=interpret)
    return a * over + b * under


@functools.partial(jax.jit, static_argnames=("num_coarse", "num_fine", "a", "b"))
def optimal_commitment_sweep(
    f: jnp.ndarray,
    *,
    a: float = 2.1,
    b: float = 1.0,
    num_coarse: int = 128,
    num_fine: int = 128,
) -> jnp.ndarray:
    """Grid+refine minimizer of C(c) on the *reference* path (jnp): coarse
    grid over [min, max], then a fine grid inside the best coarse bracket.
    Used for batched planner sweeps where the exact-quantile path would need
    a full sort per pool per horizon; matches it to ~(range/G^2) accuracy."""
    if f.ndim == 1:
        f = f[None, :]
    lo = f.min(-1)
    hi = f.max(-1)
    span = hi - lo

    def stage(lo, span, n):
        # (P, n) candidate grids per pool
        steps = jnp.arange(n, dtype=f.dtype) / (n - 1)
        cands = lo[:, None] + span[:, None] * steps[None, :]
        diff = f[:, None, :] - cands[:, :, None]
        costs = jnp.where(diff > 0, a * diff, -b * diff).sum(-1)
        best = jnp.argmin(costs, -1)
        c_best = jnp.take_along_axis(cands, best[:, None], 1)[:, 0]
        new_span = 2.0 * span / (n - 1)
        return jnp.maximum(c_best - span / (n - 1), lo), new_span, c_best

    lo1, span1, _ = stage(lo, span, num_coarse)
    _, _, c = stage(lo1, span1, num_fine)
    return c


def commitment_sweep_oracle(f, cs, w=None, a: float = 2.1, b: float = 1.0):
    """Reference path (exported for tests/benchmarks)."""
    if f.ndim == 1:
        f = f[None, :]
    if w is None:
        w = jnp.ones_like(f)
    return commitment_sweep_ref(f, w, cs, a, b)


def commitment_sweep_over_under_oracle(f, cs, w=None):
    """Reference path for the raw over/under sweep."""
    if f.ndim == 1:
        f = f[None, :]
    if cs.ndim == 1:
        cs = jnp.broadcast_to(cs[None, :], (f.shape[0], cs.shape[0]))
    if w is None:
        w = jnp.ones_like(f)
    return commitment_sweep_over_under_ref(f, w, cs)
