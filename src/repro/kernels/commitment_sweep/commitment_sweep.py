"""Pallas TPU kernel: one-HBM-pass commitment-cost sweep (paper §3.2).

Evaluates the two-sided mismatch areas for a whole candidate grid and a batch
of pools in a single pass over the demand trace.  This is the hot loop of the
planner: P pools x G candidate levels x T hours (multi-year hourly traces,
T ~ 26k) — bandwidth-bound, so the point of the kernel is to stream each
(pool, time) block of the trace HBM->VMEM exactly once and amortize it over
every candidate level resident in VMEM, instead of the naive G passes.

The sweep is 2-D on the candidate side: every pool carries its *own* grid of
candidate levels ``cs (P, G)`` (the grid+refine optimizer brackets each pool
separately, and the portfolio optimizer spans each pool's own demand range).
The kernel accumulates the raw over/under integrals

    over [p, g] = sum_t w[p,t] * max(f[p,t] - c[p,g], 0)
    under[p, g] = sum_t w[p,t] * max(c[p,g] - f[p,t], 0)

as two outputs instead of a single pre-weighted cost, so one pass serves any
(a, b) weighting — in particular all K cost lines of the §3 portfolio
optimizer — as a cheap (P, G) epilogue.

Grid: (P/bp, G/bg, T/bt), T innermost so the (bp, bg) output blocks are
revisited and accumulated across time blocks (out BlockSpecs ignore the t
grid index).  VMEM working set per step:
    f block     bp x bt        (demand)
    w block     bp x bt        (hour weights / horizon mask)
    c block     bp x bg        (per-pool candidate levels)
    out blocks  2 x bp x bg    (accumulated over/under, fp32)
    broadcast tmp bp x bg x bt — sized to stay well under VMEM (see ops.py)
All dims padded to TPU lane/sublane multiples by ops.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sweep_kernel(f_ref, w_ref, c_ref, over_ref, under_ref):
    t_idx = pl.program_id(2)

    @pl.when(t_idx == 0)
    def _init():
        over_ref[...] = jnp.zeros_like(over_ref)
        under_ref[...] = jnp.zeros_like(under_ref)

    f = f_ref[...].astype(jnp.float32)  # (bp, bt)
    w = w_ref[...].astype(jnp.float32)  # (bp, bt)
    c = c_ref[...].astype(jnp.float32)  # (bp, bg)

    diff = f[:, None, :] - c[:, :, None]             # (bp, bg, bt)
    wexp = w[:, None, :]
    over_ref[...] += (jnp.maximum(diff, 0.0) * wexp).sum(-1)
    under_ref[...] += (jnp.maximum(-diff, 0.0) * wexp).sum(-1)


@functools.partial(
    jax.jit,
    static_argnames=("bp", "bg", "bt", "interpret"),
)
def commitment_sweep_kernel(
    f: jnp.ndarray,
    w: jnp.ndarray,
    cs: jnp.ndarray,
    *,
    bp: int = 8,
    bg: int = 128,
    bt: int = 512,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """f, w: (P, T); cs: (P, G) -> (over, under), each (P, G) fp32.
    P % bp == G % bg == T % bt == 0 (ops.py handles padding)."""
    p, t = f.shape
    g = cs.shape[-1]
    grid = (p // bp, g // bg, t // bt)

    out_spec = pl.BlockSpec((bp, bg), lambda i, j, k: (i, j))
    return pl.pallas_call(
        _sweep_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bp, bt), lambda i, j, k: (i, k)),
            pl.BlockSpec((bp, bt), lambda i, j, k: (i, k)),
            pl.BlockSpec((bp, bg), lambda i, j, k: (i, j)),
        ],
        out_specs=[out_spec, out_spec],
        out_shape=[
            jax.ShapeDtypeStruct((p, g), jnp.float32),
            jax.ShapeDtypeStruct((p, g), jnp.float32),
        ],
        interpret=interpret,
    )(f, w, cs)
