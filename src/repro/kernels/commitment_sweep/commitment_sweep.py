"""Pallas TPU kernel: one-HBM-pass commitment-cost sweep (paper §3.2).

Evaluates the two-sided cost C(c) for a whole candidate grid and a batch of
pools in a single pass over the demand trace.  This is the hot loop of the
planner: P pools x G candidate levels x T hours (multi-year hourly traces,
T ~ 26k) — bandwidth-bound, so the point of the kernel is to stream each
(pool, time) block of the trace HBM->VMEM exactly once and amortize it over
every candidate level resident in VMEM, instead of the naive G passes.

Grid: (P/bp, G/bg, T/bt), T innermost so the (bp, bg) output block is
revisited and accumulated across time blocks (out BlockSpec ignores the t
grid index).  VMEM working set per step:
    f block   bp x bt        (demand)
    w block   bp x bt        (hour weights / horizon mask)
    c block   bg             (candidate levels)
    out block bp x bg        (accumulated costs, fp32)
    broadcast tmp bp x bg x bt  — sized to stay well under VMEM (see ops.py)
All dims padded to TPU lane/sublane multiples by ops.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sweep_kernel(f_ref, w_ref, c_ref, out_ref, *, a: float, b: float):
    t_idx = pl.program_id(2)

    @pl.when(t_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    f = f_ref[...].astype(jnp.float32)  # (bp, bt)
    w = w_ref[...].astype(jnp.float32)  # (bp, bt)
    c = c_ref[...].astype(jnp.float32)  # (bg,)

    diff = f[:, None, :] - c[None, :, None]          # (bp, bg, bt)
    hinge = jnp.where(diff > 0, a * diff, -b * diff)  # a*over + b*under
    out_ref[...] += (hinge * w[:, None, :]).sum(-1)


@functools.partial(
    jax.jit,
    static_argnames=("a", "b", "bp", "bg", "bt", "interpret"),
)
def commitment_sweep_kernel(
    f: jnp.ndarray,
    w: jnp.ndarray,
    cs: jnp.ndarray,
    *,
    a: float = 2.1,
    b: float = 1.0,
    bp: int = 8,
    bg: int = 128,
    bt: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """f, w: (P, T); cs: (G,) -> costs (P, G).  P % bp == G % bg == T % bt == 0
    (ops.py handles padding)."""
    p, t = f.shape
    (g,) = cs.shape
    grid = (p // bp, g // bg, t // bt)

    return pl.pallas_call(
        functools.partial(_sweep_kernel, a=a, b=b),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bp, bt), lambda i, j, k: (i, k)),
            pl.BlockSpec((bp, bt), lambda i, j, k: (i, k)),
            pl.BlockSpec((bg,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bp, bg), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((p, g), jnp.float32),
        interpret=interpret,
    )(f, w, cs)
