"""Pallas TPU kernel: chunked RWKV6 linear recurrence (paper-pool arch
``rwkv6-3b``; the same chunked pattern backs Jamba's Mamba layers in jnp).

The sequential recurrence is reformulated over chunks of length L: within a
chunk everything is dense matmul work (MXU), and the O(T) dependency is
carried as one (dk, dv) state per (batch, head) across the innermost,
sequentially-executed grid dimension.

Stability: all decay applications use exponentials of *non-positive* log
sums (w in (0,1] so logw <= 0):

    cum_t   = sum_{s<=t} logw_s                       (inclusive)
    y_t     = (r_t * exp(cum_{t-1})) . S_0
           + sum_{s<t} [sum_i r_ti k_si exp(cum_{t-1,i} - cum_{s,i})] v_s
           + (r_t . (u * k_t)) v_t
    S_L     = diag(exp(cum_L)) S_0 + sum_s (k_s * exp(cum_L - cum_s)) (x) v_s

The intra-chunk term keeps the 3-index decay tensor (L, L, dk) in VMEM
rather than factorizing it into r~ = r*exp(cum) / k~ = k*exp(-cum) — the
factored form overflows fp32 for strong decays (exp(-cum) up to e^{+L|logw|}).
Production TPU kernels would split this into log2(L) levels of secondary
chunking to land on the MXU; at L=32 the VPU einsum is ~L/dk of total FLOPs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rwkv6_kernel(
    r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref,
    y_ref, sout_ref, s_scratch,
    *, chunk: int,
):
    ic = pl.program_id(2)
    num_c = pl.num_programs(2)

    @pl.when(ic == 0)
    def _init():
        s_scratch[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)    # (L, dk)
    k = k_ref[0, 0].astype(jnp.float32)    # (L, dk)
    v = v_ref[0, 0].astype(jnp.float32)    # (L, dv)
    lw = lw_ref[0, 0].astype(jnp.float32)  # (L, dk) log-decay (<= 0)
    u = u_ref[0].astype(jnp.float32)       # (dk,)
    s = s_scratch[...]                     # (dk, dv)

    cum = jnp.cumsum(lw, axis=0)           # inclusive (L, dk)
    cum_prev = cum - lw                    # exclusive c_{t-1}

    # Contribution of the carried-in state.
    r_dec = r * jnp.exp(cum_prev)          # (L, dk)
    y = jax.lax.dot_general(
        r_dec, s, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                      # (L, dv)

    # Intra-chunk attention with per-channel relative decay.
    decay = jnp.exp(cum_prev[:, None, :] - cum[None, :, :])  # (L, L, dk)
    att = jnp.einsum("ti,si,tsi->ts", r, k, decay)           # (L, L)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    att = jnp.where(t_idx > s_idx, att, 0.0)  # strict causal
    diag = (r * u[None, :] * k).sum(-1)       # (L,) current-token bonus
    y = y + jax.lax.dot_general(
        att, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) + diag[:, None] * v
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # State propagation across the chunk.
    total = cum[-1]                                        # (dk,)
    k_dec = k * jnp.exp(total[None, :] - cum)              # (L, dk)
    s_new = jnp.exp(total)[:, None] * s + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    s_scratch[...] = s_new

    @pl.when(ic == num_c - 1)
    def _final():
        sout_ref[0, 0] = s_new.astype(sout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_kernel(
    r: jnp.ndarray,   # (B, H, T, dk), T % chunk == 0
    k: jnp.ndarray,
    v: jnp.ndarray,   # (B, H, T, dv)
    logw: jnp.ndarray,  # (B, H, T, dk), <= 0
    u: jnp.ndarray,   # (H, dk)
    s0: jnp.ndarray,  # (B, H, dk, dv)
    *,
    chunk: int = 32,
    interpret: bool = False,
):
    b, h, t, dk = r.shape
    dv = v.shape[-1]
    grid = (b, h, t // chunk)

    seq_spec = lambda d: pl.BlockSpec(  # noqa: E731
        (1, 1, chunk, d), lambda b_, h_, c: (b_, h_, c, 0)
    )
    return pl.pallas_call(
        functools.partial(_rwkv6_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            seq_spec(dk), seq_spec(dk), seq_spec(dv), seq_spec(dk),
            pl.BlockSpec((1, dk), lambda b_, h_, c: (h_, 0)),
            pl.BlockSpec((1, 1, dk, dv), lambda b_, h_, c: (b_, h_, 0, 0)),
        ],
        out_specs=(
            seq_spec(dv),
            pl.BlockSpec((1, 1, dk, dv), lambda b_, h_, c: (b_, h_, 0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b, h, t, dv), jnp.float32),
            jax.ShapeDtypeStruct((b, h, dk, dv), jnp.float32),
        ),
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u, s0)
