"""Pure-jnp oracle for the RWKV6-style linear recurrence.

Per head, with state S in R^{dk x dv}, data-dependent decay w_t in (0,1]^dk,
bonus u in R^dk:

    y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

Sequential lax.scan — the oracle for the chunked Pallas kernel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv6_ref(
    r: jnp.ndarray,  # (B, H, T, dk)
    k: jnp.ndarray,  # (B, H, T, dk)
    v: jnp.ndarray,  # (B, H, T, dv)
    w: jnp.ndarray,  # (B, H, T, dk) decay in (0, 1]
    u: jnp.ndarray,  # (H, dk) bonus
    state: jnp.ndarray | None = None,  # (B, H, dk, dv)
):
    """Returns (y (B,H,T,dv), final_state (B,H,dk,dv)) in float32."""
    b, h, t, dk = r.shape
    dv = v.shape[-1]
    f32 = jnp.float32
    r, k, v, w = (x.astype(f32) for x in (r, k, v, w))
    u = u.astype(f32)
    s0 = (
        jnp.zeros((b, h, dk, dv), f32)
        if state is None
        else state.astype(f32)
    )

    def step(s, inp):
        r_t, k_t, v_t, w_t, u_h = inp  # (B,H,dk) ... u_h (H,dk)
        kv = k_t[..., :, None] * v_t[..., None, :]      # (B,H,dk,dv)
        att = s + u_h[None, :, :, None] * kv            # bonus on current
        y = jnp.einsum("bhk,bhkv->bhv", r_t, att)
        s_new = w_t[..., :, None] * s + kv
        return s_new, y

    inputs = (
        r.transpose(2, 0, 1, 3),
        k.transpose(2, 0, 1, 3),
        v.transpose(2, 0, 1, 3),
        w.transpose(2, 0, 1, 3),
        jnp.broadcast_to(u, (t, h, dk)),
    )
    s_final, ys = jax.lax.scan(step, s0, inputs)
    return ys.transpose(1, 2, 0, 3), s_final
