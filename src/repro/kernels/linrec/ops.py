"""Public RWKV6 linear-attention op: padding, interpret fallback, decode step.

``rwkv6_linear_attention`` handles full sequences (train/prefill);
``rwkv6_step`` is the O(1)-state decode step (the long_500k enabler: no KV
cache grows with context)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.linrec.linrec import rwkv6_kernel
from repro.kernels.linrec.ref import rwkv6_ref


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def rwkv6_linear_attention(
    r: jnp.ndarray,   # (B, H, T, dk)
    k: jnp.ndarray,
    v: jnp.ndarray,   # (B, H, T, dv)
    w: jnp.ndarray,   # (B, H, T, dk) decay in (0, 1]
    u: jnp.ndarray,   # (H, dk)
    state: jnp.ndarray | None = None,
    *,
    chunk: int = 32,
    interpret: bool | None = None,
):
    """Returns (y (B,H,T,dv) f32, final_state (B,H,dk,dv) f32)."""
    b, h, t, dk = r.shape
    dv = v.shape[-1]
    if state is None:
        state = jnp.zeros((b, h, dk, dv), jnp.float32)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    tp = _round_up(t, chunk)
    if tp != t:
        pad = ((0, 0), (0, 0), (0, tp - t), (0, 0))
        # Padding steps: r=k=v=0, w=1 (logw=0) -> y=0, state unchanged.
        r = jnp.pad(r, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, ((0, 0), (0, 0), (0, tp - t), (0, 0)))
        w = jnp.pad(w, pad, constant_values=1.0)

    logw = jnp.log(jnp.clip(w.astype(jnp.float32), 1e-30, 1.0))
    y, s_out = rwkv6_kernel(
        r, k, v, logw, u, state, chunk=chunk, interpret=interpret
    )
    return y[:, :, :t, :], s_out


def rwkv6_step(
    r: jnp.ndarray,   # (B, H, dk) single token
    k: jnp.ndarray,
    v: jnp.ndarray,   # (B, H, dv)
    w: jnp.ndarray,   # (B, H, dk)
    u: jnp.ndarray,   # (H, dk)
    state: jnp.ndarray,  # (B, H, dk, dv)
):
    """One decode step: y (B,H,dv), new state. Pure jnp (no kernel needed —
    a single outer product per head)."""
    f32 = jnp.float32
    r, k, v, w = (x.astype(f32) for x in (r, k, v, w))
    kv = k[..., :, None] * v[..., None, :]
    att = state + u.astype(f32)[None, :, :, None] * kv
    y = jnp.einsum("bhk,bhkv->bhv", r, att)
    new_state = w[..., :, None] * state + kv
    return y, new_state


def rwkv6_oracle(r, k, v, w, u, state=None):
    return rwkv6_ref(r, k, v, w, u, state)
