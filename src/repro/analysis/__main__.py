"""CLI: ``python -m repro.analysis [--json] [--baseline PATH] [--root PATH]``.

Exit codes: 0 = clean (no non-baselined findings), 1 = findings,
2 = configuration error (malformed baseline / unjustified suppression).
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis.engine import run_analysis, write_baseline
from repro.analysis.rules import ALL_RULES, RULES_BY_ID


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static analyzer for the repo's trace-safety, "
                    "determinism, and contract invariants.",
    )
    parser.add_argument(
        "--root", default=".",
        help="repo root (contains src/, tests/, README.md); default: cwd",
    )
    parser.add_argument(
        "--baseline", default=None,
        help="baseline file (default: <root>/baseline.json)",
    )
    parser.add_argument(
        "--json", action="store_true",
        help="emit findings as JSON on stdout (for CI artifacts)",
    )
    parser.add_argument(
        "--rule", action="append", default=None, metavar="ID",
        choices=sorted(RULES_BY_ID),
        help="run only the given rule(s); repeatable",
    )
    parser.add_argument(
        "--write-baseline", action="store_true",
        help="write current findings to the baseline file as entries with "
             "EMPTY justifications — each must be hand-justified before the "
             "baseline is accepted",
    )
    args = parser.parse_args(argv)

    root = Path(args.root)
    if not (root / "src").is_dir():
        print(f"error: {root} does not look like the repo root "
              "(no src/ directory)", file=sys.stderr)
        return 2

    rules = [RULES_BY_ID[r] for r in args.rule] if args.rule else None
    report = run_analysis(root, baseline_path=args.baseline, rules=rules)

    if args.write_baseline:
        bpath = Path(args.baseline) if args.baseline else root / "baseline.json"
        write_baseline(report, bpath)
        print(f"wrote {len(report.findings)} entr"
              f"{'y' if len(report.findings) == 1 else 'ies'} to {bpath} — "
              "fill in every justification before committing")
        return 0

    if args.json:
        json.dump(report.to_dict(), sys.stdout, indent=2)
        print()
    else:
        for f in report.unsuppressed:
            print(f.render())
        for key in report.stale_suppressions:
            print(f"warning: stale baseline entry (matches nothing): {key}",
                  file=sys.stderr)
        for e in report.errors:
            print(f"error: {e}", file=sys.stderr)
        n, s = len(report.unsuppressed), len(report.suppressed)
        print(f"repro.analysis: {n} finding{'s' if n != 1 else ''}"
              + (f" ({s} baselined)" if s else "")
              + f" across {len(ALL_RULES) if rules is None else len(rules)}"
              " rules")

    if report.errors:
        return 2
    return 1 if report.unsuppressed else 0


if __name__ == "__main__":
    sys.exit(main())
