"""Shared AST helpers for the static-analysis rules: import resolution,
dotted-name rendering, symbol tables, and the static-expression classifier
used by the trace-purity rule.

Everything here is pure ``ast`` — the analyzer never imports the code it
checks, so a module with a missing optional dependency (or a planted
violation in a test fixture) still analyzes fine.
"""

from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Iterator

#: attribute chains whose value is shape/layout metadata, not array data —
#: reading (or branching on) them is trace-safe.
SHAPE_ATTRS = frozenset({"shape", "ndim", "size", "dtype"})

#: numpy attributes that are dtypes/constants, safe to reference under trace.
NUMPY_SAFE_ATTRS = frozenset({
    "float16", "float32", "float64", "int8", "int16", "int32", "int64",
    "uint8", "uint16", "uint32", "uint64", "bool_", "complex64",
    "complex128", "ndarray", "generic", "number", "integer", "floating",
    "dtype", "finfo", "iinfo", "newaxis", "pi", "inf", "nan", "e",
    "euler_gamma",
})


def iter_py_files(root: Path) -> Iterator[Path]:
    """All .py files under ``root``, skipping caches, sorted for stable
    finding order."""
    if not root.is_dir():
        return
    for p in sorted(root.rglob("*.py")):
        if "__pycache__" in p.parts:
            continue
        yield p


def module_name_for(path: Path, src_root: Path) -> str:
    """Dotted module name of ``path`` relative to ``src_root``
    (``src/repro/core/planner.py`` -> ``repro.core.planner``)."""
    rel = path.relative_to(src_root).with_suffix("")
    parts = list(rel.parts)
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def dotted(node: ast.AST) -> str | None:
    """Render a Name/Attribute chain as ``a.b.c``; None for anything else."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


@dataclasses.dataclass
class ImportMap:
    """Name bindings a module's top-level imports introduce.

    ``aliases`` maps a local name to the full dotted module it stands for
    (``np`` -> ``numpy``, ``gn`` -> ``repro.capacity.generations``);
    ``from_imports`` maps a local name to ``(module, original_name)`` for
    ``from module import original as local``.
    """

    aliases: dict[str, str] = dataclasses.field(default_factory=dict)
    from_imports: dict[str, tuple[str, str]] = dataclasses.field(
        default_factory=dict
    )

    def resolve(self, dotted_name: str) -> str:
        """Expand the leading component of ``a.b.c`` through the module's
        imports, returning a fully-qualified dotted name.  Unknown leading
        names pass through unchanged."""
        head, _, rest = dotted_name.partition(".")
        if head in self.aliases:
            base = self.aliases[head]
        elif head in self.from_imports:
            mod, orig = self.from_imports[head]
            base = f"{mod}.{orig}"
        else:
            return dotted_name
        return f"{base}.{rest}" if rest else base


def import_map(tree: ast.Module) -> ImportMap:
    m = ImportMap()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                m.aliases[a.asname or a.name.partition(".")[0]] = (
                    a.name if a.asname else a.name.partition(".")[0]
                )
                if a.asname is None and "." in a.name:
                    # `import a.b.c` binds `a`; remember the full path too so
                    # `a.b.c.f()` resolves without guessing.
                    m.aliases.setdefault(a.name, a.name)
        elif isinstance(node, ast.ImportFrom) and node.module and not node.level:
            for a in node.names:
                if a.name == "*":
                    continue
                m.from_imports[a.asname or a.name] = (node.module, a.name)
    return m


def top_level_symbols(tree: ast.Module) -> set[str]:
    """Names a module defines or re-exports at top level."""
    out: set[str] = set()
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            out.add(node.name)
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                for n in ast.walk(t):
                    if isinstance(n, ast.Name):
                        out.add(n.id)
        elif isinstance(node, ast.AnnAssign) and isinstance(node.target, ast.Name):
            out.add(node.target.id)
        elif isinstance(node, ast.Import):
            for a in node.names:
                out.add(a.asname or a.name.partition(".")[0])
        elif isinstance(node, ast.ImportFrom):
            for a in node.names:
                if a.name != "*":
                    out.add(a.asname or a.name)
    return out


def func_params(fn: ast.FunctionDef | ast.AsyncFunctionDef | ast.Lambda):
    """(positional_names, kwonly_names) of a function/lambda signature."""
    a = fn.args
    pos = [p.arg for p in a.posonlyargs + a.args]
    if a.vararg:
        pos.append(a.vararg.arg)
    kw = [p.arg for p in a.kwonlyargs]
    if a.kwarg:
        kw.append(a.kwarg.arg)
    return pos, kw


def is_shape_attr_chain(node: ast.AST) -> bool:
    """True for ``x.shape``, ``x.shape[0]``, ``x.ndim`` ... — metadata reads
    that never force a tracer to a concrete value."""
    while isinstance(node, ast.Subscript):
        node = node.value
    return isinstance(node, ast.Attribute) and node.attr in SHAPE_ATTRS


class StaticEnv:
    """Classifies expressions inside one traced function as *static*
    (resolvable at trace time: config, shapes, python ints) or potentially
    tracer-valued.

    Positional parameters start out tracer-valued; keyword-only parameters
    and parameters named in ``static_names`` (e.g. ``jax.jit``
    ``static_argnames``) start static.  Locals become static when assigned a
    static expression — shape unpacks (``p, t = f.shape``), ``len()``,
    constants, and arithmetic over static names all qualify.  Names bound
    outside the function (globals, closure captures) are assumed static:
    the analyzer cannot see them, and flagging every closure read would
    drown real findings (a documented limitation).
    """

    def __init__(self, fn, static_names: frozenset[str] = frozenset()):
        pos, kw = func_params(fn)
        self.tracer_names: set[str] = {
            p for p in pos
            if p not in static_names and not self._static_annotation(fn, p)
        }
        self.local_names: set[str] = set(pos) | set(kw)
        body = fn.body if isinstance(fn.body, list) else [ast.Expr(fn.body)]
        # Two passes so forward references inside straight-line bodies
        # settle (a = b; b = x.shape style orderings are rare but cheap to
        # cover).
        for _ in range(2):
            for node in ast.walk(ast.Module(body=body, type_ignores=[])):
                if isinstance(node, ast.Assign):
                    targets = node.targets
                elif isinstance(node, ast.AnnAssign) and node.value is not None:
                    targets = [node.target]
                else:
                    continue
                static_rhs = self.is_static(node.value)
                for t in targets:
                    for n in ast.walk(t):
                        if isinstance(n, ast.Name):
                            self.local_names.add(n.id)
                            if static_rhs:
                                self.tracer_names.discard(n.id)
                            else:
                                self.tracer_names.add(n.id)

    @staticmethod
    def _static_annotation(fn, param: str) -> bool:
        """A parameter annotated with a non-array type (config dataclass,
        str, int, ...) is trace-static: tracers only flow through
        array-typed (or unannotated) parameters."""
        if isinstance(fn, ast.Lambda):
            return False
        for a in fn.args.posonlyargs + fn.args.args + fn.args.kwonlyargs:
            if a.arg == param and a.annotation is not None:
                try:
                    text = ast.unparse(a.annotation)
                except Exception:
                    return False
                return not any(
                    hint in text for hint in ("ndarray", "Array", "array")
                )
        return False

    def is_static(self, expr: ast.AST) -> bool:
        """True when no tracer-valued *data* feeds the expression."""
        for node in ast.walk(expr):
            if isinstance(node, ast.Name) and node.id in self.tracer_names:
                if not self._inside_shape_read(expr, node):
                    return False
        return True

    @staticmethod
    def _inside_shape_read(root: ast.AST, name: ast.Name) -> bool:
        """Is this Name occurrence under an ``.shape``/``.ndim``/... read?"""
        for node in ast.walk(root):
            if isinstance(node, ast.Attribute) and node.attr in SHAPE_ATTRS:
                for sub in ast.walk(node):
                    if sub is name:
                        return True
            if isinstance(node, ast.Call):
                f = node.func
                if isinstance(f, ast.Name) and f.id == "len":
                    for sub in ast.walk(node):
                        if sub is name:
                            return True
        return False
