"""Rule registry.  Adding a rule = write a module defining a ``rule``
object (see any sibling) and list it here; the engine, CLI, baseline, and
fixture-test harness pick it up with no further wiring."""

from repro.analysis.rules import (
    r1_trace_purity,
    r2_determinism,
    r3_kernel_contract,
    r4_pricing_guard,
    r5_golden_coverage,
    r6_doc_drift,
    r7_telemetry,
)

ALL_RULES = [
    r1_trace_purity.rule,
    r2_determinism.rule,
    r3_kernel_contract.rule,
    r4_pricing_guard.rule,
    r5_golden_coverage.rule,
    r6_doc_drift.rule,
    r7_telemetry.rule,
]

RULES_BY_ID = {r.id: r for r in ALL_RULES}
