"""R7 — telemetry hygiene.

Two contracts introduced with the observability layer (``repro.obs``):

* **No side-channel output in traced code.**  ``print`` / ``logging.*``
  calls inside a traced function (scan bodies, jit/vmap targets, kernels —
  the same :class:`~repro.analysis.callgraph.CallGraph` set R1 walks) fire
  at *trace time*, not per step: they print once during compilation and
  then never again, which reads as telemetry but measures nothing.  Real
  per-step observability flows through the cost-attribution ledger
  (``telemetry=`` on the planner) or host-side callbacks — never ambient
  stdout from inside a trace.

* **``repro.obs.spans`` is the only wall-clock entry point.**  R2 already
  bans clock reads from the determinism-scoped packages; R7 extends the
  ban to *all* of ``src/repro`` so timing is uniformly recorded as spans
  (``SpanRecorder``) instead of ad-hoc ``time.time()`` pairs — one
  profiler, one report format, one place a clock is read.  The single
  sanctioned read site is ``repro/obs/spans.py`` itself.

Benchmarks and examples live outside ``src/`` and are not scanned; they
are the intended *consumers* of the span profiler, not subjects of it.
"""

from __future__ import annotations

import ast

from repro.analysis.astutils import dotted
from repro.analysis.callgraph import CallGraph
from repro.analysis.engine import Finding, Rule
from repro.analysis.rules.r2_determinism import (
    CLOCK_CALLS,
    _in_scope as _r2_scope,
)

#: the one module allowed to read a wall clock (the span profiler).
CLOCK_ALLOWLIST = ("src/repro/obs/spans.py",)


def _logging_target(node: ast.Call, imports) -> str | None:
    """Resolve ``logging.info(...)``-style calls; None if not logging."""
    name = dotted(node.func)
    if name is None:
        return None
    full = imports.resolve(name)
    if full == "logging" or full.startswith("logging."):
        return full
    return None


def run(ctx) -> list[Finding]:
    findings: list[Finding] = []

    # -- (a) print/logging inside traced functions -------------------------
    graph = CallGraph(ctx)
    for tf in graph.traced:
        info = tf.module
        rel = ctx.relpath(info.path)
        fname = tf.name
        body = tf.node.body if isinstance(tf.node.body, list) \
            else [ast.Expr(tf.node.body)]

        # Nested defs are traced in their own right; don't double-report.
        nested: set[int] = set()
        for stmt in body:
            for node in ast.walk(stmt):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.Lambda)) and node is not tf.node:
                    for sub in ast.walk(node):
                        nested.add(id(sub))
                    nested.discard(id(node))

        def emit(node, detail, message):
            findings.append(Finding(
                rule="R7", file=rel, line=getattr(node, "lineno", 0),
                key=f"R7:{rel}:{fname}:{detail}",
                message=f"in traced `{fname}` ({tf.entry}): {message}",
            ))

        for stmt in body:
            for node in ast.walk(stmt):
                if id(node) in nested or not isinstance(node, ast.Call):
                    continue
                callee = node.func
                if isinstance(callee, ast.Name) and callee.id == "print":
                    emit(node, "print",
                         "`print()` inside a trace fires once at compile "
                         "time, not per step; route telemetry through the "
                         "ledger/spans instead")
                    continue
                log = _logging_target(node, info.imports)
                if log is not None:
                    emit(node, log,
                         f"`{log}()` inside a trace fires at compile time, "
                         "not per step; it is not telemetry")

    # -- (b) wall-clock reads outside repro.obs.spans ----------------------
    for info in ctx.modules.values():
        rel = ctx.relpath(info.path)
        # R2 already polices its determinism scopes; the span profiler is
        # the sanctioned read site.
        if _r2_scope(rel) or rel in CLOCK_ALLOWLIST:
            continue
        imports = info.imports

        def cemit(node, detail, message):
            findings.append(Finding(
                rule="R7", file=rel, line=getattr(node, "lineno", 0),
                key=f"R7:{rel}:{detail}",
                message=message,
            ))

        for node in ast.walk(info.tree):
            if isinstance(node, ast.ImportFrom) and node.module == "time":
                for a in node.names:
                    if f"time.{a.name}" in CLOCK_CALLS:
                        cemit(node, f"import-time.{a.name}",
                              f"`from time import {a.name}`: wall-clock "
                              "reads belong in repro.obs.spans "
                              "(SpanRecorder), the one sanctioned timer")

        handled: set[int] = set()
        for node in ast.walk(info.tree):
            if isinstance(node, ast.Call):
                name = dotted(node.func)
                if name is None:
                    continue
                full = imports.resolve(name)
                if full in CLOCK_CALLS:
                    handled.add(id(node.func))
                    cemit(node, full,
                          f"`{full}()` outside repro.obs.spans; record a "
                          "span with SpanRecorder instead of an ad-hoc "
                          "timer")
        for node in ast.walk(info.tree):
            if isinstance(node, ast.Attribute) and id(node) not in handled:
                name = dotted(node)
                if name is None:
                    continue
                full = imports.resolve(name)
                if full in CLOCK_CALLS:
                    cemit(node, full,
                          f"reference to wall-clock `{full}` outside "
                          "repro.obs.spans")
    return findings


rule = Rule(
    id="R7",
    title="telemetry hygiene: no prints in traces, spans own the clock",
    run=run,
)
