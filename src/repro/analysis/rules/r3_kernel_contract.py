"""R3 — Pallas kernel contract (docs/ARCHITECTURE.md "Pallas kernel
contract", made machine-checked).

Every ``src/repro/kernels/<name>/`` directory must be the ops/ref/kernel
triad:

* **files** — ``<name>.py`` (the ``pallas_call`` kernel), ``ops.py`` (the
  public padded/interpret-fallback entry point), ``ref.py`` (the pure-jnp
  oracle that defines the semantics);
* **ref purity** — ``ref.py`` must not import pallas (the oracle is the
  spec, it cannot be the implementation);
* **ops is the only entry point** — no module outside the kernel directory
  may import the raw kernel module ``repro.kernels.<name>.<name>``;
* **signature agreement** — each public ``*_ref`` oracle must have an ops
  counterpart whose signature covers the oracle's positional parameters,
  with matching dtype annotations wherever both sides annotate the same
  parameter (``X | None`` on the ops side matches ``X`` on the ref side:
  optionality is an ops-level convenience);
* **BlockSpec divisibility** — inside the kernel file, every name used as a
  BlockSpec block dimension must be either a divisor in the
  ``pallas_call`` grid expression (``grid=(p // bp, ...)`` makes ``bp``
  structurally divide the padded dim) or a shape-derived full-dimension
  size; a free block-size name is exactly the "block doesn't tile the
  grid" bug;
* **tolerance test** — ``tests/test_kernels.py`` must exercise the kernel's
  ops entry point against the ref inside a test that asserts a tolerance
  (``assert_allclose``/``allclose``).
"""

from __future__ import annotations

import ast

from repro.analysis.astutils import dotted, func_params
from repro.analysis.engine import Finding, Rule


def _kernel_dirs(ctx):
    kroot = ctx.src_root / "repro" / "kernels"
    if not kroot.is_dir():
        return []
    return sorted(
        d for d in kroot.iterdir()
        if d.is_dir() and any(d.glob("*.py"))
    )


def _public_functions(tree: ast.Module):
    return [n for n in tree.body
            if isinstance(n, ast.FunctionDef) and not n.name.startswith("_")]


def _normalize_ann(node: ast.AST | None) -> str | None:
    """Annotation as comparable text; optionality stripped (`X | None` ==
    `X`, `Optional[X]` == `X`)."""
    if node is None:
        return None
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        try:
            node = ast.parse(node.value, mode="eval").body
        except SyntaxError:
            return node.value.strip()
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.BitOr):
        sides = [_normalize_ann(node.left), _normalize_ann(node.right)]
        sides = [s for s in sides if s != "None"]
        if len(sides) == 1:
            return sides[0]
    if isinstance(node, ast.Subscript):
        base = dotted(node.value)
        if base is not None and base.rsplit(".", 1)[-1] == "Optional":
            return _normalize_ann(node.slice)
    try:
        return ast.unparse(node).replace(" ", "")
    except Exception:
        return None


def _annotations(fn: ast.FunctionDef) -> dict[str, str]:
    out = {}
    for a in fn.args.posonlyargs + fn.args.args + fn.args.kwonlyargs:
        ann = _normalize_ann(a.annotation)
        if ann is not None:
            out[a.arg] = ann
    return out


def _positional_params(fn: ast.FunctionDef) -> list[str]:
    return [a.arg for a in fn.args.posonlyargs + fn.args.args]


def _all_params(fn: ast.FunctionDef) -> set[str]:
    pos, kw = func_params(fn)
    return set(pos) | set(kw)


def _shape_derived_names(tree: ast.Module) -> set[str]:
    """Names assigned from `.shape` unpacks / subscripts / `len()` anywhere
    in the module — full-dimension sizes a block may legitimately span."""
    out: set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign):
            continue
        v = node.value
        derived = False
        vv = v
        while isinstance(vv, ast.Subscript):
            vv = vv.value
        if isinstance(vv, ast.Attribute) and vv.attr == "shape":
            derived = True
        if isinstance(v, ast.Call) and isinstance(v.func, ast.Name) \
                and v.func.id == "len":
            derived = True
        if derived:
            for t in node.targets:
                for n in ast.walk(t):
                    if isinstance(n, ast.Name):
                        out.add(n.id)
    return out


def _local_assignments(fn: ast.FunctionDef) -> dict[str, ast.AST]:
    out = {}
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Name):
            out[node.targets[0].id] = node.value
    return out


def _check_blockspecs(ctx, info, rel, kname, findings):
    """Grid-divisibility of BlockSpec block shapes in the kernel file."""
    imports = info.imports
    shape_names = _shape_derived_names(info.tree)

    for node in ast.walk(info.tree):
        if not (isinstance(node, ast.Call) and dotted(node.func) is not None
                and imports.resolve(dotted(node.func)).endswith("pallas_call")):
            continue
        # Find the enclosing function to resolve `grid = (...)` locals.
        enclosing = None
        for fn in ast.walk(info.tree):
            if isinstance(fn, ast.FunctionDef) and any(
                    n is node for n in ast.walk(fn)):
                enclosing = fn
        local = _local_assignments(enclosing) if enclosing else {}
        lambda_params: set[str] = set()
        for fn in ast.walk(info.tree):
            if isinstance(fn, ast.Lambda):
                pos, kw = func_params(fn)
                lambda_params.update(pos)
                lambda_params.update(kw)

        grid_expr = None
        for kw in node.keywords:
            if kw.arg == "grid":
                grid_expr = kw.value
        if grid_expr is None:
            continue
        if isinstance(grid_expr, ast.Name):
            grid_expr = local.get(grid_expr.id, grid_expr)
        divisors: set[str] = set()
        elts = grid_expr.elts if isinstance(grid_expr, ast.Tuple) else [grid_expr]
        for e in elts:
            if isinstance(e, ast.BinOp) and isinstance(e.op, ast.FloorDiv):
                for n in ast.walk(e.right):
                    if isinstance(n, ast.Name):
                        divisors.add(n.id)
        if not divisors:
            continue  # grid of whole dims only: nothing to tile-check

        allowed = divisors | shape_names | lambda_params
        for spec in ast.walk(info.tree):
            # BlockSpec calls anywhere in the kernel file describe this
            # kernel's tiling (spec factories may be helpers outside the
            # pallas_call expression itself).
            if not (isinstance(spec, ast.Call) and dotted(spec.func) is not None
                    and dotted(spec.func).rsplit(".", 1)[-1] == "BlockSpec"
                    and spec.args):
                continue
            shape = spec.args[0]
            if not isinstance(shape, ast.Tuple):
                continue
            for dim in shape.elts:
                for n in ast.walk(dim):
                    if isinstance(n, ast.Name) and n.id not in allowed:
                        findings.append(Finding(
                            rule="R3", file=rel, line=n.lineno,
                            key=f"R3:{rel}:blockspec:{n.id}",
                            message=(
                                f"kernel `{kname}`: BlockSpec block dim uses "
                                f"`{n.id}`, which is neither a grid divisor "
                                f"({', '.join(sorted(divisors))}) nor a "
                                "shape-derived full dimension — the block "
                                "cannot be shown to tile the padded grid"
                            ),
                        ))


def run(ctx) -> list[Finding]:
    findings: list[Finding] = []
    kdirs = _kernel_dirs(ctx)
    test_mod = ctx.tests.get("test_kernels.py")

    for kdir in kdirs:
        kname = kdir.name
        rel_dir = ctx.relpath(kdir)
        required = {f"{kname}.py", "ops.py", "ref.py"}
        present = {p.name for p in kdir.glob("*.py")}
        for missing in sorted(required - present):
            findings.append(Finding(
                rule="R3", file=rel_dir, line=0,
                key=f"R3:{rel_dir}:missing:{missing}",
                message=(f"kernel `{kname}` is missing `{missing}` — every "
                         "kernel ships the ops/ref/kernel triad"),
            ))
        mod_prefix = f"repro.kernels.{kname}"
        ops_info = ctx.modules.get(f"{mod_prefix}.ops")
        ref_info = ctx.modules.get(f"{mod_prefix}.ref")
        kern_info = ctx.modules.get(f"{mod_prefix}.{kname}")

        # ref purity: the oracle must not import pallas.
        if ref_info is not None:
            for node in ast.walk(ref_info.tree):
                mods = []
                if isinstance(node, ast.Import):
                    mods = [a.name for a in node.names]
                elif isinstance(node, ast.ImportFrom) and node.module:
                    mods = [node.module]
                for m in mods:
                    if "pallas" in m:
                        rel = ctx.relpath(ref_info.path)
                        findings.append(Finding(
                            rule="R3", file=rel, line=node.lineno,
                            key=f"R3:{rel}:ref-imports-pallas",
                            message=(f"kernel `{kname}`: ref.py imports "
                                     f"`{m}` — the oracle must stay pure "
                                     "jnp"),
                        ))

        # signature agreement ref -> ops.
        if ops_info is not None and ref_info is not None:
            ops_funcs = _public_functions(ops_info.tree)
            for rfn in _public_functions(ref_info.tree):
                counterpart = _match_ops(rfn, ops_funcs)
                rel = ctx.relpath(ref_info.path)
                if counterpart is None:
                    findings.append(Finding(
                        rule="R3", file=rel, line=rfn.lineno,
                        key=f"R3:{rel}:no-ops-counterpart:{rfn.name}",
                        message=(f"kernel `{kname}`: oracle `{rfn.name}` has "
                                 "no public ops.py counterpart covering its "
                                 "positional parameters"),
                    ))
                    continue
                o_ann = _annotations(counterpart)
                for pname, r_ann in _annotations(rfn).items():
                    oa = o_ann.get(pname)
                    if oa is not None and oa != r_ann:
                        findings.append(Finding(
                            rule="R3", file=rel, line=rfn.lineno,
                            key=f"R3:{rel}:ann:{rfn.name}:{pname}",
                            message=(
                                f"kernel `{kname}`: `{rfn.name}` annotates "
                                f"`{pname}: {r_ann}` but ops "
                                f"`{counterpart.name}` annotates `{oa}` — "
                                "the oracle and entry point disagree on the "
                                "contract dtype"
                            ),
                        ))

        # BlockSpec divisibility in the kernel file.
        if kern_info is not None:
            _check_blockspecs(ctx, kern_info,
                              ctx.relpath(kern_info.path), kname, findings)

        # ops-only entry: nobody outside the kernel dir imports the raw
        # kernel module.
        raw = f"{mod_prefix}.{kname}"
        for scope in (ctx.modules.values(), ctx.tests.values()):
            for info in scope:
                if info.path.parent == kdir:
                    continue
                for node in ast.walk(info.tree):
                    imported = []
                    if isinstance(node, ast.Import):
                        imported = [a.name for a in node.names]
                    elif isinstance(node, ast.ImportFrom) and node.module:
                        imported = [node.module]
                    for m in imported:
                        if m == raw or m.startswith(raw + "."):
                            rel = ctx.relpath(info.path)
                            findings.append(Finding(
                                rule="R3", file=rel, line=node.lineno,
                                key=f"R3:{rel}:raw-kernel-import:{kname}",
                                message=(
                                    f"imports raw kernel module `{raw}` — "
                                    "ops.py is the only entry point (it owns "
                                    "padding and the interpret fallback)"
                                ),
                            ))

        # tolerance test in tests/test_kernels.py.
        if test_mod is None:
            findings.append(Finding(
                rule="R3", file="tests", line=0,
                key=f"R3:tests:no-test-kernels:{kname}",
                message=(f"kernel `{kname}`: tests/test_kernels.py is "
                         "missing — every kernel needs a registered "
                         "kernel-vs-ref tolerance test"),
            ))
        else:
            imported_names = {
                a.asname or a.name
                for node in ast.walk(test_mod.tree)
                if isinstance(node, ast.ImportFrom) and node.module
                and node.module.startswith(mod_prefix)
                for a in node.names
            }
            if not imported_names or not _has_tolerance_use(
                    test_mod.tree, imported_names):
                findings.append(Finding(
                    rule="R3", file="tests/test_kernels.py", line=0,
                    key=f"R3:tests/test_kernels.py:no-tolerance-test:{kname}",
                    message=(
                        f"kernel `{kname}`: no test in tests/test_kernels.py "
                        "both calls its ops entry point and asserts a "
                        "tolerance (assert_allclose) against the ref"
                    ),
                ))
    return findings


def _match_ops(rfn: ast.FunctionDef, ops_funcs):
    """The ops counterpart of an oracle: exact stem match first, else the
    unique public ops function whose parameters cover the oracle's
    positional parameters."""
    stem = rfn.name
    for suffix in ("_ref", "_oracle"):
        if stem.endswith(suffix):
            stem = stem[: -len(suffix)]
    for ofn in ops_funcs:
        if ofn.name == stem:
            return ofn
    want = set(_positional_params(rfn))
    covering = [ofn for ofn in ops_funcs if want <= _all_params(ofn)]
    if not covering:
        return None
    # Several candidates cover the positional params (e.g. a full-sequence
    # op and a decode step): the counterpart is the one sharing the most
    # parameter names with the oracle overall, fewest extras breaking ties.
    ref_all = _all_params(rfn)
    covering.sort(key=lambda ofn: (
        -len(ref_all & _all_params(ofn)),
        len(_all_params(ofn) - ref_all),
    ))
    return covering[0]


def _has_tolerance_use(tree: ast.Module, names: set[str]) -> bool:
    """Some function body both references one of ``names`` and calls an
    allclose-style assertion."""
    for fn in ast.walk(tree):
        if not isinstance(fn, ast.FunctionDef):
            continue
        uses = any(isinstance(n, ast.Name) and n.id in names
                   for n in ast.walk(fn))
        tol = any(
            isinstance(n, ast.Call) and dotted(n.func) is not None
            and dotted(n.func).rsplit(".", 1)[-1] in
            ("assert_allclose", "allclose", "assert_array_almost_equal")
            for n in ast.walk(fn)
        )
        if uses and tol:
            return True
    return False


rule = Rule(
    id="R3",
    title="kernel contract: ops/ref triad, signatures, BlockSpec tiling, "
          "tolerance tests",
    run=run,
)
