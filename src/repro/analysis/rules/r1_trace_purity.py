"""R1 — trace purity.

Functions that run under a JAX trace (scan bodies, jit/vmap targets, Pallas
kernels, and everything they transitively call inside the repo) must not
force tracers to concrete host values: no ``float()``/``int()``/``bool()``
coercions of tracer-valued expressions, no ``.item()``, no ``np.*`` calls on
tracer data, and — for scan/vmap bodies, whose positional arguments are
always tracers — no Python ``if``/``while``/ternary on a tracer-valued test.

Why it matters here: a concrete-value leak inside the rolling-replan scan or
the migration walk turns a bit-deterministic compiled program into one whose
result depends on host-side evaluation order, which silently invalidates the
golden tests and every scan-vs-loop oracle.

Shape reads (``x.shape``/``x.ndim``/``len(x)``), ``is None`` structure
checks, static jit arguments, and keyword-only config parameters are all
recognized as trace-static and never flagged.
"""

from __future__ import annotations

import ast

from repro.analysis.astutils import (
    NUMPY_SAFE_ATTRS,
    StaticEnv,
    dotted,
    is_shape_attr_chain,
)
from repro.analysis.callgraph import CallGraph
from repro.analysis.engine import Finding, Rule

_COERCIONS = ("float", "int", "bool")


def _is_structural_test(test: ast.AST) -> bool:
    """``x is None`` / ``x is not None`` — pytree-structure checks, static."""
    return isinstance(test, ast.Compare) and all(
        isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops
    )


def _numpy_root(node: ast.AST, imports) -> str | None:
    """If this Name/Attribute resolves into numpy, the attribute path under
    ``numpy.`` (e.g. ``asarray``, ``random.rand``); else None."""
    name = dotted(node)
    if name is None:
        return None
    full = imports.resolve(name)
    if full == "numpy" or full.startswith("numpy."):
        return full[len("numpy."):] if full != "numpy" else ""
    return None


def run(ctx) -> list[Finding]:
    graph = CallGraph(ctx)
    findings: list[Finding] = []

    for tf in graph.traced:
        info = tf.module
        rel = ctx.relpath(info.path)
        env = StaticEnv(tf.node, tf.static_names)
        fname = tf.name
        body = tf.node.body if isinstance(tf.node.body, list) \
            else [ast.Expr(tf.node.body)]

        # Nested function defs get traced in their own right by the call
        # graph; don't double-report their bodies under the parent.
        nested: set[int] = set()
        for stmt in body:
            for node in ast.walk(stmt):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.Lambda)) and node is not tf.node:
                    for sub in ast.walk(node):
                        nested.add(id(sub))
                    nested.discard(id(node))

        def emit(node, detail, message):
            findings.append(Finding(
                rule="R1", file=rel, line=getattr(node, "lineno", 0),
                key=f"R1:{rel}:{fname}:{detail}",
                message=f"in traced `{fname}` ({tf.entry}): {message}",
            ))

        for stmt in body:
            for node in ast.walk(stmt):
                if id(node) in nested:
                    continue
                if isinstance(node, ast.Call):
                    callee = node.func
                    # float(x) / int(x) / bool(x) on tracer data.
                    if isinstance(callee, ast.Name) and \
                            callee.id in _COERCIONS and node.args:
                        if not env.is_static(node.args[0]):
                            emit(node, f"{callee.id}()",
                                 f"`{callee.id}()` coerces a tracer to a "
                                 "host value")
                        continue
                    # .item() — always a host sync.
                    if isinstance(callee, ast.Attribute) and \
                            callee.attr == "item" and not node.args:
                        emit(node, "item()",
                             "`.item()` forces a device->host transfer")
                        continue
                    # np.f(tracer) — numpy can't trace.
                    np_attr = _numpy_root(callee, info.imports)
                    if np_attr:
                        leaf = np_attr.rsplit(".", 1)[-1]
                        if leaf not in NUMPY_SAFE_ATTRS and any(
                                not env.is_static(a) for a in node.args):
                            emit(node, f"np.{np_attr}",
                                 f"`np.{np_attr}` called on tracer-valued "
                                 "arguments (numpy evaluates on host)")
                        continue
                if isinstance(node, (ast.If, ast.While, ast.IfExp)) and \
                        tf.kind in ("scan_body", "vmap"):
                    test = node.test
                    if _is_structural_test(test):
                        continue
                    if is_shape_attr_chain(test):
                        continue
                    if not env.is_static(test):
                        kindword = ("`while`" if isinstance(node, ast.While)
                                    else "`if`")
                        emit(node, f"branch@{_test_repr(test)}",
                             f"python {kindword} on a tracer-valued test "
                             f"({_test_repr(test)}) inside a "
                             f"{tf.kind.replace('_', ' ')}")
    return findings


def _test_repr(test: ast.AST) -> str:
    try:
        s = ast.unparse(test)
    except Exception:
        s = "<expr>"
    return s if len(s) <= 40 else s[:37] + "..."


rule = Rule(
    id="R1",
    title="trace purity: no host coercions or tracer branches in traced code",
    run=run,
)
