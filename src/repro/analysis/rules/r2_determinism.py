"""R2 — determinism.

The modules backing bit-exact goldens and scan-vs-loop oracles
(``core/``, ``capacity/``, ``kernels/``, ``data/``, ``serve/``) must be
reproducible from their inputs alone: no wall-clock reads
(``time.time``/``datetime.now``), no stdlib ``random``, and no global-state
or unseeded numpy RNG (``np.random.rand``, ``np.random.default_rng()`` with
no seed).  Seeded construction — ``np.random.default_rng(seed_expr)``,
``jax.random.PRNGKey`` — is the sanctioned pattern and is never flagged.

A single unseeded draw in a demand synthesizer or replay would make every
"golden" number a function of the process that produced it, which is
exactly the hidden-risk failure mode the planner exists to eliminate.
"""

from __future__ import annotations

import ast

from repro.analysis.astutils import dotted
from repro.analysis.engine import Finding, Rule

SCOPES = ("repro/core/", "repro/capacity/", "repro/kernels/",
          "repro/data/", "repro/serve/")

#: wall-clock and ordering-dependent reads, fully qualified.
CLOCK_CALLS = frozenset({
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.datetime.today", "datetime.date.today",
})

#: numpy.random attributes allowed when *seeded* (constructor given args).
SEEDED_CTORS = frozenset({
    "default_rng", "Generator", "SeedSequence", "PCG64", "Philox",
    "MT19937", "SFC64",
})


def _in_scope(rel: str) -> bool:
    return any(rel.startswith(f"src/{s}") for s in SCOPES)


def run(ctx) -> list[Finding]:
    findings: list[Finding] = []
    for info in ctx.modules.values():
        rel = ctx.relpath(info.path)
        if not _in_scope(rel):
            continue
        imports = info.imports

        def emit(node, detail, message):
            findings.append(Finding(
                rule="R2", file=rel, line=getattr(node, "lineno", 0),
                key=f"R2:{rel}:{detail}",
                message=message,
            ))

        # `from random import X` / `from time import time` at any level.
        for node in ast.walk(info.tree):
            if isinstance(node, ast.ImportFrom) and node.module:
                if node.module == "random":
                    emit(node, "import-random",
                         "stdlib `random` is process-global state; use a "
                         "seeded np.random.default_rng or jax.random key")
                if node.module == "time":
                    for a in node.names:
                        if f"time.{a.name}" in CLOCK_CALLS:
                            emit(node, f"import-time.{a.name}",
                                 f"`from time import {a.name}` pulls a "
                                 "wall-clock read into a determinism-scoped "
                                 "module")

        handled: set[int] = set()
        for node in ast.walk(info.tree):
            if isinstance(node, ast.Call):
                name = dotted(node.func)
                if name is None:
                    continue
                full = imports.resolve(name)
                if full in CLOCK_CALLS:
                    handled.add(id(node.func))
                    emit(node, full,
                         f"`{full}()` is a wall-clock read; goldens built "
                         "through here are unreproducible")
                elif full.startswith("numpy.random."):
                    handled.add(id(node.func))
                    attr = full[len("numpy.random."):]
                    if attr in SEEDED_CTORS:
                        if not node.args and not node.keywords:
                            emit(node, f"numpy.random.{attr}:unseeded",
                                 f"`np.random.{attr}()` without a seed "
                                 "draws OS entropy; pass an explicit seed")
                    else:
                        emit(node, f"numpy.random.{attr}",
                             f"`np.random.{attr}` uses numpy's global RNG "
                             "state; construct a seeded Generator instead")
                elif full == "random" or full.startswith("random."):
                    if "random" in imports.aliases and \
                            imports.aliases["random"] == "random":
                        handled.add(id(node.func))
                        emit(node, full,
                             f"stdlib `{full}()` is process-global RNG "
                             "state; use a seeded generator")

        # Bare references (passing `time.time` / `np.random.rand` around).
        for node in ast.walk(info.tree):
            if isinstance(node, ast.Attribute) and id(node) not in handled:
                name = dotted(node)
                if name is None:
                    continue
                full = imports.resolve(name)
                if full in CLOCK_CALLS:
                    emit(node, full,
                         f"reference to wall-clock `{full}`")
                elif full.startswith("numpy.random.") and \
                        full[len("numpy.random."):] not in SEEDED_CTORS:
                    emit(node, full.replace("numpy.random.", "numpy.random.", 1),
                         f"reference to global-state `{name}`")
    return findings


rule = Rule(
    id="R2",
    title="determinism: no clocks or unseeded RNG in golden-backed modules",
    run=run,
)
