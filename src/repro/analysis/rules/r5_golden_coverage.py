"""R5 — golden coverage for optional subsystems.

Every optional-subsystem keyword the planner stack exposes (``spot=``,
``migration=``, ``convertible=``, ``policy=``, ``scenarios=``,
``telemetry=``, the telemetry knobs ``calibration=``/``provenance=``,
and the replan ``cadence=`` mode) shipped with a hard guarantee: the
disabled path stays bit-identical to the pre-subsystem planner, proven by
hardcoded golden tests.  This rule keeps that guarantee alive: for each
watched kwarg that actually appears as a defaulted parameter (or
annotated config-dataclass field) somewhere in ``src/repro``, some
top-level test file must (a) reference the disabled spelling —
``<kw>=None``/``<kw>=False``, or the per-kwarg override in
:data:`DISABLED_SPELLINGS` (``cadence="weekly"``) — and (b) carry golden
assertions (``golden`` in its text).  Drop the golden test and the next
refactor can shift the disabled path without anything noticing.

The same contract extends to *request surfaces*: redesigned entry points
(:class:`~repro.core.api.PlanRequest`) promise bit-identity with the
legacy kwarg spelling, so when a watched surface class is defined in the
repo, some test must construct it alongside golden assertions.
"""

from __future__ import annotations

import ast
import re

from repro.analysis.engine import Finding, Rule

WATCHED = ("spot", "migration", "convertible", "policy", "scenarios",
           "telemetry", "calibration", "provenance", "cadence")

#: Disabled spelling per watched kwarg: most subsystems disable with
#: ``None``/``False``, but ``cadence=`` is a string mode whose default
#: ("weekly") is the bit-identical pre-cadence path.
DISABLED_SPELLINGS = {
    "cadence": r"""(['"])weekly\1""",
}
_DEFAULT_DISABLED = r"(None|False)\b"

#: Redesigned entry-point classes that must keep a construct-it golden
#: test proving parity with the legacy spelling.
WATCHED_SURFACES = ("PlanRequest",)


def _kwargs_in_repo(ctx) -> dict[str, str]:
    """watched kwarg -> file where it first appears as a defaulted param.

    Both spellings of an optional subsystem knob count: a defaulted
    function parameter (``def replan(..., cadence="weekly")``) and an
    annotated dataclass field with a default (``calibration: bool =
    False`` on :class:`~repro.obs.config.TelemetryConfig`) — config
    dataclasses are how the telemetry knobs ship."""
    found: dict[str, str] = {}
    for info in ctx.modules.values():
        for node in ast.walk(info.tree):
            defaulted: list[str] = []
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                args = node.args
                defaulted = [
                    a.arg
                    for a in args.args[len(args.args) - len(args.defaults):]
                ] + [
                    a.arg for a, d in zip(args.kwonlyargs, args.kw_defaults)
                    if d is not None
                ]
            elif isinstance(node, ast.ClassDef):
                defaulted = [
                    stmt.target.id
                    for stmt in node.body
                    if isinstance(stmt, ast.AnnAssign)
                    and isinstance(stmt.target, ast.Name)
                    and stmt.value is not None
                ]
            for kw in WATCHED:
                if kw in defaulted and kw not in found:
                    found[kw] = ctx.relpath(info.path)
    return found


def _surfaces_in_repo(ctx) -> dict[str, str]:
    """watched surface class -> file where it is defined."""
    found: dict[str, str] = {}
    for info in ctx.modules.values():
        for node in ast.walk(info.tree):
            if (
                isinstance(node, ast.ClassDef)
                and node.name in WATCHED_SURFACES
                and node.name not in found
            ):
                found[node.name] = ctx.relpath(info.path)
    return found


def run(ctx) -> list[Finding]:
    findings: list[Finding] = []
    present = _kwargs_in_repo(ctx)
    for kw, where in sorted(present.items()):
        disabled = DISABLED_SPELLINGS.get(kw, _DEFAULT_DISABLED)
        pat = re.compile(rf"\b{kw}\s*=\s*{disabled}")
        covered = any(
            pat.search(t.source) and "golden" in t.source.lower()
            for t in ctx.tests.values()
        )
        if not covered:
            findings.append(Finding(
                rule="R5", file=where, line=0,
                key=f"R5:{kw}",
                message=(
                    f"optional subsystem kwarg `{kw}=` (first seen in "
                    f"{where}) has no disabled-path golden test: no test "
                    f"file references the disabled spelling "
                    f"(`{kw}={DISABLED_SPELLINGS.get(kw, 'None/False')}`) "
                    "alongside golden assertions"
                ),
            ))
    for name, where in sorted(_surfaces_in_repo(ctx).items()):
        pat = re.compile(rf"\b{name}\s*\(")
        covered = any(
            pat.search(t.source) and "golden" in t.source.lower()
            for t in ctx.tests.values()
        )
        if not covered:
            findings.append(Finding(
                rule="R5", file=where, line=0,
                key=f"R5:surface:{name}",
                message=(
                    f"request surface `{name}` (defined in {where}) has "
                    "no legacy-parity golden test: no test file "
                    f"constructs `{name}(...)` alongside golden assertions"
                ),
            ))
    return findings


rule = Rule(
    id="R5",
    title="golden coverage: optional kwargs keep disabled-path goldens",
    run=run,
)
