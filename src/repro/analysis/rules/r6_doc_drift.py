"""R6 — doc drift.

README.md and docs/ARCHITECTURE.md are load-bearing: the paper-section →
module map and the prose name real symbols, and CI smoke-runs the quickstart
snippets.  This rule keeps the *names* honest without executing anything:

* module-path tokens (``repro/core/demand.py``) must exist on disk;
* fenced ``python`` blocks must import-resolve: ``from X import Y`` needs
  ``X`` to be a repo module exporting ``Y``; attribute reads on imported
  repo-module aliases (``traces.synthetic_pool_set``) must hit a top-level
  symbol;
* inline-code dotted tokens (``pricing.GENERATIONS``,
  ``capacity.simulator.replay_spot_plan``) are resolved against the repo's
  module tree by basename or dotted path — a token whose leading component
  is a known repo module must resolve to an exported symbol.

Tokens whose leading component is not a repo module (``jax.lax.scan``,
``np.log``, snippet-local variables) are out of scope and skipped.
"""

from __future__ import annotations

import ast
import re

from repro.analysis.engine import Finding, Rule

_MODULE_PATH = re.compile(r"`?\b(repro/[\w/]+\.py)\b`?")
_INLINE_CODE = re.compile(r"`([^`\n]+)`")
_FENCE = re.compile(r"```(\w*)\n(.*?)```", re.DOTALL)
_DOTTED = re.compile(r"^[A-Za-z_][\w]*(\.[A-Za-z_][\w]*)+$")


def _basename_index(ctx) -> dict[str, list[str]]:
    """last-component -> [module names] for every src module."""
    idx: dict[str, list[str]] = {}
    for name in ctx.modules:
        base = name.rsplit(".", 1)[-1]
        idx.setdefault(base, []).append(name)
    return idx


def _resolve_token(ctx, idx, token: str):
    """-> (resolved: bool, relevant: bool).  relevant=False means the token
    doesn't name repo code and shouldn't be judged."""
    parts = token.split(".")
    # Whole token as a module (repro.core.demand / core.replan).
    for cand in (token, f"repro.{token}"):
        if ctx.has_module(cand):
            return True, True
    # module-prefix + symbol suffix, longest prefix first.
    for cut in range(len(parts) - 1, 0, -1):
        prefix = ".".join(parts[:cut])
        suffix = parts[cut:]
        cands = [prefix, f"repro.{prefix}"]
        cands += idx.get(parts[cut - 1], []) if cut == 1 else []
        for cand in cands:
            if ctx.has_module(cand):
                sym = suffix[0]
                if sym in ctx.module_symbols(cand):
                    return True, True
                # Known module, unknown symbol: relevant and broken —
                # unless a deeper module path also exists (handled above).
                return False, True
    head = parts[0]
    relevant = head == "repro" or head in idx or ctx.has_module(head)
    return False, relevant


def _check_python_block(ctx, idx, code: str, rel: str, base_line: int,
                        findings):
    try:
        tree = ast.parse(code)
    except SyntaxError:
        findings.append(Finding(
            rule="R6", file=rel, line=base_line,
            key=f"R6:{rel}:snippet-syntax:{base_line}",
            message="python snippet does not parse",
        ))
        return
    aliases: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module \
                and node.module.startswith("repro"):
            if not ctx.has_module(node.module):
                findings.append(Finding(
                    rule="R6", file=rel, line=base_line + node.lineno - 1,
                    key=f"R6:{rel}:snippet-module:{node.module}",
                    message=f"snippet imports missing module `{node.module}`",
                ))
                continue
            for a in node.names:
                if a.name == "*":
                    continue
                if ctx.has_module(f"{node.module}.{a.name}"):
                    aliases[a.asname or a.name] = f"{node.module}.{a.name}"
                elif a.name in ctx.module_symbols(node.module):
                    pass  # plain symbol import, resolves
                else:
                    findings.append(Finding(
                        rule="R6", file=rel,
                        line=base_line + node.lineno - 1,
                        key=f"R6:{rel}:snippet-import:{node.module}.{a.name}",
                        message=(f"snippet imports `{a.name}` which "
                                 f"`{node.module}` does not export"),
                    ))
        elif isinstance(node, ast.Import):
            for a in node.names:
                if a.name.startswith("repro") and ctx.has_module(a.name):
                    aliases[a.asname or a.name.partition(".")[0]] = a.name
    # Attribute reads on repo-module aliases.
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name):
            mod = aliases.get(node.value.id)
            if mod is None or not ctx.has_module(mod):
                continue
            if node.attr not in ctx.module_symbols(mod) \
                    and not ctx.has_module(f"{mod}.{node.attr}"):
                findings.append(Finding(
                    rule="R6", file=rel, line=base_line + node.lineno - 1,
                    key=f"R6:{rel}:snippet-attr:{mod}.{node.attr}",
                    message=(f"snippet references `{node.value.id}."
                             f"{node.attr}` but `{mod}` has no such symbol"),
                ))


def run(ctx) -> list[Finding]:
    findings: list[Finding] = []
    idx = _basename_index(ctx)
    for rel, text in ctx.docs.items():
        # 1. module file paths.
        seen_paths: set[str] = set()
        for m in _MODULE_PATH.finditer(text):
            path = m.group(1)
            if path in seen_paths:
                continue
            seen_paths.add(path)
            if not (ctx.src_root / path).is_file():
                line = text.count("\n", 0, m.start()) + 1
                findings.append(Finding(
                    rule="R6", file=rel, line=line,
                    key=f"R6:{rel}:path:{path}",
                    message=f"references `{path}`, which does not exist "
                            "under src/",
                ))

        # 2. fenced python blocks.
        fence_spans = []
        for m in _FENCE.finditer(text):
            fence_spans.append((m.start(), m.end()))
            if m.group(1) == "python":
                base_line = text.count("\n", 0, m.start()) + 2
                _check_python_block(ctx, idx, m.group(2), rel, base_line,
                                    findings)

        # 3. inline dotted tokens in prose (outside fences).
        seen_tokens: set[str] = set()
        for m in _INLINE_CODE.finditer(text):
            if any(s <= m.start() < e for s, e in fence_spans):
                continue
            token = m.group(1).strip()
            token = re.sub(r"\(.*\)$", "", token)   # strip call args
            if not _DOTTED.match(token) or token in seen_tokens:
                continue
            if re.search(r"\.(py|json|md|yml|yaml|csv|txt|toml)$", token):
                continue  # file names, not symbols
            seen_tokens.add(token)
            resolved, relevant = _resolve_token(ctx, idx, token)
            if relevant and not resolved:
                line = text.count("\n", 0, m.start()) + 1
                findings.append(Finding(
                    rule="R6", file=rel, line=line,
                    key=f"R6:{rel}:token:{token}",
                    message=(f"inline code `{token}` does not resolve to a "
                             "repo module symbol — doc drift"),
                ))
    return findings


rule = Rule(
    id="R6",
    title="doc drift: README/ARCHITECTURE symbols must import-resolve",
    run=run,
)
