"""R4 — pricing-table guard.

Any src module that consumes ``repro.capacity.pricing`` (the module itself
or any name out of it) must call ``pricing.validate_tables()`` at import
time, matching the established pattern in ``portfolio.py`` /
``preemption.py`` / ``generations.py``.  The tables are plain data; the
invariant checker is the only thing standing between a hand-edited discount
row and a silently absurd plan.  ``validate_tables`` memoizes after its
first success, so the per-import cost is one function call.

Exempt: ``pricing`` itself and the analyzer.
"""

from __future__ import annotations

import ast

from repro.analysis.astutils import dotted
from repro.analysis.engine import Finding, Rule

PRICING = "repro.capacity.pricing"


def _imports_pricing(info) -> bool:
    for node in ast.walk(info.tree):
        if isinstance(node, ast.Import):
            if any(a.name == PRICING for a in node.names):
                return True
        elif isinstance(node, ast.ImportFrom) and node.module:
            if node.module == PRICING:
                return True
            if node.module == PRICING.rsplit(".", 1)[0] and any(
                    a.name == "pricing" for a in node.names):
                return True
    return False


def _calls_validate_at_import(info) -> bool:
    """A top-level statement calling (something resolving to)
    pricing.validate_tables."""
    resolve = info.imports.resolve
    for node in info.tree.body:
        if not (isinstance(node, ast.Expr) and isinstance(node.value, ast.Call)):
            continue
        name = dotted(node.value.func)
        if name is None:
            continue
        if resolve(name) == f"{PRICING}.validate_tables":
            return True
    return False


def run(ctx) -> list[Finding]:
    findings: list[Finding] = []
    for modname, info in ctx.modules.items():
        if modname == PRICING or modname.startswith("repro.analysis"):
            continue
        if not _imports_pricing(info):
            continue
        if not _calls_validate_at_import(info):
            rel = ctx.relpath(info.path)
            findings.append(Finding(
                rule="R4", file=rel, line=0,
                key=f"R4:{rel}:no-validate-tables",
                message=(
                    f"`{modname}` imports pricing tables but never calls "
                    "`pricing.validate_tables()` at import — a corrupted "
                    "table would flow straight into a plan (the call is "
                    "memoized; it costs one comparison after the first "
                    "import)"
                ),
            ))
    return findings


rule = Rule(
    id="R4",
    title="pricing guard: table consumers validate at import",
    run=run,
)
