"""Rule engine for ``repro.analysis``: parsed-module index, rule registry,
findings, and the ``baseline.json`` suppression mechanism.

The analyzer is purely static (``ast`` only): it parses every module under
``src/``, the top-level test files, and the two prose docs, hands the parsed
index to each registered rule, and diffs the resulting findings against the
baseline.  A finding's suppression ``key`` is line-free so baselines survive
unrelated edits; every baseline entry must carry a human justification —
the baseline is a ledger of *accepted* exceptions, not a mute button.
"""

from __future__ import annotations

import ast
import dataclasses
import json
from pathlib import Path
from typing import Callable

from repro.analysis.astutils import (
    import_map,
    iter_py_files,
    module_name_for,
    top_level_symbols,
)


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str       # "R1".."R6" (or "PARSE" for unparseable sources)
    file: str       # repo-relative posix path; "" for repo-level findings
    line: int       # 1-based; 0 for file/repo-level findings
    key: str        # stable suppression identity (never includes the line)
    message: str

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def render(self) -> str:
        loc = f"{self.file}:{self.line}" if self.line else (self.file or "<repo>")
        return f"[{self.rule}] {loc}: {self.message}"


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    title: str
    run: Callable[["AnalysisContext"], list[Finding]]


@dataclasses.dataclass
class ModuleInfo:
    name: str            # dotted module name ("" for test files)
    path: Path
    source: str
    tree: ast.Module

    @property
    def imports(self):
        if not hasattr(self, "_imports"):
            self._imports = import_map(self.tree)
        return self._imports


class AnalysisContext:
    """Everything the rules see: one parse of the repo.

    Layout expectations (shared by the real repo and the test fixtures):
    ``<root>/src/repro/...`` sources, ``<root>/tests/*.py`` tests (top level
    only — fixture trees under ``tests/`` are not scanned), and prose docs at
    ``<root>/README.md`` + ``<root>/docs/ARCHITECTURE.md``.
    """

    def __init__(self, root: Path | str):
        self.root = Path(root).resolve()
        self.src_root = self.root / "src"
        self.tests_root = self.root / "tests"
        self.parse_findings: list[Finding] = []
        self.modules: dict[str, ModuleInfo] = {}
        for path in iter_py_files(self.src_root):
            name = module_name_for(path, self.src_root)
            info = self._parse(name, path)
            if info is not None:
                self.modules[name] = info
        self.tests: dict[str, ModuleInfo] = {}
        if self.tests_root.is_dir():
            for path in sorted(self.tests_root.glob("*.py")):
                info = self._parse("", path)
                if info is not None:
                    self.tests[path.name] = info
        self.docs: dict[str, str] = {}
        for rel in ("README.md", "docs/ARCHITECTURE.md"):
            p = self.root / rel
            if p.is_file():
                self.docs[rel] = p.read_text()
        # Namespace packages (source dirs without __init__.py) are modules
        # too: their "symbols" are their children, so `from repro.data
        # import traces` resolves.
        self.packages: dict[str, set[str]] = {}
        for name in list(self.modules):
            parts = name.split(".")
            for i in range(1, len(parts)):
                pkg = ".".join(parts[:i])
                self.packages.setdefault(pkg, set()).add(parts[i])
        self._symbols: dict[str, set[str]] = {}

    def _parse(self, name: str, path: Path) -> ModuleInfo | None:
        source = path.read_text()
        try:
            tree = ast.parse(source, filename=str(path))
        except SyntaxError as e:
            self.parse_findings.append(Finding(
                rule="PARSE",
                file=self.relpath(path),
                line=e.lineno or 0,
                key=f"PARSE:{self.relpath(path)}",
                message=f"unparseable source: {e.msg}",
            ))
            return None
        return ModuleInfo(name=name, path=path, source=source, tree=tree)

    def relpath(self, path: Path) -> str:
        return path.resolve().relative_to(self.root).as_posix()

    def module_symbols(self, modname: str) -> set[str]:
        """Top-level names of a repo module (empty set if unknown)."""
        if modname not in self._symbols:
            info = self.modules.get(modname)
            syms = top_level_symbols(info.tree) if info else set()
            syms |= self.packages.get(modname, set())
            self._symbols[modname] = syms
        return self._symbols[modname]

    def has_module(self, modname: str) -> bool:
        return modname in self.modules or modname in self.packages


@dataclasses.dataclass
class Report:
    findings: list[Finding]            # every raw finding, all rules
    unsuppressed: list[Finding]        # findings not covered by the baseline
    suppressed: list[Finding]
    stale_suppressions: list[str]      # baseline keys that matched nothing
    errors: list[str]                  # baseline/config problems (exit 2)

    @property
    def ok(self) -> bool:
        return not self.unsuppressed and not self.errors

    def to_dict(self) -> dict:
        return {
            "ok": self.ok,
            "counts": {
                "total": len(self.findings),
                "unsuppressed": len(self.unsuppressed),
                "suppressed": len(self.suppressed),
            },
            "findings": [f.to_dict() for f in self.unsuppressed],
            "suppressed": [f.to_dict() for f in self.suppressed],
            "stale_suppressions": self.stale_suppressions,
            "errors": self.errors,
        }


def load_baseline(path: Path) -> tuple[dict[str, str], list[str]]:
    """-> ({key: justification}, errors).  A missing file is an empty
    baseline; a malformed one (bad JSON, entry without a non-empty
    justification, duplicate key) is a config error."""
    if not path.is_file():
        return {}, []
    errors: list[str] = []
    try:
        data = json.loads(path.read_text())
    except json.JSONDecodeError as e:
        return {}, [f"baseline {path.name}: invalid JSON: {e}"]
    entries = data.get("suppressions", None)
    if not isinstance(entries, list):
        return {}, [f"baseline {path.name}: expected a 'suppressions' list"]
    out: dict[str, str] = {}
    for i, entry in enumerate(entries):
        if not isinstance(entry, dict) or "key" not in entry:
            errors.append(f"baseline entry #{i}: must be an object with 'key'")
            continue
        key = entry["key"]
        just = entry.get("justification", "")
        if not isinstance(just, str) or not just.strip():
            errors.append(
                f"baseline entry {key!r}: a non-empty 'justification' string "
                "is required — the baseline records accepted exceptions, "
                "not silenced ones"
            )
        if key in out:
            errors.append(f"baseline entry {key!r}: duplicate key")
        out[key] = just
    return out, errors


def run_analysis(
    root: Path | str,
    baseline_path: Path | str | None = None,
    rules: list[Rule] | None = None,
) -> Report:
    """Run every registered rule over the repo at ``root`` and apply the
    baseline.  ``rules=None`` means all registered rules."""
    from repro.analysis.rules import ALL_RULES

    ctx = AnalysisContext(root)
    findings: list[Finding] = list(ctx.parse_findings)
    for rule in (rules if rules is not None else ALL_RULES):
        findings.extend(rule.run(ctx))
    findings.sort(key=lambda f: (f.rule, f.file, f.line, f.key))

    bpath = (
        Path(baseline_path) if baseline_path is not None
        else ctx.root / "baseline.json"
    )
    suppressions, errors = load_baseline(bpath)
    seen_keys = {f.key for f in findings}
    suppressed = [f for f in findings if f.key in suppressions]
    unsuppressed = [f for f in findings if f.key not in suppressions]
    stale = sorted(k for k in suppressions if k not in seen_keys)
    return Report(
        findings=findings,
        unsuppressed=unsuppressed,
        suppressed=suppressed,
        stale_suppressions=stale,
        errors=errors,
    )


def write_baseline(report: Report, path: Path) -> None:
    """Write the current unsuppressed findings as a baseline skeleton.  The
    justification is intentionally left empty — the engine refuses empty
    justifications, so every entry must be hand-finished before the baseline
    is usable.  Existing justified entries are preserved."""
    existing, _ = load_baseline(path)
    entries = []
    for f in report.findings:
        entries.append({
            "key": f.key,
            "justification": existing.get(f.key, ""),
            "note": f.render(),
        })
    path.write_text(json.dumps(
        {"version": 1, "suppressions": entries}, indent=2,
    ) + "\n")
