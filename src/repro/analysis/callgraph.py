"""Traced-code discovery: which functions run under a JAX trace.

Entry points are found syntactically — functions passed to ``lax.scan`` /
``jax.jit`` / ``jax.vmap`` / ``pl.pallas_call`` (directly, via
``functools.partial``, or as ``@jax.jit`` / ``@partial(jax.jit, ...)``
decorators) — then the call graph is walked transitively: any repo function
a traced function calls is itself traced.  Resolution is deliberately
conservative: bare names resolve through enclosing function scopes, the
module's top level, and top-level ``from repro... import`` bindings;
``mod.attr`` calls resolve when ``mod`` is an imported repo module.
Anything unresolvable (``jnp.*``, third-party, dynamic dispatch) is skipped
rather than guessed at.
"""

from __future__ import annotations

import ast
import dataclasses

from repro.analysis.astutils import dotted

# Fully-qualified callables whose function-argument runs under trace, after
# alias expansion through the module's imports.
SCAN_CALLS = frozenset({"jax.lax.scan", "lax.scan"})
VMAP_CALLS = frozenset({"jax.vmap", "vmap"})
JIT_CALLS = frozenset({"jax.jit", "jit"})
PARTIAL_CALLS = frozenset({"functools.partial", "partial"})

FuncNode = ast.FunctionDef | ast.AsyncFunctionDef | ast.Lambda


@dataclasses.dataclass
class TracedFunction:
    module: "object"                  # engine.ModuleInfo
    node: FuncNode
    kind: str                         # "scan_body" | "vmap" | "jit" | "pallas" | "called"
    static_names: frozenset[str]      # params static under this trace
    entry: str                        # human description of how it got traced

    @property
    def name(self) -> str:
        return getattr(self.node, "name", "<lambda>")


class _ScopeIndex(ast.NodeVisitor):
    """Per-module index: every function node with its enclosing-scope chain,
    so a bare name at any point resolves lexically."""

    def __init__(self, tree: ast.Module):
        self.parents: dict[ast.AST, ast.AST | None] = {}
        self.functions: list[tuple[FuncNode, tuple[FuncNode, ...]]] = []
        self._stack: list[FuncNode] = []
        self._walk(tree)

    def _walk(self, node: ast.AST):
        for child in ast.iter_child_nodes(node):
            self.parents[child] = node
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                self.functions.append((child, tuple(self._stack)))
                self._stack.append(child)
                self._walk(child)
                self._stack.pop()
            else:
                self._walk(child)

    def scope_of(self, node: ast.AST) -> tuple[FuncNode, ...]:
        """Enclosing function chain (outermost first) of any AST node."""
        chain: list[FuncNode] = []
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                chain.append(cur)
            cur = self.parents.get(cur)
        return tuple(reversed(chain))


class CallGraph:
    """Traced-function closure over every module in the context."""

    def __init__(self, ctx):
        self.ctx = ctx
        self._scopes = {name: _ScopeIndex(m.tree) for name, m in ctx.modules.items()}
        self._top_funcs: dict[str, dict[str, FuncNode]] = {}
        for name, m in ctx.modules.items():
            self._top_funcs[name] = {
                n.name: n for n in m.tree.body
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
            }
        self.traced: list[TracedFunction] = []
        self._seen: set[tuple[str, int]] = set()   # (module, node id)
        self._discover_roots()
        self._close_over_calls()

    # -- root discovery ----------------------------------------------------

    def _discover_roots(self):
        for modname, info in self.ctx.modules.items():
            scope = self._scopes[modname]
            resolve = info.imports.resolve
            for node in ast.walk(info.tree):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    self._check_decorators(modname, info, node)
                if not isinstance(node, ast.Call):
                    continue
                name = dotted(node.func)
                if name is None:
                    continue
                full = resolve(name)
                if full in SCAN_CALLS and node.args:
                    self._trace_arg(modname, node, node.args[0], "scan_body",
                                    frozenset(), f"lax.scan in {modname}")
                elif full in VMAP_CALLS and node.args:
                    self._trace_arg(modname, node, node.args[0], "vmap",
                                    frozenset(), f"jax.vmap in {modname}")
                elif full in JIT_CALLS and node.args:
                    static = _jit_static_names(node, node.args[0])
                    self._trace_arg(modname, node, node.args[0], "jit",
                                    static, f"jax.jit in {modname}")
                elif full.endswith("pallas_call") and node.args:
                    self._trace_arg(modname, node, node.args[0], "pallas",
                                    frozenset(), f"pallas_call in {modname}")

    def _check_decorators(self, modname, info, fn):
        resolve = info.imports.resolve
        for dec in fn.decorator_list:
            target = None
            static: frozenset[str] = frozenset()
            name = dotted(dec)
            if name is not None and resolve(name) in JIT_CALLS:
                target = fn                                   # @jax.jit
            elif isinstance(dec, ast.Call):
                cname = dotted(dec.func)
                if cname is None:
                    continue
                cfull = resolve(cname)
                if cfull in JIT_CALLS:                        # @jax.jit(...)
                    target = fn
                    static = _static_from_kwargs(dec, fn)
                elif cfull in PARTIAL_CALLS and dec.args:     # @partial(jax.jit, ...)
                    inner = dotted(dec.args[0])
                    if inner is not None and resolve(inner) in JIT_CALLS:
                        target = fn
                        static = _static_from_kwargs(dec, fn)
            if target is not None:
                self._add(modname, target, "jit", static,
                          f"@jit decorator on {fn.name}")

    def _trace_arg(self, modname, call, arg, kind, static, entry):
        """Resolve the function-valued argument of a tracing call."""
        resolved = self._resolve_func_expr(modname, call, arg)
        for mod, fnode in resolved:
            self._add(mod, fnode, kind, static, entry)

    def _resolve_func_expr(self, modname, site, expr):
        """-> [(module_name, FuncNode)] the expression may denote."""
        if isinstance(expr, ast.Lambda):
            return [(modname, expr)]
        if isinstance(expr, ast.Call):
            # functools.partial(fn, ...) / jax.checkpoint(fn) style wrappers:
            # trace the first function-ish argument.
            name = dotted(expr.func)
            resolve = self.ctx.modules[modname].imports.resolve
            if name is not None and resolve(name) in PARTIAL_CALLS and expr.args:
                return self._resolve_func_expr(modname, site, expr.args[0])
            return []
        name = dotted(expr)
        if name is None:
            return []
        return self._resolve_name(modname, site, name)

    def _resolve_name(self, modname, site, name):
        scope = self._scopes[modname]
        info = self.ctx.modules[modname]
        head, _, rest = name.partition(".")
        if not rest:
            # Lexical: nested defs in enclosing scopes, innermost first.
            for enclosing in reversed(scope.scope_of(site)):
                body = enclosing.body if isinstance(enclosing.body, list) else []
                for n in body:
                    if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                            and n.name == head:
                        return [(modname, n)]
            if head in self._top_funcs[modname]:
                return [(modname, self._top_funcs[modname][head])]
            if head in info.imports.from_imports:
                mod, orig = info.imports.from_imports[head]
                if mod in self._top_funcs and orig in self._top_funcs[mod]:
                    return [(mod, self._top_funcs[mod][orig])]
            return []
        # mod.attr: the head must be an imported repo module.
        target_mod = info.imports.resolve(head)
        if target_mod in self._top_funcs and "." not in rest:
            fn = self._top_funcs[target_mod].get(rest)
            if fn is not None:
                return [(target_mod, fn)]
        return []

    def _add(self, modname, fnode, kind, static, entry):
        key = (modname, id(fnode))
        if key in self._seen:
            return
        self._seen.add(key)
        self.traced.append(TracedFunction(
            module=self.ctx.modules[modname], node=fnode, kind=kind,
            static_names=static, entry=entry,
        ))

    # -- transitive closure ------------------------------------------------

    def _close_over_calls(self):
        queue = list(self.traced)
        while queue:
            tf = queue.pop()
            modname = tf.module.name
            body = tf.node.body if isinstance(tf.node.body, list) \
                else [ast.Expr(tf.node.body)]
            for stmt in body:
                for node in ast.walk(stmt):
                    if not isinstance(node, ast.Call):
                        continue
                    for mod, fnode in self._resolve_func_expr(
                            modname, node, node.func):
                        key = (mod, id(fnode))
                        if key in self._seen:
                            continue
                        self._add(mod, fnode, "called", frozenset(),
                                  f"called from traced {tf.name} ({modname})")
                        queue.append(self.traced[-1])


def _jit_static_names(call: ast.Call, fn_expr) -> frozenset[str]:
    """static_argnames/static_argnums of a jit(...) call, as param names
    where statically recoverable."""
    return _static_from_kwargs(call, None)


def _static_from_kwargs(call: ast.Call, fn) -> frozenset[str]:
    names: set[str] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, str):
                    names.add(n.value)
        elif kw.arg == "static_argnums" and fn is not None:
            pos = [a.arg for a in fn.args.posonlyargs + fn.args.args]
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, int) \
                        and 0 <= n.value < len(pos):
                    names.add(pos[n.value])
    return frozenset(names)
