"""``repro.analysis`` — trace-safety, determinism, and kernel-contract
static analyzer.

The invariants every subsystem in this repo leans on (scan bodies are
trace-pure and bit-deterministic, kernels ship ops/ref pairs with tolerance
tests, pricing-table consumers validate at import, optional subsystems keep
disabled-path goldens, docs name real symbols) were previously enforced by
convention.  This package enforces them as AST-level lint rules, run in CI
as a blocking job:

    python -m repro.analysis            # human output, exit 1 on findings
    python -m repro.analysis --json     # machine output for CI artifacts

Accepted exceptions live in ``baseline.json`` at the repo root; every entry
needs a justification string.  See docs/ARCHITECTURE.md
("Static analysis & contracts") for the rule table and workflow.
"""

from repro.analysis.engine import (  # noqa: F401
    AnalysisContext,
    Finding,
    Report,
    Rule,
    run_analysis,
    write_baseline,
)
