"""deepseek-v2-lite-16b [moe] — arXiv:2405.04434 (hf tier).

27L d_model=2048 16H d_ff=1408(expert) vocab=102400, MLA kv_lora=512,
2 shared + 64 routed experts top-6, first layer dense (d_ff 10944 per HF).

NB the assignment line lists both "MoE 64e top-6" and "160 routed"; 160
routed belongs to full DeepSeek-V2.  We implement 64 routed per the primary
spec and the published V2-Lite config (see DESIGN.md).
MLA dims per HF: qk_nope=128, qk_rope=64, v_head=128, no q-LoRA.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=10944,            # dense first layer width
    vocab_size=102400,
    attention="mla",
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    head_dim=192,          # qk_nope + qk_rope
    num_experts=64,
    num_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    first_dense_layers=1,
)
