"""stablelm-1.6b [dense] — hf:stabilityai/stablelm-2-1_6b (unverified tier).

24L d_model=2048 32H (GQA kv=32 == MHA) d_ff=5632 vocab=100352.
StableLM-2 uses partial rotary embeddings (25% of head_dim).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
    rotary_pct=0.25,
    rope_theta=10_000.0,
)
