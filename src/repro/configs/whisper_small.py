"""whisper-small [audio] — arXiv:2212.04356 (unverified tier).

12L d_model=768 12H (kv=12) d_ff=3072 vocab=51865; encoder-decoder with a
conv/mel frontend STUB: input_specs() provides precomputed frame embeddings
(B, 1500, d), per the assignment.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,           # decoder layers
    encoder_layers=12,
    encoder_seq=1500,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    rotary_pct=0.0,          # learned absolute positions
    max_seq=32_768 + 8,      # decode_32k cell needs 32k learned positions
)
