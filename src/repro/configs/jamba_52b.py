"""jamba-v0.1-52b [hybrid] — arXiv:2403.19887 (hf tier).

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16e top-2.
Mamba+attention 1:7 interleave (attention at layer offset 4 of each period-8
block), MoE every other layer.  Mamba: d_state=16, d_conv=4, expand=2.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    num_experts=16,
    top_k=2,
    moe_d_ff=14336,
    moe_layer_period=2,
    attn_layer_period=8,
    attn_layer_offset=4,
    ssm_d_state=16,
    ssm_d_conv=4,
    ssm_expand=2,
)
