"""minicpm3-4b [dense] — hf:openbmb/MiniCPM3-4B (hf tier).

62L d_model=2560 40H d_ff=6400 vocab=73448 — MLA attention
(q_lora=768, kv_lora=256, qk_nope=64, qk_rope=32, v_head=64 per HF config).
The assignment's "GQA kv=40" denotes 40 effective heads; MLA replaces the
separate KV heads with the shared latent.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    attention="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_dim=64,
    qk_rope_dim=32,
    v_head_dim=64,
    head_dim=96,  # qk_nope + qk_rope
)
