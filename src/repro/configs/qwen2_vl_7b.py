"""qwen2-vl-7b [vlm] — arXiv:2409.12191 (hf tier).

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064 — M-RoPE
(t/h/w sections 16/24/24 of head_dim/2), dynamic-resolution vision frontend
STUBBED: input_specs() provides precomputed patch/text embeddings (B, S, d).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    embeds_input=True,
)
