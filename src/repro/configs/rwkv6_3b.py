"""rwkv6-3b [ssm] — "Finch", arXiv:2404.05892 (hf tier).

32L d_model=2560 (attention-free) d_ff=8960 vocab=65536;
data-dependent decay linear attention, head_size 64 -> 40 heads.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,           # d_model / rwkv_head_size
    num_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    attention="none",
    rwkv_head_size=64,
)
