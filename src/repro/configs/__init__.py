"""Architecture registry: ``--arch <id>`` resolves here.

Each module holds the exact published config assigned to this paper, plus a
``reduced()`` helper producing a same-family small config for CPU smoke
tests (full configs are exercised only via the dry-run's ShapeDtypeStructs).
"""

from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig

from repro.configs import (  # noqa: E402
    deepseek_v2_lite,
    granite_moe_1b,
    internlm2_20b,
    jamba_52b,
    minicpm3_4b,
    phi3_medium_14b,
    qwen2_vl_7b,
    rwkv6_3b,
    stablelm_1_6b,
    whisper_small,
)

ARCHS: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        stablelm_1_6b,
        minicpm3_4b,
        internlm2_20b,
        phi3_medium_14b,
        granite_moe_1b,
        deepseek_v2_lite,
        rwkv6_3b,
        whisper_small,
        jamba_52b,
        qwen2_vl_7b,
    )
}


def get(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def reduced(name: str) -> ModelConfig:
    """Small same-family config for CPU smoke tests: few layers, narrow
    width, tiny vocab/experts — structure preserved."""
    cfg = get(name)
    upd: dict = dict(
        num_layers=max(2, cfg.attn_layer_period or 2),
        d_model=128,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2),
        d_ff=256,
        vocab_size=512,
        max_seq=128,
    )
    if cfg.attention == "mla":
        upd.update(
            q_lora_rank=32 if cfg.q_lora_rank else 0,
            kv_lora_rank=32,
            qk_nope_dim=16,
            qk_rope_dim=8,
            v_head_dim=16,
            head_dim=24,
            num_kv_heads=4,
        )
    else:
        upd["head_dim"] = 32
    if cfg.num_experts:
        # capacity_factor = E/k makes the reduced config dropless, so cache
        # -consistency tests are exact (capacity dropping is shape-dependent).
        upd.update(num_experts=4, top_k=2, moe_d_ff=64,
                   moe_capacity_factor=2.0)
    if cfg.family == "ssm":
        upd.update(d_model=128, num_heads=4, num_kv_heads=4,
                   rwkv_head_size=32, rwkv_lora_decay=16, rwkv_lora_mix=8)
    if cfg.family == "hybrid":
        upd.update(num_layers=8, ssm_d_state=8, ssm_dt_rank=16)
    if cfg.family == "audio":
        upd.update(encoder_layers=2, encoder_seq=32)
    if cfg.mrope_sections is not None:
        # sections must sum to head_dim/2
        upd["mrope_sections"] = (4, 6, 6)
    return dataclasses.replace(cfg, **upd)
