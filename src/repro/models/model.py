"""Unified model API: build(cfg) -> Model with init / apply / caches /
input_specs, dispatching on architecture family."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import jamba, rwkv, transformer, whisper
from repro.models.config import ModelConfig, ShapeCell
from repro.models.params import (
    abstract_params,
    count_params,
    init_params,
    pspec_tree,
    sharding_tree,
)

_FAMILY_MODULES = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "ssm": rwkv,
    "hybrid": jamba,
    "audio": whisper,
}


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    param_specs: Any
    apply_fn: Callable

    # ---- params ----
    def init(self, key: jax.Array):
        return init_params(self.param_specs, key, self._dtype)

    def abstract(self):
        return abstract_params(self.param_specs, self._dtype)

    def num_params(self) -> int:
        return count_params(self.param_specs)

    def param_shardings(self, mesh, rules):
        return sharding_tree(self.param_specs, mesh, rules)

    def param_pspecs(self, rules):
        return pspec_tree(self.param_specs, rules)

    @property
    def _dtype(self):
        return jnp.bfloat16 if self.cfg.dtype == "bfloat16" else jnp.float32

    # ---- caches ----
    def cache_specs(self, batch: int, seq: int):
        return _FAMILY_MODULES[self.cfg.family].cache_specs(
            self.cfg, batch, seq
        )

    def abstract_cache(self, batch: int, seq: int):
        return abstract_params(self.cache_specs(batch, seq), self._dtype)

    def init_cache(self, batch: int, seq: int):
        specs = self.cache_specs(batch, seq)
        return init_params(specs, jax.random.PRNGKey(0), self._dtype)

    # ---- forward ----
    def apply(self, params, **kw):
        return self.apply_fn(params, self.cfg, **kw)

    # ---- assignment input shapes ----
    def input_specs(self, cell: ShapeCell) -> dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of a shape cell
        (weak-type-correct, shardable, no device allocation)."""
        cfg = self.cfg
        b = cell.global_batch
        s = cell.seq_len
        i32 = jnp.int32
        sds = jax.ShapeDtypeStruct

        def tok(shape):
            return sds(shape, i32)

        if cell.kind == "train":
            specs: dict[str, Any] = {}
            if cfg.embeds_input:
                specs["embeds"] = sds((b, s, cfg.d_model), self._dtype)
            else:
                specs["tokens"] = tok((b, s))
            if cfg.family == "audio":
                specs["enc_frames"] = sds(
                    (b, cfg.encoder_seq, cfg.d_model), self._dtype
                )
            specs["labels"] = tok((b, s))
            return specs
        if cell.kind == "prefill":
            specs = {}
            if cfg.embeds_input:
                specs["embeds"] = sds((b, s, cfg.d_model), self._dtype)
            else:
                specs["tokens"] = tok((b, s))
            if cfg.family == "audio":
                specs["enc_frames"] = sds(
                    (b, cfg.encoder_seq, cfg.d_model), self._dtype
                )
            return specs
        # decode: one new token against a cache of length s
        specs = {
            "tokens": tok((b, 1)),
            "cache": self.abstract_cache(b, s),
            "pos": sds((), i32),
        }
        if cfg.embeds_input:
            specs["embeds"] = sds((b, 1, cfg.d_model), self._dtype)
            del specs["tokens"]
        return specs


def build(cfg: ModelConfig) -> Model:
    mod = _FAMILY_MODULES[cfg.family]
    return Model(cfg=cfg, param_specs=mod.param_specs(cfg), apply_fn=mod.apply)
