"""Decoder-only transformer LM (dense / MoE / VLM-backbone families).

Layers are scanned with stacked parameters (O(1) HLO in depth); optional
unscanned prefix layers cover heterogeneous stacks (DeepSeek's first dense
layer).  The KV cache rides through the layer scan as scanned inputs/outputs.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import ffn
from repro.models.common import rms_norm, rms_norm_spec, shard_act
from repro.models.config import ModelConfig
from repro.models.params import Spec, stack_spec_tree


def _layer_specs(cfg: ModelConfig, moe_layer: bool) -> dict[str, Any]:
    s: dict[str, Any] = {
        "attn_norm": rms_norm_spec(cfg.d_model),
        "attn": attn.attn_specs(cfg),
        "mlp_norm": rms_norm_spec(cfg.d_model),
    }
    if moe_layer:
        s["moe"] = ffn.moe_specs(cfg)
    else:
        d_ff = cfg.d_ff
        s["mlp"] = ffn.mlp_specs(cfg.d_model, d_ff)
    return s


def param_specs(cfg: ModelConfig) -> dict[str, Any]:
    n_scanned = cfg.num_layers - cfg.first_dense_layers
    moe = cfg.num_experts > 0
    specs: dict[str, Any] = {}
    if not cfg.embeds_input:
        specs["embed"] = Spec(
            (cfg.vocab_size, cfg.d_model), ("vocab", "embed"), fan_in=1
        )
    if cfg.first_dense_layers:
        specs["prefix"] = [
            _layer_specs(cfg, moe_layer=False)
            for _ in range(cfg.first_dense_layers)
        ]
    specs["layers"] = stack_spec_tree(
        _layer_specs(cfg, moe_layer=moe), n_scanned
    )
    specs["final_norm"] = rms_norm_spec(cfg.d_model)
    specs["lm_head"] = Spec(
        (cfg.d_model, cfg.vocab_size), ("embed", "vocab"), fan_in=cfg.d_model
    )
    return specs


def cache_specs(cfg: ModelConfig, batch: int, seq: int) -> dict[str, Any]:
    n_scanned = cfg.num_layers - cfg.first_dense_layers
    per_layer = attn.cache_specs(cfg, batch, seq)
    out: dict[str, Any] = {
        "layers": stack_spec_tree(per_layer, n_scanned),
    }
    if cfg.first_dense_layers:
        out["prefix"] = [
            attn.cache_specs(cfg, batch, seq)
            for _ in range(cfg.first_dense_layers)
        ]
    return out


def _layer_apply(cfg, p_l, x, cache_l, *, mode, pos, positions, moe_layer,
                 batch_part=None):
    h, new_cache = attn.attention_layer(
        p_l["attn"],
        rms_norm(x, p_l["attn_norm"], cfg.norm_eps),
        cfg, mode=mode, cache=cache_l, pos=pos, positions=positions,
    )
    x = shard_act(x + h, batch_part)
    xn = rms_norm(x, p_l["mlp_norm"], cfg.norm_eps)
    if moe_layer:
        x = x + ffn.moe(p_l["moe"], xn, cfg)
    else:
        x = x + ffn.mlp(p_l["mlp"], xn)
    return shard_act(x, batch_part), new_cache


def apply(
    params: dict[str, Any],
    cfg: ModelConfig,
    *,
    tokens: jnp.ndarray | None = None,    # (B, S) int32
    embeds: jnp.ndarray | None = None,    # (B, S, d) for embeds_input archs
    mode: str = "train",
    cache: dict[str, Any] | None = None,
    pos: jnp.ndarray | int = 0,
    remat: bool = True,
    batch_part=None,
):
    """Returns (logits (B,S,V) fp32, new_cache)."""
    if cfg.embeds_input:
        x = embeds
        b, s, _ = x.shape
    else:
        x = params["embed"][tokens]
        b, s = tokens.shape
    x = shard_act(x, batch_part)

    positions = _positions(pos, b, s)

    moe = cfg.num_experts > 0

    new_prefix_caches = []
    if cfg.first_dense_layers:
        for i, p_l in enumerate(params["prefix"]):
            cache_l = cache["prefix"][i] if cache is not None else None
            x, nc = _layer_apply(
                cfg, p_l, x, cache_l, mode=mode, pos=pos,
                positions=positions, moe_layer=False, batch_part=batch_part,
            )
            new_prefix_caches.append(nc)

    def body(x, xs):
        p_l, cache_l = xs
        return _layer_apply(
            cfg, p_l, x, cache_l, mode=mode, pos=pos,
            positions=positions, moe_layer=moe, batch_part=batch_part,
        )

    if mode == "train" and remat:
        from repro.models.common import checkpoint_body
        body = checkpoint_body(body, cfg)

    if cfg.unroll_layers:
        x, new_layer_caches = _unrolled_layers(
            body, x, params["layers"],
            cache["layers"] if cache is not None else None,
        )
    elif cache is not None:
        x, new_layer_caches = jax.lax.scan(
            body, x, (params["layers"], cache["layers"])
        )
    else:
        x, _ = jax.lax.scan(
            functools.partial(_no_cache_body, body), x, params["layers"]
        )
        new_layer_caches = None

    if mode == "prefill":
        # next-token logits only: a 32k-token fp32 logit tensor is O(100 GB)
        # of vocab-head compute and output traffic nobody reads.
        x = x[:, -1:]
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x @ params["lm_head"]).astype(jnp.float32)

    new_cache = None
    if cache is not None:
        new_cache = {"layers": new_layer_caches}
        if cfg.first_dense_layers:
            new_cache["prefix"] = new_prefix_caches
    return logits, new_cache


def _no_cache_body(body, x, p_l):
    x, _ = body(x, (p_l, None))
    return x, None


def _positions(pos, b: int, s: int) -> jnp.ndarray:
    """(B, S) absolute positions from scalar or per-batch (B,) offsets."""
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 1:
        return pos[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
    return jnp.broadcast_to(
        pos + jnp.arange(s, dtype=jnp.int32)[None, :], (b, s)
    )


def _unrolled_layers(body, x, stacked_params, stacked_cache):
    """Python-unrolled equivalent of the layer scan (see config.unroll_layers)."""
    num = jax.tree.leaves(stacked_params)[0].shape[0]
    new_caches = []
    for i in range(num):
        p_l = jax.tree.map(lambda a: a[i], stacked_params)
        c_l = (
            jax.tree.map(lambda a: a[i], stacked_cache)
            if stacked_cache is not None else None
        )
        x, nc = body(x, (p_l, c_l))
        new_caches.append(nc)
    if stacked_cache is not None:
        stacked = jax.tree.map(lambda *zs: jnp.stack(zs), *new_caches)
    else:
        stacked = None
    return x, stacked
