"""RWKV-6 "Finch" (data-dependent decay linear attention) — arch rwkv6-3b.

Attention-free: per-head (hs x hs) state instead of a KV cache, which is what
makes the long_500k cell O(1) in context length.  The chunked recurrence
mirrors kernels/linrec (the Pallas TPU kernel); this jnp path is used inside
pjit for training/dry-run (chunk loop via lax.scan; the small FLOPs remainder
hidden from HLO cost analysis is restored analytically — see
launch/hlo_analysis.inner_recurrence_flops).

Deviation from upstream RWKV: LayerNorm is replaced by RMSNorm (consistent
with the rest of the zoo; capacity-neutral).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import rms_norm, rms_norm_spec, shard_act
from repro.models.config import ModelConfig
from repro.models.params import Spec
from repro.models.scan_utils import pick_chunk, unrolled_chunk_scan

# Mix components order: r, k, v, w (decay), g (gate)
_N_MIX = 5


def rwkv_layer_specs(cfg: ModelConfig) -> dict[str, Spec]:
    d, dff = cfg.d_model, cfg.d_ff
    h, hs = cfg.rwkv_heads, cfg.rwkv_head_size
    m, ld = cfg.rwkv_lora_mix, cfg.rwkv_lora_decay
    return {
        "ln1": rms_norm_spec(d),
        "ln2": rms_norm_spec(d),
        # time-mix (ddlerp) parameters
        "mu_base": Spec((d,), ("embed",), init="zeros"),
        "mu": Spec((_N_MIX, d), (None, "embed"), init="zeros"),
        "mix_a": Spec((d, _N_MIX * m), ("embed", None), fan_in=d),
        "mix_b": Spec((_N_MIX, m, d), (None, None, "embed"), fan_in=m),
        # data-dependent decay
        "w0": Spec((d,), ("embed",), init="zeros", dtype=jnp.float32),
        "wa": Spec((d, ld), ("embed", None), fan_in=d),
        "wb": Spec((ld, d), (None, "embed"), fan_in=ld),
        # projections
        "wr": Spec((d, d), ("embed", "ff"), fan_in=d),
        "wk": Spec((d, d), ("embed", "ff"), fan_in=d),
        "wv": Spec((d, d), ("embed", "ff"), fan_in=d),
        "wg": Spec((d, d), ("embed", "ff"), fan_in=d),
        "u": Spec((h, hs), (None, None), init="zeros", dtype=jnp.float32),
        "ln_x": Spec((d,), ("embed",), init="ones", dtype=jnp.float32),
        "wo": Spec((d, d), ("ff", "embed"), fan_in=d),
        # channel-mix
        "cmix_k": Spec((d,), ("embed",), init="zeros"),
        "cmix_r": Spec((d,), ("embed",), init="zeros"),
        "cwk": Spec((d, dff), ("embed", "ff"), fan_in=d),
        "cwv": Spec((dff, d), ("ff", "embed"), fan_in=dff),
        "cwr": Spec((d, d), ("embed", "ff"), fan_in=d),
    }


def rwkv_state_specs(cfg: ModelConfig, batch: int) -> dict[str, Spec]:
    d, h, hs = cfg.d_model, cfg.rwkv_heads, cfg.rwkv_head_size
    return {
        "att_shift": Spec((batch, d), ("batch", "embed"), init="zeros"),
        "ffn_shift": Spec((batch, d), ("batch", "embed"), init="zeros"),
        "s": Spec((batch, h, hs, hs), ("batch", None, None, None),
                  init="zeros", dtype=jnp.float32),
    }


def param_specs(cfg: ModelConfig) -> dict:
    return {
        "embed": Spec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                      fan_in=1),
        "layers": _stack(rwkv_layer_specs(cfg), cfg.num_layers),
        "final_norm": rms_norm_spec(cfg.d_model),
        "lm_head": Spec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
                        fan_in=cfg.d_model),
    }


def cache_specs(cfg: ModelConfig, batch: int, seq: int) -> dict:
    del seq  # attention-free: O(1) state regardless of context length
    return {"layers": _stack(rwkv_state_specs(cfg, batch), cfg.num_layers)}


def _stack(specs, n):
    from repro.models.params import stack_spec_tree

    return stack_spec_tree(specs, n)


def apply(
    params: dict,
    cfg: ModelConfig,
    *,
    tokens: jnp.ndarray,
    embeds=None,
    mode: str = "train",
    cache: dict | None = None,
    pos=0,
    remat: bool = True,
    batch_part=None,
):
    x = shard_act(params["embed"][tokens], batch_part)

    def body(x, xs):
        p_l, state_l = xs
        x, st = rwkv_layer(p_l, x, cfg, mode=mode, state=state_l)
        return shard_act(x, batch_part), st

    if mode == "train" and remat:
        from repro.models.common import checkpoint_body
        body = checkpoint_body(body, cfg)

    if cfg.unroll_layers:
        from repro.models.transformer import _unrolled_layers
        x, new_states = _unrolled_layers(
            body, x, params["layers"],
            cache["layers"] if cache is not None else None,
        )
        new_cache = {"layers": new_states} if cache is not None else None
    elif cache is not None:
        x, new_states = jax.lax.scan(body, x, (params["layers"],
                                               cache["layers"]))
        new_cache = {"layers": new_states}
    else:
        def body_nc(x, p_l):
            x, _ = body(x, (p_l, None))
            return x, None
        x, _ = jax.lax.scan(body_nc, x, params["layers"])
        new_cache = None

    if mode == "prefill":
        # next-token logits only: a 32k-token fp32 logit tensor is O(100 GB)
        # of vocab-head compute and output traffic nobody reads.
        x = x[:, -1:]
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x @ params["lm_head"]).astype(jnp.float32)
    return logits, new_cache


def _token_shift(x: jnp.ndarray, prev: jnp.ndarray | None) -> jnp.ndarray:
    """xs_t = x_{t-1}; first position takes ``prev`` (decode carry) or 0."""
    first = (
        jnp.zeros_like(x[:, :1]) if prev is None else prev[:, None].astype(x.dtype)
    )
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _chunked_wkv(r, k, v, logw, u, s0, chunk):
    """Chunked linear attention (jnp mirror of kernels/linrec).
    r,k,v,logw: (B, T, H, hs) fp32; u (H, hs); s0 (B, H, hs, hs)."""
    b, t, h, hs = r.shape
    nchunks = t // chunk

    def body(s, xs):
        r_c, k_c, v_c, lw_c = xs                        # (B, L, H, hs)
        cum = jnp.cumsum(lw_c, axis=1)
        cumprev = cum - lw_c
        y_state = jnp.einsum("blhi,bhij->blhj", r_c * jnp.exp(cumprev), s)
        decay = jnp.exp(cumprev[:, :, None] - cum[:, None, :])  # (B,L,M,H,hs)
        att = jnp.einsum("blhi,bmhi,blmhi->bhlm", r_c, k_c, decay)
        li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        mi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
        att = jnp.where((li > mi)[None, None], att, 0.0)
        diag = jnp.einsum("blhi,hi,blhi->blh", r_c, u, k_c)
        y = y_state + jnp.einsum("bhlm,bmhj->blhj", att, v_c) \
            + diag[..., None] * v_c
        total = cum[:, -1]                              # (B, H, hs)
        k_dec = k_c * jnp.exp(total[:, None] - cum)
        s_new = jnp.exp(total)[..., None] * s + jnp.einsum(
            "blhi,blhj->bhij", k_dec, v_c
        )
        return s_new, y

    def chunked(z):
        return z.reshape(b, nchunks, chunk, h, hs).swapaxes(0, 1)

    s_final, ys = unrolled_chunk_scan(
        body, s0, (chunked(r), chunked(k), chunked(v), chunked(logw))
    )
    return ys.swapaxes(0, 1).reshape(b, t, h, hs), s_final


def rwkv_layer(
    p: dict[str, jnp.ndarray],
    x: jnp.ndarray,                 # (B, T, d)
    cfg: ModelConfig,
    *,
    mode: str,
    state: dict[str, jnp.ndarray] | None,
):
    b, t, d = x.shape
    h, hs = cfg.rwkv_heads, cfg.rwkv_head_size
    dtype = x.dtype

    # ---- time mix ----
    xn = rms_norm(x, p["ln1"], cfg.norm_eps)
    prev_att = state["att_shift"] if (state is not None and mode == "decode") \
        else None
    xs = _token_shift(xn, prev_att)
    dx = xs - xn
    base = xn + dx * p["mu_base"][None, None].astype(dtype)
    z = jnp.tanh(base @ p["mix_a"]).reshape(b, t, _N_MIX, cfg.rwkv_lora_mix)
    offs = jnp.einsum("btfm,fmd->btfd", z, p["mix_b"])      # (B,T,5,d)
    comps = [
        xn + dx * (p["mu"][i][None, None].astype(dtype) + offs[:, :, i])
        for i in range(_N_MIX)
    ]
    x_r, x_k, x_v, x_w, x_g = comps

    f32 = jnp.float32
    w_raw = p["w0"] + jnp.tanh(x_w.astype(f32) @ p["wa"].astype(f32)) \
        @ p["wb"].astype(f32)
    logw = -jnp.exp(jnp.clip(w_raw, -20.0, 10.0))           # (B,T,d) <= 0
    r = (x_r @ p["wr"]).reshape(b, t, h, hs).astype(f32)
    k = (x_k @ p["wk"]).reshape(b, t, h, hs).astype(f32)
    v = (x_v @ p["wv"]).reshape(b, t, h, hs).astype(f32)
    g = x_g @ p["wg"]
    logw = logw.reshape(b, t, h, hs)

    s0 = (
        state["s"].astype(f32)
        if (state is not None and mode == "decode")
        else jnp.zeros((b, h, hs, hs), f32)
    )
    u = p["u"].astype(f32)

    if mode == "decode" and t == 1:
        kv = k[:, 0, :, :, None] * v[:, 0, :, None, :]
        att = s0 + u[None, :, :, None] * kv
        y = jnp.einsum("bhi,bhij->bhj", r[:, 0], att)[:, None]
        s_new = jnp.exp(logw[:, 0])[..., None] * s0 + kv
    else:
        # chunk^2 decay tensor bounds max_chunk; target few unrolled iters
        chunk = pick_chunk(t, target_iters=32, max_chunk=256)
        y, s_new = _chunked_wkv(r, k, v, logw, u, s0, chunk)

    # per-head group norm
    yh = y.reshape(b, t, h, hs)
    yh = yh * jax.lax.rsqrt((yh * yh).mean(-1, keepdims=True) + cfg.norm_eps)
    y = (yh.reshape(b, t, d) * p["ln_x"][None, None]).astype(dtype)
    att_out = (y * jax.nn.silu(g)) @ p["wo"]
    x = x + att_out

    # ---- channel mix ----
    xn2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    prev_ffn = state["ffn_shift"] if (state is not None and mode == "decode") \
        else None
    xs2 = _token_shift(xn2, prev_ffn)
    dx2 = xs2 - xn2
    xk = xn2 + dx2 * p["cmix_k"][None, None].astype(dtype)
    xr = xn2 + dx2 * p["cmix_r"][None, None].astype(dtype)
    kk = jnp.square(jax.nn.relu(xk @ p["cwk"]))
    ffn_out = jax.nn.sigmoid(xr @ p["cwr"]) * (kk @ p["cwv"])
    x = x + ffn_out

    new_state = {
        "att_shift": xn[:, -1].astype(dtype),
        "ffn_shift": xn2[:, -1].astype(dtype),
        "s": s_new,
    }
    return x, new_state
