"""Attention layers: GQA (with partial rotary / M-RoPE) and MLA
(DeepSeek/MiniCPM multi-head latent attention with absorbed decode path).

Three execution modes share one set of weights:
  * train    — full causal self-attention, no cache;
  * prefill  — same math, query-chunked (python-unrolled so HLO FLOP
               accounting stays exact — see launch/dryrun delta method), and
               writes the KV cache;
  * decode   — single-token query against the cache at fill level ``pos``.

Caches are laid out (B, S, Hkv, D) with logical axes
("batch", "cache_seq", "kv_heads", "head_dim") so long-context decode can
shard the *sequence* dimension over the model axis (context parallelism) —
GQA kv-head counts (4..48) rarely divide a 16-way axis, the cache length
always does.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import rms_norm, rms_norm_spec, rope_for
from repro.models.config import ModelConfig
from repro.models.params import Spec

NEG_INF = -1e30

# Query-chunk lengths (python-unrolled blockwise attention): bounds the
# (chunk, Skv) score buffer — the jnp stand-in for the flash kernel's tiling.
PREFILL_CHUNK = 2048
TRAIN_CHUNK = 1024


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------

def gqa_specs(cfg: ModelConfig) -> dict[str, Spec]:
    d, h, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "wq": Spec((d, h, hd), ("embed", "heads", "head_dim"), fan_in=d),
        "wk": Spec((d, hkv, hd), ("embed", "kv_heads", "head_dim"), fan_in=d),
        "wv": Spec((d, hkv, hd), ("embed", "kv_heads", "head_dim"), fan_in=d),
        "wo": Spec((h, hd, d), ("heads", "head_dim", "embed"), fan_in=h * hd),
    }


def mla_specs(cfg: ModelConfig) -> dict[str, Spec]:
    d, h = cfg.d_model, cfg.num_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    s: dict[str, Spec] = {}
    if cfg.q_lora_rank:
        s["wq_a"] = Spec((d, cfg.q_lora_rank), ("embed", "lora"), fan_in=d)
        s["q_norm"] = rms_norm_spec(cfg.q_lora_rank)
        s["wq_b"] = Spec(
            (cfg.q_lora_rank, h, qk), ("lora", "heads", "head_dim"),
            fan_in=cfg.q_lora_rank,
        )
    else:
        s["wq"] = Spec((d, h, qk), ("embed", "heads", "head_dim"), fan_in=d)
    s["wkv_a"] = Spec((d, cfg.kv_lora_rank), ("embed", "lora"), fan_in=d)
    s["kv_norm"] = rms_norm_spec(cfg.kv_lora_rank)
    s["wk_rope"] = Spec((d, cfg.qk_rope_dim), ("embed", "head_dim"), fan_in=d)
    s["wk_b"] = Spec(
        (cfg.kv_lora_rank, h, cfg.qk_nope_dim),
        ("lora", "heads", "head_dim"), fan_in=cfg.kv_lora_rank,
    )
    s["wv_b"] = Spec(
        (cfg.kv_lora_rank, h, cfg.v_head_dim),
        ("lora", "heads", "head_dim"), fan_in=cfg.kv_lora_rank,
    )
    s["wo"] = Spec(
        (h, cfg.v_head_dim, d), ("heads", "head_dim", "embed"),
        fan_in=h * cfg.v_head_dim,
    )
    return s


def attn_specs(cfg: ModelConfig) -> dict[str, Spec]:
    return mla_specs(cfg) if cfg.attention == "mla" else gqa_specs(cfg)


def gqa_cache_specs(cfg: ModelConfig, batch: int, seq: int) -> dict[str, Spec]:
    hkv, hd = cfg.num_kv_heads, cfg.head_dim
    axes = ("batch", "cache_seq", "kv_heads", "head_dim")
    if cfg.kv_cache_dtype == "int8":
        # Quantized KV cache (per-token-per-head absmax scales): halves the
        # decode memory-roofline term (EXPERIMENTS.md §Perf).
        saxes = ("batch", "cache_seq", "kv_heads", None)
        return {
            "k": Spec((batch, seq, hkv, hd), axes, init="zeros",
                      dtype=jnp.int8),
            "v": Spec((batch, seq, hkv, hd), axes, init="zeros",
                      dtype=jnp.int8),
            "k_scale": Spec((batch, seq, hkv, 1), saxes, init="zeros",
                            dtype=jnp.bfloat16),
            "v_scale": Spec((batch, seq, hkv, 1), saxes, init="zeros",
                            dtype=jnp.bfloat16),
        }
    return {
        "k": Spec((batch, seq, hkv, hd), axes, init="zeros"),
        "v": Spec((batch, seq, hkv, hd), axes, init="zeros"),
    }


def _quantize_kv(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(B,S,H,D) -> int8 values + (B,S,H,1) bf16 scales."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), -1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)


def _dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray, dtype) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)


def mla_cache_specs(cfg: ModelConfig, batch: int, seq: int) -> dict[str, Spec]:
    return {
        "c_kv": Spec(
            (batch, seq, cfg.kv_lora_rank), ("batch", "cache_seq", "lora"),
            init="zeros",
        ),
        "k_rope": Spec(
            (batch, seq, cfg.qk_rope_dim), ("batch", "cache_seq", "head_dim"),
            init="zeros",
        ),
    }


def cache_specs(cfg: ModelConfig, batch: int, seq: int) -> dict[str, Spec]:
    if cfg.attention == "mla":
        return mla_cache_specs(cfg, batch, seq)
    return gqa_cache_specs(cfg, batch, seq)


# ---------------------------------------------------------------------------
# Core attention math
# ---------------------------------------------------------------------------

def _mask(b, sq, skv, *, causal, q_offset, kv_len):
    """(B, Sq, Skv) bool mask.  ``q_offset``/``kv_len`` may be scalars or
    per-batch (B,) vectors (continuous batching: per-slot fill levels)."""
    q_offset = jnp.asarray(q_offset)
    kv_len = jnp.asarray(kv_len)
    if q_offset.ndim == 0:
        q_offset = jnp.broadcast_to(q_offset, (b,))
    if kv_len.ndim == 0:
        kv_len = jnp.broadcast_to(kv_len, (b,))
    cols = jnp.arange(skv)
    mask = cols[None, None, :] >= kv_len[:, None, None]  # cache padding
    if causal:
        rows = q_offset[:, None] + jnp.arange(sq)[None, :]     # (B, Sq)
        mask = mask | (cols[None, None, :] > rows[:, :, None])
    return mask


def _sdpa(
    q: jnp.ndarray,      # (B, Sq, H, Dq)
    k: jnp.ndarray,      # (B, Skv, Hkv, Dq)
    v: jnp.ndarray,      # (B, Skv, Hkv, Dv)
    *,
    causal: bool,
    q_offset,            # scalar or (B,): absolute position of q row 0
    kv_len,              # scalar or (B,): valid kv entries (mask beyond)
    scale: float,
) -> jnp.ndarray:
    """Blockless scaled-dot-product attention with GQA head grouping."""
    b, sq, h, dq = q.shape
    _, skv, hkv, dv = v.shape
    group = h // hkv
    qg = q.reshape(b, sq, hkv, group, dq)
    s = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    mask = _mask(b, sq, skv, causal=causal, q_offset=q_offset, kv_len=kv_len)
    s = jnp.where(mask[:, None, None], NEG_INF, s)
    # softmax in f32, probabilities cast down for the PV matmul (halves the
    # largest live buffer and doubles MXU throughput on TPU).
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
    return out.reshape(b, sq, h, dv).astype(v.dtype)


def sdpa_chunked(
    q, k, v, *, causal: bool, q_offset, kv_len, scale: float,
    chunk: int = PREFILL_CHUNK,
):
    """Query-chunked attention for long prefill: python-unrolled so the
    (Sq_chunk, Skv) score block is the peak intermediate and HLO cost
    analysis sees every chunk (no inner scan)."""
    sq = q.shape[1]
    if sq <= chunk:
        return _sdpa(
            q, k, v, causal=causal, q_offset=q_offset, kv_len=kv_len,
            scale=scale,
        )
    outs = []
    for start in range(0, sq, chunk):
        stop = min(start + chunk, sq)
        outs.append(
            _sdpa(
                q[:, start:stop], k, v,
                causal=causal, q_offset=q_offset + start, kv_len=kv_len,
                scale=scale,
            )
        )
    return jnp.concatenate(outs, axis=1)


def _update_cache(cache: jnp.ndarray, new: jnp.ndarray, pos) -> jnp.ndarray:
    """Write ``new`` (B, S_new, ...) into the cache at offset ``pos``
    (scalar, or (B,) for per-slot offsets in continuous batching)."""
    pos_arr = jnp.asarray(pos)
    if pos_arr.ndim == 1:
        return jax.vmap(
            lambda c, n, p: jax.lax.dynamic_update_slice_in_dim(
                c, n.astype(c.dtype), p, axis=0
            )
        )(cache, new, pos_arr)
    return jax.lax.dynamic_update_slice_in_dim(
        cache, new.astype(cache.dtype), pos, axis=1
    )


# ---------------------------------------------------------------------------
# GQA layer
# ---------------------------------------------------------------------------

def gqa_attention(
    p: dict[str, jnp.ndarray],
    x: jnp.ndarray,              # (B, S, d)
    cfg: ModelConfig,
    *,
    mode: str,                   # train | prefill | decode
    cache: dict[str, jnp.ndarray] | None,
    pos,                         # decode: fill level; prefill: write offset
    positions: jnp.ndarray,      # rope positions (B, S) or (3, B, S)
    causal: bool = True,
):
    b, s, d = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])

    rot = int(cfg.head_dim * cfg.rotary_pct)
    if rot:
        q = q.at[..., :rot].set(rope_for(cfg, q[..., :rot], positions))
        k = k.at[..., :rot].set(rope_for(cfg, k[..., :rot], positions))

    scale = 1.0 / math.sqrt(cfg.head_dim)
    quantized = cfg.kv_cache_dtype == "int8"

    def write_cache(cache_, k_, v_):
        if quantized:
            kq, ks = _quantize_kv(k_)
            vq, vs = _quantize_kv(v_)
            return {
                "k": _update_cache(cache_["k"], kq, pos),
                "v": _update_cache(cache_["v"], vq, pos),
                "k_scale": _update_cache(cache_["k_scale"], ks, pos),
                "v_scale": _update_cache(cache_["v_scale"], vs, pos),
            }
        return {
            "k": _update_cache(cache_["k"], k_, pos),
            "v": _update_cache(cache_["v"], v_, pos),
        }

    def read_cache(cache_):
        if quantized:
            return (
                _dequantize_kv(cache_["k"], cache_["k_scale"], x.dtype),
                _dequantize_kv(cache_["v"], cache_["v_scale"], x.dtype),
            )
        return cache_["k"], cache_["v"]

    new_cache = cache
    if mode == "train":
        out = sdpa_chunked(
            q, k, v, causal=causal, q_offset=0, kv_len=s, scale=scale,
            chunk=TRAIN_CHUNK,
        )
    elif mode == "prefill":
        new_cache = write_cache(cache, k, v)
        out = sdpa_chunked(
            q, k, v, causal=causal, q_offset=0, kv_len=s, scale=scale
        )
    else:  # decode
        new_cache = write_cache(cache, k, v)
        k_full, v_full = read_cache(new_cache)
        out = _sdpa(
            q, k_full, v_full,
            causal=causal, q_offset=pos, kv_len=pos + s, scale=scale,
        )
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA layer
# ---------------------------------------------------------------------------

def mla_attention(
    p: dict[str, jnp.ndarray],
    x: jnp.ndarray,
    cfg: ModelConfig,
    *,
    mode: str,
    cache: dict[str, jnp.ndarray] | None,
    pos,
    positions: jnp.ndarray,
):
    """Multi-head latent attention.  Cache holds the rank-``kv_lora``
    latent + the shared rope key — the MLA memory saving.  Decode uses the
    weight-absorbed form (scores and values contracted in latent space)."""
    b, s, d = x.shape
    h = cfg.num_heads
    nope, rope_d = cfg.qk_nope_dim, cfg.qk_rope_dim

    if cfg.q_lora_rank:
        cq = rms_norm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps)
        q = jnp.einsum("bsr,rhk->bshk", cq, p["wq_b"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = rope_for(cfg, q_rope, positions)

    c_kv = rms_norm(x @ p["wkv_a"], p["kv_norm"], cfg.norm_eps)  # (B,S,r)
    k_rope = rope_for(
        cfg, (x @ p["wk_rope"])[:, :, None, :], positions
    )[:, :, 0, :]                                                # (B,S,rd)

    scale = 1.0 / math.sqrt(nope + rope_d)

    if mode in ("train", "prefill"):
        k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["wk_b"])
        v = jnp.einsum("bsr,rhk->bshk", c_kv, p["wv_b"])
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, rope_d))],
            -1,
        )
        q_full = jnp.concatenate([q_nope, q_rope], -1)
        out = sdpa_chunked(
            q_full, k_full, v, causal=True, q_offset=0, kv_len=s, scale=scale,
            chunk=PREFILL_CHUNK if mode == "prefill" else TRAIN_CHUNK,
        )
        new_cache = cache
        if mode == "prefill":
            new_cache = {
                "c_kv": _update_cache(cache["c_kv"], c_kv, pos),
                "k_rope": _update_cache(cache["k_rope"], k_rope, pos),
            }
    else:  # decode: absorbed form
        new_cache = {
            "c_kv": _update_cache(cache["c_kv"], c_kv, pos),
            "k_rope": _update_cache(cache["k_rope"], k_rope, pos),
        }
        ck = new_cache["c_kv"].astype(jnp.float32)     # (B, T, r)
        kr = new_cache["k_rope"].astype(jnp.float32)   # (B, T, rd)
        # Absorb wk_b into the query: q_lat (B,S,H,r)
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope.astype(jnp.float32),
                           p["wk_b"].astype(jnp.float32))
        scores = (
            jnp.einsum("bshr,btr->bhst", q_lat, ck)
            + jnp.einsum("bshk,btk->bhst", q_rope.astype(jnp.float32), kr)
        ) * scale
        t = new_cache["c_kv"].shape[1]
        mask = _mask(b, s, t, causal=True, q_offset=pos, kv_len=pos + s)
        scores = jnp.where(mask[:, None], NEG_INF, scores)  # (B,H,S,T)
        w = jax.nn.softmax(scores, axis=-1)
        lat = jnp.einsum("bhst,btr->bshr", w, ck)      # latent attention
        out = jnp.einsum("bshr,rhk->bshk", lat, p["wv_b"].astype(jnp.float32))
        out = out.astype(x.dtype)

    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache


def attention_layer(p, x, cfg, **kw) -> tuple[jnp.ndarray, Any]:
    if cfg.attention == "mla":
        return mla_attention(p, x, cfg, **kw)
    return gqa_attention(p, x, cfg, **kw)
