"""Mamba (selective SSM) block — Jamba's attention-free layer.

TPU adaptation: instead of the CUDA hardware-aware sequential SRAM scan, the
recurrence is chunked — a python-unrolled loop over sequence chunks with an
associative scan *inside* each chunk.  Chunk sizing keeps both the
materialized (B, L, d_inner, d_state) chunk tensors inside a per-device VMEM
/HBM budget and the unroll count low enough for fast SPMD compiles, while
keeping HLO FLOP accounting exact (no `while` bodies — see scan_utils).

State for decode: (conv_tail (B, d_conv-1, d_inner), h (B, d_inner, d_state)).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.params import Spec
from repro.models.scan_utils import pick_chunk, unrolled_chunk_scan


def mamba_specs(cfg: ModelConfig) -> dict[str, Spec]:
    d, di = cfg.d_model, cfg.ssm_d_inner
    n, dc, dtr = cfg.ssm_d_state, cfg.ssm_d_conv, cfg.ssm_dt_rank
    return {
        "in_proj": Spec((d, 2 * di), ("embed", "ff"), fan_in=d),
        "conv_w": Spec((dc, di), (None, "ff")),
        "conv_b": Spec((di,), ("ff",), init="zeros"),
        "x_proj": Spec((di, dtr + 2 * n), ("ff", None), fan_in=di),
        "dt_w": Spec((dtr, di), (None, "ff"), fan_in=dtr),
        "dt_b": Spec((di,), ("ff",), init="zeros", dtype=jnp.float32),
        "a_log": Spec((di, n), ("ff", "state"), init="zeros",
                      dtype=jnp.float32),
        "d_skip": Spec((di,), ("ff",), init="ones", dtype=jnp.float32),
        "out_proj": Spec((di, d), ("ff", "embed"), fan_in=di),
    }


def mamba_state_specs(cfg: ModelConfig, batch: int) -> dict[str, Spec]:
    di, n, dc = cfg.ssm_d_inner, cfg.ssm_d_state, cfg.ssm_d_conv
    return {
        "conv": Spec((batch, dc - 1, di), ("batch", None, "ff"), init="zeros"),
        "h": Spec((batch, di, n), ("batch", "ff", "state"), init="zeros",
                  dtype=jnp.float32),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 tail: jnp.ndarray | None) -> jnp.ndarray:
    """Depthwise causal conv along seq.  x (B,S,di), w (dc,di)."""
    dc = w.shape[0]
    if tail is None:
        pad = jnp.zeros((x.shape[0], dc - 1, x.shape[2]), x.dtype)
    else:
        pad = tail.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)          # (B, S+dc-1, di)
    # sum_j w[j] * x[t-dc+1+j]: unrolled taps (dc is 4)
    s = x.shape[1]
    out = sum(
        xp[:, j : j + s, :] * w[j][None, None, :] for j in range(dc)
    )
    return out + b[None, None, :]


def _ssm_scan(
    delta: jnp.ndarray,  # (B, S, di) fp32
    a: jnp.ndarray,      # (di, n) fp32, negative
    b_ssm: jnp.ndarray,  # (B, S, n) fp32
    c: jnp.ndarray,      # (B, S, n) fp32
    xf: jnp.ndarray,     # (B, S, di) fp32
    h0: jnp.ndarray,     # (B, di, n) fp32
    chunk: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked h_t = exp(delta_t A) h_{t-1} + delta_t B_t x_t;
    y_t = sum_n C_tn h_tn.  The (B, L, di, n) decay/input tensors exist only
    per chunk (computed inside the body), never for the full sequence."""
    b, s, di = delta.shape
    n = a.shape[-1]
    nchunks = s // chunk

    def body(h, xs):
        delta_c, b_c, c_c, x_c = xs                  # (B,L,di), (B,L,n), ...
        da_c = jnp.exp(delta_c[..., None] * a[None, None])      # (B,L,di,n)
        bx_c = delta_c[..., None] * b_c[:, :, None, :] * x_c[..., None]
        # Fold carry into the first step, then associative scan in-chunk.
        bx_c = bx_c.at[:, 0].add(da_c[:, 0] * h)

        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, bl * ar + br

        _, hs = jax.lax.associative_scan(
            combine, (da_c, bx_c), axis=1
        )                                            # hs: (B, L, di, n)
        y_c = jnp.einsum("bln,bldn->bld", c_c, hs)
        return hs[:, -1], y_c

    def chunked(t):  # (B, S, ...) -> (nchunks, B, L, ...)
        return t.reshape(b, nchunks, chunk, *t.shape[2:]).swapaxes(0, 1)

    xs = (chunked(delta), chunked(b_ssm), chunked(c), chunked(xf))
    h_final, ys = unrolled_chunk_scan(body, h0, xs)
    y = ys.swapaxes(0, 1).reshape(b, s, di)
    return y, h_final


def mamba_layer(
    p: dict[str, jnp.ndarray],
    x: jnp.ndarray,                       # (B, S, d)
    cfg: ModelConfig,
    *,
    mode: str,
    state: dict[str, jnp.ndarray] | None,
):
    """Returns (out (B,S,d), new_state)."""
    b, s, d = x.shape
    di, n, dtr = cfg.ssm_d_inner, cfg.ssm_d_state, cfg.ssm_dt_rank

    xz = x @ p["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)             # (B, S, di) each

    if mode == "decode":
        conv_tail = state["conv"]
        x_conv = _causal_conv(x_in, p["conv_w"], p["conv_b"], conv_tail)
        new_conv = jnp.concatenate([conv_tail, x_in], 1)[:, -(cfg.ssm_d_conv - 1):]
    else:
        x_conv = _causal_conv(x_in, p["conv_w"], p["conv_b"], None)
        new_conv = jnp.concatenate(
            [jnp.zeros((b, cfg.ssm_d_conv - 1, di), x.dtype), x_in], 1
        )[:, -(cfg.ssm_d_conv - 1):]
    x_conv = jax.nn.silu(x_conv)

    proj = x_conv @ p["x_proj"]                     # (B, S, dtr + 2n)
    dt_raw = proj[..., :dtr]
    b_ssm = proj[..., dtr : dtr + n].astype(jnp.float32)
    c_ssm = proj[..., dtr + n :].astype(jnp.float32)
    delta = jax.nn.softplus(
        dt_raw.astype(jnp.float32) @ p["dt_w"].astype(jnp.float32) + p["dt_b"]
    )                                               # (B, S, di)
    a = -jnp.exp(p["a_log"])                        # (di, n)

    xf = x_conv.astype(jnp.float32)
    h0 = (
        state["h"].astype(jnp.float32)
        if (state is not None and mode == "decode")
        else jnp.zeros((b, di, n), jnp.float32)
    )

    if mode == "decode" and s == 1:
        da = jnp.exp(delta[:, 0, :, None] * a[None])            # (B, di, n)
        bx = delta[:, 0, :, None] * b_ssm[:, 0, None, :] * xf[:, 0, :, None]
        h = da * h0 + bx
        y = jnp.einsum("bn,bdn->bd", c_ssm[:, 0], h)[:, None, :]
        h_final = h
    else:
        # Fewer, larger chunks: trace/compile cost scales with the unroll
        # count while per-chunk VMEM stays modest (B,L,di,n tiles).
        chunk = pick_chunk(s, target_iters=16, max_chunk=2048)
        y, h_final = _ssm_scan(delta, a, b_ssm, c_ssm, xf, h0, chunk)

    y = y + p["d_skip"][None, None] * xf
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = y @ p["out_proj"]
    new_state = {"conv": new_conv.astype(x.dtype), "h": h_final}
    return out, new_state
