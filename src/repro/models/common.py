"""Shared layer primitives: RMSNorm, RoPE (standard / partial / M-RoPE)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.params import Spec


def rms_norm_spec(d: int) -> Spec:
    return Spec((d,), ("embed",), init="ones", dtype=jnp.float32)


def shard_act(x: jnp.ndarray, batch_part):
    """Activation sharding constraint: pin the batch dim of (B, ...) to the
    DP mesh axes.  Without this, GSPMD can resolve the FSDP conflict (batch
    and param-embed both sharded on "data") by gathering the *batch* —
    catastrophically — instead of the parameters.  No-op outside a mesh
    context (CPU smoke tests pass batch_part=None)."""
    if batch_part is None:
        return x
    spec = jax.sharding.PartitionSpec(batch_part, *(None,) * (x.ndim - 1))
    return jax.lax.with_sharding_constraint(x, spec)


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = (xf * xf).mean(-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale).astype(x.dtype)


def rope_freqs(dim: int, theta: float) -> jnp.ndarray:
    """(dim/2,) inverse frequencies."""
    return 1.0 / (
        theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    )


def apply_rope(
    x: jnp.ndarray,            # (B, S, H, D_rot) -- rotary slice only
    positions: jnp.ndarray,    # (B, S) int32
    theta: float,
) -> jnp.ndarray:
    """Standard rotary embedding on the last dim (interleaved halves)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                      # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (B, S, d/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jnp.ndarray,            # (B, S, H, D_rot)
    positions: jnp.ndarray,    # (3, B, S) int32 -- (t, h, w) position streams
    theta: float,
    sections: tuple[int, ...],  # per-section half-dims, sum == D_rot/2
) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE: the head-dim halves are split into
    (temporal, height, width) sections, each rotated by its own position
    stream.  For pure text all three streams are identical => standard RoPE.
    """
    d = x.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    inv = rope_freqs(d, theta)                       # (d/2,)
    # Build a (B, S, d/2) position tensor by section.
    parts = []
    off = 0
    for sec, stream in zip(sections, positions):
        parts.append(
            stream[..., None].astype(jnp.float32)
            * jnp.ones((sec,), jnp.float32)
        )
        off += sec
    pos_full = jnp.concatenate(parts, -1)            # (B, S, d/2)
    ang = pos_full * inv[None, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def rope_for(cfg: ModelConfig, x, positions):
    """Dispatch: M-RoPE if configured, else standard; partial rotary slices
    handled by the caller."""
    if cfg.mrope_sections is not None:
        if positions.ndim == 2:  # text-only: replicate stream
            positions = jnp.broadcast_to(positions[None], (3, *positions.shape))
        return apply_mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    return apply_rope(x, positions, cfg.rope_theta)


def checkpoint_body(body, cfg):
    """jax.checkpoint with the configured policy: "full" saves only layer
    inputs (max recompute, min memory); "dots" saves matmul outputs
    (no matmul recompute in backward -> fewer FLOPs, more memory)."""
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_saveable
        )
    return jax.checkpoint(body)
