"""Unified model configuration covering all assigned architecture families."""

from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "audio", "hybrid", "vlm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- attention ---
    attention: Literal["gqa", "mla", "none"] = "gqa"
    rope_theta: float = 10_000.0
    rotary_pct: float = 1.0          # stablelm: partial rotary
    mrope_sections: tuple[int, ...] | None = None  # qwen2-vl M-RoPE

    # --- MLA (minicpm3 / deepseek-v2) ---
    q_lora_rank: int = 0             # 0 -> direct q projection
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0      # deepseek: first layer dense
    moe_layer_period: int = 1        # jamba: MoE on every 2nd layer
    moe_capacity_factor: float = 1.25

    # --- hybrid (jamba): attention every `attn_layer_period` layers ---
    attn_layer_period: int = 0       # 0 -> attention everywhere
    attn_layer_offset: int = 0

    # --- SSM (mamba) ---
    ssm_d_state: int = 16
    ssm_d_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0             # 0 -> ceil(d_model / 16)

    # --- RWKV ---
    rwkv_head_size: int = 64
    rwkv_lora_decay: int = 64
    rwkv_lora_mix: int = 32

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 1500          # precomputed frame embeddings (stub)

    # --- input stub: model consumes precomputed embeddings, not token ids ---
    embeds_input: bool = False       # qwen2-vl patch/text embedding stub

    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    max_seq: int = 8192              # cache default; shapes override
    # --- perf knobs (EXPERIMENTS.md §Perf hillclimbs) ---
    kv_cache_dtype: str = "bf16"     # "bf16" | "int8" (quantized KV cache)
    remat_policy: str = "full"       # "full" | "dots" (save matmul outputs)
    # Python-unroll the layer stack instead of lax.scan.  Used by the
    # dry-run's L1/L2 cost-delta variants: XLA cost analysis counts a while
    # body once regardless of trip count, so exact per-layer costs need the
    # layers materialized in HLO.
    unroll_layers: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.ssm_dt_rank == 0:
            object.__setattr__(
                self, "ssm_dt_rank", -(-self.d_model // 16)
            )

    # ---- derived ----
    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_size

    def is_attn_layer(self, i: int) -> bool:
        if self.family == "ssm":
            return False
        if self.attn_layer_period == 0:
            return True
        return i % self.attn_layer_period == self.attn_layer_offset

    def is_moe_layer(self, i: int) -> bool:
        if self.num_experts == 0 or i < self.first_dense_layers:
            return False
        return (i % self.moe_layer_period) == (self.moe_layer_period - 1) \
            if self.moe_layer_period > 1 else True

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic context handling (per-assignment long_500k gate)."""
        return self.family in ("ssm", "hybrid")


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (input-shape) cell of the assignment matrix."""

    name: str
    kind: Literal["train", "prefill", "decode"]
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
}


def cells_for(cfg: ModelConfig) -> list[str]:
    """Shape cells this arch runs (long_500k only for sub-quadratic archs;
    no encoder-only archs in the pool, so decode runs everywhere)."""
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.supports_long_context:
        cells.append("long_500k")
    return cells
