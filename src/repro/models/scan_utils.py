"""Chunked-scan helper.

XLA's HLO cost analysis counts a `while` body once, regardless of trip
count.  The dry-run's L1/L2 delta method corrects the *layer* dimension by
unrolling layers; the inner per-layer chunk recurrences (Mamba/RWKV) stay as
``lax.scan`` (unrolling them exploded trace/compile time ~20x via
associative_scan expansion), and the small FLOPs remainder they hide —
measured <5% of a Mamba/RWKV layer, dominated by projections — is added
back analytically (`hlo_analysis.inner_recurrence_flops`, documented in
EXPERIMENTS.md §Roofline).
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

MAX_UNROLL = 512


def unrolled_chunk_scan(
    body: Callable[[Any, Any], tuple[Any, Any]],
    carry: Any,
    xs: Any,
    *,
    axis: int = 0,
) -> tuple[Any, Any]:
    """Scan over the leading axis of ``xs`` leaves via lax.scan.

    body(carry, x_slice) -> (carry, y_slice); ys are stacked on ``axis``.
    (Name kept from the earlier python-unrolled implementation; see module
    docstring for why this is a lax.scan now.)
    """
    if axis != 0:
        xs = jax.tree.map(lambda a: jnp.moveaxis(a, axis, 0), xs)
    carry, ys = jax.lax.scan(body, carry, xs)
    if axis != 0:
        ys = jax.tree.map(lambda a: jnp.moveaxis(a, 0, axis), ys)
    return carry, ys


def pick_chunk(seq_len: int, *, target_iters: int = 64, min_chunk: int = 32,
               max_chunk: int = 1024) -> int:
    """Chunk length giving ~target_iters unrolled iterations, divisor-aligned."""
    chunk = max(min_chunk, min(max_chunk, -(-seq_len // target_iters)))
    # round up to a multiple of min_chunk that divides seq_len if possible
    while seq_len % chunk and chunk < max_chunk:
        chunk += 1
    return min(chunk, seq_len)
