"""Whisper-small encoder-decoder backbone (audio family).

Per the assignment, the conv/mel frontend is a STUB: the encoder consumes
precomputed frame embeddings (B, S_enc, d) supplied by input_specs().  The
transformer backbone is faithful: bidirectional encoder, causal decoder with
cross-attention, learned positional embeddings, GELU MLPs.

Decode-time cache: per-decoder-layer self-attn KV (grows with generated
tokens) plus the cross-attn KV computed once at prefill from the encoder
output (static thereafter).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import ffn
from repro.models.common import rms_norm, rms_norm_spec, shard_act
from repro.models.config import ModelConfig
from repro.models.params import Spec, stack_spec_tree


def _enc_layer_specs(cfg: ModelConfig) -> dict[str, Any]:
    return {
        "attn_norm": rms_norm_spec(cfg.d_model),
        "attn": attn.gqa_specs(cfg),
        "mlp_norm": rms_norm_spec(cfg.d_model),
        "mlp": ffn.mlp_specs(cfg.d_model, cfg.d_ff, act="gelu"),
    }


def _dec_layer_specs(cfg: ModelConfig) -> dict[str, Any]:
    return {
        "self_norm": rms_norm_spec(cfg.d_model),
        "self_attn": attn.gqa_specs(cfg),
        "cross_norm": rms_norm_spec(cfg.d_model),
        "cross_attn": attn.gqa_specs(cfg),
        "mlp_norm": rms_norm_spec(cfg.d_model),
        "mlp": ffn.mlp_specs(cfg.d_model, cfg.d_ff, act="gelu"),
    }


def param_specs(cfg: ModelConfig) -> dict[str, Any]:
    d = cfg.d_model
    return {
        "embed": Spec((cfg.vocab_size, d), ("vocab", "embed"), fan_in=1),
        "enc_pos": Spec((cfg.encoder_seq, d), (None, "embed"), fan_in=1),
        "dec_pos": Spec((cfg.max_seq, d), (None, "embed"), fan_in=1),
        "enc_layers": stack_spec_tree(_enc_layer_specs(cfg),
                                      cfg.encoder_layers),
        "dec_layers": stack_spec_tree(_dec_layer_specs(cfg), cfg.num_layers),
        "enc_norm": rms_norm_spec(d),
        "final_norm": rms_norm_spec(d),
        "lm_head": Spec((d, cfg.vocab_size), ("embed", "vocab"), fan_in=d),
    }


def cache_specs(cfg: ModelConfig, batch: int, seq: int) -> dict[str, Any]:
    hkv, hd = cfg.num_kv_heads, cfg.head_dim
    axes = ("batch", "cache_seq", "kv_heads", "head_dim")
    per_layer = {
        "k": Spec((batch, seq, hkv, hd), axes, init="zeros"),
        "v": Spec((batch, seq, hkv, hd), axes, init="zeros"),
        "cross_k": Spec((batch, cfg.encoder_seq, hkv, hd), axes, init="zeros"),
        "cross_v": Spec((batch, cfg.encoder_seq, hkv, hd), axes, init="zeros"),
    }
    return {"dec_layers": stack_spec_tree(per_layer, cfg.num_layers)}


def _encode(params, cfg, enc_frames, batch_part=None):
    s = enc_frames.shape[1]
    x = enc_frames + params["enc_pos"][None, :s].astype(enc_frames.dtype)
    x = shard_act(x, batch_part)
    positions = jnp.zeros(enc_frames.shape[:2], jnp.int32)  # rotary_pct=0

    def body(x, p_l):
        h, _ = attn.gqa_attention(
            p_l["attn"], rms_norm(x, p_l["attn_norm"], cfg.norm_eps), cfg,
            mode="train", cache=None, pos=0, positions=positions,
            causal=False,
        )
        x = x + h
        x = x + ffn.mlp(p_l["mlp"], rms_norm(x, p_l["mlp_norm"], cfg.norm_eps))
        return shard_act(x, batch_part), None

    if cfg.unroll_layers:
        from repro.models.transformer import _unrolled_layers

        def body2(x, xs):
            p_l, _ = xs
            return body(x, p_l)

        x, _ = _unrolled_layers(body2, x, params["enc_layers"], None)
    else:
        x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _cross_kv(p_attn, enc_out):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p_attn["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p_attn["wv"])
    return k, v


def _decoder(params, cfg, tokens, cache, mode, pos, enc_out=None,
             batch_part=None):
    from repro.models.transformer import _positions

    b, s = tokens.shape
    x = params["embed"][tokens]
    dec_positions = _positions(pos, b, s)               # (B, S)
    x = shard_act(x + params["dec_pos"][dec_positions].astype(x.dtype),
                  batch_part)
    positions = jnp.zeros((b, s), jnp.int32)  # learned positions, no rope

    enc_len = cfg.encoder_seq

    def body(x, xs):
        p_l, cache_l = xs
        # self attention (causal, cached)
        h, nc = attn.gqa_attention(
            p_l["self_attn"], rms_norm(x, p_l["self_norm"], cfg.norm_eps),
            cfg, mode=mode if mode != "train" else "train",
            cache=(
                {"k": cache_l["k"], "v": cache_l["v"]}
                if cache_l is not None else None
            ),
            pos=pos, positions=positions, causal=True,
        )
        x = x + h
        # cross attention (non-causal against encoder KV)
        xn = rms_norm(x, p_l["cross_norm"], cfg.norm_eps)
        if mode == "decode":
            ck, cv = cache_l["cross_k"], cache_l["cross_v"]
        else:
            ck, cv = _cross_kv(p_l["cross_attn"], enc_out)
        q = jnp.einsum("bsd,dhk->bshk", xn, p_l["cross_attn"]["wq"])
        outc = attn._sdpa(
            q, ck, cv, causal=False, q_offset=0, kv_len=enc_len,
            scale=cfg.head_dim ** -0.5,
        )
        x = shard_act(
            x + jnp.einsum("bshk,hkd->bsd", outc, p_l["cross_attn"]["wo"]),
            batch_part,
        )
        x = shard_act(
            x + ffn.mlp(p_l["mlp"],
                        rms_norm(x, p_l["mlp_norm"], cfg.norm_eps)),
            batch_part,
        )
        new_cache_l = None
        if cache_l is not None:
            new_cache_l = dict(nc) if nc is not None else {
                "k": cache_l["k"], "v": cache_l["v"]}
            new_cache_l["cross_k"] = ck
            new_cache_l["cross_v"] = cv
        return x, new_cache_l

    if cfg.unroll_layers:
        from repro.models.transformer import _unrolled_layers
        x, new_layers = _unrolled_layers(
            body, x, params["dec_layers"],
            cache["dec_layers"] if cache is not None else None,
        )
        new_cache = (
            {"dec_layers": new_layers} if cache is not None else None
        )
    elif cache is not None:
        x, new_layers = jax.lax.scan(body, x, (params["dec_layers"],
                                               cache["dec_layers"]))
        new_cache = {"dec_layers": new_layers}
    else:
        def body_nc(x, p_l):
            x, _ = body(x, (p_l, None))
            return x, None
        x, _ = jax.lax.scan(body_nc, x, params["dec_layers"])
        new_cache = None

    if mode == "prefill":
        x = x[:, -1:]  # next-token logits only (see transformer.apply)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return (x @ params["lm_head"]).astype(jnp.float32), new_cache


def apply(
    params: dict[str, Any],
    cfg: ModelConfig,
    *,
    tokens: jnp.ndarray,                 # (B, S_dec)
    enc_frames: jnp.ndarray | None = None,  # (B, S_enc, d) stub embeddings
    embeds=None,
    mode: str = "train",
    cache: dict[str, Any] | None = None,
    pos: jnp.ndarray | int = 0,
    remat: bool = True,  # noqa: ARG001 (enc/dec scans already bound memory)
    batch_part=None,
):
    if mode in ("train", "prefill"):
        enc_out = _encode(params, cfg, enc_frames, batch_part)
        return _decoder(params, cfg, tokens, cache, mode, pos, enc_out,
                        batch_part)
    return _decoder(params, cfg, tokens, cache, "decode", pos,
                    batch_part=batch_part)
