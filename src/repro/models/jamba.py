"""Jamba: hybrid Mamba + attention + MoE (arch jamba-v0.1-52b).

32 layers = 4 scanned super-blocks of the period-8 pattern:
  slot i in 0..7:  mixer = attention at i == attn_layer_offset (4), else Mamba
                   ffn   = MoE on odd slots, dense MLP on even slots
(1:7 attention:Mamba interleave, MoE every other layer — paper config
arXiv:2403.19887).  The super-block is the scan unit, so per-block params /
caches stack on a leading axis of 4 and HLO contains exactly one block.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import ffn
from repro.models.common import rms_norm, rms_norm_spec, shard_act
from repro.models.config import ModelConfig
from repro.models.mamba import mamba_layer, mamba_specs, mamba_state_specs
from repro.models.params import Spec, stack_spec_tree

PERIOD = 8


def _block_specs(cfg: ModelConfig) -> dict[str, Any]:
    s: dict[str, Any] = {}
    for i in range(PERIOD):
        layer: dict[str, Any] = {"norm": rms_norm_spec(cfg.d_model)}
        if cfg.is_attn_layer(i):
            layer["attn"] = attn.attn_specs(cfg)
        else:
            layer["mamba"] = mamba_specs(cfg)
        layer["ffn_norm"] = rms_norm_spec(cfg.d_model)
        if cfg.is_moe_layer(i):
            layer["moe"] = ffn.moe_specs(cfg)
        else:
            layer["mlp"] = ffn.mlp_specs(cfg.d_model, cfg.d_ff)
        s[f"l{i}"] = layer
    return s


def param_specs(cfg: ModelConfig) -> dict[str, Any]:
    assert cfg.num_layers % PERIOD == 0
    nblocks = cfg.num_layers // PERIOD
    return {
        "embed": Spec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                      fan_in=1),
        "blocks": stack_spec_tree(_block_specs(cfg), nblocks),
        "final_norm": rms_norm_spec(cfg.d_model),
        "lm_head": Spec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
                        fan_in=cfg.d_model),
    }


def cache_specs(cfg: ModelConfig, batch: int, seq: int) -> dict[str, Any]:
    nblocks = cfg.num_layers // PERIOD
    per_block: dict[str, Any] = {}
    for i in range(PERIOD):
        if cfg.is_attn_layer(i):
            per_block[f"l{i}"] = attn.cache_specs(cfg, batch, seq)
        else:
            per_block[f"l{i}"] = mamba_state_specs(cfg, batch)
    return {"blocks": stack_spec_tree(per_block, nblocks)}


def _block_apply(cfg, p_b, x, cache_b, *, mode, pos, positions,
                 batch_part=None):
    new_cache = {}
    for i in range(PERIOD):
        p_l = p_b[f"l{i}"]
        cache_l = cache_b[f"l{i}"] if cache_b is not None else None
        xn = rms_norm(x, p_l["norm"], cfg.norm_eps)
        if cfg.is_attn_layer(i):
            h, nc = attn.attention_layer(
                p_l["attn"], xn, cfg, mode=mode, cache=cache_l, pos=pos,
                positions=positions,
            )
        else:
            h, nc = mamba_layer(p_l["mamba"], xn, cfg, mode=mode,
                                state=cache_l)
        x = shard_act(x + h, batch_part)
        new_cache[f"l{i}"] = nc
        xn = rms_norm(x, p_l["ffn_norm"], cfg.norm_eps)
        if cfg.is_moe_layer(i):
            x = x + ffn.moe(p_l["moe"], xn, cfg)
        else:
            x = x + ffn.mlp(p_l["mlp"], xn)
        x = shard_act(x, batch_part)
    return x, new_cache


def apply(
    params: dict[str, Any],
    cfg: ModelConfig,
    *,
    tokens: jnp.ndarray,
    embeds=None,
    mode: str = "train",
    cache: dict[str, Any] | None = None,
    pos: jnp.ndarray | int = 0,
    remat: bool = True,
    batch_part=None,
):
    from repro.models.transformer import _positions

    x = shard_act(params["embed"][tokens], batch_part)
    b, s = tokens.shape
    positions = _positions(pos, b, s)

    def body(x, xs):
        p_b, cache_b = xs
        return _block_apply(
            cfg, p_b, x, cache_b, mode=mode, pos=pos, positions=positions,
            batch_part=batch_part,
        )

    if mode == "train" and remat:
        from repro.models.common import checkpoint_body
        body = checkpoint_body(body, cfg)

    if cfg.unroll_layers:
        from repro.models.transformer import _unrolled_layers
        x, new_blocks = _unrolled_layers(
            body, x, params["blocks"],
            cache["blocks"] if cache is not None else None,
        )
        new_cache = {"blocks": new_blocks} if cache is not None else None
    elif cache is not None:
        x, new_blocks = jax.lax.scan(body, x, (params["blocks"],
                                               cache["blocks"]))
        new_cache = {"blocks": new_blocks}
    else:
        def body_nc(x, p_b):
            x, _ = body(x, (p_b, None))
            return x, None
        x, _ = jax.lax.scan(body_nc, x, params["blocks"])
        new_cache = None

    if mode == "prefill":
        # next-token logits only: a 32k-token fp32 logit tensor is O(100 GB)
        # of vocab-head compute and output traffic nobody reads.
        x = x[:, -1:]
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x @ params["lm_head"]).astype(jnp.float32)
    return logits, new_cache
