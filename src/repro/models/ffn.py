"""Feed-forward layers: SwiGLU MLP and sort-based capacity-buffer MoE.

The MoE dispatch is FLOP-faithful (computes only top-k routed tokens up to a
per-expert capacity, not a dense all-experts product) and avoids the
O(tokens x experts x capacity) one-hot dispatch tensors of einsum-style MoE:
tokens are argsorted by expert id, ranked within their expert segment, and
scattered into an (E, C, d) compute buffer (drop-on-overflow).  Expert and
buffer tensors carry the "experts" logical axis so expert parallelism is a
sharding-rule choice.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.params import Spec


# ---------------------------------------------------------------------------
# Dense SwiGLU
# ---------------------------------------------------------------------------

def mlp_specs(d_model: int, d_ff: int, act: str = "swiglu") -> dict[str, Spec]:
    if act == "gelu":  # whisper-style
        return {
            "w_in": Spec((d_model, d_ff), ("embed", "ff"), fan_in=d_model),
            "w_out": Spec((d_ff, d_model), ("ff", "embed"), fan_in=d_ff),
        }
    return {
        "w_gate": Spec((d_model, d_ff), ("embed", "ff"), fan_in=d_model),
        "w_up": Spec((d_model, d_ff), ("embed", "ff"), fan_in=d_model),
        "w_down": Spec((d_ff, d_model), ("ff", "embed"), fan_in=d_ff),
    }


def mlp(p: dict[str, jnp.ndarray], x: jnp.ndarray) -> jnp.ndarray:
    if "w_in" in p:
        return jax.nn.gelu(x @ p["w_in"]) @ p["w_out"]
    return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def moe_specs(cfg: ModelConfig) -> dict[str, Spec]:
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    s = {
        "router": Spec((d, e), ("embed", "experts"), fan_in=d,
                       dtype=jnp.float32),
        "w_gate": Spec((e, d, f), ("experts", "embed", "moe_ff"), fan_in=d),
        "w_up": Spec((e, d, f), ("experts", "embed", "moe_ff"), fan_in=d),
        "w_down": Spec((e, f, d), ("experts", "moe_ff", "embed"), fan_in=f),
    }
    if cfg.num_shared_experts:
        fs = cfg.num_shared_experts * cfg.moe_d_ff
        s["shared"] = mlp_specs(d, fs)
    return s


def _capacity(num_tokens: int, cfg: ModelConfig) -> int:
    cap = int(
        cfg.top_k * num_tokens / cfg.num_experts * cfg.moe_capacity_factor
    )
    return max(cap, 8)


def moe(
    p: dict[str, jnp.ndarray], x: jnp.ndarray, cfg: ModelConfig
) -> jnp.ndarray:
    """Sort-based top-k MoE with capacity dropping. x: (B, S, d)."""
    b, s, d = x.shape
    n = b * s
    k = cfg.top_k
    e = cfg.num_experts
    cap = _capacity(n, cfg)
    xf = x.reshape(n, d)

    # Routing (fp32 for numerics).
    logits = xf.astype(jnp.float32) @ p["router"]          # (n, e)
    probs = jax.nn.softmax(logits, -1)
    top_w, top_i = jax.lax.top_k(probs, k)                  # (n, k)
    top_w = top_w / top_w.sum(-1, keepdims=True)            # renormalize

    # Rank each (token, k) assignment within its expert segment.
    flat_e = top_i.reshape(-1)                              # (n*k,)
    order = jnp.argsort(flat_e)                             # stable
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(e))   # (e,)
    rank = jnp.arange(n * k) - seg_start[sorted_e]          # within-expert
    slot = sorted_e * cap + rank                            # (n*k,)
    valid = rank < cap                                      # capacity drop
    slot = jnp.where(valid, slot, e * cap)                  # OOB -> dropped

    # Scatter tokens into the (e*cap, d) compute buffer.
    token_of = order // k                                   # source token
    buf = jnp.zeros((e * cap + 1, d), x.dtype)
    buf = buf.at[slot].set(xf[token_of], mode="drop")
    buf = buf[: e * cap].reshape(e, cap, d)

    # Expert FFNs (batched einsum over the expert dim).
    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]))
    up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    out = jnp.einsum("ecf,efd->ecd", gate * up, p["w_down"])

    # Gather back and combine with routing weights.
    out_flat = out.reshape(e * cap, d)
    y_sorted = jnp.where(
        valid[:, None], out_flat[jnp.clip(slot, 0, e * cap - 1)], 0.0
    )                                                       # (n*k, d)
    inv = jnp.argsort(order)                                # unsort
    y = y_sorted[inv].reshape(n, k, d)
    y = (y * top_w[..., None].astype(y.dtype)).sum(1)       # (n, d)

    if "shared" in p:
        y = y + mlp(p["shared"], xf)
    return y.reshape(b, s, d)


def moe_aux_loss(
    p: dict[str, jnp.ndarray], x: jnp.ndarray, cfg: ModelConfig
) -> jnp.ndarray:
    """Switch-style load-balancing auxiliary loss (mean over layers is added
    to the training objective)."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    probs = jax.nn.softmax(xf.astype(jnp.float32) @ p["router"], -1)
    top_i = jnp.argmax(probs, -1)
    me = probs.mean(0)                                      # router prob mass
    ce = jnp.zeros((cfg.num_experts,)).at[top_i].add(1.0) / xf.shape[0]
    return cfg.num_experts * (me * ce).sum()
