"""Parameter specs: one declaration drives init, abstract shapes, and
logical-axis sharding (MaxText-style logical->mesh rules)."""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec


@dataclasses.dataclass(frozen=True)
class Spec:
    """Declaration of one parameter tensor."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]       # logical axis name per dim
    init: str = "normal"               # normal | zeros | ones | scaled
    fan_in: int | None = None          # for "scaled": stddev = 1/sqrt(fan_in)
    dtype: Any = None                  # override (e.g. fp32 for norms)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


PyTree = Any


def tree_specs_map(fn: Callable[[Spec], Any], specs: PyTree) -> PyTree:
    return jax.tree.map(fn, specs, is_leaf=lambda x: isinstance(x, Spec))


def init_params(specs: PyTree, key: jax.Array, default_dtype=jnp.bfloat16):
    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, Spec)
    )
    keys = jax.random.split(key, len(leaves))

    def make(spec: Spec, k):
        dt = spec.dtype or default_dtype
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dt)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dt)
        fan = spec.fan_in or (spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1])
        std = 1.0 / math.sqrt(max(fan, 1))
        return (jax.random.normal(k, spec.shape, jnp.float32) * std).astype(dt)

    return jax.tree.unflatten(treedef, [make(s, k) for s, k in zip(leaves, keys)])


def abstract_params(specs: PyTree, default_dtype=jnp.bfloat16):
    return tree_specs_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype or default_dtype), specs
    )


def partition_spec(spec: Spec, rules: dict[str, str | tuple | None]) -> PartitionSpec:
    return PartitionSpec(*(rules.get(a) if a else None for a in spec.axes))


def _axis_size(mesh: Mesh, part) -> int:
    if part is None:
        return 1
    if isinstance(part, (tuple, list)):
        n = 1
        for p in part:
            n *= mesh.shape[p]
        return n
    return mesh.shape[part]


def sanitize_partition_spec(
    spec: Spec, rules: dict, mesh: Mesh
) -> PartitionSpec:
    """Partition spec with divisibility repair ("axis spill").

    GQA head counts (4..48), some vocab sizes, and whisper's 1500-frame
    cross cache don't divide a 16-way mesh axis.  Rather than rely on GSPMD
    padding for parameters (memory-hostile) we *spill*: a mesh axis whose
    target dim is indivisible moves to the first other dim of the same
    tensor that divides it and is not yet sharded on that axis; if none
    exists the axis is dropped (replicated).  Deterministic, per-tensor, and
    logged into the spec so the dry-run report shows what moved.
    """
    parts = [rules.get(a) if a else None for a in spec.axes]

    def mesh_axes_of(part):
        if part is None:
            return []
        return list(part) if isinstance(part, (tuple, list)) else [part]

    # Pass 1: strip mesh axes that don't divide their dim, or that an
    # earlier dim of this tensor already uses (a mesh axis may appear only
    # once per PartitionSpec).
    homeless: list[str] = []
    used: set[str] = set()
    for i, part in enumerate(parts):
        axes = mesh_axes_of(part)
        kept = []
        size = spec.shape[i]
        for ax in axes:
            if ax in used:
                continue  # duplicate across dims: drop silently
            n = mesh.shape[ax]
            combined = n
            for k in kept:
                combined *= mesh.shape[k]
            if size % combined == 0:
                kept.append(ax)
                used.add(ax)
            else:
                homeless.append(ax)
        parts[i] = tuple(kept) if len(kept) > 1 else (kept[0] if kept else None)

    # Pass 2: re-home stripped axes on other dims (never duplicating a mesh
    # axis already used by this tensor).
    for ax in homeless:
        if ax in used:
            continue
        for i, part in enumerate(parts):
            current = _axis_size(mesh, part)
            if spec.shape[i] % (current * mesh.shape[ax]) == 0:
                axes = mesh_axes_of(part) + [ax]
                parts[i] = tuple(axes) if len(axes) > 1 else axes[0]
                used.add(ax)
                break
        # not placeable -> replicated on that axis (dropped)
    return PartitionSpec(*parts)


def sharding_tree(specs: PyTree, mesh: Mesh, rules: dict) -> PyTree:
    return tree_specs_map(
        lambda s: NamedSharding(mesh, sanitize_partition_spec(s, rules, mesh)),
        specs,
    )


def pspec_tree(specs: PyTree, rules: dict) -> PyTree:
    return tree_specs_map(lambda s: partition_spec(s, rules), specs)


def count_params(specs: PyTree) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, Spec))
    return int(sum(np.prod(s.shape) for s in leaves))


def stack_layers(spec: Spec, num_layers: int) -> Spec:
    """Add a leading scanned-layers dim (never sharded)."""
    return dataclasses.replace(
        spec,
        shape=(num_layers, *spec.shape),
        axes=("layers", *spec.axes),
    )


def stack_spec_tree(specs: PyTree, num_layers: int) -> PyTree:
    return tree_specs_map(lambda s: stack_layers(s, num_layers), specs)
