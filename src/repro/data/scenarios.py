"""Adversarial scenario families for the policy tournament (§2 taxonomy).

The paper evaluates one strategy on one realized demand trace; the
tournament (``repro.core.tournament``) instead scores every policy across
the canonical workload taxonomy cloud cost planners are judged on —
steady, burst, cyclic, declining, unpredictable — with N seeded paths per
family, so competitive-ratio and regret numbers are *distributions*, not
anecdotes.

Each family reuses the ``synthetic_pool_set`` drivers: the same
:func:`repro.core.demand.synth_demand` trend x seasonality x AR(1) model
(clouds cycling aws/azure/gcp so pool keys line up with the Table-2
purchase options), then applies a family-specific transform with its own
seeded generator:

    steady         flat trend, mild seasonality, low noise
    burst          steady base + rare short multiplicative spikes
    cyclic         strong weekly + 4-week modulation on top
    declining      negative annual growth (a sunsetting fleet)
    unpredictable  regime-switching level shifts + heavy noise

Every family has a *defining property* the test-suite asserts per seed
(burst exceedance counts, cyclic lag-168 autocorrelation, declining
trend sign, ...), and every path is a pure function of
``(family, base_seed)`` — reproducibility is part of the contract.
"""

from __future__ import annotations

import jax
import numpy as np

from repro.core import demand as dm
from repro.core.demand import HOURS_PER_WEEK

FAMILIES: tuple[str, ...] = (
    "steady", "burst", "cyclic", "declining", "unpredictable",
)

_CLOUDS = ("aws", "azure", "gcp")

# Burst family knobs (shared with the coverage tests).
BURST_EVERY_WEEKS = 4          # ~one spike per this many weeks
BURST_FACTOR = 3.0             # spike multiplier
BURST_LEN_HOURS = (6, 24)      # spike duration range
# Unpredictable family knobs.
REGIME_SEGMENTS = 5
REGIME_RANGE = (0.5, 1.8)


def _family_config(family: str, pool: int) -> dm.DemandConfig:
    """The per-pool driver config, varied across pools the same way
    ``traces._pool_configs`` varies the synthetic artifact."""
    base = 40.0 * (1.5 ** (pool % 3))
    if family == "steady":
        return dm.DemandConfig(
            base_level=base, annual_growth=0.0,
            diurnal_amplitude=0.10 + 0.02 * (pool % 3),
            weekly_amplitude=0.12 + 0.02 * (pool % 4),
            noise_sigma=0.04,
        )
    if family == "burst":
        return dm.DemandConfig(
            base_level=base, annual_growth=0.0,
            diurnal_amplitude=0.08, weekly_amplitude=0.10,
            noise_sigma=0.05,
        )
    if family == "cyclic":
        return dm.DemandConfig(
            base_level=base, annual_growth=0.0,
            diurnal_amplitude=0.35 + 0.05 * (pool % 2),
            weekly_amplitude=0.45 + 0.05 * (pool % 3),
            noise_sigma=0.05,
        )
    if family == "declining":
        return dm.DemandConfig(
            base_level=1.6 * base, annual_growth=-0.90,
            diurnal_amplitude=0.10, weekly_amplitude=0.12,
            noise_sigma=0.05,
        )
    if family == "unpredictable":
        return dm.DemandConfig(
            base_level=base, annual_growth=0.0,
            diurnal_amplitude=0.10, weekly_amplitude=0.12,
            noise_sigma=0.15,
        )
    raise ValueError(f"unknown family {family!r}; known: {FAMILIES}")


def _transform(
    family: str, y: np.ndarray, rng: np.random.Generator
) -> np.ndarray:
    """Family-specific post-transform on one pool's hourly series."""
    t = y.shape[-1]
    if family == "burst":
        num_bursts = max(1, t // (BURST_EVERY_WEEKS * HOURS_PER_WEEK))
        for _ in range(num_bursts):
            ln = int(rng.integers(*BURST_LEN_HOURS))
            at = int(rng.integers(0, max(t - ln, 1)))
            y = y.copy()
            y[at:at + ln] *= BURST_FACTOR
        return y
    if family == "cyclic":
        # A 4-week business cycle on top of the weekly/diurnal pattern —
        # the autocorrelation structure the family is named for.
        phase = rng.uniform(0.0, 2.0 * np.pi)
        month = 1.0 + 0.3 * np.sin(
            2.0 * np.pi * np.arange(t) / (4 * HOURS_PER_WEEK) + phase
        )
        return y * month
    if family == "unpredictable":
        # Piecewise-constant regime multipliers: level shifts no
        # smooth structural fit anticipates.
        edges = np.sort(
            rng.integers(1, t, size=REGIME_SEGMENTS - 1)
        )
        mult = rng.uniform(*REGIME_RANGE, size=REGIME_SEGMENTS)
        levels = np.repeat(
            mult, np.diff(np.concatenate([[0], edges, [t]]))
        )
        return y * levels
    return y


def scenario_keys(num_pools: int) -> tuple[dm.PoolKey, ...]:
    """Pool keys for a scenario fleet, cloud-cycled like the artifact."""
    return tuple(
        (_CLOUDS[i % 3], f"region_{i % 4}", f"type_{i:02d}")
        for i in range(num_pools)
    )


def scenario_path(
    family: str,
    *,
    num_pools: int = 3,
    num_weeks: int = 40,
    seed: int = 0,
) -> np.ndarray:
    """One (P, T) demand path of ``family`` at ``seed``, T in whole weeks."""
    if family not in FAMILIES:
        raise ValueError(f"unknown family {family!r}; known: {FAMILIES}")
    num_hours = num_weeks * HOURS_PER_WEEK
    fam_idx = FAMILIES.index(family)
    rows = []
    for p in range(num_pools):
        cfg = _family_config(family, p)
        key = jax.random.PRNGKey(100_000 * fam_idx + 100 * seed + p)
        y = np.asarray(dm.synth_demand(num_hours, cfg, key=key))
        rng = np.random.default_rng((fam_idx, seed, p))
        rows.append(_transform(family, y, rng))
    return np.stack(rows).astype(np.float32)


def scenario_paths(
    family: str,
    *,
    num_pools: int = 3,
    num_weeks: int = 40,
    num_seeds: int = 32,
    base_seed: int = 0,
) -> np.ndarray:
    """(N, P, T) seeded paths of one family — the tournament's unit of
    coverage (N >= 32 by default so ratio/regret tails are populated)."""
    return np.stack([
        scenario_path(
            family, num_pools=num_pools, num_weeks=num_weeks,
            seed=base_seed + s,
        )
        for s in range(num_seeds)
    ])


def scenario_pool_set(
    family: str,
    *,
    num_pools: int = 3,
    num_weeks: int = 40,
    seed: int = 0,
) -> dm.PoolSet:
    """One scenario path wrapped as a :class:`~repro.core.demand.PoolSet`
    so the full planner surface (``plan_fleet_pools``) runs on it."""
    demand = scenario_path(
        family, num_pools=num_pools, num_weeks=num_weeks, seed=seed
    )
    return dm.PoolSet(
        keys=scenario_keys(num_pools),
        demand=demand,
        configs={
            k: _family_config(family, i)
            for i, k in enumerate(scenario_keys(num_pools))
        },
    )
