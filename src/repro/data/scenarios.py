"""Adversarial scenario families for the policy tournament (§2 taxonomy).

The paper evaluates one strategy on one realized demand trace; the
tournament (``repro.core.tournament``) instead scores every policy across
the canonical workload taxonomy cloud cost planners are judged on —
steady, burst, cyclic, declining, unpredictable — with N seeded paths per
family, so competitive-ratio and regret numbers are *distributions*, not
anecdotes.

Each family reuses the ``synthetic_pool_set`` drivers: the same
:func:`repro.core.demand.synth_demand` trend x seasonality x AR(1) model
(clouds cycling aws/azure/gcp so pool keys line up with the Table-2
purchase options), then applies a family-specific transform with its own
seeded generator:

    steady         flat trend, mild seasonality, low noise
    burst          steady base + rare short multiplicative spikes
    cyclic         strong weekly + 4-week modulation on top
    declining      negative annual growth (a sunsetting fleet)
    unpredictable  regime-switching level shifts + heavy noise

Every family has a *defining property* the test-suite asserts per seed
(burst exceedance counts, cyclic lag-168 autocorrelation, declining
trend sign, ...), and every path is a pure function of
``(family, base_seed)`` — reproducibility is part of the contract.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core import demand as dm
from repro.core.demand import HOURS_PER_WEEK

FAMILIES: tuple[str, ...] = (
    "steady", "burst", "cyclic", "declining", "unpredictable",
)

#: Perturbation families for :func:`scenario_batch` — transforms of the
#: *realized* trace (scenario 0 is always the realized path verbatim):
#:
#:     realized   N identical copies of the realized trace (the batching
#:                identity: ``n_scenarios=1`` IS today's single-path replay)
#:     burst      rare short multiplicative spikes (the §2 burst transform)
#:     regime     piecewise-constant level shifts (the unpredictable
#:                transform) — demand migrates without warning
#:     growth     a seeded exponential drift ramp, up or down
#:     scale      one lognormal level multiplier per pool — "our forecast
#:                of absolute fleet size is off by x%"
PERTURBATIONS: tuple[str, ...] = (
    "realized", "burst", "regime", "growth", "scale",
)

# Growth/scale perturbation knobs (annualized drift range, level sigma).
GROWTH_RANGE = (-0.35, 0.45)
SCALE_SIGMA = 0.20

_CLOUDS = ("aws", "azure", "gcp")

# Burst family knobs (shared with the coverage tests).
BURST_EVERY_WEEKS = 4          # ~one spike per this many weeks
BURST_FACTOR = 3.0             # spike multiplier
BURST_LEN_HOURS = (6, 24)      # spike duration range
# Unpredictable family knobs.
REGIME_SEGMENTS = 5
REGIME_RANGE = (0.5, 1.8)


def _family_config(family: str, pool: int) -> dm.DemandConfig:
    """The per-pool driver config, varied across pools the same way
    ``traces._pool_configs`` varies the synthetic artifact."""
    base = 40.0 * (1.5 ** (pool % 3))
    if family == "steady":
        return dm.DemandConfig(
            base_level=base, annual_growth=0.0,
            diurnal_amplitude=0.10 + 0.02 * (pool % 3),
            weekly_amplitude=0.12 + 0.02 * (pool % 4),
            noise_sigma=0.04,
        )
    if family == "burst":
        return dm.DemandConfig(
            base_level=base, annual_growth=0.0,
            diurnal_amplitude=0.08, weekly_amplitude=0.10,
            noise_sigma=0.05,
        )
    if family == "cyclic":
        return dm.DemandConfig(
            base_level=base, annual_growth=0.0,
            diurnal_amplitude=0.35 + 0.05 * (pool % 2),
            weekly_amplitude=0.45 + 0.05 * (pool % 3),
            noise_sigma=0.05,
        )
    if family == "declining":
        return dm.DemandConfig(
            base_level=1.6 * base, annual_growth=-0.90,
            diurnal_amplitude=0.10, weekly_amplitude=0.12,
            noise_sigma=0.05,
        )
    if family == "unpredictable":
        return dm.DemandConfig(
            base_level=base, annual_growth=0.0,
            diurnal_amplitude=0.10, weekly_amplitude=0.12,
            noise_sigma=0.15,
        )
    raise ValueError(f"unknown family {family!r}; known: {FAMILIES}")


def _transform(
    family: str, y: np.ndarray, rng: np.random.Generator
) -> np.ndarray:
    """Family-specific post-transform on one pool's hourly series."""
    t = y.shape[-1]
    if family == "burst":
        num_bursts = max(1, t // (BURST_EVERY_WEEKS * HOURS_PER_WEEK))
        for _ in range(num_bursts):
            ln = int(rng.integers(*BURST_LEN_HOURS))
            at = int(rng.integers(0, max(t - ln, 1)))
            y = y.copy()
            y[at:at + ln] *= BURST_FACTOR
        return y
    if family == "cyclic":
        # A 4-week business cycle on top of the weekly/diurnal pattern —
        # the autocorrelation structure the family is named for.
        phase = rng.uniform(0.0, 2.0 * np.pi)
        month = 1.0 + 0.3 * np.sin(
            2.0 * np.pi * np.arange(t) / (4 * HOURS_PER_WEEK) + phase
        )
        return y * month
    if family == "unpredictable":
        # Piecewise-constant regime multipliers: level shifts no
        # smooth structural fit anticipates.
        edges = np.sort(
            rng.integers(1, t, size=REGIME_SEGMENTS - 1)
        )
        mult = rng.uniform(*REGIME_RANGE, size=REGIME_SEGMENTS)
        levels = np.repeat(
            mult, np.diff(np.concatenate([[0], edges, [t]]))
        )
        return y * levels
    return y


@dataclasses.dataclass(frozen=True)
class ScenarioConfig:
    """Scenario axis of the batched rolling replay (``scenarios=`` on
    ``replan_fleet_pools`` / ``PlanRequest.scenarios``).

    ``n_scenarios`` demand futures are derived from the realized trace by
    the ``family`` perturbation (:data:`PERTURBATIONS`); scenario 0 is
    always the realized path itself, so ladders and goldens anchor on it
    and ``n_scenarios=1`` with the default ``"realized"`` family is
    *bit-identical* to the unbatched replay.  ``chunk`` bounds how many
    scenarios one compiled replay program carries (memory relief on a
    single host; ``None`` runs all N in one program)."""

    n_scenarios: int = 1
    family: str = "realized"
    seed: int = 0
    chunk: int | None = None

    def __post_init__(self):
        if self.n_scenarios < 1:
            raise ValueError(
                f"n_scenarios must be >= 1, got {self.n_scenarios}"
            )
        if self.family not in PERTURBATIONS:
            raise ValueError(
                f"unknown scenario family {self.family!r}; "
                f"known: {PERTURBATIONS}"
            )
        if self.chunk is not None and self.chunk < 1:
            raise ValueError(f"chunk must be >= 1 or None, got {self.chunk}")


def resolve_scenarios(
    spec: "ScenarioConfig | int | None",
) -> "ScenarioConfig | None":
    """Normalize the ``scenarios=`` spelling: ``None`` stays off,
    an int means ``ScenarioConfig(n_scenarios=int)``."""
    if spec is None or isinstance(spec, ScenarioConfig):
        return spec
    if isinstance(spec, bool):
        raise TypeError("scenarios= takes an int or ScenarioConfig, not bool")
    if isinstance(spec, int):
        return ScenarioConfig(n_scenarios=spec)
    raise TypeError(
        f"scenarios= takes None, an int, or a ScenarioConfig, "
        f"got {type(spec).__name__}"
    )


def _perturb(family: str, y: np.ndarray, rng: np.random.Generator):
    """One perturbed copy of one pool's realized hourly series."""
    t = y.shape[-1]
    if family == "burst":
        return _transform("burst", y, rng)
    if family == "regime":
        return _transform("unpredictable", y, rng)
    if family == "growth":
        g = rng.uniform(*GROWTH_RANGE)
        ramp = np.exp(g * np.arange(t) / (52.0 * HOURS_PER_WEEK))
        return y * ramp
    if family == "scale":
        return y * rng.lognormal(0.0, SCALE_SIGMA)
    return y


def scenario_batch(demand: np.ndarray, cfg: ScenarioConfig) -> np.ndarray:
    """(N, P, T) scenario batch derived from the realized ``demand`` (P, T).

    Scenario 0 is the realized trace verbatim; scenarios ``s >= 1`` apply
    the ``cfg.family`` perturbation with a generator seeded on
    ``(family, cfg.seed, s, pool)`` — every batch is a pure function of
    (demand, cfg), reproducibility being part of the contract exactly as
    for :func:`scenario_path`."""
    demand = np.asarray(demand, np.float32)
    if demand.ndim != 2:
        raise ValueError(f"demand must be (P, T), got shape {demand.shape}")
    if cfg.family == "realized":
        return np.broadcast_to(
            demand[None], (cfg.n_scenarios,) + demand.shape
        ).copy()
    fam_idx = PERTURBATIONS.index(cfg.family)
    out = [demand]
    for s in range(1, cfg.n_scenarios):
        rows = []
        for p in range(demand.shape[0]):
            rng = np.random.default_rng(
                (1_000_003 * fam_idx, cfg.seed, s, p)
            )
            rows.append(_perturb(cfg.family, demand[p], rng))
        out.append(np.stack(rows).astype(np.float32))
    return np.stack(out)


def scenario_keys(num_pools: int) -> tuple[dm.PoolKey, ...]:
    """Pool keys for a scenario fleet, cloud-cycled like the artifact."""
    return tuple(
        (_CLOUDS[i % 3], f"region_{i % 4}", f"type_{i:02d}")
        for i in range(num_pools)
    )


def scenario_path(
    family: str,
    *,
    num_pools: int = 3,
    num_weeks: int = 40,
    seed: int = 0,
) -> np.ndarray:
    """One (P, T) demand path of ``family`` at ``seed``, T in whole weeks."""
    if family not in FAMILIES:
        raise ValueError(f"unknown family {family!r}; known: {FAMILIES}")
    num_hours = num_weeks * HOURS_PER_WEEK
    fam_idx = FAMILIES.index(family)
    rows = []
    for p in range(num_pools):
        cfg = _family_config(family, p)
        key = jax.random.PRNGKey(100_000 * fam_idx + 100 * seed + p)
        y = np.asarray(dm.synth_demand(num_hours, cfg, key=key))
        rng = np.random.default_rng((fam_idx, seed, p))
        rows.append(_transform(family, y, rng))
    return np.stack(rows).astype(np.float32)


def scenario_paths(
    family: str,
    *,
    num_pools: int = 3,
    num_weeks: int = 40,
    num_seeds: int = 32,
    base_seed: int = 0,
) -> np.ndarray:
    """(N, P, T) seeded paths of one family — the tournament's unit of
    coverage (N >= 32 by default so ratio/regret tails are populated)."""
    return np.stack([
        scenario_path(
            family, num_pools=num_pools, num_weeks=num_weeks,
            seed=base_seed + s,
        )
        for s in range(num_seeds)
    ])


def scenario_pool_set(
    family: str,
    *,
    num_pools: int = 3,
    num_weeks: int = 40,
    seed: int = 0,
) -> dm.PoolSet:
    """One scenario path wrapped as a :class:`~repro.core.demand.PoolSet`
    so the full planner surface (``plan_fleet_pools``) runs on it."""
    demand = scenario_path(
        family, num_pools=num_pools, num_weeks=num_weeks, seed=seed
    )
    return dm.PoolSet(
        keys=scenario_keys(num_pools),
        demand=demand,
        configs={
            k: _family_config(family, i)
            for i, k in enumerate(scenario_keys(num_pools))
        },
    )
