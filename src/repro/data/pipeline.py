"""Deterministic synthetic LM data pipeline with restart/skip-ahead support.

Production properties kept: per-(shard, step) deterministic batches (restart
reproduces the exact stream), host-sharded iteration for DP, background
prefetch, and state small enough to live in the checkpoint metadata.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    num_shards: int = 1       # data-parallel host shards
    shard_id: int = 0
    seed: int = 0


class TokenPipeline:
    """Synthetic corpus: Zipf-distributed tokens with short-range structure
    (next-token correlation) so cross-entropy actually decreases."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        assert cfg.global_batch % cfg.num_shards == 0
        self.cfg = cfg
        self.step = start_step

    def state(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed}

    @classmethod
    def from_state(cls, cfg: DataConfig, state: dict) -> "TokenPipeline":
        return cls(
            dataclasses.replace(cfg, seed=state["seed"]),
            start_step=state["step"],
        )

    def _batch_for(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        local = cfg.global_batch // cfg.num_shards
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 65_537 + cfg.shard_id
        )
        # Zipf marginal + markov-ish structure: token_t depends on t-1.
        base = rng.zipf(1.3, size=(local, cfg.seq_len + 1)).astype(np.int64)
        base = np.minimum(base - 1, cfg.vocab_size - 1)
        mixed = np.where(
            rng.uniform(size=base.shape) < 0.5,
            base,
            np.roll(base, 1, axis=1) * 7 % cfg.vocab_size,
        ).astype(np.int32)
        return {"tokens": mixed[:, :-1], "labels": mixed[:, 1:]}

    def next_batch(self) -> dict[str, np.ndarray]:
        batch = self._batch_for(self.step)
        self.step += 1
        return batch

    def skip_to(self, step: int):
        """Restart support: jump the stream to an arbitrary step."""
        self.step = step

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()


class PrefetchingLoader:
    """Background-thread prefetch (depth-bounded) around any pipeline."""

    def __init__(self, pipeline: TokenPipeline, depth: int = 2):
        self.pipeline = pipeline
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        while not self._stop.is_set():
            batch = self.pipeline.next_batch()
            while not self._stop.is_set():
                try:
                    self.q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def next_batch(self) -> dict[str, np.ndarray]:
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
