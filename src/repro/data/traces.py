"""Loader for the released Shaved Ice dataset schema (paper §6) + calibrated
synthetic fallback.

The Zenodo/GitHub artifact (Snowflake-Labs/shavedice-dataset) publishes
normalized hourly VM demand as CSV with columns
``timestamp, cloud, region, machine_type, normalized_count``.  Offline we
synthesize traces matching every published statistic of the dataset
(DESIGN.md §9); when the artifact is present on disk the loader reads it
directly, so all benchmarks/examples run identically against real data.

Two API levels:

  * dict level — ``load_dataset_csv`` / ``synthetic_pools`` return
    ``{(cloud, region, machine_type): hourly ndarray}``;
  * :class:`repro.core.demand.PoolSet` level — ``synthetic_pool_set`` /
    ``load_pool_set`` return the aligned (P, T) matrix the batched planner
    (``planner.plan_fleet_pools``) and the Pallas 2-D sweep consume.
"""

from __future__ import annotations

import csv
import os
from collections import defaultdict
from datetime import datetime

import jax
import numpy as np

from repro.capacity import generations as gn
from repro.core import demand as dm

DATASET_ENV = "SHAVEDICE_DATASET"


def _time_index(timestamps: set[str]) -> tuple[dict[str, int], int]:
    """(timestamp -> row index, grid length) for the alignment grid.

    ISO-8601 timestamps get a *contiguous* hourly grid from the earliest
    to the latest observed stamp, so hours missing from every pool at once
    (a global recording outage) still occupy a slot instead of silently
    compressing the time axis — downstream code does hour arithmetic
    (weekly horizon slicing, Fourier phases) on array indices.  RARE
    sub-hourly stamps snap to their nearest hour slot (a single glitchy
    half-hour row — the typical companion of duplicate rows — must not
    poison the whole dataset's grid; snapped collisions are summed by the
    loader, the same semantics as duplicate rows).  Unparseable stamps —
    or a systematically sub-hourly cadence, where snap-and-sum would
    inflate every pool's demand — fall back to the sorted union of
    observed stamps."""
    if not timestamps:
        raise ValueError(
            "dataset has no rows: an empty CSV defines no timestamp grid"
        )
    try:
        parsed = {ts: datetime.fromisoformat(ts) for ts in timestamps}
        # Anchor the grid on the earliest stamp's WHOLE hour: if the
        # earliest observation is itself a sub-hourly glitch, anchoring on
        # it verbatim would shift every whole-hour stamp to a half-open
        # offset and the rounding would merge distinct hours.
        lo = min(parsed.values()).replace(minute=0, second=0, microsecond=0)
        offsets = {
            ts: (dt - lo).total_seconds() / 3600.0
            for ts, dt in parsed.items()
        }
    except (ValueError, TypeError):      # non-ISO stamps / mixed tz-ness
        grid = sorted(timestamps)
        return {ts: i for i, ts in enumerate(grid)}, len(grid)
    off_hour = sum(
        1 for o in offsets.values() if abs(o - round(o)) > 1e-9
    )
    if off_hour > max(1, len(offsets) // 20):
        # SYSTEMATICALLY sub-hourly (e.g. a 30-minute-cadence export, not
        # one glitchy row): snapping would sum several samples into every
        # hour slot and silently inflate demand — keep each sample in its
        # own slot on the sorted-union grid instead.
        grid = sorted(timestamps)
        return {ts: i for i, ts in enumerate(grid)}, len(grid)
    index = {ts: int(round(o)) for ts, o in offsets.items()}
    return index, max(index.values()) + 1


def load_dataset_csv(path: str) -> dict[tuple[str, str, str], np.ndarray]:
    """Returns {(cloud, region, machine_type): hourly ndarray}, aligned.

    Alignment rule: real pools come and go (a machine family launches
    mid-dataset, a region is retired), so per-pool row sets are ragged.
    All series are placed on one shared grid — the contiguous hourly range
    spanning the earliest to latest observed timestamp (see
    ``_time_index``) — and a pool contributes its ``normalized_count`` at
    the stamps it has rows for and **0.0 demand** at grid hours it is
    missing: absence of a row means the pool had no recorded demand that
    hour, not unknown demand.  Duplicate (timestamp, pool) rows are
    summed, as are distinct stamps that snap to the same hour slot, so a
    pool made entirely of duplicate rows or a single-row pool still lands
    correctly on the union grid (the degenerate shapes that used to
    produce broken grids).  Every returned array therefore has the same
    length and the mapping stacks directly into a (P, T) matrix
    (``PoolSet.from_dict``); an empty CSV raises instead of returning an
    un-stackable empty mapping.
    """
    series: dict[tuple[str, str, str], dict[str, float]] = defaultdict(
        lambda: defaultdict(float)
    )
    timestamps: set[str] = set()
    with open(path) as f:
        for row in csv.DictReader(f):
            key = (row["cloud"], row["region"], row["machine_type"])
            ts = row["timestamp"]
            series[key][ts] += float(row["normalized_count"])
            timestamps.add(ts)
    index, n = _time_index(timestamps)
    out = {}
    for key, by_ts in series.items():
        arr = np.zeros(n, np.float32)
        for ts, v in by_ts.items():
            arr[index[ts]] += v       # += : snapped stamps may share a slot
        out[key] = arr
    return out


def _pool_configs(num_pools: int) -> dict[tuple[str, str, str], dm.DemandConfig]:
    """Per-pool synthetic configs keyed like the artifact (12 machine types
    across 3 clouds / 4 regions), varying scale, growth, and seasonality the
    way the paper's §2 per-pool statistics do.  Clouds are the paper's real
    three so pool keys line up with the Table-2 purchase options."""
    clouds = ["aws", "azure", "gcp"]
    out = {}
    for i in range(num_pools):
        key = (clouds[i % 3], f"region_{i % 4}", f"type_{i:02d}")
        out[key] = dm.DemandConfig(
            base_level=40.0 * (1.5 ** (i % 4)),
            annual_growth=0.35 + 0.1 * (i % 5),
            diurnal_amplitude=0.10 + 0.02 * (i % 3),
            weekly_amplitude=0.12 + 0.02 * (i % 4),
        )
    return out


def synthetic_pools(
    num_pools: int = 12, num_hours: int = 24 * 365 * 3, seed: int = 0
) -> dict[tuple[str, str, str], np.ndarray]:
    """12 machine types x synthetic 3-year traces, mirroring the artifact's
    shape (12 types, 4 regions collapsed per-pool) and the paper's §2
    statistics."""
    cfgs = _pool_configs(num_pools)
    return {
        key: np.asarray(
            dm.synth_demand(num_hours, cfg, key=jax.random.PRNGKey(seed + i))
        )
        for i, (key, cfg) in enumerate(cfgs.items())
    }


def _turnover_pool_configs(
    num_pools: int, cfg: gn.MigrationConfig
) -> dict[tuple[str, str, str], dm.DemandConfig]:
    """Per-pool configs for a fleet undergoing generation turnover: pools
    come in (old family, successor family) pairs keyed by the successor
    table, replicated across regions until ``num_pools`` is reached.  The
    old-family pool carries the pair's base demand; the successor starts
    empty and receives volume only through migration — exactly the shape
    the paper's §2.3 dataset shows around a family launch."""
    gens = list(cfg.generations)
    if not gens:
        raise ValueError("migration config has no generations to plant")
    if num_pools < 2 or num_pools % 2:
        raise ValueError(
            "a turnover fleet is built from (old family, successor) pool "
            f"pairs; num_pools must be even and >= 2, got {num_pools}"
        )
    out: dict[tuple[str, str, str], dm.DemandConfig] = {}
    num_pairs = num_pools // 2
    for i in range(num_pairs):
        g = gens[i % len(gens)]
        region = f"region_{i // len(gens)}"
        out[(g.cloud, region, g.old_family)] = dm.DemandConfig(
            base_level=60.0 * (1.5 ** (i % 3)),
            annual_growth=0.35 + 0.1 * (i % 4),
            diurnal_amplitude=0.10 + 0.02 * (i % 3),
            weekly_amplitude=0.12 + 0.02 * (i % 4),
        )
        out[(g.cloud, region, g.new_family)] = dm.DemandConfig(
            base_level=0.0
        )
    return out


def synthetic_base_pool_set(
    num_pools: int = 12,
    num_hours: int = 24 * 365 * 3,
    seed: int = 0,
    migration: "gn.MigrationConfig | bool | None" = True,
) -> dm.PoolSet:
    """The *pre-turnover* fleet a migration scenario starts from: demand is
    attributed to the old-family pools, successor pools exist but are empty.
    Kept public so tests can plant a known base, run
    ``generations.migrate_pool_set`` themselves, and hand the base's
    aggregate to ``migration.decompose_drivers`` as the user-volume series.
    """
    cfg = gn.resolve_migration(migration)
    if cfg is None:
        # Unlike synthetic_pool_set, there IS no non-turnover base fleet:
        # silently substituting the default table would make False mean
        # the opposite of what it means one function up.
        raise ValueError(
            "synthetic_base_pool_set builds a turnover fleet; pass "
            "migration=True or a MigrationConfig (use synthetic_pool_set "
            "for the legacy fleet)"
        )
    cfgs = _turnover_pool_configs(num_pools, cfg)
    pools = {
        key: np.asarray(
            dm.synth_demand(num_hours, c, key=jax.random.PRNGKey(seed + i))
        ) if c.base_level > 0 else np.zeros(num_hours, np.float32)
        for i, (key, c) in enumerate(cfgs.items())
    }
    return dm.PoolSet.from_dict(pools, configs=cfgs)


def synthetic_pool_set(
    num_pools: int = 12,
    num_hours: int = 24 * 365 * 3,
    seed: int = 0,
    migration: "gn.MigrationConfig | bool | None" = None,
) -> dm.PoolSet:
    """The synthetic fleet as an aligned :class:`PoolSet` (keys sorted),
    carrying each pool's generating ``DemandConfig``.

    ``migration`` switches the fleet to the hardware-turnover scenario:
    pools are keyed by the successor table's (old family, new family)
    pairs, base demand lands on the old families, and
    ``capacity.generations`` transfers volume to the successors along the
    planted logistic S-curves while the software-efficiency deflator acts
    on every pool.  ``migration=None`` (default) keeps the legacy fleet
    bit-identical."""
    mig = gn.resolve_migration(migration)
    if mig is not None:
        base = synthetic_base_pool_set(num_pools, num_hours, seed, mig)
        return gn.migrate_pool_set(base, mig)
    return dm.PoolSet.from_dict(
        synthetic_pools(num_pools, num_hours, seed),
        configs=_pool_configs(num_pools),
    )


def load_pools(**synth_kw) -> dict[tuple[str, str, str], np.ndarray]:
    """Artifact if available (env SHAVEDICE_DATASET=path/to/csv), else the
    calibrated synthetic pools."""
    path = os.environ.get(DATASET_ENV, "")
    if path and os.path.exists(path):
        return load_dataset_csv(path)
    return synthetic_pools(**synth_kw)


def load_pool_set(**synth_kw) -> dm.PoolSet:
    """PoolSet from the artifact when present, else the synthetic fleet.

    Dataset pools are aligned by ``load_dataset_csv`` (union timestamp
    grid), so stacking never fails on ragged sources."""
    path = os.environ.get(DATASET_ENV, "")
    if path and os.path.exists(path):
        return dm.PoolSet.from_dict(load_dataset_csv(path))
    return synthetic_pool_set(**synth_kw)
