"""Loader for the released Shaved Ice dataset schema (paper §6) + calibrated
synthetic fallback.

The Zenodo/GitHub artifact (Snowflake-Labs/shavedice-dataset) publishes
normalized hourly VM demand as CSV with columns
``timestamp, cloud, region, machine_type, normalized_count``.  Offline we
synthesize traces matching every published statistic of the dataset
(DESIGN.md §9); when the artifact is present on disk the loader reads it
directly, so all benchmarks/examples run identically against real data.
"""

from __future__ import annotations

import csv
import os
from collections import defaultdict

import jax
import numpy as np

from repro.core import demand as dm

DATASET_ENV = "SHAVEDICE_DATASET"


def load_dataset_csv(path: str) -> dict[tuple[str, str, str], np.ndarray]:
    """Returns {(cloud, region, machine_type): hourly ndarray}."""
    series: dict[tuple[str, str, str], list[tuple[str, float]]] = defaultdict(list)
    with open(path) as f:
        for row in csv.DictReader(f):
            key = (row["cloud"], row["region"], row["machine_type"])
            series[key].append(
                (row["timestamp"], float(row["normalized_count"]))
            )
    out = {}
    for key, rows in series.items():
        rows.sort()
        out[key] = np.asarray([v for _, v in rows], np.float32)
    return out


def synthetic_pools(
    num_pools: int = 12, num_hours: int = 24 * 365 * 3, seed: int = 0
) -> dict[tuple[str, str, str], np.ndarray]:
    """12 machine types x synthetic 3-year traces, mirroring the artifact's
    shape (12 types, 4 regions collapsed per-pool) and the paper's §2
    statistics."""
    clouds = ["cloud_a", "cloud_b", "cloud_c"]
    out = {}
    for i in range(num_pools):
        cfg = dm.DemandConfig(
            base_level=40.0 * (1.5 ** (i % 4)),
            annual_growth=0.35 + 0.1 * (i % 5),
            diurnal_amplitude=0.10 + 0.02 * (i % 3),
            weekly_amplitude=0.12 + 0.02 * (i % 4),
        )
        key = (clouds[i % 3], f"region_{i % 4}", f"type_{i:02d}")
        out[key] = np.asarray(
            dm.synth_demand(num_hours, cfg, key=jax.random.PRNGKey(seed + i))
        )
    return out


def load_pools(**synth_kw) -> dict[tuple[str, str, str], np.ndarray]:
    """Artifact if available (env SHAVEDICE_DATASET=path/to/csv), else the
    calibrated synthetic pools."""
    path = os.environ.get(DATASET_ENV, "")
    if path and os.path.exists(path):
        return load_dataset_csv(path)
    return synthetic_pools(**synth_kw)
