"""HLO-derived roofline inputs: collective-byte parsing and the three-term
roofline model for TPU v5e.

Hardware constants (assignment): 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.

Collective bytes are NOT in compiled.cost_analysis(); we parse the optimized
HLO text and sum per-op wire-byte estimates over every collective op.  Shapes
in post-SPMD HLO are per-device shard shapes, so the result is bytes per
device — matching cost_analysis()'s per-device FLOPs/bytes convention.

Wire-byte conventions (ring algorithms, per device):
  all-gather          -> output bytes  (receives the full gathered tensor)
  all-reduce          -> 2 x input     (reduce-scatter + all-gather phases)
  reduce-scatter      -> input bytes
  all-to-all          -> input bytes
  collective-permute  -> input bytes
"""

from __future__ import annotations

import dataclasses
import re

import jax

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z]\d*|pred|token|bf16|f16|f32|f64)\[([\d,]*)\]")
_COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
# e.g.:  %ar = f32[4,8]{1,0} all-reduce(f32[4,8]{1,0} %x), ...
_OP_RE = re.compile(
    r"=\s*(\(?[a-z0-9\[\],{}\s/#_\-\.]*?\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        nb = _DTYPE_BYTES.get(dt)
        if nb is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * nb
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict[str, int]
    count_by_kind: dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    bytes_by: dict[str, int] = {k: 0 for k in _COLLECTIVE_KINDS}
    count_by: dict[str, int] = {k: 0 for k in _COLLECTIVE_KINDS}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group(2)
        if kind + "-done(" in line:
            continue  # count async pairs once (at -start)
        result_part = m.group(1)
        operand_part = line[m.end() - 1:]
        # strip metadata/attrs after the operand list's closing paren
        depth = 0
        for i, ch in enumerate(operand_part):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    operand_part = operand_part[: i + 1]
                    break
        in_bytes = _shape_bytes(operand_part)
        out_bytes = _shape_bytes(result_part)
        # Optimized HLO sometimes prints operands as bare names (no inline
        # types); fall back to the result shape (exact for all-reduce /
        # all-to-all / collective-permute, conservative for reduce-scatter).
        if in_bytes == 0:
            in_bytes = out_bytes
        if kind == "all-gather":
            wire = out_bytes
        elif kind == "all-reduce":
            wire = 2 * in_bytes
        else:
            wire = in_bytes
        bytes_by[kind] += wire
        count_by[kind] += 1
    return CollectiveStats(bytes_by, count_by)


@dataclasses.dataclass
class Roofline:
    flops: float               # per device
    hbm_bytes: float           # per device (TPU-projected analytic model)
    hbm_bytes_hlo: float       # per device, raw cost_analysis (CPU-inflated)
    collective_bytes: float    # per device
    compute_s: float
    memory_s: float            # from the projected bytes
    memory_s_hlo: float        # from raw HLO bytes (reported, not used for
    collective_s: float        # dominance — see DESIGN §dry-run caveats)
    dominant: str
    model_flops: float         # analytic 6ND / 2ND per device
    useful_ratio: float        # model_flops / hlo_flops

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def roofline_terms(
    flops: float,
    hbm_bytes: float,
    collective_bytes: float,
    model_flops: float,
    hbm_bytes_hlo: float | None = None,
) -> Roofline:
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm_bytes / HBM_BW
    collective_s = collective_bytes / ICI_BW
    terms = {
        "compute": compute_s, "memory": memory_s, "collective": collective_s,
    }
    dominant = max(terms, key=terms.get)  # type: ignore[arg-type]
    return Roofline(
        flops=flops,
        hbm_bytes=hbm_bytes,
        hbm_bytes_hlo=float(hbm_bytes_hlo or hbm_bytes),
        collective_bytes=collective_bytes,
        compute_s=compute_s,
        memory_s=memory_s,
        memory_s_hlo=float(hbm_bytes_hlo or hbm_bytes) / HBM_BW,
        collective_s=collective_s,
        dominant=dominant,
        model_flops=model_flops,
        useful_ratio=model_flops / max(flops, 1.0),
    )


# ---------------------------------------------------------------------------
# Analytic per-device HBM traffic (the TPU memory roofline term)
# ---------------------------------------------------------------------------

def _local_bytes(specs, mesh, rules, default_dtype_bytes=2) -> float:
    """Exact per-device resident bytes of a Spec tree under the (sanitized)
    sharding rules."""
    import numpy as np

    from repro.models.params import (
        Spec,
        sanitize_partition_spec,
        tree_specs_map,
    )

    total = 0.0

    def add(spec: Spec):
        nonlocal total
        pspec = sanitize_partition_spec(spec, rules, mesh)
        shards = 1
        for part in pspec:
            if part is None:
                continue
            for ax in (part if isinstance(part, tuple) else (part,)):
                shards *= mesh.shape[ax]
        nbytes = (
            np.dtype(spec.dtype).itemsize if spec.dtype is not None
            else default_dtype_bytes
        )
        total += float(np.prod(spec.shape)) * nbytes / shards
        return spec

    tree_specs_map(add, specs)
    return total


def analytic_hbm_bytes(cell, mesh, rules) -> float:
    """TPU-projected HBM bytes per device per step.

    CPU-backend 'bytes accessed' counts every unfused elementwise buffer and
    the f32-widened loop state, inflating the memory term ~10x vs a TPU
    compile (measured; DESIGN §dry-run caveats).  This model counts what a
    fused TPU execution actually moves:
      train:   3x params (fwd + bwd + remat-recompute reads) + 1x param
               write + opt state r/w (24B/param) + grads (8B/param)
               + activation IO (~14 bf16 tensor r/w per layer) + logits x3
               + MoE buffer r/w
      prefill: 1x params + activations + KV-cache write + KV re-read per
               query chunk + logits
      decode:  1x params + full KV-cache read + O(1) activations
    """
    import numpy as np

    cfg = cell.cfg
    shape_cell = cell.cell
    n_model = mesh.shape.get("model", 1)
    batch_axes = rules.get("batch") or ()
    if not isinstance(batch_axes, tuple):
        batch_axes = (batch_axes,)
    n_batch = int(np.prod([mesh.shape[a] for a in batch_axes])) or 1

    params_loc = _local_bytes(cell.model.param_specs, mesh, rules)
    n_params_loc = params_loc / 2  # bf16 resident copy

    b_loc = max(shape_cell.global_batch // n_batch, 1)
    s = shape_cell.seq_len
    d = cfg.d_model
    l_layers = cfg.num_layers + cfg.encoder_layers
    v_loc = cfg.vocab_size / n_model

    if shape_cell.kind == "train":
        param_io = 4 * params_loc + 32 * n_params_loc
        act_io = 14 * l_layers * b_loc * s * d * 2
        logits_io = 3 * b_loc * s * v_loc * 4
        moe_io = 0.0
        if cfg.num_experts:
            n_tokens = shape_cell.global_batch * s
            cap = cfg.top_k * n_tokens / cfg.num_experts \
                * cfg.moe_capacity_factor
            moe_layers = sum(
                cfg.is_moe_layer(i) for i in range(cfg.num_layers)
            )
            moe_io = moe_layers * 6 * (cfg.num_experts / n_model) * cap \
                * d * 2
        return param_io + act_io + logits_io + moe_io

    cache_specs = cell.model.cache_specs(shape_cell.global_batch, s)
    cache_loc = _local_bytes(cache_specs, mesh, rules)

    if shape_cell.kind == "prefill":
        param_io = params_loc
        act_io = 8 * l_layers * b_loc * s * d * 2
        chunks = max(s // 2048, 1)
        kv_reread = (chunks - 1) * cache_loc  # flash streams KV per q chunk
        logits_io = b_loc * v_loc * 4  # next-token logits only
        return param_io + act_io + cache_loc + kv_reread + logits_io

    # decode: params once + read the whole (sharded) cache + tiny writes
    act_io = 8 * l_layers * b_loc * 1 * d * 2
    logits_io = b_loc * v_loc * 4
    return params_loc + cache_loc + act_io + logits_io


# ---------------------------------------------------------------------------
# Analytic MODEL_FLOPS (assignment formula: 6*N*D train, 2*N*D inference,
# N = active non-embedding params)
# ---------------------------------------------------------------------------

def active_params(cfg, model) -> float:
    """Active parameter count: total minus embedding/lm_head minus the
    non-routed fraction of MoE experts."""
    import numpy as np

    from repro.models.params import Spec

    total = 0.0
    leaves_with_path = jax.tree_util.tree_flatten_with_path(
        model.param_specs, is_leaf=lambda x: isinstance(x, Spec)
    )[0]
    for path, spec in leaves_with_path:
        n = float(np.prod(spec.shape))
        keys = [getattr(p, "key", str(p)) for p in path]
        name = "/".join(str(k) for k in keys)
        if "embed" in name.split("/")[-1] or name.endswith("lm_head") \
                or "_pos" in name:
            continue
        if "experts" in spec.axes:
            e_axis = spec.axes.index("experts")
            if spec.shape[e_axis] == cfg.num_experts:
                n *= cfg.top_k / cfg.num_experts
        total += n
    return total


def analytic_temp_bytes(cfg, cell, n_data_shards: int, n_model_shards: int,
                        microbatches: int = 1) -> float:
    """TPU-projected transient memory per device.

    The CPU backend's ``memory_analysis().temp_size_in_bytes`` overstates
    TPU reality in two documented ways (DESIGN.md §dry-run): (a) the CPU
    pipeline widens bf16 while-loop state to f32 (the remat residual stack
    doubles), and (b) CPU does not fuse elementwise chains, so every softmax
    intermediate is a buffer.  This analytic model reproduces what a TPU
    compile holds live:
      * remat residual stack: one (B_loc, S, d) bf16 per scan unit,
      * logits + CE backward buffer (B_loc, S, V_loc) f32 x2,
      * transient layer working set: ~6 activation-sized f32 buffers plus
        one attention score chunk (B_loc, H_loc, chunk, S) f32.
    """
    b_loc = max(cell.global_batch // n_data_shards // microbatches, 1)
    s = cell.seq_len if cell.kind != "decode" else 1
    d = cfg.d_model
    scan_units = cfg.num_layers
    if cfg.family == "hybrid" and cfg.attn_layer_period:
        scan_units = cfg.num_layers // cfg.attn_layer_period
    resid = scan_units * b_loc * s * d * 2 if cell.kind == "train" else 0
    v_loc = cfg.vocab_size / n_model_shards
    s_logits = s if cell.kind == "train" else 1  # prefill: last token only
    logits = 2 * b_loc * s_logits * v_loc * 4
    h_loc = max(cfg.num_heads // n_model_shards, 1)
    chunk = min(s, 1024 if cell.kind == "train" else 2048)
    kv_span = cell.seq_len
    scores = b_loc * h_loc * chunk * kv_span * 4 if cfg.family != "ssm" else 0
    ff_loc = max(cfg.d_ff, cfg.moe_d_ff or 0, cfg.ssm_d_inner
                 if cfg.family in ("hybrid",) else 0) / n_model_shards
    working = 6 * b_loc * s * d * 4 + 2 * b_loc * s * ff_loc * 4
    return float(resid + logits + scores + working)


def inner_recurrence_flops(cfg, cell) -> float:
    """GLOBAL FLOPs hidden from HLO cost analysis by the per-layer chunk
    scans (Mamba/RWKV recurrences run as lax.scan over chunks; the body is
    counted once, so (nchunks-1)/nchunks of the recurrence is unmeasured).
    Closed-form estimate, <5% of the layer total (projections dominate);
    added to the measured FLOPs for the roofline."""
    import math

    from repro.models.scan_utils import pick_chunk

    if cell.kind == "decode":
        return 0.0  # single-step path has no chunk scan
    s = cell.seq_len
    tokens = cell.global_batch * s
    mult = 3.0 if cell.kind == "train" else 1.0  # bwd + remat recompute
    total = 0.0
    if cfg.family == "hybrid":
        chunk = pick_chunk(s, target_iters=16, max_chunk=2048)
        nchunks = max(s // chunk, 1)
        n_mamba = sum(
            1 for i in range(cfg.num_layers) if not cfg.is_attn_layer(i)
        )
        # da/bx build (~6) + associative scan (~6 log2 L) + y einsum (~2)
        per_tok = cfg.ssm_d_inner * cfg.ssm_d_state * (
            8 + 6 * math.log2(max(chunk, 2))
        )
        total += n_mamba * tokens * per_tok * mult * (1 - 1 / nchunks)
    if cfg.family == "ssm":
        chunk = pick_chunk(s, target_iters=32, max_chunk=256)
        nchunks = max(s // chunk, 1)
        hs = cfg.rwkv_head_size
        # intra-chunk attention (~7 L d: decay build + 3-tensor einsum + PV)
        # + state propagation (~6 d hs)
        per_tok = 7 * chunk * cfg.d_model + 6 * cfg.d_model * hs
        total += cfg.num_layers * tokens * per_tok * mult * (1 - 1 / nchunks)
    return total


def model_flops_for(cfg, model, cell) -> float:
    """Per-DEVICE-step analytic model FLOPs (divide by chips at call site)."""
    n_active = active_params(cfg, model)
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_active * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * cell.global_batch

