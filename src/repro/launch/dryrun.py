import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell, prove it fits, and extract the roofline inputs.

The two lines above MUST stay the first statements in this module — jax
locks the device count on first backend initialization, and the production
meshes need 512 placeholder host devices.  Nothing else in the repo sets
this flag (smoke tests and benchmarks see 1 device).

Per cell this driver lowers/compiles THREE modules:
  * the full model — memory_analysis (fits-per-device proof) + compile proof
    + the optimized collective schedule;
  * an L1 (one scan unit) and L2 (two scan units) variant — XLA's HLO cost
    analysis counts `while` bodies once regardless of trip count (calibrated
    in tests/test_dryrun_unit.py), so exact totals come from the affine
    extrapolation  total = C(L1) + (repeat-1) * (C(L2) - C(L1)),
    applied identically to FLOPs, HBM bytes, and parsed collective bytes.

Results are cached as JSON per cell under --out so reruns are incremental.

Usage:
  python -m repro.launch.dryrun --arch stablelm-1.6b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]
"""  # noqa: E402

import argparse     # noqa: E402
import contextlib   # noqa: E402
import json         # noqa: E402
import traceback    # noqa: E402

import jax          # noqa: E402

from repro.launch import hlo_analysis as ha            # noqa: E402
from repro.launch.cells import (                        # noqa: E402
    all_cells,
    delta_configs,
    make_cell,
)
from repro.launch.mesh import make_production_mesh      # noqa: E402
from repro.obs.spans import SpanRecorder                # noqa: E402

MEM_BUDGET_BYTES = 16 * 1024**3  # v5e HBM per chip


def _compile_cell(cell, mesh):
    kw = {"in_shardings": cell.in_shardings}
    if cell.out_shardings is not None:
        kw["out_shardings"] = cell.out_shardings
    with mesh:
        lowered = jax.jit(cell.step_fn, **kw).lower(*cell.abstract_args)
        compiled = lowered.compile()
    return lowered, compiled


def _cost(compiled):
    ca = compiled.cost_analysis()
    return float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0))


def run_cell(
    arch: str,
    shape: str,
    *,
    multi_pod: bool = False,
    rules_override=None,
    cfg_transform=None,
    verbose: bool = True,
) -> dict:
    """Compile + analyze one cell; returns the result record.

    ``cfg_transform`` (ModelConfig -> ModelConfig) is applied to the cell's
    config AND its L1/L2 delta variants — the §Perf hillclimbs use it to
    inject knobs (kv_cache_dtype, moe_capacity_factor, remat_policy...).
    """
    mesh = make_production_mesh(multi_pod=multi_pod)
    nchips = mesh.size
    # Clock reads live in repro.obs.spans (rule R7); the cell's whole
    # lower+compile+analyze pass is one "compile" span, closed just before
    # the record is assembled so compile_seconds covers exactly what the
    # old inline timer did.
    rec = SpanRecorder()
    timer = contextlib.ExitStack()
    sp = timer.enter_context(
        rec.span(f"dryrun/{arch}/{shape}", phase="compile")
    )

    from repro import configs as _configs

    base_cfg = _configs.get(arch)
    if cfg_transform is not None:
        base_cfg = cfg_transform(base_cfg)

    cell = make_cell(arch, shape, mesh, cfg_override=base_cfg,
                     rules_override=rules_override)
    lowered, compiled = _compile_cell(cell, mesh)
    mem = compiled.memory_analysis()
    full_flops, full_bytes = _cost(compiled)
    full_coll = ha.parse_collectives(compiled.as_text())

    # Delta-method exact totals (scan bodies counted once otherwise).
    cfg1, cfg2, repeat = delta_configs(cell.cfg)
    c1 = make_cell(arch, shape, mesh, cfg_override=cfg1,
                   rules_override=rules_override)
    c2 = make_cell(arch, shape, mesh, cfg_override=cfg2,
                   rules_override=rules_override)
    _, comp1 = _compile_cell(c1, mesh)
    _, comp2 = _compile_cell(c2, mesh)
    f1, b1 = _cost(comp1)
    f2, b2 = _cost(comp2)
    k1 = ha.parse_collectives(comp1.as_text()).total_bytes
    k2 = ha.parse_collectives(comp2.as_text()).total_bytes

    flops = f1 + (repeat - 1) * (f2 - f1)
    flops += ha.inner_recurrence_flops(cell.cfg, cell.cell) / nchips
    hbm_bytes = b1 + (repeat - 1) * (b2 - b1)
    coll_bytes = k1 + (repeat - 1) * (k2 - k1)

    model_flops_global = ha.model_flops_for(cell.cfg, cell.model, cell.cell)
    from repro.launch.cells import resolve_rules
    from repro.sharding.rules import RULESETS
    rules = rules_override or RULESETS[cell.cell.kind]
    rules = resolve_rules(dict(rules), mesh, cell.cell.global_batch)
    hbm_projected = ha.analytic_hbm_bytes(cell, mesh, rules)
    roof = ha.roofline_terms(
        flops, hbm_projected, coll_bytes, model_flops_global / nchips,
        hbm_bytes_hlo=hbm_bytes,
    )

    per_dev_bytes = (
        mem.argument_size_in_bytes + mem.temp_size_in_bytes
        + mem.output_size_in_bytes
    )
    # TPU-projected memory: the CPU backend widens bf16 loop state to f32
    # and never fuses, inflating temp (DESIGN.md §dry-run caveats); args and
    # outputs are exact (sharded) either way.
    from repro.launch.cells import default_microbatches
    n_model = mesh.shape.get("model", 1)
    n_data = nchips // n_model
    micro = default_microbatches(cell.cfg, cell.cell, mesh)
    tpu_temp = ha.analytic_temp_bytes(
        cell.cfg, cell.cell, n_data, n_model, micro
    )
    tpu_projected = (
        mem.argument_size_in_bytes + mem.output_size_in_bytes + tpu_temp
    )
    timer.close()
    record = {
        "arch": arch,
        "shape": shape,
        "mesh": list(mesh.devices.shape),
        "mesh_axes": list(mesh.axis_names),
        "chips": nchips,
        "compile_ok": True,
        "compile_seconds": round(sp.duration_s, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "temp_bytes_cpu_backend": mem.temp_size_in_bytes,
            "temp_bytes_tpu_projected": tpu_temp,
            "output_bytes": mem.output_size_in_bytes,
            "total_per_device_cpu": per_dev_bytes,
            "total_per_device_tpu_projected": tpu_projected,
            "fits_16gb": bool(tpu_projected < MEM_BUDGET_BYTES),
        },
        "cost_full_module": {
            "flops": full_flops, "bytes": full_bytes,
            "collective_bytes": full_coll.total_bytes,
            "collective_counts": full_coll.count_by_kind,
        },
        "delta": {
            "repeat": repeat, "l1_flops": f1, "l2_flops": f2,
            "l1_coll": k1, "l2_coll": k2,
        },
        "roofline": roof.as_dict(),
        "microbatches": micro,
        "params_total": cell.model.num_params(),
        "params_active": ha.active_params(cell.cfg, cell.model),
    }
    if verbose:
        m = record["memory"]
        r = record["roofline"]
        print(
            f"[{arch} x {shape} x {'multi' if multi_pod else 'single'}-pod] "
            f"compile {record['compile_seconds']}s | "
            f"mem/dev {tpu_projected/1e9:.2f} GB tpu-proj "
            f"({per_dev_bytes/1e9:.1f} cpu) "
            f"(fits={m['fits_16gb']}) | "
            f"compute {r['compute_s']*1e3:.2f} ms, "
            f"memory {r['memory_s']*1e3:.2f} ms, "
            f"collective {r['collective_s']*1e3:.2f} ms "
            f"-> {r['dominant']}-bound | useful {r['useful_ratio']:.2f}",
            flush=True,
        )
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = all_cells() if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for multi_pod in meshes:
        for arch, shape in cells:
            tag = f"{arch}__{shape}__{'multi' if multi_pod else 'single'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path) and not args.force:
                print(f"[cached] {tag}", flush=True)
                continue
            try:
                rec = run_cell(arch, shape, multi_pod=multi_pod)
            except Exception as e:  # noqa: BLE001
                traceback.print_exc()
                failures.append((tag, str(e)))
                rec = {
                    "arch": arch, "shape": shape, "compile_ok": False,
                    "multi_pod": multi_pod, "error": str(e),
                }
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for tag, err in failures:
            print(f"  {tag}: {err[:200]}")
        raise SystemExit(1)
    print("\nAll dry-run cells compiled successfully.")


if __name__ == "__main__":
    main()
