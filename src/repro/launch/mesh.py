"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (jax locks the device count on first backend init, and the
dry-run must set XLA_FLAGS before that happens).
"""

from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """Single-pod: 256 chips as ("data", "model") = (16, 16).
    Multi-pod: 2 pods x 256 chips as ("pod", "data", "model") = (2, 16, 16).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(
        shape, axes, axis_types=compat.auto_axis_types(len(axes))
    )


def make_host_mesh():
    """Whatever devices exist locally, as a 1-D "data" mesh (smoke tests,
    examples).  Kept separate so tests never build the 512-way mesh."""
    n = len(jax.devices())
    return compat.make_mesh(
        (n,), ("data",), axis_types=compat.auto_axis_types(1)
    )


def shard_rows(*arrays):
    """Shard each array's leading axis across the local 1-D "data" mesh.

    The fleet-scale replay flattens (N scenarios x P pools) into one row
    axis and every per-row op is elementwise along it, so placing the rows
    once lets XLA's computation-follows-data propagation shard the whole
    scan.  On a single-device host (or when the row count doesn't divide
    the device count) this is a no-op, so the compiled program — and its
    bit-exact outputs — are unchanged.  Returns the arrays in order (a
    single array when called with one argument)."""
    n = len(jax.devices())
    if n > 1 and all(a.shape[0] % n == 0 for a in arrays):
        mesh = make_host_mesh()
        spec = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec("data")
        )
        arrays = tuple(jax.device_put(a, spec) for a in arrays)
    return arrays[0] if len(arrays) == 1 else arrays
