"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (jax locks the device count on first backend init, and the
dry-run must set XLA_FLAGS before that happens).
"""

from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """Single-pod: 256 chips as ("data", "model") = (16, 16).
    Multi-pod: 2 pods x 256 chips as ("pod", "data", "model") = (2, 16, 16).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(
        shape, axes, axis_types=compat.auto_axis_types(len(axes))
    )


def make_host_mesh():
    """Whatever devices exist locally, as a 1-D "data" mesh (smoke tests,
    examples).  Kept separate so tests never build the 512-way mesh."""
    n = len(jax.devices())
    return compat.make_mesh(
        (n,), ("data",), axis_types=compat.auto_axis_types(1)
    )
