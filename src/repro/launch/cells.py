"""Dry-run cell construction: step functions, abstract inputs, shardings —
shared by launch/dryrun.py, benchmarks/roofline.py and the perf hillclimbs."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs
from repro.models.config import SHAPES, ModelConfig, ShapeCell, cells_for
from repro.models.model import Model, build
from repro.models.params import sharding_tree
from repro.sharding.rules import RULESETS, Rules
from repro.train.step import (
    build_grad_accum_train_step,
    build_prefill_step,
    build_serve_step,
    build_train_step,
)


def default_microbatches(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh) -> int:
    """Gradient-accumulation factor for train cells, sized so the per-layer
    remat-residual stack (L x B_loc x S x d bf16) fits comfortably under the
    16 GB/chip budget alongside params+opt.  Production systems make exactly
    this tradeoff (activation memory vs collective granularity); the roofline
    totals stay exact because the microbatch loop is python-unrolled."""
    if cell.kind != "train":
        return 1
    n_batch = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    b_loc = max(cell.global_batch // n_batch, 1)
    resid = cfg.num_layers * b_loc * cell.seq_len * cfg.d_model * 2
    budget = 8 * 1024**3  # headroom for params/opt/transients
    micro = 1
    while resid / micro > budget and micro < b_loc:
        micro *= 2
    return micro


def resolve_rules(rules: Rules, mesh: Mesh, global_batch: int) -> Rules:
    """Adapt a ruleset to a concrete mesh: drop mesh axes that don't exist
    (single-pod has no "pod"), and shrink the batch axes to a prefix whose
    product divides the global batch (long_500k has batch 1)."""
    out = dict(rules)
    names = set(mesh.axis_names)

    def filter_part(part):
        if part is None:
            return None
        parts = part if isinstance(part, (tuple, list)) else (part,)
        kept = tuple(p for p in parts if p in names)
        return kept if kept else None

    for k, v in out.items():
        out[k] = filter_part(v)

    batch_axes = out.get("batch") or ()
    if not isinstance(batch_axes, tuple):
        batch_axes = (batch_axes,)
    kept: list[str] = []
    prod = 1
    for ax in batch_axes:
        if global_batch % (prod * mesh.shape[ax]) == 0:
            kept.append(ax)
            prod *= mesh.shape[ax]
    out["batch"] = tuple(kept) if kept else None
    return out


def batch_shardings(inputs: dict[str, Any], mesh: Mesh, rules: Rules):
    """Shardings for the model-input dict: leading dim is batch."""
    batch = rules.get("batch")

    def shard(sds):
        if sds.ndim == 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(batch, *(None,) * (sds.ndim - 1)))

    return {k: jax.tree.map(shard, v) for k, v in inputs.items()}


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    cfg: ModelConfig
    model: Model
    cell: ShapeCell
    step_fn: Any
    abstract_args: tuple
    in_shardings: tuple
    out_shardings: Any = None


def _opt_abstract(params_abs):
    return {
        "master": jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params_abs
        ),
        "m": jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params_abs
        ),
        "v": jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params_abs
        ),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def _opt_shardings(param_sh, mesh):
    return {
        "master": param_sh,
        "m": param_sh,
        "v": param_sh,
        "step": NamedSharding(mesh, P()),
    }


def make_cell(
    arch: str,
    shape: str,
    mesh: Mesh,
    *,
    cfg_override: ModelConfig | None = None,
    rules_override: Rules | None = None,
) -> Cell:
    cfg = cfg_override or configs.get(arch)
    model = build(cfg)
    cell = SHAPES[shape]
    rules = dict(rules_override or RULESETS[cell.kind])
    rules = resolve_rules(rules, mesh, cell.global_batch)

    params_abs = model.abstract()
    param_sh = model.param_shardings(mesh, rules)
    inputs = model.input_specs(cell)

    batch_part = rules.get("batch")
    out_shardings = None
    if cell.kind == "train":
        micro = default_microbatches(cfg, cell, mesh)
        if micro > 1:
            step = build_grad_accum_train_step(
                model, num_microbatches=micro, batch_part=batch_part
            )
        else:
            step = build_train_step(model, batch_part=batch_part)
        opt_abs = _opt_abstract(params_abs)
        args = (params_abs, opt_abs, inputs)
        shardings = (
            param_sh,
            _opt_shardings(param_sh, mesh),
            batch_shardings(inputs, mesh, rules),
        )
        out_shardings = (
            NamedSharding(mesh, P()),          # loss
            param_sh,                           # new params
            _opt_shardings(param_sh, mesh),     # new opt state
        )
    elif cell.kind == "prefill":
        step = build_prefill_step(
            model, cache_len=cell.seq_len, batch_part=batch_part
        )
        args = (params_abs, inputs)
        shardings = (param_sh, batch_shardings(inputs, mesh, rules))
        cache_sh = sharding_tree(
            model.cache_specs(cell.global_batch, cell.seq_len), mesh, rules
        )
        out_shardings = (
            NamedSharding(mesh, P(batch_part)),  # logits: batch-sharded
            cache_sh,                             # cache: seq over "model"
        )
    else:  # decode
        step = build_serve_step(model, batch_part=batch_part)
        cache_abs = inputs.pop("cache")
        pos = inputs.pop("pos")
        cache_sh = sharding_tree(
            model.cache_specs(cell.global_batch, cell.seq_len), mesh, rules
        )
        args = (params_abs, cache_abs, inputs, pos)
        shardings = (
            param_sh,
            cache_sh,
            batch_shardings(inputs, mesh, rules),
            NamedSharding(mesh, P()),
        )
        out_shardings = (
            NamedSharding(mesh, P(batch_part)),  # logits
            cache_sh,                             # updated cache
        )

    return Cell(
        arch=arch, shape=shape, cfg=cfg, model=model, cell=cell,
        step_fn=step, abstract_args=args, in_shardings=shardings,
        out_shardings=out_shardings,
    )


def delta_configs(cfg: ModelConfig) -> tuple[ModelConfig, ModelConfig, int]:
    """(cfg_L1, cfg_L2, repeat) for the scan-trip cost-extrapolation:
    total_cost = cost(L1) + (repeat - 1) * (cost(L2) - cost(L1)).
    The scan unit is a layer (most archs) or a period-8 block (jamba);
    whisper scales encoder and decoder stacks together."""
    if cfg.family == "hybrid":
        period = cfg.attn_layer_period
        return (
            dataclasses.replace(cfg, num_layers=period, unroll_layers=True),
            dataclasses.replace(cfg, num_layers=2 * period,
                                unroll_layers=True),
            cfg.num_layers // period,
        )
    if cfg.family == "audio":
        return (
            dataclasses.replace(cfg, num_layers=1, encoder_layers=1,
                                unroll_layers=True),
            dataclasses.replace(cfg, num_layers=2, encoder_layers=2,
                                unroll_layers=True),
            cfg.num_layers,
        )
    base = cfg.first_dense_layers
    return (
        dataclasses.replace(cfg, num_layers=base + 1, unroll_layers=True),
        dataclasses.replace(cfg, num_layers=base + 2, unroll_layers=True),
        cfg.num_layers - base,
    )


def all_cells() -> list[tuple[str, str]]:
    out = []
    for arch in sorted(configs.ARCHS):
        for shape in cells_for(configs.get(arch)):
            out.append((arch, shape))
    return out
