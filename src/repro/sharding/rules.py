"""Logical-axis -> mesh-axis sharding rules (MaxText-style).

Mesh axes (launch/mesh.py): single-pod ("data", "model") = (16, 16);
multi-pod ("pod", "data", "model") = (2, 16, 16).

Strategy (baseline; hillclimbs in EXPERIMENTS.md §Perf adjust these):
  * train   — FSDP("data") x TP("model") x DP("pod"): parameters and AdamW
    state shard embed->data and heads/ff/experts/vocab->model; batch shards
    over (pod, data).
  * prefill — weights TP over model (params resident, no FSDP gather per
    microbatch at inference); batch over (pod, data).
  * decode  — KV-cache *sequence* dim shards over "model" (context
    parallelism: kv-head counts rarely divide 16, cache length always does);
    batch over (pod, data); weights TP over model.
"""

from __future__ import annotations

from jax.sharding import PartitionSpec

Rules = dict[str, str | tuple | None]

# Parameter logical axes:
#   embed   d_model dims of weights
#   heads/kv_heads/head_dim  attention projation dims
#   ff / moe_ff   MLP hidden dims
#   experts       MoE expert dim
#   vocab         embedding/head vocab dim
#   lora / state / layers / conv  never sharded
# Activation/cache logical axes:
#   batch, seq, cache_seq

TRAIN_RULES: Rules = {
    "embed": "data",         # FSDP: params/opt-state sharded over data
    "heads": "model",
    "ff": "model",
    "moe_ff": None,
    "experts": "model",      # expert parallelism
    "vocab": "model",
    "kv_heads": None,        # 4..48 kv heads rarely divide 16 -> replicate
    "head_dim": None,
    "batch": ("pod", "data"),
    "cache_seq": None,
}

PREFILL_RULES: Rules = {
    "embed": None,
    "heads": "model",
    "ff": "model",
    "moe_ff": None,
    "experts": "model",
    "vocab": "model",
    "kv_heads": None,
    "head_dim": None,
    "batch": ("pod", "data"),
    "cache_seq": "model",    # cache written sequence-sharded for decode
}

DECODE_RULES: Rules = {
    "embed": None,
    "heads": "model",
    "ff": "model",
    "moe_ff": None,
    "experts": "model",
    "vocab": "model",
    "kv_heads": None,
    "head_dim": None,
    "batch": ("pod", "data"),
    "cache_seq": "model",    # context parallelism over the KV cache
}

RULESETS: dict[str, Rules] = {
    "train": TRAIN_RULES,
    "prefill": PREFILL_RULES,
    "decode": DECODE_RULES,
}


def batch_pspec(rules: Rules) -> PartitionSpec:
    return PartitionSpec(rules.get("batch"))


def data_pspec(rules: Rules, ndim: int) -> PartitionSpec:
    """(B, S, ...) activations: batch sharded, rest replicated."""
    return PartitionSpec(rules.get("batch"), *(None,) * (ndim - 1))
