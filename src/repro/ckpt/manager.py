"""Checkpoint manager: atomic, keep-k, async, elastic-restore.

Layout (one directory per step)::

    <root>/step_000100.tmp/...      (written first)
    <root>/step_000100/             (atomic rename on completion)
        manifest.json               (treedef, shapes, dtypes, step, metadata)
        arr_00000.npy ...           (one file per leaf)

Design notes for real clusters (documented, host-count-agnostic API):
  * leaves are written via ``np.save`` after a ``jax.device_get`` — on a
    multi-host deployment each host would write only its addressable shards
    and the manifest records the global shape (the restore path already
    accepts a target sharding and ``device_put``s into it);
  * restore takes an optional (mesh, shardings) pair — restoring onto a
    *different* mesh shape than the one that saved is the elastic-scaling
    path (tested in tests/test_fault_tolerance.py via a subprocess with a
    different forced device count);
  * writes are atomic (tmp dir + rename), so a crash mid-save never corrupts
    the latest checkpoint; ``keep_last`` prunes old steps after a successful
    rename;
  * ``save_async`` moves serialization off the training thread.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

_MANIFEST = "manifest.json"


class CheckpointManager:
    def __init__(self, root: str, *, keep_last: int = 3):
        self.root = root
        self.keep_last = keep_last
        os.makedirs(root, exist_ok=True)
        self._pending: threading.Thread | None = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, metadata: dict | None = None):
        self.wait()
        self._save_sync(step, jax.device_get(tree), metadata or {})

    def save_async(self, step: int, tree: Any, metadata: dict | None = None):
        self.wait()
        host_tree = jax.device_get(tree)  # snapshot before training mutates

        def work():
            self._save_sync(step, host_tree, metadata or {})

        self._pending = threading.Thread(target=work, daemon=True)
        self._pending.start()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _save_sync(self, step: int, host_tree: Any, metadata: dict):
        name = f"step_{step:08d}"
        tmp = os.path.join(self.root, name + ".tmp")
        final = os.path.join(self.root, name)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)

        leaves, treedef = jax.tree.flatten(host_tree)
        for i, leaf in enumerate(leaves):
            arr = np.asarray(leaf)
            np.save(os.path.join(tmp, f"arr_{i:05d}.npy"), arr)
        manifest = {
            "step": step,
            "num_leaves": len(leaves),
            "treedef": str(treedef),
            "shapes": [list(np.asarray(l).shape) for l in leaves],
            "dtypes": [str(np.asarray(l).dtype) for l in leaves],
            "metadata": metadata,
        }
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._prune()

    def _prune(self):
        steps = self.all_steps()
        for s in steps[: -self.keep_last] if self.keep_last else []:
            shutil.rmtree(os.path.join(self.root, f"step_{s:08d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    out.append(int(d.split("_")[1]))
                except ValueError:
                    continue
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        step: int,
        target_tree: Any,
        *,
        shardings: Any | None = None,
    ) -> tuple[Any, dict]:
        """Restore into the structure of ``target_tree``.  If ``shardings``
        (same-structure NamedSharding tree) is given, leaves are placed onto
        those devices — this is the elastic re-mesh path: the mesh that
        restores need not match the mesh that saved."""
        path = os.path.join(self.root, f"step_{step:08d}")
        with open(os.path.join(path, _MANIFEST)) as f:
            manifest = json.load(f)
        leaves, treedef = jax.tree.flatten(target_tree)
        assert manifest["num_leaves"] == len(leaves), (
            f"checkpoint has {manifest['num_leaves']} leaves, "
            f"target {len(leaves)} — incompatible trees"
        )
        def load(i, ref):
            a = np.load(os.path.join(path, f"arr_{i:05d}.npy"))
            if a.dtype.kind == "V":
                # numpy round-trips ml_dtypes (bfloat16 &c.) as raw void —
                # reinterpret using the target leaf dtype (bit-exact).
                a = a.view(np.dtype(ref.dtype))
            return a

        loaded = [load(i, ref) for i, ref in enumerate(leaves)]
        for a, ref, shp in zip(loaded, leaves, manifest["shapes"]):
            assert list(a.shape) == shp
            assert tuple(a.shape) == tuple(ref.shape), (
                f"shape mismatch: ckpt {a.shape} vs target {ref.shape}"
            )
        def cast(a, ref):
            return a if a.dtype == ref.dtype else a.astype(ref.dtype)

        if shardings is not None:
            flat_sh = jax.tree.leaves(
                shardings, is_leaf=lambda x: hasattr(x, "device_set")
            )
            arrays = [
                jax.device_put(cast(a, ref), sh)
                for a, ref, sh in zip(loaded, leaves, flat_sh)
            ]
        else:
            arrays = [
                jax.numpy.asarray(cast(a, ref))
                for a, ref in zip(loaded, leaves)
            ]
        return jax.tree.unflatten(treedef, arrays), manifest["metadata"]

    def restore_latest(self, target_tree: Any, **kw):
        step = self.latest_step()
        if step is None:
            return None
        tree, meta = self.restore(step, target_tree, **kw)
        return step, tree, meta
