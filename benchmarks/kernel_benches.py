"""Kernel microbenchmarks: Pallas (interpret on CPU) vs jnp oracle, plus the
jit'd-oracle throughput that the capacity planner actually uses on CPU.

On-TPU the pallas_call path compiles to MXU/VPU kernels; interpret mode
timings here only validate plumbing overhead, so the `derived` column
reports the problem size and the oracle GFLOP/s (the CPU-meaningful
number).

Every bench that touches a kernel also *checks* it against its reference at
the benched shapes (the ARCHITECTURE.md tolerance policy); a mismatch
raises, which `benchmarks/run.py` reports as a failed bench and turns into
a nonzero exit — this is what the CI `bench-smoke` job gates on.  All
benches accept ``quick=True`` (tiny shapes, fewer iters) for that job.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

Row = tuple[str, float, str]


def commitment_sweep_kernel_stats(quick: bool = False) -> dict:
    """Structured launch accounting (``repro.obs.kernelstats``) for the
    commitment-sweep shapes this module benches — block plan, padded
    dims, HBM trace passes, VMEM temp, FLOP estimate.  Stamped into the
    BENCH_ci.json payload by ``benchmarks/run.py`` so kernel-shape
    regressions (a block plan drifting past its budgets) are visible in
    the CI artifact trajectory."""
    from repro.obs.kernelstats import sweep_kernel_stats

    shapes = {
        # (p, t, g) mirrors bench_commitment_sweep / bench_pool_portfolio_sweep.
        "commitment_sweep": (
            (4, 24 * 28, 32) if quick else (32, 24 * 365, 128)
        ),
        "pool_portfolio_sweep": (
            (4, 24 * 7 * 8, 32) if quick else (12, 24 * 365 * 3, 128)
        ),
    }
    return {
        name: sweep_kernel_stats(p, g, t).to_dict()
        for name, (p, t, g) in shapes.items()
    }


def _time(fn, *args, iters=3, warmup=1) -> float:
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def bench_commitment_sweep(quick: bool = False, seed: int = 0) -> list[Row]:
    from repro.kernels.commitment_sweep.ops import (
        commitment_sweep,
        commitment_sweep_oracle,
    )

    rng = np.random.default_rng(seed)
    # 32 pools x 1y hourly x 128 candidates (quick: 4 x 4wk x 32)
    p, t, g = (4, 24 * 28, 32) if quick else (32, 24 * 365, 128)
    f = jnp.asarray(rng.gamma(2, 50, (p, t)).astype(np.float32))
    cs = jnp.linspace(float(f.min()), float(f.max()), g)

    oracle = jax.jit(lambda f_, c_: commitment_sweep_oracle(f_, c_))
    us_oracle = _time(oracle, f, cs)
    flops = 4.0 * p * t * g  # sub, 2x hinge, fma-accumulate
    rows = [
        (
            "kernel_commitment_sweep_oracle",
            us_oracle,
            f"{p}x{t}x{g} {flops / us_oracle / 1e3:.1f} GFLOP/s",
        )
    ]
    kf, kc = f[:4], cs
    us_kernel = _time(
        lambda f_, c_: commitment_sweep(f_, c_, interpret=True),
        kf, kc, iters=1, warmup=1,
    )
    np.testing.assert_allclose(
        np.asarray(commitment_sweep(kf, kc, interpret=True)),
        np.asarray(commitment_sweep_oracle(kf, kc)),
        rtol=2e-4, atol=1e-2,
    )
    rows.append(
        ("kernel_commitment_sweep_interpret", us_kernel,
         "pallas interpret-mode validation path, checked vs oracle")
    )

    # 2-D sweep: per-pool candidate grids + dual over/under accumulators
    # (the portfolio optimizer's input) — jnp oracle throughput plus the
    # Pallas kernel path in interpret mode (plumbing validation off-TPU).
    from repro.kernels.commitment_sweep.ops import (
        commitment_sweep_over_under,
        commitment_sweep_over_under_oracle,
    )
    cs2 = f.min(-1, keepdims=True) + (
        f.max(-1, keepdims=True) - f.min(-1, keepdims=True)
    ) * jnp.linspace(0.0, 1.0, g)[None, :]
    oracle2 = jax.jit(
        lambda f_, c_: commitment_sweep_over_under_oracle(f_, c_)
    )
    us_2d = _time(oracle2, f, cs2)
    rows.append(
        ("kernel_commitment_sweep_2d_over_under_oracle", us_2d,
         f"{p} per-pool grids x{g}, {2 * flops / us_2d / 1e3:.1f} GFLOP/s")
    )
    us_2d_k = _time(
        lambda f_, c_: commitment_sweep_over_under(f_, c_, interpret=True),
        f[:4], cs2[:4], iters=1, warmup=1,
    )
    ko, ku = commitment_sweep_over_under(f[:4], cs2[:4], interpret=True)
    ro, ru = commitment_sweep_over_under_oracle(f[:4], cs2[:4])
    np.testing.assert_allclose(np.asarray(ko), np.asarray(ro),
                               rtol=2e-4, atol=1e-2)
    np.testing.assert_allclose(np.asarray(ku), np.asarray(ru),
                               rtol=2e-4, atol=1e-2)
    rows.append(
        ("kernel_commitment_sweep_2d_over_under_interpret", us_2d_k,
         "pallas 2-D per-pool-grid path, checked vs oracle")
    )
    return rows


def bench_pool_portfolio_sweep(quick: bool = False, seed: int = 0) -> list[Row]:
    """Fleet-scale per-pool planning shape (paper §6): P=12 pools x 3y of
    hourly demand (T=26280) x G=128 per-pool candidate levels — the batch
    the multi-pool planner feeds the commitment_sweep kernel.  Compares ONE
    batched (P, T) x (P, G) pass against a python loop of P single-pool
    calls.  On the kernel path the loop pays per-call dispatch AND pool
    padding (every (1, T) call is padded to the bp=8 pool block), so the
    batched sweep wins by ~an order of magnitude; the jnp-oracle rows are
    context showing XLA CPU materializing the (P, G, T) broadcast instead
    of tiling it (the problem the Pallas kernel exists to solve)."""
    from repro.kernels.commitment_sweep.ops import (
        commitment_sweep_over_under,
        commitment_sweep_over_under_oracle,
    )

    rng = np.random.default_rng(seed + 3)
    p, t, g = (4, 24 * 7 * 8, 32) if quick else (12, 24 * 365 * 3, 128)
    f = jnp.asarray(rng.gamma(2, 50, (p, t)).astype(np.float32))
    lo = f.min(-1, keepdims=True)
    hi = f.max(-1, keepdims=True)
    cs = lo + (hi - lo) * jnp.linspace(0.0, 1.0, g)[None, :]
    shape = f"{p} pools x {t}h x {g} levels"

    us_kb = _time(
        lambda f_, c_: commitment_sweep_over_under(f_, c_, interpret=True),
        f, cs, iters=1, warmup=1,
    )
    ko, ku = commitment_sweep_over_under(f, cs, interpret=True)
    ro, ru = commitment_sweep_over_under_oracle(f, cs)
    np.testing.assert_allclose(np.asarray(ko), np.asarray(ro),
                               rtol=2e-4, atol=1e-2)
    np.testing.assert_allclose(np.asarray(ku), np.asarray(ru),
                               rtol=2e-4, atol=1e-2)

    def kernel_loop(f_, c_):
        return [
            commitment_sweep_over_under(
                f_[i : i + 1], c_[i : i + 1], interpret=True
            )
            for i in range(p)
        ]

    us_kl = _time(kernel_loop, f, cs, iters=1, warmup=1)
    rows = [
        ("kernel_pool_sweep_batched", us_kb,
         f"{shape}, one (P,T)x(P,G) kernel pass, checked vs oracle"),
        ("kernel_pool_sweep_loop", us_kl,
         f"{p} single-pool kernel calls, {us_kl / us_kb:.1f}x slower "
         "than batched (dispatch + bp=8 pool padding)"),
    ]

    oracle = jax.jit(
        lambda f_, c_: commitment_sweep_over_under_oracle(f_, c_)
    )
    us_ob = _time(oracle, f, cs, iters=1, warmup=1)
    us_ol = _time(
        lambda f_, c_: [
            oracle(f_[i : i + 1], c_[i : i + 1]) for i in range(p)
        ],
        f, cs, iters=1, warmup=1,
    )
    rows.append(
        ("kernel_pool_sweep_oracle_batched", us_ob,
         f"{shape}, jnp oracle, one dispatch")
    )
    rows.append(
        ("kernel_pool_sweep_oracle_loop", us_ol,
         f"{p} single-pool oracle dispatches")
    )
    return rows


def bench_preemption_scan(quick: bool = False, seed: int = 0) -> list[Row]:
    """Spot-revocation Monte-Carlo walk (spot subsystem): the per-pool
    two-state Markov chain simulated as ONE compiled ``lax.scan`` over the
    hour axis carrying the (N draws, P pools) state, vs the naive python
    replay dispatching the identical step once per hour (the same baseline
    shape as ``bench_rolling_replan``).  Fleet scale is P=12 pools x 3
    years hourly (T=26280) x N=32 draws; both walks consume the SAME
    pre-drawn noise and must produce bit-identical state/interruption
    paths (prices to float tolerance — the scan contracts the price AR(1)
    into an fma).  Target: scan >= 5x.  NOTE: the full-mode loop replay
    dispatches ~26k eager steps (O(1 minute)); ``--quick`` drops to 4
    weeks."""
    from repro.capacity import preemption as pe
    from repro.core import spot as sp

    clouds = ["aws", "azure", "gcp"] * (2 if quick else 4)
    params = pe.params_for_clouds(clouds)
    t, n = (24 * 7 * 4, 8) if quick else (24 * 365 * 3, 32)
    noise = pe.draw_noise(params, t, n, jax.random.PRNGKey(seed))
    jax.block_until_ready(noise)
    scan = pe.revocation_walk(params, *noise)       # pay the compile once
    jax.block_until_ready(scan.available)
    t0 = time.perf_counter()
    scan = pe.revocation_walk(params, *noise)
    jax.block_until_ready(scan.available)
    us_scan = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    loop = pe.revocation_walk_loop(params, *noise)
    us_loop = (time.perf_counter() - t0) * 1e6
    np.testing.assert_array_equal(
        np.asarray(scan.available), np.asarray(loop.available)
    )
    np.testing.assert_array_equal(
        np.asarray(scan.interrupted), np.asarray(loop.interrupted)
    )
    np.testing.assert_allclose(
        np.asarray(scan.price), np.asarray(loop.price), atol=1e-5
    )
    a_emp = scan.availability()
    a_th = np.asarray(pe.stationary_availability(params))
    lines = sp.pool_spot_lines(clouds, od_rate=2.1)
    shape = f"{len(clouds)} pools x {t}h x {n} draws"
    return [
        ("preemption_mc_scan", us_scan,
         f"{shape}, one lax.scan program, checked vs loop"),
        ("preemption_mc_python_loop", us_loop,
         f"per-hour eager replay, {us_loop / us_scan:.1f}x slower than "
         "scan (bit-identical paths)"),
        ("preemption_stationary_vs_empirical", us_scan,
         f"max |a_emp - a| = {np.abs(a_emp - a_th).max():.4f}"),
        ("spot_effective_rate_range", us_scan,
         f"{float(lines.rate.min()):.2f}-{float(lines.rate.max()):.2f} "
         "per used chip-hour vs od 2.1"),
    ]


def bench_rolling_replan(quick: bool = False, seed: int = 0) -> list[Row]:
    """Rolling weekly re-planning replay (paper Algorithm 1 as operated):
    ONE scan-compiled program vs the naive python-loop replay that re-fits
    the forecaster on every week's extended prefix from scratch.  Fleet
    scale is P=12 pools x 3 years x weekly cadence (~130 re-plans); the
    scan path turns each weekly refit into a cumulative-normal-equation
    gather, so the loop's per-week O(T D^2) re-accumulation + host
    dispatch is the honest cost of not compiling the loop.  Target: scan
    >= 5x at fleet scale.  Also checks the two replays price the window
    identically (same step math, different summation order)."""
    from repro.core import replan
    from repro.data import traces

    p, weeks, start, cadence = (
        (3, 16, 6, 2) if quick else (12, 156, 26, 1)
    )
    pools = traces.synthetic_pool_set(
        num_pools=p, num_hours=24 * 7 * weeks, seed=seed
    )
    kw = dict(
        cadence_weeks=cadence, start_weeks=start, horizon_weeks=4 if quick
        else 8, compare=False,
    )

    def scan_run():
        return replan.replan_fleet_pools(pools, backend="scan", **kw)

    def loop_run():
        return replan.replan_fleet_pools(pools, backend="loop", **kw)

    scan_run()                                     # pay the compile once
    t0 = time.perf_counter()
    scan_rep = scan_run()
    us_scan = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    loop_rep = loop_run()
    us_loop = (time.perf_counter() - t0) * 1e6
    np.testing.assert_allclose(
        scan_rep.total_cost, loop_rep.total_cost, rtol=1e-4
    )
    shape = (f"{p} pools x {weeks}w, cadence {cadence}w, "
             f"{len(scan_rep.weeks)} weeks replayed")
    return [
        ("replan_rolling_scan", us_scan,
         f"{shape}, one lax.scan program"),
        ("replan_rolling_python_loop", us_loop,
         f"per-week prefix re-fits, {us_loop / us_scan:.1f}x slower than "
         "scan (checked equal spend)"),
    ]


def bench_migration_scan(quick: bool = False, seed: int = 0) -> list[Row]:
    """Hardware-generation turnover replay (generation subsystem): logistic
    demand transfer between (old family, successor) pool pairs + the
    software-efficiency deflator, walked as ONE compiled ``lax.scan`` over
    the hour axis carrying the per-edge migrated shares, vs the naive
    python replay dispatching the identical (jitted) step once per hour.
    Fleet scale is P=16 pools (8 turnover pairs, >= 12) x 3 years hourly
    (T=26280); both walks must produce BIT-IDENTICAL demand matrices (the
    step evaluates the hazard recurrence's closed-form solution and
    multiplies by a precomputed reciprocal, so no fma-contraction drift
    separates the two compilations).  Target: scan >= 5x."""
    import jax.numpy as jnp

    from repro.capacity import generations as gn
    from repro.data import traces

    p, hours = (4, 24 * 7 * 8) if quick else (16, 24 * 365 * 3)
    cfg = gn.MigrationConfig()
    base = traces.synthetic_base_pool_set(
        num_pools=p, num_hours=hours, seed=seed, migration=cfg
    )
    edges = gn.migration_edges(base.keys, cfg)
    demand = jnp.asarray(base.demand)
    scan = gn.migrate_demand(demand, edges)     # pay the compile once
    jax.block_until_ready(scan)
    t0 = time.perf_counter()
    scan = gn.migrate_demand(demand, edges)
    jax.block_until_ready(scan)
    us_scan = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    loop = gn.migrate_demand_loop(demand, edges)
    us_loop = (time.perf_counter() - t0) * 1e6
    np.testing.assert_array_equal(np.asarray(scan), np.asarray(loop))
    # The transfer conserves perf-adjusted volume: undo the deflator and
    # re-weight successors by (1 + uplift) — must equal the base total.
    t = jnp.arange(base.num_hours)
    eff = gn.software_deflator(t, cfg.software_efficiency_per_year)
    perf = np.ones(p, np.float32)
    perf[np.asarray(edges.dst)] = 1.0 + np.asarray(edges.uplift)
    vol = float(((np.asarray(scan) / np.asarray(eff)) * perf[:, None]).sum())
    base_vol = float(base.demand.sum())
    np.testing.assert_allclose(vol, base_vol, rtol=1e-4)
    shape = f"{p} pools x {base.num_hours}h x {edges.num_edges} edges"
    return [
        ("migration_turnover_scan", us_scan,
         f"{shape}, one lax.scan program, bit-identical to loop"),
        ("migration_turnover_python_loop", us_loop,
         f"per-hour eager replay, {us_loop / us_scan:.1f}x slower than "
         "scan"),
        ("migration_volume_conservation", us_scan,
         f"perf-adjusted volume drift {abs(vol / base_vol - 1):.2e}"),
    ]


def bench_flash_attention(quick: bool = False, seed: int = 0) -> list[Row]:
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref

    rng = np.random.default_rng(seed + 1)
    b, hq, hkv, d = 1, 8, 2, 64
    s = 256 if quick else 1024
    q = jnp.asarray(rng.normal(size=(b, hq, s, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)).astype(np.float32))

    ref = jax.jit(lambda q_, k_, v_: attention_ref(q_, k_, v_, causal=True))
    us_ref = _time(ref, q, k, v)
    flops = 4.0 * b * hq * s * s * d
    rows = [
        ("kernel_flash_attention_oracle", us_ref,
         f"b{b} h{hq}/{hkv} s{s} d{d} {flops / us_ref / 1e3:.1f} GFLOP/s"),
    ]
    sk = 128 if quick else 256
    qs_, ks_, vs_ = q[:, :, :sk], k[:, :, :sk], v[:, :, :sk]
    us_k = _time(
        lambda q_, k_, v_: flash_attention(q_, k_, v_, causal=True,
                                           interpret=True),
        qs_, ks_, vs_, iters=1, warmup=1,
    )
    np.testing.assert_allclose(
        np.asarray(flash_attention(qs_, ks_, vs_, causal=True,
                                   interpret=True)),
        np.asarray(attention_ref(qs_, ks_, vs_, causal=True)),
        atol=2e-5, rtol=1e-4,
    )
    rows.append(("kernel_flash_attention_interpret", us_k,
                 "pallas interpret-mode validation path, checked vs ref"))
    return rows


def bench_linrec(quick: bool = False, seed: int = 0) -> list[Row]:
    from repro.kernels.linrec.ops import rwkv6_linear_attention, rwkv6_oracle

    rng = np.random.default_rng(seed + 2)
    b, h, d = 2, 8, 64
    t = 128 if quick else 512
    r = jnp.asarray(rng.normal(size=(b, h, t, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, h, t, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, h, t, d)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.5, 1.0, (b, h, t, d)).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(h, d)).astype(np.float32))

    oracle = jax.jit(lambda *a: rwkv6_oracle(*a)[0])
    us_o = _time(oracle, r, k, v, w, u)
    rows = [
        ("kernel_linrec_oracle_scan", us_o,
         f"b{b} h{h} t{t} d{d} sequential lax.scan"),
    ]
    sl = (slice(None, 1), slice(None, 2), slice(None, 64))
    args = (r[sl], k[sl], v[sl], w[sl], u[:2])
    us_k = _time(
        lambda *a: rwkv6_linear_attention(*a, chunk=32, interpret=True)[0],
        *args, iters=1, warmup=1,
    )
    y_k = rwkv6_linear_attention(*args, chunk=32, interpret=True)[0]
    y_r = rwkv6_oracle(*args)[0]
    np.testing.assert_allclose(
        np.asarray(y_k), np.asarray(y_r), atol=2e-3, rtol=2e-3
    )
    rows.append(("kernel_linrec_interpret", us_k,
                 "pallas interpret-mode validation path, checked vs ref"))
    return rows


ALL_KERNEL_BENCHES = [
    bench_commitment_sweep,
    bench_pool_portfolio_sweep,
    bench_preemption_scan,
    bench_migration_scan,
    bench_rolling_replan,
    bench_flash_attention,
    bench_linrec,
]
