"""Roofline report generator: reads the dry-run JSON artifacts and emits the
EXPERIMENTS.md §Dry-run / §Roofline tables.

    PYTHONPATH=src python -m benchmarks.roofline [--dir results/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def load_records(dirpath: str) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def _ms(x: float) -> str:
    return f"{x * 1e3:.2f}"


def _gb(x: float) -> str:
    return f"{x / 1e9:.2f}"


def whats_limiting(rec: dict) -> str:
    """One sentence on what would move the dominant term down."""
    r = rec["roofline"]
    dom = r["dominant"]
    kind = rec["shape"].split("_")[0]
    if dom == "compute":
        if r["useful_ratio"] < 0.5:
            return ("compute-bound with low useful ratio: cut remat/MoE-"
                    "capacity waste (fewer recomputed FLOPs per step)")
        return ("compute-bound near the useful limit: only faster kernels "
                "(flash attention on MXU) or lower precision move it")
    if dom == "memory":
        if rec["shape"].startswith(("decode", "long")):
            return ("memory-bound on KV-cache reads: shrink the cache "
                    "(MLA/GQA compression, quantized KV) or raise batch to "
                    "amortize weight reads")
        return ("memory-bound on weight/activation traffic: increase "
                "per-chip batch or fuse activations")
    return ("collective-bound: overlap FSDP gathers with compute, shard "
            "differently, or compress the payload (EF-int8)")


def dry_run_table(recs: list[dict]) -> str:
    lines = [
        "| arch | shape | mesh | compile | mem/dev (TPU-proj) | fits 16GB | collectives |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if not r.get("compile_ok"):
            lines.append(
                f"| {r['arch']} | {r['shape']} | "
                f"{'multi' if r.get('multi_pod') else 'single'} | FAILED | — | — | — |"
            )
            continue
        mesh = "x".join(str(d) for d in r["mesh"])
        m = r["memory"]
        counts = r["cost_full_module"]["collective_counts"]
        coll = ", ".join(f"{k.split('-')[-1] if '-' in k else k}:{v}"
                         for k, v in counts.items() if v)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {mesh} | "
            f"{r['compile_seconds']}s | "
            f"{_gb(m['total_per_device_tpu_projected'])} GB | "
            f"{'yes' if m['fits_16gb'] else 'NO'} | {coll or '-'} |"
        )
    return "\n".join(lines)


def roofline_table(recs: list[dict]) -> str:
    lines = [
        "| arch | shape | compute ms | memory ms | collective ms | dominant |"
        " MODEL_FLOPs/HLO | bottleneck note |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if not r.get("compile_ok") or len(r.get("mesh", [])) != 2:
            continue  # single-pod only for the roofline table
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {_ms(rf['compute_s'])} | "
            f"{_ms(rf['memory_s'])} | {_ms(rf['collective_s'])} | "
            f"**{rf['dominant']}** | {rf['useful_ratio']:.2f} | "
            f"{whats_limiting(r)} |"
        )
    return "\n".join(lines)


def summary(recs: list[dict]) -> str:
    ok = [r for r in recs if r.get("compile_ok")]
    failed = [r for r in recs if not r.get("compile_ok")]
    fits = [r for r in ok if r["memory"]["fits_16gb"]]
    single = [r for r in ok if len(r.get("mesh", [])) == 2]
    multi = [r for r in ok if len(r.get("mesh", [])) == 3]
    by_dom: dict[str, int] = {}
    for r in single:
        by_dom[r["roofline"]["dominant"]] = (
            by_dom.get(r["roofline"]["dominant"], 0) + 1
        )
    out = [
        f"- cells compiled: {len(ok)} ({len(single)} single-pod, "
        f"{len(multi)} multi-pod); failures: {len(failed)}",
        f"- fits 16 GB/chip (TPU-projected): {len(fits)}/{len(ok)}",
        f"- dominant terms (single-pod): {by_dom}",
    ]
    if failed:
        out.append("- FAILED: " + ", ".join(
            f"{r['arch']}x{r['shape']}" for r in failed))
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--format", choices=("md", "csv"), default="md")
    args = ap.parse_args()
    recs = load_records(args.dir)
    if args.format == "csv":
        print("name,us_per_call,derived")
        for r in recs:
            if not r.get("compile_ok"):
                continue
            rf = r["roofline"]
            mesh = "x".join(str(d) for d in r["mesh"])
            dom_s = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
            print(f"roofline_{r['arch']}_{r['shape']}_{mesh},"
                  f"{dom_s * 1e6:.1f},"
                  f"{rf['dominant']}-bound useful={rf['useful_ratio']:.2f}")
        return
    print("## Summary\n")
    print(summary(recs))
    print("\n## Dry-run matrix\n")
    print(dry_run_table(recs))
    print("\n## Roofline (single-pod 16x16, per step)\n")
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
