"""§Perf hillclimbing driver: hypothesis -> change -> re-lower -> validate.

Each experiment re-runs a dry-run cell with a config/rules variant and
reports the roofline-term deltas vs the baseline JSON.  The narrative log
(hypothesis, napkin math, confirmed/refuted) lives in EXPERIMENTS.md §Perf;
this driver produces the numbers.

    PYTHONPATH=src python -m benchmarks.hillclimb --cell A1 [--out results/perf]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os

from repro import configs


def _variant_cfg(arch: str, **changes):
    return dataclasses.replace(configs.get(arch), **changes)


# ---------------------------------------------------------------------------
# Experiment registry: (cell-id) -> (arch, shape, description, cfg changes,
# rules changes)
# ---------------------------------------------------------------------------

def experiments() -> dict[str, dict]:
    from repro.sharding.rules import RULESETS

    decode_batch_amortized = dict(RULESETS["decode"])

    return {
        # ---- Cell A: internlm2-20b x decode_32k (memory-bound decode;
        #      the shape §5 free pools provision) ----
        "A1": {
            "arch": "internlm2-20b", "shape": "decode_32k",
            "desc": "int8 KV cache (halve cache-read bytes)",
            "cfg": {"kv_cache_dtype": "int8"},
        },
        "A2": {
            "arch": "internlm2-20b", "shape": "decode_32k",
            "desc": "int8 KV + params fully sharded at decode "
                    "(embed->data: kill replicated-weight reads)",
            "cfg": {"kv_cache_dtype": "int8"},
            "rules": {"embed": "data"},
        },
        "A3": {
            "arch": "internlm2-20b", "shape": "decode_32k",
            "desc": "int8 KV + shard kv projections over model via "
                    "head_dim spill (kv_heads=8 < 16; kills the replicated "
                    "wk/wv reads)",
            "cfg": {"kv_cache_dtype": "int8"},
            "rules": {"kv_heads": "model"},
        },
        # ---- Cell B: deepseek-v2-lite-16b x train_4k (compute-bound,
        #      useful 0.40: MoE-capacity + remat waste) ----
        "B1": {
            "arch": "deepseek-v2-lite-16b", "shape": "train_4k",
            "desc": "MoE capacity factor 1.25 -> 1.0 (cut dead-slot FLOPs)",
            "cfg": {"moe_capacity_factor": 1.0},
        },
        "B2": {
            "arch": "deepseek-v2-lite-16b", "shape": "train_4k",
            "desc": "capacity 1.0 + dots-saveable remat (no matmul "
                    "recompute in backward)",
            "cfg": {"moe_capacity_factor": 1.0, "remat_policy": "dots"},
        },
        # ---- Cell C: granite-moe-1b-a400m x train_4k (worst useful 0.19;
        #      memory/collective-bound tiny-expert MoE) ----
        "C1": {
            "arch": "granite-moe-1b-a400m", "shape": "train_4k",
            "desc": "MoE capacity 1.25 -> 1.0",
            "cfg": {"moe_capacity_factor": 1.0},
        },
        "C2": {
            "arch": "granite-moe-1b-a400m", "shape": "train_4k",
            "desc": "capacity 1.0 + no expert parallelism (experts "
                    "replicated, tokens stay data-local: kills the MoE "
                    "dispatch collectives for 32 tiny experts)",
            "cfg": {"moe_capacity_factor": 1.0},
            "rules": {"experts": None, "moe_ff": "model"},
        },
        "C3": {
            "arch": "granite-moe-1b-a400m", "shape": "train_4k",
            "desc": "C2 + dots remat",
            "cfg": {"moe_capacity_factor": 1.0, "remat_policy": "dots"},
            "rules": {"experts": None, "moe_ff": "model"},
        },
    }


def run_experiment(name: str, out_dir: str, multi_pod: bool = False) -> dict:
    from repro.launch.dryrun import run_cell
    from repro.sharding.rules import RULESETS

    exp = experiments()[name]
    arch, shape = exp["arch"], exp["shape"]

    kind = "train" if shape.startswith("train") else (
        "prefill" if shape.startswith("prefill") else "decode")
    rules = dict(RULESETS[kind])
    rules.update(exp.get("rules", {}))

    rec = run_cell(
        arch, shape, multi_pod=multi_pod, rules_override=rules,
        cfg_transform=lambda c: dataclasses.replace(c, **exp.get("cfg", {})),
    )

    rec["experiment"] = name
    rec["description"] = exp["desc"]
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"{name}.json"), "w") as f:
        json.dump(rec, f, indent=1)

    # baseline comparison
    base_path = (
        f"results/dryrun/{arch}__{shape}__"
        f"{'multi' if multi_pod else 'single'}.json"
    )
    if os.path.exists(base_path):
        base = json.load(open(base_path))
        b, v = base["roofline"], rec["roofline"]
        print(f"\n=== {name}: {exp['desc']} ===")
        for term in ("compute_s", "memory_s", "collective_s"):
            delta = (v[term] - b[term]) / max(b[term], 1e-12) * 100
            print(f"  {term:14s} {b[term]*1e3:10.2f} -> {v[term]*1e3:10.2f} ms"
                  f"  ({delta:+.1f}%)")
        print(f"  useful_ratio   {b['useful_ratio']:.3f} -> "
              f"{v['useful_ratio']:.3f}")
        print(f"  dominant       {b['dominant']} -> {v['dominant']}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", nargs="+", default=sorted(experiments()))
    ap.add_argument("--out", default="results/perf")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    for name in args.cell:
        run_experiment(name, args.out, multi_pod=args.multi_pod)


if __name__ == "__main__":
    import os as _os
    _os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=512"
    )
    main()
