"""Benchmark harness: one function per paper table/figure + kernel micro.

Prints ``name,us_per_call,derived`` CSV.  Roofline terms come from the
dry-run artifacts (see benchmarks/roofline.py and EXPERIMENTS.md §Roofline);
this harness covers the paper-results reproduction and kernel throughputs.

Flags:
    --quick        tiny shapes / fewer iters — the CI `bench-smoke` mode.
                   Kernel benches still run their kernel-vs-reference
                   tolerance checks, so a kernel regression fails the job.
    --json PATH    also write rows + failures as JSON (the CI artifact),
                   stamped with provenance (schema version, git SHA, seed,
                   JAX/numpy/backend versions, platform) so BENCH_ci.json
                   trajectories are comparable across machines and
                   commits, plus a per-bench wall-clock span breakdown
                   (``repro.obs.spans``).
    --spans PATH   also write the span report as its own JSON artifact.
    --seed N       PRNG seed threaded to every bench (default 0), so two
                   runs at the same seed produce identical `derived`
                   columns — the CI BENCH_ci.json artifact is stable run
                   to run (timing columns aside).
    --filter S     run only benches whose function name contains S
                   (case-insensitive substring, e.g. `--filter migration`
                   runs just bench_migration_scan) — lets CI or a dev
                   iterate on one bench without rerunning everything.
                   Unknown filters (zero matches) exit nonzero.

Exit status is nonzero if any bench raises (including a failed
kernel-vs-reference check inside a bench).
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import subprocess
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: BENCH_ci.json payload schema; bump when the payload shape changes.
BENCH_SCHEMA_VERSION = 2


def _git_sha() -> str:
    """Commit provenance for the JSON artifact: CI env var if present,
    else the working tree's HEAD, else "unknown"."""
    for var in ("GITHUB_SHA", "CI_COMMIT_SHA"):
        sha = os.environ.get(var)
        if sha:
            return sha
    try:
        return subprocess.run(
            ["git", "-C", _ROOT, "rev-parse", "HEAD"],
            capture_output=True, text=True, timeout=10, check=True,
        ).stdout.strip()
    except Exception:  # noqa: BLE001
        return "unknown"


def main(argv=None) -> None:
    # Robust to invocation directory: repo root (for `benchmarks.*`) and
    # src (for `repro.*`) both land on the path.
    for p in (os.path.join(_ROOT, "src"), _ROOT):
        if p not in sys.path:
            sys.path.insert(0, p)
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="tiny-shape smoke mode (CI bench-smoke job)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write results JSON (e.g. BENCH_ci.json)")
    ap.add_argument("--spans", default=None, metavar="PATH",
                    help="write the wall-clock span report JSON")
    ap.add_argument("--seed", type=int, default=0,
                    help="PRNG seed for every bench (stable derived values)")
    ap.add_argument("--filter", default=None, metavar="SUBSTR",
                    help="only run benches whose name contains SUBSTR")
    args = ap.parse_args(argv)

    from benchmarks.kernel_benches import (
        ALL_KERNEL_BENCHES,
        commitment_sweep_kernel_stats,
    )
    from benchmarks.paper_benches import ALL_PAPER_BENCHES
    from repro.obs.spans import SpanRecorder

    benches = ALL_PAPER_BENCHES + ALL_KERNEL_BENCHES
    if args.filter is not None:
        want = args.filter.lower()
        benches = [b for b in benches if want in b.__name__.lower()]
        if not benches:
            names = [b.__name__ for b in
                     ALL_PAPER_BENCHES + ALL_KERNEL_BENCHES]
            raise SystemExit(
                f"--filter {args.filter!r} matches no bench; "
                f"available: {names}"
            )

    rec = SpanRecorder()
    print("name,us_per_call,derived")
    rows, failures = [], []
    for bench in benches:
        try:
            with rec.span(bench.__name__, phase="execute"):
                for name, us, derived in bench(
                    quick=args.quick, seed=args.seed
                ):
                    rows.append({"name": name, "us_per_call": us,
                                 "derived": derived})
                    print(f"{name},{us:.1f},{derived}")
        except Exception as e:  # noqa: BLE001
            failures.append({"bench": bench.__name__, "error": repr(e)})
            print(f"{bench.__name__},NaN,FAILED: {e!r}")

    if args.json:
        import jax
        import numpy as np

        payload = {
            "schema_version": BENCH_SCHEMA_VERSION,
            "git_sha": _git_sha(),
            "quick": args.quick,
            "seed": args.seed,
            "filter": args.filter,
            "python": platform.python_version(),
            "platform": platform.platform(),
            "jax": jax.__version__,
            "numpy": np.__version__,
            "backend": jax.default_backend(),
            "rows": rows,
            "failures": failures,
            "spans": rec.summary(),
            "kernel_stats": commitment_sweep_kernel_stats(args.quick),
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json}: {len(rows)} rows, "
              f"{len(failures)} failures", file=sys.stderr)
    if args.spans:
        rec.to_json(args.spans)
        print(f"wrote {args.spans}: {len(rec.spans)} spans",
              file=sys.stderr)

    if failures:
        raise SystemExit(f"{len(failures)} benches failed: {failures}")


if __name__ == "__main__":
    main()
