"""Benchmark harness: one function per paper table/figure + kernel micro.

Prints ``name,us_per_call,derived`` CSV.  Roofline terms come from the
dry-run artifacts (see benchmarks/roofline.py and EXPERIMENTS.md §Roofline);
this harness covers the paper-results reproduction and kernel throughputs.
"""

from __future__ import annotations

import sys


def main() -> None:
    sys.path.insert(0, "src")
    from benchmarks.kernel_benches import ALL_KERNEL_BENCHES
    from benchmarks.paper_benches import ALL_PAPER_BENCHES

    print("name,us_per_call,derived")
    failures = []
    for bench in ALL_PAPER_BENCHES + ALL_KERNEL_BENCHES:
        try:
            for name, us, derived in bench():
                print(f"{name},{us:.1f},{derived}")
        except Exception as e:  # noqa: BLE001
            failures.append((bench.__name__, repr(e)))
            print(f"{bench.__name__},NaN,FAILED: {e!r}")
    if failures:
        raise SystemExit(f"{len(failures)} benches failed: {failures}")


if __name__ == "__main__":
    main()
