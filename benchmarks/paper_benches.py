"""One benchmark per paper table/figure.  Each function returns CSV rows
(name, us_per_call, derived) where `derived` is the headline number the
paper's table/figure reports."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import commitment as cm
from repro.core import demand as dm
from repro.core import forecast as fc
from repro.core import freepool as fp
from repro.core import ladder as ld
from repro.core import planner as pl
from repro.core import portfolio as pt
from repro.core import timeshift as ts
from repro.core.demand import HOURS_PER_WEEK

Row = tuple[str, float, str]


def _time(fn, *args, iters=5, warmup=2) -> float:
    """Wall time per call in microseconds (after jit warmup)."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def bench_demand_characterization(quick: bool = False, seed: int = 0) -> list[Row]:
    """Paper §2.2 / Figs 2,5,7: dataset statistics of the calibrated trace."""
    trace = dm.synth_demand(
        24 * 365 if quick else 24 * 365 * 3, key=jax.random.PRNGKey(seed + 7)
    )
    us = _time(lambda t: dm.hourly_to_daily(t), trace)
    stats = dm.characterize(np.asarray(trace))
    return [
        ("fig2_lag7_autocorr", us, f"{stats['lag7_daily_autocorr']:.3f}"),
        ("fig2_weekly_peak_trough", us, f"{stats['weekly_ratio']:.2f}x"),
        ("fig2_diurnal_peak_trough", us, f"{stats['diurnal_ratio']:.2f}x"),
        ("fig5_neg_week_fraction", us, f"{stats['neg_week_fraction']:.2f}"),
        ("fig2_3yr_growth", us, f"{stats['total_growth']:.1f}x"),
    ]


def bench_commitment_fig4(quick: bool = False, seed: int = 0) -> list[Row]:
    """Paper Fig 4: 9 commitment scenarios over two weeks, A=2.1, B=1."""
    f = dm.synth_demand(
        24 * 14, dm.DemandConfig(annual_growth=0.0, noise_sigma=0.005),
        key=jax.random.PRNGKey(seed + 1),
    )
    levels, costs, best = cm.scenario_costs(f, 9)
    us = _time(lambda x: cm.scenario_costs(x, 9)[1], f)
    exact = float(cm.optimal_commitment_quantile(f))
    brent = cm.optimal_commitment_brent(np.asarray(f))
    return [
        ("fig4_best_scenario_of_9", us, f"scenario {int(best)+1}"),
        ("fig4_exact_optimum_quantile", us,
         f"c*={exact:.1f} (q=A/(A+B)={2.1/3.1:.3f})"),
        ("fig4_brent_agreement", us,
         "|brent-exact| cost delta "
         f"{abs(float(cm.commitment_cost(f, brent)) - float(cm.commitment_cost(f, exact))):.2f}"),
    ]


def bench_sensitivity_table3(quick: bool = False, seed: int = 0) -> list[Row]:
    """Paper Table 3: cost delta per $1M when the commitment is computed
    from a trend-blind forecast instead of actuals, by trend x update freq."""
    rows: list[Row] = []
    base = dm.synth_demand(
        HOURS_PER_WEEK, dm.DemandConfig(annual_growth=0.0, noise_sigma=0.0)
    )
    t0 = time.perf_counter()
    for update_weeks in (1, 2) if quick else (1, 2, 4, 8):
        for trend in (0.10, 0.50, 1.00):
            hours = update_weeks * HOURS_PER_WEEK
            growth = (1.0 + trend) ** (
                jnp.arange(hours, dtype=jnp.float32) / (24 * 365)
            )
            actual = jnp.tile(base, update_weeks) * growth
            naive = jnp.tile(base, update_weeks)  # trend-blind forecast
            c_actual = cm.optimal_commitment_quantile(actual)
            c_naive = cm.optimal_commitment_quantile(naive)
            cost_actual = float(cm.commitment_cost(actual, c_actual))
            cost_naive = float(cm.commitment_cost(actual, c_naive))
            delta_per_m = (cost_naive - cost_actual) / cost_actual * 1e6
            rows.append((
                f"table3_u{update_weeks}w_trend{int(trend*100)}",
                0.0,
                f"${delta_per_m:.2f} per $1M",
            ))
    us = (time.perf_counter() - t0) / len(rows) * 1e6
    return [(n, us, d) for n, _, d in rows]


def bench_planner_fig8(quick: bool = False, seed: int = 0) -> list[Row]:
    """Paper Fig 8: 1-week vs 2-week forecast horizon commitment, evaluated
    over the 2-week window containing a holiday dip."""
    hist = dm.synth_demand(
        24 * 7 * (8 if quick else 20), key=jax.random.PRNGKey(seed + 3)
    )
    res = pl.plan_commitment(hist, num_horizons=4)
    base = dm.synth_demand(
        HOURS_PER_WEEK * 2, dm.DemandConfig(annual_growth=0.0,
                                            noise_sigma=0.0))
    dip = jnp.concatenate([
        jnp.ones(HOURS_PER_WEEK),
        jnp.full((HOURS_PER_WEEK,), 0.88),  # holiday week: -12% demand
    ])
    yhat = base * dip
    out = pl.compare_horizons(yhat, (1, 2))
    us = _time(lambda h: pl.plan_commitment(h, num_horizons=4).forecast, hist,
               iters=2, warmup=1)
    return [
        ("fig8_c_w1_level", us, f"{out[1]['level']:.1f}"),
        ("fig8_c_w2_level", us, f"{out[2]['level']:.1f}"),
        ("fig8_2wk_cheaper_by", us,
         f"{(out[1]['total_spend'] - out[2]['total_spend']) / out[1]['total_spend'] * 100:.2f}%"),
        ("alg1_cstar_min_over_horizons", us,
         f"{res.commitment:.1f} (binding horizon w={res.argmin_horizon + 1})"),
    ]


def bench_ladder_fig9(quick: bool = False, seed: int = 0) -> list[Row]:
    """Paper Fig 9: flat vs perfectly-laddered commitment over a 4-week
    window with a year-end demand drop (paper: ~1.1% savings)."""
    demand = np.asarray(dm.synth_demand(
        HOURS_PER_WEEK * 4,
        dm.DemandConfig(annual_growth=0.0, noise_sigma=0.0)))
    demand = demand.copy()
    demand[HOURS_PER_WEEK * 2: HOURS_PER_WEEK * 3] *= 0.92  # holiday week
    t0 = time.perf_counter()
    weekly = [
        float(cm.optimal_commitment_quantile(jnp.asarray(
            demand[w * HOURS_PER_WEEK:(w + 1) * HOURS_PER_WEEK])))
        for w in range(4)
    ]
    out = ld.ladder_vs_flat(demand, np.array(weekly))
    us = (time.perf_counter() - t0) * 1e6
    return [
        ("fig9_flat_vs_laddered_savings", us,
         f"{out['savings_frac'] * 100:.2f}% (paper ~1.1%)"),
    ]


def bench_timeshift_sec4(quick: bool = False, seed: int = 0) -> list[Row]:
    """Paper §4: unused-commitment trough supply and shiftable workloads."""
    f = np.asarray(dm.synth_demand(
        24 * 7 * (12 if quick else 52), key=jax.random.PRNGKey(seed + 4)
    ))
    c = float(cm.optimal_commitment_quantile(jnp.asarray(f)))
    stats = ts.shiftable_supply_stats(f, c)
    # schedule a 5%-of-total deferrable workload into the troughs
    total_work = f.sum() * 0.05
    n_jobs = 12 if quick else 52
    jobs = [
        ts.Job(arrival=int(h), work=float(total_work / n_jobs),
               deadline=int(h) + 24 * 7)
        for h in np.linspace(0, len(f) - 24 * 7 - 1, n_jobs)
    ]
    t0 = time.perf_counter()
    out = ts.schedule_jobs(f, c, jobs)
    us = (time.perf_counter() - t0) * 1e6
    saved_frac = out["on_demand_savings"] / max(out["on_demand_cost_naive"],
                                                1e-9)
    return [
        ("sec4_unused_commitment_frac", us,
         f"{stats['unused_frac'] * 100:.1f}% (paper 4.3%)"),
        ("sec4_weekend_trough_share", us,
         f"{stats['weekend_share'] * 100:.0f}%"),
        ("sec4_timeshift_od_cost_saved", us, f"{saved_frac * 100:.0f}%"),
    ]


def bench_freepool_fig12(quick: bool = False, seed: int = 0) -> list[Row]:
    """Paper Fig 12: static vs predicted free pool on held-out demand."""
    hist = dm.synth_demand(24 * 7 * 8, key=jax.random.PRNGKey(seed + 5))
    fut = dm.synth_demand(24 * 7 * 9, key=jax.random.PRNGKey(seed + 5))[-24 * 7:]
    cfg = fp.FreePoolConfig(p_over=1.0, p_under=10.0, lead_time=1)
    us = _time(
        lambda h: fp.predicted_pool(h, 24 * 7, cfg), hist, iters=3, warmup=1
    )
    out = fp.compare_static_vs_predicted(hist, fut, cfg)
    return [
        ("fig12_static_pool_cost", us, f"{out['static_cost']:.0f}"),
        ("fig12_predicted_pool_cost", us, f"{out['predicted_cost']:.0f}"),
        ("fig12_cost_reduction", us,
         f"{(1 - out['predicted_cost'] / out['static_cost']) * 100:.0f}%"),
        ("fig12_under_minutes_ratio", us,
         f"{out['under_minutes_predicted'] / max(out['under_minutes_static'], 1e-9):.2f}"),
    ]


def bench_forecast_quality(quick: bool = False, seed: int = 0) -> list[Row]:
    """§3.3.3: forecaster asymmetric-error metric on held-out data."""
    n = 12 if quick else 30
    full = dm.synth_demand(24 * 7 * n, key=jax.random.PRNGKey(seed + 6))
    hist, fut = full[: 24 * 7 * (n - 4)], full[24 * 7 * (n - 4):]
    model = fc.fit(hist)
    us = _time(lambda h: fc._fit(h, fc.ForecastConfig(),
                                 float(h.shape[0] - 1)), hist,
               iters=3, warmup=1)
    yhat = fc.forecast_horizon(model, hist.shape[0], fut.shape[0])
    wmape = float(fc.weighted_mape(fut, yhat))
    mape = float(jnp.abs((fut - yhat) / fut).mean())
    return [
        ("forecast_holdout_mape_4wk", us, f"{mape * 100:.1f}%"),
        ("forecast_holdout_wmape_asym", us, f"{wmape * 100:.1f}%"),
    ]


def bench_portfolio_table2(quick: bool = False, seed: int = 0) -> list[Row]:
    """Beyond-paper: Table-2 SKU portfolio vs the single averaged commitment
    level, batched over a fleet of pools.  The exact stacked-quantile solver
    is one sort + K gathers per pool; the grid solver is timed on its jnp
    reference path (the Pallas 2-D sweep behind ``use_kernel=True`` is
    benchmarked in kernel_benches and validated in tests)."""
    n_pools, n_weeks = (4, 8) if quick else (16, 52)
    pools = jnp.stack([
        dm.synth_demand(24 * 7 * n_weeks, key=jax.random.PRNGKey(seed + i))
        for i in range(n_pools)
    ])
    opts = pt.options_from_pricing()
    al, be = pt.option_lines(opts, term_weighting=1.0)
    od = 2.1

    exact = jax.jit(
        lambda f: pt.optimal_portfolio_stack(f, al, be, od_rate=od).cost
    )
    us_exact = _time(exact, pools, iters=3, warmup=1)
    plan = pt.optimal_portfolio_stack(pools, al, be, od_rate=od)

    grid_fn = jax.jit(
        lambda f: pt.optimal_portfolio_grid(f, al, be, od_rate=od).cost
    )
    us_grid = _time(grid_fn, pools, iters=3, warmup=1)

    # Real-dollar comparison (both sides billed in-window at actual rates;
    # the term-weighted *planning* objective is not a billing statement, so
    # the savings headline uses the in-window-optimal tw=0 stack):
    al0, be0 = pt.option_lines(opts, term_weighting=0.0)
    plan0 = pt.optimal_portfolio_stack(pools, al0, be0, od_rate=od)
    port = np.asarray([
        pt.portfolio_spend(
            pools[i], np.asarray(plan0.widths)[i], opts, od_rate=od
        ).total
        for i in range(pools.shape[0])
    ])
    c_single = cm.optimal_commitment_quantile(pools, od - 1.0, 1.0)
    base = np.asarray(
        cm.total_spend(pools, c_single, od)  # rate-1.0 commitment + od over
    )
    saving = float((1.0 - port / base).mean())
    n_opts = int((jnp.asarray(plan.widths) > 0).any(0).sum())
    return [
        ("portfolio_exact_16pools_1y", us_exact,
         f"{n_opts} SKUs on envelope"),
        ("portfolio_grid_16pools_1y", us_grid,
         f"mean saving vs single-level {saving * 100:.1f}%"),
    ]


def bench_tournament(quick: bool = False, seed: int = 0) -> list[Row]:
    """Beyond-paper: the policy tournament (competitive ratio vs per-path
    hindsight across the §2 scenario taxonomy).  Times one compiled
    vmapped replay program per policy; the derived columns are the mean
    competitive ratios the acceptance tests pin (hedging bounds on
    steady, rolling's margin on declining)."""
    from repro.core import tournament as tn

    kw = dict(
        policies=("rolling_portfolio", "deterministic_hedge",
                  "randomized_hedge"),
        families=("steady", "declining"),
        num_pools=2 if quick else 3,
        num_weeks=24 if quick else 48,
        num_seeds=2 if quick else 8,
        base_seed=seed,
        start_weeks=12 if quick else 20,
        cadence_weeks=2,
        horizon_weeks=4 if quick else 8,
    )
    t0 = time.perf_counter()
    rep = tn.run_tournament(**kw)
    rep.elapsed_s = time.perf_counter() - t0
    us = rep.elapsed_s * 1e6
    rows: list[Row] = []
    for pol_name, short in (
        ("rolling_portfolio", "rolling"),
        ("deterministic_hedge", "det_hedge"),
        ("randomized_hedge", "rand_hedge"),
    ):
        for fam in rep.families:
            st = rep.family_stats(pol_name, fam)
            rows.append((
                f"tournament_{short}_{fam}", us,
                f"CR mean {st['cr_mean']:.3f} max {st['cr_max']:.3f}",
            ))
    return rows


def bench_fleet_scale(quick: bool = False, seed: int = 0) -> list[Row]:
    """Beyond-paper: the (N scenarios x P pools) batched rolling replay's
    fleet-scale curve.  One batched ``replan_fleet_pools(scenarios=N)``
    program per P, plus the loop-over-scenarios oracle (N unbatched
    replays) at the middle size — the batched scan's speedup over it is
    the headline.  Gate: scenario 0 of every batched run is BIT-IDENTICAL
    to the unbatched replay at the same P (the flattening contract).

    ``--quick`` (the CI bench-smoke job) runs P in {16, 128} on a short
    trace; the full curve — P in {16, 128, 1024}, N=32, a 3-year weekly
    replan — sits behind ``--filter fleet_scale`` without ``--quick``."""
    import dataclasses

    from repro.core import replan as rp
    from repro.data import scenarios as sc
    from repro.data import traces

    if quick:
        p_sizes, n_scen, hours = (16, 128), 4, 24 * 7 * 20
        kw = dict(cadence_weeks=2, start_weeks=6, horizon_weeks=4,
                  compare=False)
    else:
        p_sizes, n_scen, hours = (16, 128, 1024), 32, 24 * 7 * 156
        kw = dict(cadence_weeks=1, start_weeks=26, horizon_weeks=8,
                  compare=False)
    oracle_p = 128
    cfg = sc.ScenarioConfig(n_scenarios=n_scen, family="growth", seed=seed)

    rows: list[Row] = []
    for p in p_sizes:
        pools = traces.synthetic_pool_set(
            num_pools=p, num_hours=hours, seed=seed
        )
        t0 = time.perf_counter()
        rep = rp.replan_fleet_pools(pools, scenarios=cfg, **kw)
        us_batched = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        base = rp.replan_fleet_pools(pools, **kw)
        us_single = (time.perf_counter() - t0) * 1e6
        # The flattening contract: scenario 0 IS the realized replay.
        np.testing.assert_array_equal(rep.targets[:, 0], base.targets)
        np.testing.assert_array_equal(
            float(rep.scenario_cost[0]), base.total_cost
        )
        derived = (
            f"P={p} N={n_scen} {hours // HOURS_PER_WEEK}wk, "
            f"{us_batched / us_single:.1f}x one unbatched replay, "
            f"scenario0 bit-identical"
        )
        rows.append((f"fleet_scale_p{p}", us_batched, derived))
        if p == oracle_p:
            # Loop-over-scenarios oracle: N unbatched replays over the
            # perturbed paths — the program the batched scan replaces.
            batch = sc.scenario_batch(pools.demand, cfg)
            t0 = time.perf_counter()
            per_scen = []
            for s in range(n_scen):
                srep = rp.replan_fleet_pools(
                    dataclasses.replace(pools, demand=batch[s]), **kw
                )
                per_scen.append(srep.total_cost)
            us_loop = (time.perf_counter() - t0) * 1e6
            np.testing.assert_allclose(
                np.asarray(per_scen), rep.scenario_cost, rtol=1e-5
            )
            speedup = us_loop / us_batched
            rows.append((
                f"fleet_scale_p{oracle_p}_vs_loop", us_loop,
                f"batched scan {speedup:.1f}x loop-over-{n_scen}-scenarios",
            ))
    return rows


def bench_breach_cadence(quick: bool = False, seed: int = 0) -> list[Row]:
    """Beyond-paper: breach-triggered replan cadence vs the weekly
    baseline on a steady fleet.  ``cadence="breach"`` re-solves only in
    weeks where realized demand exits the previous decision's forecast
    band, so most weeks carry the standing plan — the headline is the
    decision-week reduction at a near-zero realized-cost delta.  Gates:
    the weekly spelling stays the default program, and breach must
    actually skip decisions (strictly fewer decision weeks than weekly).

    ``--quick`` (the CI bench-smoke job) runs the short fleet; the full
    52-week acceptance configuration sits behind ``--filter breach``
    without ``--quick``."""
    from repro.core import replan as rp
    from repro.data import scenarios as sc

    if quick:
        num_weeks, start_weeks = 26, 12
    else:
        num_weeks, start_weeks = 52, 24
    pools = sc.scenario_pool_set(
        "steady", num_pools=4, num_weeks=num_weeks, seed=seed
    )
    kw = dict(cadence_weeks=1, start_weeks=start_weeks, horizon_weeks=4,
              compare=False)

    t0 = time.perf_counter()
    weekly = rp.replan_fleet_pools(pools, **kw)
    us_weekly = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    breach = rp.replan_fleet_pools(pools, cadence="breach", **kw)
    us_breach = (time.perf_counter() - t0) * 1e6

    n_weekly = int(np.asarray(weekly.decision_mask).sum())
    n_breach = int(np.asarray(breach.decision_mask).sum())
    assert n_breach < n_weekly, (
        f"breach cadence skipped nothing: {n_breach} vs {n_weekly}"
    )
    rel = abs(breach.total_cost - weekly.total_cost) / weekly.total_cost
    return [
        ("breach_cadence_weekly", us_weekly,
         f"{n_weekly} decision weeks ({num_weeks}wk steady fleet)"),
        ("breach_cadence_breach", us_breach,
         f"{n_breach} decision weeks "
         f"({1 - n_breach / n_weekly:.0%} fewer), "
         f"cost delta {rel:.2%}"),
    ]


ALL_PAPER_BENCHES = [
    bench_demand_characterization,
    bench_commitment_fig4,
    bench_sensitivity_table3,
    bench_planner_fig8,
    bench_ladder_fig9,
    bench_timeshift_sec4,
    bench_freepool_fig12,
    bench_forecast_quality,
    bench_portfolio_table2,
    bench_tournament,
    bench_fleet_scale,
    bench_breach_cadence,
]
