"""Planted R5 violation: a `cadence=` replan mode whose disabled
spelling is the string "weekly" (not None/False), with no disabled-path
golden test anywhere under tests/."""


def replay(demand, cadence="weekly"):
    if cadence == "weekly":
        return demand
    return demand[::2]
