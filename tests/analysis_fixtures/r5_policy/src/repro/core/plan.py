"""Planted R5 violation: optional `policy=` kwarg with no disabled-path
golden test anywhere under tests/."""


def replay(demand, policy=None):
    if policy is None:
        return demand
    return policy(demand)
