"""Planted R1 violation: a scan body concretizes a tracer with float()."""

import jax
import jax.numpy as jnp


def body(carry, x):
    carry = carry + float(x)  # planted: float() on a tracer
    return carry, x


def run(xs):
    return jax.lax.scan(body, jnp.float32(0.0), xs)
