"""Planted R5 violation: an optional `telemetry=` kwarg with no
disabled-path golden test anywhere under tests/."""


def replay(demand, telemetry=None):
    if telemetry is None:
        return demand
    return demand, {"ledger": list(demand)}
