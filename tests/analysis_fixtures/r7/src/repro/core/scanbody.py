"""Planted R7 violation: a print() inside a scan body — it fires once at
trace time, not per step, so it looks like telemetry but measures nothing."""

import jax
import jax.numpy as jnp


def body(carry, x):
    print("step", carry)  # planted: trace-time side channel
    return carry + x, x


def run(xs):
    return jax.lax.scan(body, jnp.float32(0.0), xs)
