"""Planted R7 violation: an ad-hoc wall-clock pair outside
``repro.obs.spans`` (and outside R2's determinism scopes)."""

import time


def timed(fn, *args):
    t0 = time.perf_counter()  # planted: use repro.obs.spans.SpanRecorder
    out = fn(*args)
    return out, time.perf_counter() - t0
