"""Module for the r6 fixture — exports `cumsum` only."""


def cumsum(xs):
    total = 0.0
    out = []
    for x in xs:
        total += x
        out.append(total)
    return total, out
