"""Planted R5 violations: an optional `scenarios=` kwarg and a
`PlanRequest` surface class, with no golden test anywhere under tests/."""


class PlanRequest:
    def __init__(self, demand, scenarios=None):
        self.demand = demand
        self.scenarios = scenarios


def replay(demand, scenarios=None):
    if scenarios is None:
        return demand
    return [demand for _ in range(scenarios)]
