"""Clean module: deterministic, trace-pure — no rule should fire here."""

import jax
import jax.numpy as jnp


def body(carry, x):
    return carry + x, x


def cumsum(xs):
    final, ys = jax.lax.scan(body, jnp.float32(0.0), xs)
    return final, ys
