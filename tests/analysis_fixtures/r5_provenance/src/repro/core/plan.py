"""Planted R5 violation: a `provenance=` telemetry knob shipped as an
annotated dataclass-field default, with no disabled-path golden test
anywhere under tests/."""


class TelemetryConfig:
    ledger: bool = True
    provenance: bool = False


def replay(demand, config=None):
    if config is None or not config.provenance:
        return demand
    return demand, {"decisions": list(demand)}
