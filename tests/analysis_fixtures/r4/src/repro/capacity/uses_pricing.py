"""Planted R4 violation: consumes pricing tables without validating them."""

from repro.capacity import pricing


def premium():
    return pricing.ON_DEMAND_PREMIUM  # planted: no validate_tables() call
