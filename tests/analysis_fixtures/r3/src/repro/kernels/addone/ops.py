"""Ops entry point for the r3 fixture kernel."""


def addone(x):
    return x + 1.0
