"""Kernel file for the r3 fixture (the triad is missing ref.py)."""


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] + 1.0
