import numpy as np

from repro.kernels.addone.ops import addone


def test_addone_matches_golden():
    x = np.zeros(4, np.float32)
    np.testing.assert_allclose(addone(x), x + 1.0)
