"""Planted R5 violation: optional `spot=` kwarg with no disabled-path
golden test anywhere under tests/."""


def plan(demand, spot=None):
    if spot is None:
        return demand
    return demand + spot
