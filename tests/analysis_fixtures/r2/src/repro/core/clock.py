"""Planted R2 violation: a wall-clock read in deterministic scope."""

import time


def stamp():
    return time.time()  # planted: nondeterministic clock in repro/core/
