"""Planted R5 violation: a `calibration=` telemetry knob shipped as an
annotated dataclass-field default, with no disabled-path golden test
anywhere under tests/."""


class TelemetryConfig:
    ledger: bool = True
    calibration: bool = False


def replay(demand, config=None):
    if config is None or not config.calibration:
        return demand
    return demand, {"levels": sorted(demand)}
