"""Serving runtime: continuous batching engine + free-pool autoscaler."""

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import demand as dm
from repro.models.model import build
from repro.serve.autoscaler import AutoscalerConfig, FreePoolAutoscaler
from repro.serve.engine import Request, ServeEngine


def setup_engine(num_slots=3, cache_len=48):
    model = build(configs.reduced("stablelm-1.6b"))
    params = model.init(jax.random.PRNGKey(0))
    return model, params, ServeEngine(
        model, num_slots=num_slots, cache_len=cache_len
    )


class TestServeEngine:
    def test_batched_requests_complete(self):
        model, params, eng = setup_engine()
        rng = np.random.default_rng(0)
        reqs = [
            Request(rid=i,
                    prompt=rng.integers(0, 256, size=(5 + i)).astype(np.int32),
                    max_new_tokens=4)
            for i in range(3)
        ]
        for r in reqs:
            assert eng.try_admit(params, r)
        assert eng.active_slots == 3
        for _ in range(10):
            eng.tick(params)
            if all(r.done for r in reqs):
                break
        assert all(r.done for r in reqs)
        for r in reqs:
            assert len(r.generated) >= r.max_new_tokens
        assert eng.active_slots == 0

    def test_engine_matches_sequential_decode(self):
        """Engine greedy decode == manual prefill+decode for one request."""
        model, params, eng = setup_engine(num_slots=2)
        rng = np.random.default_rng(1)
        prompt = rng.integers(0, 256, size=6).astype(np.int32)
        req = Request(rid=0, prompt=prompt, max_new_tokens=3)
        assert eng.try_admit(params, req)
        while not req.done:
            eng.tick(params)

        # manual reference
        cache = model.init_cache(1, 48)
        logits, cache = model.apply(
            params, tokens=jnp.asarray(prompt)[None], mode="prefill",
            cache=cache, pos=0,
        )
        toks = [int(jnp.argmax(logits[0, -1]))]
        pos = len(prompt)
        for _ in range(2):
            logits, cache = model.apply(
                params, tokens=jnp.asarray([[toks[-1]]], jnp.int32),
                mode="decode", cache=cache, pos=jnp.int32(pos),
            )
            toks.append(int(jnp.argmax(logits[0, 0])))
            pos += 1
        assert req.generated[:3] == toks

    def test_slot_reuse_after_completion(self):
        model, params, eng = setup_engine(num_slots=1)
        rng = np.random.default_rng(2)
        r1 = Request(0, rng.integers(0, 256, 4).astype(np.int32), 2)
        r2 = Request(1, rng.integers(0, 256, 4).astype(np.int32), 2)
        assert eng.try_admit(params, r1)
        assert not eng.try_admit(params, r2)  # pool full
        while not r1.done:
            eng.tick(params)
        assert eng.try_admit(params, r2)      # slot freed


class TestAutoscaler:
    def _demand(self, n_hist=24 * 21, n_fut=24 * 2):
        f = dm.synth_demand(
            n_hist + n_fut,
            dm.DemandConfig(base_level=20.0, annual_growth=0.2),
            key=jax.random.PRNGKey(0),
        )
        f = np.asarray(f)
        return f[:n_hist], f[n_hist:]

    def test_predicted_beats_static_minimum(self):
        hist, fut = self._demand()
        pred = FreePoolAutoscaler(AutoscalerConfig())
        pred.run(hist, fut)
        static_low = FreePoolAutoscaler(AutoscalerConfig())
        static_low.run(hist, fut, static_size=float(np.percentile(hist, 50)))
        assert pred.stats.slo_misses < static_low.stats.slo_misses

    def test_predicted_cheaper_than_static_max(self):
        hist, fut = self._demand()
        pred = FreePoolAutoscaler(AutoscalerConfig())
        pred.run(hist, fut)
        static_hi = FreePoolAutoscaler(AutoscalerConfig())
        static_hi.run(hist, fut, static_size=float(hist.max() * 1.2))
        assert pred.stats.replica_ticks < static_hi.stats.replica_ticks

    def test_provisioning_latency_respected(self):
        auto = FreePoolAutoscaler(AutoscalerConfig(provision_latency=3))
        auto.step(target=5.0, demand=0.0)
        assert auto.warm == 0          # cold starts take 3 ticks
        auto.step(target=5.0, demand=5.0)
        assert auto.stats.slo_misses == 5  # demand while cold is missed
        auto.step(target=5.0, demand=0.0)
        auto.step(target=5.0, demand=5.0)
        assert auto.warm == 5          # now warm
        assert auto.stats.slo_misses == 5  # warm demand served
