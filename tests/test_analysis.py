"""Tests for ``repro.analysis`` — the trace-safety / determinism /
kernel-contract static analyzer.

Three layers:

* **fixtures** — each miniature repo under ``tests/analysis_fixtures/``
  plants exactly one violation; the matching rule (and only that rule)
  must fire, and the ``clean`` fixture must pass every rule.
* **baseline mechanics** — justified suppressions hide a finding, empty
  justifications are a config error (exit 2), stale keys are reported.
* **the repo itself** — ``run_analysis`` over the real repo with the
  shipped ``baseline.json`` must come back clean, and the CLI must exit 1
  when a violation is injected into a scratch tree (the contract the CI
  lint job relies on).
"""

from __future__ import annotations

import json
import os
import shutil
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import run_analysis
from repro.analysis.engine import load_baseline

FIXTURES = Path(__file__).resolve().parent / "analysis_fixtures"
REPO_ROOT = Path(__file__).resolve().parents[1]

#: fixture dir -> the one rule its planted violation must trigger.
CASES = {
    "r1": "R1",
    "r2": "R2",
    "r3": "R3",
    "r4": "R4",
    "r5": "R5",
    "r5_cadence": "R5",
    "r5_calibration": "R5",
    "r5_policy": "R5",
    "r5_provenance": "R5",
    "r5_scenarios": "R5",
    "r5_telemetry": "R5",
    "r6": "R6",
    "r7": "R7",
}


class TestFixtures:
    def test_clean_fixture_has_no_findings(self):
        report = run_analysis(FIXTURES / "clean")
        assert report.ok
        assert report.findings == []

    @pytest.mark.parametrize("case", sorted(CASES))
    def test_planted_violation_fires_exactly_its_rule(self, case):
        report = run_analysis(FIXTURES / case)
        assert report.unsuppressed, f"fixture {case}: expected a finding"
        fired = {f.rule for f in report.unsuppressed}
        assert fired == {CASES[case]}, (
            f"fixture {case}: expected only {CASES[case]}, got "
            f"{sorted(fired)}: "
            + "; ".join(f.render() for f in report.unsuppressed)
        )

    @pytest.mark.parametrize("case", sorted(CASES))
    def test_finding_keys_are_line_free(self, case):
        for f in run_analysis(FIXTURES / case).unsuppressed:
            assert f":{f.line}" not in f.key or f.line == 0, (
                f"{f.key}: suppression keys must survive line shifts"
            )


class TestBaseline:
    def _r2_key(self) -> str:
        (finding,) = run_analysis(FIXTURES / "r2").unsuppressed
        return finding.key

    def test_justified_suppression_hides_finding(self, tmp_path):
        bl = tmp_path / "baseline.json"
        bl.write_text(json.dumps({
            "version": 1,
            "suppressions": [
                {"key": self._r2_key(),
                 "justification": "fixture: accepted for the test"},
            ],
        }))
        report = run_analysis(FIXTURES / "r2", baseline_path=bl)
        assert report.ok
        assert len(report.suppressed) == 1
        assert report.unsuppressed == []

    def test_empty_justification_is_config_error(self, tmp_path):
        bl = tmp_path / "baseline.json"
        bl.write_text(json.dumps({
            "version": 1,
            "suppressions": [{"key": self._r2_key(), "justification": ""}],
        }))
        report = run_analysis(FIXTURES / "r2", baseline_path=bl)
        assert report.errors
        assert not report.ok

    def test_stale_suppression_is_reported(self, tmp_path):
        bl = tmp_path / "baseline.json"
        bl.write_text(json.dumps({
            "version": 1,
            "suppressions": [
                {"key": "R2:nonexistent.py:whatever",
                 "justification": "left over from a deleted module"},
            ],
        }))
        report = run_analysis(FIXTURES / "clean", baseline_path=bl)
        assert report.stale_suppressions == ["R2:nonexistent.py:whatever"]
        assert report.ok  # stale entries warn, they don't fail the run

    def test_malformed_baseline_is_config_error(self, tmp_path):
        bl = tmp_path / "baseline.json"
        bl.write_text("{not json")
        _, errors = load_baseline(bl)
        assert errors


class TestRealRepo:
    def test_repo_lints_clean_with_shipped_baseline(self):
        report = run_analysis(REPO_ROOT)
        assert report.ok, "repo must lint clean:\n" + "\n".join(
            f.render() for f in report.unsuppressed
        ) + "\n".join(report.errors)

    def test_shipped_baseline_entries_are_justified(self):
        suppressions, errors = load_baseline(REPO_ROOT / "baseline.json")
        assert errors == []
        assert all(j.strip() for j in suppressions.values())


def _run_cli(root: Path, *extra: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO_ROOT / "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--root", str(root), *extra],
        capture_output=True, text=True, env=env,
    )


class TestCLI:
    def test_exit_zero_on_clean_tree(self):
        proc = _run_cli(FIXTURES / "clean")
        assert proc.returncode == 0, proc.stdout + proc.stderr

    def test_exit_one_on_injected_violation(self, tmp_path):
        # Scratch copy of the clean tree with an R2 violation injected —
        # exactly what the CI lint job must catch.
        scratch = tmp_path / "scratch"
        shutil.copytree(FIXTURES / "clean", scratch)
        bad = scratch / "src" / "repro" / "core" / "leak.py"
        bad.write_text(
            "import time\n\n\ndef now():\n    return time.time()\n"
        )
        proc = _run_cli(scratch, "--json")
        assert proc.returncode == 1, proc.stdout + proc.stderr
        payload = json.loads(proc.stdout)
        assert payload["ok"] is False
        assert {f["rule"] for f in payload["findings"]} == {"R2"}

    def test_exit_two_on_unjustified_baseline(self, tmp_path):
        scratch = tmp_path / "scratch"
        shutil.copytree(FIXTURES / "r2", scratch)
        (scratch / "baseline.json").write_text(json.dumps({
            "version": 1,
            "suppressions": [{"key": "R2:x", "justification": ""}],
        }))
        proc = _run_cli(scratch)
        assert proc.returncode == 2, proc.stdout + proc.stderr

    def test_single_rule_selection(self):
        # r2 fixture analyzed under R1 only: nothing to report.
        proc = _run_cli(FIXTURES / "r2", "--rule", "R1")
        assert proc.returncode == 0, proc.stdout + proc.stderr
