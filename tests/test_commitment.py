"""Tests for §3 commitment optimization: solver agreement, convexity,
paper-number reproduction."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import commitment as cm
from repro.core import demand as dm

jax.config.update("jax_enable_x64", False)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # degrade to the deterministic tests only
    HAVE_HYPOTHESIS = False


def _trace(n=24 * 14, key=0):
    return dm.synth_demand(n, key=jax.random.PRNGKey(key))


class TestCostFunction:
    def test_cost_at_extremes(self):
        f = _trace()
        # c = max(f): no on-demand overage term
        c_max = float(f.max())
        cost = float(cm.commitment_cost(f, c_max))
        only_under = float(jnp.maximum(c_max - f, 0.0).sum())
        assert cost == pytest.approx(only_under, rel=1e-5)
        # c = min(f): no unused term
        c_min = float(f.min())
        cost = float(cm.commitment_cost(f, c_min))
        only_over = 2.1 * float(jnp.maximum(f - c_min, 0.0).sum())
        assert cost == pytest.approx(only_over, rel=1e-5)

    def test_cost_curve_matches_pointwise(self):
        f = _trace()
        cs = jnp.linspace(f.min(), f.max(), 17)
        curve = cm.cost_curve(f, cs)
        pointwise = jnp.stack([cm.commitment_cost(f, c) for c in cs])
        np.testing.assert_allclose(curve, pointwise, rtol=1e-5)

    def test_convexity_on_grid(self):
        f = _trace()
        cs = jnp.linspace(f.min(), f.max(), 101)
        curve = np.asarray(cm.cost_curve(f, cs))
        d2 = np.diff(curve, 2)
        assert (d2 >= -1e-2 * np.abs(curve).max()).all(), "C(c) must be convex"


class TestSolverAgreement:
    def test_quantile_equals_brent(self):
        f = _trace()
        c_q = float(cm.optimal_commitment_quantile(f))
        c_b = cm.optimal_commitment_brent(np.asarray(f))
        # Equal cost (minimizer may be a flat segment on PWL objective)
        cost_q = float(cm.commitment_cost(f, c_q))
        cost_b = float(cm.commitment_cost(f, c_b))
        assert cost_q <= cost_b * (1 + 1e-4)

    def test_golden_matches_quantile_cost(self):
        f = _trace()
        c_g = float(cm.optimal_commitment_golden(f))
        c_q = float(cm.optimal_commitment_quantile(f))
        cost_g = float(cm.commitment_cost(f, c_g))
        cost_q = float(cm.commitment_cost(f, c_q))
        assert cost_g == pytest.approx(cost_q, rel=1e-3)

    def test_vmap_golden(self):
        fs = jnp.stack([_trace(key=k) for k in range(4)])
        cs = jax.vmap(cm.optimal_commitment_golden)(fs)
        for i in range(4):
            c_q = float(cm.optimal_commitment_quantile(fs[i]))
            cq_cost = float(cm.commitment_cost(fs[i], c_q))
            cg_cost = float(cm.commitment_cost(fs[i], cs[i]))
            assert cg_cost == pytest.approx(cq_cost, rel=1e-3)


if HAVE_HYPOTHESIS:
    class TestSolverProperties:
        @settings(max_examples=25, deadline=None)
        @given(
            seed=st.integers(0, 2**31 - 1),
            a=st.floats(1.1, 5.0),
            b=st.floats(0.2, 2.0),
            n=st.integers(24, 24 * 21),
        )
        def test_property_quantile_is_global_min(self, seed, a, b, n):
            """Property: the quantile solution is never beaten by any grid
            point."""
            rng = np.random.default_rng(seed)
            f = jnp.asarray(rng.gamma(2.0, 50.0, size=n).astype(np.float32))
            c_q = cm.optimal_commitment_quantile(f, a, b)
            cost_q = float(cm.commitment_cost(f, c_q, a, b))
            grid = jnp.linspace(f.min(), f.max(), 257)
            grid_costs = cm.cost_curve(f, grid, a, b)
            assert cost_q <= float(grid_costs.min()) * (1 + 1e-4)
else:
    class TestSolverProperties:
        def test_property_quantile_is_global_min(self):
            pytest.importorskip("hypothesis")


class TestPaperNumbers:
    def test_fig4_interior_optimum(self):
        """Fig 4: with A=2.1, B=1 the optimal scenario is interior (paper:
        scenario 5 of 9), not min- or max-commitment."""
        f = _trace(24 * 14)
        levels, costs, best = cm.scenario_costs(f, 9)
        assert 0 < int(best) < 8
        # And the exact optimum sits at the 2.1/3.1 ~= 67.7th percentile.
        c_q = float(cm.optimal_commitment_quantile(f))
        q_rank = float((f < c_q).mean())
        assert 0.55 < q_rank < 0.8

    def test_unused_commitment_fraction_magnitude(self):
        """§4: optimal commitment leaves a small single-digit-% unused slice
        (paper: 4.3% over 3 years)."""
        f = dm.synth_demand(24 * 7 * 52, key=jax.random.PRNGKey(1))
        c = cm.optimal_commitment_quantile(f)
        frac = float(cm.unused_commitment_fraction(f, c))
        assert 0.005 < frac < 0.15

    def test_on_demand_premium_constant(self):
        assert cm.DEFAULT_A == pytest.approx(2.1)


class TestDemandCalibration:
    def test_paper_statistics(self):
        """§2.2/§3.3: generator reproduces the published dataset statistics."""
        f = dm.synth_demand(24 * 365 * 3, key=jax.random.PRNGKey(7))
        stats = dm.characterize(np.asarray(f))
        assert stats["lag7_daily_autocorr"] > 0.95
        assert 1.2 < stats["weekly_ratio"] < 1.6
        assert 1.2 < stats["diurnal_ratio"] < 1.6
        assert 0.4 < stats["annual_growth"] < 0.8
        assert 3.0 < stats["total_growth"] < 5.0  # paper: 3.9x over 3y

    def test_negative_weeks_exist_despite_growth(self):
        """Fig 5: despite 58%/yr growth, a meaningful share of weeks shrink."""
        f = dm.synth_demand(24 * 365 * 3, key=jax.random.PRNGKey(3))
        wow = np.asarray(dm.week_over_week_growth(f))
        assert (wow < 0).mean() > 0.1

    def test_holiday_drop(self):
        f = dm.synth_demand(24 * 365, key=None)
        day = np.asarray(dm.hourly_to_daily(f))
        holiday = day[357:364].mean()
        before = day[343:357].mean()
        assert holiday < before * 0.97

    def test_efficiency_events_reduce_demand(self):
        f = dm.synth_demand(24 * 30)
        f2 = dm.apply_efficiency_events(f, [24 * 10], [0.25])
        np.testing.assert_allclose(f2[: 24 * 10], f[: 24 * 10], rtol=1e-6)
        np.testing.assert_allclose(
            f2[24 * 10 :], f[24 * 10 :] / 1.25, rtol=1e-6
        )
