"""Training runtime tests: loss descent, determinism, optimizer, data
pipeline, straggler watchdog."""

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data.pipeline import DataConfig, PrefetchingLoader, TokenPipeline
from repro.models.model import build
from repro.train.optimizer import AdamWConfig, global_norm
from repro.train.step import build_train_step, init_train_state
from repro.train.trainer import StragglerWatchdog, Trainer, TrainerConfig


def tiny_model():
    return build(configs.reduced("stablelm-1.6b"))


def tiny_data(model, batch=4, seq=16):
    return TokenPipeline(DataConfig(
        vocab_size=model.cfg.vocab_size, seq_len=seq, global_batch=batch,
    ))


class TestTrainStep:
    def test_loss_descends(self, tmp_path):
        model = tiny_model()
        trainer = Trainer(
            model, tiny_data(model),
            TrainerConfig(total_steps=30, ckpt_every=100,
                          opt=AdamWConfig(lr=1e-2, warmup_steps=5)),
            str(tmp_path / "ckpt"),
        )
        trainer.init_or_restore()
        losses = trainer.fit()
        first = np.mean(losses[:5])
        last = np.mean(losses[-5:])
        assert last < first * 0.9, f"no descent: {first} -> {last}"

    def test_step_determinism(self):
        model = tiny_model()
        step_fn = jax.jit(build_train_step(model, AdamWConfig(lr=1e-3)))
        data = tiny_data(model)
        batch = jax.tree.map(jnp.asarray, data.next_batch())
        out = []
        for _ in range(2):
            params, opt = init_train_state(model, jax.random.PRNGKey(0))
            loss, params, opt = step_fn(params, opt, batch)
            out.append((float(loss), params))
        assert out[0][0] == out[1][0]
        for a, b in zip(jax.tree.leaves(out[0][1]), jax.tree.leaves(out[1][1])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_grad_clip_bounds_update(self):
        model = tiny_model()
        params, opt = init_train_state(model, jax.random.PRNGKey(0))
        from repro.train.optimizer import adamw_update
        huge = jax.tree.map(
            lambda p: jnp.full(p.shape, 1e6, jnp.float32), params
        )
        cfg = AdamWConfig(lr=1e-3, grad_clip=1.0, warmup_steps=1)
        new_params, _ = adamw_update(huge, opt, cfg)
        delta = global_norm(jax.tree.map(
            lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
            new_params, params,
        ))
        # update magnitude bounded by lr * O(1) per weight even with 1e6 grads
        assert float(delta) < 1.0


class TestDataPipeline:
    def test_determinism_and_skip(self):
        model = tiny_model()
        p1 = tiny_data(model)
        batches = [p1.next_batch() for _ in range(5)]
        p2 = tiny_data(model)
        p2.skip_to(3)
        b3 = p2.next_batch()
        np.testing.assert_array_equal(b3["tokens"], batches[3]["tokens"])

    def test_shards_disjoint(self):
        model = tiny_model()
        cfg = DataConfig(vocab_size=512, seq_len=16, global_batch=4,
                         num_shards=2, shard_id=0)
        a = TokenPipeline(cfg).next_batch()
        b = TokenPipeline(
            DataConfig(vocab_size=512, seq_len=16, global_batch=4,
                       num_shards=2, shard_id=1)
        ).next_batch()
        assert a["tokens"].shape == (2, 16)
        assert not np.array_equal(a["tokens"], b["tokens"])

    def test_prefetch_matches_sync(self):
        model = tiny_model()
        sync = tiny_data(model)
        pre = PrefetchingLoader(tiny_data(model), depth=2)
        try:
            for _ in range(4):
                np.testing.assert_array_equal(
                    pre.next_batch()["tokens"], sync.next_batch()["tokens"]
                )
        finally:
            pre.close()

    def test_labels_are_shifted_tokens(self):
        model = tiny_model()
        b = tiny_data(model).next_batch()
        assert b["tokens"].shape == b["labels"].shape


class TestStragglerWatchdog:
    def test_flags_slow_steps(self):
        wd = StragglerWatchdog(factor=3.0, ema=0.9)
        hits = []
        for i, dt in enumerate([1.0, 1.1, 0.9, 1.0, 5.0, 1.0, 1.05]):
            wd.observe(i, dt, mitigate=lambda: hits.append(i))
        assert wd.flagged_steps == [4]
        assert wd.mitigations == 1
        assert hits == [4]

    def test_slow_steps_do_not_poison_ema(self):
        wd = StragglerWatchdog(factor=3.0, ema=0.5)
        for i, dt in enumerate([1.0, 1.0, 100.0, 1.0, 1.0]):
            wd.observe(i, dt)
        # EMA stays near 1s-scale, so the next slow step is still caught
        assert wd.ema < 3.0
        assert wd.observe(5, 10.0) is True
