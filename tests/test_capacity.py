"""Capacity layer: pricing tables, fleet simulation, end-to-end planning,
deferrable-workload scheduling."""

import numpy as np
import pytest

from repro.capacity import pricing
from repro.capacity.scheduler import default_workloads, schedule
from repro.capacity.simulator import (
    ServingFleet,
    TrainingJob,
    default_fleet,
    fleet_chip_demand,
    plan_fleet,
)
from repro.core import commitment as cm


class TestPricing:
    def test_paper_premium(self):
        """Paper §3.1: on-demand ~2.1x the 3y savings-plan rate."""
        assert pricing.on_demand_premium() == pytest.approx(2.1, abs=0.05)

    def test_table2_rows(self):
        assert len(pricing.SAVINGS_PLANS) == 8
        assert 0.50 <= pricing.mean_discount_3y() <= 0.55

    def test_table1_transitions(self):
        gains = {t.new: t.latency_reduction
                 for t in pricing.HARDWARE_TRANSITIONS}
        assert gains["Graviton3"] == 0.25
        assert gains["Axion"] == 0.50


class TestFleetSimulator:
    def test_default_fleet_covers_all_archs(self):
        fleets, jobs = default_fleet()
        assert len(fleets) == 10
        big = {f.arch: f.chips_per_replica for f in fleets}
        # replica footprints scale with model size
        assert big["jamba-v0.1-52b"] > big["stablelm-1.6b"]
        assert all(j.chips >= 64 for j in jobs)

    def test_demand_includes_training_blocks(self):
        fleets = [ServingFleet("stablelm-1.6b", 1, 5e4, 50.0)]
        jobs = [TrainingJob("stablelm-1.6b", chips=100, start_hour=48,
                            duration_hours=24)]
        d = fleet_chip_demand(fleets, jobs, 24 * 7)
        assert d[50] >= d[20] + 99  # training block visible

    def test_plan_fleet_saves_money(self):
        fleets, jobs = default_fleet()
        demand = fleet_chip_demand(fleets, jobs, 24 * 7 * 30)
        plan = plan_fleet(demand, horizon_weeks=4)
        assert plan.commitment > 0
        assert 0.0 < plan.savings_vs_on_demand < 0.6
        assert plan.total_cost < plan.all_on_demand_cost

    def test_timeshift_reduces_on_demand(self):
        fleets, jobs = default_fleet()
        demand = fleet_chip_demand(fleets, jobs, 24 * 7 * 30)
        base = plan_fleet(demand, horizon_weeks=4, shiftable_frac=0.0)
        shifted = plan_fleet(demand, horizon_weeks=4, shiftable_frac=0.3)
        assert shifted.on_demand_cost <= base.on_demand_cost


class TestScheduler:
    def test_framework_workloads_fit_troughs(self):
        import jax
        from repro.core import demand as dm

        base = np.asarray(dm.synth_demand(
            24 * 7, dm.DemandConfig(annual_growth=0.0, base_level=100.0),
            key=jax.random.PRNGKey(0)))
        c = float(cm.optimal_commitment_quantile(
            np.asarray(base, np.float32)))
        report = schedule(base, c, default_workloads())
        assert report.savings >= 0.0
        assert set(report.placements) == {
            "nightly-eval-sweep", "ckpt-replay-regression",
            "serving-loadtest", "artifact-builds",
        }
        # interruptible workloads may be split; every placement lands work
        for name, slices in report.placements.items():
            assert sum(w for _, w in slices) > 0, name
