"""Unit tests for the dry-run machinery that don't need the 512-device mesh:
collective parsing, delta configs, rule resolution, sharding sanitization."""

import pytest
from jax.sharding import PartitionSpec as P

from repro import compat, configs
from repro.launch import hlo_analysis as ha
from repro.launch.cells import delta_configs, resolve_rules
from repro.models.config import SHAPES
from repro.models.params import Spec, sanitize_partition_spec
from repro.sharding.rules import RULESETS


class TestCollectiveParsing:
    HLO = """
  %ag = bf16[16,4096]{1,0} all-gather(bf16[1,4096]{1,0} %x), dims={0}
  %ar = f32[256,128]{1,0} all-reduce(f32[256,128]{1,0} %y), to_apply=%sum
  %rs = f32[2,128]{1,0} reduce-scatter(f32[32,128]{1,0} %z), dims={0}
  %a2a = bf16[8,64]{1,0} all-to-all(bf16[8,64]{1,0} %w), dims={0}
  %cp = bf16[4,4]{1,0} collective-permute(bf16[4,4]{1,0} %v), pairs={{0,1}}
  %other = f32[10]{0} add(f32[10]{0} %a, f32[10]{0} %b)
"""

    def test_kinds_and_bytes(self):
        st = ha.parse_collectives(self.HLO)
        assert st.count_by_kind["all-gather"] == 1
        assert st.count_by_kind["all-reduce"] == 1
        assert st.count_by_kind["reduce-scatter"] == 1
        assert st.count_by_kind["all-to-all"] == 1
        assert st.count_by_kind["collective-permute"] == 1
        # all-gather counts output bytes
        assert st.bytes_by_kind["all-gather"] == 16 * 4096 * 2
        # all-reduce counts 2x input
        assert st.bytes_by_kind["all-reduce"] == 2 * 256 * 128 * 4
        # reduce-scatter counts input
        assert st.bytes_by_kind["reduce-scatter"] == 32 * 128 * 4

    def test_async_pairs_counted_once(self):
        hlo = """
  %s = bf16[8,8]{1,0} all-gather-start(bf16[1,8]{1,0} %x), dims={0}
  %d = bf16[8,8]{1,0} all-gather-done(bf16[8,8]{1,0} %s)
"""
        st = ha.parse_collectives(hlo)
        assert st.count_by_kind["all-gather"] == 1


class TestDeltaConfigs:
    @pytest.mark.parametrize("arch", sorted(configs.ARCHS))
    def test_repeat_counts(self, arch):
        cfg = configs.get(arch)
        c1, c2, repeat = delta_configs(cfg)
        assert c1.unroll_layers and c2.unroll_layers
        if cfg.family == "hybrid":
            assert (c2.num_layers - c1.num_layers) == cfg.attn_layer_period
            assert repeat * cfg.attn_layer_period == cfg.num_layers
        elif cfg.family == "audio":
            assert repeat == cfg.num_layers
        else:
            assert c2.num_layers - c1.num_layers == 1
            assert repeat == cfg.num_layers - cfg.first_dense_layers

    def test_extrapolation_identity(self):
        """cost(L1) + (repeat-1)*(cost(L2)-cost(L1)) is exact for affine
        per-layer costs."""
        per_layer, base = 7.0, 100.0
        cfg = configs.get("stablelm-1.6b")
        c1, c2, repeat = delta_configs(cfg)
        cost = lambda n: base + per_layer * n  # noqa: E731
        total = cost(c1.num_layers) + (repeat - 1) * (
            cost(c2.num_layers) - cost(c1.num_layers)
        )
        assert total == base + per_layer * cfg.num_layers


class TestRules:
    def test_resolve_drops_missing_axes(self):
        mesh = compat.make_mesh((1,), ("data",),
                                axis_types=compat.auto_axis_types(1))
        rules = resolve_rules(dict(RULESETS["train"]), mesh, 256)
        assert rules["batch"] == ("data",)
        assert rules["heads"] is None  # "model" axis doesn't exist

    def test_batch_1_unsharded(self):
        class FakeMesh:
            shape = {"data": 16, "model": 16}
            axis_names = ("data", "model")

        rules = resolve_rules(dict(RULESETS["decode"]), FakeMesh(), 1)
        assert rules["batch"] is None  # 1 % 16 != 0 -> replicate batch

    def test_cells_for_counts(self):
        from repro.launch.cells import all_cells

        cells = all_cells()
        assert len(cells) == 32  # 10x3 + 2 long_500k
        assert ("rwkv6-3b", "long_500k") in cells
        assert ("jamba-v0.1-52b", "long_500k") in cells
        assert ("phi3-medium-14b", "long_500k") not in cells


class TestSanitize:
    def _mesh(self):
        # uses whatever devices exist; spec math only needs mesh.shape
        return compat.make_mesh((1,), ("model",),
                                axis_types=compat.auto_axis_types(1))

    def test_even_dims_untouched(self):
        mesh = compat.make_mesh((1,), ("model",),
                                axis_types=compat.auto_axis_types(1))
        spec = Spec((32, 64), ("heads", None))
        ps = sanitize_partition_spec(spec, {"heads": "model"}, mesh)
        assert ps == P("model", None)

    def test_uneven_dim_spills(self):
        class FakeMesh:
            shape = {"model": 16}
            axis_names = ("model",)

        spec = Spec((40, 128), ("heads", "head_dim"))  # 40 % 16 != 0
        ps = sanitize_partition_spec(spec, {"heads": "model"}, FakeMesh())
        assert ps == P(None, "model")  # spilled to head_dim (128 % 16 == 0)

    def test_unplaceable_axis_dropped(self):
        class FakeMesh:
            shape = {"model": 16}
            axis_names = ("model",)

        spec = Spec((6, 7), ("heads", None))
        ps = sanitize_partition_spec(spec, {"heads": "model"}, FakeMesh())
        assert ps == P(None, None)


class TestAnalyticModels:
    def test_active_params_moe_discount(self):
        from repro.models.model import build

        cfg = configs.get("deepseek-v2-lite-16b")
        model = build(cfg)
        total = model.num_params()
        active = ha.active_params(cfg, model)
        assert active < 0.25 * total  # 6/64 routing + shared + dense

    def test_model_flops_formulas(self):
        from repro.models.model import build

        cfg = configs.get("stablelm-1.6b")
        model = build(cfg)
        train = ha.model_flops_for(cfg, model, SHAPES["train_4k"])
        prefill = ha.model_flops_for(cfg, model, SHAPES["prefill_32k"])
        decode = ha.model_flops_for(cfg, model, SHAPES["decode_32k"])
        n = ha.active_params(cfg, model)
        assert train == pytest.approx(6 * n * 256 * 4096)
        assert prefill == pytest.approx(2 * n * 32 * 32768)
        assert decode == pytest.approx(2 * n * 128)

    def test_roofline_dominance(self):
        r = ha.roofline_terms(
            flops=197e12, hbm_bytes=1e9, collective_bytes=1e9,
            model_flops=100e12,
        )
        assert r.dominant == "compute"
        assert r.compute_s == pytest.approx(1.0)
        r = ha.roofline_terms(
            flops=1e12, hbm_bytes=819e9 * 2, collective_bytes=0,
            model_flops=1e12,
        )
        assert r.dominant == "memory"
        assert r.memory_s == pytest.approx(2.0)
