"""Tests for the §3 commitment-portfolio optimizer: exact stacked-quantile
solver vs brute force, degenerate cases, the Pallas 2-D sweep, the per-term
planner/ladder threading, and the fleet-level acceptance comparison."""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import commitment as cm
from repro.core import demand as dm
from repro.core import ladder as ld
from repro.core import planner as pl
from repro.core import portfolio as pf

OD = 2.1


def _trace(n=200, seed=0, scale=50.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.gamma(2.0, scale, size=n).astype(np.float32))


def _brute_force_cost(f, alphas, betas, num_grid=48):
    """Global min over monotone stacks on a level grid, trying every option
    assignment order — the no-cleverness oracle."""
    k = alphas.shape[0]
    grid = np.linspace(0.0, float(f.max()) * 1.02, num_grid)
    stacks = np.asarray([
        s for s in itertools.combinations_with_replacement(grid, k)
    ], np.float32)  # monotone by construction
    best = np.inf
    for perm in itertools.permutations(range(k)):
        al = alphas[jnp.asarray(perm)]
        be = betas[jnp.asarray(perm)]
        costs = pf.portfolio_cost(
            f[None, :], jnp.asarray(stacks), al, be, od_rate=OD
        )
        best = min(best, float(jnp.min(costs)))
    return best


class TestExactSolver:
    def test_k1_reproduces_single_level_quantile(self):
        """K=1 with (alpha=0, beta=B, od=A) IS the paper's Eq (1): the stack
        top must equal the A/(A+B) order-statistic solver exactly."""
        for seed, (a, b) in itertools.product(
            range(4), [(2.1, 1.0), (3.0, 0.5)]
        ):
            f = _trace(seed=seed, n=137)
            plan = pf.optimal_portfolio_stack(
                f, jnp.asarray([0.0]), jnp.asarray([b]), od_rate=a
            )
            c_q = float(cm.optimal_commitment_quantile(f, a, b))
            assert float(plan.total) == pytest.approx(c_q, rel=1e-6)
            assert float(plan.cost) == pytest.approx(
                float(cm.commitment_cost(f, c_q, a, b)), rel=1e-5
            )

    @pytest.mark.parametrize("seed", range(5))
    def test_matches_brute_force(self, seed):
        """Exact stacked solver is never beaten by any monotone grid stack
        under any option ordering (random cost lines)."""
        rng = np.random.default_rng(100 + seed)
        k = 3
        alphas = jnp.asarray(rng.uniform(0.0, 1.8, k).astype(np.float32))
        betas = jnp.asarray(rng.uniform(0.1, 2.5, k).astype(np.float32))
        f = _trace(seed=seed, n=150)
        plan = pf.optimal_portfolio_stack(f, alphas, betas, od_rate=OD)
        brute = _brute_force_cost(f, alphas, betas)
        assert float(plan.cost) <= brute * (1 + 1e-4)

    def test_cost_matches_evaluator(self):
        """Solver-reported cost == portfolio_cost of its own stack (options
        taken in envelope depth order)."""
        opts = pf.options_from_pricing()
        al, be = pf.option_lines(opts, term_weighting=1.0)
        f = _trace(n=400, seed=3)
        plan = pf.optimal_portfolio_stack(f, al, be, od_rate=OD)
        nz = [i for i in range(len(opts)) if float(plan.widths[i]) > 0]
        nz.sort(key=lambda i: float(plan.levels[i]))
        levels = jnp.asarray(
            np.cumsum([float(plan.widths[i]) for i in nz]).astype(np.float32)
        )
        c = pf.portfolio_cost(
            f, levels, al[jnp.asarray(nz)], be[jnp.asarray(nz)], od_rate=OD
        )
        assert float(plan.cost) == pytest.approx(float(c), rel=1e-5)

    def test_zero_discount_gets_zero_allocation(self):
        """An option priced at the on-demand rate can never out-compete
        on-demand (it adds idle cost), so it must get zero width."""
        opts = [
            pf.PurchaseOption("useless/1y", "aws", OD, 52),
            pf.PurchaseOption("useless/3y", "aws", OD, 156),
            pf.PurchaseOption("good/3y", "gcp", 0.93, 156),
        ]
        for tw in (0.0, 1.0):
            al, be = pf.option_lines(opts, term_weighting=tw)
            plan = pf.optimal_portfolio_stack(
                _trace(seed=7), al, be, od_rate=OD
            )
            w = np.asarray(plan.widths)
            assert w[0] == 0.0 and w[1] == 0.0
            assert w[2] > 0.0

    def test_dominated_rate_gets_zero_allocation(self):
        """With equal terms, only the cheapest rate can sit on the envelope
        (identical lines up to level shifts) — single-SKU degeneracy."""
        opts = pf.options_from_pricing(terms=("3y",))
        al, be = pf.option_lines(opts)
        plan = pf.optimal_portfolio_stack(_trace(seed=2), al, be, od_rate=OD)
        w = np.asarray(plan.widths)
        assert (w > 0).sum() == 1
        assert w[int(np.argmin([o.rate for o in opts]))] > 0

    def test_term_weighting_builds_mixed_stack(self):
        """Term-proportional idle discounting puts a weaker-discount 1y band
        on top of the 3y base (the hedge structure from Table-2 numbers)."""
        opts = pf.options_from_pricing()
        al, be = pf.option_lines(opts, term_weighting=1.0)
        plan = pf.optimal_portfolio_stack(_trace(seed=0), al, be, od_rate=OD)
        terms = np.asarray([o.term_weeks for o in opts])
        w = np.asarray(plan.widths)
        assert (w[terms == 156] > 0).any()
        assert (w[terms == 52] > 0).any()

    def test_vmap_batch_of_pools(self):
        opts = pf.options_from_pricing()
        al, be = pf.option_lines(opts, term_weighting=1.0)
        fs = jnp.stack([_trace(seed=s, n=300) for s in range(4)])
        plan = pf.optimal_portfolio_stack(fs, al, be, od_rate=OD)
        assert plan.widths.shape == (4, len(opts))
        for i in range(4):
            solo = pf.optimal_portfolio_stack(fs[i], al, be, od_rate=OD)
            np.testing.assert_allclose(
                np.asarray(plan.widths[i]), np.asarray(solo.widths),
                rtol=1e-5, atol=1e-4,
            )


class TestGridSolver:
    @pytest.mark.parametrize("use_kernel", [False, True])
    def test_matches_exact(self, use_kernel):
        opts = pf.options_from_pricing()
        al, be = pf.option_lines(opts, term_weighting=1.0)
        fs = jnp.stack([_trace(seed=s, n=500) for s in range(3)])
        exact = pf.optimal_portfolio_stack(fs, al, be, od_rate=OD)
        grid = pf.optimal_portfolio_grid(
            fs, al, be, od_rate=OD, num_grid=512, use_kernel=use_kernel
        )
        np.testing.assert_allclose(
            np.asarray(grid.cost), np.asarray(exact.cost), rtol=2e-3
        )
        np.testing.assert_allclose(
            np.asarray(grid.total), np.asarray(exact.total), rtol=2e-2
        )


class TestGridSolverStackTop:
    def test_total_is_stack_top_regardless_of_option_order(self):
        """Regression: grid solver's ``total`` must be the stack top
        (sum of widths), not the last listed option's band top — a deep
        option listed last used to truncate it to its own band."""
        opts = [
            pf.PurchaseOption("hedge/1y", "gcp", 1.3, 52),
            pf.PurchaseOption("base/3y", "gcp", 0.8, 156),
        ]
        al, be = pf.option_lines(opts, term_weighting=1.0)
        f = _trace(seed=11, n=400)
        exact = pf.optimal_portfolio_stack(f, al, be, od_rate=OD)
        grid = pf.optimal_portfolio_grid(f, al, be, od_rate=OD, num_grid=512)
        assert float(grid.total) == pytest.approx(
            float(jnp.sum(grid.widths)), rel=1e-6
        )
        assert float(grid.total) == pytest.approx(
            float(exact.total), rel=2e-2
        )


class TestPallas2DSweep:
    def test_fleet_size_vs_cost_curve(self):
        """Acceptance: (64 pools x 256 grid x 2048 hours) kernel sweep
        matches the jnp cost_curve reference within 1e-5 (relative)."""
        from repro.kernels.commitment_sweep.ops import commitment_sweep

        rng = np.random.default_rng(0)
        f = jnp.asarray(rng.gamma(2, 50, (64, 2048)).astype(np.float32))
        cs = jnp.linspace(float(f.min()), float(f.max()), 256)
        out = commitment_sweep(f, cs)
        ref = cm.cost_curve(f, cs)
        err = float(jnp.max(jnp.abs(out - ref)) / jnp.max(jnp.abs(ref)))
        assert err < 1e-5

    def test_per_pool_grids_vs_oracle(self):
        from repro.kernels.commitment_sweep.ops import (
            commitment_sweep_over_under,
            commitment_sweep_over_under_oracle,
        )

        rng = np.random.default_rng(1)
        f = jnp.asarray(rng.gamma(2, 50, (9, 413)).astype(np.float32))
        cs = jnp.asarray(
            np.sort(rng.uniform(0, 400, (9, 33)), -1).astype(np.float32)
        )
        over, under = commitment_sweep_over_under(f, cs)
        over_r, under_r = commitment_sweep_over_under_oracle(f, cs)
        np.testing.assert_allclose(over, over_r, rtol=2e-4, atol=1e-2)
        np.testing.assert_allclose(under, under_r, rtol=2e-4, atol=1e-2)


class TestPortfolioPlanner:
    def _history(self):
        return dm.synth_demand(24 * 7 * 20, key=jax.random.PRNGKey(0))

    def test_stack_is_monotone_and_on_envelope(self):
        res = pl.plan_portfolio(self._history(), num_horizons=6)
        w = np.asarray(res.widths)
        assert (w >= 0).all() and w.sum() > 0
        qs = np.asarray(res.fractiles)
        assert (w[qs == 0] == 0).all()          # off-envelope: nothing bought

    def test_shorter_terms_clear_fewer_horizons(self):
        """A 2-week-term synthetic option may commit above a 156-week one
        when a demand dip lies beyond week 2 (Step 4 min is per-term)."""
        hist = self._history()
        opts = [
            pf.PurchaseOption("short", "aws", 0.9, 2),
            pf.PurchaseOption("long", "aws", 0.9, 156),
        ]
        res = pl.plan_portfolio(hist, opts, num_horizons=8)
        ph = np.asarray(res.per_horizon_levels)
        # identical rates => identical fractiles => identical per-horizon
        # thresholds; the min differs only through the horizon mask:
        assert ph[:2, 0].min() >= ph.min()

    def test_portfolio_ladder_tranches_carry_terms(self):
        opts = pf.options_from_pricing(clouds=("gcp",))
        targets = np.asarray([[3.0, 10.0], [4.0, 10.0], [4.0, 12.0]])
        terms = np.asarray([o.term_weeks * 168 for o in opts[:2]])
        lad = ld.plan_portfolio_purchases(
            targets, terms, period_hours=168
        )
        assert set(np.asarray(lad.option)) <= {0, 1}
        for k in (0, 1):
            sel = lad.option == k
            assert (lad.term[sel] == terms[k]).all()
        # per-option active level reaches each target band width
        lvl0 = lad.active_level(3 * 168, option=0)
        lvl1 = lad.active_level(3 * 168, option=1)
        assert lvl0[2 * 168] == pytest.approx(4.0)
        assert lvl1[2 * 168] == pytest.approx(12.0)


class TestFleetAcceptance:
    def test_portfolio_beats_single_level_on_default_fleet(self):
        """Acceptance: portfolio total cost <= single-level plan_fleet cost
        on the same default-fleet trace."""
        from repro.capacity.simulator import (
            default_fleet, fleet_chip_demand, plan_fleet,
        )

        fleets, jobs = default_fleet()
        demand = fleet_chip_demand(fleets, jobs, 24 * 7 * 30)
        single = plan_fleet(demand, horizon_weeks=4)
        port = plan_fleet(demand, horizon_weeks=4, portfolio=True)
        assert port.total_cost <= single.total_cost
        assert port.savings_vs_single_level >= 0.0
        assert port.breakdown                       # nonzero per-SKU spend
        assert port.total_cost < port.all_on_demand_cost
