"""Observability layer (``repro.obs``): telemetry goldens, the
cost-attribution ledger, span profiler, kernel stats, scenario replay,
the CLI, and bench provenance.

The load-bearing guarantee is bit-identity: ``telemetry=None`` (the
default) must reproduce the pre-telemetry planner exactly — the scan
only emits its extra ledger outputs when telemetry is on, so the
disabled program is the same compiled program.  The hardcoded golden
outputs below were captured *before* the telemetry plumbing landed, for
every registry policy and every spot/migration/convertible band
combination the planner exposes; ``telemetry=True`` must then reproduce
the same totals bitwise (extra scan outputs, same billing math), and the
ledger it materializes must reconcile with the report's weekly costs to
f32 machine precision.
"""

from __future__ import annotations

import json
import sys
import time
from pathlib import Path

import numpy as np
import pytest

from repro.capacity import simulator as sim
from repro.core import api
from repro.core import planner as pl
from repro.core import replan
from repro.data import scenarios as sc
from repro.data import traces
from repro.obs import (
    CostLedger,
    KernelStats,
    SpanRecorder,
    TelemetryConfig,
    ledger_from_report,
    resolve_telemetry,
    sweep_kernel_stats,
)
from repro.obs.__main__ import main as obs_cli

REPO_ROOT = Path(__file__).resolve().parents[1]

ROLLING = dict(cadence_weeks=2, start_weeks=6, horizon_weeks=4,
               compare=False)

#: policy:s<spot>m<migration>c<convertible> -> [total_cost, targets.sum()]
#: captured at the pre-telemetry HEAD with the harness in ``_run_case``.
GOLDENS = {
    "deterministic_hedge:s0m0c0": [585213.1875, 2579.50830078125],
    "hindsight:s0m0c0": [538106.5625, 2979.73193359375],
    "one_shot:s0m0c0": [546055.125, 2829.31884765625],
    "one_shot:s0m0c1": [426567.0625, 2130.490234375],
    "one_shot:s0m1c0": [426558.40625, 2129.86767578125],
    "one_shot:s0m1c1": [426558.40625, 2129.86767578125],
    "one_shot:s1m0c0": [516133.1875, 2273.6552734375],
    "one_shot:s1m0c1": [402879.8515625, 1680.738037109375],
    "one_shot:s1m1c0": [396272.78125, 1679.398193359375],
    "one_shot:s1m1c1": [402877.0859375, 1679.398193359375],
    "randomized_hedge:s0m0c0": [547963.8125, 2849.55029296875],
    "rolling_portfolio:s0m0c0": [538633.8125, 2829.31884765625],
    "rolling_portfolio:s0m0c1": [421820.84375, 2130.490234375],
    "rolling_portfolio:s0m1c0": [421817.5, 2129.86767578125],
    "rolling_portfolio:s0m1c1": [421817.5, 2129.86767578125],
    "rolling_portfolio:s1m0c0": [494227.5, 2273.6552734375],
    "rolling_portfolio:s1m0c1": [395715.2265625, 1680.738037109375],
    "rolling_portfolio:s1m1c0": [385695.0078125, 1679.398193359375],
    "rolling_portfolio:s1m1c1": [395719.5859375, 1679.398193359375],
}

_POOLS_CACHE: dict[bool, object] = {}


def _pools(migration_fleet: bool):
    """The golden fleets: migration fleets need an even pool count."""
    if migration_fleet not in _POOLS_CACHE:
        _POOLS_CACHE[migration_fleet] = (
            traces.synthetic_pool_set(num_pools=4, num_hours=24 * 7 * 20,
                                      migration=True)
            if migration_fleet
            else traces.synthetic_pool_set(num_pools=3,
                                           num_hours=24 * 7 * 20)
        )
    return _POOLS_CACHE[migration_fleet]


def _run_case(policy, s, m, c, **extra):
    pools = _pools(bool(m or c))
    return replan.replan_fleet_pools(
        pools, policy=policy, spot=bool(s), migration=bool(m),
        convertible=bool(c), **ROLLING, **extra,
    )


class TestTelemetryNoneGolden:
    """telemetry=None keeps every policy x band path bit-identical to the
    pre-telemetry planner: hardcoded golden outputs for the full grid."""

    @pytest.mark.parametrize("case", sorted(GOLDENS))
    def test_default_path_matches_pre_telemetry_golden(self, case):
        policy, bands = case.split(":")
        s, m, c = int(bands[1]), int(bands[3]), int(bands[5])
        rep = _run_case(policy, s, m, c, telemetry=None)
        want = GOLDENS[case]
        np.testing.assert_allclose(rep.total_cost, want[0], rtol=1e-6)
        np.testing.assert_allclose(
            float(np.asarray(rep.targets).sum()), want[1], rtol=1e-6
        )
        # The disabled path must carry no telemetry artifacts at all.
        assert rep.ledger is None
        assert rep.committed_by_sku is None
        assert rep.kernel_stats is None

    def test_telemetry_on_is_bitwise_identical(self):
        off = _run_case("rolling_portfolio", 1, 1, 1, telemetry=None)
        on = _run_case("rolling_portfolio", 1, 1, 1, telemetry=True)
        assert on.total_cost == off.total_cost  # bitwise, not approx
        np.testing.assert_array_equal(
            np.asarray(on.targets), np.asarray(off.targets)
        )
        np.testing.assert_array_equal(
            np.asarray(on.weekly_cost), np.asarray(off.weekly_cost)
        )
        assert on.ledger is not None and off.ledger is None


@pytest.fixture(scope="module")
def rep_full():
    """All-bands telemetry-enabled report on the drifting migration
    fleet — the acceptance configuration."""
    return _run_case("rolling_portfolio", 1, 1, 1, telemetry=True)


class TestCostLedger:
    def test_reconciles_with_report_weekly_costs(self, rep_full):
        res = rep_full.ledger.reconcile(rep_full)
        assert res["ok"], res
        assert res["max_rel"] <= 1e-5
        np.testing.assert_allclose(
            res["total_ledger"], rep_full.total_cost, rtol=1e-6
        )

    def test_sources_cover_every_band(self, rep_full):
        led = rep_full.ledger
        srcs = set(led.sources)
        assert "on_demand" in srcs
        assert {"spot_market", "spot_requeue", "spot_fallback"} <= srcs
        assert any(s.startswith("commit:") for s in srcs)
        assert any(s.startswith("convertible:") for s in srcs)
        assert any(e.startswith("cloud:") for e in led.entities)

    def test_attribute_slices_sum_to_total(self, rep_full):
        led = rep_full.ledger
        total = led.attribute()
        np.testing.assert_allclose(total, led.total, rtol=1e-12)
        by_week = sum(
            led.attribute(week=int(w)) for w in led.weeks
        )
        np.testing.assert_allclose(by_week, total, rtol=1e-9)
        by_entity = sum(led.attribute(pool=e) for e in led.entities)
        np.testing.assert_allclose(by_entity, total, rtol=1e-9)
        np.testing.assert_allclose(
            sum(led.by_source().values()), total, rtol=1e-9
        )

    def test_attribute_unknown_selectors_raise(self, rep_full):
        led = rep_full.ledger
        with pytest.raises(KeyError):
            led.attribute(pool="not/a/pool")
        with pytest.raises(KeyError):
            led.attribute(source="not_a_source")
        with pytest.raises(KeyError):
            led.attribute(week=10**6)

    def test_unit_economics_shape(self, rep_full):
        econ = rep_full.ledger.unit_economics()
        np.testing.assert_allclose(
            econ["total_cost"], rep_full.ledger.total, rtol=1e-12
        )
        assert 0.0 <= econ["idle_fraction"] <= 1.0
        assert 0.0 < econ["utilization_mean"] <= 1.0
        assert econ["cost_per_used_chip_hour"] > 0.0
        parts = (econ["committed_cost"] + econ["convertible_cost"]
                 + econ["on_demand_cost"] + econ["spot_cost"])
        np.testing.assert_allclose(parts, econ["total_cost"], rtol=1e-9)

    def test_jsonl_roundtrip_is_exact(self, rep_full, tmp_path):
        led = rep_full.ledger
        path = str(tmp_path / "ledger.jsonl")
        led.to_jsonl(path)
        back = CostLedger.from_jsonl(path)
        assert back.entities == led.entities
        assert back.sources == led.sources
        np.testing.assert_array_equal(back.cost, led.cost)
        np.testing.assert_array_equal(back.volume, led.volume)
        np.testing.assert_array_equal(back.used_hours, led.used_hours)
        assert led.diff(back).max_abs_delta == 0.0

    def test_diff_pinpoints_a_perturbed_cell(self, rep_full, tmp_path):
        import dataclasses

        led = rep_full.ledger
        cost2 = led.cost.copy()
        ei = 0
        mi = led.sources.index("on_demand")
        cost2[:, ei, mi] += 100.0
        other = dataclasses.replace(led, cost=cost2)
        diff = other.diff(led)
        n_weeks = len(led.weeks)
        np.testing.assert_allclose(diff.total_delta, 100.0 * n_weeks)
        e, s, d = diff.top_movers(1)[0]
        assert (e, s) == (led.entities[ei], "on_demand")
        np.testing.assert_allclose(d, 100.0 * n_weeks)
        assert "on_demand" in diff.report()

    def test_ledger_requires_telemetry(self):
        rep = _run_case("rolling_portfolio", 0, 0, 0, telemetry=None)
        with pytest.raises(ValueError, match="telemetry"):
            ledger_from_report(rep)


class TestRequestSurfaces:
    def test_plan_request_threads_telemetry(self):
        pools = traces.synthetic_pool_set(num_pools=2, num_hours=24 * 7 * 12)
        req = api.PlanRequest(
            pools=pools, mode="rolling", telemetry=True,
            rolling=api.RollingConfig(cadence_weeks=2, start_weeks=4,
                                      compare=False),
            horizon_weeks=4,
        )
        rep = api.plan(req)
        assert rep.ledger is not None
        assert rep.ledger.reconcile(rep)["ok"]

    def test_one_shot_telemetry_is_a_construction_error(self):
        pools = traces.synthetic_pool_set(num_pools=2, num_hours=24 * 7 * 12)
        with pytest.raises(ValueError, match="rolling"):
            api.PlanRequest(pools=pools, mode="one_shot", telemetry=True)
        with pytest.raises(TypeError, match="rolling"):
            pl.plan_fleet_pools(pools, mode="one_shot", telemetry=True)

    def test_resolve_telemetry_spellings(self):
        assert resolve_telemetry(None) is None
        assert resolve_telemetry(False) is None
        cfg = resolve_telemetry(True)
        assert isinstance(cfg, TelemetryConfig) and cfg.ledger
        same = TelemetryConfig(ledger=True, kernel_stats=False)
        assert resolve_telemetry(same) is same
        assert resolve_telemetry(
            TelemetryConfig(ledger=False, kernel_stats=False)
        ) is None
        with pytest.raises(TypeError):
            resolve_telemetry(1.5)


class TestSpans:
    def _fake_clock(self):
        state = {"t": 0.0}

        def clock():
            state["t"] += 1.0
            return state["t"]

        return clock

    def test_nesting_and_durations(self):
        rec = SpanRecorder(clock=self._fake_clock())
        with rec.span("outer", phase="execute"):
            with rec.span("inner"):
                pass
        outer, inner = rec.spans
        assert (outer.name, outer.depth, outer.parent) == ("outer", 0, -1)
        assert (inner.name, inner.depth, inner.parent) == ("inner", 1, 0)
        # clock ticks: outer@1, inner@2, inner ends@3, outer ends@4
        assert inner.duration_s == 1.0
        assert outer.duration_s == 3.0
        assert rec.total_s == 3.0  # roots only, no double-count

    def test_summary_and_by_phase(self):
        rec = SpanRecorder(clock=self._fake_clock())
        with rec.span("a", phase="execute"):
            with rec.span("b", phase="host"):
                pass
        summ = rec.summary()
        assert summ["a"]["count"] == 1 and summ["b"]["count"] == 1
        phases = rec.by_phase()
        # a's self time excludes b
        assert phases["execute"] == 2.0 and phases["host"] == 1.0
        assert phases["compile"] == 0.0
        assert "a" in rec.report() and "total execute" in rec.report()

    def test_unknown_phase_rejected(self):
        rec = SpanRecorder()
        with pytest.raises(ValueError, match="phase"):
            with rec.span("x", phase="gpu"):
                pass

    def test_module_span_noops_on_none(self):
        from repro.obs import span

        with span(None, "anything") as s:
            assert s is None

    def test_to_json(self, tmp_path):
        rec = SpanRecorder(clock=self._fake_clock())
        with rec.span("a"):
            pass
        path = tmp_path / "spans.json"
        rec.to_json(str(path))
        payload = json.loads(path.read_text())
        assert payload["spans"][0]["name"] == "a"
        assert set(payload["by_phase"]) == {"compile", "execute", "host"}


class TestKernelStats:
    def test_stats_respect_budgets(self):
        # The planner's own shape: g is the candidate grid (num_grid).
        st = sweep_kernel_stats(12, 128, 24 * 365)
        assert isinstance(st, KernelStats)
        assert st.vmem_temp_bytes <= st.vmem_budget
        assert st.hbm_passes <= st.pass_budget
        assert st.flops == 4 * 12 * 128 * 24 * 365
        assert 0.0 < st.vmem_utilization <= 1.0
        assert st.padding_waste >= 0.0
        d = st.to_dict()
        assert d["kernel"] == "commitment_sweep"
        assert d["block"] == list(st.block)

    def test_grid_solver_report_carries_stats(self):
        pools = traces.synthetic_pool_set(num_pools=2, num_hours=24 * 7 * 12)
        rep = replan.replan_fleet_pools(
            pools, cadence_weeks=2, start_weeks=4, horizon_weeks=4,
            compare=False, solver="grid", telemetry=True,
        )
        assert rep.kernel_stats is not None
        assert rep.kernel_stats.hbm_passes >= 1
        assert rep.ledger.meta["kernel_stats"]["kernel"] == \
            "commitment_sweep"


class TestTelemetryOverhead:
    def test_ledger_overhead_within_budget(self):
        """telemetry=True costs <= 1.3x the quick-bench scan runtime —
        the extra scan outputs are tiny arrays, not extra compute."""
        pools = traces.synthetic_pool_set(num_pools=2, num_hours=24 * 7 * 10)
        kw = dict(cadence_weeks=2, start_weeks=4, horizon_weeks=4,
                  compare=False)

        def timed(**extra):
            replan.replan_fleet_pools(pools, **kw, **extra)  # warmup
            best = float("inf")
            for _ in range(3):
                t0 = time.perf_counter()
                replan.replan_fleet_pools(pools, **kw, **extra)
                best = min(best, time.perf_counter() - t0)
            return best

        base = timed(telemetry=None)
        tele = timed(telemetry=True)
        assert tele <= 1.3 * base + 0.05, (
            f"telemetry overhead {tele / base:.2f}x exceeds 1.3x "
            f"({tele:.3f}s vs {base:.3f}s)"
        )


class TestScenarioReplay:
    @pytest.fixture(scope="class")
    def pools(self):
        return traces.synthetic_pool_set(num_pools=2, num_hours=24 * 7 * 12)

    @pytest.fixture(scope="class")
    def batched(self, pools):
        # A perturbing family, so scenarios 1.. are genuinely different
        # demand futures (the int spelling's "realized" family replays
        # the same trace N times).
        return replan.replan_fleet_pools(
            pools, spot=True,
            scenarios=sc.ScenarioConfig(n_scenarios=3, family="growth"),
            cadence_weeks=2, start_weeks=4, horizon_weeks=4,
            compare=False,
        )

    def test_scenario0_matches_unbatched_replay(self, pools, batched):
        unbatched = replan.replan_fleet_pools(
            pools, spot=True, cadence_weeks=2, start_weeks=4,
            horizon_weeks=4, compare=False,
        )
        a = sim.replay_spot_plan(pools, batched, num_draws=8, seed=0,
                                 scenario=0)
        b = sim.replay_spot_plan(pools, unbatched, num_draws=8, seed=0)
        assert a.realized_cost == b.realized_cost
        np.testing.assert_array_equal(a.availability, b.availability)

    def test_nonzero_scenario_replays_its_own_future(self, pools, batched):
        rep1 = sim.replay_spot_plan(pools, batched, num_draws=8, seed=0,
                                    scenario=1)
        assert np.isfinite(rep1.realized_cost)
        np.testing.assert_allclose(
            rep1.planned_cost, float(batched.scenario_cost[1]), rtol=1e-6
        )
        rep0 = sim.replay_spot_plan(pools, batched, num_draws=8, seed=0,
                                    scenario=0)
        assert rep1.realized_cost != rep0.realized_cost

    def test_out_of_range_scenario_raises(self, pools, batched):
        with pytest.raises(ValueError, match="out of range"):
            sim.replay_spot_plan(pools, batched, scenario=3)
        unbatched = replan.replan_fleet_pools(
            pools, spot=True, cadence_weeks=2, start_weeks=4,
            horizon_weeks=4, compare=False,
        )
        with pytest.raises(ValueError, match="out of range"):
            sim.replay_spot_plan(pools, unbatched, scenario=1)


class TestObsCli:
    @pytest.fixture(scope="class")
    def ledger_paths(self, rep_full, tmp_path_factory):
        tmp = tmp_path_factory.mktemp("obs_cli")
        a = str(tmp / "a.jsonl")
        b = str(tmp / "b.jsonl")
        led = rep_full.ledger
        led.to_jsonl(a)
        import dataclasses

        bumped = dataclasses.replace(led, cost=led.cost + 1.0)
        bumped.to_jsonl(b)
        return a, b

    def test_report(self, ledger_paths, tmp_path, capsys):
        a, _ = ledger_paths
        out_json = str(tmp_path / "report.json")
        assert obs_cli(["report", a, "--json", out_json]) == 0
        assert "spend by source" in capsys.readouterr().out
        payload = json.loads(Path(out_json).read_text())
        assert "unit_economics" in payload and "by_source" in payload

    def test_diff_gate(self, ledger_paths, capsys):
        a, b = ledger_paths
        assert obs_cli(["diff", a, a]) == 0
        assert obs_cli(["diff", a, b]) == 0          # no gate: report only
        assert obs_cli(["diff", a, b, "--fail-above", "0.5"]) == 1
        assert "FAIL" in capsys.readouterr().err

    def test_top(self, ledger_paths, capsys):
        a, b = ledger_paths
        assert obs_cli(["top", a, "-n", "3"]) == 0
        assert obs_cli(["top", a, b, "--fail-above", "0.5"]) == 1
        out = capsys.readouterr().out
        assert "top 3 spend cells" in out


class TestBenchProvenance:
    def test_quick_bench_json_is_stamped(self, tmp_path):
        if str(REPO_ROOT) not in sys.path:
            sys.path.insert(0, str(REPO_ROOT))
        from benchmarks import run as bench_run

        out = str(tmp_path / "BENCH.json")
        spans = str(tmp_path / "SPANS.json")
        bench_run.main([
            "--quick", "--json", out, "--spans", spans,
            "--filter", "commitment_sweep",
        ])
        payload = json.loads(Path(out).read_text())
        assert payload["schema_version"] == bench_run.BENCH_SCHEMA_VERSION
        assert payload["git_sha"] and payload["git_sha"] != ""
        assert payload["quick"] is True and payload["seed"] == 0
        for key in ("jax", "numpy", "backend", "python", "platform"):
            assert payload[key]
        assert payload["rows"] and not payload["failures"]
        assert payload["spans"]  # per-bench wall-clock breakdown
        assert "commitment_sweep" in payload["kernel_stats"]
        span_payload = json.loads(Path(spans).read_text())
        assert span_payload["spans"]

    def test_unknown_filter_exits_nonzero(self):
        if str(REPO_ROOT) not in sys.path:
            sys.path.insert(0, str(REPO_ROOT))
        from benchmarks import run as bench_run

        with pytest.raises(SystemExit):
            bench_run.main(["--quick", "--filter", "no_such_bench"])
