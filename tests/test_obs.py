"""Observability layer (``repro.obs``): telemetry goldens, the
cost-attribution ledger, span profiler, kernel stats, scenario replay,
the CLI, and bench provenance.

The load-bearing guarantee is bit-identity: ``telemetry=None`` (the
default) must reproduce the pre-telemetry planner exactly — the scan
only emits its extra ledger outputs when telemetry is on, so the
disabled program is the same compiled program.  The hardcoded golden
outputs below were captured *before* the telemetry plumbing landed, for
every registry policy and every spot/migration/convertible band
combination the planner exposes; ``telemetry=True`` must then reproduce
the same totals bitwise (extra scan outputs, same billing math), and the
ledger it materializes must reconcile with the report's weekly costs to
f32 machine precision.
"""

from __future__ import annotations

import json
import sys
import time
from pathlib import Path

import numpy as np
import pytest

from repro.capacity import simulator as sim
from repro.core import api
from repro.core import planner as pl
from repro.core import replan
from repro.data import scenarios as sc
from repro.data import traces
from repro.core import demand as dmnd
from repro.obs import (
    CalibrationCube,
    CostLedger,
    KernelStats,
    SpanRecorder,
    TelemetryConfig,
    ledger_from_report,
    resolve_telemetry,
    sweep_kernel_stats,
)
from repro.obs.__main__ import main as obs_cli

REPO_ROOT = Path(__file__).resolve().parents[1]

#: cadence="weekly" is the explicit disabled spelling of the breach
#: cadence — the goldens below prove it stays bit-identical.
ROLLING = dict(cadence_weeks=2, start_weeks=6, horizon_weeks=4,
               compare=False, cadence="weekly")

#: policy:s<spot>m<migration>c<convertible> -> [total_cost, targets.sum()]
#: captured at the pre-telemetry HEAD with the harness in ``_run_case``.
GOLDENS = {
    "deterministic_hedge:s0m0c0": [585213.1875, 2579.50830078125],
    "hindsight:s0m0c0": [538106.5625, 2979.73193359375],
    "one_shot:s0m0c0": [546055.125, 2829.31884765625],
    "one_shot:s0m0c1": [426567.0625, 2130.490234375],
    "one_shot:s0m1c0": [426558.40625, 2129.86767578125],
    "one_shot:s0m1c1": [426558.40625, 2129.86767578125],
    "one_shot:s1m0c0": [516133.1875, 2273.6552734375],
    "one_shot:s1m0c1": [402879.8515625, 1680.738037109375],
    "one_shot:s1m1c0": [396272.78125, 1679.398193359375],
    "one_shot:s1m1c1": [402877.0859375, 1679.398193359375],
    "randomized_hedge:s0m0c0": [547963.8125, 2849.55029296875],
    "rolling_portfolio:s0m0c0": [538633.8125, 2829.31884765625],
    "rolling_portfolio:s0m0c1": [421820.84375, 2130.490234375],
    "rolling_portfolio:s0m1c0": [421817.5, 2129.86767578125],
    "rolling_portfolio:s0m1c1": [421817.5, 2129.86767578125],
    "rolling_portfolio:s1m0c0": [494227.5, 2273.6552734375],
    "rolling_portfolio:s1m0c1": [395715.2265625, 1680.738037109375],
    "rolling_portfolio:s1m1c0": [385695.0078125, 1679.398193359375],
    "rolling_portfolio:s1m1c1": [395719.5859375, 1679.398193359375],
}

_POOLS_CACHE: dict[bool, object] = {}


def _pools(migration_fleet: bool):
    """The golden fleets: migration fleets need an even pool count."""
    if migration_fleet not in _POOLS_CACHE:
        _POOLS_CACHE[migration_fleet] = (
            traces.synthetic_pool_set(num_pools=4, num_hours=24 * 7 * 20,
                                      migration=True)
            if migration_fleet
            else traces.synthetic_pool_set(num_pools=3,
                                           num_hours=24 * 7 * 20)
        )
    return _POOLS_CACHE[migration_fleet]


def _run_case(policy, s, m, c, **extra):
    pools = _pools(bool(m or c))
    return replan.replan_fleet_pools(
        pools, policy=policy, spot=bool(s), migration=bool(m),
        convertible=bool(c), **ROLLING, **extra,
    )


class TestTelemetryNoneGolden:
    """telemetry=None keeps every policy x band path bit-identical to the
    pre-telemetry planner: hardcoded golden outputs for the full grid."""

    @pytest.mark.parametrize("case", sorted(GOLDENS))
    def test_default_path_matches_pre_telemetry_golden(self, case):
        policy, bands = case.split(":")
        s, m, c = int(bands[1]), int(bands[3]), int(bands[5])
        rep = _run_case(policy, s, m, c, telemetry=None)
        want = GOLDENS[case]
        np.testing.assert_allclose(rep.total_cost, want[0], rtol=1e-6)
        np.testing.assert_allclose(
            float(np.asarray(rep.targets).sum()), want[1], rtol=1e-6
        )
        # The disabled path must carry no telemetry artifacts at all.
        assert rep.ledger is None
        assert rep.committed_by_sku is None
        assert rep.kernel_stats is None
        assert rep.calibration is None
        assert rep.decision_log is None
        assert rep.fractile_levels is None
        assert rep.breach_band_lo is None and rep.breach_band_hi is None
        assert rep.cadence == "weekly"

    def test_calibration_provenance_off_is_bitwise_identical(self):
        """The ledger-only telemetry spelling — calibration=False,
        provenance=False — must match the goldens' telemetry=None path
        bitwise; the new instruments only exist when asked for."""
        off = _run_case("rolling_portfolio", 1, 1, 1, telemetry=None)
        on = _run_case(
            "rolling_portfolio", 1, 1, 1,
            telemetry=TelemetryConfig(calibration=False, provenance=False),
        )
        assert on.total_cost == off.total_cost
        np.testing.assert_array_equal(
            np.asarray(on.weekly_cost), np.asarray(off.weekly_cost)
        )
        assert on.calibration is None and on.decision_log is None

    def test_telemetry_on_is_bitwise_identical(self):
        off = _run_case("rolling_portfolio", 1, 1, 1, telemetry=None)
        on = _run_case("rolling_portfolio", 1, 1, 1, telemetry=True)
        assert on.total_cost == off.total_cost  # bitwise, not approx
        np.testing.assert_array_equal(
            np.asarray(on.targets), np.asarray(off.targets)
        )
        np.testing.assert_array_equal(
            np.asarray(on.weekly_cost), np.asarray(off.weekly_cost)
        )
        assert on.ledger is not None and off.ledger is None


@pytest.fixture(scope="module")
def rep_full():
    """All-bands telemetry-enabled report on the drifting migration
    fleet — the acceptance configuration."""
    return _run_case("rolling_portfolio", 1, 1, 1, telemetry=True)


class TestCostLedger:
    def test_reconciles_with_report_weekly_costs(self, rep_full):
        res = rep_full.ledger.reconcile(rep_full)
        assert res["ok"], res
        assert res["max_rel"] <= 1e-5
        np.testing.assert_allclose(
            res["total_ledger"], rep_full.total_cost, rtol=1e-6
        )

    def test_sources_cover_every_band(self, rep_full):
        led = rep_full.ledger
        srcs = set(led.sources)
        assert "on_demand" in srcs
        assert {"spot_market", "spot_requeue", "spot_fallback"} <= srcs
        assert any(s.startswith("commit:") for s in srcs)
        assert any(s.startswith("convertible:") for s in srcs)
        assert any(e.startswith("cloud:") for e in led.entities)

    def test_attribute_slices_sum_to_total(self, rep_full):
        led = rep_full.ledger
        total = led.attribute()
        np.testing.assert_allclose(total, led.total, rtol=1e-12)
        by_week = sum(
            led.attribute(week=int(w)) for w in led.weeks
        )
        np.testing.assert_allclose(by_week, total, rtol=1e-9)
        by_entity = sum(led.attribute(pool=e) for e in led.entities)
        np.testing.assert_allclose(by_entity, total, rtol=1e-9)
        np.testing.assert_allclose(
            sum(led.by_source().values()), total, rtol=1e-9
        )

    def test_attribute_unknown_selectors_raise(self, rep_full):
        led = rep_full.ledger
        with pytest.raises(KeyError):
            led.attribute(pool="not/a/pool")
        with pytest.raises(KeyError):
            led.attribute(source="not_a_source")
        with pytest.raises(KeyError):
            led.attribute(week=10**6)

    def test_unit_economics_shape(self, rep_full):
        econ = rep_full.ledger.unit_economics()
        np.testing.assert_allclose(
            econ["total_cost"], rep_full.ledger.total, rtol=1e-12
        )
        assert 0.0 <= econ["idle_fraction"] <= 1.0
        assert 0.0 < econ["utilization_mean"] <= 1.0
        assert econ["cost_per_used_chip_hour"] > 0.0
        parts = (econ["committed_cost"] + econ["convertible_cost"]
                 + econ["on_demand_cost"] + econ["spot_cost"])
        np.testing.assert_allclose(parts, econ["total_cost"], rtol=1e-9)

    def test_jsonl_roundtrip_is_exact(self, rep_full, tmp_path):
        led = rep_full.ledger
        path = str(tmp_path / "ledger.jsonl")
        led.to_jsonl(path)
        back = CostLedger.from_jsonl(path)
        assert back.entities == led.entities
        assert back.sources == led.sources
        np.testing.assert_array_equal(back.cost, led.cost)
        np.testing.assert_array_equal(back.volume, led.volume)
        np.testing.assert_array_equal(back.used_hours, led.used_hours)
        assert led.diff(back).max_abs_delta == 0.0

    def test_diff_pinpoints_a_perturbed_cell(self, rep_full, tmp_path):
        import dataclasses

        led = rep_full.ledger
        cost2 = led.cost.copy()
        ei = 0
        mi = led.sources.index("on_demand")
        cost2[:, ei, mi] += 100.0
        other = dataclasses.replace(led, cost=cost2)
        diff = other.diff(led)
        n_weeks = len(led.weeks)
        np.testing.assert_allclose(diff.total_delta, 100.0 * n_weeks)
        e, s, d = diff.top_movers(1)[0]
        assert (e, s) == (led.entities[ei], "on_demand")
        np.testing.assert_allclose(d, 100.0 * n_weeks)
        assert "on_demand" in diff.report()

    def test_ledger_requires_telemetry(self):
        rep = _run_case("rolling_portfolio", 0, 0, 0, telemetry=None)
        with pytest.raises(ValueError, match="telemetry"):
            ledger_from_report(rep)


class TestRequestSurfaces:
    def test_plan_request_threads_telemetry(self):
        pools = traces.synthetic_pool_set(num_pools=2, num_hours=24 * 7 * 12)
        req = api.PlanRequest(
            pools=pools, mode="rolling", telemetry=True,
            rolling=api.RollingConfig(cadence_weeks=2, start_weeks=4,
                                      compare=False),
            horizon_weeks=4,
        )
        rep = api.plan(req)
        assert rep.ledger is not None
        assert rep.ledger.reconcile(rep)["ok"]

    def test_one_shot_telemetry_is_a_construction_error(self):
        pools = traces.synthetic_pool_set(num_pools=2, num_hours=24 * 7 * 12)
        with pytest.raises(ValueError, match="rolling"):
            api.PlanRequest(pools=pools, mode="one_shot", telemetry=True)
        with pytest.raises(TypeError, match="rolling"):
            pl.plan_fleet_pools(pools, mode="one_shot", telemetry=True)

    def test_resolve_telemetry_spellings(self):
        assert resolve_telemetry(None) is None
        assert resolve_telemetry(False) is None
        cfg = resolve_telemetry(True)
        assert isinstance(cfg, TelemetryConfig) and cfg.ledger
        same = TelemetryConfig(ledger=True, kernel_stats=False)
        assert resolve_telemetry(same) is same
        assert resolve_telemetry(
            TelemetryConfig(ledger=False, kernel_stats=False)
        ) is None
        with pytest.raises(TypeError):
            resolve_telemetry(1.5)


class TestSpans:
    def _fake_clock(self):
        state = {"t": 0.0}

        def clock():
            state["t"] += 1.0
            return state["t"]

        return clock

    def test_nesting_and_durations(self):
        rec = SpanRecorder(clock=self._fake_clock())
        with rec.span("outer", phase="execute"):
            with rec.span("inner"):
                pass
        outer, inner = rec.spans
        assert (outer.name, outer.depth, outer.parent) == ("outer", 0, -1)
        assert (inner.name, inner.depth, inner.parent) == ("inner", 1, 0)
        # clock ticks: outer@1, inner@2, inner ends@3, outer ends@4
        assert inner.duration_s == 1.0
        assert outer.duration_s == 3.0
        assert rec.total_s == 3.0  # roots only, no double-count

    def test_summary_and_by_phase(self):
        rec = SpanRecorder(clock=self._fake_clock())
        with rec.span("a", phase="execute"):
            with rec.span("b", phase="host"):
                pass
        summ = rec.summary()
        assert summ["a"]["count"] == 1 and summ["b"]["count"] == 1
        phases = rec.by_phase()
        # a's self time excludes b
        assert phases["execute"] == 2.0 and phases["host"] == 1.0
        assert phases["compile"] == 0.0
        assert "a" in rec.report() and "total execute" in rec.report()

    def test_unknown_phase_rejected(self):
        rec = SpanRecorder()
        with pytest.raises(ValueError, match="phase"):
            with rec.span("x", phase="gpu"):
                pass

    def test_module_span_noops_on_none(self):
        from repro.obs import span

        with span(None, "anything") as s:
            assert s is None

    def test_to_json(self, tmp_path):
        rec = SpanRecorder(clock=self._fake_clock())
        with rec.span("a"):
            pass
        path = tmp_path / "spans.json"
        rec.to_json(str(path))
        payload = json.loads(path.read_text())
        assert payload["spans"][0]["name"] == "a"
        assert set(payload["by_phase"]) == {"compile", "execute", "host"}


class TestKernelStats:
    def test_stats_respect_budgets(self):
        # The planner's own shape: g is the candidate grid (num_grid).
        st = sweep_kernel_stats(12, 128, 24 * 365)
        assert isinstance(st, KernelStats)
        assert st.vmem_temp_bytes <= st.vmem_budget
        assert st.hbm_passes <= st.pass_budget
        assert st.flops == 4 * 12 * 128 * 24 * 365
        assert 0.0 < st.vmem_utilization <= 1.0
        assert st.padding_waste >= 0.0
        d = st.to_dict()
        assert d["kernel"] == "commitment_sweep"
        assert d["block"] == list(st.block)

    def test_grid_solver_report_carries_stats(self):
        pools = traces.synthetic_pool_set(num_pools=2, num_hours=24 * 7 * 12)
        rep = replan.replan_fleet_pools(
            pools, cadence_weeks=2, start_weeks=4, horizon_weeks=4,
            compare=False, solver="grid", telemetry=True,
        )
        assert rep.kernel_stats is not None
        assert rep.kernel_stats.hbm_passes >= 1
        assert rep.ledger.meta["kernel_stats"]["kernel"] == \
            "commitment_sweep"


class TestTelemetryOverhead:
    def test_ledger_overhead_within_budget(self):
        """telemetry=True costs <= 1.3x the quick-bench scan runtime —
        the extra scan outputs are tiny arrays, not extra compute."""
        pools = traces.synthetic_pool_set(num_pools=2, num_hours=24 * 7 * 10)
        kw = dict(cadence_weeks=2, start_weeks=4, horizon_weeks=4,
                  compare=False)

        def timed(**extra):
            replan.replan_fleet_pools(pools, **kw, **extra)  # warmup
            best = float("inf")
            for _ in range(3):
                t0 = time.perf_counter()
                replan.replan_fleet_pools(pools, **kw, **extra)
                best = min(best, time.perf_counter() - t0)
            return best

        base = timed(telemetry=None)
        tele = timed(telemetry=True)
        assert tele <= 1.3 * base + 0.05, (
            f"telemetry overhead {tele / base:.2f}x exceeds 1.3x "
            f"({tele:.3f}s vs {base:.3f}s)"
        )
        # The full instrument set — ledger + calibration + provenance —
        # stays inside the same budget: the extra scan outputs are small
        # per-week arrays, not extra solver work.
        full = timed(telemetry=TelemetryConfig(
            calibration=True, provenance=True,
        ))
        assert full <= 1.3 * base + 0.05, (
            f"calibration+provenance overhead {full / base:.2f}x exceeds "
            f"1.3x ({full:.3f}s vs {base:.3f}s)"
        )


class TestScenarioReplay:
    @pytest.fixture(scope="class")
    def pools(self):
        return traces.synthetic_pool_set(num_pools=2, num_hours=24 * 7 * 12)

    @pytest.fixture(scope="class")
    def batched(self, pools):
        # A perturbing family, so scenarios 1.. are genuinely different
        # demand futures (the int spelling's "realized" family replays
        # the same trace N times).
        return replan.replan_fleet_pools(
            pools, spot=True,
            scenarios=sc.ScenarioConfig(n_scenarios=3, family="growth"),
            cadence_weeks=2, start_weeks=4, horizon_weeks=4,
            compare=False,
        )

    def test_scenario0_matches_unbatched_replay(self, pools, batched):
        unbatched = replan.replan_fleet_pools(
            pools, spot=True, cadence_weeks=2, start_weeks=4,
            horizon_weeks=4, compare=False,
        )
        a = sim.replay_spot_plan(pools, batched, num_draws=8, seed=0,
                                 scenario=0)
        b = sim.replay_spot_plan(pools, unbatched, num_draws=8, seed=0)
        assert a.realized_cost == b.realized_cost
        np.testing.assert_array_equal(a.availability, b.availability)

    def test_nonzero_scenario_replays_its_own_future(self, pools, batched):
        rep1 = sim.replay_spot_plan(pools, batched, num_draws=8, seed=0,
                                    scenario=1)
        assert np.isfinite(rep1.realized_cost)
        np.testing.assert_allclose(
            rep1.planned_cost, float(batched.scenario_cost[1]), rtol=1e-6
        )
        rep0 = sim.replay_spot_plan(pools, batched, num_draws=8, seed=0,
                                    scenario=0)
        assert rep1.realized_cost != rep0.realized_cost

    def test_out_of_range_scenario_raises(self, pools, batched):
        with pytest.raises(ValueError, match="out of range"):
            sim.replay_spot_plan(pools, batched, scenario=3)
        unbatched = replan.replan_fleet_pools(
            pools, spot=True, cadence_weeks=2, start_weeks=4,
            horizon_weeks=4, compare=False,
        )
        with pytest.raises(ValueError, match="out of range"):
            sim.replay_spot_plan(pools, unbatched, scenario=1)


class TestObsCli:
    @pytest.fixture(scope="class")
    def ledger_paths(self, rep_full, tmp_path_factory):
        tmp = tmp_path_factory.mktemp("obs_cli")
        a = str(tmp / "a.jsonl")
        b = str(tmp / "b.jsonl")
        led = rep_full.ledger
        led.to_jsonl(a)
        import dataclasses

        bumped = dataclasses.replace(led, cost=led.cost + 1.0)
        bumped.to_jsonl(b)
        return a, b

    def test_report(self, ledger_paths, tmp_path, capsys):
        a, _ = ledger_paths
        out_json = str(tmp_path / "report.json")
        assert obs_cli(["report", a, "--json", out_json]) == 0
        assert "spend by source" in capsys.readouterr().out
        payload = json.loads(Path(out_json).read_text())
        assert "unit_economics" in payload and "by_source" in payload

    def test_diff_gate(self, ledger_paths, capsys):
        a, b = ledger_paths
        assert obs_cli(["diff", a, a]) == 0
        assert obs_cli(["diff", a, b]) == 0          # no gate: report only
        assert obs_cli(["diff", a, b, "--fail-above", "0.5"]) == 1
        assert "FAIL" in capsys.readouterr().err

    def test_top(self, ledger_paths, capsys):
        a, b = ledger_paths
        assert obs_cli(["top", a, "-n", "3"]) == 0
        assert obs_cli(["top", a, b, "--fail-above", "0.5"]) == 1
        out = capsys.readouterr().out
        assert "top 3 spend cells" in out


def _steady_fleet(family: str = "steady", num_seeds: int = 32,
                  num_weeks: int = 20):
    """N seeded single-pool paths of one family, flattened into an
    N-pool fleet — the coverage test's unit of statistical power."""
    arr = np.asarray(sc.scenario_paths(
        family, num_pools=1, num_weeks=num_weeks, num_seeds=num_seeds,
    )).reshape(num_seeds, -1)
    return dmnd.PoolSet(keys=sc.scenario_keys(num_seeds), demand=arr)


@pytest.fixture(scope="module")
def calib_cubes():
    """Calibration cubes for the steady and unpredictable families from
    identically configured replays."""
    tele = TelemetryConfig(calibration=True)
    cubes = {}
    for family in ("steady", "unpredictable"):
        rep = replan.replan_fleet_pools(
            _steady_fleet(family), cadence_weeks=1, start_weeks=8,
            horizon_weeks=4, compare=False, telemetry=tele,
        )
        cubes[family] = rep.calibration
    return cubes


class TestCalibration:
    def test_steady_coverage_within_3pp_of_nominal(self, calib_cubes):
        cube = calib_cubes["steady"]
        assert cube.max_coverage_drift <= 0.03, cube.report()

    def test_unpredictable_family_degrades_detectably(self, calib_cubes):
        steady = calib_cubes["steady"].max_coverage_drift
        rough = calib_cubes["unpredictable"].max_coverage_drift
        assert rough > 2.0 * steady, (
            f"unpredictable drift {rough:.4f} not detectably worse than "
            f"steady {steady:.4f}"
        )

    def test_cube_shape_and_summary(self, calib_cubes):
        cube = calib_cubes["steady"]
        s, n, p, q = cube.levels.shape
        assert (n, p) == (1, 32)
        assert cube.hits.shape == cube.pinball.shape == (s, n, p, q)
        assert cube.realized_mean.shape == (s, n, p)
        assert np.all((0.0 <= cube.hits) & (cube.hits <= 1.0))
        assert np.all(cube.pinball >= 0.0)
        assert np.all(np.diff(np.asarray(cube.fractiles)) > 0)
        summ = cube.summary()
        assert summ["weeks"] == s and summ["n_scenarios"] == 1
        assert summ["max_coverage_drift"] == cube.max_coverage_drift
        assert summ["interval_width"] > 0.0
        assert "coverage" in summ and len(summ["coverage"]) == q
        assert "fractile" in cube.report()

    def test_report_carries_levels_and_mask(self, calib_cubes):
        # fractile_levels ride the report next to the cube; the weekly
        # decision mask reflects the cadence grid.
        tele = TelemetryConfig(calibration=True)
        rep = replan.replan_fleet_pools(
            _steady_fleet(num_seeds=4), cadence_weeks=2, start_weeks=8,
            horizon_weeks=4, compare=False, telemetry=tele,
        )
        s = len(np.asarray(rep.calibration.weeks))
        assert np.asarray(rep.fractile_levels).shape == (s, 4, 5)
        mask = np.asarray(rep.decision_mask)
        assert mask.shape == (s,)
        np.testing.assert_array_equal(mask, (np.arange(s) % 2) == 0)
        assert rep.summary()["decision_weeks"] == int(mask.sum())

    def test_jsonl_roundtrip_is_exact(self, calib_cubes, tmp_path):
        cube = calib_cubes["steady"]
        path = str(tmp_path / "calib.jsonl")
        cube.to_jsonl(path)
        back = CalibrationCube.from_jsonl(path)
        assert back.entities == cube.entities
        assert back.fractiles == cube.fractiles
        np.testing.assert_array_equal(back.weeks, cube.weeks)
        np.testing.assert_array_equal(back.levels, cube.levels)
        np.testing.assert_array_equal(back.hits, cube.hits)
        np.testing.assert_array_equal(back.pinball, cube.pinball)
        np.testing.assert_array_equal(back.realized_mean,
                                      cube.realized_mean)
        np.testing.assert_array_equal(back.realized_peak,
                                      cube.realized_peak)
        assert back.diff(cube).max_abs_coverage_delta == 0.0

    def test_diff_compares_families(self, calib_cubes):
        diff = calib_cubes["unpredictable"].diff(calib_cubes["steady"])
        assert diff.max_abs_coverage_delta > 0.0
        assert diff.drift_a > diff.drift_b
        assert set(diff.coverage_delta) == set(
            float(q) for q in calib_cubes["steady"].fractiles
        )
        assert "d-coverage" in diff.report()
        payload = diff.to_dict()
        assert payload["max_abs_coverage_delta"] == \
            diff.max_abs_coverage_delta
        with pytest.raises(ValueError, match="fractile"):
            import dataclasses as dc

            other = dc.replace(
                calib_cubes["steady"], fractiles=(0.1, 0.5, 0.9),
                levels=calib_cubes["steady"].levels[..., :3],
                hits=calib_cubes["steady"].hits[..., :3],
                pinball=calib_cubes["steady"].pinball[..., :3],
            )
            calib_cubes["steady"].diff(other)

    def test_scenario_batched_cube_from_one_scan(self):
        pools = traces.synthetic_pool_set(num_pools=2,
                                          num_hours=24 * 7 * 12)
        rep = replan.replan_fleet_pools(
            pools,
            scenarios=sc.ScenarioConfig(n_scenarios=3, family="regime"),
            cadence_weeks=1, start_weeks=6, horizon_weeks=4,
            compare=False, telemetry=TelemetryConfig(calibration=True),
        )
        cube = rep.calibration
        assert cube.n_scenarios == 3
        per_scen = cube.scenario_coverage()
        assert per_scen.shape == (3, len(cube.fractiles))
        np.testing.assert_allclose(
            per_scen[0], cube.coverage(scenario=0), rtol=1e-12
        )
        np.testing.assert_allclose(
            per_scen.mean(axis=0), cube.coverage(), rtol=1e-12
        )
        # The regime scenarios perturb demand away from the realized
        # trace, so their coverage genuinely differs from scenario 0.
        assert np.abs(per_scen[1:] - per_scen[0]).max() > 0.0
        with pytest.raises(ValueError, match="out of range"):
            cube.coverage(scenario=3)

    def test_interval_width_unknown_pair_raises(self, calib_cubes):
        with pytest.raises(KeyError, match="not carried"):
            calib_cubes["steady"].interval_width(0.123, 0.456)

    def test_calibration_requires_forecasting_policy(self):
        pools = traces.synthetic_pool_set(num_pools=2,
                                          num_hours=24 * 7 * 12)
        with pytest.raises(ValueError, match="forecast"):
            replan.replan_fleet_pools(
                pools, policy="deterministic_hedge", cadence_weeks=1,
                start_weeks=6, horizon_weeks=4, compare=False,
                telemetry=TelemetryConfig(calibration=True),
            )

    def test_fractile_validation(self):
        with pytest.raises(ValueError, match="fractiles"):
            TelemetryConfig(fractiles=())
        with pytest.raises(ValueError, match="fractiles"):
            TelemetryConfig(fractiles=(0.5, 0.25))
        with pytest.raises(ValueError, match="fractiles"):
            TelemetryConfig(fractiles=(0.0, 0.5))


@pytest.fixture(scope="module")
def prov_rep():
    """All-bands replay with provenance telemetry on."""
    return _run_case(
        "rolling_portfolio", 1, 1, 1,
        telemetry=TelemetryConfig(provenance=True),
    )


class TestDecisionLog:
    def test_log_materializes_with_all_bands(self, prov_rep):
        log = prov_rep.decision_log
        assert log is not None
        assert len(log.entities) == 4
        assert log.conv_clouds is not None
        assert log.increments.shape == log.targets.shape
        assert set(np.unique(log.binding)) <= set(
            ("convertible", "spot_cap", "envelope", "carry")
        )

    def test_decision_weeks_follow_cadence(self, prov_rep):
        log = prov_rep.decision_log
        mask = np.asarray(prov_rep.decision_mask)
        np.testing.assert_array_equal(
            log.decision_weeks, log.weeks[mask]
        )
        # cadence_weeks=2: every other evaluated week decides.
        np.testing.assert_array_equal(log.is_decision, mask)
        # Non-decision weeks never buy and always carry.
        nondec = ~log.is_decision
        assert float(log.increments[nondec].sum()) == 0.0
        assert np.all(log.binding[nondec] == "carry")

    def test_holdings_reconstruct_active_stack(self, prov_rep):
        log = prov_rep.decision_log
        for week in (int(log.weeks[0]), int(log.weeks[-1])):
            si = int(np.flatnonzero(log.weeks == week)[0])
            held = log.holdings(week)
            for pi, pool in enumerate(log.entities):
                tranche_sum = sum(t["width"] for t in held[pool])
                np.testing.assert_allclose(
                    tranche_sum, log.active[si, pi].sum(), rtol=1e-6,
                    err_msg=f"week {week} pool {pool}",
                )
                for t in held[pool]:
                    assert t["bought_week"] <= week < t["expires_week"]
                    assert t["sku"] in log.skus

    def test_explain_answers_why(self, prov_rep):
        log = prov_rep.decision_log
        w = int(log.decision_weeks[0])
        rec = log.explain(w)
        assert rec["week"] == w and rec["is_decision"]
        pool = rec["pools"][log.entities[0]]
        assert set(pool) == {"binding", "bought", "rolled_off",
                             "target_top", "stack_top"}
        assert "clouds" in rec
        cloud = rec["clouds"][log.conv_clouds[0]]
        assert set(cloud) == {"bought", "rolled_off", "stack_top"}
        with pytest.raises(KeyError, match="not in log"):
            log.explain(10 ** 6)

    def test_summary_and_binding_counts(self, prov_rep):
        log = prov_rep.decision_log
        counts = log.binding_counts()
        assert sum(counts.values()) == log.binding.size
        summ = log.summary()
        assert summ["decision_weeks"] == int(log.is_decision.sum())
        assert summ["tranches_bought"] >= 1
        assert summ["binding_counts"] == counts
        assert "conv_width_bought" in summ
        assert summ["policy"] == "rolling_portfolio"

    def test_spot_free_replay_has_no_spot_cap(self):
        rep = _run_case(
            "rolling_portfolio", 0, 0, 0,
            telemetry=TelemetryConfig(provenance=True),
        )
        log = rep.decision_log
        assert log.conv_clouds is None
        counts = log.binding_counts()
        assert counts["spot_cap"] == 0 and counts["convertible"] == 0
        assert counts["envelope"] >= 1


class TestBreachCadence:
    @pytest.fixture(scope="class")
    def steady_pools(self):
        return sc.scenario_pool_set("steady", num_pools=4, num_weeks=52)

    @pytest.fixture(scope="class")
    def weekly_rep(self, steady_pools):
        return replan.replan_fleet_pools(
            steady_pools, cadence_weeks=1, start_weeks=24,
            horizon_weeks=4, compare=False,
        )

    @pytest.fixture(scope="class")
    def breach_rep(self, steady_pools):
        return replan.replan_fleet_pools(
            steady_pools, cadence_weeks=1, cadence="breach",
            start_weeks=24, horizon_weeks=4, compare=False,
        )

    def test_breach_skips_decisions_at_tiny_cost_delta(
        self, weekly_rep, breach_rep,
    ):
        """The acceptance criterion: >= 60% fewer decision weeks than the
        weekly cadence on a steady fleet, at <= 1% realized-cost delta."""
        n_weekly = int(np.asarray(weekly_rep.decision_mask).sum())
        n_breach = int(np.asarray(breach_rep.decision_mask).sum())
        assert n_breach <= 0.4 * n_weekly, (
            f"breach decided {n_breach}/{n_weekly} weeks"
        )
        cw = float(weekly_rep.total_cost)
        cb = float(breach_rep.total_cost)
        assert abs(cb - cw) / cw <= 0.01, (
            f"cost delta {abs(cb - cw) / cw:.4%} exceeds 1%"
        )

    def test_python_loop_oracle_reproduces_mask_bitwise(
        self, steady_pools, breach_rep,
    ):
        """The in-scan breach mask must equal a host-side python loop
        over the emitted bands bit-for-bit — integer hour counts against
        integer budgets, no float tolerance."""
        start = 24
        h = 168
        demand = np.asarray(steady_pools.demand).reshape(
            len(steady_pools.keys), -1, h
        )
        lo_all = np.asarray(breach_rep.breach_band_lo)
        hi_all = np.asarray(breach_rep.breach_band_hi)
        mask = np.asarray(breach_rep.decision_mask)
        q_lo, q_hi, tol = 0.05, 0.95, 4.0
        allow_above = int(tol * (1.0 - q_hi) * h)
        allow_below = int(tol * q_lo * h)
        want = np.zeros_like(mask)
        lo = np.zeros(demand.shape[0])
        hi = np.zeros(demand.shape[0])
        for i in range(mask.shape[0]):
            w = start + i
            d_prev = demand[:, w - 1]
            above = (d_prev > hi[:, None]).sum(-1)
            below = (d_prev < lo[:, None]).sum(-1)
            dec = bool(
                ((above > allow_above) | (below > allow_below)).any()
                or w == start
            )
            want[i] = dec
            if dec:
                lo, hi = lo_all[i], hi_all[i]
        np.testing.assert_array_equal(want, mask)

    def test_never_misses_a_breach_week(self, steady_pools, breach_rep):
        """Every week whose realized demand exited the held band beyond
        the hour budget IS a decision week (plus the mandatory start)."""
        h = 168
        demand = np.asarray(steady_pools.demand).reshape(
            len(steady_pools.keys), -1, h
        )
        lo_all = np.asarray(breach_rep.breach_band_lo)
        hi_all = np.asarray(breach_rep.breach_band_hi)
        mask = np.asarray(breach_rep.decision_mask)
        allow = int(4.0 * 0.05 * h)
        lo = np.zeros(demand.shape[0])
        hi = np.zeros(demand.shape[0])
        for i in range(mask.shape[0]):
            d_prev = demand[:, 24 + i - 1]
            breached = (
                ((d_prev > hi[:, None]).sum(-1) > allow)
                | ((d_prev < lo[:, None]).sum(-1) > allow)
            ).any()
            if breached or i == 0:
                assert mask[i], f"missed breach at step {i}"
            if mask[i]:
                lo, hi = lo_all[i], hi_all[i]

    def test_report_carries_cadence_and_bands(self, breach_rep):
        assert breach_rep.cadence == "breach"
        assert breach_rep.summary()["cadence"] == "breach"
        s = np.asarray(breach_rep.decision_mask).shape[0]
        assert np.asarray(breach_rep.breach_band_lo).shape == (s, 4)
        assert np.all(
            np.asarray(breach_rep.breach_band_hi)
            >= np.asarray(breach_rep.breach_band_lo)
        )

    def test_weekly_spelling_is_the_golden_path(self, steady_pools):
        """cadence='weekly' (explicit) is the same compiled program as
        the default — same costs bitwise."""
        a = replan.replan_fleet_pools(
            steady_pools, cadence_weeks=2, start_weeks=24,
            horizon_weeks=4, compare=False,
        )
        b = replan.replan_fleet_pools(
            steady_pools, cadence_weeks=2, start_weeks=24,
            horizon_weeks=4, compare=False, cadence="weekly",
        )
        assert a.total_cost == b.total_cost
        np.testing.assert_array_equal(
            np.asarray(a.weekly_cost), np.asarray(b.weekly_cost)
        )

    def test_scenario_batched_breach_masks_per_scenario(self):
        pools = traces.synthetic_pool_set(num_pools=2,
                                          num_hours=24 * 7 * 16)
        rep = replan.replan_fleet_pools(
            pools, cadence_weeks=1, cadence="breach", start_weeks=8,
            horizon_weeks=4, compare=False,
            scenarios=sc.ScenarioConfig(n_scenarios=3, family="regime"),
        )
        mask = np.asarray(rep.decision_mask)
        assert mask.ndim == 2 and mask.shape[1] == 3
        # Scenario 0 is the realized trace: its mask matches the
        # unbatched breach replay of the same pools.
        solo = replan.replan_fleet_pools(
            pools, cadence_weeks=1, cadence="breach", start_weeks=8,
            horizon_weeks=4, compare=False,
        )
        np.testing.assert_array_equal(
            mask[:, 0], np.asarray(solo.decision_mask)
        )
        # Regime scenarios shift demand, so at least one scenario's
        # replan schedule must differ from the realized one.
        assert np.any(mask[:, 1:] != mask[:, :1])

    def test_breach_validation_errors(self, steady_pools):
        with pytest.raises(ValueError, match="cadence"):
            replan.replan_fleet_pools(
                steady_pools, cadence_weeks=1, cadence="hourly",
                start_weeks=24, horizon_weeks=4, compare=False,
            )
        with pytest.raises(ValueError, match="cadence_weeks=1"):
            replan.replan_fleet_pools(
                steady_pools, cadence_weeks=2, cadence="breach",
                start_weeks=24, horizon_weeks=4, compare=False,
            )
        with pytest.raises(ValueError, match="forecast"):
            replan.replan_fleet_pools(
                steady_pools, cadence_weeks=1, cadence="breach",
                policy="deterministic_hedge", start_weeks=24,
                horizon_weeks=4, compare=False,
            )
        with pytest.raises(ValueError, match="cadence"):
            api.RollingConfig(cadence="hourly")
        with pytest.raises(ValueError, match="cadence_weeks=1"):
            api.RollingConfig(cadence="breach", cadence_weeks=2)
        with pytest.raises(ValueError, match="breach_band"):
            api.RollingConfig(breach_band=(0.9, 0.1))
        with pytest.raises(ValueError, match="breach_band"):
            api.RollingConfig(breach_band=(0.05, 0.5, 0.95))
        with pytest.raises(ValueError, match="breach_tolerance"):
            api.RollingConfig(breach_tolerance=0.0)


class TestLedgerScenarios:
    @pytest.fixture(scope="class")
    def batched_rep(self):
        pools = traces.synthetic_pool_set(num_pools=2,
                                          num_hours=24 * 7 * 12)
        return replan.replan_fleet_pools(
            pools, spot=True,
            scenarios=sc.ScenarioConfig(n_scenarios=3, family="growth"),
            cadence_weeks=2, start_weeks=4, horizon_weeks=4,
            compare=False, telemetry=True,
        )

    def test_default_ledger_is_scenario_zero(self, batched_rep):
        led = batched_rep.ledger
        assert led.meta["scenario"] == 0
        assert led.reconcile(batched_rep)["ok"]

    def test_nonzero_scenario_ledger_reconciles_its_column(
        self, batched_rep,
    ):
        led1 = ledger_from_report(batched_rep, scenario=1)
        assert led1.meta["scenario"] == 1
        res = led1.reconcile(batched_rep)          # k from meta
        assert res["ok"], res
        assert res["scenario"] == 1
        np.testing.assert_allclose(
            res["total_report"],
            float(np.asarray(batched_rep.scenario_cost)[1]),
            rtol=1e-6,
        )
        # A growth future genuinely re-prices the fleet.
        assert led1.total != batched_rep.ledger.total
        explicit = led1.reconcile(batched_rep, scenario=1)
        assert explicit["ok"]

    def test_cross_scenario_reconcile_mismatches(self, batched_rep):
        led1 = ledger_from_report(batched_rep, scenario=1)
        res = led1.reconcile(batched_rep, scenario=0)
        assert not res["ok"]

    def test_out_of_range_scenario_raises(self, batched_rep):
        with pytest.raises(ValueError, match="out of range"):
            ledger_from_report(batched_rep, scenario=3)
        with pytest.raises(ValueError, match="out of range"):
            batched_rep.ledger.reconcile(batched_rep, scenario=3)

    def test_unbatched_report_rejects_nonzero_scenario(self, rep_full):
        with pytest.raises(ValueError, match="out of range"):
            ledger_from_report(rep_full, scenario=1)


class TestLedgerEdgeCases:
    def test_unit_economics_idle_only_fleet_is_inf_free(self, rep_full):
        import dataclasses

        led = rep_full.ledger
        idle = dataclasses.replace(
            led,
            used_hours=np.zeros_like(led.used_hours),
            idle_hours=led.idle_hours + led.used_hours,
        )
        econ = idle.unit_economics()
        assert econ["idle_only"] is True
        assert econ["cost_per_used_chip_hour"] == 0.0
        for v in econ.values():
            assert np.isfinite(float(v))
        live = led.unit_economics()
        assert live["idle_only"] is False
        assert live["cost_per_used_chip_hour"] > 0.0

    def test_top_movers_empty_diff(self, rep_full):
        diff = rep_full.ledger.diff(rep_full.ledger)
        assert diff.max_abs_delta == 0.0
        assert diff.top_movers(10) == []
        assert isinstance(diff.report(), str)


class TestCalibCli:
    @pytest.fixture(scope="class")
    def cube_paths(self, calib_cubes, tmp_path_factory):
        tmp = tmp_path_factory.mktemp("calib_cli")
        a = str(tmp / "steady.jsonl")
        b = str(tmp / "rough.jsonl")
        calib_cubes["steady"].to_jsonl(a)
        calib_cubes["unpredictable"].to_jsonl(b)
        return a, b

    def test_report_and_gate(self, cube_paths, tmp_path, capsys):
        a, _ = cube_paths
        out_json = str(tmp_path / "calib.json")
        assert obs_cli(["calib", a, "--json", out_json]) == 0
        assert "coverage" in capsys.readouterr().out
        payload = json.loads(Path(out_json).read_text())
        assert "max_coverage_drift" in payload
        # Permissive gate passes, impossible gate fails with exit 1.
        assert obs_cli(["calib", a, "--fail-above", "0.5"]) == 0
        assert obs_cli(["calib", a, "--fail-above", "0.0"]) == 1
        assert "FAIL" in capsys.readouterr().err

    def test_diff_gate(self, cube_paths, capsys):
        a, b = cube_paths
        assert obs_cli(["calib", a, a]) == 0
        assert obs_cli(["calib", a, b, "--fail-above", "1.0"]) == 0
        assert obs_cli(["calib", a, b, "--fail-above", "0.0"]) == 1
        assert "FAIL" in capsys.readouterr().err


class TestBenchProvenance:
    def test_quick_bench_json_is_stamped(self, tmp_path):
        if str(REPO_ROOT) not in sys.path:
            sys.path.insert(0, str(REPO_ROOT))
        from benchmarks import run as bench_run

        out = str(tmp_path / "BENCH.json")
        spans = str(tmp_path / "SPANS.json")
        bench_run.main([
            "--quick", "--json", out, "--spans", spans,
            "--filter", "commitment_sweep",
        ])
        payload = json.loads(Path(out).read_text())
        assert payload["schema_version"] == bench_run.BENCH_SCHEMA_VERSION
        assert payload["git_sha"] and payload["git_sha"] != ""
        assert payload["quick"] is True and payload["seed"] == 0
        for key in ("jax", "numpy", "backend", "python", "platform"):
            assert payload[key]
        assert payload["rows"] and not payload["failures"]
        assert payload["spans"]  # per-bench wall-clock breakdown
        assert "commitment_sweep" in payload["kernel_stats"]
        span_payload = json.loads(Path(spans).read_text())
        assert span_payload["spans"]

    def test_unknown_filter_exits_nonzero(self):
        if str(REPO_ROOT) not in sys.path:
            sys.path.insert(0, str(REPO_ROOT))
        from benchmarks import run as bench_run

        with pytest.raises(SystemExit):
            bench_run.main(["--quick", "--filter", "no_such_bench"])
