"""Hardware-generation turnover subsystem: pricing tables + invariants,
logistic adoption scan vs loop, driver decomposition recovery, share-based
forecasting, convertible commitments in the one-shot and rolling planners —
plus the no-regression guarantee that migration=None / convertible=None
paths stay bit-identical to the pre-generation planner (hardcoded
goldens)."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.capacity import generations as gn
from repro.capacity import pricing
from repro.core import forecast as fc
from repro.core import ladder as ld
from repro.core import migration as mg
from repro.core import planner as pl
from repro.core import portfolio as pf
from repro.core.demand import HOURS_PER_WEEK
from repro.data import traces

WK = HOURS_PER_WEEK

# Two planted turnovers with epochs that differ from the pricing table —
# recovery tests must prove the fits come from the data, not the table.
PLANT = gn.MigrationConfig(generations=(
    pricing.Generation("aws", "C6i", "C7i", 20, 30.0, 0.25),
    pricing.Generation("gcp", "N2-Standard", "N4-Standard", 55, 26.0, 0.50),
))


class TestPricingTables:
    def test_tables_validate(self):
        pricing.validate_tables(force=True)  # the shipped data must be clean

    def test_corrupted_savings_plan_raises(self, monkeypatch):
        bad = pricing.SavingsPlan("aws", "C6i", 0.60, 0.52)  # 1y > 3y
        monkeypatch.setattr(
            pricing, "SAVINGS_PLANS", [bad] + pricing.SAVINGS_PLANS[1:]
        )
        with pytest.raises(ValueError, match="monotone in term"):
            pricing.validate_tables(force=True)

    def test_corrupted_spot_market_raises(self, monkeypatch):
        bad = pricing.SpotMarket("oraclecloud", 0.5, 0.05, 0.5, 0.1)
        monkeypatch.setattr(
            pricing, "SPOT_MARKETS", pricing.SPOT_MARKETS + [bad]
        )
        with pytest.raises(ValueError, match="unknown cloud"):
            pricing.validate_tables(force=True)

    def test_corrupted_generation_raises(self, monkeypatch):
        bad = pricing.Generation("aws", "C6i", "NotASku", 26, 40.0, 0.25)
        monkeypatch.setattr(
            pricing, "GENERATIONS", pricing.GENERATIONS + [bad]
        )
        with pytest.raises(ValueError, match="Table-2"):
            pricing.validate_tables(force=True)

    def test_chained_generation_raises(self, monkeypatch):
        chain = pricing.Generation("aws", "C7i", "C6i", 10, 10.0, 0.1)
        monkeypatch.setattr(
            pricing, "GENERATIONS", pricing.GENERATIONS + [chain]
        )
        with pytest.raises(ValueError, match="chained"):
            pricing.validate_tables(force=True)

    def test_unsorted_transitions_raise(self, monkeypatch):
        monkeypatch.setattr(
            pricing, "HARDWARE_TRANSITIONS",
            list(reversed(pricing.HARDWARE_TRANSITIONS)),
        )
        with pytest.raises(ValueError, match="date-sorted"):
            pricing.validate_tables(force=True)

    def test_convertible_discounts_haircut(self):
        for c in sorted(pricing.known_clouds()):
            d1, d3 = pricing.convertible_discounts(c)
            rows = [p for p in pricing.SAVINGS_PLANS if p.cloud == c]
            m1 = sum(p.discount_1y for p in rows) / len(rows)
            m3 = sum(p.discount_3y for p in rows) / len(rows)
            assert d1 < m1 and d3 < m3       # flexibility is never free
            assert 0.0 < d1 < d3 < 1.0

    def test_generation_midpoint(self):
        g = pricing.Generation("aws", "C6i", "C7i", 10, 20.0, 0.25)
        assert g.midpoint_week == 20.0


class TestMigrationEdges:
    def test_edges_matched_by_region(self):
        keys = [
            ("aws", "region_0", "C6i"), ("aws", "region_0", "C7i"),
            ("aws", "region_1", "C6i"),      # successor absent -> no edge
            ("gcp", "region_0", "N2-Standard"),
            ("gcp", "region_0", "N4-Standard"),
        ]
        edges = gn.migration_edges(keys, PLANT)
        assert edges.num_edges == 2
        np.testing.assert_array_equal(np.asarray(edges.src), [0, 3])
        np.testing.assert_array_equal(np.asarray(edges.dst), [1, 4])
        np.testing.assert_allclose(np.asarray(edges.uplift), [0.25, 0.5])
        np.testing.assert_allclose(
            np.asarray(edges.inv_gain), [1 / 1.25, 1 / 1.5]
        )

    def test_legacy_fleet_has_no_edges(self):
        pools = traces.synthetic_pool_set(num_pools=3, num_hours=24 * 7)
        assert gn.migration_edges(pools.keys).num_edges == 0

    def test_custom_config_validates_structure(self):
        """Planted rows must satisfy the same structural invariants as the
        static table — a duplicate source would scatter >100% of a pool's
        volume away (negative demand)."""
        with pytest.raises(ValueError, match="duplicate generation source"):
            gn.MigrationConfig(generations=(
                pricing.Generation("aws", "C6i", "C7i", 20, 28.0, 0.25),
                pricing.Generation("aws", "C6i", "M7GD", 20, 28.0, 0.30),
            ))
        with pytest.raises(ValueError, match="chained"):
            gn.MigrationConfig(generations=(
                pricing.Generation("aws", "C6i", "C7i", 20, 28.0, 0.25),
                pricing.Generation("aws", "C7i", "M7GD", 40, 28.0, 0.30),
            ))
        with pytest.raises(ValueError, match="duplicate generation succ"):
            gn.MigrationConfig(generations=(
                pricing.Generation("aws", "C6i", "C7i", 20, 28.0, 0.25),
                pricing.Generation("aws", "C7GD", "C7i", 20, 28.0, 0.30),
            ))
        with pytest.raises(ValueError, match="positive"):
            gn.MigrationConfig(generations=(
                pricing.Generation("aws", "C6i", "C7i", 20, -1.0, 0.25),
            ))
        with pytest.raises(ValueError, match="turnover fleet"):
            traces.synthetic_base_pool_set(
                num_pools=4, num_hours=24, migration=False
            )

    def test_resolve_migration_variants(self):
        assert gn.resolve_migration(None) is None
        assert gn.resolve_migration(False) is None
        assert isinstance(gn.resolve_migration(True), gn.MigrationConfig)
        assert gn.resolve_migration(PLANT) is PLANT
        with pytest.raises(TypeError):
            gn.resolve_migration("yes")


class TestMigrateScan:
    @pytest.fixture(scope="class")
    def setup(self):
        base = traces.synthetic_base_pool_set(
            num_pools=4, num_hours=24 * 7 * 12, seed=2, migration=PLANT
        )
        edges = gn.migration_edges(base.keys, PLANT)
        return base, edges

    def test_scan_matches_loop_bitwise(self, setup):
        """The compiled scan and the per-hour jitted-step replay must
        produce bit-identical demand matrices (acceptance)."""
        base, edges = setup
        d = jnp.asarray(base.demand)
        scan = gn.migrate_demand(d, edges)
        loop = gn.migrate_demand_loop(d, edges)
        np.testing.assert_array_equal(
            np.asarray(scan), np.asarray(loop)
        )

    def test_matches_closed_form(self, setup):
        """The scan's hazard walk IS the closed-form logistic: src keeps
        (1 - s) of its base, dst gains s / (1 + uplift), everyone is
        deflated by the software-efficiency curve."""
        base, edges = setup
        d = np.asarray(gn.migrate_demand(jnp.asarray(base.demand), edges))
        t = jnp.arange(base.num_hours)
        s = np.asarray(gn.adoption_shares(edges, t))
        eff = np.asarray(gn.software_deflator(
            t, PLANT.software_efficiency_per_year
        ))
        src = np.asarray(edges.src)
        dst = np.asarray(edges.dst)
        up = np.asarray(edges.uplift)
        for g in range(edges.num_edges):
            b_src = base.demand[src[g]]
            b_dst = base.demand[dst[g]]
            np.testing.assert_allclose(
                d[src[g]], b_src * (1 - s[g]) * eff, rtol=3e-4, atol=1e-4
            )
            np.testing.assert_allclose(
                d[dst[g]],
                (b_dst + b_src * s[g] / (1 + up[g])) * eff,
                rtol=3e-4, atol=1e-4,
            )

    def test_volume_conservation(self, setup):
        """Perf-adjusted volume (successors x (1 + uplift), deflator
        undone) equals the base volume: turnover moves demand, it does
        not create or destroy it."""
        base, edges = setup
        d = np.asarray(gn.migrate_demand(jnp.asarray(base.demand), edges))
        eff = np.asarray(gn.software_deflator(
            jnp.arange(base.num_hours), PLANT.software_efficiency_per_year
        ))
        perf = np.ones(base.num_pools, np.float32)
        perf[np.asarray(edges.dst)] = 1.0 + np.asarray(edges.uplift)
        got = ((d / eff) * perf[:, None]).sum()
        np.testing.assert_allclose(got, base.demand.sum(), rtol=1e-4)

    def test_no_edges_is_pure_deflation(self):
        pools = traces.synthetic_pool_set(num_pools=2, num_hours=24 * 7 * 2)
        edges = gn.migration_edges(pools.keys)
        out = np.asarray(
            gn.migrate_demand(jnp.asarray(pools.demand), edges)
        )
        eff = np.asarray(gn.software_deflator(
            jnp.arange(pools.num_hours), pricing.SOFTWARE_EFFICIENCY_PER_YEAR
        ))
        np.testing.assert_allclose(out, pools.demand * eff, rtol=1e-5)

    def test_turnover_fleet_shape(self):
        pools = traces.synthetic_pool_set(
            num_pools=8, num_hours=24 * 7 * 2, migration=True
        )
        assert pools.num_pools == 8
        families = {k[2] for k in pools.keys}
        table = {f for g in pricing.GENERATIONS
                 for f in (g.old_family, g.new_family)}
        assert families <= table

    def test_turnover_fleet_rejects_odd_pool_counts(self):
        with pytest.raises(ValueError, match="even"):
            traces.synthetic_pool_set(
                num_pools=13, num_hours=24 * 7, migration=True
            )
        with pytest.raises(ValueError, match="even"):
            traces.synthetic_base_pool_set(num_pools=1, num_hours=24 * 7)


class TestDriverDecomposition:
    @pytest.fixture(scope="class")
    def fleet(self):
        base = traces.synthetic_base_pool_set(
            num_pools=4, num_hours=24 * 7 * 104, seed=3, migration=PLANT
        )
        pools = gn.migrate_pool_set(base, PLANT)
        return base, pools

    def test_recovers_planted_logistics(self, fleet):
        """Fitted midpoints/spans must match the planted S-curves even
        though the decomposer only sees the table's *structure* (which
        pairs exist), not its epochs (acceptance)."""
        base, pools = fleet
        dec = mg.decompose_drivers(pools, migration=PLANT)
        for ef, g in zip(dec.edge_fits, PLANT.generations):
            assert ef.midpoint_weeks == pytest.approx(
                g.midpoint_week, abs=1.0
            )
            assert ef.span_weeks == pytest.approx(g.span_weeks, rel=0.05)

    def test_decompose_rejects_disabled_migration(self, fleet):
        _, pools = fleet
        with pytest.raises(ValueError, match="successor structure"):
            mg.decompose_drivers(pools, migration=False)

    def test_recovers_efficiency_drift(self, fleet):
        base, pools = fleet
        dec = mg.decompose_drivers(
            pools, migration=PLANT, user_volume=base.demand.sum(0)
        )
        assert dec.efficiency_per_year == pytest.approx(
            PLANT.software_efficiency_per_year, rel=0.05
        )

    def test_hardware_index_falls_with_adoption(self, fleet):
        _, pools = fleet
        dec = mg.decompose_drivers(pools, migration=PLANT)
        # Both uplifts > 0: once adoption is underway the fleet needs
        # fewer VMs per old-equivalent VM of work.
        assert dec.hardware_index[-1] < dec.hardware_index[0] - 0.05

    def test_share_prefix_matches_full_fit(self, fleet):
        """solve_share_prefix at the final week must equal the full-window
        fit_share (same moments, gathered vs summed)."""
        _, pools = fleet
        edges = gn.migration_edges(pools.keys, PLANT)
        d = jnp.asarray(pools.demand)
        t_max = float(pools.num_hours - 1)
        a_full, b_full = mg.fit_share(d, edges, t_max=t_max)
        state = mg.share_prefix_state(d, edges, t_max=t_max)
        a_pre, b_pre = mg.solve_share_prefix(
            state, pools.num_hours // WK
        )
        np.testing.assert_allclose(
            np.asarray(a_pre), np.asarray(a_full), rtol=2e-4, atol=2e-4
        )
        np.testing.assert_allclose(
            np.asarray(b_pre), np.asarray(b_full), rtol=2e-4, atol=2e-4
        )

    def test_prior_dominates_pre_launch(self):
        """Before launch the data carries ~no signal, so a prior-weighted
        fit must reproduce the announced curve; a data-only fit must not
        invent one."""
        base = traces.synthetic_base_pool_set(
            num_pools=4, num_hours=24 * 7 * 10, seed=5, migration=PLANT
        )
        pools = gn.migrate_pool_set(base, PLANT)  # 10 weeks << launch 20
        edges = gn.migration_edges(pools.keys, PLANT)
        t_max = float(pools.num_hours - 1)
        a, b = mg.fit_share(
            jnp.asarray(pools.demand), edges, t_max=t_max,
            prior_weight=100.0,
        )
        t_mid = jnp.asarray([
            g.midpoint_week * WK for g in PLANT.generations
        ])
        s_mid = mg.predict_share(a, b, t_mid, t_max)
        # at the announced midpoint the prior-backed fit predicts ~50%
        np.testing.assert_allclose(
            np.asarray(jnp.diagonal(s_mid)), 0.5, atol=0.1
        )

    def test_transform_and_compose_roundtrip(self, fleet):
        """compose_forecast(transform totals, true shares) reproduces the
        per-pool series."""
        _, pools = fleet
        edges = gn.migration_edges(pools.keys, PLANT)
        d = jnp.asarray(pools.demand)
        totals = mg.transform_for_fit(d, edges)
        z, _ = mg.share_observations(d, edges)
        shares = jax.nn.sigmoid(z)
        out = np.asarray(mg.compose_forecast(totals, shares, edges))
        np.testing.assert_allclose(
            out, np.asarray(d), rtol=1e-3, atol=1e-2
        )


class TestShareForecast:
    def test_reduces_error_on_migrating_pools(self):
        """Acceptance: mid-migration, the share-based forecaster beats the
        raw per-pool structural fit on the migrating pools (summed
        weighted MAPE over each turnover pair)."""
        pools = traces.synthetic_pool_set(
            num_pools=4, num_hours=24 * 7 * 80, seed=3, migration=PLANT
        )
        edges = gn.migration_edges(pools.keys, PLANT)
        h = 8 * WK
        hist = jnp.asarray(pools.demand[:, :-h], jnp.float32)
        actual = jnp.asarray(pools.demand[:, -h:], jnp.float32)
        t_fut = hist.shape[-1] + jnp.arange(h)
        cfg = fc.ForecastConfig()

        raw = fc.predict_batched(fc.fit_batched(hist, cfg), t_fut)

        t_max = float(hist.shape[-1] - 1)
        tot = fc.predict_batched(
            fc.fit_batched(mg.transform_for_fit(hist, edges), cfg), t_fut
        )
        a, b = mg.fit_share(hist, edges, t_max=t_max, prior_weight=100.0)
        sh = mg.predict_share(a, b, t_fut, t_max)
        composed = mg.compose_forecast(jnp.asarray(tot), sh, edges)

        err_raw = np.asarray(fc.weighted_mape(actual, jnp.asarray(raw)))
        err_mig = np.asarray(fc.weighted_mape(actual, composed))
        migrating = sorted(
            set(np.asarray(edges.src)) | set(np.asarray(edges.dst))
        )
        # A pair whose turnover already completed forecasts ~identically
        # either way; what must improve is the migrating fleet as a whole.
        assert err_mig[migrating].sum() < err_raw[migrating].sum()


class TestConvertibleOptions:
    def test_rates_carry_the_haircut(self):
        conv = pf.convertible_options_from_pricing(["aws"])
        std = pf.options_from_pricing(clouds=["aws"])
        for term in (52, 156):
            c = [o for o in conv if o.term_weeks == term]
            s = [o for o in std if o.term_weeks == term]
            assert len(c) == 1 and all(o.convertible for o in c)
            # convertible is pricier than the cloud's mean standard rate
            mean_std = sum(o.rate for o in s) / len(s)
            assert c[0].rate > mean_std
        # but still far below on-demand
        assert all(o.rate < 2.0 for o in conv)

    def test_resolve_variants(self):
        clouds = ("aws", "gcp", "aws")
        assert pf.resolve_convertible(None, clouds) is None
        assert pf.resolve_convertible(False, clouds) is None
        got = pf.resolve_convertible(True, clouds)
        assert {o.cloud for o in got} == {"aws", "gcp"}
        assert pf.resolve_convertible(got, clouds) == got
        # an empty list means "no convertible SKUs" = disabled, not a
        # zero-option solve that would crash downstream
        assert pf.resolve_convertible([], clouds) is None
        with pytest.raises(TypeError):
            pf.resolve_convertible(pf.options_from_pricing(), clouds)

    def test_allocate_convertible_scarce(self):
        """Width below the cloud's total need: everything is handed out,
        proportionally, never past any pool's need, never across clouds."""
        member = jnp.asarray([[1.0, 1.0, 0.0], [0.0, 0.0, 1.0]])
        need = np.asarray([4.0, 20.0, 2.0])
        alloc = np.asarray(pf.allocate_convertible(
            jnp.asarray([12.0, 1.5]), jnp.asarray(need), member,
        ))
        assert (alloc <= need + 1e-5).all()
        np.testing.assert_allclose(
            np.asarray(member) @ alloc, [12.0, 1.5], atol=1e-4
        )

    def test_allocate_convertible_surplus_idles(self):
        """Width beyond the cloud's need: every pool is filled to its need
        and the leftover stays unallocated (it bills either way)."""
        member = jnp.asarray([[1.0, 1.0, 0.0], [0.0, 0.0, 1.0]])
        need = np.asarray([2.0, 20.0, 2.0])
        alloc = np.asarray(pf.allocate_convertible(
            jnp.asarray([30.0, 5.0]), jnp.asarray(need), member,
        ))
        np.testing.assert_allclose(alloc, need, atol=1e-3)

    def test_convertible_ladder_book_keys(self):
        targets = np.zeros((2, 3, 1), np.float32)
        targets[:, 0, 0] = [5.0, 7.0]
        book = ld.convertible_ladder_book(
            targets, np.asarray([52 * WK]), ["aws", "gcp"]
        )
        assert book.keys == (
            ("aws", "*", "convertible"), ("gcp", "*", "convertible"),
        )
        np.testing.assert_allclose(
            book.option_widths(0, 1)[:, 0], [5.0, 7.0]
        )


class TestRollingMigrationConvertible:
    @pytest.fixture(scope="class")
    def fleet(self):
        plant = gn.MigrationConfig(generations=(
            pricing.Generation("aws", "C6i", "C7i", 8, 12.0, 0.25),
            pricing.Generation(
                "gcp", "N2-Standard", "N4-Standard", 16, 10.0, 0.50
            ),
        ))
        pools = traces.synthetic_pool_set(
            num_pools=4, num_hours=24 * 7 * 30, seed=3, migration=plant
        )
        return plant, pools

    @pytest.fixture(scope="class")
    def report(self, fleet):
        plant, pools = fleet
        return pl.plan_fleet_pools(
            pools, mode="rolling", cadence_weeks=2, start_weeks=8,
            horizon_weeks=6, compare=False, migration=plant,
            convertible=True,
        )

    def test_report_fields_and_accounting(self, report):
        s, c, kc = report.conv_targets.shape
        assert s == len(report.weeks)
        assert c == len(report.conv_clouds)
        assert kc == len(report.conv_options)
        assert report.conv_alloc.shape == report.committed_cost.shape
        want = float(
            report.committed_cost.sum() + report.on_demand_cost.sum()
            + report.conv_committed_cost.sum()
        )
        assert report.total_cost == pytest.approx(want, rel=1e-6)
        assert report.weekly_cost.sum() == pytest.approx(want, rel=1e-6)
        assert report.migration_edges.num_edges == 2

    def test_conv_ladder_reconciles_with_scan(self, report):
        """Acceptance: the cloud-level convertible book's live widths must
        equal the scan's carried cloud-level stack every week."""
        for i, w in enumerate(report.weeks):
            want = report.conv_active[i]
            got = report.conv_ladders.option_widths(
                int(w) * WK, len(report.conv_options)
            )
            np.testing.assert_allclose(got, want, atol=1e-4)

    def test_standard_ladder_reconciles_under_suppression(self, report):
        """Live convertible capacity suppresses standard purchases, so the
        book replays the realized stack — and must still match it."""
        for i, w in enumerate(report.weeks):
            got = report.ladders.option_widths(
                int(w) * WK, len(report.options)
            )
            np.testing.assert_allclose(got, report.active[i], atol=1e-4)

    def test_conv_allocation_stays_inside_cloud(self, report):
        member = np.asarray([
            [1.0 if c == k[0] else 0.0 for k in report.keys]
            for c in report.conv_clouds
        ])
        for i in range(len(report.weeks)):
            per_cloud = member @ report.conv_alloc[i]
            width = report.conv_active[i].sum(-1)
            assert (per_cloud <= width + 1e-3).all()

    def test_scan_matches_loop(self, fleet):
        plant, pools = fleet
        kw = dict(
            mode="rolling", cadence_weeks=2, start_weeks=8,
            horizon_weeks=4, compare=False, migration=plant,
            convertible=True,
        )
        scan = pl.plan_fleet_pools(pools, backend="scan", **kw)
        loop = pl.plan_fleet_pools(pools, backend="loop", **kw)
        np.testing.assert_allclose(
            scan.total_cost, loop.total_cost, rtol=1e-4
        )

    def test_grid_solver_close_to_quantile(self, fleet, report):
        plant, pools = fleet
        grid = pl.plan_fleet_pools(
            pools, mode="rolling", cadence_weeks=2, start_weeks=8,
            horizon_weeks=6, compare=False, migration=plant,
            convertible=True, solver="grid", num_grid=128,
        )
        assert grid.total_cost == pytest.approx(
            report.total_cost, rel=0.02
        )

    def test_one_shot_carries_conv_fields(self, fleet):
        plant, pools = fleet
        plan = pl.plan_fleet_pools(
            pools, horizon_weeks=6, migration=plant, convertible=True
        )
        assert plan.migration_edges.num_edges == 2
        assert plan.conv_widths.shape == (
            len(plan.conv_clouds), len(plan.conv_options)
        )
        assert plan.conv_cost >= 0.0
        assert plan.conv_ladders.keys[0][2] == "convertible"
        # accounting: conv spend is part of the reported total
        base = sum(float(e.spend.committed.sum()) for e in plan.per_pool)
        od = sum(e.spend.on_demand for e in plan.per_pool)
        assert plan.total_cost == pytest.approx(
            base + od + plan.conv_cost, rel=1e-6
        )


# Outputs of the pre-generation planner (PR 4 HEAD) on the scenario below —
# the migration=None / convertible=None paths must keep reproducing them
# bit for bit (allclose guards only against BLAS last-ulp drift).  The
# one-shot pins were refreshed in PR 7 after the same ~1e-5 toolchain
# drift test_spot's goldens caught (see TestGoldenIsolation there).
GOLDEN_POOLS = dict(num_pools=4, num_hours=24 * 7 * 24, seed=5)
GOLDEN_ONE_SHOT_TOTAL = 295006.96253740025
GOLDEN_ONE_SHOT_POOL_WIDTHS = [
    45.397674560546875, 159.97650146484375, 72.62496948242188,
    110.23088073730469,
]
GOLDEN_ROLLING = dict(cadence_weeks=2, start_weeks=8, horizon_weeks=4)
GOLDEN_ROLLING_TOTAL = 1118779.375
GOLDEN_ROLLING_TARGETS_SUM = 5942.73388671875
GOLDEN_ROLLING_INC_SUM = 414.34368896484375
GOLDEN_ROLLING_GRID_TOTAL = 1118972.25
GOLDEN_ROLLING_GRID_INC_SUM = 412.8358459472656
GOLDEN_STACK_COST = [78608.2421875, 72014.28125, 75383.375]
GOLDEN_GRID_COST = [78648.7578125, 72030.34375, 75404.921875]


class TestMigrationDisabledBitIdentical:
    """Satellite: migration=None / convertible=None reproduce the pre-PR
    outputs exactly on every path — one-shot, rolling, grid and
    stacked-quantile solvers — mirroring the PR 4 spot=None goldens."""

    @pytest.fixture(scope="class")
    def pools(self):
        return traces.synthetic_pool_set(**GOLDEN_POOLS)

    @pytest.mark.parametrize("off", [None, False])
    def test_one_shot_golden(self, pools, off):
        plan = pl.plan_fleet_pools(
            pools, horizon_weeks=4, migration=off, convertible=off
        )
        np.testing.assert_allclose(
            plan.total_cost, GOLDEN_ONE_SHOT_TOTAL, rtol=1e-6
        )
        np.testing.assert_allclose(
            plan.widths.astype(np.float64).sum(1),
            GOLDEN_ONE_SHOT_POOL_WIDTHS, rtol=1e-6,
        )
        assert plan.migration_edges is None
        assert plan.conv_options is None
        assert plan.conv_widths is None
        assert plan.conv_cost == 0.0

    @pytest.mark.parametrize("off", [None, False])
    def test_rolling_golden(self, pools, off):
        rep = pl.plan_fleet_pools(
            pools, mode="rolling", compare=False, migration=off,
            convertible=off, **GOLDEN_ROLLING,
        )
        np.testing.assert_allclose(
            rep.total_cost, GOLDEN_ROLLING_TOTAL, rtol=1e-6
        )
        np.testing.assert_allclose(
            float(rep.targets.sum()), GOLDEN_ROLLING_TARGETS_SUM, rtol=1e-6
        )
        np.testing.assert_allclose(
            float(rep.increments.sum()), GOLDEN_ROLLING_INC_SUM, rtol=1e-6
        )
        assert rep.conv_options is None
        assert rep.conv_active is None
        assert rep.migration_edges is None

    def test_rolling_grid_golden(self, pools):
        rep = pl.plan_fleet_pools(
            pools, mode="rolling", compare=False, solver="grid",
            num_grid=64, **GOLDEN_ROLLING,
        )
        np.testing.assert_allclose(
            rep.total_cost, GOLDEN_ROLLING_GRID_TOTAL, rtol=1e-6
        )
        np.testing.assert_allclose(
            float(rep.increments.sum()), GOLDEN_ROLLING_GRID_INC_SUM,
            rtol=1e-6,
        )

    def test_solver_goldens(self):
        rng = np.random.default_rng(17)
        f = jnp.asarray(rng.gamma(2.0, 40.0, (3, 600)).astype(np.float32))
        opts = pf.options_from_pricing()
        al, be, _ = pf.pool_option_lines(opts, ("aws", "azure", "gcp"))
        stack = jax.vmap(
            lambda f_, a_, b_: pf.optimal_portfolio_stack(
                f_, a_, b_, od_rate=2.1
            )
        )(f, al, be)
        np.testing.assert_allclose(
            np.asarray(stack.cost, np.float64), GOLDEN_STACK_COST,
            rtol=1e-6,
        )
        grid = pf.optimal_portfolio_grid(f, al, be, od_rate=2.1, num_grid=64)
        np.testing.assert_allclose(
            np.asarray(grid.cost, np.float64), GOLDEN_GRID_COST, rtol=1e-6
        )


class TestTwoTurnoverAcceptance:
    """Acceptance: on a synthetic 3-year fleet with two family turnovers,
    migration-aware rolling with convertible commitments beats the
    migration-blind rolling plan by >= 5% (the planner sees the turnover
    window, the blind baseline keeps buying on dying families)."""

    @pytest.fixture(scope="class")
    def reports(self):
        two = gn.MigrationConfig(generations=(
            pricing.Generation("aws", "C6i", "C7i", 30, 40.0, 0.25),
            pricing.Generation(
                "gcp", "N2-Standard", "N4-Standard", 85, 36.0, 0.50
            ),
        ))
        pools = traces.synthetic_pool_set(
            num_pools=4, num_hours=24 * 7 * 156, seed=7, migration=two
        )
        kw = dict(
            mode="rolling", cadence_weeks=2, start_weeks=26,
            horizon_weeks=52, compare=False,
        )
        blind = pl.plan_fleet_pools(pools, **kw)
        aware = pl.plan_fleet_pools(
            pools, migration=two, convertible=True, **kw
        )
        return blind, aware

    def test_margin_at_least_5pct(self, reports):
        blind, aware = reports
        margin = 1.0 - aware.total_cost / blind.total_cost
        assert margin >= 0.05, f"margin {margin:.3f} below 5%"

    def test_convertible_capacity_was_bought_and_pinned(self, reports):
        _, aware = reports
        assert float(aware.conv_active[-1].sum()) > 1.0
        assert float(aware.conv_alloc.sum()) > 0.0
        # the convertible band suppressed some standard purchases
        assert aware.conv_committed_cost.sum() > 0.0
