"""Per-kernel correctness: shape/dtype sweeps against the pure-jnp oracles,
executed in interpret mode (TPU kernels, CPU validation)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import commitment as cm
from repro.kernels.commitment_sweep.ops import (
    SWEEP_HBM_PASS_BUDGET,
    SWEEP_VMEM_BUDGET,
    commitment_sweep,
    commitment_sweep_oracle,
    optimal_commitment_sweep,
    sweep_block_plan,
)
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.linrec.ops import (
    rwkv6_linear_attention,
    rwkv6_oracle,
    rwkv6_step,
)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # degrade to the deterministic tests only
    HAVE_HYPOTHESIS = False

RNG = np.random.default_rng(42)


def randn(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype=dtype)


# ---------------------------------------------------------------------------
# commitment_sweep
# ---------------------------------------------------------------------------

class TestCommitmentSweep:
    @pytest.mark.parametrize("p,t,g", [
        (1, 100, 9),          # paper Fig 4 scenario scan
        (5, 700, 37),
        (8, 512, 128),        # exactly one block
        (9, 513, 129),        # ragged everything
        (16, 24 * 7 * 4, 64),
    ])
    def test_shapes_vs_oracle(self, p, t, g):
        f = jnp.asarray(RNG.gamma(2, 50, (p, t)).astype(np.float32))
        cs = jnp.linspace(float(f.min()), float(f.max()), g)
        np.testing.assert_allclose(
            commitment_sweep(f, cs),
            commitment_sweep_oracle(f, cs),
            rtol=2e-4, atol=1e-2,
        )

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        f = jnp.asarray(RNG.gamma(2, 50, (4, 300)), dtype=dtype)
        cs = jnp.linspace(10.0, 300.0, 33).astype(dtype)
        tol = 1e-4 if dtype == jnp.float32 else 6e-3
        np.testing.assert_allclose(
            commitment_sweep(f, cs),
            commitment_sweep_oracle(f, cs),
            rtol=tol, atol=tol * 1e3,
        )

    def test_weights_mask_prefix(self):
        """Weighted sweep == unweighted sweep on the prefix (Algorithm 1)."""
        f = jnp.asarray(RNG.gamma(2, 50, (2, 400)).astype(np.float32))
        cs = jnp.linspace(10.0, 300.0, 17)
        w = jnp.zeros_like(f).at[:, :250].set(1.0)
        np.testing.assert_allclose(
            commitment_sweep(f, cs, w),
            commitment_sweep_oracle(f[:, :250], cs),
            rtol=2e-4, atol=1e-2,
        )

    def test_matches_core_cost_curve(self):
        f = jnp.asarray(RNG.gamma(2, 50, (200,)).astype(np.float32))
        cs = jnp.linspace(float(f.min()), float(f.max()), 21)
        np.testing.assert_allclose(
            commitment_sweep(f, cs), cm.cost_curve(f, cs), rtol=2e-4,
        )

    def test_grid_refine_matches_exact(self):
        f = jnp.asarray(RNG.gamma(2, 60, (6, 24 * 14)).astype(np.float32))
        c_gr = optimal_commitment_sweep(f)
        c_ex = cm.optimal_commitment_quantile(f)
        for i in range(6):
            assert float(cm.commitment_cost(f[i], c_gr[i])) <= float(
                cm.commitment_cost(f[i], c_ex[i])
            ) * (1 + 1e-3)


class TestSweepBlockPlan:
    """Budgeted block-size chooser: historical defaults preserved, the
    R3 divisor/lane invariants hold, and both budgets bind."""

    @pytest.mark.parametrize("p,g,t", [
        (8, 128, 24 * 7 * 156),   # 3-year trace, one candidate tile
        (3, 52, 3360),            # the rolling replay's weekly shape
        (8, 64, 100),             # tiny everything
        (16, 1024, 24 * 7 * 40),
    ])
    def test_historical_defaults_preserved(self, p, g, t):
        """Every pre-fleet-scale shape gets exactly the old
        (8, min(128, G_pad), min(512, T_pad)) choice — accumulation order
        (and bit-exact kernel output) unchanged."""
        def rup(x, m):
            return (x + m - 1) // m * m
        expect = (8, min(128, rup(g, 128)), min(512, rup(t, 128)))
        assert sweep_block_plan(p, g, t) == expect

    @pytest.mark.parametrize("p,g,t", [
        (1024, 4096, 24 * 7 * 156),   # P~1000 fleet, wide refine grid
        (1024, 2048, 8736),
        (16, 10_000, 500),            # pathologically wide grid
        (8, 128, 128),
    ])
    def test_budgets_and_lane_invariants(self, p, g, t):
        bp, bg, bt = sweep_block_plan(p, g, t)
        assert bp % 8 == 0 and bg % 128 == 0 and bt % 128 == 0
        # VMEM is the hard constraint (broadcast tmp, fp32).
        assert bp * bg * bt * 4 <= SWEEP_VMEM_BUDGET
        # The pass budget binds whenever VMEM allows it.
        bg_max = SWEEP_VMEM_BUDGET // (bp * 128 * 4) // 128 * 128
        if -(-g // bg_max) <= SWEEP_HBM_PASS_BUDGET:
            assert -(-g // bg) <= SWEEP_HBM_PASS_BUDGET

    def test_blocks_divide_padded_dims(self):
        """ops.py pads each dim up to its block, so grid = padded//block
        is exact — the R3 contract's divisor property by construction."""
        def rup(x, m):
            return (x + m - 1) // m * m
        for (p, g, t) in [(9, 513, 129), (1000, 3000, 26280), (1, 1, 1)]:
            bp, bg, bt = sweep_block_plan(p, g, t)
            assert rup(p, bp) % bp == 0
            assert rup(g, bg) % bg == 0
            assert rup(t, bt) % bt == 0

    def test_wide_grid_matches_oracle(self):
        """A G > 1024 sweep (multi-tile candidate grids at a grown bg)
        still matches the reference bit-for-bit-close."""
        f = jnp.asarray(RNG.gamma(2, 50, (5, 700)).astype(np.float32))
        cs = jnp.asarray(
            np.sort(RNG.uniform(0, 300, (5, 1500))).astype(np.float32)
        )
        np.testing.assert_allclose(
            commitment_sweep(f, cs),
            commitment_sweep_oracle(f, cs),
            rtol=2e-4, atol=1e-2,
        )


if HAVE_HYPOTHESIS:
    class TestCommitmentSweepProperties:
        @settings(max_examples=15, deadline=None)
        @given(
            a=st.floats(1.0, 4.0), b=st.floats(0.25, 2.0),
            seed=st.integers(0, 10_000),
        )
        def test_property_ab_weighting(self, a, b, seed):
            rng = np.random.default_rng(seed)
            f = jnp.asarray(rng.gamma(2, 50, (3, 257)).astype(np.float32))
            cs = jnp.linspace(float(f.min()), float(f.max()), 13)
            np.testing.assert_allclose(
                commitment_sweep(f, cs, a=a, b=b),
                commitment_sweep_oracle(f, cs, a=a, b=b),
                rtol=3e-4, atol=1e-2,
            )
else:
    class TestCommitmentSweepProperties:
        def test_property_ab_weighting(self):
            pytest.importorskip("hypothesis")


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

class TestFlashAttention:
    @pytest.mark.parametrize("b,hq,hkv,sq,skv,d", [
        (1, 4, 4, 128, 128, 64),    # MHA, exact blocks
        (2, 8, 2, 200, 200, 64),    # GQA 4:1, ragged seq
        (1, 8, 1, 64, 64, 128),     # MQA
        (2, 4, 2, 1, 300, 64),      # decode: single query
        (1, 2, 2, 96, 160, 32),     # cross-ish lengths
    ])
    def test_shapes_vs_oracle(self, b, hq, hkv, sq, skv, d):
        q = randn((b, hq, sq, d))
        k = randn((b, hkv, skv, d))
        v = randn((b, hkv, skv, d))
        np.testing.assert_allclose(
            flash_attention(q, k, v, causal=True),
            attention_ref(q, k, v, causal=True),
            atol=2e-5, rtol=1e-4,
        )

    def test_noncausal(self):
        q, k, v = randn((2, 4, 100, 64)), randn((2, 2, 150, 64)), randn((2, 2, 150, 64))
        np.testing.assert_allclose(
            flash_attention(q, k, v, causal=False),
            attention_ref(q, k, v, causal=False),
            atol=2e-5, rtol=1e-4,
        )

    def test_kv_len_padded_cache(self):
        """Decode against a partially-filled, padded KV cache."""
        q = randn((2, 8, 1, 64))
        k = randn((2, 2, 384, 64))
        v = randn((2, 2, 384, 64))
        out = flash_attention(q, k, v, causal=True, kv_len=257)
        ref = attention_ref(q, k[:, :, :257], v[:, :, :257], causal=True)
        np.testing.assert_allclose(out, ref, atol=2e-5, rtol=1e-4)

    @pytest.mark.parametrize("dtype,atol", [
        (jnp.float32, 2e-5), (jnp.bfloat16, 2e-2),
    ])
    def test_dtypes(self, dtype, atol):
        q = randn((1, 4, 128, 64), dtype)
        k = randn((1, 2, 128, 64), dtype)
        v = randn((1, 2, 128, 64), dtype)
        out = flash_attention(q, k, v, causal=True)
        ref = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(
            out.astype(jnp.float32), ref.astype(jnp.float32),
            atol=atol, rtol=1e-2,
        )
        assert out.dtype == dtype

    def test_causality_property(self):
        """Perturbing future tokens must not change past outputs."""
        q, k, v = randn((1, 2, 64, 32)), randn((1, 2, 64, 32)), randn((1, 2, 64, 32))
        out1 = flash_attention(q, k, v, causal=True)
        k2 = k.at[:, :, 50:, :].add(10.0)
        v2 = v.at[:, :, 50:, :].add(10.0)
        out2 = flash_attention(q, k2, v2, causal=True)
        np.testing.assert_allclose(
            out1[:, :, :50], out2[:, :, :50], atol=1e-5, rtol=1e-5
        )


# ---------------------------------------------------------------------------
# linrec (RWKV6)
# ---------------------------------------------------------------------------

class TestLinrec:
    @pytest.mark.parametrize("b,h,t,d,chunk", [
        (1, 2, 32, 16, 32),    # single chunk
        (2, 3, 70, 16, 16),    # ragged
        (1, 4, 128, 64, 32),   # rwkv6 head_size
        (2, 2, 33, 32, 32),    # T = chunk + 1
    ])
    def test_shapes_vs_oracle(self, b, h, t, d, chunk):
        r, k, v = randn((b, h, t, d)), randn((b, h, t, d)), randn((b, h, t, d))
        w = jnp.asarray(RNG.uniform(0.2, 1.0, (b, h, t, d)).astype(np.float32))
        u = randn((h, d))
        y_k, s_k = rwkv6_linear_attention(r, k, v, w, u, chunk=chunk)
        y_r, s_r = rwkv6_oracle(r, k, v, w, u)
        np.testing.assert_allclose(y_k, y_r, atol=2e-3, rtol=2e-3)
        np.testing.assert_allclose(s_k, s_r, atol=2e-3, rtol=2e-3)

    def test_strong_decay_stability(self):
        """Decays near 0 (logw very negative) must not overflow/NaN — this is
        the case that breaks the factored r~/k~ formulation."""
        b, h, t, d = 1, 2, 64, 16
        r, k, v = randn((b, h, t, d)), randn((b, h, t, d)), randn((b, h, t, d))
        w = jnp.full((b, h, t, d), 1e-6, jnp.float32)
        u = randn((h, d))
        y_k, s_k = rwkv6_linear_attention(r, k, v, w, u, chunk=32)
        y_r, s_r = rwkv6_oracle(r, k, v, w, u)
        assert jnp.isfinite(y_k).all()
        np.testing.assert_allclose(y_k, y_r, atol=2e-3, rtol=2e-3)

    def test_step_consistency(self):
        """T sequential decode steps == one chunked call."""
        b, h, t, d = 1, 2, 17, 16
        r, k, v = randn((b, h, t, d)), randn((b, h, t, d)), randn((b, h, t, d))
        w = jnp.asarray(RNG.uniform(0.3, 1.0, (b, h, t, d)).astype(np.float32))
        u = randn((h, d))
        y_full, s_full = rwkv6_linear_attention(r, k, v, w, u, chunk=16)
        s = jnp.zeros((b, h, d, d), jnp.float32)
        ys = []
        for i in range(t):
            y_i, s = rwkv6_step(r[:, :, i], k[:, :, i], v[:, :, i], w[:, :, i], u, s)
            ys.append(y_i)
        np.testing.assert_allclose(
            jnp.stack(ys, 2), y_full, atol=2e-3, rtol=2e-3
        )
        np.testing.assert_allclose(s, s_full, atol=2e-3, rtol=2e-3)

    def test_state_carry_across_calls(self):
        """Splitting a sequence across two kernel calls == one call."""
        b, h, t, d = 2, 2, 64, 16
        r, k, v = randn((b, h, t, d)), randn((b, h, t, d)), randn((b, h, t, d))
        w = jnp.asarray(RNG.uniform(0.3, 1.0, (b, h, t, d)).astype(np.float32))
        u = randn((h, d))
        y_full, s_full = rwkv6_linear_attention(r, k, v, w, u, chunk=16)
        y1, s1 = rwkv6_linear_attention(
            r[:, :, :32], k[:, :, :32], v[:, :, :32], w[:, :, :32], u, chunk=16)
        y2, s2 = rwkv6_linear_attention(
            r[:, :, 32:], k[:, :, 32:], v[:, :, 32:], w[:, :, 32:], u,
            state=s1, chunk=16)
        np.testing.assert_allclose(
            jnp.concatenate([y1, y2], 2), y_full, atol=2e-3, rtol=2e-3
        )
        np.testing.assert_allclose(s2, s_full, atol=2e-3, rtol=2e-3)
